//! Minimal offline stand-in for the `anyhow` crate.
//!
//! The real crates.io registry is unavailable in this environment
//! (DESIGN.md §2 offline-substrate note), so this vendored shim implements
//! exactly the subset the workspace uses: `Error`, `Result`, the `anyhow!`
//! macro, and the `Context` extension trait. Error values carry a flattened
//! message string — no backtraces, no downcasting.

use std::fmt;

/// Boxed-string error type. Deliberately does **not** implement
/// `std::error::Error`, which is what makes the blanket `From` impl below
/// coherent (the same trick the real crate uses).
pub struct Error {
    msg: String,
}

impl Error {
    pub fn msg<M: fmt::Display>(m: M) -> Error {
        Error { msg: m.to_string() }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        Error::msg(&e)
    }
}

pub type Result<T, E = Error> = std::result::Result<T, E>;

/// `.context(..)` / `.with_context(|| ..)` on any displayable error.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F)
                                                       -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{c}: {e}")))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F)
                                                       -> Result<T> {
        self.map_err(|e| Error::msg(format!("{}: {e}", f())))
    }
}

/// Construct an [`Error`] from a format string or any `Display` value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg(format!("{}", $err))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::{Context, Error, Result};

    #[test]
    fn macro_forms() {
        let a: Error = anyhow!("plain");
        assert_eq!(a.to_string(), "plain");
        let x = 3;
        let b: Error = anyhow!("x = {x}");
        assert_eq!(b.to_string(), "x = 3");
        let c: Error = anyhow!("x = {}", x);
        assert_eq!(c.to_string(), "x = 3");
        let d: Error = anyhow!(String::from("owned"));
        assert_eq!(d.to_string(), "owned");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn f() -> Result<String> {
            let s = std::fs::read_to_string("/definitely/not/a/file")?;
            Ok(s)
        }
        assert!(f().is_err());
    }

    #[test]
    fn context_prepends() {
        let r: std::result::Result<(), std::fmt::Error> =
            Err(std::fmt::Error);
        let e = r.context("outer").unwrap_err();
        assert!(e.to_string().starts_with("outer: "));
        let r: std::result::Result<(), std::fmt::Error> =
            Err(std::fmt::Error);
        let e = r.with_context(|| format!("n {}", 2)).unwrap_err();
        assert!(e.to_string().starts_with("n 2: "));
    }
}
