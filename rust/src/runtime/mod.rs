//! Runtime layer: execute AOT-compiled model artifacts on the request path.
//!
//! Two interchangeable backends share one API surface (`Runtime`,
//! `PjrtModel`) so the harness/bench/CLI layers compile identically:
//!
//! * `pjrt` (feature `pjrt`) — the real thing: loads `artifacts/*.hlo.txt`
//!   via the offline `xla` crate and executes through PJRT.
//! * `stub` (default) — for environments without the `xla` vendor set;
//!   `Runtime::cpu()` errors at startup and every artifact-driven path
//!   falls back to its "skipped" branch. Mock-model serving is unaffected.
//!
//! `manifest` (artifact discovery) is backend-independent pure JSON.

pub mod manifest;

#[cfg(feature = "pjrt")]
mod pjrt;
#[cfg(feature = "pjrt")]
pub use pjrt::{PjrtModel, Runtime};

#[cfg(not(feature = "pjrt"))]
mod stub;
#[cfg(not(feature = "pjrt"))]
pub use stub::{PjrtModel, Runtime};

pub use manifest::{Manifest, ModelConfig, ModelEntry};
