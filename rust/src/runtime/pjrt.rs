//! PJRT runtime: load AOT HLO-text artifacts and execute them on the
//! request path (pattern adapted from /opt/xla-example/load_hlo).
//!
//! One `PjRtClient` per process; each model variant compiles one executable
//! per (draft|verify, batch bucket) pair at startup. Python is never
//! involved after `make artifacts` — the HLO carries the trained weights as
//! constants. Compiled only with `--features pjrt` (requires the offline
//! `xla` vendor set); `runtime::stub` replaces it otherwise.

use std::collections::BTreeMap;

use anyhow::{anyhow, Context, Result};

use crate::engine::HybridModel;
use crate::runtime::manifest::{ModelConfig, ModelEntry};

/// Process-wide PJRT client wrapper.
pub struct Runtime {
    client: xla::PjRtClient,
}

impl Runtime {
    pub fn cpu() -> Result<Runtime> {
        let client =
            xla::PjRtClient::cpu().map_err(|e| anyhow!("pjrt cpu: {e}"))?;
        Ok(Runtime { client })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    fn compile_file(&self, path: &std::path::Path)
                    -> Result<xla::PjRtLoadedExecutable> {
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("bad path"))?,
        )
        .map_err(|e| anyhow!("parse {}: {e}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        self.client
            .compile(&comp)
            .map_err(|e| anyhow!("compile {}: {e}", path.display()))
    }

    /// Load + compile all buckets of one manifest entry.
    pub fn load_model(&self, entry: &ModelEntry) -> Result<PjrtModel> {
        let mut draft = BTreeMap::new();
        for (&b, path) in &entry.draft {
            draft.insert(b, self.compile_file(path).with_context(|| {
                format!("loading draft bucket {b} of {}", entry.name)
            })?);
        }
        let mut verify = BTreeMap::new();
        for (&b, path) in &entry.verify {
            verify.insert(b, self.compile_file(path).with_context(|| {
                format!("loading verify bucket {b} of {}", entry.name)
            })?);
        }
        Ok(PjrtModel {
            name: entry.name.clone(),
            config: entry.config.clone(),
            client: self.client.clone(),
            draft,
            verify,
        })
    }
}

/// PJRT may return a multi-element computation result either as one
/// tuple-shaped buffer or untupled into one buffer per element (the CPU
/// client untuples). Normalize to a Vec<Literal> of the elements.
fn untuple(mut row: Vec<xla::PjRtBuffer>) -> Vec<xla::Literal> {
    if row.len() == 1 {
        let mut lit = row.remove(0).to_literal_sync().expect("to_literal");
        match lit.primitive_type() {
            Ok(xla::PrimitiveType::Tuple) => {
                lit.decompose_tuple().expect("decompose tuple")
            }
            _ => vec![lit],
        }
    } else {
        row.into_iter()
            .map(|b| b.to_literal_sync().expect("to_literal"))
            .collect()
    }
}

/// A compiled model variant: implements the engine's `HybridModel` over
/// PJRT executables.
pub struct PjrtModel {
    pub name: String,
    pub config: ModelConfig,
    /// Used to upload the verify state host->device once per draft (the
    /// device-resident-state seam; see `State`).
    client: xla::PjRtClient,
    draft: BTreeMap<usize, xla::PjRtLoadedExecutable>,
    verify: BTreeMap<usize, xla::PjRtLoadedExecutable>,
}

impl PjrtModel {
    fn exe_for<'a>(
        map: &'a BTreeMap<usize, xla::PjRtLoadedExecutable>,
        batch: usize,
        what: &str,
    ) -> &'a xla::PjRtLoadedExecutable {
        map.get(&batch).unwrap_or_else(|| {
            panic!(
                "no {what} executable for bucket {batch}; available: {:?}",
                map.keys().collect::<Vec<_>>()
            )
        })
    }

    fn literal_i32(data: &[i32], rows: usize, cols: usize) -> xla::Literal {
        xla::Literal::vec1(data)
            .reshape(&[rows as i64, cols as i64])
            .expect("reshape tokens")
    }

    /// One host->device upload (the PJRT CPU client makes this a cheap
    /// local copy; on an accelerator it is the transfer). `None` =
    /// default device ordinal, matching the single-device clients this
    /// runtime creates.
    fn upload(&self, lit: &xla::Literal) -> xla::PjRtBuffer {
        self.client
            .buffer_from_host_literal(None, lit)
            .expect("host->device upload")
    }
}

impl HybridModel for PjrtModel {
    /// Non-causal hiddens `[B, D, C]`, **device-resident**: uploaded once
    /// per draft pass and handed to every verify execution of the outer
    /// loop as a `PjRtBuffer`. The previous host-`Literal` state was
    /// re-uploaded by `execute` on *every* verify call — with n_verify
    /// inner passes per outer loop that re-paid the biggest transfer of
    /// the step n_verify times (the ROADMAP follow-up this retires).
    /// Token/sigma inputs still upload per verify pass: they change
    /// every pass and are D/C+V times smaller than `h`.
    type State = xla::PjRtBuffer;

    fn seq_len(&self) -> usize {
        self.config.seq_len
    }

    fn vocab(&self) -> usize {
        self.config.vocab_size
    }

    fn n_noncausal(&self) -> usize {
        self.config.n_noncausal
    }

    fn n_causal(&self) -> usize {
        self.config.n_causal
    }

    fn buckets(&self) -> Vec<usize> {
        self.draft.keys().copied().collect()
    }

    fn has_verify(&self) -> bool {
        !self.verify.is_empty()
    }

    fn draft(&self, tokens: &[i32], batch: usize)
             -> (xla::PjRtBuffer, Vec<f32>) {
        let mut state = None;
        let mut logits = Vec::new();
        self.draft_into(tokens, batch, &mut state, &mut logits);
        (state.expect("draft_into sets the state"), logits)
    }

    fn verify(&self, state: &xla::PjRtBuffer, tokens: &[i32],
              sigma: &[i32], batch: usize) -> Vec<f32> {
        let mut logits = Vec::new();
        self.verify_into(state, tokens, sigma, batch, &mut logits);
        logits
    }

    /// Arena-write draft: the device output is split **directly into the
    /// caller's logits buffer** (the scheduler's `StepArena` hands its
    /// retained `draft_logits` vec here), so warm steps reuse one stable
    /// allocation instead of receiving a fresh `Vec` per forward pass
    /// and dropping the old one, and the `h` state is uploaded to the
    /// device **here, once** — the verify passes below execute against
    /// the resident buffer instead of re-uploading a host literal per
    /// pass. The host staging copy of the [B, D, C+V] device array is
    /// still inherent to the single-array draft output contract (a
    /// device-side split needs a dedicated executable: ROADMAP
    /// follow-up).
    fn draft_into(&self, tokens: &[i32], batch: usize,
                  state: &mut Option<xla::PjRtBuffer>,
                  logits: &mut Vec<f32>) {
        let d = self.config.seq_len;
        let c = self.config.hidden;
        let v = self.config.vocab_size;
        debug_assert_eq!(tokens.len(), batch * d);
        let exe = Self::exe_for(&self.draft, batch, "draft");
        let input = Self::literal_i32(tokens, batch, d);
        let mut rows = exe
            .execute::<xla::Literal>(&[input])
            .expect("draft execute");
        let mut elems = untuple(rows.swap_remove(0));
        assert_eq!(elems.len(), 1, "draft must return concat(h, logits)");
        // Single-array output [B, D, C+V] (see python make_draft_fn);
        // split back into h and the caller's logits buffer.
        let full = elems.pop().unwrap().to_vec::<f32>().expect("draft vec");
        debug_assert_eq!(full.len(), batch * d * (c + v));
        let mut h = Vec::with_capacity(batch * d * c);
        logits.clear();
        logits.reserve(batch * d * v);
        for row in full.chunks_exact(c + v) {
            h.extend_from_slice(&row[..c]);
            logits.extend_from_slice(&row[c..]);
        }
        let h_lit = xla::Literal::vec1(&h)
            .reshape(&[batch as i64, d as i64, c as i64])
            .expect("h reshape");
        *state = Some(self.upload(&h_lit));
    }

    /// Verify flavor of the arena seam, running against the
    /// **device-resident** `h` buffer: only the (much smaller)
    /// token/sigma inputs are uploaded per pass, and the outer loop's
    /// n_verify passes share one `h` transfer. The host read (`to_vec`)
    /// must allocate — the xla surface used here has no
    /// read-into-buffer call — so the cheapest correct move is to hand
    /// that vec to the caller's slot directly (no extra copy; the
    /// previous buffer is dropped). A true zero-churn device→arena copy
    /// needs a raw-copy literal API: ROADMAP follow-up.
    fn verify_into(&self, state: &xla::PjRtBuffer, tokens: &[i32],
                   sigma: &[i32], batch: usize, logits: &mut Vec<f32>) {
        let d = self.config.seq_len;
        debug_assert_eq!(tokens.len(), batch * d);
        let exe = Self::exe_for(&self.verify, batch, "verify");
        let tok = self.upload(&Self::literal_i32(tokens, batch, d));
        let sig = self.upload(&Self::literal_i32(sigma, batch, d));
        let args: Vec<&xla::PjRtBuffer> = vec![state, &tok, &sig];
        let mut rows = exe
            .execute_b::<&xla::PjRtBuffer>(&args)
            .expect("verify execute");
        let mut elems = untuple(rows.swap_remove(0));
        assert_eq!(elems.len(), 1, "verify must return (logits,)");
        *logits = elems.pop().unwrap().to_vec::<f32>().expect("verify vec");
    }
}
