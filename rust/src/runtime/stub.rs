//! Stub runtime used when the crate is built **without** `--features pjrt`
//! (the `xla` vendor set is absent in that configuration).
//!
//! API-compatible with `runtime::pjrt`: `Runtime::cpu()` fails with a clear
//! message, so every artifact-driven path (CLI, benches, parity tests)
//! degrades to its existing "skipped: no artifacts/runtime" branch while
//! the mock-model engine, coordinator, and server remain fully usable.

use anyhow::{anyhow, Result};

use crate::engine::HybridModel;
use crate::runtime::manifest::{ModelConfig, ModelEntry};

fn unavailable() -> anyhow::Error {
    anyhow!(
        "PJRT runtime unavailable: built without the `pjrt` feature \
         (vendor the `xla` crate and build with `--features pjrt`)"
    )
}

/// Placeholder for `pjrt::Runtime`; construction always fails.
pub struct Runtime {
    _private: (),
}

impl Runtime {
    pub fn cpu() -> Result<Runtime> {
        Err(unavailable())
    }

    pub fn platform(&self) -> String {
        "stub".into()
    }

    pub fn load_model(&self, _entry: &ModelEntry) -> Result<PjrtModel> {
        Err(unavailable())
    }
}

/// Placeholder for `pjrt::PjrtModel`. Never constructible (the only
/// factory, `Runtime::load_model`, always errors), so the `HybridModel`
/// methods below are unreachable; they exist to keep harness/bench/test
/// code compiling unmodified.
pub struct PjrtModel {
    pub name: String,
    pub config: ModelConfig,
    _private: (),
}

impl HybridModel for PjrtModel {
    type State = ();

    fn seq_len(&self) -> usize {
        self.config.seq_len
    }

    fn vocab(&self) -> usize {
        self.config.vocab_size
    }

    fn n_noncausal(&self) -> usize {
        self.config.n_noncausal
    }

    fn n_causal(&self) -> usize {
        self.config.n_causal
    }

    fn buckets(&self) -> Vec<usize> {
        Vec::new()
    }

    fn has_verify(&self) -> bool {
        false
    }

    fn draft(&self, _tokens: &[i32], _batch: usize) -> ((), Vec<f32>) {
        unreachable!("stub runtime cannot execute models")
    }

    fn verify(&self, _state: &(), _tokens: &[i32], _sigma: &[i32],
              _batch: usize) -> Vec<f32> {
        unreachable!("stub runtime cannot execute models")
    }

    // API parity with `runtime::pjrt`: the real runtime overrides the
    // buffer-reusing flavors to write device outputs straight into the
    // scheduler's arena and keeps the verify state device-resident (its
    // `State` is a PjRtBuffer uploaded once per draft; the unit State
    // here stands in for it), so both feature configurations expose the
    // identical surface.
    fn draft_into(&self, _tokens: &[i32], _batch: usize,
                  _state: &mut Option<()>, _logits: &mut Vec<f32>) {
        unreachable!("stub runtime cannot execute models")
    }

    fn verify_into(&self, _state: &(), _tokens: &[i32], _sigma: &[i32],
                   _batch: usize, _logits: &mut Vec<f32>) {
        unreachable!("stub runtime cannot execute models")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cpu_runtime_reports_missing_feature() {
        let err = Runtime::cpu().err().expect("stub must not construct");
        assert!(err.to_string().contains("pjrt"), "{err}");
    }
}
