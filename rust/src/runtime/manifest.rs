//! `artifacts/manifest.json` parsing: model discovery for the coordinator.
//!
//! The manifest is written by `python/compile/aot.py` and lists, per model,
//! the L2 config and the per-bucket HLO artifact file names, plus the data
//! generator specs used by the oracle scorers.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, Context, Result};

use crate::util::json::Json;

/// Mirror of python/compile/config.py `ModelConfig`.
#[derive(Clone, Debug, PartialEq)]
pub struct ModelConfig {
    pub vocab_size: usize,
    pub seq_len: usize,
    pub hidden: usize,
    pub heads: usize,
    pub ffn: usize,
    pub n_noncausal: usize,
    pub n_causal: usize,
    pub residual_out: bool,
}

impl ModelConfig {
    pub fn mask_id(&self) -> i32 {
        self.vocab_size as i32
    }

    pub fn from_json(v: &Json) -> Result<ModelConfig> {
        let u = |k: &str| -> Result<usize> {
            v.get(k)
                .and_then(|x| x.as_usize())
                .ok_or_else(|| anyhow!("config missing {k}"))
        };
        Ok(ModelConfig {
            vocab_size: u("vocab_size")?,
            seq_len: u("seq_len")?,
            hidden: u("hidden")?,
            heads: u("heads")?,
            ffn: u("ffn")?,
            n_noncausal: u("n_noncausal")?,
            n_causal: u("n_causal")?,
            residual_out: v
                .get("residual_out")
                .and_then(|x| x.as_bool())
                .unwrap_or(true),
        })
    }
}

/// One model's artifact set.
#[derive(Clone, Debug)]
pub struct ModelEntry {
    pub name: String,
    pub config: ModelConfig,
    pub buckets: Vec<usize>,
    /// bucket -> HLO file path.
    pub draft: BTreeMap<usize, PathBuf>,
    pub verify: BTreeMap<usize, PathBuf>,
}

impl ModelEntry {
    pub fn has_verify(&self) -> bool {
        !self.verify.is_empty()
    }
}

#[derive(Clone, Debug, Default)]
pub struct Manifest {
    pub dir: PathBuf,
    pub models: BTreeMap<String, ModelEntry>,
    /// task name ("text8" / "owt" / "protein") -> spec file path.
    pub specs: BTreeMap<String, PathBuf>,
}

impl Manifest {
    pub fn load(dir: &str) -> Result<Manifest> {
        let dir = Path::new(dir).to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        let v = Json::parse(&text).map_err(|e| anyhow!("{e}"))?;

        let mut models = BTreeMap::new();
        let model_obj = v
            .get("models")
            .and_then(|m| m.as_obj())
            .ok_or_else(|| anyhow!("manifest missing models"))?;
        for (name, entry) in model_obj {
            let config = ModelConfig::from_json(
                entry.get("config").ok_or_else(|| anyhow!("no config"))?,
            )?;
            let buckets: Vec<usize> = entry
                .get("buckets")
                .and_then(|b| b.as_arr())
                .ok_or_else(|| anyhow!("no buckets"))?
                .iter()
                .filter_map(|x| x.as_usize())
                .collect();
            let files = |key: &str| -> BTreeMap<usize, PathBuf> {
                entry
                    .get(key)
                    .and_then(|m| m.as_obj())
                    .map(|m| {
                        m.iter()
                            .filter_map(|(k, f)| {
                                Some((
                                    k.parse().ok()?,
                                    dir.join(f.as_str()?),
                                ))
                            })
                            .collect()
                    })
                    .unwrap_or_default()
            };
            models.insert(
                name.clone(),
                ModelEntry {
                    name: name.clone(),
                    config,
                    buckets,
                    draft: files("draft"),
                    verify: files("verify"),
                },
            );
        }

        let mut specs = BTreeMap::new();
        if let Some(s) = v.get("specs").and_then(|s| s.as_obj()) {
            for (task, file) in s {
                if let Some(f) = file.as_str() {
                    specs.insert(task.clone(), dir.join(f));
                }
            }
        }
        Ok(Manifest { dir, models, specs })
    }

    pub fn model(&self, name: &str) -> Result<&ModelEntry> {
        self.models
            .get(name)
            .ok_or_else(|| anyhow!("model '{name}' not in manifest"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest() {
        let dir = std::env::temp_dir().join(format!(
            "ssmd_manifest_test_{}",
            std::process::id()
        ));
        std::fs::create_dir_all(&dir).unwrap();
        let manifest = r#"{
          "models": {
            "owt": {
              "config": {"vocab_size": 256, "seq_len": 64, "hidden": 64,
                         "heads": 4, "ffn": 256, "n_noncausal": 3,
                         "n_causal": 1, "residual_out": true},
              "buckets": [1, 4],
              "draft": {"1": "owt_draft_b1.hlo.txt",
                        "4": "owt_draft_b4.hlo.txt"},
              "verify": {"1": "owt_verify_b1.hlo.txt",
                         "4": "owt_verify_b4.hlo.txt"}
            }
          },
          "specs": {"owt": "owt_spec.json"}
        }"#;
        std::fs::write(dir.join("manifest.json"), manifest).unwrap();
        let m = Manifest::load(dir.to_str().unwrap()).unwrap();
        let e = m.model("owt").unwrap();
        assert_eq!(e.config.vocab_size, 256);
        assert_eq!(e.buckets, vec![1, 4]);
        assert!(e.has_verify());
        assert!(e.draft[&4].ends_with("owt_draft_b4.hlo.txt"));
        assert!(m.specs["owt"].ends_with("owt_spec.json"));
        assert!(m.model("nope").is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn config_defaults_residual() {
        let v = Json::parse(
            r#"{"vocab_size":10,"seq_len":8,"hidden":4,"heads":2,
                "ffn":8,"n_noncausal":2,"n_causal":1}"#,
        )
        .unwrap();
        let c = ModelConfig::from_json(&v).unwrap();
        assert!(c.residual_out);
        assert_eq!(c.mask_id(), 10);
    }
}
