//! Standard masked-diffusion baseline sampler (Sec. 5.1 comparison).
//!
//! Follows the Shi et al. (2024) implementation with the truncation fix of
//! Zheng et al. (2025) as described in App. G.1: at each grid step we first
//! sample a full x0 from the factorized denoising distribution, then reveal
//! a number of *uniformly chosen* masked positions determined by the noise
//! schedule — never combining reveal probability with token probability.
//!
//! NFE uses the paper's best-case analysis: a grid step that reveals no
//! token for a given batch element could have been skipped and costs that
//! element 0 NFE.

use crate::engine::softmax::softmax_row;
use crate::engine::{HybridModel, Prompt, Sample};
use crate::util::rng::Pcg;

#[derive(Clone, Debug)]
pub struct MdmParams {
    /// Number of discretization steps of the reverse process.
    pub steps: usize,
    /// Sampling temperature on the denoising distribution.
    pub temperature: f64,
}

impl Default for MdmParams {
    fn default() -> Self {
        MdmParams { steps: 64, temperature: 1.0 }
    }
}

/// Cosine schedule: masked proportion at uniform time tau in [0, 1]
/// (tau=1 -> all masked, tau=0 -> clean), matching Shi et al.
fn alpha(tau: f64) -> f64 {
    (std::f64::consts::PI / 2.0 * (1.0 - tau)).cos()
}

/// Sample a batch with the standard MDM algorithm on a cosine grid.
pub fn mdm_sample<M: HybridModel>(
    model: &M,
    prompts: &[Prompt],
    params: &MdmParams,
    rng: &mut Pcg,
) -> Vec<Sample> {
    let d = model.seq_len();
    let v = model.vocab();
    let mask = model.mask_id();
    let n_req = prompts.len();
    let buckets = model.buckets();
    let bucket = buckets
        .iter()
        .copied()
        .filter(|&b| b >= n_req)
        .min()
        .unwrap_or_else(|| buckets.iter().copied().max().unwrap_or(n_req));

    struct Row {
        tokens: Vec<i32>,
        masked: Vec<usize>,
        nfe: f64,
        steps_used: usize,
        rng: Pcg,
        m0: usize,
    }
    let mut rows: Vec<Row> = (0..bucket)
        .map(|b| {
            let prompt =
                prompts.get(b).cloned().unwrap_or_else(|| Prompt::empty(d));
            let mut tokens = vec![mask; d];
            let mut masked = Vec::new();
            for (pos, slot) in prompt.0.iter().enumerate() {
                match slot {
                    Some(t) => tokens[pos] = *t,
                    None => masked.push(pos),
                }
            }
            let m0 = masked.len();
            Row { tokens, masked, nfe: 0.0, steps_used: 0,
                  rng: rng.split(), m0 }
        })
        .collect();

    let k = params.steps.max(1);
    for step in 0..k {
        if rows.iter().all(|r| r.masked.is_empty()) {
            break;
        }
        // Reveal counts for this grid step (deterministic discretization of
        // the cosine schedule, scaled per-row by its initial mask count).
        let tau_next = 1.0 - (step + 1) as f64 / k as f64;
        let mut reveal_counts = Vec::with_capacity(bucket);
        let mut any = false;
        for r in &rows {
            let m_next = (r.m0 as f64 * alpha(tau_next)).round() as usize;
            let c = r.masked.len().saturating_sub(m_next);
            any |= c > 0 && !r.masked.is_empty();
            reveal_counts.push(c);
        }
        if !any {
            continue; // best-case: nobody changes, forward pass skipped
        }

        let mut batch_tokens = Vec::with_capacity(bucket * d);
        for r in &rows {
            batch_tokens.extend_from_slice(&r.tokens);
        }
        let (_, logits) = model.draft(&batch_tokens, bucket);

        for (b, r) in rows.iter_mut().enumerate() {
            let c = reveal_counts[b].min(r.masked.len());
            if c == 0 || r.masked.is_empty() {
                continue; // this element's update was a no-op: 0 NFE
            }
            r.nfe += 1.0;
            r.steps_used += 1;
            // Zheng fix: choose WHICH positions to reveal uniformly,
            // independent of the sampled values.
            r.rng.shuffle(&mut r.masked);
            for _ in 0..c {
                let pos = r.masked.pop().unwrap();
                let row = &logits[(b * d + pos) * v..(b * d + pos) * v + v];
                let p = if (params.temperature - 1.0).abs() < 1e-12 {
                    softmax_row(row)
                } else {
                    crate::engine::softmax::softmax_row_temp(
                        row, params.temperature)
                };
                r.tokens[pos] = r.rng.categorical(&p) as i32;
            }
        }
    }

    // Any positions still masked after the grid (rounding) get one final
    // forced reveal pass.
    if rows.iter().any(|r| !r.masked.is_empty()) {
        let mut batch_tokens = Vec::with_capacity(bucket * d);
        for r in &rows {
            batch_tokens.extend_from_slice(&r.tokens);
        }
        let (_, logits) = model.draft(&batch_tokens, bucket);
        for (b, r) in rows.iter_mut().enumerate() {
            if r.masked.is_empty() {
                continue;
            }
            r.nfe += 1.0;
            r.steps_used += 1;
            while let Some(pos) = r.masked.pop() {
                let row = &logits[(b * d + pos) * v..(b * d + pos) * v + v];
                let p = softmax_row(row);
                r.tokens[pos] = r.rng.categorical(&p) as i32;
            }
        }
    }

    rows.into_iter()
        .take(n_req)
        .map(|r| Sample {
            tokens: r.tokens,
            nfe: r.nfe,
            outer_loops: r.steps_used,
            accepted: 0,
            rejected: 0,
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::mock::MockModel;

    fn run(steps: usize, n: usize, seed: u64) -> Vec<Sample> {
        let m = MockModel::new(16, 5, 3);
        let prompts = vec![Prompt::empty(16); n];
        let mut rng = Pcg::new(seed);
        mdm_sample(&m, &prompts, &MdmParams { steps, temperature: 1.0 },
                   &mut rng)
    }

    #[test]
    fn completes_fully() {
        for s in run(8, 3, 1) {
            assert!(s.tokens.iter().all(|&t| (0..5).contains(&t)));
        }
    }

    #[test]
    fn nfe_at_most_steps_plus_final() {
        for s in run(8, 2, 2) {
            assert!(s.nfe <= 9.0, "{s:?}");
            assert!(s.nfe >= 1.0);
        }
    }

    #[test]
    fn more_steps_more_nfe() {
        let few: f64 = run(4, 4, 3).iter().map(|s| s.nfe).sum();
        let many: f64 = run(64, 4, 3).iter().map(|s| s.nfe).sum();
        assert!(many > few, "{many} !> {few}");
    }

    #[test]
    fn nfe_capped_by_seq_len() {
        // Best-case counting: even with steps >> D, at most D reveals can
        // happen so at most D steps are counted.
        for s in run(256, 2, 4) {
            assert!(s.nfe <= 16.0, "{s:?}");
        }
    }

    #[test]
    fn prompt_preserved() {
        let m = MockModel::new(8, 4, 7);
        let mut p = Prompt::empty(8);
        p.0[0] = Some(3);
        let mut rng = Pcg::new(9);
        let out = mdm_sample(&m, &[p], &MdmParams::default(), &mut rng);
        assert_eq!(out[0].tokens[0], 3);
    }

    #[test]
    fn single_step_reveals_all_at_once() {
        for s in run(1, 2, 5) {
            assert!((s.nfe - 1.0).abs() < 1e-9, "{s:?}");
        }
    }
}
