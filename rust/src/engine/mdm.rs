//! Standard masked-diffusion baseline sampler (Sec. 5.1 comparison).
//!
//! Follows the Shi et al. (2024) implementation with the truncation fix of
//! Zheng et al. (2025) as described in App. G.1: at each grid step we first
//! sample a full x0 from the factorized denoising distribution, then reveal
//! a number of *uniformly chosen* masked positions determined by the noise
//! schedule — never combining reveal probability with token probability.
//!
//! NFE uses the paper's best-case analysis: a grid step that reveals no
//! token for a given batch element could have been skipped and costs that
//! element 0 NFE.
//!
//! The per-row grid state machine lives in `engine::scheduler` (shared
//! continuous-batching slot table); `mdm_sample` is the drive-to-completion
//! wrapper. Because the reveal schedule is a per-row function of its
//! initial mask count, rows progress independently and the scheduler can
//! retire finished rows and backfill queued ones mid-run.

use crate::engine::scheduler::{run_to_completion, SeqParams};
use crate::engine::{HybridModel, Prompt, Sample};
use crate::util::rng::Pcg;

#[derive(Clone, Debug)]
pub struct MdmParams {
    /// Number of discretization steps of the reverse process.
    pub steps: usize,
    /// Sampling temperature on the denoising distribution.
    pub temperature: f64,
}

impl Default for MdmParams {
    fn default() -> Self {
        MdmParams { steps: 64, temperature: 1.0 }
    }
}

/// Cosine schedule: masked proportion at uniform time tau in [0, 1]
/// (tau=1 -> all masked, tau=0 -> clean), matching Shi et al.
pub(crate) fn mdm_alpha(tau: f64) -> f64 {
    (std::f64::consts::PI / 2.0 * (1.0 - tau)).cos()
}

/// Sample a batch with the standard MDM algorithm on a cosine grid.
/// Drive-to-completion wrapper over `SpecScheduler` (see module docs).
pub fn mdm_sample<M: HybridModel>(
    model: &M,
    prompts: &[Prompt],
    params: &MdmParams,
    rng: &mut Pcg,
) -> Vec<Sample> {
    run_to_completion(model, prompts, &SeqParams::Mdm(params.clone()), rng).0
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::mock::MockModel;

    fn run(steps: usize, n: usize, seed: u64) -> Vec<Sample> {
        let m = MockModel::new(16, 5, 3);
        let prompts = vec![Prompt::empty(16); n];
        let mut rng = Pcg::new(seed);
        mdm_sample(&m, &prompts, &MdmParams { steps, temperature: 1.0 },
                   &mut rng)
    }

    #[test]
    fn completes_fully() {
        for s in run(8, 3, 1) {
            assert!(s.tokens.iter().all(|&t| (0..5).contains(&t)));
        }
    }

    #[test]
    fn nfe_at_most_steps_plus_final() {
        for s in run(8, 2, 2) {
            assert!(s.nfe <= 9.0, "{s:?}");
            assert!(s.nfe >= 1.0);
        }
    }

    #[test]
    fn more_steps_more_nfe() {
        let few: f64 = run(4, 4, 3).iter().map(|s| s.nfe).sum();
        let many: f64 = run(64, 4, 3).iter().map(|s| s.nfe).sum();
        assert!(many > few, "{many} !> {few}");
    }

    #[test]
    fn nfe_capped_by_seq_len() {
        // Best-case counting: even with steps >> D, at most D reveals can
        // happen so at most D steps are counted.
        for s in run(256, 2, 4) {
            assert!(s.nfe <= 16.0, "{s:?}");
        }
    }

    #[test]
    fn prompt_preserved() {
        let m = MockModel::new(8, 4, 7);
        let mut p = Prompt::empty(8);
        p.0[0] = Some(3);
        let mut rng = Pcg::new(9);
        let out = mdm_sample(&m, &[p], &MdmParams::default(), &mut rng);
        assert_eq!(out[0].tokens[0], 3);
    }

    #[test]
    fn single_step_reveals_all_at_once() {
        for s in run(1, 2, 5) {
            assert!((s.nfe - 1.0).abs() < 1e-9, "{s:?}");
        }
    }

    #[test]
    fn oversized_batch_round_trips() {
        // More prompts than the largest bucket: queued + backfilled.
        let mut m = MockModel::new(8, 4, 23);
        m.buckets = vec![1, 2];
        let prompts = vec![Prompt::empty(8); 7];
        let mut rng = Pcg::new(6);
        let out = mdm_sample(&m, &prompts, &MdmParams { steps: 4,
                                                        temperature: 1.0 },
                             &mut rng);
        assert_eq!(out.len(), 7);
        for s in &out {
            assert!(s.tokens.iter().all(|&t| (0..4).contains(&t)));
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let a = run(8, 3, 42);
        let b = run(8, 3, 42);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.tokens, y.tokens);
        }
    }
}
