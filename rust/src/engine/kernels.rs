//! Logits-domain sampling kernels for the scheduler hot path.
//!
//! The old hot loop materialized a full `Vec<f64>` softmax row (B·D·V f64
//! of transient probability mass per outer loop) even though the
//! accept/reject test of Algorithm 3 only ever reads `q[tok] / p[tok]` for
//! one token per row. This module replaces probability-vector arithmetic
//! with three logits-domain identities, none of which allocates:
//!
//! * **Gumbel-max draws** ([`gumbel_draw_lse`]): `argmax_i(x_i + g_i)`
//!   with `x = logits / T` and `g_i = -ln(-ln u_i)` i.i.d. Gumbel samples
//!   exactly `softmax(logits / T)`. We evaluate it in the equivalent
//!   *exponential-race* form `argmin_i E_i / e_i` (`E_i = -ln u_i`,
//!   `e_i = exp(x_i - max x)`), which reuses the `exp` values the row's
//!   log-sum-exp needs anyway and costs one `ln` per element instead of
//!   two. The race comparison is division-free (`E_i < best * e_i`).
//! * **LSE accept tests** ([`accept_prob`]): the speculative acceptance
//!   probability `min(1, q[tok]/p[tok])` equals
//!   `min(1, exp((q_l[tok]/T - lse_q) - (p_l[tok]/T - lse_p)))` with
//!   `lse = ln Σ exp(l_i / T)` — one cached scalar per row replaces a
//!   V-length probability vector.
//! * **Lazy residuals** ([`residual_draw_into`]): the resampling
//!   distribution `max(0, q - p)` is only needed *after* a rejection, so
//!   it is computed on demand into one caller-owned scratch row instead of
//!   being derivable from two materialized rows.
//!
//! Per-element transcendentals use branchless polynomial kernels
//! ([`fexp32`], [`fln64`]) written as fixed-lane blocked loops so the
//! compiler can vectorize them (the repo builds with `target-cpu=native`;
//! see `.cargo/config.toml`). With the `simd` cargo feature the hot
//! 64-element block forms of these kernels are replaced by explicit
//! `core::arch` implementations (AVX2/SSE2 on x86_64, NEON on aarch64,
//! runtime-dispatched — see `engine::simd`) that replicate the portable
//! loops operation-for-operation, so results are **bit-identical** with
//! the feature on or off (pinned by a test below). Their relative error
//! (~5e-6 / ~4e-9) is far below anything a sampling test can resolve;
//! the chi-square tests below pin distributional equivalence to the old
//! `softmax_row` path.
//!
//! **RNG-stream note.** The Gumbel draw needs one noise value *per vocab
//! entry*, so driving it from the sequential PCG stream would consume V
//! draws per token (and serialize the hot loop on the generator). Instead
//! each row draw consumes exactly **one** `Pcg::next_u64()` which seeds a
//! counter-based SplitMix64 stream (`u_i = mix64(seed + i·GOLDEN)`) — the
//! same construction GPU samplers use. Draws are therefore seed-stable
//! and deterministic, but the token stream differs from the old
//! CDF-inversion sampler: determinism tests assert reproducibility of the
//! *new* path plus chi-square equivalence to the old distribution, not
//! bitwise equality with pre-change streams.
//!
//! Consistency guarantee: [`gumbel_draw_lse`] and [`row_lse`] accumulate
//! their sums in the identical order, so the LSE a draw caches for its
//! draft row is bit-identical to the LSE an accept test would compute for
//! the same logits — when target == draft the accept probability is
//! exactly 1.0 (zero spurious rejections).

use crate::util::rng::Pcg;

/// Lane width of the blocked accumulations (matches a 256-bit f32 vector).
pub(crate) const LANES: usize = 8;
/// Elements per noise block in the fused draw loop.
pub(crate) const BLK: usize = 64;
/// SplitMix64 counter increment (odd; 2^64 / golden ratio).
const GOLDEN: u64 = 0x9e37_79b9_7f4a_7c15;

/// 2^r Taylor coefficients of [`fexp32`] (shared verbatim by the
/// explicit-SIMD variants in `engine::simd` — the bit-identity guarantee
/// rests on both paths evaluating the same polynomial in the same order).
pub(crate) const EXP_C1: f32 = std::f32::consts::LN_2;
pub(crate) const EXP_C2: f32 = 0.240_226_51;
pub(crate) const EXP_C3: f32 = 0.055_504_11;
pub(crate) const EXP_C4: f32 = 0.009_618_129;
pub(crate) const EXP_C5: f32 = 0.001_333_355_8;
/// 1.5·2^23: magic round-to-nearest constant of [`fexp32`].
pub(crate) const EXP_MAGIC: f32 = 12_582_912.0;

/// Cephes-style minimax coefficients for ln(1+w) of [`fln64`], applied
/// Horner-first-to-last (shared verbatim with `engine::simd`).
pub(crate) const LN_POLY: [f64; 9] = [
    7.037_683_629_2e-2,
    -1.151_461_031_0e-1,
    1.167_699_874_0e-1,
    -1.242_014_084_6e-1,
    1.424_932_278_7e-1,
    -1.666_805_766_5e-1,
    2.000_071_476_5e-1,
    -2.499_999_399_3e-1,
    3.333_333_117_4e-1,
];
/// Mantissa bits of sqrt(2): the octave-fold threshold of [`fln64`].
pub(crate) const LN_SQRT2_MANT: u64 = 0x6_a09e_667f_3bcd;

// lint: hot-region — sampling kernels; allocation-free by contract
// (scratch buffers are caller-owned, see residual_draw_into).
/// Fast branchless `exp` for f32, intended for max-subtracted arguments
/// (`x <= 0`); the result saturates at `2^±126` outside `|x| < 87`.
/// Relative error ~5e-6. Inputs must be finite.
#[inline(always)]
pub fn fexp32(x: f32) -> f32 {
    // Decompose exp(x) = 2^n * 2^r with n = round(x·log2e), r in [-.5, .5].
    let z = (x * std::f32::consts::LOG2E).clamp(-126.0, 126.0);
    let zs = z + EXP_MAGIC;
    let n = (zs.to_bits() & 0x7f_ffff) as i32 - 0x40_0000;
    let r = z - (zs - EXP_MAGIC);
    // 2^r via the exp(r·ln2) Taylor series, Estrin-ish grouping.
    let r2 = r * r;
    let p = (1.0 + EXP_C1 * r)
        + r2 * ((EXP_C2 + EXP_C3 * r) + r2 * (EXP_C4 + EXP_C5 * r));
    f32::from_bits((p.to_bits() as i32).wrapping_add(n << 23) as u32)
}

/// Fast branchless natural log for positive, finite, normal f64 inputs
/// (the uniform variates fed to the Gumbel noise are all in (2^-54, 1)).
/// Division-free; relative error ~4e-9.
#[inline(always)]
pub fn fln64(x: f64) -> f64 {
    let bits = x.to_bits();
    let mant = bits & 0x000f_ffff_ffff_ffff;
    let mut e = ((bits >> 52) & 0x7ff) as i64 - 1023;
    // Fold mantissas above sqrt(2) down one octave (integer-side select
    // keeps the pass branch-free for the vectorizer).
    let adj = (mant >= LN_SQRT2_MANT) as i64;
    e += adj;
    let m = f64::from_bits(mant | (((1023 - adj) as u64) << 52));
    let w = m - 1.0; // in [sqrt(2)/2 - 1, sqrt(2) - 1]
    let z = w * w;
    // ln(1+w) = w - w²/2 + w³·P(w), P in Horner form.
    let mut p = LN_POLY[0];
    for &c in &LN_POLY[1..] {
        p = p * w + c;
    }
    let y = w * z * p - 0.5 * z;
    w + y + e as f64 * std::f64::consts::LN_2
}

/// SplitMix64 finalizer: the counter-based noise generator for Gumbel
/// draws (one independent uniform per vocab entry from one PCG seed).
#[inline(always)]
fn mix64(mut z: u64) -> u64 {
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    z ^ (z >> 31)
}

/// Uniform in (0, 1) from 53 high bits of a hash (never exactly 0 or 1,
/// so `-ln(u)` is always finite and positive).
#[inline(always)]
fn unit_open(h: u64) -> f64 {
    ((h >> 11) as f64 + 0.5) * (1.0 / 9_007_199_254_740_992.0)
}

/// Portable (auto-vectorized) block kernels: the reference semantics of
/// the hot loops. The explicit-SIMD variants in `engine::simd` replicate
/// these operation-for-operation, so their results are **bit-identical**
/// (pinned by `dispatched_blocks_match_portable_bitwise` below); without
/// the `simd` cargo feature they are the only implementation.
pub(crate) mod portable {
    use super::{fexp32, fln64, BLK, LANES};

    /// `out[k] = fexp32(x[k]·inv_temp - ms)` over one 64-element block,
    /// accumulating `acc[k % LANES] += out[k]` in the fixed 8-lane order
    /// every LSE consumer shares.
    #[inline]
    pub fn exp_accum_block(x: &[f32], inv_temp: f32, ms: f32,
                           acc: &mut [f32; LANES], out: &mut [f32; BLK]) {
        debug_assert_eq!(x.len(), BLK);
        for k in 0..BLK {
            out[k] = fexp32(x[k] * inv_temp - ms);
        }
        for k in (0..BLK).step_by(LANES) {
            for k2 in 0..LANES {
                acc[k2] += out[k + k2];
            }
        }
    }

    /// In-place `u[k] = -fln64(u[k])` over one 64-element block (the
    /// exponential-race noise `E = -ln u`).
    #[inline]
    pub fn neg_ln_block(u: &mut [f64; BLK]) {
        for v in u.iter_mut() {
            *v = -fln64(*v);
        }
    }

    /// Max over a logits row (lane-blocked so it vectorizes). Row must
    /// be non-empty and finite.
    #[inline]
    pub fn row_max(logits: &[f32]) -> f32 {
        let mut acc = [f32::NEG_INFINITY; LANES];
        let mut chunks = logits.chunks_exact(LANES);
        for c in chunks.by_ref() {
            for k in 0..LANES {
                acc[k] = c[k].max(acc[k]);
            }
        }
        let mut m = f32::NEG_INFINITY;
        for &a in &acc {
            m = a.max(m);
        }
        for &x in chunks.remainder() {
            m = x.max(m);
        }
        m
    }
}

// Runtime-dispatched block kernels: explicit `core::arch` SIMD when the
// `simd` feature is on (AVX2/SSE2 on x86_64, NEON on aarch64; see
// `engine::simd` for the dispatch table), the portable loops otherwise.
// Both paths are bit-identical by construction.
#[cfg(feature = "simd")]
use crate::engine::simd::{exp_accum_block, neg_ln_block, row_max};
#[cfg(not(feature = "simd"))]
use self::portable::{exp_accum_block, neg_ln_block, row_max};

/// Shared summation pass: `Σ exp(l_i·inv_temp - ms)` with a fixed
/// accumulation order — 64-element blocks of 8 f32 lanes, an f64 scalar
/// tail, lanes folded in last. [`gumbel_draw_lse`] replicates this exact
/// order (same block split, same lane stride), which makes the LSE it
/// caches bit-identical to [`row_lse`] on the same row.
#[inline]
fn sum_exp(logits: &[f32], inv_temp: f32, ms: f32) -> f64 {
    let mut acc = [0.0_f32; LANES];
    let mut ebuf = [0.0_f32; BLK];
    let mut sum_tail = 0.0_f64;
    let n = logits.len();
    let mut i = 0;
    while i + BLK <= n {
        exp_accum_block(&logits[i..i + BLK], inv_temp, ms, &mut acc,
                        &mut ebuf);
        i += BLK;
    }
    while i < n {
        sum_tail += fexp32(logits[i] * inv_temp - ms) as f64;
        i += 1;
    }
    let mut sum = sum_tail;
    for &a in &acc {
        sum += a as f64;
    }
    sum
}

/// Log-sum-exp of `logits · inv_temp`: the per-row normalizer scalar the
/// accept tests cache instead of a softmax vector.
pub fn row_lse(logits: &[f32], inv_temp: f32) -> f64 {
    let ms = row_max(logits) * inv_temp;
    ms as f64 + sum_exp(logits, inv_temp, ms).ln()
}

/// Fused Gumbel-max categorical draw + log-sum-exp over one logits row.
///
/// Returns `(token, lse)` where `token ~ softmax(logits · inv_temp)` and
/// `lse = ln Σ exp(l_i · inv_temp)` (bit-identical to [`row_lse`] on the
/// same row). `seed` is one `Pcg::next_u64()`; the per-element noise is a
/// counter-based SplitMix64 stream (see module docs). Zero allocation.
pub fn gumbel_draw_lse(logits: &[f32], inv_temp: f32, seed: u64)
                       -> (usize, f64) {
    debug_assert!(!logits.is_empty(), "draw over an empty row");
    let ms = row_max(logits) * inv_temp;
    // Race state: token i wins iff E_i / e_i is the running minimum, which
    // is exactly argmax_i (x_i + gumbel_i). Comparisons are division-free;
    // the division only runs when the minimum improves (~ln V times).
    let mut best = f64::INFINITY;
    let mut arg = 0usize;
    let mut acc = [0.0_f32; LANES];
    let mut sum_tail = 0.0_f64;
    let mut ebuf = [0.0_f32; BLK];
    let mut enb = [0.0_f64; BLK];
    let n = logits.len();
    let mut i = 0;
    while i + BLK <= n {
        exp_accum_block(&logits[i..i + BLK], inv_temp, ms, &mut acc,
                        &mut ebuf);
        // Counter-based uniforms stay scalar (64-bit multiplies have no
        // AVX2 lane form); the -ln pass over the block is dispatched.
        for (k, u) in enb.iter_mut().enumerate() {
            let h = mix64(
                seed.wrapping_add(((i + k) as u64).wrapping_mul(GOLDEN)),
            );
            *u = unit_open(h);
        }
        neg_ln_block(&mut enb);
        for k in 0..BLK {
            let e = ebuf[k] as f64;
            if enb[k] < best * e {
                best = enb[k] / e;
                arg = i + k;
            }
        }
        i += BLK;
    }
    while i < n {
        let e32 = fexp32(logits[i] * inv_temp - ms);
        sum_tail += e32 as f64;
        let h = mix64(seed.wrapping_add((i as u64).wrapping_mul(GOLDEN)));
        let en = -fln64(unit_open(h));
        let e = e32 as f64;
        if en < best * e {
            best = en / e;
            arg = i;
        }
        i += 1;
    }
    let mut sum = sum_tail;
    for &a in &acc {
        sum += a as f64;
    }
    (arg, ms as f64 + sum.ln())
}

/// Speculative acceptance probability in log space:
/// `min(1, exp((q_l·inv_t - lse_q) - (p_l·inv_t - lse_p)))`, identical to
/// the probability-domain `min(1, q[tok]/p[tok])` (including the `p == 0
/// => accept` edge, where the exponent overflows toward +inf).
#[inline]
pub fn accept_prob(q_logit: f32, lse_q: f64, p_logit: f32, lse_p: f64,
                   inv_temp: f64) -> f64 {
    let diff = (q_logit as f64 * inv_temp - lse_q)
        - (p_logit as f64 * inv_temp - lse_p);
    diff.exp().min(1.0)
}

/// Lazy residual resample: draw from `max(0, q - p)` (normalized), built
/// on demand into `scratch` (reused across calls — resized, never
/// reallocated once warm). Falls back to sampling `q` itself when the
/// residual carries no mass (q <= p everywhere, i.e. q == p), matching
/// the old `residual_distribution(..).unwrap_or(q_row)` behavior.
pub fn residual_draw_into(scratch: &mut Vec<f64>, q_logits: &[f32],
                          lse_q: f64, p_logits: &[f32], lse_p: f64,
                          inv_temp: f64, rng: &mut Pcg) -> usize {
    let n = q_logits.len();
    debug_assert_eq!(p_logits.len(), n);
    scratch.clear();
    scratch.resize(n, 0.0);
    let mut sum = 0.0_f64;
    for i in 0..n {
        let dq = fexp32((q_logits[i] as f64 * inv_temp - lse_q) as f32);
        let dp = fexp32((p_logits[i] as f64 * inv_temp - lse_p) as f32);
        let r = (dq as f64 - dp as f64).max(0.0);
        scratch[i] = r;
        sum += r;
    }
    if sum <= 0.0 {
        return gumbel_draw_lse(q_logits, inv_temp as f32, rng.next_u64()).0;
    }
    let mut u = rng.f64() * sum;
    for (i, &w) in scratch.iter().enumerate() {
        u -= w;
        if u <= 0.0 {
            return i;
        }
    }
    n - 1
}

/// Exact (libm, f64) log-sum-exp of a raw logits row — the cold-path
/// flavor for the likelihood tables, where a scalar probability
/// `exp(l[tok] - lse_f64(row))` replaces a full `softmax_row` allocation.
pub fn lse_f64(logits: &[f32]) -> f64 {
    let m = logits.iter().copied().fold(f32::NEG_INFINITY, f32::max) as f64;
    let s: f64 = logits.iter().map(|&x| (x as f64 - m).exp()).sum();
    m + s.ln()
}
// lint: end-hot-region

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::softmax::{residual_distribution, softmax_row,
                                 softmax_row_temp};
    use crate::util::ptest::{self, chi_square, chi_square_crit, Size};

    fn random_row(rng: &mut Pcg, v: usize, scale: f64) -> Vec<f32> {
        (0..v).map(|_| ((rng.f64() * 2.0 - 1.0) * scale) as f32).collect()
    }

    /// The old path's distribution for a row at a given temperature.
    fn old_probs(row: &[f32], temp: f64) -> Vec<f64> {
        if (temp - 1.0).abs() < 1e-12 {
            softmax_row(row)
        } else {
            softmax_row_temp(row, temp)
        }
    }

    #[test]
    fn fexp32_matches_std_exp() {
        let mut rng = Pcg::new(11);
        for _ in 0..50_000 {
            let x = (-rng.f64() * 100.0) as f32;
            let got = fexp32(x);
            let want = x.exp();
            if want > 1e-30 {
                assert!(
                    ((got - want) / want).abs() < 2e-5,
                    "exp({x}) = {got} vs {want}"
                );
            }
        }
        assert!((fexp32(0.0) - 1.0).abs() < 1e-6);
    }

    #[test]
    fn fln64_matches_std_ln() {
        let mut rng = Pcg::new(12);
        for _ in 0..50_000 {
            let u = rng.f64().max(1e-300);
            let got = fln64(u);
            let want = u.ln();
            assert!(
                (got - want).abs() <= want.abs().max(1e-12) * 1e-7,
                "ln({u}) = {got} vs {want}"
            );
        }
        // The Gumbel tail: u near 1 must keep relative precision.
        for k in 1..100u64 {
            let u = 1.0 - k as f64 * 1e-9;
            let got = fln64(u);
            let want = u.ln();
            assert!(((got - want) / want).abs() < 1e-6, "{got} vs {want}");
        }
    }

    #[test]
    fn row_lse_matches_exact_lse() {
        let mut rng = Pcg::new(13);
        for v in [1usize, 7, 27, 64, 100, 1000] {
            let row = random_row(&mut rng, v, 6.0);
            let fast = row_lse(&row, 1.0);
            let exact = lse_f64(&row);
            assert!(
                (fast - exact).abs() < 1e-4,
                "V={v}: {fast} vs {exact}"
            );
            let fast_t = row_lse(&row, 1.0 / 0.7);
            let scaled: Vec<f32> =
                row.iter().map(|&x| (x as f64 / 0.7) as f32).collect();
            let exact_t = lse_f64(&scaled);
            assert!((fast_t - exact_t).abs() < 1e-3);
        }
    }

    /// The zero-spurious-rejection invariant: a draw's cached LSE must be
    /// bit-identical to `row_lse` on the same logits, so q == p implies
    /// accept probability exactly 1.
    #[test]
    fn draw_lse_is_bitwise_row_lse() {
        let mut rng = Pcg::new(14);
        for v in [1usize, 8, 27, 63, 64, 65, 200, 1000] {
            for &temp in &[0.7_f64, 1.0] {
                let row = random_row(&mut rng, v, 5.0);
                let inv_t = (1.0 / temp) as f32;
                let (_, lse) = gumbel_draw_lse(&row, inv_t, rng.next_u64());
                let direct = row_lse(&row, inv_t);
                assert_eq!(
                    lse.to_bits(),
                    direct.to_bits(),
                    "V={v} T={temp}: {lse} vs {direct}"
                );
                let a = accept_prob(row[0], lse, row[0], direct, 1.0 / temp);
                assert_eq!(a, 1.0);
            }
        }
    }

    #[test]
    fn draw_is_seed_stable_and_seed_sensitive() {
        let mut rng = Pcg::new(15);
        let row = random_row(&mut rng, 50, 4.0);
        let (a, la) = gumbel_draw_lse(&row, 1.0, 42);
        let (b, lb) = gumbel_draw_lse(&row, 1.0, 42);
        assert_eq!((a, la.to_bits()), (b, lb.to_bits()));
        // lint: allow(det-iteration) — test only counts distinct draws;
        // iteration order is never observed.
        let distinct: std::collections::HashSet<usize> = (0..200)
            .map(|s| gumbel_draw_lse(&row, 1.0, s).0)
            .collect();
        assert!(distinct.len() > 3, "draws ignore the seed");
    }

    #[test]
    fn draw_prefers_dominant_logit() {
        let mut row = vec![0.0_f32; 40];
        row[17] = 30.0;
        for seed in 0..50 {
            assert_eq!(gumbel_draw_lse(&row, 1.0, seed).0, 17);
        }
    }

    /// Distributional equivalence of the Gumbel-max draw to the old
    /// materialized-softmax path at the paper's temperatures, chi-square
    /// at the 99.99% critical value (seeded, deterministic).
    #[test]
    fn draw_matches_old_softmax_distribution() {
        for (case, &temp) in [0.7_f64, 1.0].iter().enumerate() {
            let mut rng = Pcg::new(0x6a11 + case as u64);
            let v = 27;
            let row = random_row(&mut rng, v, 3.0);
            let probs = old_probs(&row, temp);
            let n = 200_000;
            let mut counts = vec![0usize; v];
            let inv_t = (1.0 / temp) as f32;
            for _ in 0..n {
                counts[gumbel_draw_lse(&row, inv_t, rng.next_u64()).0] += 1;
            }
            let chi2 = chi_square(&counts, &probs);
            let crit = chi_square_crit(v - 1);
            assert!(
                chi2 < crit,
                "T={temp}: chi2 {chi2:.1} >= crit {crit:.1}"
            );
        }
    }

    /// Property flavor of the same equivalence over random small rows.
    #[test]
    fn draw_distribution_property() {
        ptest::check(
            8,
            0xd1a3,
            |rng: &mut Pcg, s: Size| {
                let v = 4 + (s.0 * 3).min(24);
                let temp = if s.0 % 2 == 0 { 0.7 } else { 1.0 };
                let row = random_row(rng, v, 3.0);
                let seeds: Vec<u64> =
                    (0..30_000).map(|_| rng.next_u64()).collect();
                (row, temp, seeds)
            },
            |(row, temp, seeds)| {
                let probs = old_probs(row, *temp);
                let mut counts = vec![0usize; row.len()];
                let inv_t = (1.0 / temp) as f32;
                for &s in seeds {
                    counts[gumbel_draw_lse(row, inv_t, s).0] += 1;
                }
                let chi2 = chi_square(&counts, &probs);
                let crit = chi_square_crit(row.len() - 1);
                if chi2 < crit {
                    Ok(())
                } else {
                    Err(format!("chi2 {chi2:.1} >= crit {crit:.1}"))
                }
            },
        );
    }

    /// Lump bins whose expected count is tiny into one tail bucket so
    /// the chi-square approximation holds at sharp temperatures (shared
    /// with the residual-distribution test below).
    fn lump_small_bins(counts: &[usize], probs: &[f64], n: usize)
                       -> (Vec<usize>, Vec<f64>) {
        let mut big_c = Vec::new();
        let mut big_p = Vec::new();
        let mut tail_c = 0usize;
        let mut tail_p = 0.0;
        for i in 0..probs.len() {
            if probs[i] * n as f64 >= 10.0 {
                big_c.push(counts[i]);
                big_p.push(probs[i]);
            } else {
                tail_c += counts[i];
                tail_p += probs[i];
            }
        }
        if tail_p > 0.0 {
            big_c.push(tail_c);
            big_p.push(tail_p);
        }
        (big_c, big_p)
    }

    /// Coverage at the temperature extremes and the V=2 edge (the paper's
    /// temperatures 0.7/1.0 are covered above): the Gumbel-max draw must
    /// match the old materialized-softmax distribution at T=0.3 (sharp)
    /// and T=2.0 (flat), on binary and word-sized vocabularies alike.
    #[test]
    fn draw_matches_softmax_at_temperature_extremes() {
        for (case, &temp) in [0.3_f64, 2.0].iter().enumerate() {
            for &v in &[2usize, 27] {
                let mut rng =
                    Pcg::new(0x7e3a + 31 * case as u64 + v as u64);
                // Moderate logit scale at V=2 keeps both bins populated
                // even at T=0.3 (the lumping below has nothing to lump
                // into on a binary vocabulary).
                let scale = if v == 2 { 1.0 } else { 3.0 };
                let row = random_row(&mut rng, v, scale);
                let probs = old_probs(&row, temp);
                let n = 200_000;
                let mut counts = vec![0usize; v];
                let inv_t = (1.0 / temp) as f32;
                for _ in 0..n {
                    counts
                        [gumbel_draw_lse(&row, inv_t, rng.next_u64()).0] +=
                        1;
                }
                let (big_c, big_p) = lump_small_bins(&counts, &probs, n);
                let chi2 = chi_square(&big_c, &big_p);
                let crit = chi_square_crit(big_c.len().saturating_sub(1));
                assert!(
                    chi2 < crit,
                    "T={temp} V={v}: chi2 {chi2:.1} >= crit {crit:.1}"
                );
            }
        }
    }

    /// The log-space accept probability must match the old
    /// probability-domain ratio numerically (not just statistically).
    #[test]
    fn accept_prob_matches_old_ratio() {
        ptest::check(
            40,
            0xacc,
            |rng: &mut Pcg, s: Size| {
                let v = 2 + (s.0 * 7).min(120);
                let temp = if s.0 % 2 == 0 { 0.7 } else { 1.0 };
                (random_row(rng, v, 4.0), random_row(rng, v, 4.0), temp)
            },
            |(p_row, q_row, temp)| {
                let pp = old_probs(p_row, *temp);
                let qq = old_probs(q_row, *temp);
                let inv_t = 1.0 / temp;
                let lse_p = row_lse(p_row, inv_t as f32);
                let lse_q = row_lse(q_row, inv_t as f32);
                for tok in 0..p_row.len() {
                    let old = (qq[tok] / pp[tok]).min(1.0);
                    let new = accept_prob(q_row[tok], lse_q, p_row[tok],
                                          lse_p, inv_t);
                    if (old - new).abs() > 1e-4 {
                        return Err(format!(
                            "tok {tok}: old {old} vs new {new}"
                        ));
                    }
                }
                Ok(())
            },
        );
    }

    /// Residual resampling must follow the old normalized max(0, q - p).
    #[test]
    fn residual_matches_old_distribution() {
        let mut rng = Pcg::new(0x4e5);
        let v = 27;
        let temp = 0.7;
        let p_row = random_row(&mut rng, v, 3.0);
        let q_row = random_row(&mut rng, v, 3.0);
        let pp = old_probs(&p_row, temp);
        let qq = old_probs(&q_row, temp);
        let res = residual_distribution(&qq, &pp).expect("has mass");
        let inv_t = 1.0 / temp;
        let lse_p = row_lse(&p_row, inv_t as f32);
        let lse_q = row_lse(&q_row, inv_t as f32);
        let mut scratch = Vec::new();
        let n = 200_000;
        let mut counts = vec![0usize; v];
        for _ in 0..n {
            counts[residual_draw_into(&mut scratch, &q_row, lse_q, &p_row,
                                      lse_p, inv_t, &mut rng)] += 1;
        }
        // Lump near-empty residual bins into one tail bucket so the
        // chi-square approximation holds.
        let (big_counts, big_probs) = lump_small_bins(&counts, &res, n);
        let chi2 = chi_square(&big_counts, &big_probs);
        let crit = chi_square_crit(big_counts.len() - 1);
        assert!(chi2 < crit, "chi2 {chi2:.1} >= crit {crit:.1}");
    }

    #[test]
    fn residual_falls_back_to_q_when_massless() {
        // q == p: the residual has no mass; the draw must come from q
        // (here: the dominant logit) instead of panicking.
        let mut rng = Pcg::new(0x4e6);
        let mut row = vec![0.0_f32; 16];
        row[3] = 25.0;
        let lse = row_lse(&row, 1.0);
        let mut scratch = Vec::new();
        let tok = residual_draw_into(&mut scratch, &row, lse, &row, lse,
                                     1.0, &mut rng);
        assert_eq!(tok, 3);
    }

    #[test]
    fn lse_f64_matches_softmax_row() {
        let mut rng = Pcg::new(0x15e);
        for v in [2usize, 27, 300] {
            let row = random_row(&mut rng, v, 6.0);
            let probs = softmax_row(&row);
            let lse = lse_f64(&row);
            for (i, &p) in probs.iter().enumerate() {
                let via_lse = (row[i] as f64 - lse).exp();
                assert!((p - via_lse).abs() < 1e-12, "{p} vs {via_lse}");
            }
        }
    }

    #[test]
    fn single_element_row() {
        let row = [2.5_f32];
        let (tok, lse) = gumbel_draw_lse(&row, 1.0, 9);
        assert_eq!(tok, 0);
        assert!((lse - 2.5).abs() < 1e-5);
    }

    /// The block kernels the hot loops actually call (explicit SIMD when
    /// the `simd` feature is on, the portable loops otherwise) must be
    /// **bit-identical** to the portable reference — this is what makes
    /// token streams invariant under `--features simd`. Exercises every
    /// dispatch target available on the build host; trivially green on a
    /// scalar build (both sides are the portable path).
    #[test]
    fn dispatched_blocks_match_portable_bitwise() {
        let mut rng = Pcg::new(0x51_3d);
        for trial in 0..200 {
            // Logit-scaled f32 inputs plus the temperatures the
            // scheduler uses.
            let inv_temp = [1.0_f32, 1.0 / 0.7, 1.0 / 0.3, 0.5]
                [trial % 4];
            let mut x = [0.0_f32; BLK];
            for v in x.iter_mut() {
                *v = ((rng.f64() * 2.0 - 1.0) * 8.0) as f32;
            }
            let ms = portable::row_max(&x) * inv_temp;

            let mut acc_a = [0.0_f32; LANES];
            let mut out_a = [0.0_f32; BLK];
            exp_accum_block(&x, inv_temp, ms, &mut acc_a, &mut out_a);
            let mut acc_b = [0.0_f32; LANES];
            let mut out_b = [0.0_f32; BLK];
            portable::exp_accum_block(&x, inv_temp, ms, &mut acc_b,
                                      &mut out_b);
            for k in 0..BLK {
                assert_eq!(out_a[k].to_bits(), out_b[k].to_bits(),
                           "exp lane {k}: {} vs {}", out_a[k], out_b[k]);
            }
            for k in 0..LANES {
                assert_eq!(acc_a[k].to_bits(), acc_b[k].to_bits(),
                           "acc lane {k}: {} vs {}", acc_a[k], acc_b[k]);
            }

            // Uniforms in (0, 1) — exactly what the Gumbel race feeds in.
            let mut u_a = [0.0_f64; BLK];
            for v in u_a.iter_mut() {
                *v = unit_open(rng.next_u64());
            }
            let u_ref = u_a;
            let mut u_b = u_ref;
            neg_ln_block(&mut u_a);
            portable::neg_ln_block(&mut u_b);
            for k in 0..BLK {
                assert_eq!(u_a[k].to_bits(), u_b[k].to_bits(),
                           "ln lane {k}: {} vs {}", u_a[k], u_b[k]);
            }

            // Row max over an odd-length row (remainder path included).
            let row: Vec<f32> = (0..77)
                .map(|_| ((rng.f64() * 2.0 - 1.0) * 6.0) as f32)
                .collect();
            assert_eq!(row_max(&row).to_bits(),
                       portable::row_max(&row).to_bits());

            // The baseline-ISA variants too (SSE2 on x86_64, NEON on
            // aarch64): the dispatcher picks the best ISA on this host,
            // but a weaker host would dispatch to these — the
            // bit-identity guarantee must cover every variant.
            #[cfg(feature = "simd")]
            {
                use crate::engine::simd;
                let mut acc_c = [0.0_f32; LANES];
                let mut out_c = [0.0_f32; BLK];
                simd::exp_accum_block_baseline(&x, inv_temp, ms,
                                               &mut acc_c, &mut out_c);
                for k in 0..BLK {
                    assert_eq!(out_c[k].to_bits(), out_b[k].to_bits(),
                               "baseline exp lane {k}");
                }
                for k in 0..LANES {
                    assert_eq!(acc_c[k].to_bits(), acc_b[k].to_bits(),
                               "baseline acc lane {k}");
                }
                let mut u_c = u_ref;
                simd::neg_ln_block_baseline(&mut u_c);
                for k in 0..BLK {
                    assert_eq!(u_c[k].to_bits(), u_b[k].to_bits(),
                               "baseline ln lane {k}");
                }
                assert_eq!(simd::row_max_baseline(&row).to_bits(),
                           portable::row_max(&row).to_bits());
            }
        }
    }
}
