//! Bounded exhaustive model checker for the [`pool`](super::pool)
//! condvar protocol (a hand-rolled mini-loom; loom itself is
//! unavailable offline).
//!
//! ## Why critical-section granularity is sound
//!
//! Every field of the pool's `JobState` is only ever read or written
//! while holding the one `Mutex`, and every `notify_*` is issued while
//! holding that same lock. Any real execution is therefore a
//! serialization of the protocol's critical sections. Chunk execution
//! happens outside the lock but touches only chunk-disjoint data (the
//! `SharedSlice` contract), so it can be modeled as one independent
//! atomic event between the job-capture and completion sections.
//! Exhaustively interleaving these atomic transitions — plus condvar
//! wait-sets with notify baked into the notifier's transition — covers
//! every behavior of the real protocol.
//!
//! A thread parks (enters a wait-set) *atomically with* its failed
//! predicate check, exactly the guarantee `Condvar::wait` gives by
//! taking the lock guard; a lost wakeup would therefore appear here as
//! a reachable state with no enabled transition. The main legs model no
//! spurious wakeups, so:
//!
//! * **no lost wakeup / no deadlock** — every reachable quiescent state
//!   is the fully-terminated one (workers exited, caller joined);
//! * **exactly-once chunks** — every non-empty chunk of every job runs
//!   exactly once (no double run, no skipped chunk) and `remaining`
//!   never underflows or absorbs a stale decrement;
//! * **panic visibility** — with the panic leg on, a caught worker
//!   panic is always observable by the caller once its barrier passes;
//! * **quiescence on drop** — shutdown leaves no thread parked.
//!
//! The spurious-wakeup leg re-checks all safety properties under
//! spontaneous wakes (deadlock-freedom is vacuous there: a parked
//! thread can always wake, so no state is transition-free until done).
//!
//! One intentional divergence from `StepPool::run`: jobs with
//! `n_items <= 1` take the inline fast path in the real pool (no
//! publish at all), so model configs use `n_items >= 2` — the protocol
//! is only exercised beyond that threshold.
//!
//! Run with `cargo test pool_model` (the legs are ordinary unit tests;
//! the largest explores a few thousand states and finishes in
//! milliseconds).

use std::collections::BTreeSet;

use super::pool::chunk_range;

/// One bounded scenario: a caller publishes `jobs` in sequence on a
/// pool with `workers` worker threads (`chunks = workers + 1`, as in
/// the real pool), then drops the pool.
#[derive(Clone)]
pub struct ModelCfg {
    pub workers: usize,
    /// `n_items` of each published job, in order (use values `>= 2`:
    /// below that the real pool runs inline and never publishes).
    pub jobs: Vec<usize>,
    /// Add spontaneous condvar wakeups. Safety-only leg: every
    /// assertion must still hold on every path, but deadlock-freedom
    /// becomes vacuous (a parked thread is always wakeable).
    pub spurious_wakeups: bool,
    /// Let every non-empty worker chunk nondeterministically panic
    /// (modeling the caught-and-recorded `catch_unwind` path).
    pub worker_may_panic: bool,
}

impl ModelCfg {
    pub fn new(workers: usize, jobs: &[usize]) -> ModelCfg {
        ModelCfg {
            workers,
            jobs: jobs.to_vec(),
            spurious_wakeups: false,
            worker_may_panic: false,
        }
    }
}

/// Caller program counter: each variant is the next atomic transition
/// the caller will take. `Barrier` re-runs its check on every wake,
/// exactly like the `while remaining > 0 { wait }` loop it models.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
enum CallerPc {
    Publish(usize),
    RunChunk0(usize),
    Barrier(usize),
    Shutdown,
    Join,
    Done,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
enum WorkerPc {
    /// About to run the wait-loop predicate check (lock held).
    Check,
    /// Captured `(gen, n_items)`; about to execute the chunk body
    /// outside the lock.
    Run(u64, usize),
    /// About to run the completion section; the flag is "my chunk
    /// panicked (caught)".
    Finish(u64, bool),
    Exited,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
struct Worker {
    pc: WorkerPc,
    seen_gen: u64,
    /// In the `work` condvar's wait-set (not schedulable until a
    /// notify — or a spurious wake — removes it).
    parked: bool,
}

/// One interleaving state: thread positions + the mutex-protected
/// `JobState` mirror + the run ledger the assertions check against.
#[derive(Clone, Debug, PartialEq, Eq, PartialOrd, Ord)]
struct State {
    caller: CallerPc,
    /// Caller is in the `done` condvar's wait-set.
    caller_parked: bool,
    workers: Vec<Worker>,
    gen: u64,
    task: bool,
    n_items: usize,
    remaining: usize,
    panicked: bool,
    shutdown: bool,
    /// `runs[(gen - 1) * chunks + chunk]`: times that chunk executed.
    runs: Vec<u8>,
    /// Ground truth per gen: some worker chunk of that job panicked.
    chunk_panics: Vec<bool>,
}

fn record_run(t: &mut State, gen: u64, chunk: usize, chunks: usize) {
    let idx = (gen - 1) as usize * chunks + chunk;
    assert_eq!(t.runs[idx], 0,
               "chunk {chunk} of job gen {gen} ran twice:\n{t:#?}");
    t.runs[idx] = 1;
}

/// Barrier-passed invariants: the job's chunks each ran exactly once
/// (empty chunks: zero times) and a worker panic, if any, is visible.
fn check_job_complete(s: &State, chunks: usize) {
    let gen = s.gen;
    let base = (gen - 1) as usize * chunks;
    for c in 0..chunks {
        let expect =
            usize::from(!chunk_range(s.n_items, chunks, c).is_empty());
        let got = s.runs[base + c] as usize;
        assert_eq!(got, expect,
                   "chunk {c} of job gen {gen} ran {got} time(s), \
                    expected {expect}:\n{s:#?}");
    }
    assert_eq!(s.panicked, s.chunk_panics[(gen - 1) as usize],
               "worker panic not faithfully recorded at the \
                barrier:\n{s:#?}");
}

fn check_terminal(s: &State) {
    assert!(s.workers.iter().all(|w| w.pc == WorkerPc::Exited
                                 && !w.parked),
            "drop did not quiesce the workers:\n{s:#?}");
    assert!(!s.task && s.remaining == 0 && !s.caller_parked,
            "terminal state is not clean:\n{s:#?}");
}

/// All states reachable in one atomic transition. An empty result on a
/// non-terminal state is a deadlock — with no spurious wakeups, that is
/// precisely a lost wakeup.
fn successors(s: &State, cfg: &ModelCfg) -> Vec<State> {
    let chunks = cfg.workers + 1;
    let mut out = Vec::new();

    if !s.caller_parked {
        match s.caller {
            CallerPc::Publish(j) => {
                assert!(!s.task,
                        "publish over a still-posted task (run is not \
                         reentrant):\n{s:#?}");
                let mut t = s.clone();
                t.gen += 1;
                t.task = true;
                t.n_items = cfg.jobs[j];
                t.remaining = chunks - 1;
                t.panicked = false;
                // notify_all(work), issued under the lock.
                for w in &mut t.workers {
                    w.parked = false;
                }
                t.caller = CallerPc::RunChunk0(j);
                out.push(t);
            }
            CallerPc::RunChunk0(j) => {
                let mut t = s.clone();
                if !chunk_range(t.n_items, chunks, 0).is_empty() {
                    let gen = t.gen;
                    record_run(&mut t, gen, 0, chunks);
                }
                t.caller = CallerPc::Barrier(j);
                out.push(t);
            }
            CallerPc::Barrier(j) => {
                let mut t = s.clone();
                if t.remaining > 0 {
                    t.caller_parked = true; // wait(done)
                } else {
                    check_job_complete(&t, chunks);
                    t.task = false;
                    t.caller = if j + 1 < cfg.jobs.len() {
                        CallerPc::Publish(j + 1)
                    } else {
                        CallerPc::Shutdown
                    };
                }
                out.push(t);
            }
            CallerPc::Shutdown => {
                let mut t = s.clone();
                t.shutdown = true;
                for w in &mut t.workers {
                    w.parked = false; // notify_all(work)
                }
                t.caller = CallerPc::Join;
                out.push(t);
            }
            CallerPc::Join => {
                // join() returns only once every worker exited.
                if s.workers.iter().all(|w| w.pc == WorkerPc::Exited) {
                    let mut t = s.clone();
                    t.caller = CallerPc::Done;
                    out.push(t);
                }
            }
            CallerPc::Done => {}
        }
    }

    for (i, w) in s.workers.iter().enumerate() {
        if w.parked {
            continue;
        }
        let chunk = i + 1;
        match w.pc {
            WorkerPc::Check => {
                let mut t = s.clone();
                if s.shutdown {
                    t.workers[i].pc = WorkerPc::Exited;
                } else if s.task && s.gen != w.seen_gen {
                    t.workers[i].pc = WorkerPc::Run(s.gen, s.n_items);
                    t.workers[i].seen_gen = s.gen;
                } else {
                    // wait(work): parking is atomic with the failed
                    // check — the lock is held throughout.
                    t.workers[i].parked = true;
                }
                out.push(t);
            }
            WorkerPc::Run(gen, n_items) => {
                if chunk_range(n_items, chunks, chunk).is_empty() {
                    let mut t = s.clone();
                    t.workers[i].pc = WorkerPc::Finish(gen, false);
                    out.push(t);
                } else {
                    let mut t = s.clone();
                    record_run(&mut t, gen, chunk, chunks);
                    t.workers[i].pc = WorkerPc::Finish(gen, false);
                    out.push(t);
                    if cfg.worker_may_panic {
                        let mut t = s.clone();
                        record_run(&mut t, gen, chunk, chunks);
                        t.chunk_panics[(gen - 1) as usize] = true;
                        t.workers[i].pc = WorkerPc::Finish(gen, true);
                        out.push(t);
                    }
                }
            }
            WorkerPc::Finish(gen, p) => {
                assert_eq!(gen, s.gen,
                           "stale completion: worker {chunk} finishing \
                            gen {gen}:\n{s:#?}");
                assert!(s.remaining > 0,
                        "remaining underflow (double \
                         decrement):\n{s:#?}");
                let mut t = s.clone();
                if p {
                    t.panicked = true;
                }
                t.remaining -= 1;
                if t.remaining == 0 {
                    // notify_one(done): the caller is the only thread
                    // that ever waits on `done`, so there is no wake
                    // choice to branch on.
                    t.caller_parked = false;
                }
                t.workers[i].pc = WorkerPc::Check;
                out.push(t);
            }
            WorkerPc::Exited => {}
        }
    }

    if cfg.spurious_wakeups {
        for (i, w) in s.workers.iter().enumerate() {
            if w.parked {
                let mut t = s.clone();
                t.workers[i].parked = false;
                out.push(t);
            }
        }
        if s.caller_parked {
            let mut t = s.clone();
            t.caller_parked = false;
            out.push(t);
        }
    }

    out
}

/// Runaway backstop, far above any bounded config in the tests.
const STATE_CAP: usize = 1_000_000;

/// Exhaustively explore every interleaving of `cfg`, panicking (with
/// the offending state) on any protocol violation. Returns the number
/// of distinct states visited.
pub fn explore(cfg: &ModelCfg) -> usize {
    assert!(cfg.workers >= 1, "a workerless pool never publishes");
    assert!(cfg.jobs.iter().all(|&n| n >= 2),
            "jobs below 2 items take the real pool's inline fast path");
    let chunks = cfg.workers + 1;
    let init = State {
        caller: if cfg.jobs.is_empty() {
            CallerPc::Shutdown
        } else {
            CallerPc::Publish(0)
        },
        caller_parked: false,
        workers: vec![
            Worker { pc: WorkerPc::Check, seen_gen: 0, parked: false };
            cfg.workers
        ],
        gen: 0,
        task: false,
        n_items: 0,
        remaining: 0,
        panicked: false,
        shutdown: false,
        runs: vec![0; cfg.jobs.len() * chunks],
        chunk_panics: vec![false; cfg.jobs.len()],
    };

    let mut visited: BTreeSet<State> = BTreeSet::new();
    let mut stack = vec![init.clone()];
    visited.insert(init);
    while let Some(s) = stack.pop() {
        let succ = successors(&s, cfg);
        if succ.is_empty() {
            if s.caller == CallerPc::Done {
                check_terminal(&s);
            } else {
                panic!("deadlock (lost wakeup): no enabled transition \
                        in a non-terminal state:\n{s:#?}");
            }
        }
        for t in succ {
            if !visited.contains(&t) {
                visited.insert(t.clone());
                stack.push(t);
            }
        }
        assert!(visited.len() <= STATE_CAP,
                "state-space cap exceeded — unbounded model?");
    }
    visited.len()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn two_workers_two_jobs_all_interleavings() {
        // The headline leg: 3 executors (caller + 2 workers), two
        // consecutive jobs — covers job handoff, gen observation races,
        // park/notify orderings, and drop.
        let n = explore(&ModelCfg::new(2, &[5, 4]));
        assert!(n > 200, "suspiciously small state space: {n}");
    }

    #[test]
    fn three_workers_single_job() {
        explore(&ModelCfg::new(3, &[7]));
    }

    #[test]
    fn empty_trailing_chunks_still_quiesce() {
        // 2 items over 3 chunks: chunk 2 is empty and must decrement
        // without executing.
        explore(&ModelCfg::new(2, &[2]));
    }

    #[test]
    fn shutdown_with_no_jobs_quiesces() {
        explore(&ModelCfg::new(3, &[]));
    }

    #[test]
    fn worker_panics_are_recorded_and_visible() {
        let mut cfg = ModelCfg::new(2, &[3]);
        cfg.worker_may_panic = true;
        explore(&cfg);
    }

    #[test]
    fn panicked_job_leaves_the_pool_reusable() {
        // A panic in job 1 must not poison job 2 (publish resets the
        // flag; check_job_complete asserts per-job ground truth).
        let mut cfg = ModelCfg::new(2, &[3, 4]);
        cfg.worker_may_panic = true;
        explore(&cfg);
    }

    #[test]
    fn spurious_wakeups_cannot_break_safety() {
        let mut cfg = ModelCfg::new(2, &[3, 2]);
        cfg.spurious_wakeups = true;
        explore(&cfg);
    }
}
