//! Explicit-SIMD (`core::arch`) variants of the sampling block kernels.
//!
//! Compiled only with the `simd` cargo feature. Each function here
//! replicates its portable reference in `engine::kernels::portable`
//! **operation for operation** — same per-lane arithmetic, same
//! accumulation order, same integer bit games — so results are
//! bit-identical with the feature on or off (pinned by
//! `engine::kernels::tests::dispatched_blocks_match_portable_bitwise`),
//! which is what keeps token streams invariant across builds.
//!
//! Dispatch is at runtime, cached after the first probe:
//!
//! | arch     | exp block | -ln block | row max |
//! |----------|-----------|-----------|---------|
//! | x86_64 + AVX2 | AVX2 | AVX2      | AVX2    |
//! | x86_64 (base) | SSE2 | portable¹ | SSE2    |
//! | aarch64       | NEON | NEON      | NEON    |
//! | other         | portable | portable | portable |
//!
//! ¹ SSE2 has no 64-bit integer compare or i64→f64 convert, which the
//!   `fln64` bit games need; the portable loop (auto-vectorized under
//!   `target-cpu=native`) stands in.
//!
//! The counter-based SplitMix64 uniforms feeding the Gumbel race stay
//! scalar everywhere: 64×64-bit multiplies have no AVX2/NEON lane form,
//! and the hash is a small fraction of the block cost next to `exp`/`ln`.

use crate::engine::kernels::{portable, BLK, LANES};

/// Instruction set selected for the block kernels on this host.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum Isa {
    Portable,
    Sse2,
    Avx2,
    Neon,
}

/// Runtime-detected ISA, probed once and cached.
#[cfg(target_arch = "x86_64")]
pub fn isa() -> Isa {
    use std::sync::atomic::{AtomicU8, Ordering};
    static CACHED: AtomicU8 = AtomicU8::new(0);
    match CACHED.load(Ordering::Relaxed) {
        2 => Isa::Avx2,
        1 => Isa::Sse2,
        _ => {
            let detected = if std::arch::is_x86_feature_detected!("avx2") {
                Isa::Avx2
            } else {
                Isa::Sse2 // x86_64 baseline
            };
            CACHED.store(if detected == Isa::Avx2 { 2 } else { 1 },
                         Ordering::Relaxed);
            detected
        }
    }
}

/// Runtime-detected ISA (NEON is baseline on aarch64).
#[cfg(target_arch = "aarch64")]
pub fn isa() -> Isa {
    Isa::Neon
}

/// Runtime-detected ISA (no explicit kernels for this architecture).
#[cfg(not(any(target_arch = "x86_64", target_arch = "aarch64")))]
pub fn isa() -> Isa {
    Isa::Portable
}

/// Dispatched `exp_accum_block`: see
/// [`portable::exp_accum_block`](crate::engine::kernels::portable) for
/// the contract.
#[inline]
pub fn exp_accum_block(x: &[f32], inv_temp: f32, ms: f32,
                       acc: &mut [f32; LANES], out: &mut [f32; BLK]) {
    match isa() {
        // SAFETY: isa() probed AVX2 support on this host.
        #[cfg(target_arch = "x86_64")]
        Isa::Avx2 => unsafe {
            x86::exp_accum_block_avx2(x, inv_temp, ms, acc, out)
        },
        // SAFETY: SSE2 is the x86_64 baseline.
        #[cfg(target_arch = "x86_64")]
        Isa::Sse2 => unsafe {
            x86::exp_accum_block_sse2(x, inv_temp, ms, acc, out)
        },
        // SAFETY: NEON is the aarch64 baseline.
        #[cfg(target_arch = "aarch64")]
        Isa::Neon => unsafe {
            arm::exp_accum_block_neon(x, inv_temp, ms, acc, out)
        },
        _ => portable::exp_accum_block(x, inv_temp, ms, acc, out),
    }
}

/// Dispatched in-place `-ln` block (SSE2 falls back to portable — no
/// 64-bit lane compare/convert).
#[inline]
pub fn neg_ln_block(u: &mut [f64; BLK]) {
    match isa() {
        // SAFETY: isa() probed AVX2 support on this host.
        #[cfg(target_arch = "x86_64")]
        Isa::Avx2 => unsafe { x86::neg_ln_block_avx2(u) },
        // SAFETY: NEON is the aarch64 baseline.
        #[cfg(target_arch = "aarch64")]
        Isa::Neon => unsafe { arm::neg_ln_block_neon(u) },
        _ => portable::neg_ln_block(u),
    }
}

/// Dispatched row max.
#[inline]
pub fn row_max(logits: &[f32]) -> f32 {
    match isa() {
        // SAFETY: isa() probed AVX2 support on this host.
        #[cfg(target_arch = "x86_64")]
        Isa::Avx2 => unsafe { x86::row_max_avx2(logits) },
        // SAFETY: SSE2 is the x86_64 baseline.
        #[cfg(target_arch = "x86_64")]
        Isa::Sse2 => unsafe { x86::row_max_sse2(logits) },
        // SAFETY: NEON is the aarch64 baseline.
        #[cfg(target_arch = "aarch64")]
        Isa::Neon => unsafe { arm::row_max_neon(logits) },
        _ => portable::row_max(logits),
    }
}

// ---------------------------------------------------------------------------
// Baseline-ISA entry points (SSE2 on x86_64, NEON on aarch64, portable
// elsewhere). The runtime dispatcher never picks these on a host with a
// better ISA, but a *different* host would — so the bit-identity test in
// `engine::kernels` calls them directly: the "bitwise identical with
// simd on/off" guarantee must hold for every variant any machine could
// dispatch to, not just the best one on the CI runner.
// ---------------------------------------------------------------------------

/// Baseline-ISA `exp_accum_block` (see above).
pub fn exp_accum_block_baseline(x: &[f32], inv_temp: f32, ms: f32,
                                acc: &mut [f32; LANES],
                                out: &mut [f32; BLK]) {
    #[cfg(target_arch = "x86_64")]
    // SAFETY: SSE2 is the x86_64 baseline.
    return unsafe { x86::exp_accum_block_sse2(x, inv_temp, ms, acc, out) };
    #[cfg(target_arch = "aarch64")]
    // SAFETY: NEON is the aarch64 baseline.
    return unsafe { arm::exp_accum_block_neon(x, inv_temp, ms, acc, out) };
    #[cfg(not(any(target_arch = "x86_64", target_arch = "aarch64")))]
    portable::exp_accum_block(x, inv_temp, ms, acc, out)
}

/// Baseline-ISA `-ln` block (portable on x86_64 — SSE2 has no 64-bit
/// lane compare/convert, exactly what the dispatcher does there).
pub fn neg_ln_block_baseline(u: &mut [f64; BLK]) {
    #[cfg(target_arch = "aarch64")]
    // SAFETY: NEON is the aarch64 baseline.
    return unsafe { arm::neg_ln_block_neon(u) };
    #[cfg(not(target_arch = "aarch64"))]
    portable::neg_ln_block(u)
}

/// Baseline-ISA row max (see above).
pub fn row_max_baseline(logits: &[f32]) -> f32 {
    #[cfg(target_arch = "x86_64")]
    // SAFETY: SSE2 is the x86_64 baseline.
    return unsafe { x86::row_max_sse2(logits) };
    #[cfg(target_arch = "aarch64")]
    // SAFETY: NEON is the aarch64 baseline.
    return unsafe { arm::row_max_neon(logits) };
    #[cfg(not(any(target_arch = "x86_64", target_arch = "aarch64")))]
    portable::row_max(logits)
}

#[cfg(target_arch = "x86_64")]
mod x86 {
    use core::arch::x86_64::*;

    use crate::engine::kernels::{EXP_C1, EXP_C2, EXP_C3, EXP_C4, EXP_C5,
                                 EXP_MAGIC, LN_POLY, LN_SQRT2_MANT, BLK,
                                 LANES};

    /// AVX2 `exp_accum_block`: eight `fexp32` lanes per iteration, lane
    /// accumulation in the portable order.
    ///
    /// # Safety
    ///
    /// Caller must have verified AVX2 support (`super::isa()`).
    #[target_feature(enable = "avx2")]
    pub unsafe fn exp_accum_block_avx2(x: &[f32], inv_temp: f32, ms: f32,
                                       acc: &mut [f32; LANES],
                                       out: &mut [f32; BLK]) {
        debug_assert_eq!(x.len(), BLK);
        let inv_t = _mm256_set1_ps(inv_temp);
        let msv = _mm256_set1_ps(ms);
        let log2e = _mm256_set1_ps(std::f32::consts::LOG2E);
        let lo = _mm256_set1_ps(-126.0);
        let hi = _mm256_set1_ps(126.0);
        let magic = _mm256_set1_ps(EXP_MAGIC);
        let mant_mask = _mm256_set1_epi32(0x7f_ffff);
        let bias = _mm256_set1_epi32(0x40_0000);
        let one = _mm256_set1_ps(1.0);
        let c1 = _mm256_set1_ps(EXP_C1);
        let c2 = _mm256_set1_ps(EXP_C2);
        let c3 = _mm256_set1_ps(EXP_C3);
        let c4 = _mm256_set1_ps(EXP_C4);
        let c5 = _mm256_set1_ps(EXP_C5);
        let mut accv = _mm256_loadu_ps(acc.as_ptr());
        let mut k = 0;
        while k < BLK {
            let xv = _mm256_loadu_ps(x.as_ptr().add(k));
            // Mirrors fexp32(x·inv_temp - ms) term for term.
            let xa = _mm256_sub_ps(_mm256_mul_ps(xv, inv_t), msv);
            let z = _mm256_min_ps(
                _mm256_max_ps(_mm256_mul_ps(xa, log2e), lo), hi);
            let zs = _mm256_add_ps(z, magic);
            let n = _mm256_sub_epi32(
                _mm256_and_si256(_mm256_castps_si256(zs), mant_mask),
                bias);
            let r = _mm256_sub_ps(z, _mm256_sub_ps(zs, magic));
            let r2 = _mm256_mul_ps(r, r);
            let t1 = _mm256_add_ps(one, _mm256_mul_ps(c1, r));
            let t2 = _mm256_add_ps(c2, _mm256_mul_ps(c3, r));
            let t3 = _mm256_add_ps(c4, _mm256_mul_ps(c5, r));
            let p = _mm256_add_ps(
                t1,
                _mm256_mul_ps(r2, _mm256_add_ps(t2, _mm256_mul_ps(r2, t3))),
            );
            let e = _mm256_castsi256_ps(_mm256_add_epi32(
                _mm256_castps_si256(p),
                _mm256_slli_epi32::<23>(n),
            ));
            _mm256_storeu_ps(out.as_mut_ptr().add(k), e);
            accv = _mm256_add_ps(accv, e);
            k += 8;
        }
        _mm256_storeu_ps(acc.as_mut_ptr(), accv);
    }

    /// Four `fexp32` lanes on a pre-scaled argument (`x·inv_temp - ms`).
    ///
    /// # Safety
    ///
    /// SSE2 is the x86_64 baseline; unsafe only for the intrinsics.
    #[inline]
    unsafe fn exp4_sse2(xa: __m128) -> __m128 {
        let log2e = _mm_set1_ps(std::f32::consts::LOG2E);
        let lo = _mm_set1_ps(-126.0);
        let hi = _mm_set1_ps(126.0);
        let magic = _mm_set1_ps(EXP_MAGIC);
        let z = _mm_min_ps(_mm_max_ps(_mm_mul_ps(xa, log2e), lo), hi);
        let zs = _mm_add_ps(z, magic);
        let n = _mm_sub_epi32(
            _mm_and_si128(_mm_castps_si128(zs), _mm_set1_epi32(0x7f_ffff)),
            _mm_set1_epi32(0x40_0000),
        );
        let r = _mm_sub_ps(z, _mm_sub_ps(zs, magic));
        let r2 = _mm_mul_ps(r, r);
        let t1 = _mm_add_ps(_mm_set1_ps(1.0),
                            _mm_mul_ps(_mm_set1_ps(EXP_C1), r));
        let t2 = _mm_add_ps(_mm_set1_ps(EXP_C2),
                            _mm_mul_ps(_mm_set1_ps(EXP_C3), r));
        let t3 = _mm_add_ps(_mm_set1_ps(EXP_C4),
                            _mm_mul_ps(_mm_set1_ps(EXP_C5), r));
        let p = _mm_add_ps(
            t1, _mm_mul_ps(r2, _mm_add_ps(t2, _mm_mul_ps(r2, t3))));
        _mm_castsi128_ps(_mm_add_epi32(_mm_castps_si128(p),
                                       _mm_slli_epi32::<23>(n)))
    }

    /// SSE2 `exp_accum_block`: the 8-lane accumulator is kept as two
    /// 4-lane halves, preserving the portable per-lane add order.
    ///
    /// # Safety
    ///
    /// SSE2 is the x86_64 baseline; unsafe only for the raw loads.
    pub unsafe fn exp_accum_block_sse2(x: &[f32], inv_temp: f32, ms: f32,
                                       acc: &mut [f32; LANES],
                                       out: &mut [f32; BLK]) {
        debug_assert_eq!(x.len(), BLK);
        let inv_t = _mm_set1_ps(inv_temp);
        let msv = _mm_set1_ps(ms);
        let mut acc_lo = _mm_loadu_ps(acc.as_ptr());
        let mut acc_hi = _mm_loadu_ps(acc.as_ptr().add(4));
        let mut k = 0;
        while k < BLK {
            let x0 = _mm_loadu_ps(x.as_ptr().add(k));
            let x1 = _mm_loadu_ps(x.as_ptr().add(k + 4));
            let e0 = exp4_sse2(_mm_sub_ps(_mm_mul_ps(x0, inv_t), msv));
            let e1 = exp4_sse2(_mm_sub_ps(_mm_mul_ps(x1, inv_t), msv));
            _mm_storeu_ps(out.as_mut_ptr().add(k), e0);
            _mm_storeu_ps(out.as_mut_ptr().add(k + 4), e1);
            acc_lo = _mm_add_ps(acc_lo, e0);
            acc_hi = _mm_add_ps(acc_hi, e1);
            k += 8;
        }
        _mm_storeu_ps(acc.as_mut_ptr(), acc_lo);
        _mm_storeu_ps(acc.as_mut_ptr().add(4), acc_hi);
    }

    /// AVX2 `-fln64` over one block: four f64 lanes per iteration,
    /// mirroring the scalar mantissa/exponent bit games exactly. The
    /// exponent field is converted to f64 via the 2^52 magic-or trick
    /// (exact for the 11-bit field; AVX2 has no i64→f64 convert).
    ///
    /// # Safety
    ///
    /// Caller must have verified AVX2 support (`super::isa()`).
    #[target_feature(enable = "avx2")]
    pub unsafe fn neg_ln_block_avx2(u: &mut [f64; BLK]) {
        let mant_mask = _mm256_set1_epi64x(0x000f_ffff_ffff_ffff);
        let sqrt2_lt = _mm256_set1_epi64x(LN_SQRT2_MANT as i64 - 1);
        let exp_field = _mm256_set1_epi64x(0x7ff);
        let int_magic = _mm256_set1_epi64x(0x4330_0000_0000_0000);
        let int_magic_f = _mm256_set1_pd(4_503_599_627_370_496.0); // 2^52
        let bias_f = _mm256_set1_pd(1023.0);
        let one_bit52 = _mm256_set1_epi64x(1i64 << 52);
        let exp_bias = _mm256_set1_epi64x(1023i64 << 52);
        let one = _mm256_set1_pd(1.0);
        let half = _mm256_set1_pd(0.5);
        let ln2 = _mm256_set1_pd(std::f64::consts::LN_2);
        let sign = _mm256_set1_pd(-0.0);
        let mut k = 0;
        while k < BLK {
            let xv = _mm256_loadu_pd(u.as_ptr().add(k));
            let bits = _mm256_castpd_si256(xv);
            let mant = _mm256_and_si256(bits, mant_mask);
            // mant >= sqrt(2) mantissa, as mant > (threshold - 1): both
            // operands are < 2^52 so the signed compare is exact.
            let ge = _mm256_cmpgt_epi64(mant, sqrt2_lt);
            let eraw = _mm256_and_si256(_mm256_srli_epi64::<52>(bits),
                                        exp_field);
            let ef = _mm256_sub_pd(
                _mm256_castsi256_pd(_mm256_or_si256(eraw, int_magic)),
                int_magic_f,
            );
            let adj_f = _mm256_and_pd(_mm256_castsi256_pd(ge), one);
            // e = raw_exponent - 1023 + adj, exactly (all integers).
            let e_val = _mm256_add_pd(_mm256_sub_pd(ef, bias_f), adj_f);
            let sub52 = _mm256_and_si256(ge, one_bit52);
            let biased = _mm256_sub_epi64(exp_bias, sub52);
            let m = _mm256_castsi256_pd(_mm256_or_si256(mant, biased));
            let w = _mm256_sub_pd(m, one);
            let z = _mm256_mul_pd(w, w);
            let mut p = _mm256_set1_pd(LN_POLY[0]);
            for &c in &LN_POLY[1..] {
                p = _mm256_add_pd(_mm256_mul_pd(p, w), _mm256_set1_pd(c));
            }
            let y = _mm256_sub_pd(_mm256_mul_pd(_mm256_mul_pd(w, z), p),
                                  _mm256_mul_pd(half, z));
            let res = _mm256_add_pd(_mm256_add_pd(w, y),
                                    _mm256_mul_pd(e_val, ln2));
            // -x = exact sign-bit flip, matching the scalar negation.
            _mm256_storeu_pd(u.as_mut_ptr().add(k),
                             _mm256_xor_pd(res, sign));
            k += 4;
        }
    }

    /// AVX2 row max (portable lane order: vector max per 8-chunk, lanes
    /// folded sequentially, scalar remainder).
    ///
    /// # Safety
    ///
    /// Caller must have verified AVX2 support (`super::isa()`).
    #[target_feature(enable = "avx2")]
    pub unsafe fn row_max_avx2(logits: &[f32]) -> f32 {
        let n = logits.len();
        let mut accv = _mm256_set1_ps(f32::NEG_INFINITY);
        let mut i = 0;
        while i + 8 <= n {
            accv = _mm256_max_ps(_mm256_loadu_ps(logits.as_ptr().add(i)),
                                 accv);
            i += 8;
        }
        let mut acc = [f32::NEG_INFINITY; LANES];
        _mm256_storeu_ps(acc.as_mut_ptr(), accv);
        let mut m = f32::NEG_INFINITY;
        for &a in &acc {
            m = a.max(m);
        }
        while i < n {
            m = logits[i].max(m);
            i += 1;
        }
        m
    }

    /// SSE2 row max, two 4-lane halves of the 8-lane accumulator.
    ///
    /// # Safety
    ///
    /// SSE2 is the x86_64 baseline; unsafe only for the raw loads.
    pub unsafe fn row_max_sse2(logits: &[f32]) -> f32 {
        let n = logits.len();
        let mut acc_lo = _mm_set1_ps(f32::NEG_INFINITY);
        let mut acc_hi = _mm_set1_ps(f32::NEG_INFINITY);
        let mut i = 0;
        while i + 8 <= n {
            acc_lo = _mm_max_ps(_mm_loadu_ps(logits.as_ptr().add(i)),
                                acc_lo);
            acc_hi = _mm_max_ps(_mm_loadu_ps(logits.as_ptr().add(i + 4)),
                                acc_hi);
            i += 8;
        }
        let mut acc = [f32::NEG_INFINITY; LANES];
        _mm_storeu_ps(acc.as_mut_ptr(), acc_lo);
        _mm_storeu_ps(acc.as_mut_ptr().add(4), acc_hi);
        let mut m = f32::NEG_INFINITY;
        for &a in &acc {
            m = a.max(m);
        }
        while i < n {
            m = logits[i].max(m);
            i += 1;
        }
        m
    }
}

#[cfg(target_arch = "aarch64")]
mod arm {
    use core::arch::aarch64::*;

    use crate::engine::kernels::{EXP_C1, EXP_C2, EXP_C3, EXP_C4, EXP_C5,
                                 EXP_MAGIC, LN_POLY, LN_SQRT2_MANT, BLK,
                                 LANES};

    /// Four `fexp32` lanes on a pre-scaled argument.
    ///
    /// # Safety
    ///
    /// NEON is the aarch64 baseline; unsafe only for the intrinsics.
    #[inline]
    unsafe fn exp4_neon(xa: float32x4_t) -> float32x4_t {
        let log2e = vdupq_n_f32(std::f32::consts::LOG2E);
        let lo = vdupq_n_f32(-126.0);
        let hi = vdupq_n_f32(126.0);
        let magic = vdupq_n_f32(EXP_MAGIC);
        let z = vminq_f32(vmaxq_f32(vmulq_f32(xa, log2e), lo), hi);
        let zs = vaddq_f32(z, magic);
        let n = vsubq_s32(
            vandq_s32(vreinterpretq_s32_f32(zs), vdupq_n_s32(0x7f_ffff)),
            vdupq_n_s32(0x40_0000),
        );
        let r = vsubq_f32(z, vsubq_f32(zs, magic));
        let r2 = vmulq_f32(r, r);
        let t1 = vaddq_f32(vdupq_n_f32(1.0),
                           vmulq_f32(vdupq_n_f32(EXP_C1), r));
        let t2 = vaddq_f32(vdupq_n_f32(EXP_C2),
                           vmulq_f32(vdupq_n_f32(EXP_C3), r));
        let t3 = vaddq_f32(vdupq_n_f32(EXP_C4),
                           vmulq_f32(vdupq_n_f32(EXP_C5), r));
        let p = vaddq_f32(t1, vmulq_f32(r2, vaddq_f32(t2, vmulq_f32(r2, t3))));
        vreinterpretq_f32_s32(vaddq_s32(vreinterpretq_s32_f32(p),
                                        vshlq_n_s32::<23>(n)))
    }

    /// NEON `exp_accum_block` (two 4-lane halves of the 8-lane
    /// accumulator, portable add order).
    ///
    /// # Safety
    ///
    /// NEON is the aarch64 baseline; unsafe only for the raw loads.
    pub unsafe fn exp_accum_block_neon(x: &[f32], inv_temp: f32, ms: f32,
                                       acc: &mut [f32; LANES],
                                       out: &mut [f32; BLK]) {
        debug_assert_eq!(x.len(), BLK);
        let inv_t = vdupq_n_f32(inv_temp);
        let msv = vdupq_n_f32(ms);
        let mut acc_lo = vld1q_f32(acc.as_ptr());
        let mut acc_hi = vld1q_f32(acc.as_ptr().add(4));
        let mut k = 0;
        while k < BLK {
            let x0 = vld1q_f32(x.as_ptr().add(k));
            let x1 = vld1q_f32(x.as_ptr().add(k + 4));
            let e0 = exp4_neon(vsubq_f32(vmulq_f32(x0, inv_t), msv));
            let e1 = exp4_neon(vsubq_f32(vmulq_f32(x1, inv_t), msv));
            vst1q_f32(out.as_mut_ptr().add(k), e0);
            vst1q_f32(out.as_mut_ptr().add(k + 4), e1);
            acc_lo = vaddq_f32(acc_lo, e0);
            acc_hi = vaddq_f32(acc_hi, e1);
            k += 8;
        }
        vst1q_f32(acc.as_mut_ptr(), acc_lo);
        vst1q_f32(acc.as_mut_ptr().add(4), acc_hi);
    }

    /// NEON `-fln64` over one block, two f64 lanes per iteration
    /// (aarch64 has a direct exact i64→f64 convert).
    ///
    /// # Safety
    ///
    /// NEON is the aarch64 baseline; unsafe only for the raw loads.
    pub unsafe fn neg_ln_block_neon(u: &mut [f64; BLK]) {
        let mut k = 0;
        while k < BLK {
            let xv = vld1q_f64(u.as_ptr().add(k));
            let bits = vreinterpretq_u64_f64(xv);
            let mant = vandq_u64(bits, vdupq_n_u64(0x000f_ffff_ffff_ffff));
            let ge = vcgeq_u64(mant, vdupq_n_u64(LN_SQRT2_MANT));
            let eraw = vandq_u64(vshrq_n_u64::<52>(bits),
                                 vdupq_n_u64(0x7ff));
            let adj = vandq_u64(ge, vdupq_n_u64(1));
            let e_i = vsubq_s64(
                vaddq_s64(vreinterpretq_s64_u64(eraw),
                          vreinterpretq_s64_u64(adj)),
                vdupq_n_s64(1023),
            );
            let e_f = vcvtq_f64_s64(e_i);
            let sub52 = vandq_u64(ge, vdupq_n_u64(1u64 << 52));
            let biased = vsubq_u64(vdupq_n_u64(1023u64 << 52), sub52);
            let m = vreinterpretq_f64_u64(vorrq_u64(mant, biased));
            let w = vsubq_f64(m, vdupq_n_f64(1.0));
            let z = vmulq_f64(w, w);
            let mut p = vdupq_n_f64(LN_POLY[0]);
            for &c in &LN_POLY[1..] {
                p = vaddq_f64(vmulq_f64(p, w), vdupq_n_f64(c));
            }
            let y = vsubq_f64(vmulq_f64(vmulq_f64(w, z), p),
                              vmulq_f64(vdupq_n_f64(0.5), z));
            let res = vaddq_f64(
                vaddq_f64(w, y),
                vmulq_f64(e_f, vdupq_n_f64(std::f64::consts::LN_2)),
            );
            vst1q_f64(u.as_mut_ptr().add(k), vnegq_f64(res));
            k += 2;
        }
    }

    /// NEON row max (portable lane order).
    ///
    /// # Safety
    ///
    /// NEON is the aarch64 baseline; unsafe only for the raw loads.
    pub unsafe fn row_max_neon(logits: &[f32]) -> f32 {
        let n = logits.len();
        let mut acc_lo = vdupq_n_f32(f32::NEG_INFINITY);
        let mut acc_hi = vdupq_n_f32(f32::NEG_INFINITY);
        let mut i = 0;
        while i + 8 <= n {
            acc_lo = vmaxq_f32(vld1q_f32(logits.as_ptr().add(i)), acc_lo);
            acc_hi = vmaxq_f32(vld1q_f32(logits.as_ptr().add(i + 4)),
                               acc_hi);
            i += 8;
        }
        let mut acc = [f32::NEG_INFINITY; LANES];
        vst1q_f32(acc.as_mut_ptr(), acc_lo);
        vst1q_f32(acc.as_mut_ptr().add(4), acc_hi);
        let mut m = f32::NEG_INFINITY;
        for &a in &acc {
            m = a.max(m);
        }
        while i < n {
            m = logits[i].max(m);
            i += 1;
        }
        m
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn isa_probe_is_stable() {
        let a = isa();
        let b = isa();
        assert_eq!(a, b);
        // On x86_64 the probe must land on a real x86 ISA.
        #[cfg(target_arch = "x86_64")]
        assert!(matches!(a, Isa::Avx2 | Isa::Sse2));
    }
}
