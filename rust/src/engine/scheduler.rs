//! Continuous-batching scheduler for the sampling engine.
//!
//! The original engine ran each batch to completion: every request waited
//! for the slowest sequence in its bucket, and padding rows — never marked
//! done — generated garbage until the last real row finished. This module
//! restructures sampling as a **step-based slot machine** (the design the
//! speculative-decoding serving literature calls continuous batching):
//!
//! * `admit` enqueues a sequence (speculative or MDM) and returns a
//!   `SlotId` handle;
//! * `step` runs **one outer loop** — one draft pass plus, for the
//!   speculative sampler, its inner verify/accept sweeps — over the
//!   currently resident sequences, retires everything that finished, and
//!   backfills freed slots from the pending queue *between* outer loops;
//! * rows beyond the resident count are pure mask padding and do **zero**
//!   generation work (no RNG, no accept/reject accounting, no reveals).
//!
//! The slot table is sized to the model's largest batch bucket, and each
//! step executes in the smallest bucket that covers the resident count
//! ([`pick_bucket`] — the single bucket policy in the codebase, also
//! re-exported as `coordinator::batcher::pick_bucket` for the L3 layer).
//! Because admission overflow parks in the pending queue, a
//! request with more samples than the largest bucket is transparently
//! chunked across steps instead of being handed to an uncompiled batch
//! size.
//!
//! ## Zero-allocation steps (the arena invariant)
//!
//! All per-step buffers live in a [`StepArena`] owned by the scheduler:
//! token/sigma staging, both logits buffers (filled in place via
//! `HybridModel::draft_into` / `verify_into`), the per-row draft LSE
//! table, the residual scratch row, and the step-local bookkeeping vecs.
//! After the first step warms their capacities, a steady-state `step`
//! performs **zero heap allocations** (asserted by
//! `tests/alloc_regression.rs`; retirement and backfill may allocate, the
//! per-step sampling work never does). The old hot loop instead
//! materialized a `Vec<Vec<Vec<f64>>>` of full softmax rows per outer
//! loop — B·D·V f64 of transient probability mass — even though the
//! accept test only reads one scalar per row; that table is gone,
//! replaced by `engine::kernels` logits-domain primitives (Gumbel-max
//! draws, cached log-sum-exps, lazy residuals — see the module docs there
//! for the identities and the RNG-stream compatibility note).
//!
//! Drafting is also **window-lazy**: an outer loop only samples the
//! ordering positions its accept window can consume (`[i, i + W(i))`).
//! The old loop drew and softmaxed *every* remaining position each outer
//! loop, but positions beyond the window were never accept-tested and
//! were redrawn from fresh logits the next loop — dead work. Positions
//! beyond the window enter the verify pass as mask tokens; causal tracks
//! `< target` never attend to them, so consumed logits are unchanged.
//!
//! ## Planar step execution (the parallel hot loop)
//!
//! The per-step sampling work is organized as **planar phases over the
//! whole arena** instead of per-row interleaved loops: (1) a *draw*
//! phase performs all Gumbel draws for all residents, (2) a batched
//! *LSE* phase computes every verify-row log-sum-exp the current verify
//! pass can consume into one flat table (`verify_lse` — each row exactly
//! once), and (3) an *accept/residual* phase runs the per-resident
//! accept sweeps reading only cached scalars. Each phase executes
//! chunked across a fixed-worker [`StepPool`] (`engine::pool`,
//! installed via [`SpecScheduler::set_pool`]; the default single-thread
//! pool is the exact sequential code path). Residents are independent —
//! per-sequence counter-based RNG streams, disjoint arena rows — so
//! **token streams and all counters are bitwise identical for any
//! thread count**. Per-phase wall-clock costs are accumulated into
//! [`StepPhases`] for the coordinator's step-cost reporting.
//!
//! ## Preemption (checkpoint / evict / resume)
//!
//! Residents are **evictable mid-sequence**: between steps,
//! [`SpecScheduler::evict`] / [`SpecScheduler::evict_lowest`] pull a
//! resident out as a [`SeqCheckpoint`] (revealed tokens, σ/window
//! position, accept/reject tallies, and the sequence's counter-based RNG
//! stream), freeing its slot; [`SpecScheduler::resume`] re-admits the
//! checkpoint at the front of its priority class. Because every
//! sequence owns an independent RNG stream and the model conditions each
//! row only on that row, a preempted sequence's token stream is
//! **bitwise identical** to the same-seed unpreempted run — and evicting
//! it cannot perturb its neighbours either (pinned by
//! `evict_resume_is_bitwise_identical`). Admissions carry a `priority`
//! class ([`SpecScheduler::admit_prio`]) ordering the pending queue, so
//! the serving layer can both queue-jump urgent work and choose
//! preemption victims lowest-priority-first.
//!
//! `speculative_sample` / `mdm_sample` remain as drive-to-completion
//! wrappers over this scheduler, so single-shot call sites (likelihood
//! cross-checks, harnesses, examples, benches) are unchanged.

use std::any::Any;
use std::collections::VecDeque;
use std::sync::Arc;

use crate::engine::kernels;
use crate::engine::mdm::{mdm_alpha, MdmParams};
use crate::engine::pool::{SharedSlice, StepPool};
use crate::engine::{HybridModel, Prompt, Sample, SpecParams, SpecStats};
use crate::util::rng::Pcg;
use crate::util::simclock::{Clock, MonotonicClock};

/// Handle for an admitted sequence; unique within one scheduler.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct SlotId(pub u64);

/// Per-sequence sampler settings, fixed at admission.
#[derive(Clone, Debug)]
pub enum SeqParams {
    /// Algorithm 3: speculative draft/verify loops.
    Spec(SpecParams),
    /// Standard masked-diffusion baseline on a cosine grid.
    Mdm(MdmParams),
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Mode {
    Spec,
    Mdm,
}

/// Speculative per-sequence state machine (Alg. 3), extracted from the old
/// monolithic `speculative_sample` loop.
pub(crate) struct SeqState {
    pub tokens: Vec<i32>,
    pub sigma: Vec<i32>,
    /// revealed[pos]: position already carries its final token. Kept
    /// incrementally — rebuilding it from sigma[..i] each outer loop made
    /// the draft-context build O(D^2 * i) (see EXPERIMENTS.md §Perf L3).
    pub revealed: Vec<bool>,
    /// Tokens revealed so far (= next ordering position to decide).
    pub i: usize,
    pub done: bool,
    pub nfe: f64,
    pub outer: usize,
    pub accepted: usize,
    pub rejected: usize,
    pub rng: Pcg,
}

/// MDM per-sequence state machine (Shi et al. grid with the Zheng fix),
/// extracted from the old `mdm_sample` loop. The grid index is per-row, so
/// a scheduler step can fast-forward through reveal-free grid steps (which
/// the paper's best-case NFE accounting already treated as skippable).
struct MdmState {
    tokens: Vec<i32>,
    masked: Vec<usize>,
    m0: usize,
    grid_step: usize,
    nfe: f64,
    steps_used: usize,
    rng: Pcg,
}

enum Kernel {
    Spec(SeqState, SpecParams),
    Mdm(MdmState, MdmParams),
}

struct Slot {
    id: SlotId,
    /// Per-request priority class: within one scheduler the pending
    /// queue is ordered by descending priority (FIFO inside a class), so
    /// a high-priority sequence overtakes queued lower-priority work
    /// without touching residents.
    priority: i32,
    /// True for a sequence re-entering via [`SpecScheduler::resume`]:
    /// its re-placement is counted in `resumes` (not `placements`/
    /// `backfills`) so callers never observe a second queue wait for it.
    resumed: bool,
    kernel: Kernel,
}

/// A mid-sequence checkpoint: everything one evicted sequence needs to
/// continue later with a **bitwise-identical token stream** — revealed
/// tokens, the σ ordering and window position (`SeqState::i` /
/// `MdmState`'s grid cursor), accept/reject tallies, and the
/// per-resident counter-based RNG stream (the `Pcg` state *is* the
/// stream offset, so resuming replays exactly the draws an unpreempted
/// run would have made). Produced by [`SpecScheduler::evict`] /
/// [`SpecScheduler::evict_lowest`] between steps; the caller holds it
/// (off the scheduler) until [`SpecScheduler::resume`]. Sequences are
/// mutually independent (per-sequence RNG streams, per-row model
/// conditioning), so eviction can never perturb the streams of the
/// sequences left behind either.
pub struct SeqCheckpoint {
    slot: Slot,
}

impl SeqCheckpoint {
    /// The evicted sequence's slot handle; preserved across resume, so
    /// caller-side routing keyed by [`SlotId`] stays valid.
    pub fn id(&self) -> SlotId {
        self.slot.id
    }

    pub fn priority(&self) -> i32 {
        self.slot.priority
    }

    /// Ordering positions already decided (speculative: the σ-prefix
    /// length; MDM: initially-masked positions revealed so far).
    pub fn progress(&self) -> usize {
        match &self.slot.kernel {
            Kernel::Spec(s, _) => s.i,
            Kernel::Mdm(m, _) => m.m0 - m.masked.len(),
        }
    }
}

/// Raw pointer to one resident's slot, collected once per step so the
/// planar phases can hand each pool chunk a disjoint set of residents to
/// mutate. `Send + Sync` is sound because the pool assigns every
/// resident index to exactly one chunk. The pointers are all derived
/// from a single raw base of the slot buffer (not per-element indexing,
/// which would invalidate siblings under Stacked Borrows), and `slots`
/// itself is not touched again until the phases finish.
struct ResidentPtr(*mut Slot);

// SAFETY: see the type docs above — each resident index is handed to
// exactly one pool chunk, so no two threads alias one slot.
unsafe impl Send for ResidentPtr {}
// SAFETY: same disjointness argument as Send; shared references to the
// wrapper only ever yield the one chunk-owned slot pointer.
unsafe impl Sync for ResidentPtr {}

/// Wall-clock cost of scheduler steps since the last
/// [`SpecScheduler::take_phases`], split by planar phase. The
/// coordinator exports these as per-phase histograms and feeds the total
/// to the cross-queue selector's step-cost accounting.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct StepPhases {
    /// Model forward passes (`draft_into` + `verify_into`).
    pub model_s: f64,
    /// Draw phase: all Gumbel draws for all residents.
    pub draw_s: f64,
    /// Batched verify-row log-sum-exp phase. Zero on a single-thread
    /// pool: there the LSEs are computed lazily inside the accept sweep
    /// (and thus billed to `accept_s`) — same scalars, no wasted work
    /// past a rejection.
    pub lse_s: f64,
    /// Accept/residual phase (cached LSE scalars on multi-thread pools;
    /// includes the lazy LSEs on single-thread pools).
    pub accept_s: f64,
}

impl StepPhases {
    /// Non-model scheduler CPU time (the part the step pool scales).
    pub fn sampling_s(&self) -> f64 {
        self.draw_s + self.lse_s + self.accept_s
    }

    pub fn total_s(&self) -> f64 {
        self.model_s + self.sampling_s()
    }
}

/// All per-step buffers, owned by the scheduler so steady-state steps
/// reuse capacity instead of allocating (see module docs). The model
/// `State` is retained type-erased because `SpecScheduler` itself is not
/// generic over the model.
struct StepArena {
    /// Step-local list of resident slot indices.
    active: Vec<usize>,
    /// Step-local raw slot pointers, one per resident (see
    /// [`ResidentPtr`]); rebuilt each step, reused capacity.
    residents: Vec<ResidentPtr>,
    /// `[bucket, D]` masked draft input (mask-padded past the residents).
    masked_tokens: Vec<i32>,
    /// `[bucket, D]` verify input: decided prefix + window draws; mask
    /// beyond the window (causal tracks below the window never attend to
    /// those positions, so their logits are unaffected).
    full_tokens: Vec<i32>,
    /// `[bucket, D]` orderings (identity for padding rows).
    sigma_flat: Vec<i32>,
    /// Draft logits `[bucket, D, V]`, rebuilt in place by `draft_into`.
    draft_logits: Vec<f32>,
    /// Target logits `[bucket, D, V]`, rebuilt in place by `verify_into`.
    target_logits: Vec<f32>,
    /// Per-row log-sum-exp of the drafted rows, cached at draw time and
    /// reused by every accept test of the outer loop (replaces the old
    /// per-row softmax vectors). Indexed `r * D + pos`.
    draft_lse: Vec<f64>,
    /// Per-verify-pass flat table of target-row log-sum-exps, indexed
    /// `r * D + track` — filled by the batched LSE phase so the accept
    /// phase consumes only cached scalars.
    verify_lse: Vec<f64>,
    /// Work list of the LSE phase: `(flat row index, 1/temperature)`
    /// per verify row the current pass can consume.
    lse_jobs: Vec<(u32, f32)>,
    /// Reusable V-length rows for lazy residual resampling, one per pool
    /// chunk (pre-warmed to vocab capacity so a worker's first rejection
    /// does not allocate).
    scratch: Vec<Vec<f64>>,
    /// Per-resident reveal targets / progress / verify-pass counts.
    targets: Vec<usize>,
    j: Vec<usize>,
    verify_used: Vec<usize>,
    /// Per-resident accept/reject tallies of one verify pass, reduced
    /// into `SpecStats` in resident order (deterministic for any thread
    /// count).
    acc_cnt: Vec<usize>,
    rej_cnt: Vec<usize>,
    /// Per-resident MDM (reveal count, forced-final) pairs.
    reveals: Vec<(usize, bool)>,
    /// Retained `Option<M::State>` (type-erased), rebuilt in place by
    /// models that override `draft_into`.
    state: Option<Box<dyn Any>>,
}

impl StepArena {
    fn new(capacity: usize, d: usize, vocab: usize, threads: usize)
           -> StepArena {
        StepArena {
            active: Vec::with_capacity(capacity),
            residents: Vec::with_capacity(capacity),
            masked_tokens: Vec::with_capacity(capacity * d),
            full_tokens: Vec::with_capacity(capacity * d),
            sigma_flat: Vec::with_capacity(capacity * d),
            draft_logits: Vec::new(),
            target_logits: Vec::new(),
            draft_lse: Vec::with_capacity(capacity * d),
            verify_lse: Vec::with_capacity(capacity * d),
            lse_jobs: Vec::with_capacity(capacity * d),
            scratch: (0..threads.max(1))
                .map(|_| Vec::with_capacity(vocab))
                .collect(),
            targets: Vec::with_capacity(capacity),
            j: Vec::with_capacity(capacity),
            verify_used: Vec::with_capacity(capacity),
            acc_cnt: Vec::with_capacity(capacity),
            rej_cnt: Vec::with_capacity(capacity),
            reveals: Vec::with_capacity(capacity),
            state: None,
        }
    }
}

pub struct SpecScheduler {
    d: usize,
    vocab: usize,
    mask: i32,
    buckets: Vec<usize>,
    capacity: usize,
    slots: Vec<Option<Slot>>,
    pending: VecDeque<Slot>,
    next_id: u64,
    mode: Option<Mode>,
    stats: SpecStats,
    steps: u64,
    row_steps: u64,
    padded_row_steps: u64,
    backfills: u64,
    evictions: u64,
    resumes: u64,
    placements: Vec<SlotId>,
    phases: StepPhases,
    /// Time source for the [`StepPhases`] accounting. Wall time by
    /// default; tests and the virtual-time sim install a `SimClock` via
    /// [`SpecScheduler::set_clock`] so phase costs are scripted, not
    /// measured — no raw `Instant::now` on the step path (enforced by
    /// repolint's clock-discipline rule).
    clock: Box<dyn Clock>,
    /// Executor of the planar phases. The default is a single-thread
    /// pool (no workers — the exact sequential code path); the engine
    /// installs its shared multi-thread pool via
    /// [`SpecScheduler::set_pool`]. Token streams are bitwise identical
    /// for any thread count (per-resident RNG streams, deterministic
    /// chunking — see `engine::pool`).
    pool: Arc<StepPool>,
    arena: StepArena,
}

impl SpecScheduler {
    pub fn new(seq_len: usize, vocab: usize, mask: i32,
               buckets: Vec<usize>) -> SpecScheduler {
        let capacity = buckets.iter().copied().max().unwrap_or(1).max(1);
        SpecScheduler {
            d: seq_len,
            vocab,
            mask,
            buckets,
            capacity,
            slots: (0..capacity).map(|_| None).collect(),
            pending: VecDeque::new(),
            next_id: 0,
            mode: None,
            stats: SpecStats::default(),
            steps: 0,
            row_steps: 0,
            padded_row_steps: 0,
            backfills: 0,
            evictions: 0,
            resumes: 0,
            placements: Vec::new(),
            phases: StepPhases::default(),
            clock: Box::new(MonotonicClock::new()),
            pool: Arc::new(StepPool::new(1)),
            arena: StepArena::new(capacity, seq_len, vocab, 1),
        }
    }

    pub fn for_model<M: HybridModel>(model: &M) -> SpecScheduler {
        SpecScheduler::new(model.seq_len(), model.vocab(), model.mask_id(),
                           model.buckets())
    }

    /// Install a (shared) step pool: subsequent steps execute their
    /// planar phases across its workers. Per-chunk residual scratch rows
    /// are pre-warmed here so pooled warm steps stay allocation-free.
    pub fn set_pool(&mut self, pool: Arc<StepPool>) {
        while self.arena.scratch.len() < pool.threads() {
            self.arena.scratch.push(Vec::with_capacity(self.vocab));
        }
        self.pool = pool;
    }

    /// Executor thread count of the installed pool.
    pub fn step_threads(&self) -> usize {
        self.pool.threads()
    }

    /// Install the time source for phase accounting (virtual time in
    /// tests/sim; wall time is the default).
    pub fn set_clock(&mut self, clock: Box<dyn Clock>) {
        self.clock = clock;
    }

    /// Per-phase wall-clock cost accumulated since the last call.
    pub fn take_phases(&mut self) -> StepPhases {
        std::mem::take(&mut self.phases)
    }

    /// Namespace this scheduler's [`SlotId`] allocation: subsequent
    /// admissions draw ids from `base` upward. Multi-engine serving gives
    /// each replica a disjoint base (replica `k` uses `k << 40`) so a
    /// checkpoint migrated between replicas can never collide with an id
    /// the adopting scheduler issued locally. Must be called before any
    /// admission; single-engine paths keep the default base 0, so their
    /// id sequences (and every token-stream pin keyed on them) are
    /// unchanged.
    pub fn set_id_base(&mut self, base: u64) {
        assert_eq!(
            self.next_id, 0,
            "set_id_base must precede the first admission"
        );
        self.next_id = base;
    }

    /// Total remaining work across *resident* sequences, in ordering
    /// positions still to decide (speculative: `D - i`; MDM: masked
    /// positions left). This is what an eviction puts at risk of delay:
    /// the preemption victim policy prefers queues with the most
    /// residual (evicting a nearly-finished resident maximizes the
    /// completed work parked behind a checkpoint). Pending sequences are
    /// excluded — they hold no slot and are never evicted.
    pub fn residual(&self) -> usize {
        self.slots
            .iter()
            .flatten()
            .map(|s| match &s.kernel {
                Kernel::Spec(st, _) => self.d.saturating_sub(st.i),
                Kernel::Mdm(m, _) => m.masked.len(),
            })
            .sum()
    }

    /// Enqueue one sequence at the default priority (0). See
    /// [`SpecScheduler::admit_prio`].
    pub fn admit(&mut self, prompt: &Prompt, params: SeqParams, rng: Pcg)
                 -> SlotId {
        self.admit_prio(prompt, params, rng, 0)
    }

    /// Enqueue one sequence. It becomes resident at the next `step` with a
    /// free slot; until then it parks in the pending queue (which is how
    /// oversized requests get chunked across the bucket ladder). The
    /// pending queue is ordered by descending `priority` — a later
    /// high-priority admission overtakes queued lower-priority sequences
    /// (residents are never displaced by admission; that is eviction's
    /// job) — and FIFO within one priority class.
    pub fn admit_prio(&mut self, prompt: &Prompt, params: SeqParams,
                      rng: Pcg, priority: i32) -> SlotId {
        assert_eq!(prompt.0.len(), self.d,
                   "prompt length {} != D {}", prompt.0.len(), self.d);
        let mode = match &params {
            SeqParams::Spec(_) => Mode::Spec,
            SeqParams::Mdm(_) => Mode::Mdm,
        };
        self.merge_mode(mode);
        let id = SlotId(self.next_id);
        self.next_id += 1;
        let kernel = match params {
            SeqParams::Spec(p) => {
                let s = init_seq(prompt, self.d, self.mask, rng,
                                 p.sigma.as_deref());
                Kernel::Spec(s, p)
            }
            SeqParams::Mdm(p) => {
                Kernel::Mdm(init_mdm(prompt, self.d, self.mask, rng), p)
            }
        };
        self.enqueue_pending(Slot { id, priority, resumed: false, kernel });
        id
    }

    fn merge_mode(&mut self, mode: Mode) {
        match self.mode {
            None => self.mode = Some(mode),
            Some(m) => assert_eq!(
                m, mode,
                "one scheduler batches one sampler kind; \
                 key run queues by sampler settings"
            ),
        }
    }

    /// Insert into the pending queue keeping it sorted by descending
    /// priority. Fresh admissions join the *back* of their priority class
    /// (FIFO within a class); resumed checkpoints join the *front* of
    /// theirs — they already waited out one queue pass and carry partial
    /// progress, so equal-priority fresh work must not overtake them.
    fn enqueue_pending(&mut self, slot: Slot) {
        let p = slot.priority;
        let pos = if slot.resumed {
            self.pending.iter().position(|s| s.priority <= p)
        } else {
            self.pending.iter().position(|s| s.priority < p)
        };
        let idx = match pos {
            Some(i) => i,
            None => self.pending.len(),
        };
        self.pending.insert(idx, slot);
    }

    /// Evict a *resident* sequence mid-run, between steps: the slot is
    /// freed (backfillable on the next step) and the sequence's complete
    /// state comes back as a [`SeqCheckpoint`]. Returns `None` if `id`
    /// is not currently resident (pending sequences are not evictable —
    /// they hold no slot). Token-stream determinism is unaffected: the
    /// checkpoint carries the sequence's own RNG stream and residents
    /// are mutually independent.
    pub fn evict(&mut self, id: SlotId) -> Option<SeqCheckpoint> {
        for slot in self.slots.iter_mut() {
            if slot.as_ref().map(|s| s.id) == Some(id) {
                let s = slot.take().unwrap();
                self.evictions += 1;
                return Some(SeqCheckpoint { slot: s });
            }
        }
        None
    }

    /// Evict the lowest-priority resident (ties broken toward the
    /// latest-admitted — highest [`SlotId`] — which on average has the
    /// least progress to redo). `None` when no sequence is resident.
    pub fn evict_lowest(&mut self) -> Option<SeqCheckpoint> {
        let mut victim: Option<(i32, SlotId)> = None;
        for s in self.slots.iter().flatten() {
            let better = match victim {
                None => true,
                Some((p, id)) => {
                    s.priority < p || (s.priority == p && s.id > id)
                }
            };
            if better {
                victim = Some((s.priority, s.id));
            }
        }
        victim.and_then(|(_, id)| self.evict(id))
    }

    /// Re-admit an evicted sequence. It rejoins the pending queue at the
    /// *front* of its priority class (ahead of equal-priority fresh
    /// admissions) keeping its original [`SlotId`], and continues from
    /// its checkpointed state with a token stream bitwise identical to
    /// an unpreempted run. Its re-placement is counted in
    /// [`SpecScheduler::resumes`], not in `take_placements` — callers
    /// must not observe a second queue wait for it.
    pub fn resume(&mut self, ck: SeqCheckpoint) {
        let mut slot = ck.slot;
        let mode = match &slot.kernel {
            Kernel::Spec(..) => Mode::Spec,
            Kernel::Mdm(..) => Mode::Mdm,
        };
        self.merge_mode(mode);
        // Checkpoints normally return to the scheduler that issued them;
        // keep id allocation collision-free even if one does not.
        self.next_id = self.next_id.max(slot.id.0 + 1);
        slot.resumed = true;
        self.enqueue_pending(slot);
    }

    /// Adopt a checkpoint minted by *another* scheduler (cross-replica
    /// migration): re-mint the slot id from this scheduler's own counter
    /// so id namespaces never interleave, then resume as usual. The id
    /// is only a routing label — kernel state (σ ordering, tallies, the
    /// per-sequence RNG stream) is untouched, so the bitwise-identical
    /// continuation guarantee of [`SpecScheduler::resume`] carries over.
    /// Returns the new local id for the caller's routing tables.
    pub fn adopt(&mut self, mut ck: SeqCheckpoint) -> SlotId {
        let id = SlotId(self.next_id);
        self.next_id += 1;
        ck.slot.id = id;
        self.resume(ck);
        id
    }

    /// Remove a *pending* (not-yet-resident) sequence, dropping its
    /// state. Returns `false` if `id` is not pending. Deadline expiry
    /// uses this for sequences that never reached a slot; residents go
    /// through [`SpecScheduler::evict`] instead.
    pub fn remove_pending(&mut self, id: SlotId) -> bool {
        match self.pending.iter().position(|s| s.id == id) {
            Some(i) => {
                self.pending.remove(i);
                true
            }
            None => false,
        }
    }

    /// Drain the pending queue, returning the removed ids in queue
    /// order (quarantine: the coordinator answers each one explicitly).
    pub fn take_pending_ids(&mut self) -> Vec<SlotId> {
        self.pending.drain(..).map(|s| s.id).collect()
    }

    /// Drain the pending queue as checkpoints, in queue order. A pending
    /// slot *is* its complete state (it never touched a slot table), so
    /// wrapping it as a [`SeqCheckpoint`] is exact: an adopter resumes it
    /// from zero progress with a bitwise-identical token stream. Replica
    /// evacuation uses this to re-board not-yet-placed work instead of
    /// dropping it.
    pub fn take_pending(&mut self) -> Vec<SeqCheckpoint> {
        self.pending.drain(..).map(|slot| SeqCheckpoint { slot }).collect()
    }

    /// The lowest-priority pending sequence — the back of the queue
    /// (pending is sorted by descending priority; within the lowest
    /// class the back is the youngest fresh admission, the cheapest to
    /// turn away). Priority-aware shedding inspects this to decide
    /// whether an incoming higher-class request should displace pending
    /// work instead of being shed itself.
    pub fn lowest_pending(&self) -> Option<(SlotId, i32)> {
        self.pending.back().map(|s| (s.id, s.priority))
    }

    /// Whether `id` currently sits in the pending queue (not resident,
    /// not retired).
    pub fn is_pending(&self, id: SlotId) -> bool {
        self.pending.iter().any(|s| s.id == id)
    }

    pub fn n_active(&self) -> usize {
        self.slots.iter().filter(|s| s.is_some()).count()
    }

    pub fn n_pending(&self) -> usize {
        self.pending.len()
    }

    pub fn is_idle(&self) -> bool {
        self.pending.is_empty() && self.slots.iter().all(|s| s.is_none())
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Aggregate speculative statistics since construction / `take_stats`.
    pub fn stats(&self) -> &SpecStats {
        &self.stats
    }

    pub fn take_stats(&mut self) -> SpecStats {
        std::mem::take(&mut self.stats)
    }

    /// Outer loops executed (= draft forward passes).
    pub fn steps(&self) -> u64 {
        self.steps
    }

    /// Σ bucket size over steps: total batch rows paid for, padding
    /// included — the cost currency continuous batching optimizes.
    pub fn row_steps(&self) -> u64 {
        self.row_steps
    }

    /// Σ (bucket - resident) over steps: rows paid for but carrying no
    /// sequence.
    pub fn padded_row_steps(&self) -> u64 {
        self.padded_row_steps
    }

    /// Fresh pending sequences placed into a slot freed by a retirement
    /// (placements after the first step; initial placements and resumed
    /// re-placements don't count).
    pub fn backfills(&self) -> u64 {
        self.backfills
    }

    /// Sequences evicted mid-run via `evict`/`evict_lowest`.
    pub fn evictions(&self) -> u64 {
        self.evictions
    }

    /// Resumed sequences placed back into a slot (each checkpoint counts
    /// once, at its re-placement step).
    pub fn resumes(&self) -> u64 {
        self.resumes
    }

    /// Sequences that entered a slot (began executing) for the *first
    /// time* since the last call — lets the coordinator time enqueue ->
    /// execution start. Resumed re-placements are deliberately excluded
    /// (their wait was observed at the original placement; see
    /// [`SpecScheduler::resumes`]).
    pub fn take_placements(&mut self) -> Vec<SlotId> {
        std::mem::take(&mut self.placements)
    }

    /// Move pending sequences into free slots; returns placements made.
    fn backfill(&mut self) -> usize {
        let mut placed = 0;
        for slot in self.slots.iter_mut() {
            if self.pending.is_empty() {
                break;
            }
            if slot.is_none() {
                *slot = self.pending.pop_front();
                let s = slot.as_ref().unwrap();
                if s.resumed {
                    self.resumes += 1;
                } else {
                    self.placements.push(s.id);
                    if self.steps > 0 {
                        self.backfills += 1;
                    }
                }
                placed += 1;
            }
        }
        placed
    }

    /// Retire every resident sequence that is already finished (fully
    /// revealed prompts retire here without ever touching the model).
    fn retire_finished(&mut self, finished: &mut Vec<(SlotId, Sample)>)
                       -> usize {
        let mut retired = 0;
        for slot in self.slots.iter_mut() {
            let done = match slot {
                Some(Slot { kernel: Kernel::Spec(s, _), .. }) => s.done,
                Some(Slot { kernel: Kernel::Mdm(m, _), .. }) => {
                    m.masked.is_empty()
                }
                None => false,
            };
            if done {
                let s = slot.take().unwrap();
                finished.push((s.id, emit_sample(s.kernel)));
                retired += 1;
            }
        }
        retired
    }

    /// Run one outer loop over the resident sequences: backfill freed
    /// slots, execute one draft pass (plus verify sweeps for the
    /// speculative sampler) in the smallest covering bucket, advance every
    /// resident state machine, and retire whatever finished.
    pub fn step<M: HybridModel>(&mut self, model: &M)
                                -> Vec<(SlotId, Sample)> {
        debug_assert_eq!(model.seq_len(), self.d);
        debug_assert_eq!(model.mask_id(), self.mask);
        let mut finished = Vec::new();
        loop {
            let placed = self.backfill();
            let retired = self.retire_finished(&mut finished);
            if placed == 0 && retired == 0 {
                break;
            }
        }

        self.arena.active.clear();
        for (i, slot) in self.slots.iter().enumerate() {
            if slot.is_some() {
                self.arena.active.push(i);
            }
        }
        if self.arena.active.is_empty() {
            return finished;
        }
        let bucket = pick_bucket(&self.buckets, self.arena.active.len());
        debug_assert!(bucket >= self.arena.active.len(),
                      "slot table exceeds bucket ladder");
        self.steps += 1;
        self.row_steps += bucket as u64;
        self.padded_row_steps += (bucket - self.arena.active.len()) as u64;

        match self.mode.expect("active slots imply a mode") {
            Mode::Spec => self.step_spec(model, bucket, &mut finished),
            Mode::Mdm => self.step_mdm(model, bucket, &mut finished),
        }
        finished
    }

    /// Reclaim (or lazily create) the type-erased retained model state.
    fn take_state<M: HybridModel>(state: &mut Option<Box<dyn Any>>)
                                  -> Box<Option<M::State>> {
        match state.take() {
            Some(any) => any.downcast().unwrap_or_else(|_| Box::new(None)),
            None => Box::new(None),
        }
    }

    /// One speculative outer loop (Alg. 3) over the residents, batch
    /// `bucket`, restructured into **planar phases over the whole
    /// step's arena** (each phase chunked across the step pool):
    ///
    /// 1. **draw** — all Gumbel draws for all residents (window-lazy, as
    ///    before), caching each drafted row's LSE;
    /// 2. **LSE** — per verify pass, one batched sweep computing every
    ///    verify-row log-sum-exp the pass can consume into a flat table
    ///    (`verify_lse`), each row exactly once (multi-thread pools;
    ///    a single-thread pool computes the same scalars lazily inside
    ///    the accept sweep — the exact sequential path, no eager work
    ///    past a rejection);
    /// 3. **accept/residual** — the per-resident accept sweeps, reading
    ///    only cached LSE scalars (plus logit rows for the occasional
    ///    residual resample).
    ///
    /// Residents are mutually independent (per-sequence RNG streams,
    /// disjoint arena rows), so the phases parallelize without locks and
    /// token streams are bitwise identical for any thread count.
    /// Allocation-free once the arena is warm.
    fn step_spec<M: HybridModel>(&mut self, model: &M, bucket: usize,
                                 finished: &mut Vec<(SlotId, Sample)>) {
        let d = self.d;
        let v = self.vocab;
        let mask = self.mask;
        let pool = &self.pool;
        let clock: &dyn Clock = self.clock.as_ref();
        let slots = &mut self.slots;
        let stats = &mut self.stats;
        let phases = &mut self.phases;
        let StepArena {
            active, residents, masked_tokens, full_tokens, sigma_flat,
            draft_logits, target_logits, draft_lse, verify_lse, lse_jobs,
            scratch, targets, j, verify_used, acc_cnt, rej_cnt, state, ..
        } = &mut self.arena;
        let n_act = active.len();

        // lint: hot-region — warm speculative step; allocation-free by
        // contract (pinned dynamically by tests/alloc_regression.rs).
        // ---- draft pass: resident rows first, then pure-mask padding ----
        masked_tokens.clear();
        masked_tokens.resize(bucket * d, mask);
        for (r, &si) in active.iter().enumerate() {
            let (s, _) = spec_ref(&slots[si]);
            for pos in 0..d {
                if s.revealed[pos] {
                    masked_tokens[r * d + pos] = s.tokens[pos];
                }
            }
        }
        // Padding-liveness invariant: rows beyond the resident count carry
        // only mask tokens into the draft pass and are never sampled from.
        debug_assert!(
            masked_tokens[n_act * d..].iter().all(|&t| t == mask),
            "padding rows must contribute only mask tokens"
        );
        let mut state_box = Self::take_state::<M>(state);
        let t0 = clock.now();
        model.draft_into(&masked_tokens[..], bucket, &mut state_box,
                         draft_logits);
        phases.model_s += clock.now() - t0;
        stats.outer_loops += 1;

        // Per-resident slot pointers for the planar phases: each pool
        // chunk mutates a disjoint set of residents. `slots` itself is
        // not touched again until the bookkeeping block below.
        residents.clear();
        // Every pointer is derived from one raw base: indexing `slots`
        // per iteration would create a fresh unique reborrow of the
        // whole buffer each time, invalidating the previously collected
        // pointers under the Stacked Borrows aliasing rules.
        let base = slots.as_mut_ptr();
        for &si in active.iter() {
            // SAFETY: `si < slots.len()` (collected from this very vec a
            // moment ago) and every active slot is occupied.
            let slot = unsafe {
                (*base.add(si)).as_mut().expect("active slot")
            };
            residents.push(ResidentPtr(slot as *mut Slot));
        }

        // ---- phase 1: draws (window-lazy, all residents) ----------------
        // Only the ordering positions the accept window can consume are
        // drawn; each draw caches its row's log-sum-exp for the accept
        // tests below. Beyond-window positions stay mask in the verify
        // input (their tracks are never read this loop — see module docs).
        targets.clear();
        targets.resize(n_act, 0);
        j.clear();
        j.resize(n_act, 0);
        verify_used.clear();
        verify_used.resize(n_act, 0);
        full_tokens.clear();
        full_tokens.resize(bucket * d, mask);
        sigma_flat.clear();
        sigma_flat.resize(bucket * d, 0);
        for row in sigma_flat[n_act * d..].chunks_exact_mut(d) {
            for (pos, out) in row.iter_mut().enumerate() {
                *out = pos as i32; // identity σ for padding rows
            }
        }
        draft_lse.clear();
        draft_lse.resize(bucket * d, f64::NAN);
        let t0 = clock.now();
        {
            let res: &[ResidentPtr] = &residents[..];
            let dl: &[f32] = &draft_logits[..];
            let lse_w = SharedSlice::new(draft_lse);
            let full_w = SharedSlice::new(full_tokens);
            let sig_w = SharedSlice::new(sigma_flat);
            let tgt_w = SharedSlice::new(targets);
            let j_w = SharedSlice::new(j);
            let vu_w = SharedSlice::new(verify_used);
            pool.run(n_act, |_chunk, range| {
                for r in range {
                    // SAFETY: resident r and row r of every shared
                    // buffer are handed to exactly one chunk.
                    let slot = unsafe { &mut *res[r].0 };
                    let (s, p) = spec_parts(slot);
                    let w = p.window.limit(s.i, d);
                    let target = (s.i + w).min(d);
                    let inv_t = (1.0 / p.temperature) as f32;
                    // SAFETY: element r of each per-resident buffer is
                    // owned by this chunk (one resident, one chunk).
                    unsafe {
                        *tgt_w.get_mut(r) = target;
                        *j_w.get_mut(r) = s.i;
                        *vu_w.get_mut(r) = 0;
                    }
                    // SAFETY: row r of the LSE buffer is owned by this
                    // chunk.
                    let lse_row = unsafe { lse_w.range_mut(r * d, d) };
                    for od in s.i..target {
                        let pos = s.sigma[od] as usize;
                        let row = &dl[(r * d + pos) * v
                                      ..(r * d + pos) * v + v];
                        let (tok, lse) = kernels::gumbel_draw_lse(
                            row, inv_t, s.rng.next_u64());
                        s.tokens[pos] = tok as i32;
                        lse_row[pos] = lse;
                    }
                    // SAFETY: row r of the token buffer is owned by this
                    // chunk.
                    let full_row = unsafe { full_w.range_mut(r * d, d) };
                    for od in 0..target {
                        let pos = s.sigma[od] as usize;
                        full_row[pos] = s.tokens[pos];
                    }
                    // SAFETY: row r of the σ buffer is owned by this
                    // chunk.
                    unsafe { sig_w.range_mut(r * d, d) }
                        .copy_from_slice(&s.sigma);
                }
            });
        }
        phases.draw_s += clock.now() - t0;

        let max_nv = (0..n_act)
            .map(|r| {
                // SAFETY: sequential read between phases; no chunk holds
                // the pointer anymore.
                let slot = unsafe { &*residents[r].0 };
                spec_params_of(slot).n_verify.max(1)
            })
            .max()
            .unwrap_or(1);

        // ---- inner speculative loops ------------------------------------
        for k in 0..max_nv {
            let any_active = (0..n_act).any(|r| {
                // SAFETY: sequential read between phases.
                let slot = unsafe { &*residents[r].0 };
                k < spec_params_of(slot).n_verify.max(1)
                    && j[r] < targets[r]
            });
            if !any_active {
                break;
            }
            let st =
                (*state_box).as_ref().expect("draft_into sets the state");
            let t0 = clock.now();
            model.verify_into(st, &full_tokens[..], &sigma_flat[..], bucket,
                              target_logits);
            phases.model_s += clock.now() - t0;
            stats.verify_passes += 1;

            // ---- phase 2: batched verify-row LSEs -----------------------
            // One flat work list over every (resident, track) row this
            // pass may accept-test — each row's LSE computed exactly
            // once, chunked across the pool, so the accept phase
            // consumes only cached scalars. Eager LSEs are a win only
            // when there are workers to absorb them (rows past a
            // rejection are computed but never read), so with a
            // single-thread pool this phase is skipped and the accept
            // sweep computes each LSE lazily at its accept test — the
            // exact pre-planar sequential path, zero wasted O(V) work.
            // `row_lse` is deterministic, so both paths consume
            // bit-identical scalars and the token stream does not depend
            // on the thread count. (First-position rule: track dd-1
            // exists only for dd >= 1, hence the max(j, 1).)
            let planar_lse = pool.threads() > 1;
            let t0 = clock.now();
            if planar_lse {
                lse_jobs.clear();
                for r in 0..n_act {
                    // SAFETY: sequential read between phases.
                    let slot = unsafe { &*residents[r].0 };
                    let p = spec_params_of(slot);
                    if k >= p.n_verify.max(1) || j[r] >= targets[r] {
                        continue;
                    }
                    let inv_t32 = (1.0 / p.temperature) as f32;
                    for dd in j[r].max(1)..targets[r] {
                        lse_jobs.push(((r * d + (dd - 1)) as u32,
                                       inv_t32));
                    }
                }
                verify_lse.clear();
                verify_lse.resize(bucket * d, f64::NAN);
                let jobs: &[(u32, f32)] = &lse_jobs[..];
                let tl: &[f32] = &target_logits[..];
                let out_w = SharedSlice::new(verify_lse);
                pool.run(jobs.len(), |_chunk, range| {
                    for i in range {
                        let (flat, inv_t32) = jobs[i];
                        let fl = flat as usize;
                        let row = &tl[fl * v..fl * v + v];
                        // SAFETY: each flat row id appears at most once
                        // in the job list.
                        unsafe {
                            *out_w.get_mut(fl) =
                                kernels::row_lse(row, inv_t32);
                        }
                    }
                });
            }
            phases.lse_s += clock.now() - t0;

            // ---- phase 3: accept/residual sweeps ------------------------
            let t0 = clock.now();
            acc_cnt.clear();
            acc_cnt.resize(n_act, 0);
            rej_cnt.clear();
            rej_cnt.resize(n_act, 0);
            {
                let res: &[ResidentPtr] = &residents[..];
                let dl: &[f32] = &draft_logits[..];
                let tl: &[f32] = &target_logits[..];
                let dlse: &[f64] = &draft_lse[..];
                let vlse: &[f64] = &verify_lse[..];
                let tg: &[usize] = &targets[..];
                let full_w = SharedSlice::new(full_tokens);
                let j_w = SharedSlice::new(j);
                let vu_w = SharedSlice::new(verify_used);
                let acc_w = SharedSlice::new(acc_cnt);
                let rej_w = SharedSlice::new(rej_cnt);
                let scr_w = SharedSlice::new(scratch.as_mut_slice());
                pool.run(n_act, |chunk, range| {
                    for r in range {
                        // SAFETY: resident r, row r of every shared
                        // buffer, and scratch[chunk] are owned by
                        // exactly this chunk.
                        let slot = unsafe { &mut *res[r].0 };
                        let (s, p) = spec_parts(slot);
                        // SAFETY: element r is owned by this chunk.
                        let jj = unsafe { *j_w.get_mut(r) };
                        if k >= p.n_verify.max(1) || jj >= tg[r] {
                            continue;
                        }
                        // SAFETY: element r is owned by this chunk.
                        unsafe { *vu_w.get_mut(r) += 1 };
                        let inv_t = 1.0 / p.temperature;
                        let full_row =
                            // SAFETY: row r is owned by this chunk.
                            unsafe { full_w.range_mut(r * d, d) };
                        // SAFETY: scratch row `chunk` belongs to this
                        // chunk by construction.
                        let scratch_row = unsafe { scr_w.get_mut(chunk) };
                        let mut dd = jj;
                        let mut accepted = 0usize;
                        let mut rejected = 0usize;
                        while dd < tg[r] {
                            if dd == 0 {
                                // First-position rule: ordering position
                                // 0's target IS the draft row, so the
                                // acceptance probability is exactly 1 —
                                // no q row, no RNG.
                                s.accepted += 1;
                                accepted += 1;
                                dd += 1;
                                continue;
                            }
                            let pos = s.sigma[dd] as usize;
                            let tok = s.tokens[pos] as usize;
                            let pr = (r * d + pos) * v;
                            let p_row = &dl[pr..pr + v];
                            let lse_p = dlse[r * d + pos];
                            debug_assert!(
                                lse_p.is_finite(),
                                "accept test on an undrafted row"
                            );
                            // Target: track dd-1 of this verify pass —
                            // LSE cached by phase 2, or computed lazily
                            // on the single-thread path (identical
                            // scalar either way).
                            let tr_flat = r * d + (dd - 1);
                            let q_row =
                                &tl[tr_flat * v..tr_flat * v + v];
                            let lse_q = if planar_lse {
                                let cached = vlse[tr_flat];
                                debug_assert!(
                                    cached.is_finite(),
                                    "accept test on a row the LSE \
                                     phase did not cover"
                                );
                                cached
                            } else {
                                kernels::row_lse(q_row, inv_t as f32)
                            };
                            let accept_p = kernels::accept_prob(
                                q_row[tok], lse_q, p_row[tok], lse_p,
                                inv_t);
                            if s.rng.f64() < accept_p {
                                s.accepted += 1;
                                accepted += 1;
                                dd += 1;
                            } else {
                                s.rejected += 1;
                                rejected += 1;
                                let new_tok =
                                    kernels::residual_draw_into(
                                        scratch_row, q_row, lse_q, p_row,
                                        lse_p, inv_t, &mut s.rng)
                                        as i32;
                                s.tokens[pos] = new_tok;
                                full_row[pos] = new_tok;
                                dd += 1;
                                break; // resample ends this inner sweep
                            }
                        }
                        // SAFETY: element r of each per-resident buffer
                        // is owned by this chunk.
                        unsafe {
                            *j_w.get_mut(r) = dd;
                            *acc_w.get_mut(r) = accepted;
                            *rej_w.get_mut(r) = rejected;
                        }
                    }
                });
            }
            // Deterministic stats reduction in resident order (identical
            // totals for any thread count).
            for (&a, &rj) in acc_cnt.iter().zip(rej_cnt.iter()) {
                stats.accepted += a;
                stats.rejected += rj;
            }
            phases.accept_s += clock.now() - t0;
        }
        // Raw pointers die here; `slots` is re-borrowed below.
        residents.clear();
        // lint: end-hot-region — retirement below may allocate (samples
        // are materialized for the finished list).

        // ---- bookkeeping + immediate retirement -------------------------
        for (r, &si) in active.iter().enumerate() {
            let (s, p) = spec_mut(&mut slots[si]);
            s.outer += 1;
            s.nfe += model.nfe_cost(verify_used[r]);
            for od in s.i..j[r] {
                s.revealed[s.sigma[od] as usize] = true;
            }
            s.i = j[r];
            if s.i >= d {
                s.done = true;
            }
            // Safety valve: a well-formed run needs at most D outer loops.
            // A valve retirement emits the mask id at every undecided
            // position (never-drafted positions already hold it; drawn-
            // but-unverified window positions are masked out here), so an
            // incomplete sample is unambiguously marked as cut off.
            let retire = s.done || s.outer >= p.max_outer;
            if retire {
                if !s.done {
                    for od in j[r]..targets[r] {
                        s.tokens[s.sigma[od] as usize] = mask;
                    }
                }
                let slot = slots[si].take().unwrap();
                finished.push((slot.id, emit_sample(slot.kernel)));
            }
        }
        *state = Some(state_box);
    }

    /// One MDM reveal step over the residents, batch `bucket`. Each row is
    /// fast-forwarded through reveal-free grid steps (0 NFE, per the
    /// paper's best-case accounting) so every draft pass reveals work for
    /// every resident row. The reveal/draw loop is planar: residents are
    /// independent (own RNG streams, disjoint rows), so it runs chunked
    /// across the step pool with bitwise-identical results for any
    /// thread count. Allocation-free once the arena is warm.
    fn step_mdm<M: HybridModel>(&mut self, model: &M, bucket: usize,
                                finished: &mut Vec<(SlotId, Sample)>) {
        let d = self.d;
        let v = self.vocab;
        let mask = self.mask;
        let pool = &self.pool;
        let clock: &dyn Clock = self.clock.as_ref();
        let slots = &mut self.slots;
        let phases = &mut self.phases;
        let StepArena {
            active, residents, masked_tokens, draft_logits, reveals, state,
            ..
        } = &mut self.arena;
        let n_act = active.len();

        // lint: hot-region — warm MDM step; allocation-free by contract
        // (pinned dynamically by tests/alloc_regression.rs).
        // Reveal counts for this step (advances each row's grid cursor).
        reveals.clear();
        for &si in active.iter() {
            let (m, p) = mdm_mut(&mut slots[si]);
            reveals.push(next_reveal(m, p));
        }

        masked_tokens.clear();
        masked_tokens.resize(bucket * d, mask);
        for (r, &si) in active.iter().enumerate() {
            let (m, _) = mdm_mut(&mut slots[si]);
            masked_tokens[r * d..(r + 1) * d].copy_from_slice(&m.tokens);
        }
        debug_assert!(
            masked_tokens[n_act * d..].iter().all(|&t| t == mask),
            "padding rows must contribute only mask tokens"
        );
        let mut state_box = Self::take_state::<M>(state);
        let t0 = clock.now();
        model.draft_into(&masked_tokens[..], bucket, &mut state_box,
                         draft_logits);
        phases.model_s += clock.now() - t0;

        // Per-resident slot pointers for the planar reveal phase.
        residents.clear();
        // Every pointer is derived from one raw base: indexing `slots`
        // per iteration would create a fresh unique reborrow of the
        // whole buffer each time, invalidating the previously collected
        // pointers under the Stacked Borrows aliasing rules.
        let base = slots.as_mut_ptr();
        for &si in active.iter() {
            // SAFETY: `si < slots.len()` (collected from this very vec a
            // moment ago) and every active slot is occupied.
            let slot = unsafe {
                (*base.add(si)).as_mut().expect("active slot")
            };
            residents.push(ResidentPtr(slot as *mut Slot));
        }

        // ---- planar reveal/draw phase -----------------------------------
        let t0 = clock.now();
        {
            let res: &[ResidentPtr] = &residents[..];
            let dl: &[f32] = &draft_logits[..];
            let rv: &[(usize, bool)] = &reveals[..];
            pool.run(n_act, |_chunk, range| {
                for r in range {
                    // SAFETY: resident r is handed to exactly one chunk.
                    let slot = unsafe { &mut *res[r].0 };
                    let (m, p) = mdm_parts(slot);
                    let (c, forced) = rv[r];
                    let c = c.min(m.masked.len());
                    debug_assert!(c > 0,
                                  "resident MDM row must reveal every step");
                    m.nfe += 1.0;
                    m.steps_used += 1;
                    // Zheng fix: choose WHICH positions to reveal
                    // uniformly, independent of the sampled values.
                    m.rng.shuffle(&mut m.masked);
                    // The grid uses the sampling temperature; the final
                    // forced pass (rounding leftovers) reveals at
                    // temperature 1.
                    let inv_t = if forced {
                        1.0
                    } else {
                        (1.0 / p.temperature) as f32
                    };
                    for _ in 0..c {
                        let pos = m.masked.pop().unwrap();
                        let row = &dl[(r * d + pos) * v
                                      ..(r * d + pos) * v + v];
                        let (tok, _) = kernels::gumbel_draw_lse(
                            row, inv_t, m.rng.next_u64());
                        m.tokens[pos] = tok as i32;
                    }
                }
            });
        }
        phases.draw_s += clock.now() - t0;

        // Raw pointers die here; retirement re-borrows `slots`.
        residents.clear();
        // lint: end-hot-region — retirement below may allocate (samples
        // are materialized for the finished list).
        for &si in active.iter() {
            let done = {
                let (m, _) = mdm_mut(&mut slots[si]);
                m.masked.is_empty()
            };
            if done {
                let slot = slots[si].take().unwrap();
                finished.push((slot.id, emit_sample(slot.kernel)));
            }
        }
        *state = Some(state_box);
    }
}

fn spec_ref(slot: &Option<Slot>) -> (&SeqState, &SpecParams) {
    match slot {
        Some(Slot { kernel: Kernel::Spec(s, p), .. }) => (s, p),
        _ => unreachable!("non-speculative slot in speculative step"),
    }
}

fn spec_mut(slot: &mut Option<Slot>) -> (&mut SeqState, &SpecParams) {
    match slot {
        Some(Slot { kernel: Kernel::Spec(s, p), .. }) => (s, p),
        _ => unreachable!("non-speculative slot in speculative step"),
    }
}

fn mdm_mut(slot: &mut Option<Slot>) -> (&mut MdmState, &MdmParams) {
    match slot {
        Some(Slot { kernel: Kernel::Mdm(m, p), .. }) => (m, p),
        _ => unreachable!("non-MDM slot in MDM step"),
    }
}

/// Direct-slot flavors of the accessors above, used by the planar phases
/// (which reach residents through [`ResidentPtr`], not `&mut Option`).
fn spec_parts(slot: &mut Slot) -> (&mut SeqState, &SpecParams) {
    match &mut slot.kernel {
        Kernel::Spec(s, p) => (s, p),
        _ => unreachable!("non-speculative slot in speculative step"),
    }
}

fn spec_params_of(slot: &Slot) -> &SpecParams {
    match &slot.kernel {
        Kernel::Spec(_, p) => p,
        _ => unreachable!("non-speculative slot in speculative step"),
    }
}

fn mdm_parts(slot: &mut Slot) -> (&mut MdmState, &MdmParams) {
    match &mut slot.kernel {
        Kernel::Mdm(m, p) => (m, p),
        _ => unreachable!("non-MDM slot in MDM step"),
    }
}

fn emit_sample(kernel: Kernel) -> Sample {
    match kernel {
        Kernel::Spec(s, _) => Sample {
            tokens: s.tokens,
            nfe: s.nfe,
            outer_loops: s.outer,
            accepted: s.accepted,
            rejected: s.rejected,
        },
        Kernel::Mdm(m, _) => Sample {
            tokens: m.tokens,
            nfe: m.nfe,
            outer_loops: m.steps_used,
            accepted: 0,
            rejected: 0,
        },
    }
}

pub(crate) fn init_seq(prompt: &Prompt, d: usize, mask: i32, mut rng: Pcg,
                       fixed_sigma: Option<&[i32]>) -> SeqState {
    let mut revealed: Vec<i32> = Vec::new();
    let mut hidden: Vec<i32> = Vec::new();
    let mut tokens = vec![mask; d];
    for (pos, slot) in prompt.0.iter().enumerate() {
        match slot {
            Some(tok) => {
                tokens[pos] = *tok;
                revealed.push(pos as i32);
            }
            None => hidden.push(pos as i32),
        }
    }
    rng.shuffle(&mut revealed);
    rng.shuffle(&mut hidden);
    let i = revealed.len();
    let mut sigma = revealed;
    sigma.extend(hidden);
    if let Some(fixed) = fixed_sigma {
        debug_assert_eq!(fixed.len(), d);
        debug_assert!(fixed[..i]
            .iter()
            .all(|p| prompt.0[*p as usize].is_some()));
        sigma = fixed.to_vec();
    }
    let revealed_mask: Vec<bool> =
        prompt.0.iter().map(|s| s.is_some()).collect();
    SeqState {
        tokens,
        sigma,
        revealed: revealed_mask,
        i,
        done: i >= d,
        nfe: 0.0,
        outer: 0,
        accepted: 0,
        rejected: 0,
        rng,
    }
}

fn init_mdm(prompt: &Prompt, d: usize, mask: i32, rng: Pcg) -> MdmState {
    let mut tokens = vec![mask; d];
    let mut masked = Vec::new();
    for (pos, slot) in prompt.0.iter().enumerate() {
        match slot {
            Some(t) => tokens[pos] = *t,
            None => masked.push(pos),
        }
    }
    let m0 = masked.len();
    MdmState { tokens, masked, m0, grid_step: 0, nfe: 0.0, steps_used: 0,
               rng }
}

/// Advance a row's grid cursor to its next *revealing* step and return
/// (reveal count, is-forced-final). Reveal-free grid steps cost nothing
/// (the paper's best-case NFE accounting) so they are skipped outright.
fn next_reveal(m: &mut MdmState, p: &MdmParams) -> (usize, bool) {
    let k = p.steps.max(1);
    loop {
        if m.grid_step >= k {
            // Rounding leftovers after the grid: one forced reveal pass.
            return (m.masked.len(), true);
        }
        let tau_next = 1.0 - (m.grid_step + 1) as f64 / k as f64;
        let m_next = (m.m0 as f64 * mdm_alpha(tau_next)).round() as usize;
        m.grid_step += 1;
        let c = m.masked.len().saturating_sub(m_next);
        if c > 0 {
            return (c, false);
        }
    }
}

/// Drive-to-completion helper shared by `speculative_sample` and
/// `mdm_sample`: admit every prompt, step until the scheduler drains, and
/// reassemble samples in admission order.
pub fn run_to_completion<M: HybridModel>(
    model: &M,
    prompts: &[Prompt],
    params: &SeqParams,
    rng: &mut Pcg,
) -> (Vec<Sample>, SpecStats) {
    let mut sched = SpecScheduler::for_model(model);
    let ids: Vec<SlotId> = prompts
        .iter()
        .map(|p| sched.admit(p, params.clone(), rng.split()))
        .collect();
    let mut done: std::collections::BTreeMap<SlotId, Sample> =
        std::collections::BTreeMap::new();
    while !sched.is_idle() {
        for (id, sample) in sched.step(model) {
            done.insert(id, sample);
        }
    }
    let samples = ids
        .into_iter()
        .map(|id| done.remove(&id).expect("scheduler retired every admit"))
        .collect();
    (samples, sched.take_stats())
}

/// Smallest bucket >= n, or the largest available if n exceeds them all.
///
/// The **single** bucket-selection policy in the codebase (re-exported as
/// `coordinator::batcher::pick_bucket` for the L3 layer). The scheduler
/// caps residency at the largest rung, so the truncating fallback is never
/// reached from the engine — a model is never handed a batch size it
/// didn't compile.
pub fn pick_bucket(buckets: &[usize], n: usize) -> usize {
    buckets
        .iter()
        .copied()
        .filter(|&b| b >= n)
        .min()
        .or_else(|| buckets.iter().copied().max())
        .unwrap_or(n.max(1))
}

// ---------------------------------------------------------------------------
// Object-safe stepping facade for the coordinator
// ---------------------------------------------------------------------------

/// Why a step failed. The coordinator's supervision policy keys off the
/// variant: `Transient` is retriable, `Fatal` quarantines the queue.
#[derive(Clone, Debug, PartialEq)]
pub enum StepError {
    /// The model call failed but unwound at a phase boundary where every
    /// resident kernel still satisfies its between-step invariant (see
    /// the safety argument on `BoundStepper::step`). Retrying the step
    /// is valid; the retried queue's streams may consume later RNG
    /// positions than a fault-free run, but other queues are untouched.
    Transient(String),
    /// The step unwound for an unclassified reason (a genuine panic).
    /// The queue's state must be treated as torn: quarantine it, never
    /// re-step it.
    Fatal(String),
    /// The whole replica is dead (an injected `kill@N` fault or an
    /// equivalent terminal backend condition). Unlike `Fatal`, the queue
    /// state is *not* torn — the kill fires at a step boundary, before
    /// any model call — so the engine loop evacuates every checkpoint it
    /// holds onto the migration board and exits its thread.
    Killed(String),
}

impl StepError {
    pub fn message(&self) -> &str {
        match self {
            StepError::Transient(m)
            | StepError::Fatal(m)
            | StepError::Killed(m) => m,
        }
    }
}

/// Outcome of one fallible scheduler step.
pub type StepResult = Result<Vec<(SlotId, Sample)>, StepError>;

/// What the coordinator's run queues drive: a scheduler bound to a model,
/// with the `HybridModel::State` type erased so it can live behind
/// `Box<dyn EngineModel>`.
pub trait Stepper {
    fn admit(&mut self, prompt: &Prompt, rng: Pcg) -> SlotId;
    /// [`Stepper::admit`] with an explicit priority class (pending-queue
    /// ordering; see [`SpecScheduler::admit_prio`]).
    fn admit_prio(&mut self, prompt: &Prompt, rng: Pcg, priority: i32)
                  -> SlotId;
    /// Run one outer loop. Model-call unwinds are contained at this
    /// boundary and classified as [`StepError`]; `Err` never leaves a
    /// resident sequence half-stepped (see `BoundStepper::step`).
    fn step(&mut self) -> StepResult;
    fn n_active(&self) -> usize;
    fn n_pending(&self) -> usize;
    fn is_idle(&self) -> bool;
    fn capacity(&self) -> usize;
    fn steps(&self) -> u64;
    fn backfills(&self) -> u64;
    /// Evict one specific resident as a checkpoint (quarantine/deadline
    /// paths); `None` if `id` is not resident. See
    /// [`SpecScheduler::evict`].
    fn evict(&mut self, id: SlotId) -> Option<SeqCheckpoint>;
    /// Evict the lowest-priority resident as a checkpoint (preemption);
    /// `None` when nothing is resident. See [`SpecScheduler::evict_lowest`].
    fn evict_lowest(&mut self) -> Option<SeqCheckpoint>;
    /// Drop one pending sequence (deadline expiry before placement).
    /// See [`SpecScheduler::remove_pending`].
    fn remove_pending(&mut self, id: SlotId) -> bool;
    /// Drain the pending queue (quarantine). See
    /// [`SpecScheduler::take_pending_ids`].
    fn take_pending_ids(&mut self) -> Vec<SlotId>;
    /// Drain the pending queue as zero-progress checkpoints (replica
    /// evacuation). See [`SpecScheduler::take_pending`].
    fn take_pending(&mut self) -> Vec<SeqCheckpoint>;
    /// The lowest-priority pending sequence, if any (priority-aware
    /// shedding's victim probe). See [`SpecScheduler::lowest_pending`].
    fn lowest_pending(&self) -> Option<(SlotId, i32)>;
    /// Whether `id` is currently pending. See
    /// [`SpecScheduler::is_pending`].
    fn is_pending(&self, id: SlotId) -> bool;
    /// Re-admit an evicted checkpoint. See [`SpecScheduler::resume`].
    fn resume(&mut self, ck: SeqCheckpoint);
    /// Adopt a checkpoint from *another* scheduler, re-minting its slot
    /// id locally; returns the new id. See [`SpecScheduler::adopt`].
    fn adopt(&mut self, ck: SeqCheckpoint) -> SlotId;
    /// Total remaining work (ordering positions still to decide) across
    /// resident sequences — the preemption victim policy's residual-work
    /// signal. See [`SpecScheduler::residual`].
    fn residual(&self) -> usize;
    /// Namespace [`SlotId`] allocation from `base` upward (multi-engine
    /// replicas use disjoint bases so migrated checkpoints cannot
    /// collide). Must precede the first admission. See
    /// [`SpecScheduler::set_id_base`].
    fn set_id_base(&mut self, base: u64);
    /// Cumulative sequences evicted / resumed-into-slots counters.
    fn evictions(&self) -> u64;
    fn resumes(&self) -> u64;
    fn take_placements(&mut self) -> Vec<SlotId>;
    /// Per-phase wall-clock cost (model / draw / LSE / accept) since the
    /// last call — the coordinator's per-phase step-cost reporting.
    fn take_phases(&mut self) -> StepPhases;
}

/// A `SpecScheduler` bound to one model reference and one sampler setting
/// (the coordinator keys run queues by `batch_key`, so every sequence in a
/// queue shares its settings).
pub struct BoundStepper<'m, M: HybridModel> {
    model: &'m M,
    params: SeqParams,
    pub sched: SpecScheduler,
}

impl<'m, M: HybridModel> BoundStepper<'m, M> {
    pub fn new(model: &'m M, params: SeqParams) -> BoundStepper<'m, M> {
        BoundStepper { model, params, sched: SpecScheduler::for_model(model) }
    }

    /// Bound stepper whose scheduler runs its planar phases on the given
    /// (shared) step pool.
    pub fn with_pool(model: &'m M, params: SeqParams, pool: Arc<StepPool>)
                     -> BoundStepper<'m, M> {
        let mut stepper = BoundStepper::new(model, params);
        stepper.sched.set_pool(pool);
        stepper
    }
}

impl<'m, M: HybridModel> Stepper for BoundStepper<'m, M> {
    fn admit(&mut self, prompt: &Prompt, rng: Pcg) -> SlotId {
        self.sched.admit(prompt, self.params.clone(), rng)
    }

    fn admit_prio(&mut self, prompt: &Prompt, rng: Pcg, priority: i32)
                  -> SlotId {
        self.sched.admit_prio(prompt, self.params.clone(), rng, priority)
    }

    /// The containment boundary: every model call in the engine runs
    /// under this `catch_unwind`, so a crashing backend kills one step
    /// of one run queue, never the engine thread.
    ///
    /// Unwind-safety argument for the `AssertUnwindSafe` below — why no
    /// torn state escapes the catch:
    /// * The only unwind sources inside `SpecScheduler::step` are the
    ///   `draft_into`/`verify_into` model calls, executed on this thread
    ///   (the step pool runs only the pure kernel phases). A panic in
    ///   the pure phases would be an engine bug; it is classified
    ///   `Fatal` and the queue is quarantined, never re-stepped, so even
    ///   then torn state is unreachable.
    /// * Phases execute planar: every kernel-mutating phase (draw,
    ///   accept) runs to completion across all rows before the next
    ///   model call begins. At any model-call unwind point the resident
    ///   kernels therefore satisfy their between-step invariant.
    /// * All per-step buffers (tokens, logits, sigma, proposals) live in
    ///   the `StepArena` and are rebuilt from kernel state at the top of
    ///   every step, so partially-written scratch never feeds a retry.
    /// * The only state a `Transient` retry observes from the failed
    ///   attempt is per-sequence RNG streams advanced past draws whose
    ///   proposals died with the arena: later stream positions, same
    ///   distribution, other queues untouched.
    fn step(&mut self) -> StepResult {
        let model = self.model;
        let sched = &mut self.sched;
        match std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            sched.step(model)
        })) {
            Ok(finished) => Ok(finished),
            Err(payload) => {
                if let Some(e) =
                    payload.downcast_ref::<crate::engine::InjectedErr>()
                {
                    Err(StepError::Transient(e.0.clone()))
                } else if let Some(m) = payload.downcast_ref::<&str>() {
                    Err(StepError::Fatal(format!("model panicked: {m}")))
                } else if let Some(m) = payload.downcast_ref::<String>() {
                    Err(StepError::Fatal(format!("model panicked: {m}")))
                } else {
                    Err(StepError::Fatal(
                        "model panicked: <non-string payload>".into(),
                    ))
                }
            }
        }
    }

    fn n_active(&self) -> usize {
        self.sched.n_active()
    }

    fn n_pending(&self) -> usize {
        self.sched.n_pending()
    }

    fn is_idle(&self) -> bool {
        self.sched.is_idle()
    }

    fn capacity(&self) -> usize {
        self.sched.capacity()
    }

    fn steps(&self) -> u64 {
        self.sched.steps()
    }

    fn backfills(&self) -> u64 {
        self.sched.backfills()
    }

    fn evict(&mut self, id: SlotId) -> Option<SeqCheckpoint> {
        self.sched.evict(id)
    }

    fn evict_lowest(&mut self) -> Option<SeqCheckpoint> {
        self.sched.evict_lowest()
    }

    fn remove_pending(&mut self, id: SlotId) -> bool {
        self.sched.remove_pending(id)
    }

    fn take_pending_ids(&mut self) -> Vec<SlotId> {
        self.sched.take_pending_ids()
    }

    fn take_pending(&mut self) -> Vec<SeqCheckpoint> {
        self.sched.take_pending()
    }

    fn lowest_pending(&self) -> Option<(SlotId, i32)> {
        self.sched.lowest_pending()
    }

    fn is_pending(&self, id: SlotId) -> bool {
        self.sched.is_pending(id)
    }

    fn resume(&mut self, ck: SeqCheckpoint) {
        self.sched.resume(ck)
    }

    fn adopt(&mut self, ck: SeqCheckpoint) -> SlotId {
        self.sched.adopt(ck)
    }

    fn residual(&self) -> usize {
        self.sched.residual()
    }

    fn set_id_base(&mut self, base: u64) {
        self.sched.set_id_base(base)
    }

    fn evictions(&self) -> u64 {
        self.sched.evictions()
    }

    fn resumes(&self) -> u64 {
        self.sched.resumes()
    }

    fn take_placements(&mut self) -> Vec<SlotId> {
        self.sched.take_placements()
    }

    fn take_phases(&mut self) -> StepPhases {
        self.sched.take_phases()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::mock::MockModel;
    use crate::engine::Window;

    fn spec(params: &SpecParams) -> SeqParams {
        SeqParams::Spec(params.clone())
    }

    #[test]
    fn admissions_park_in_pending_until_stepped() {
        let mut m = MockModel::new(8, 4, 3);
        m.buckets = vec![1, 2];
        let mut sched = SpecScheduler::for_model(&m);
        let mut rng = Pcg::new(1);
        for _ in 0..5 {
            sched.admit(&Prompt::empty(8), spec(&SpecParams::default()),
                        rng.split());
        }
        assert_eq!(sched.n_pending(), 5);
        assert_eq!(sched.n_active(), 0);
        assert_eq!(sched.capacity(), 2);
        assert!(!sched.is_idle());
    }

    #[test]
    fn backfill_admits_queued_after_retirement() {
        let mut m = MockModel::new(8, 4, 3);
        m.buckets = vec![1, 2];
        let mut sched = SpecScheduler::for_model(&m);
        let mut rng = Pcg::new(2);
        let n = 5;
        let ids: Vec<SlotId> = (0..n)
            .map(|_| sched.admit(&Prompt::empty(8),
                                 spec(&SpecParams::default()), rng.split()))
            .collect();
        let mut done = Vec::new();
        let mut guard = 0;
        while !sched.is_idle() {
            assert!(sched.n_active() <= 2, "slot table overflow");
            done.extend(sched.step(&m));
            guard += 1;
            assert!(guard < 10_000, "scheduler failed to drain");
        }
        assert_eq!(done.len(), n);
        let mut got: Vec<SlotId> = done.iter().map(|(id, _)| *id).collect();
        got.sort();
        assert_eq!(got, ids);
        // Capacity 2, five sequences: at least three must have entered via
        // backfill after a retirement freed a slot.
        assert!(sched.backfills() >= 3, "backfills {}", sched.backfills());
    }

    #[test]
    fn short_request_retires_while_long_still_resident() {
        let d = 24;
        let m = MockModel::new(d, 4, 7);
        let mut sched = SpecScheduler::for_model(&m);
        let mut rng = Pcg::new(3);
        let mut short = Prompt::empty(d);
        for pos in 0..d - 2 {
            short.0[pos] = Some((pos % 4) as i32);
        }
        let long_id = sched.admit(&Prompt::empty(d),
                                  spec(&SpecParams::default()), rng.split());
        let short_id =
            sched.admit(&short, spec(&SpecParams::default()), rng.split());
        // Step until the first retirement: it must be the short sequence,
        // and the long one must still be resident (not held hostage).
        let mut first = None;
        let mut guard = 0;
        while first.is_none() {
            let fin = sched.step(&m);
            if let Some((id, s)) = fin.into_iter().next() {
                first = Some((id, s));
            }
            guard += 1;
            assert!(guard < 10_000);
        }
        let (id, sample) = first.unwrap();
        assert_eq!(id, short_id);
        assert_eq!(sample.accepted + sample.rejected, 2);
        assert!(!sched.is_idle(), "long sequence must still be running");
        // Drain the long one too.
        let mut rest = Vec::new();
        while !sched.is_idle() {
            rest.extend(sched.step(&m));
        }
        assert_eq!(rest.len(), 1);
        assert_eq!(rest[0].0, long_id);
    }

    #[test]
    fn fully_revealed_prompt_retires_without_model_work() {
        let d = 6;
        let m = MockModel::new(d, 3, 11);
        let mut sched = SpecScheduler::for_model(&m);
        let mut prompt = Prompt::empty(d);
        for pos in 0..d {
            prompt.0[pos] = Some((pos % 3) as i32);
        }
        let id = sched.admit(&prompt, spec(&SpecParams::default()),
                             Pcg::new(1));
        let fin = sched.step(&m);
        assert_eq!(fin.len(), 1);
        assert_eq!(fin[0].0, id);
        assert_eq!(fin[0].1.nfe, 0.0);
        assert_eq!(sched.steps(), 0, "no forward pass may run");
        assert!(sched.is_idle());
    }

    #[test]
    fn padding_never_exceeds_bucket_ladder() {
        let mut m = MockModel::new(8, 4, 5);
        m.buckets = vec![1, 2, 4];
        let mut sched = SpecScheduler::for_model(&m);
        let mut rng = Pcg::new(9);
        for _ in 0..3 {
            sched.admit(&Prompt::empty(8), spec(&SpecParams::default()),
                        rng.split());
        }
        while !sched.is_idle() {
            sched.step(&m);
        }
        // 3 resident rows run in bucket 4 (1 padded row) until the first
        // retirement shrinks the batch down the ladder; no bucket is ever
        // made up on the fly (the old `max(bucket, n)` fallback).
        assert!(sched.padded_row_steps() >= 1,
                "3 rows in bucket 4 must pad");
        assert!(sched.row_steps() >= sched.steps(),
                "every step pays at least one row");
    }

    /// Seed-stability of the new Gumbel-draw path: identical admissions
    /// (same seeds) must reproduce identical tokens. Distributional
    /// equivalence to the old CDF-inversion path is pinned separately by
    /// the chi-square tests in `engine::kernels` and the likelihood
    /// cross-check in `likelihood::tests` — bitwise equality with
    /// pre-change RNG streams is explicitly *not* a goal (the Gumbel draw
    /// consumes the PCG stream differently).
    #[test]
    fn scheduler_is_deterministic_for_identical_admissions() {
        let run = || {
            let m = MockModel::new(10, 5, 13);
            let mut sched = SpecScheduler::for_model(&m);
            let mut rng = Pcg::new(77);
            for _ in 0..3 {
                sched.admit(&Prompt::empty(10),
                            spec(&SpecParams::default()), rng.split());
            }
            let mut out = Vec::new();
            while !sched.is_idle() {
                out.extend(sched.step(&m));
            }
            out.sort_by_key(|(id, _)| *id);
            out.into_iter().map(|(_, s)| s.tokens).collect::<Vec<_>>()
        };
        assert_eq!(run(), run());
    }

    /// The planar-phase determinism contract at the scheduler level:
    /// identical admissions produce bitwise-identical token streams and
    /// counters for any `step_threads` (the full workload-level pin,
    /// including the coordinator, lives in tests/thread_invariance.rs).
    #[test]
    fn scheduler_is_thread_count_invariant() {
        let run = |threads: usize| {
            let m = MockModel::new(18, 7, 91);
            let mut sched = SpecScheduler::for_model(&m);
            sched.set_pool(Arc::new(StepPool::new(threads)));
            assert_eq!(sched.step_threads(), threads);
            let mut rng = Pcg::new(0x7c0);
            let params = SpecParams { n_verify: 2, ..Default::default() };
            for _ in 0..6 {
                sched.admit(&Prompt::empty(18), spec(&params), rng.split());
            }
            let mut out = Vec::new();
            while !sched.is_idle() {
                out.extend(sched.step(&m));
            }
            out.sort_by_key(|(id, _)| *id);
            let tokens: Vec<Vec<i32>> =
                out.iter().map(|(_, s)| s.tokens.clone()).collect();
            let stats = sched.take_stats();
            (tokens, sched.steps(), sched.row_steps(),
             stats.accepted, stats.rejected, stats.verify_passes)
        };
        let base = run(1);
        for t in [2usize, 3, 8] {
            assert_eq!(run(t), base, "step_threads={t} diverged");
        }
    }

    /// Phase timings are accumulated and drained.
    #[test]
    fn step_phases_are_reported() {
        let m = MockModel::new(12, 4, 33);
        let mut sched = SpecScheduler::for_model(&m);
        sched.admit(&Prompt::empty(12), spec(&SpecParams::default()),
                    Pcg::new(8));
        sched.step(&m);
        let ph = sched.take_phases();
        assert!(ph.model_s > 0.0, "{ph:?}");
        assert!(ph.total_s() >= ph.sampling_s());
        let drained = sched.take_phases();
        assert_eq!(drained, StepPhases::default());
    }

    #[test]
    fn mdm_rows_flow_through_scheduler() {
        let d = 16;
        let m = MockModel::new(d, 5, 17);
        let mut sched = SpecScheduler::for_model(&m);
        let mut rng = Pcg::new(21);
        let params = MdmParams { steps: 8, temperature: 1.0 };
        for _ in 0..3 {
            sched.admit(&Prompt::empty(d), SeqParams::Mdm(params.clone()),
                        rng.split());
        }
        let mut out = Vec::new();
        while !sched.is_idle() {
            out.extend(sched.step(&m));
        }
        assert_eq!(out.len(), 3);
        for (_, s) in &out {
            assert!(s.tokens.iter().all(|&t| (0..5).contains(&t)));
            assert!(s.nfe >= 1.0 && s.nfe <= 9.0, "{s:?}");
        }
    }

    /// The load-bearing preemption invariant: evicting residents
    /// mid-sequence, letting other work run in their slots, and resuming
    /// them later must reproduce the *exact* token streams (and
    /// accept/reject tallies) of an uninterrupted same-seed run — the
    /// checkpoint carries each sequence's full state including its RNG
    /// stream, and sequences are mutually independent.
    #[test]
    fn evict_resume_is_bitwise_identical() {
        let collect = |out: Vec<(SlotId, Sample)>| {
            let mut m = std::collections::BTreeMap::new();
            for (id, s) in out {
                assert!(m.insert(id, (s.tokens, s.accepted, s.rejected))
                            .is_none(),
                        "sequence answered twice");
            }
            m
        };
        let admit_all = |sched: &mut SpecScheduler| {
            let mut rng = Pcg::new(0xbeef);
            (0..5)
                .map(|_| {
                    sched.admit(&Prompt::empty(16),
                                spec(&SpecParams::default()), rng.split())
                })
                .collect::<Vec<SlotId>>()
        };
        let mut m = MockModel::new(16, 5, 23);
        m.buckets = vec![1, 2];

        // Baseline: uninterrupted drain.
        let mut sched = SpecScheduler::for_model(&m);
        admit_all(&mut sched);
        let mut out = Vec::new();
        while !sched.is_idle() {
            out.extend(sched.step(&m));
        }
        let baseline = collect(out);
        assert_eq!(baseline.len(), 5);

        // Preempted run: same admissions; after two steps evict every
        // resident, let pending sequences take the freed slots for a few
        // steps, then resume the checkpoints and drain.
        let mut sched = SpecScheduler::for_model(&m);
        admit_all(&mut sched);
        let mut out = Vec::new();
        out.extend(sched.step(&m));
        out.extend(sched.step(&m));
        let mut parked = Vec::new();
        while let Some(ck) = sched.evict_lowest() {
            assert!(ck.progress() < 16, "evicted mid-sequence");
            parked.push(ck);
        }
        assert_eq!(parked.len(), 2, "both residents evicted");
        assert_eq!(sched.evictions(), 2);
        assert_eq!(sched.n_active(), 0);
        for _ in 0..3 {
            out.extend(sched.step(&m)); // backfilled pending work runs
        }
        for ck in parked {
            sched.resume(ck);
        }
        while !sched.is_idle() {
            out.extend(sched.step(&m));
        }
        assert_eq!(sched.resumes(), 2);
        assert_eq!(collect(out), baseline,
                   "preempted token streams diverged from the \
                    unpreempted run");
    }

    /// Pending-queue priority classes: higher priority overtakes queued
    /// lower-priority work (FIFO within a class); residents stay put.
    #[test]
    fn priority_orders_pending_within_queue() {
        let mut m = MockModel::new(8, 4, 3);
        m.buckets = vec![1];
        let mut sched = SpecScheduler::for_model(&m);
        let mut rng = Pcg::new(6);
        let params = SpecParams::default();
        let a = sched.admit_prio(&Prompt::empty(8), spec(&params),
                                 rng.split(), 0);
        let b = sched.admit_prio(&Prompt::empty(8), spec(&params),
                                 rng.split(), 5);
        let c = sched.admit_prio(&Prompt::empty(8), spec(&params),
                                 rng.split(), 5);
        let d = sched.admit_prio(&Prompt::empty(8), spec(&params),
                                 rng.split(), 0);
        let mut order = Vec::new();
        while !sched.is_idle() {
            order.extend(sched.step(&m).into_iter().map(|(id, _)| id));
        }
        // Capacity 1 ⇒ retirement order == placement order: the two
        // priority-5 sequences first (admission order within the class),
        // then the priority-0 ones.
        assert_eq!(order, vec![b, c, a, d]);
    }

    /// A resumed checkpoint rejoins *ahead of* equal-priority fresh
    /// pending work (it already waited once and carries progress).
    #[test]
    fn resumed_rejoins_ahead_of_equal_priority_fresh() {
        let mut m = MockModel::new(8, 4, 3);
        m.buckets = vec![1];
        let mut sched = SpecScheduler::for_model(&m);
        let mut rng = Pcg::new(41);
        let params = SpecParams::default();
        let a = sched.admit(&Prompt::empty(8), spec(&params), rng.split());
        let b = sched.admit(&Prompt::empty(8), spec(&params), rng.split());
        sched.step(&m); // a resident, b pending
        let ck = sched.evict(a).expect("a is resident");
        assert_eq!(ck.id(), a);
        assert!(sched.evict(a).is_none(), "already evicted");
        sched.resume(ck);
        let mut order = Vec::new();
        while !sched.is_idle() {
            order.extend(sched.step(&m).into_iter().map(|(id, _)| id));
        }
        assert_eq!(order, vec![a, b],
                   "resumed sequence must run before equal-priority \
                    fresh pending work");
        // The resumed re-placement is a resume, not a fresh placement or
        // backfill: a caller timing queue waits never sees `a` twice.
        assert_eq!(sched.resumes(), 1);
    }

    /// Window-lazy drafting must not change the per-loop reveal
    /// accounting: with a constant window of 1 and one verify pass, every
    /// outer loop decides exactly one ordering position.
    #[test]
    fn constant_window_decides_one_position_per_loop() {
        let d = 12;
        let m = MockModel::new(d, 4, 31);
        let mut sched = SpecScheduler::for_model(&m);
        let params = SpecParams {
            window: Window::Constant(1),
            n_verify: 1,
            ..Default::default()
        };
        sched.admit(&Prompt::empty(d), spec(&params), Pcg::new(5));
        let mut out = Vec::new();
        while !sched.is_idle() {
            out.extend(sched.step(&m));
        }
        assert_eq!(out.len(), 1);
        let s = &out[0].1;
        assert_eq!(s.accepted + s.rejected, d);
        assert_eq!(s.outer_loops, d, "window 1 ⇒ one decision per loop");
        assert_eq!(sched.steps(), d as u64);
    }
}
