//! Self-speculative masked diffusion sampling — Algorithms 2 and 3.
//!
//! One **outer loop** = one non-causal (draft) forward pass producing a
//! factorized draft distribution over all masked positions. Inside it, up to
//! `n_verify` **inner loops** each run one causal (verify) pass over the
//! drafted tokens and a speculative accept/reject sweep: accepted tokens are
//! revealed; the first rejection resamples from the residual distribution
//! max(0, q - p) and ends the sweep (the resample changes the causal
//! conditioning, so the next inner loop recomputes targets). A window W(i)
//! (App. D) caps the reveals per outer loop.
//!
//! NFE accounting follows Sec. 5.1 exactly: a pass of all L blocks is 1 NFE,
//! so an outer loop that used `n` verify passes costs
//! (n_noncausal + n * n_causal) / L — counted per batch element.

use crate::engine::softmax::{residual_distribution, softmax_row};
use crate::engine::window::Window;
use crate::engine::{HybridModel, Prompt, Sample};
use crate::util::rng::Pcg;

#[derive(Clone, Debug)]
pub struct SpecParams {
    pub window: Window,
    /// N: draft/verify inner loops per non-causal pass (Alg. 3).
    pub n_verify: usize,
    /// Safety valve (a well-formed run needs at most D outer loops).
    pub max_outer: usize,
    /// Optional sampling temperature applied to draft AND target logits.
    pub temperature: f64,
    /// Fix the generation ordering (tests, likelihood cross-checks, and the
    /// HTTP API's explicit-ordering mode). Must be a permutation of 0..D
    /// whose prefix covers the prompt's revealed positions.
    pub sigma: Option<Vec<i32>>,
}

impl Default for SpecParams {
    fn default() -> Self {
        SpecParams {
            window: Window::Cosine { dtau: 0.05 },
            n_verify: 1,
            max_outer: 100_000,
            temperature: 1.0,
            sigma: None,
        }
    }
}

/// Aggregate statistics over one batched sampling call.
#[derive(Clone, Debug, Default)]
pub struct SpecStats {
    pub outer_loops: usize,
    pub verify_passes: usize,
    pub accepted: usize,
    pub rejected: usize,
}

struct SeqState {
    tokens: Vec<i32>,
    sigma: Vec<i32>,
    /// revealed[pos]: position already carries its final token. Kept
    /// incrementally — rebuilding it from sigma[..i] each outer loop made
    /// the draft-context build O(D^2 * i) (see EXPERIMENTS.md §Perf L3).
    revealed: Vec<bool>,
    /// Tokens revealed so far (= next ordering position to decide).
    i: usize,
    done: bool,
    nfe: f64,
    outer: usize,
    accepted: usize,
    rejected: usize,
    rng: Pcg,
}

/// Sample a batch of sequences with Algorithm 3.
///
/// Prompt positions are treated as already revealed: they are placed first
/// in the generation ordering sigma (in random order), matching the paper's
/// arbitrary-location conditioning.
pub fn speculative_sample<M: HybridModel>(
    model: &M,
    prompts: &[Prompt],
    params: &SpecParams,
    rng: &mut Pcg,
) -> (Vec<Sample>, SpecStats) {
    assert!(model.has_verify(), "model has no causal half");
    let d = model.seq_len();
    let v = model.vocab();
    let mask = model.mask_id();
    let n_req = prompts.len();
    let bucket = pick_bucket(&model.buckets(), n_req);

    let mut seqs: Vec<SeqState> = (0..bucket)
        .map(|b| {
            let prompt = prompts.get(b).cloned().unwrap_or_else(|| {
                Prompt::empty(d) // padding rows
            });
            init_seq(&prompt, d, mask, rng.split(), params.sigma.as_deref())
        })
        .collect();
    let mut stats = SpecStats::default();

    for _ in 0..params.max_outer {
        if seqs.iter().all(|s| s.done) {
            break;
        }
        stats.outer_loops += 1;

        // ---- draft pass over the whole bucket --------------------------
        let mut masked_tokens = Vec::with_capacity(bucket * d);
        for s in &seqs {
            for pos in 0..d {
                masked_tokens
                    .push(if s.revealed[pos] { s.tokens[pos] } else { mask });
            }
        }
        let (state, draft_logits) = model.draft(&masked_tokens, bucket);

        // Per-sequence draft probabilities + window target.
        let mut draft_probs: Vec<Vec<Vec<f64>>> = Vec::with_capacity(bucket);
        let mut targets = Vec::with_capacity(bucket);
        let mut full_tokens = Vec::with_capacity(bucket * d);
        for (b, s) in seqs.iter_mut().enumerate() {
            let mut probs_rows: Vec<Vec<f64>> = vec![Vec::new(); d];
            if !s.done {
                let w = params.window.limit(s.i, d);
                targets.push((s.i + w).min(d));
                // Sample draft tokens for every masked ordering position.
                for od in s.i..d {
                    let pos = s.sigma[od] as usize;
                    let row = &draft_logits[(b * d + pos) * v..
                                            (b * d + pos) * v + v];
                    let p = temp_probs(row, params.temperature);
                    let tok = s.rng.categorical(&p) as i32;
                    s.tokens[pos] = tok;
                    probs_rows[pos] = p;
                }
            } else {
                targets.push(s.i);
            }
            draft_probs.push(probs_rows);
            full_tokens.extend_from_slice(&s.tokens);
        }
        let sigma_flat: Vec<i32> =
            seqs.iter().flat_map(|s| s.sigma.iter().copied()).collect();

        // j = reveals within this outer loop, per sequence.
        let mut j: Vec<usize> = seqs.iter().map(|s| s.i).collect();
        let mut verify_used = vec![0usize; bucket];

        // ---- inner speculative loops ------------------------------------
        for _ in 0..params.n_verify {
            let any_active = seqs
                .iter()
                .enumerate()
                .any(|(b, s)| !s.done && j[b] < targets[b]);
            if !any_active {
                break;
            }
            let target_logits =
                model.verify(&state, &full_tokens, &sigma_flat, bucket);
            stats.verify_passes += 1;

            for (b, s) in seqs.iter_mut().enumerate() {
                if s.done || j[b] >= targets[b] {
                    continue;
                }
                verify_used[b] += 1;
                let mut dd = j[b];
                while dd < targets[b] {
                    let pos = s.sigma[dd] as usize;
                    let tok = s.tokens[pos] as usize;
                    let p_row = &draft_probs[b][pos];
                    // Target: ordering position 0 falls back to the draft
                    // (first-position rule); otherwise track dd-1.
                    let q_row: Vec<f64> = if dd == 0 {
                        p_row.clone()
                    } else {
                        let tr = (b * d + (dd - 1)) * v;
                        temp_probs(&target_logits[tr..tr + v],
                                   params.temperature)
                    };
                    let accept_p = if p_row[tok] > 0.0 {
                        (q_row[tok] / p_row[tok]).min(1.0)
                    } else {
                        1.0
                    };
                    if s.rng.f64() < accept_p {
                        s.accepted += 1;
                        stats.accepted += 1;
                        dd += 1;
                    } else {
                        s.rejected += 1;
                        stats.rejected += 1;
                        let res = residual_distribution(&q_row, p_row)
                            .unwrap_or(q_row);
                        let new_tok = s.rng.categorical(&res) as i32;
                        s.tokens[pos] = new_tok;
                        full_tokens[b * d + pos] = new_tok;
                        dd += 1;
                        break; // resample ends this inner sweep
                    }
                }
                j[b] = dd;
            }
        }

        // ---- bookkeeping -------------------------------------------------
        for (b, s) in seqs.iter_mut().enumerate() {
            if s.done {
                continue;
            }
            s.outer += 1;
            s.nfe += model.nfe_cost(verify_used[b]);
            for od in s.i..j[b] {
                s.revealed[s.sigma[od] as usize] = true;
            }
            s.i = j[b];
            if s.i >= d {
                s.done = true;
            }
        }
    }

    let samples = seqs
        .into_iter()
        .take(n_req)
        .map(|s| Sample {
            tokens: s.tokens,
            nfe: s.nfe,
            outer_loops: s.outer,
            accepted: s.accepted,
            rejected: s.rejected,
        })
        .collect();
    (samples, stats)
}

fn init_seq(prompt: &Prompt, d: usize, mask: i32, mut rng: Pcg,
            fixed_sigma: Option<&[i32]>) -> SeqState {
    let mut revealed: Vec<i32> = Vec::new();
    let mut hidden: Vec<i32> = Vec::new();
    let mut tokens = vec![mask; d];
    for (pos, slot) in prompt.0.iter().enumerate() {
        match slot {
            Some(tok) => {
                tokens[pos] = *tok;
                revealed.push(pos as i32);
            }
            None => hidden.push(pos as i32),
        }
    }
    rng.shuffle(&mut revealed);
    rng.shuffle(&mut hidden);
    let i = revealed.len();
    let mut sigma = revealed;
    sigma.extend(hidden);
    if let Some(fixed) = fixed_sigma {
        debug_assert_eq!(fixed.len(), d);
        debug_assert!(fixed[..i]
            .iter()
            .all(|p| prompt.0[*p as usize].is_some()));
        sigma = fixed.to_vec();
    }
    let revealed_mask: Vec<bool> =
        prompt.0.iter().map(|s| s.is_some()).collect();
    SeqState {
        tokens,
        sigma,
        revealed: revealed_mask,
        i,
        done: i >= d,
        nfe: 0.0,
        outer: 0,
        accepted: 0,
        rejected: 0,
        rng,
    }
}

fn pick_bucket(buckets: &[usize], n: usize) -> usize {
    buckets
        .iter()
        .copied()
        .filter(|&b| b >= n)
        .min()
        .unwrap_or_else(|| buckets.iter().copied().max().unwrap_or(n).max(n))
}

fn temp_probs(logits: &[f32], temperature: f64) -> Vec<f64> {
    if (temperature - 1.0).abs() < 1e-12 {
        softmax_row(logits)
    } else {
        crate::engine::softmax::softmax_row_temp(logits, temperature)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::mock::MockModel;

    fn run(model: &MockModel, n: usize, params: &SpecParams, seed: u64)
           -> (Vec<Sample>, SpecStats) {
        let prompts = vec![Prompt::empty(model.seq_len); n];
        let mut rng = Pcg::new(seed);
        speculative_sample(model, &prompts, params, &mut rng)
    }

    #[test]
    fn completes_and_tokens_valid() {
        let m = MockModel::new(12, 5, 3);
        let (samples, _) = run(&m, 3, &SpecParams::default(), 1);
        for s in &samples {
            assert_eq!(s.tokens.len(), 12);
            assert!(s.tokens.iter().all(|&t| (0..5).contains(&t)),
                    "{:?}", s.tokens);
            assert!(s.nfe > 0.0);
        }
    }

    #[test]
    fn target_equals_draft_accepts_everything() {
        // With q == p every accept test passes: zero rejections, and each
        // outer loop reveals the full window.
        let mut m = MockModel::new(16, 4, 9);
        m.target_equals_draft = true;
        let (samples, stats) = run(&m, 2, &SpecParams::default(), 2);
        assert_eq!(stats.rejected, 0);
        for s in samples {
            assert_eq!(s.rejected, 0);
            assert_eq!(s.accepted, 16);
        }
    }

    #[test]
    fn accepted_plus_rejected_is_seq_len() {
        // Every ordering position is decided exactly once: either accepted
        // or rejected-and-resampled.
        let m = MockModel::new(20, 6, 5);
        let params = SpecParams {
            n_verify: 3,
            window: Window::Cosine { dtau: 0.1 },
            ..Default::default()
        };
        let (samples, _) = run(&m, 4, &params, 7);
        for s in samples {
            assert_eq!(s.accepted + s.rejected, 20, "{s:?}");
        }
    }

    #[test]
    fn nfe_formula_holds_for_single_verify() {
        // With n_verify = 1 each outer loop costs exactly 1 NFE
        // ((11 + 1)/12) so nfe == outer_loops.
        let m = MockModel::new(16, 4, 11);
        let params = SpecParams { n_verify: 1, ..Default::default() };
        let (samples, _) = run(&m, 2, &params, 3);
        for s in samples {
            assert!((s.nfe - s.outer_loops as f64).abs() < 1e-9, "{s:?}");
        }
    }

    #[test]
    fn prompt_tokens_survive() {
        let m = MockModel::new(10, 4, 13);
        let mut p = Prompt::empty(10);
        p.0[3] = Some(2);
        p.0[7] = Some(1);
        let mut rng = Pcg::new(5);
        let (samples, _) =
            speculative_sample(&m, &[p], &SpecParams::default(), &mut rng);
        assert_eq!(samples[0].tokens[3], 2);
        assert_eq!(samples[0].tokens[7], 1);
    }

    #[test]
    fn deterministic_given_seed() {
        let m = MockModel::new(14, 5, 17);
        let (a, _) = run(&m, 2, &SpecParams::default(), 42);
        let (b, _) = run(&m, 2, &SpecParams::default(), 42);
        assert_eq!(a[0].tokens, b[0].tokens);
        assert_eq!(a[1].tokens, b[1].tokens);
    }

    #[test]
    fn larger_window_fewer_outer_loops() {
        let m = MockModel::new(32, 4, 19);
        let small = SpecParams {
            window: Window::Cosine { dtau: 0.01 },
            ..Default::default()
        };
        let big = SpecParams {
            window: Window::Cosine { dtau: 0.2 },
            n_verify: 4,
            ..Default::default()
        };
        let (a, _) = run(&m, 4, &small, 23);
        let (b, _) = run(&m, 4, &big, 23);
        let nfe = |v: &[Sample]| {
            v.iter().map(|s| s.nfe).sum::<f64>() / v.len() as f64
        };
        assert!(nfe(&b) < nfe(&a), "{} !< {}", nfe(&b), nfe(&a));
    }

    #[test]
    fn bucket_padding_returns_requested_count() {
        let m = MockModel::new(8, 3, 29);
        let (samples, _) = run(&m, 3, &SpecParams::default(), 31);
        assert_eq!(samples.len(), 3);
    }
}
