//! Self-speculative masked diffusion sampling — Algorithms 2 and 3.
//!
//! One **outer loop** = one non-causal (draft) forward pass producing a
//! factorized draft distribution over all masked positions. Inside it, up to
//! `n_verify` **inner loops** each run one causal (verify) pass over the
//! drafted tokens and a speculative accept/reject sweep: accepted tokens are
//! revealed; the first rejection resamples from the residual distribution
//! max(0, q - p) and ends the sweep (the resample changes the causal
//! conditioning, so the next inner loop recomputes targets). A window W(i)
//! (App. D) caps the reveals per outer loop.
//!
//! NFE accounting follows Sec. 5.1 exactly: a pass of all L blocks is 1 NFE,
//! so an outer loop that used `n` verify passes costs
//! (n_noncausal + n * n_causal) / L — counted per batch element.
//!
//! The outer/inner loop machinery itself lives in `engine::scheduler`
//! (continuous batching: slot table + pending queue + per-step backfill);
//! `speculative_sample` below is the drive-to-completion wrapper that
//! admits a fixed prompt set and steps the scheduler until it drains.
//! Padding rows no longer exist as sequences at all — rows beyond the
//! resident count are mask-only filler that accrues no accept/reject
//! counts and does no generation work.
//!
//! Sampling runs on the logits-domain kernels of `engine::kernels`:
//! Gumbel-max draws (one PCG draw per token seeding a counter-based
//! noise stream), log-space accept tests from cached per-row
//! log-sum-exps, and lazy residual resampling. Same-seed runs are
//! reproducible and the sampled distribution is unchanged (chi-square
//! pinned in `engine::kernels::tests`), but token streams differ
//! bitwise from the pre-kernel CDF-inversion sampler — pin seeds, not
//! historical outputs.

use crate::engine::scheduler::{run_to_completion, SeqParams};
use crate::engine::window::Window;
use crate::engine::{HybridModel, Prompt, Sample};
use crate::util::rng::Pcg;

#[derive(Clone, Debug)]
pub struct SpecParams {
    pub window: Window,
    /// N: draft/verify inner loops per non-causal pass (Alg. 3).
    pub n_verify: usize,
    /// Safety valve (a well-formed run needs at most D outer loops).
    pub max_outer: usize,
    /// Optional sampling temperature applied to draft AND target logits.
    pub temperature: f64,
    /// Fix the generation ordering (tests, likelihood cross-checks, and the
    /// HTTP API's explicit-ordering mode). Must be a permutation of 0..D
    /// whose prefix covers the prompt's revealed positions.
    pub sigma: Option<Vec<i32>>,
}

impl Default for SpecParams {
    fn default() -> Self {
        SpecParams {
            window: Window::Cosine { dtau: 0.05 },
            n_verify: 1,
            max_outer: 100_000,
            temperature: 1.0,
            sigma: None,
        }
    }
}

/// Aggregate statistics over one batched sampling call. With the
/// continuous-batching engine these cover **real sequences only**: padding
/// rows contribute nothing.
#[derive(Clone, Debug, Default)]
pub struct SpecStats {
    pub outer_loops: usize,
    pub verify_passes: usize,
    pub accepted: usize,
    pub rejected: usize,
}

/// Sample a batch of sequences with Algorithm 3.
///
/// Prompt positions are treated as already revealed: they are placed first
/// in the generation ordering sigma (in random order), matching the paper's
/// arbitrary-location conditioning.
///
/// Drive-to-completion wrapper over `SpecScheduler`: prompts beyond the
/// model's largest batch bucket are queued and backfilled as slots free up,
/// so any `prompts.len()` is valid — the model only ever sees bucket sizes
/// it compiled.
pub fn speculative_sample<M: HybridModel>(
    model: &M,
    prompts: &[Prompt],
    params: &SpecParams,
    rng: &mut Pcg,
) -> (Vec<Sample>, SpecStats) {
    assert!(model.has_verify(), "model has no causal half");
    run_to_completion(model, prompts, &SeqParams::Spec(params.clone()), rng)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::mock::MockModel;

    fn run(model: &MockModel, n: usize, params: &SpecParams, seed: u64)
           -> (Vec<Sample>, SpecStats) {
        let prompts = vec![Prompt::empty(model.seq_len); n];
        let mut rng = Pcg::new(seed);
        speculative_sample(model, &prompts, params, &mut rng)
    }

    #[test]
    fn completes_and_tokens_valid() {
        let m = MockModel::new(12, 5, 3);
        let (samples, _) = run(&m, 3, &SpecParams::default(), 1);
        for s in &samples {
            assert_eq!(s.tokens.len(), 12);
            assert!(s.tokens.iter().all(|&t| (0..5).contains(&t)),
                    "{:?}", s.tokens);
            assert!(s.nfe > 0.0);
        }
    }

    #[test]
    fn target_equals_draft_accepts_everything() {
        // With q == p every accept test passes: zero rejections, and each
        // outer loop reveals the full window.
        let mut m = MockModel::new(16, 4, 9);
        m.target_equals_draft = true;
        let (samples, stats) = run(&m, 2, &SpecParams::default(), 2);
        assert_eq!(stats.rejected, 0);
        for s in samples {
            assert_eq!(s.rejected, 0);
            assert_eq!(s.accepted, 16);
        }
    }

    #[test]
    fn accepted_plus_rejected_is_seq_len() {
        // Every ordering position is decided exactly once: either accepted
        // or rejected-and-resampled.
        let m = MockModel::new(20, 6, 5);
        let params = SpecParams {
            n_verify: 3,
            window: Window::Cosine { dtau: 0.1 },
            ..Default::default()
        };
        let (samples, _) = run(&m, 4, &params, 7);
        for s in samples {
            assert_eq!(s.accepted + s.rejected, 20, "{s:?}");
        }
    }

    #[test]
    fn padding_rows_accrue_no_counts() {
        // 3 requests in a bucket of 4: the padding row must contribute
        // zero accepted/rejected decisions — batch statistics are exactly
        // the sum over the real sequences.
        let m = MockModel::new(12, 5, 3);
        let (samples, stats) = run(&m, 3, &SpecParams::default(), 11);
        assert_eq!(samples.len(), 3);
        assert_eq!(
            samples.iter().map(|s| s.accepted).sum::<usize>(),
            stats.accepted
        );
        assert_eq!(
            samples.iter().map(|s| s.rejected).sum::<usize>(),
            stats.rejected
        );
        for s in &samples {
            assert_eq!(s.accepted + s.rejected, 12, "{s:?}");
        }
    }

    #[test]
    fn oversized_batch_chunks_through_bucket_ladder() {
        // More prompts than the largest bucket: the scheduler queues the
        // overflow and backfills, never inventing an uncompiled batch size.
        let mut m = MockModel::new(8, 4, 19);
        m.buckets = vec![1, 2, 4];
        let (samples, _) = run(&m, 11, &SpecParams::default(), 13);
        assert_eq!(samples.len(), 11);
        for s in &samples {
            assert_eq!(s.accepted + s.rejected, 8);
            assert!(s.tokens.iter().all(|&t| (0..4).contains(&t)));
        }
    }

    #[test]
    fn nfe_formula_holds_for_single_verify() {
        // With n_verify = 1 each outer loop costs exactly 1 NFE
        // ((11 + 1)/12) so nfe == outer_loops.
        let m = MockModel::new(16, 4, 11);
        let params = SpecParams { n_verify: 1, ..Default::default() };
        let (samples, _) = run(&m, 2, &params, 3);
        for s in samples {
            assert!((s.nfe - s.outer_loops as f64).abs() < 1e-9, "{s:?}");
        }
    }

    #[test]
    fn prompt_tokens_survive() {
        let m = MockModel::new(10, 4, 13);
        let mut p = Prompt::empty(10);
        p.0[3] = Some(2);
        p.0[7] = Some(1);
        let mut rng = Pcg::new(5);
        let (samples, _) =
            speculative_sample(&m, &[p], &SpecParams::default(), &mut rng);
        assert_eq!(samples[0].tokens[3], 2);
        assert_eq!(samples[0].tokens[7], 1);
    }

    #[test]
    fn deterministic_given_seed() {
        let m = MockModel::new(14, 5, 17);
        let (a, _) = run(&m, 2, &SpecParams::default(), 42);
        let (b, _) = run(&m, 2, &SpecParams::default(), 42);
        assert_eq!(a[0].tokens, b[0].tokens);
        assert_eq!(a[1].tokens, b[1].tokens);
    }

    #[test]
    fn larger_window_fewer_outer_loops() {
        let m = MockModel::new(32, 4, 19);
        let small = SpecParams {
            window: Window::Cosine { dtau: 0.01 },
            ..Default::default()
        };
        let big = SpecParams {
            window: Window::Cosine { dtau: 0.2 },
            n_verify: 4,
            ..Default::default()
        };
        let (a, _) = run(&m, 4, &small, 23);
        let (b, _) = run(&m, 4, &big, 23);
        let nfe = |v: &[Sample]| {
            v.iter().map(|s| s.nfe).sum::<f64>() / v.len() as f64
        };
        assert!(nfe(&b) < nfe(&a), "{} !< {}", nfe(&b), nfe(&a));
    }

    #[test]
    fn bucket_padding_returns_requested_count() {
        let m = MockModel::new(8, 3, 29);
        let (samples, _) = run(&m, 3, &SpecParams::default(), 31);
        assert_eq!(samples.len(), 3);
    }
}
