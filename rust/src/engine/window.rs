//! Window functions W(i) — Appendix D.
//!
//! The window caps how many tokens one draft (non-causal) pass may reveal.
//! Monotonically increasing windows work best: early tokens pin down the
//! sample and must be chosen carefully; late tokens are strongly determined
//! by context and can be revealed in bulk.

/// A window schedule mapping `i` (tokens revealed so far) to the maximum
/// number of tokens the current outer loop may reveal.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Window {
    /// W(i) = i + 1 (App. D Eq. 124).
    Linear,
    /// Fixed window size (plain speculative-decoding style).
    Constant(usize),
    /// Cosine window emulating an MDM sampled on a cosine grid with time
    /// step `dtau` (App. D Eq. 127–129). The paper's best choice.
    Cosine { dtau: f64 },
}

impl Window {
    /// Maximum reveals for this pass. Always in [1, D - i].
    pub fn limit(&self, i: usize, d: usize) -> usize {
        debug_assert!(i < d);
        let remaining = d - i;
        let w = match *self {
            Window::Linear => i + 1,
            Window::Constant(k) => k.max(1),
            Window::Cosine { dtau } => {
                // alpha_tau = proportion of masks; invert the cosine
                // schedule for the equivalent time, advance by dtau, and
                // take the expected number of newly revealed positions.
                let alpha = remaining as f64 / d as f64;
                let tau = 1.0 - 2.0 / std::f64::consts::PI * alpha.acos();
                let alpha_next = (std::f64::consts::PI / 2.0
                    * (1.0 - tau + dtau))
                    .cos()
                    .max(0.0);
                (d as f64 * (alpha - alpha_next)).floor() as usize
            }
        };
        w.clamp(1, remaining)
    }

    /// Parse "linear" | "constant:K" | "cosine:DTAU" (CLI / HTTP API).
    pub fn parse(s: &str) -> Option<Window> {
        if s == "linear" {
            return Some(Window::Linear);
        }
        if let Some(k) = s.strip_prefix("constant:") {
            return k.parse().ok().map(Window::Constant);
        }
        if let Some(dt) = s.strip_prefix("cosine:") {
            return dt.parse().ok().map(|dtau| Window::Cosine { dtau });
        }
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::ptest;
    use crate::util::rng::Pcg;

    #[test]
    fn linear_is_i_plus_one() {
        let w = Window::Linear;
        assert_eq!(w.limit(0, 64), 1);
        assert_eq!(w.limit(5, 64), 6);
        assert_eq!(w.limit(63, 64), 1); // clamped to remaining
    }

    #[test]
    fn cosine_matches_closed_form() {
        // Hand-check one value: D=64, i=32 -> alpha=0.5,
        // tau = 1 - (2/pi) acos(0.5) = 1 - 2/3 = 1/3.
        // alpha_next = cos(pi/2 (2/3 + dtau)).
        let d = 64;
        let dtau = 0.1;
        let alpha_next = (std::f64::consts::PI / 2.0 * (2.0 / 3.0 + dtau)).cos();
        let expect = (64.0 * (0.5 - alpha_next)).floor() as usize;
        assert_eq!(Window::Cosine { dtau }.limit(32, d), expect.clamp(1, 32));
    }

    #[test]
    fn cosine_window_grows_with_i() {
        // Monotonically increasing reveals as generation progresses
        // (App. D's motivation), sampled at a few points.
        let w = Window::Cosine { dtau: 0.05 };
        let d = 256;
        let w0 = w.limit(0, d);
        let w_half = w.limit(d / 2, d);
        let w_late = w.limit(3 * d / 4, d);
        assert!(w0 <= w_half && w_half <= w_late,
                "{w0} {w_half} {w_late}");
    }

    #[test]
    fn limits_always_valid_property() {
        ptest::check(
            300,
            0x1d0e5,
            |rng: &mut Pcg, _| {
                let d = 2 + rng.below(512);
                let i = rng.below(d);
                let kind = rng.below(3);
                let w = match kind {
                    0 => Window::Linear,
                    1 => Window::Constant(1 + rng.below(64)),
                    _ => Window::Cosine { dtau: 0.001 + rng.f64() * 0.3 },
                };
                (w, i, d)
            },
            |&(w, i, d)| {
                let l = w.limit(i, d);
                if l >= 1 && l <= d - i {
                    Ok(())
                } else {
                    Err(format!("limit {l} outside [1, {}]", d - i))
                }
            },
        );
    }

    #[test]
    fn parse_forms() {
        assert_eq!(Window::parse("linear"), Some(Window::Linear));
        assert_eq!(Window::parse("constant:8"), Some(Window::Constant(8)));
        assert_eq!(
            Window::parse("cosine:0.05"),
            Some(Window::Cosine { dtau: 0.05 })
        );
        assert_eq!(Window::parse("bogus"), None);
    }
}
