//! Deterministic mock `HybridModel` for engine / likelihood tests.
//!
//! Distributions are derived by hashing the exact information the real
//! model would condition on, so the mock is *consistent* (same context →
//! same distribution), which is the property the likelihood recursions of
//! Prop. 3.1 rely on:
//!
//! * draft logits for position `p` depend only on the masked context
//!   (all `[B, D]` masked tokens) and `p`;
//! * target logits for track `j` depend on the masked context, the permuted
//!   tokens up to and including track `j` (causal attention), and the
//!   position being predicted `sigma[j+1]`.
//!
//! The hashing is streamed (`Fnv`) and the `HybridModel::draft_into` /
//! `verify_into` overrides write logits into caller-owned buffers, so a
//! warm scheduler step on a MockModel performs **zero heap allocations**
//! (asserted by `tests/alloc_regression.rs`). `draft`/`verify` delegate
//! to the `_into` flavors, so both paths produce identical logits.

use crate::engine::HybridModel;
use crate::util::rng::Pcg;

#[derive(Clone, Debug)]
pub struct MockModel {
    pub seq_len: usize,
    pub vocab: usize,
    /// Logit scale; higher = sharper distributions (lower acceptance when
    /// draft and target disagree).
    pub sharp: f32,
    /// Extra seed so tests can instantiate independent models.
    pub seed: u64,
    /// If true, target == draft (acceptance rate must then be 1).
    pub target_equals_draft: bool,
    /// Batch-size ladder; overridable so scheduler tests can force small
    /// capacities (and exercise pending-queue backfill) cheaply.
    pub buckets: Vec<usize>,
}

/// Streaming FNV-1a over the conditioning info (replaces the old
/// payload-vector build, which allocated per track in `verify`).
struct Fnv(u64);

impl Fnv {
    #[inline]
    fn new(seed: u64) -> Fnv {
        Fnv(0xcbf29ce484222325 ^ seed)
    }

    #[inline]
    fn feed(&mut self, x: u64) {
        self.0 ^= x;
        self.0 = self.0.wrapping_mul(0x100000001b3);
    }
}

impl MockModel {
    pub fn new(seq_len: usize, vocab: usize, seed: u64) -> MockModel {
        MockModel { seq_len, vocab, sharp: 1.5, seed,
                    target_equals_draft: false,
                    buckets: vec![1, 2, 4, 8, 16, 32] }
    }

    /// PCG-generated logits from a finished hash, appended to `out`.
    fn push_logits(&self, h: u64, out: &mut Vec<f32>) {
        let mut rng = Pcg::new(h);
        for _ in 0..self.vocab {
            out.push((rng.f64() as f32 * 4.0 - 2.0) * self.sharp);
        }
    }

    /// Draft-row hash + logits for sequence position `pos` under a masked
    /// context, appended to `out`.
    fn push_draft_row(&self, masked_tokens: &[i32], pos: usize,
                      out: &mut Vec<f32>) {
        let mut h = Fnv::new(self.seed);
        h.feed(1);
        h.feed(pos as i32 as u64);
        for &t in masked_tokens {
            h.feed(t as u64);
        }
        self.push_logits(h.0, out);
    }

    /// Target-row hash + logits for track `j` (predicting `sigma[j+1]`),
    /// appended to `out`. The causal prefix is streamed into the hash, so
    /// no payload vector is built.
    fn push_target_row(&self, masked_tokens: &[i32], tokens: &[i32],
                       sigma: &[i32], j: usize, out: &mut Vec<f32>) {
        let d = self.seq_len;
        if self.target_equals_draft {
            let pos = sigma[(j + 1) % d] as usize;
            return self.push_draft_row(masked_tokens, pos, out);
        }
        let next_pos = sigma[(j + 1) % d];
        let mut h = Fnv::new(self.seed);
        h.feed(2);
        h.feed(next_pos as u64);
        for &t in masked_tokens {
            h.feed(t as u64);
        }
        for t in sigma.iter().take(j + 1) {
            h.feed(tokens[*t as usize] as u64);
        }
        self.push_logits(h.0, out);
    }

    /// Draft logits for sequence position `pos` under a masked context.
    pub fn draft_logits(&self, masked_tokens: &[i32], pos: usize) -> Vec<f32> {
        let mut out = Vec::with_capacity(self.vocab);
        self.push_draft_row(masked_tokens, pos, &mut out);
        out
    }

    /// Target logits for track `j` (predicting `sigma[j+1]`).
    pub fn target_logits(&self, masked_tokens: &[i32], tokens: &[i32],
                         sigma: &[i32], j: usize) -> Vec<f32> {
        let mut out = Vec::with_capacity(self.vocab);
        self.push_target_row(masked_tokens, tokens, sigma, j, &mut out);
        out
    }
}

impl HybridModel for MockModel {
    type State = Vec<i32>;

    fn seq_len(&self) -> usize {
        self.seq_len
    }

    fn vocab(&self) -> usize {
        self.vocab
    }

    fn n_noncausal(&self) -> usize {
        11
    }

    fn n_causal(&self) -> usize {
        1
    }

    fn buckets(&self) -> Vec<usize> {
        self.buckets.clone()
    }

    fn draft(&self, tokens: &[i32], batch: usize) -> (Vec<i32>, Vec<f32>) {
        let mut state = None;
        let mut logits = Vec::new();
        self.draft_into(tokens, batch, &mut state, &mut logits);
        (state.expect("draft_into sets the state"), logits)
    }

    fn verify(&self, state: &Vec<i32>, tokens: &[i32], sigma: &[i32],
              batch: usize) -> Vec<f32> {
        let mut logits = Vec::new();
        self.verify_into(state, tokens, sigma, batch, &mut logits);
        logits
    }

    fn draft_into(&self, tokens: &[i32], batch: usize,
                  state: &mut Option<Vec<i32>>, logits: &mut Vec<f32>) {
        match state {
            Some(s) => {
                s.clear();
                s.extend_from_slice(tokens);
            }
            None => *state = Some(tokens.to_vec()),
        }
        let d = self.seq_len;
        logits.clear();
        logits.reserve(batch * d * self.vocab);
        for b in 0..batch {
            let ctx = &tokens[b * d..(b + 1) * d];
            for pos in 0..d {
                self.push_draft_row(ctx, pos, logits);
            }
        }
    }

    fn verify_into(&self, state: &Vec<i32>, tokens: &[i32], sigma: &[i32],
                   batch: usize, logits: &mut Vec<f32>) {
        let d = self.seq_len;
        logits.clear();
        logits.reserve(batch * d * self.vocab);
        for b in 0..batch {
            let ctx = &state[b * d..(b + 1) * d];
            let toks = &tokens[b * d..(b + 1) * d];
            let sig = &sigma[b * d..(b + 1) * d];
            for j in 0..d {
                self.push_target_row(ctx, toks, sig, j, logits);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let m = MockModel::new(4, 3, 7);
        let ctx = vec![3, 3, 1, 3]; // mask id = 3
        assert_eq!(m.draft_logits(&ctx, 2), m.draft_logits(&ctx, 2));
        let toks = vec![0, 2, 1, 0];
        let sigma = vec![2i32, 0, 3, 1];
        assert_eq!(
            m.target_logits(&ctx, &toks, &sigma, 1),
            m.target_logits(&ctx, &toks, &sigma, 1)
        );
    }

    #[test]
    fn target_depends_only_on_causal_prefix() {
        // Changing a token *after* track j must not change track j's logits.
        let m = MockModel::new(4, 3, 7);
        let ctx = vec![3, 3, 3, 3];
        let sigma = vec![2i32, 0, 3, 1];
        let a = vec![0, 2, 1, 0];
        let mut b = a.clone();
        b[1] = 1; // position 1 = sigma[3], after track 1's prefix {2, 0}
        assert_eq!(
            m.target_logits(&ctx, &a, &sigma, 1),
            m.target_logits(&ctx, &b, &sigma, 1)
        );
    }

    #[test]
    fn target_changes_with_prefix() {
        let m = MockModel::new(4, 3, 7);
        let ctx = vec![3, 3, 3, 3];
        let sigma = vec![2i32, 0, 3, 1];
        let a = vec![0, 2, 1, 0];
        let mut b = a.clone();
        b[2] = 2; // position 2 = sigma[0], inside every prefix
        assert_ne!(
            m.target_logits(&ctx, &a, &sigma, 1),
            m.target_logits(&ctx, &b, &sigma, 1)
        );
    }

    #[test]
    fn batch_layout_matches_single() {
        let m = MockModel::new(3, 2, 1);
        let t0 = vec![2, 2, 0];
        let t1 = vec![1, 2, 2];
        let both: Vec<i32> = [t0.clone(), t1.clone()].concat();
        let (_, l) = m.draft(&both, 2);
        let (_, l0) = m.draft(&t0, 1);
        let (_, l1) = m.draft(&t1, 1);
        assert_eq!(&l[..l0.len()], &l0[..]);
        assert_eq!(&l[l0.len()..], &l1[..]);
    }

    #[test]
    fn into_flavors_match_allocating_flavors() {
        // draft/verify delegate to the _into overrides; a reused buffer
        // (dirty from a previous call) must produce identical logits.
        let m = MockModel::new(5, 4, 9);
        let tokens = vec![4, 1, 4, 2, 4, 0, 4, 4, 4, 3];
        let (state, logits) = m.draft(&tokens, 2);
        let mut state2 = Some(vec![9i32; 3]); // wrong size, gets rebuilt
        let mut logits2 = vec![1.0f32; 7];
        m.draft_into(&tokens, 2, &mut state2, &mut logits2);
        assert_eq!(state, state2.unwrap());
        assert_eq!(logits, logits2);

        let sigma: Vec<i32> = vec![1, 3, 0, 4, 2, 1, 3, 0, 4, 2];
        let full = vec![0i32, 1, 2, 3, 0, 1, 2, 3, 0, 1];
        let v1 = m.verify(&state, &full, &sigma, 2);
        let mut v2 = vec![5.0f32; 3];
        m.verify_into(&state, &full, &sigma, 2, &mut v2);
        assert_eq!(v1, v2);
    }

    #[test]
    fn target_equals_draft_rows_match_draft_rows() {
        let mut m = MockModel::new(4, 3, 7);
        m.target_equals_draft = true;
        let ctx = vec![3, 3, 3, 3];
        let sigma = vec![2i32, 0, 3, 1];
        let toks = vec![0, 2, 1, 0];
        // Track j predicts sigma[j+1]; with target==draft the row must be
        // the draft row for that position, bit-for-bit.
        for j in 0..3 {
            let t = m.target_logits(&ctx, &toks, &sigma, j);
            let d = m.draft_logits(&ctx, sigma[j + 1] as usize);
            assert_eq!(t, d);
        }
    }
}
