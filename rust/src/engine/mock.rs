//! Deterministic mock `HybridModel` for engine / likelihood tests.
//!
//! Distributions are derived by hashing the exact information the real
//! model would condition on, so the mock is *consistent* (same context →
//! same distribution), which is the property the likelihood recursions of
//! Prop. 3.1 rely on:
//!
//! * draft logits for position `p` depend only on the masked context
//!   (all `[B, D]` masked tokens) and `p`;
//! * target logits for track `j` depend on the masked context, the permuted
//!   tokens up to and including track `j` (causal attention), and the
//!   position being predicted `sigma[j+1]`.

use crate::engine::HybridModel;
use crate::util::rng::Pcg;

#[derive(Clone, Debug)]
pub struct MockModel {
    pub seq_len: usize,
    pub vocab: usize,
    /// Logit scale; higher = sharper distributions (lower acceptance when
    /// draft and target disagree).
    pub sharp: f32,
    /// Extra seed so tests can instantiate independent models.
    pub seed: u64,
    /// If true, target == draft (acceptance rate must then be 1).
    pub target_equals_draft: bool,
    /// Batch-size ladder; overridable so scheduler tests can force small
    /// capacities (and exercise pending-queue backfill) cheaply.
    pub buckets: Vec<usize>,
}

impl MockModel {
    pub fn new(seq_len: usize, vocab: usize, seed: u64) -> MockModel {
        MockModel { seq_len, vocab, sharp: 1.5, seed,
                    target_equals_draft: false,
                    buckets: vec![1, 2, 4, 8, 16, 32] }
    }

    fn hash_logits(&self, tag: u64, payload: &[i32], pos: i32) -> Vec<f32> {
        // FNV-1a over the conditioning info, then PCG-generated logits.
        let mut h: u64 = 0xcbf29ce484222325 ^ self.seed;
        let mut feed = |x: u64| {
            h ^= x;
            h = h.wrapping_mul(0x100000001b3);
        };
        feed(tag);
        feed(pos as u64 as u64);
        for &t in payload {
            feed(t as u64);
        }
        let mut rng = Pcg::new(h);
        (0..self.vocab)
            .map(|_| (rng.f64() as f32 * 4.0 - 2.0) * self.sharp)
            .collect()
    }

    /// Draft logits for sequence position `pos` under a masked context.
    pub fn draft_logits(&self, masked_tokens: &[i32], pos: usize) -> Vec<f32> {
        self.hash_logits(1, masked_tokens, pos as i32)
    }

    /// Target logits for track `j` (predicting `sigma[j+1]`).
    pub fn target_logits(&self, masked_tokens: &[i32], tokens: &[i32],
                         sigma: &[i32], j: usize) -> Vec<f32> {
        if self.target_equals_draft {
            let pos = sigma[(j + 1) % self.seq_len] as usize;
            return self.draft_logits(masked_tokens, pos);
        }
        let d = self.seq_len;
        let mut payload: Vec<i32> = masked_tokens.to_vec();
        // Causal prefix in permuted order (tracks 0..=j).
        for t in sigma.iter().take(j + 1) {
            payload.push(tokens[*t as usize]);
        }
        let next_pos = sigma[(j + 1) % d];
        self.hash_logits(2, &payload, next_pos)
    }
}

impl HybridModel for MockModel {
    type State = Vec<i32>;

    fn seq_len(&self) -> usize {
        self.seq_len
    }

    fn vocab(&self) -> usize {
        self.vocab
    }

    fn n_noncausal(&self) -> usize {
        11
    }

    fn n_causal(&self) -> usize {
        1
    }

    fn buckets(&self) -> Vec<usize> {
        self.buckets.clone()
    }

    fn draft(&self, tokens: &[i32], batch: usize) -> (Vec<i32>, Vec<f32>) {
        let d = self.seq_len;
        let v = self.vocab;
        let mut logits = Vec::with_capacity(batch * d * v);
        for b in 0..batch {
            let ctx = &tokens[b * d..(b + 1) * d];
            for pos in 0..d {
                logits.extend(self.draft_logits(ctx, pos));
            }
        }
        (tokens.to_vec(), logits)
    }

    fn verify(&self, state: &Vec<i32>, tokens: &[i32], sigma: &[i32],
              batch: usize) -> Vec<f32> {
        let d = self.seq_len;
        let v = self.vocab;
        let mut logits = Vec::with_capacity(batch * d * v);
        for b in 0..batch {
            let ctx = &state[b * d..(b + 1) * d];
            let toks = &tokens[b * d..(b + 1) * d];
            let sig = &sigma[b * d..(b + 1) * d];
            for j in 0..d {
                logits.extend(self.target_logits(ctx, toks, sig, j));
            }
        }
        logits
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let m = MockModel::new(4, 3, 7);
        let ctx = vec![3, 3, 1, 3]; // mask id = 3
        assert_eq!(m.draft_logits(&ctx, 2), m.draft_logits(&ctx, 2));
        let toks = vec![0, 2, 1, 0];
        let sigma = vec![2i32, 0, 3, 1];
        assert_eq!(
            m.target_logits(&ctx, &toks, &sigma, 1),
            m.target_logits(&ctx, &toks, &sigma, 1)
        );
    }

    #[test]
    fn target_depends_only_on_causal_prefix() {
        // Changing a token *after* track j must not change track j's logits.
        let m = MockModel::new(4, 3, 7);
        let ctx = vec![3, 3, 3, 3];
        let sigma = vec![2i32, 0, 3, 1];
        let a = vec![0, 2, 1, 0];
        let mut b = a.clone();
        b[1] = 1; // position 1 = sigma[3], after track 1's prefix {2, 0}
        assert_eq!(
            m.target_logits(&ctx, &a, &sigma, 1),
            m.target_logits(&ctx, &b, &sigma, 1)
        );
    }

    #[test]
    fn target_changes_with_prefix() {
        let m = MockModel::new(4, 3, 7);
        let ctx = vec![3, 3, 3, 3];
        let sigma = vec![2i32, 0, 3, 1];
        let a = vec![0, 2, 1, 0];
        let mut b = a.clone();
        b[2] = 2; // position 2 = sigma[0], inside every prefix
        assert_ne!(
            m.target_logits(&ctx, &a, &sigma, 1),
            m.target_logits(&ctx, &b, &sigma, 1)
        );
    }

    #[test]
    fn batch_layout_matches_single() {
        let m = MockModel::new(3, 2, 1);
        let t0 = vec![2, 2, 0];
        let t1 = vec![1, 2, 2];
        let both: Vec<i32> = [t0.clone(), t1.clone()].concat();
        let (_, l) = m.draft(&both, 2);
        let (_, l0) = m.draft(&t0, 1);
        let (_, l1) = m.draft(&t1, 1);
        assert_eq!(&l[..l0.len()], &l0[..]);
        assert_eq!(&l[l0.len()..], &l1[..]);
    }
}
