//! Numerically stable softmax / log-softmax over logits rows.
//!
//! The runtime returns raw logits `[B, D, V]`. These materializing
//! helpers are the *reference* implementations: the scheduler hot path
//! now runs on the allocation-free logits-domain kernels in
//! `engine::kernels` (Gumbel-max draws, cached log-sum-exps, lazy
//! residuals), and the chi-square tests there pin the kernels to the
//! distributions these functions define. Cold paths (likelihood tables,
//! oracle scoring, benches) and tests still use them directly.

/// Stable softmax of one row, in f64 for downstream probability arithmetic.
pub fn softmax_row(logits: &[f32]) -> Vec<f64> {
    let m = logits.iter().copied().fold(f32::NEG_INFINITY, f32::max) as f64;
    let mut out: Vec<f64> =
        logits.iter().map(|&x| ((x as f64) - m).exp()).collect();
    let s: f64 = out.iter().sum();
    out.iter_mut().for_each(|x| *x /= s);
    out
}

/// Stable log-softmax of one row.
pub fn log_softmax_row(logits: &[f32]) -> Vec<f64> {
    let m = logits.iter().copied().fold(f32::NEG_INFINITY, f32::max) as f64;
    let lse = logits
        .iter()
        .map(|&x| ((x as f64) - m).exp())
        .sum::<f64>()
        .ln()
        + m;
    logits.iter().map(|&x| x as f64 - lse).collect()
}

/// Softmax with temperature (Table 1 note: generative perplexity can be
/// cheated with low temperature; exposed so harnesses can demonstrate it).
///
/// Single f64 pass over the row. The seed implementation scaled into an
/// intermediate `Vec<f32>` — an extra allocation *and* a round-trip of
/// `f64/temp` back through f32 that quantized the scaled logits before
/// the softmax saw them.
pub fn softmax_row_temp(logits: &[f32], temp: f64) -> Vec<f64> {
    debug_assert!(temp > 0.0, "temperature must be positive");
    let m = logits.iter().copied().fold(f32::NEG_INFINITY, f32::max) as f64
        / temp;
    let mut out: Vec<f64> =
        logits.iter().map(|&x| (x as f64 / temp - m).exp()).collect();
    let s: f64 = out.iter().sum();
    out.iter_mut().for_each(|x| *x /= s);
    out
}

/// The speculative residual distribution max(0, q - p), normalized.
/// Returns None if q <= p everywhere (numerically zero mass — caller then
/// falls back to q itself, which only happens when p == q exactly).
pub fn residual_distribution(q: &[f64], p: &[f64]) -> Option<Vec<f64>> {
    let mut out: Vec<f64> =
        q.iter().zip(p).map(|(&a, &b)| (a - b).max(0.0)).collect();
    let s: f64 = out.iter().sum();
    if s <= 0.0 {
        return None;
    }
    out.iter_mut().for_each(|x| *x /= s);
    Some(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn softmax_sums_to_one_and_orders() {
        let p = softmax_row(&[1.0, 2.0, 3.0]);
        assert!((p.iter().sum::<f64>() - 1.0).abs() < 1e-12);
        assert!(p[2] > p[1] && p[1] > p[0]);
    }

    #[test]
    fn softmax_handles_large_logits() {
        let p = softmax_row(&[1000.0, 1000.0]);
        assert!((p[0] - 0.5).abs() < 1e-12);
        let p = softmax_row(&[-1000.0, 0.0]);
        assert!(p[1] > 0.999);
    }

    #[test]
    fn log_softmax_consistent_with_softmax() {
        let logits = [0.3f32, -1.2, 2.0, 0.0];
        let p = softmax_row(&logits);
        let lp = log_softmax_row(&logits);
        for (a, b) in p.iter().zip(&lp) {
            assert!((a.ln() - b).abs() < 1e-9);
        }
    }

    #[test]
    fn temperature_sharpens() {
        let logits = [1.0f32, 2.0];
        let p1 = softmax_row_temp(&logits, 1.0);
        let p01 = softmax_row_temp(&logits, 0.1);
        assert!(p01[1] > p1[1]);
    }

    #[test]
    fn temp_softmax_is_full_precision() {
        // The seed implementation round-tripped the scaled logits through
        // f32; the one-pass version must match an exact f64 reference.
        let logits = [1.0f32, -0.5, 2.25, 0.125];
        let temp = 3.0;
        let got = softmax_row_temp(&logits, temp);
        let exact: Vec<f64> = {
            let scaled: Vec<f64> =
                logits.iter().map(|&x| x as f64 / temp).collect();
            let m = scaled.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
            let e: Vec<f64> = scaled.iter().map(|x| (x - m).exp()).collect();
            let s: f64 = e.iter().sum();
            e.into_iter().map(|x| x / s).collect()
        };
        for (g, x) in got.iter().zip(&exact) {
            assert!((g - x).abs() < 1e-15, "{g} vs {x}");
        }
        // temp == 1 agrees with the plain softmax.
        let a = softmax_row_temp(&logits, 1.0);
        let b = softmax_row(&logits);
        for (x, y) in a.iter().zip(&b) {
            assert!((x - y).abs() < 1e-15);
        }
    }

    #[test]
    fn residual_matches_hand_calc() {
        let q = [0.5, 0.3, 0.2];
        let p = [0.2, 0.5, 0.3];
        let r = residual_distribution(&q, &p).unwrap();
        // max(0, q-p) = [0.3, 0, 0] -> [1, 0, 0]
        assert!((r[0] - 1.0).abs() < 1e-12);
        assert_eq!(r[1], 0.0);
        assert_eq!(r[2], 0.0);
    }

    #[test]
    fn residual_none_when_equal() {
        let q = [0.5, 0.5];
        assert!(residual_distribution(&q, &q).is_none());
    }
}
