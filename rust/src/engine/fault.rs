//! Deterministic fault injection (the chaos layer's only fault source).
//!
//! A [`FaultPlan`] scripts *when* a wrapped component misbehaves — panic,
//! transient error, or injected latency — strictly by a monotone counter,
//! never by wall time or OS entropy, so every chaos run replays
//! bit-for-bit (the same clock/rng discipline repolint enforces on the
//! scheduler; see README §"Correctness tooling" and §"Failure semantics").
//!
//! Two injection seams share the plan machinery:
//! * [`FaultyModel`] wraps a [`HybridModel`] and fires on the Nth
//!   draft/verify **model call**. Faults surface as real unwinds out of
//!   the model boundary — exactly the shape a crashing PJRT backend has —
//!   so `BoundStepper`'s `catch_unwind` containment is genuinely
//!   exercised. Used by the chaos sim and engine/coordinator tests.
//! * [`FaultyStepper`] wraps a run queue's boxed [`Stepper`] and fires on
//!   the Nth **scheduler step**. This is the `BatcherConfig::faults` /
//!   `--fault-plan` wiring: the engine cannot see through
//!   `Box<dyn EngineModel>`, so panic faults here surface as an
//!   already-classified [`StepError::Fatal`] rather than a genuine
//!   unwind, and stalls block the engine thread for real wall time.

use std::cell::Cell;
use std::collections::BTreeMap;
use std::rc::Rc;

use crate::engine::scheduler::{SeqCheckpoint, SlotId, StepError, StepPhases,
                               StepResult, Stepper};
use crate::engine::{HybridModel, Prompt};
use crate::util::rng::Pcg;

/// What a fault does when it fires.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum FaultKind {
    /// Simulated backend crash: a plain `panic!` unwind ([`FaultyModel`])
    /// or a pre-classified [`StepError::Fatal`] ([`FaultyStepper`]).
    Panic,
    /// Transient backend error — retriable by the coordinator's
    /// supervision policy.
    Err,
    /// Injected latency in seconds: the call still succeeds, but late.
    Stall(f64),
    /// Replica death: the owning engine thread terminates deterministically
    /// at this step, evacuating its checkpoints ([`FaultyStepper`] surfaces
    /// it as [`StepError::Killed`]; replica scripts in the fleet sim drive
    /// it directly). At model-call granularity it degrades to a plain
    /// panic (a dead backend is a crashed backend from inside one call).
    Kill,
}

/// One scripted fault: fires when the wrapped unit's counter reaches
/// `at` (1-based; model calls for [`FaultyModel`], steps for
/// [`FaultyStepper`]).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct FaultSpec {
    pub at: u64,
    pub kind: FaultKind,
}

/// A deterministic fault script, replayable bit-for-bit.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct FaultPlan {
    /// Sorted by `at`. Multiple faults may share an index; the first
    /// match wins (parse keeps input order within one index).
    pub faults: Vec<FaultSpec>,
}

impl FaultPlan {
    /// Parse `"panic@5,err@12,stall@20:0.5"`: comma-separated
    /// `kind@index` entries, stalls carrying `:seconds`.
    pub fn parse(s: &str) -> Result<FaultPlan, String> {
        let mut faults = Vec::new();
        for part in s.split(',').map(str::trim).filter(|p| !p.is_empty()) {
            let (kind_s, rest) = part.split_once('@').ok_or_else(|| {
                format!("fault '{part}': expected kind@index")
            })?;
            let (at_s, arg) = match rest.split_once(':') {
                Some((a, b)) => (a, Some(b)),
                None => (rest, None),
            };
            let at: u64 = at_s.trim().parse().map_err(|_| {
                format!("fault '{part}': bad index '{at_s}'")
            })?;
            if at == 0 {
                return Err(format!("fault '{part}': indices are 1-based"));
            }
            let kind = match (kind_s.trim(), arg) {
                ("panic", None) => FaultKind::Panic,
                ("err", None) => FaultKind::Err,
                ("stall", Some(sec)) => {
                    let s: f64 = sec.trim().parse().map_err(|_| {
                        format!("fault '{part}': bad stall seconds '{sec}'")
                    })?;
                    if !s.is_finite() || s < 0.0 {
                        return Err(format!(
                            "fault '{part}': stall seconds must be finite \
                             and >= 0"
                        ));
                    }
                    FaultKind::Stall(s)
                }
                ("stall", None) => {
                    return Err(format!(
                        "fault '{part}': stall needs ':seconds'"
                    ))
                }
                ("kill", None) => FaultKind::Kill,
                (k, _) => {
                    return Err(format!(
                        "fault '{part}': unknown kind '{k}' \
                         (panic | err | stall | kill)"
                    ))
                }
            };
            faults.push(FaultSpec { at, kind });
        }
        if faults.is_empty() {
            return Err("empty fault plan".into());
        }
        faults.sort_by_key(|f| f.at);
        Ok(FaultPlan { faults })
    }

    /// Inverse of [`FaultPlan::parse`] (trace-file round-trips).
    pub fn format(&self) -> String {
        self.faults
            .iter()
            .map(|f| match f.kind {
                FaultKind::Panic => format!("panic@{}", f.at),
                FaultKind::Err => format!("err@{}", f.at),
                FaultKind::Stall(s) => format!("stall@{}:{}", f.at, s),
                FaultKind::Kill => format!("kill@{}", f.at),
            })
            .collect::<Vec<_>>()
            .join(",")
    }
}

/// Parse the CLI `--fault-plan` grammar: `;`-separated `model=plan`
/// entries, e.g. `"mock=err@2,panic@5;tiny=stall@1:0.25"`.
pub fn parse_fault_cli(spec: &str)
                       -> Result<BTreeMap<String, FaultPlan>, String> {
    let mut map = BTreeMap::new();
    for entry in spec.split(';').map(str::trim).filter(|e| !e.is_empty()) {
        let (model, plan) = entry.split_once('=').ok_or_else(|| {
            format!("fault entry '{entry}': expected model=plan")
        })?;
        map.insert(model.trim().to_string(), FaultPlan::parse(plan)?);
    }
    if map.is_empty() {
        return Err("empty fault plan spec".into());
    }
    Ok(map)
}

/// Panic payload tunneling a *transient* backend error through the
/// infallible [`HybridModel`] interface. `BoundStepper::step` downcasts
/// the caught payload: this type maps to [`StepError::Transient`]; any
/// other payload is a genuine crash and maps to [`StepError::Fatal`].
#[derive(Clone, Debug)]
pub struct InjectedErr(pub String);

/// Shared firing state for one wrapped component: the monotone counter
/// plus stall seconds accrued but not yet observed. `Cell`-based so the
/// `&self` model interface can advance it; single-threaded by design
/// (each engine thread / sim owns its models outright).
pub struct FaultState {
    plan: FaultPlan,
    count: Cell<u64>,
    stalled: Cell<f64>,
}

impl FaultState {
    pub fn new(plan: FaultPlan) -> FaultState {
        FaultState { plan, count: Cell::new(0), stalled: Cell::new(0.0) }
    }

    /// Advance the counter and return the fault scheduled for this
    /// count, if any. `Stall` faults additionally accrue their latency
    /// into [`FaultState::take_stall`].
    pub fn advance(&self) -> Option<FaultKind> {
        let n = self.count.get() + 1;
        self.count.set(n);
        let hit =
            self.plan.faults.iter().find(|f| f.at == n).map(|f| f.kind);
        if let Some(FaultKind::Stall(s)) = hit {
            self.stalled.set(self.stalled.get() + s);
        }
        hit
    }

    /// Calls/steps observed so far.
    pub fn count(&self) -> u64 {
        self.count.get()
    }

    /// Drain stall seconds accrued since the last call. The sim advances
    /// its virtual clock by this; [`FaultyStepper`] instead sleeps as the
    /// stall fires.
    pub fn take_stall(&self) -> f64 {
        let s = self.stalled.get();
        self.stalled.set(0.0);
        s
    }
}

/// A [`HybridModel`] wrapper injecting the plan on the Nth draft/verify
/// call. Deterministic: the counter is the only trigger, and the wrapped
/// model's outputs are untouched on non-fault calls.
pub struct FaultyModel<M: HybridModel> {
    inner: M,
    fault: Rc<FaultState>,
}

impl<M: HybridModel> FaultyModel<M> {
    pub fn new(inner: M, plan: FaultPlan) -> FaultyModel<M> {
        FaultyModel { inner, fault: Rc::new(FaultState::new(plan)) }
    }

    /// Handle to the shared firing state (the sim drains accrued stall
    /// time out of it after each step).
    pub fn fault_state(&self) -> Rc<FaultState> {
        Rc::clone(&self.fault)
    }

    fn fire(&self) {
        match self.fault.advance() {
            Some(FaultKind::Panic) | Some(FaultKind::Kill) => panic!(
                "injected fault: backend panic at model call {}",
                self.fault.count()
            ),
            Some(FaultKind::Err) => {
                std::panic::panic_any(InjectedErr(format!(
                    "injected fault: transient backend error at model \
                     call {}",
                    self.fault.count()
                )))
            }
            Some(FaultKind::Stall(_)) | None => {}
        }
    }
}

impl<M: HybridModel> HybridModel for FaultyModel<M> {
    type State = M::State;

    fn seq_len(&self) -> usize {
        self.inner.seq_len()
    }

    fn vocab(&self) -> usize {
        self.inner.vocab()
    }

    fn n_noncausal(&self) -> usize {
        self.inner.n_noncausal()
    }

    fn n_causal(&self) -> usize {
        self.inner.n_causal()
    }

    fn buckets(&self) -> Vec<usize> {
        self.inner.buckets()
    }

    fn has_verify(&self) -> bool {
        self.inner.has_verify()
    }

    fn draft(&self, tokens: &[i32], batch: usize)
             -> (Self::State, Vec<f32>) {
        self.fire();
        self.inner.draft(tokens, batch)
    }

    fn verify(&self, state: &Self::State, tokens: &[i32], sigma: &[i32],
              batch: usize) -> Vec<f32> {
        self.fire();
        self.inner.verify(state, tokens, sigma, batch)
    }

    fn draft_into(&self, tokens: &[i32], batch: usize,
                  state: &mut Option<Self::State>, logits: &mut Vec<f32>) {
        self.fire();
        self.inner.draft_into(tokens, batch, state, logits)
    }

    fn verify_into(&self, state: &Self::State, tokens: &[i32],
                   sigma: &[i32], batch: usize, logits: &mut Vec<f32>) {
        self.fire();
        self.inner.verify_into(state, tokens, sigma, batch, logits)
    }
}

/// The `BatcherConfig::faults` seam: wraps a run queue's boxed
/// [`Stepper`] and injects the plan at **step** granularity. Panic
/// faults return a pre-classified [`StepError::Fatal`] (the genuine
/// unwind path is exercised by [`FaultyModel`] under `BoundStepper`);
/// stalls block for real wall time so `--fault-plan stall@…` exercises
/// live deadline expiry.
pub struct FaultyStepper<'m> {
    inner: Box<dyn Stepper + 'm>,
    fault: FaultState,
}

impl<'m> FaultyStepper<'m> {
    pub fn new(inner: Box<dyn Stepper + 'm>, plan: FaultPlan)
               -> FaultyStepper<'m> {
        FaultyStepper { inner, fault: FaultState::new(plan) }
    }
}

impl<'m> Stepper for FaultyStepper<'m> {
    fn admit(&mut self, prompt: &Prompt, rng: Pcg) -> SlotId {
        self.inner.admit(prompt, rng)
    }

    fn admit_prio(&mut self, prompt: &Prompt, rng: Pcg, priority: i32)
                  -> SlotId {
        self.inner.admit_prio(prompt, rng, priority)
    }

    fn step(&mut self) -> StepResult {
        match self.fault.advance() {
            Some(FaultKind::Panic) => {
                return Err(StepError::Fatal(format!(
                    "injected fault: backend panic at step {}",
                    self.fault.count()
                )))
            }
            Some(FaultKind::Err) => {
                return Err(StepError::Transient(format!(
                    "injected fault: transient backend error at step {}",
                    self.fault.count()
                )))
            }
            Some(FaultKind::Stall(_)) => {
                let s = self.fault.take_stall();
                // lint: allow(clock-discipline) — injected latency is
                // wall latency by definition on the live engine thread;
                // the sim stalls in virtual time via FaultyModel.
                std::thread::sleep(std::time::Duration::from_secs_f64(s));
            }
            Some(FaultKind::Kill) => {
                return Err(StepError::Killed(format!(
                    "injected fault: replica kill at step {}",
                    self.fault.count()
                )))
            }
            None => {}
        }
        self.inner.step()
    }

    fn n_active(&self) -> usize {
        self.inner.n_active()
    }

    fn n_pending(&self) -> usize {
        self.inner.n_pending()
    }

    fn is_idle(&self) -> bool {
        self.inner.is_idle()
    }

    fn capacity(&self) -> usize {
        self.inner.capacity()
    }

    fn steps(&self) -> u64 {
        self.inner.steps()
    }

    fn backfills(&self) -> u64 {
        self.inner.backfills()
    }

    fn evict(&mut self, id: SlotId) -> Option<SeqCheckpoint> {
        self.inner.evict(id)
    }

    fn evict_lowest(&mut self) -> Option<SeqCheckpoint> {
        self.inner.evict_lowest()
    }

    fn remove_pending(&mut self, id: SlotId) -> bool {
        self.inner.remove_pending(id)
    }

    fn take_pending_ids(&mut self) -> Vec<SlotId> {
        self.inner.take_pending_ids()
    }

    fn take_pending(&mut self) -> Vec<SeqCheckpoint> {
        self.inner.take_pending()
    }

    fn lowest_pending(&self) -> Option<(SlotId, i32)> {
        self.inner.lowest_pending()
    }

    fn is_pending(&self, id: SlotId) -> bool {
        self.inner.is_pending(id)
    }

    fn resume(&mut self, ck: SeqCheckpoint) {
        self.inner.resume(ck)
    }

    fn adopt(&mut self, ck: SeqCheckpoint) -> SlotId {
        self.inner.adopt(ck)
    }

    fn residual(&self) -> usize {
        self.inner.residual()
    }

    fn set_id_base(&mut self, base: u64) {
        self.inner.set_id_base(base)
    }

    fn evictions(&self) -> u64 {
        self.inner.evictions()
    }

    fn resumes(&self) -> u64 {
        self.inner.resumes()
    }

    fn take_placements(&mut self) -> Vec<SlotId> {
        self.inner.take_placements()
    }

    fn take_phases(&mut self) -> StepPhases {
        self.inner.take_phases()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plan_parses_and_round_trips() {
        let p = FaultPlan::parse("err@12, panic@5,stall@20:0.5,kill@30")
            .unwrap();
        assert_eq!(p.faults, vec![
            FaultSpec { at: 5, kind: FaultKind::Panic },
            FaultSpec { at: 12, kind: FaultKind::Err },
            FaultSpec { at: 20, kind: FaultKind::Stall(0.5) },
            FaultSpec { at: 30, kind: FaultKind::Kill },
        ]);
        assert_eq!(FaultPlan::parse(&p.format()).unwrap(), p);
    }

    #[test]
    fn bad_plans_are_rejected() {
        for bad in ["", "panic", "panic@0", "panic@x", "stall@3",
                    "stall@3:nan", "stall@3:-1", "boom@2"] {
            assert!(FaultPlan::parse(bad).is_err(), "accepted '{bad}'");
        }
    }

    #[test]
    fn cli_grammar_parses_per_model_plans() {
        let m = parse_fault_cli("mock=err@2,panic@5; tiny=stall@1:0.25")
            .unwrap();
        assert_eq!(m.len(), 2);
        assert_eq!(m["mock"].faults.len(), 2);
        assert_eq!(m["tiny"].faults[0].kind, FaultKind::Stall(0.25));
        assert!(parse_fault_cli("err@2").is_err(), "model= is required");
        assert!(parse_fault_cli("").is_err());
    }

    #[test]
    fn state_fires_deterministically_and_accrues_stalls() {
        let st = FaultState::new(
            FaultPlan::parse("err@2,stall@3:0.25,stall@4:0.5").unwrap(),
        );
        assert_eq!(st.advance(), None);
        assert_eq!(st.advance(), Some(FaultKind::Err));
        assert_eq!(st.advance(), Some(FaultKind::Stall(0.25)));
        assert_eq!(st.advance(), Some(FaultKind::Stall(0.5)));
        assert_eq!(st.advance(), None);
        assert_eq!(st.count(), 5);
        assert!((st.take_stall() - 0.75).abs() < 1e-12);
        assert_eq!(st.take_stall(), 0.0);
    }
}
