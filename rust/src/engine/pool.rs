//! Hand-rolled chunked thread pool for the scheduler's planar phases.
//!
//! The planar step loop (`engine::scheduler`) executes three phases —
//! draws, batched verify-row LSEs, accept/residual sweeps — each of which
//! is a loop over *independent* work items (residents, or logits rows).
//! [`StepPool`] runs such a loop across a fixed set of worker threads:
//!
//! * workers are spawned **once** (per engine, at pool construction) and
//!   parked on a condvar between steps — no per-step thread or channel
//!   churn, and a warm [`StepPool::run`] performs **zero heap
//!   allocations** (pinned by `tests/alloc_regression.rs`);
//! * each `run` splits `0..n_items` into exactly `threads` contiguous
//!   chunks; chunk 0 executes inline on the calling thread, so a
//!   single-thread pool is byte-for-byte the plain sequential loop (no
//!   workers, no synchronization, no atomics — the exact single-threaded
//!   code path `--step-threads 1` promises);
//! * the task is borrowed, not `Arc`-wrapped: `run` publishes a raw fat
//!   pointer to the caller's closure and blocks until every chunk
//!   finished, so the closure may freely borrow the scheduler's
//!   `StepArena` (scoped-thread semantics without `std::thread::scope`'s
//!   per-call spawn cost).
//!
//! Determinism note: the chunk split is a pure function of
//! `(n_items, threads)` and every item is processed exactly once by
//! exactly one chunk, so any computation whose items are independent
//! (the scheduler's phases: per-resident RNG streams, per-row LSEs)
//! produces bitwise-identical results for **any** thread count.
//!
//! [`SharedSlice`] is the companion aliasing escape hatch: a `Send +
//! Sync` view over a `&mut [T]` whose disjoint per-item regions are
//! written by different chunks. Safety is the caller's obligation (each
//! index touched by at most one concurrent chunk), which the scheduler
//! upholds by indexing every shared buffer by item id.

use std::ops::Range;
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

use crate::util::sync::{lock_recover, wait_recover};

/// The shape every pooled task is erased to: `(chunk_index, item_range)`.
/// Chunk 0 always runs on the thread that called [`StepPool::run`];
/// `chunk_index` doubles as a scratch-buffer selector for tasks that
/// need per-worker mutable scratch (e.g. residual rows).
type Task = dyn Fn(usize, Range<usize>) + Sync;

/// Lifetime-erased handle to the currently published task. The
/// `'static` is a fiction confined to this module: [`StepPool::run`]
/// does not return until every chunk completed, so the borrow it erases
/// strictly outlives every call through this handle.
#[derive(Clone, Copy)]
struct TaskPtr(&'static Task);

struct JobState {
    /// Bumped once per published job; workers run each generation once.
    gen: u64,
    task: Option<TaskPtr>,
    n_items: usize,
    chunks: usize,
    /// Worker chunks still running (the caller's chunk 0 not included).
    remaining: usize,
    /// A worker chunk of the current job panicked (caught, recorded,
    /// re-raised on the calling thread once the job completes).
    panicked: bool,
    shutdown: bool,
}

struct Shared {
    state: Mutex<JobState>,
    /// Signalled when a job is published (or on shutdown).
    work: Condvar,
    /// Signalled when the last worker chunk of a job completes.
    done: Condvar,
}

/// Fixed-size worker pool executing chunked loops (see module docs).
pub struct StepPool {
    shared: Arc<Shared>,
    workers: Vec<JoinHandle<()>>,
    threads: usize,
}

impl StepPool {
    /// Spawn `threads - 1` workers (the calling thread is the first
    /// executor). `threads <= 1` spawns nothing and makes every
    /// [`StepPool::run`] a plain inline loop.
    pub fn new(threads: usize) -> StepPool {
        let threads = threads.max(1);
        let shared = Arc::new(Shared {
            state: Mutex::new(JobState {
                gen: 0,
                task: None,
                n_items: 0,
                chunks: 0,
                remaining: 0,
                panicked: false,
                shutdown: false,
            }),
            work: Condvar::new(),
            done: Condvar::new(),
        });
        let mut workers = Vec::with_capacity(threads - 1);
        for w in 1..threads {
            let sh = shared.clone();
            workers.push(
                std::thread::Builder::new()
                    .name(format!("ssmd-step-{w}"))
                    .spawn(move || worker_loop(&sh, w))
                    .expect("spawn step-pool worker"),
            );
        }
        StepPool { shared, workers, threads }
    }

    /// Number of concurrent executors (worker threads + the caller).
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Run `task(chunk_index, item_range)` over `0..n_items` split into
    /// `threads` contiguous chunks; blocks until every chunk completed.
    /// Chunk 0 runs inline on the calling thread. With no workers this
    /// is exactly `task(0, 0..n_items)` — no synchronization at all.
    pub fn run<F: Fn(usize, Range<usize>) + Sync>(&self, n_items: usize,
                                                  task: F) {
        if n_items == 0 {
            return;
        }
        if self.workers.is_empty() || n_items == 1 {
            task(0, 0..n_items);
            return;
        }
        let chunks = self.threads;
        {
            let r: &(dyn Fn(usize, Range<usize>) + Sync) = &task;
            // SAFETY: pure lifetime erasure (the types differ only in
            // the object lifetime bound). The completion barrier below
            // keeps the closure alive past every worker call.
            #[allow(clippy::useless_transmute)]
            let ptr = TaskPtr(unsafe {
                std::mem::transmute::<
                    &(dyn Fn(usize, Range<usize>) + Sync),
                    &'static Task,
                >(r)
            });
            let mut st = lock_recover(&self.shared.state);
            debug_assert!(st.task.is_none(),
                          "StepPool::run is not reentrant");
            st.gen = st.gen.wrapping_add(1);
            st.task = Some(ptr);
            st.n_items = n_items;
            st.chunks = chunks;
            st.remaining = chunks - 1;
            st.panicked = false;
            self.shared.work.notify_all();
        }
        // Completion barrier as a drop guard: even if chunk 0 (below)
        // unwinds, we wait for every worker chunk and clear the
        // published task *before* the borrowed closure is dropped — no
        // worker can ever call a dead closure, and the job state is
        // clean for the next `run`.
        let guard = CompletionGuard { shared: &self.shared };
        let r0 = chunk_range(n_items, chunks, 0);
        if !r0.is_empty() {
            task(0, r0);
        }
        drop(guard);
        // Re-raise a worker-chunk panic on the calling thread (workers
        // catch theirs so the barrier always completes).
        let panicked = lock_recover(&self.shared.state).panicked;
        if panicked {
            panic!("StepPool task panicked in a worker chunk");
        }
    }
}

impl Drop for StepPool {
    fn drop(&mut self) {
        {
            let mut st = lock_recover(&self.shared.state);
            st.shutdown = true;
            self.shared.work.notify_all();
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Waits for all worker chunks of the current job and retracts the
/// published task pointer, whether the caller's chunk completed or
/// unwound (see [`StepPool::run`]).
struct CompletionGuard<'a> {
    shared: &'a Shared,
}

impl Drop for CompletionGuard<'_> {
    fn drop(&mut self) {
        let mut st = lock_recover(&self.shared.state);
        while st.remaining > 0 {
            st = wait_recover(&self.shared.done, st);
        }
        st.task = None;
    }
}

/// Contiguous chunk `i` of `0..n` split into `chunks` near-equal parts
/// (the first `n % chunks` chunks carry one extra item). Pure function
/// of its arguments — the determinism anchor of the pool. `pub(crate)`
/// so the model checker (`engine::pool_model`) splits work with the
/// exact production function.
pub(crate) fn chunk_range(n: usize, chunks: usize, i: usize) -> Range<usize> {
    let base = n / chunks;
    let rem = n % chunks;
    let start = i * base + i.min(rem);
    let len = base + usize::from(i < rem);
    start..start + len
}

fn worker_loop(shared: &Shared, chunk: usize) {
    let mut seen_gen = 0u64;
    loop {
        let (task, gen, n_items, chunks) = {
            let mut st = lock_recover(&shared.state);
            loop {
                if st.shutdown {
                    return;
                }
                if let Some(t) = st.task {
                    if st.gen != seen_gen {
                        break (t, st.gen, st.n_items, st.chunks);
                    }
                }
                st = wait_recover(&shared.work, st);
            }
        };
        seen_gen = gen;
        let range = chunk_range(n_items, chunks, chunk);
        // The handle's 'static is a fiction (see TaskPtr): `run`'s
        // completion barrier keeps the closure alive for the duration of
        // this call. Panics are caught so the barrier always completes
        // (a dead worker would deadlock the caller); `run` re-raises
        // them on the calling thread.
        let outcome = if range.is_empty() {
            Ok(())
        } else {
            std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                (task.0)(chunk, range)
            }))
        };
        let mut st = lock_recover(&shared.state);
        if outcome.is_err() {
            st.panicked = true;
        }
        st.remaining -= 1;
        if st.remaining == 0 {
            shared.done.notify_one();
        }
    }
}

/// `Send + Sync` view over a `&mut [T]` for phase loops whose chunks
/// write disjoint regions. The borrow checker cannot see the
/// disjointness, so the accessors are `unsafe` and the caller promises
/// it (the scheduler indexes every shared buffer by item id, and the
/// pool hands each item to exactly one chunk).
pub struct SharedSlice<T> {
    ptr: *mut T,
    len: usize,
}

// SAFETY: a SharedSlice is only a pointer + length; every aliasing
// obligation is deferred to the unsafe accessors below, whose contracts
// require per-index exclusivity across threads.
unsafe impl<T: Send> Send for SharedSlice<T> {}
// SAFETY: same argument — a shared reference exposes nothing but the
// unsafe accessors, so cross-thread sharing adds no new capability.
unsafe impl<T: Send> Sync for SharedSlice<T> {}

impl<T> SharedSlice<T> {
    pub fn new(slice: &mut [T]) -> SharedSlice<T> {
        SharedSlice { ptr: slice.as_mut_ptr(), len: slice.len() }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Mutable element access without a unique borrow of the backing
    /// slice.
    ///
    /// # Safety
    ///
    /// `i` must be in bounds and no other thread may concurrently access
    /// element `i` (each index owned by exactly one pool chunk).
    #[allow(clippy::mut_from_ref)]
    pub unsafe fn get_mut(&self, i: usize) -> &mut T {
        debug_assert!(i < self.len);
        &mut *self.ptr.add(i)
    }

    /// Mutable subslice access without a unique borrow of the backing
    /// slice.
    ///
    /// # Safety
    ///
    /// `start + len` must be in bounds and no other thread may
    /// concurrently access any element of the range (each range owned by
    /// exactly one pool chunk).
    #[allow(clippy::mut_from_ref)]
    pub unsafe fn range_mut(&self, start: usize, len: usize) -> &mut [T] {
        debug_assert!(start + len <= self.len);
        std::slice::from_raw_parts_mut(self.ptr.add(start), len)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn chunks_partition_exactly() {
        for n in [0usize, 1, 2, 7, 64, 65, 1000] {
            for threads in [1usize, 2, 3, 4, 8] {
                let mut seen = vec![0u8; n];
                for c in 0..threads {
                    for i in chunk_range(n, threads, c) {
                        seen[i] += 1;
                    }
                }
                assert!(seen.iter().all(|&s| s == 1),
                        "n={n} threads={threads}: {seen:?}");
                // Contiguity: chunk c ends where chunk c+1 starts.
                for c in 0..threads - 1 {
                    assert_eq!(chunk_range(n, threads, c).end,
                               chunk_range(n, threads, c + 1).start);
                }
            }
        }
    }

    #[test]
    fn single_thread_pool_runs_inline() {
        let pool = StepPool::new(1);
        assert_eq!(pool.threads(), 1);
        let mut out = vec![0usize; 10];
        let view = SharedSlice::new(&mut out);
        pool.run(10, |w, range| {
            assert_eq!(w, 0);
            for i in range {
                // SAFETY: i is in bounds and owned by this chunk alone.
                unsafe { *view.get_mut(i) = i * i };
            }
        });
        for (i, &x) in out.iter().enumerate() {
            assert_eq!(x, i * i);
        }
    }

    #[test]
    fn multi_thread_pool_covers_every_item() {
        let pool = StepPool::new(4);
        let n = 1003;
        let mut out = vec![0usize; n];
        let view = SharedSlice::new(&mut out);
        pool.run(n, |_w, range| {
            for i in range {
                // SAFETY: i is in bounds and owned by this chunk alone.
                unsafe { *view.get_mut(i) = i + 1 };
            }
        });
        for (i, &x) in out.iter().enumerate() {
            assert_eq!(x, i + 1, "item {i} missed or doubled");
        }
    }

    #[test]
    fn pool_is_reusable_across_many_runs() {
        let pool = StepPool::new(3);
        let hits = AtomicUsize::new(0);
        for _ in 0..100 {
            pool.run(17, |_w, range| {
                hits.fetch_add(range.len(), Ordering::Relaxed);
            });
        }
        assert_eq!(hits.load(Ordering::Relaxed), 1700);
    }

    #[test]
    fn results_identical_for_any_thread_count() {
        // The determinism contract: a per-item pure computation lands
        // identical results regardless of the executor count.
        let compute = |threads: usize| {
            let pool = StepPool::new(threads);
            let mut out = vec![0u64; 513];
            let view = SharedSlice::new(&mut out);
            pool.run(513, |_w, range| {
                for i in range {
                    // lint: allow(rng-discipline) — fixed test mix, not
                    // a generator stream.
                    let mut h = i as u64 ^ 0x9e3779b97f4a7c15;
                    // lint: allow(rng-discipline) — fixed test mix, not
                    // a generator stream.
                    h = h.wrapping_mul(0xbf58476d1ce4e5b9);
                    // SAFETY: i is in bounds and owned by this chunk
                    // alone.
                    unsafe { *view.get_mut(i) = h };
                }
            });
            out
        };
        let base = compute(1);
        for t in [2, 3, 8] {
            assert_eq!(compute(t), base, "threads={t} diverged");
        }
    }

    #[test]
    fn zero_items_is_a_no_op() {
        let pool = StepPool::new(2);
        pool.run(0, |_, _| panic!("must not run"));
    }

    #[test]
    fn worker_panic_propagates_and_pool_survives() {
        let pool = StepPool::new(3);
        let result =
            std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                pool.run(100, |_w, range| {
                    if range.contains(&50) {
                        panic!("boom");
                    }
                });
            }));
        assert!(result.is_err(), "panic must reach the caller");
        // The pool must be clean and reusable after a panicked job.
        let hits = AtomicUsize::new(0);
        pool.run(10, |_w, range| {
            hits.fetch_add(range.len(), Ordering::Relaxed);
        });
        assert_eq!(hits.load(Ordering::Relaxed), 10);
    }

    #[test]
    fn empty_chunks_are_skipped() {
        // Fewer items than threads: trailing chunks are empty and the
        // run still completes (no hang on the completion barrier).
        let pool = StepPool::new(8);
        let mut out = vec![0usize; 3];
        let view = SharedSlice::new(&mut out);
        pool.run(3, |_w, range| {
            for i in range {
                // SAFETY: i is in bounds and owned by this chunk alone.
                unsafe { *view.get_mut(i) = 7 };
            }
        });
        assert_eq!(out, vec![7, 7, 7]);
    }
}
