//! The paper's sampling engine (L3 core).
//!
//! `HybridModel` abstracts the two AOT-compiled forward passes so the
//! engine logic (Alg. 1–3, Prop. 3.1/C.2) is testable against closed-form
//! mock models without PJRT. The production implementation lives in
//! `runtime::PjrtModel`.

pub mod fault;
pub mod kernels;
pub mod mdm;
pub mod mock;
pub mod pool;
pub mod pool_model;
pub mod scheduler;
#[cfg(feature = "simd")]
pub mod simd;
pub mod softmax;
pub mod speculative;
pub mod window;

pub use fault::{FaultKind, FaultPlan, FaultSpec, FaultyModel,
                FaultyStepper, InjectedErr};
pub use mdm::{mdm_sample, MdmParams};
pub use mock::MockModel;
pub use pool::{SharedSlice, StepPool};
pub use scheduler::{pick_bucket, run_to_completion, BoundStepper,
                    SeqCheckpoint, SeqParams, SlotId, SpecScheduler,
                    StepError, StepPhases, StepResult, Stepper};
pub use softmax::{log_softmax_row, softmax_row};
pub use speculative::{speculative_sample, SpecParams, SpecStats};
pub use window::Window;

/// Abstract interface over the hybrid model's two executables.
///
/// Layout conventions (shared with python/compile/model.py):
/// * tokens are `[B, D]` row-major, mask token id = `vocab()`;
/// * `draft` returns `(state, logits)` with logits `[B, D, V]` in
///   **sequence-position** order;
/// * `verify` returns logits `[B, D, V]` in **track** order: track `j`
///   predicts the token at position `sigma[b, j+1]`; track `D-1` is
///   wrap-around filler and must not be read. Ordering position 0 has no
///   causal prediction — its target is the draft distribution (the paper's
///   first-position rule).
pub trait HybridModel {
    /// Opaque non-causal activations passed from draft to verify
    /// (`Vec<f32>` hiddens for PJRT, token context for mocks). `'static`
    /// so the scheduler's `StepArena` can retain it across steps (type-
    /// erased) and implementations can rebuild it in place instead of
    /// reallocating.
    type State: 'static;

    fn seq_len(&self) -> usize;
    fn vocab(&self) -> usize;
    fn mask_id(&self) -> i32 {
        self.vocab() as i32
    }
    /// Non-causal / causal block counts — used for fractional NFE
    /// accounting (Sec. 5.1: 11nc+1c forward = 1 NFE; each extra causal
    /// pass costs 1/12).
    fn n_noncausal(&self) -> usize;
    fn n_causal(&self) -> usize;

    /// Batch sizes this model can execute. The engine picks the smallest
    /// bucket >= requested batch and pads.
    fn buckets(&self) -> Vec<usize>;

    /// Non-causal forward: masked tokens `[B, D]` -> (state, draft logits
    /// `[B, D, V]`).
    fn draft(&self, tokens: &[i32], batch: usize) -> (Self::State, Vec<f32>);

    /// Causal forward re-using the draft state: (state, full tokens
    /// `[B, D]`, sigma `[B, D]`) -> target logits `[B, D, V]` track order.
    fn verify(&self, state: &Self::State, tokens: &[i32], sigma: &[i32],
              batch: usize) -> Vec<f32>;

    /// Buffer-reusing draft: rebuild `state` and `logits` in place. The
    /// default delegates to [`HybridModel::draft`] and moves the results
    /// into the caller's buffers; implementations on the serving hot path
    /// (MockModel, and any backend that can write into caller memory)
    /// should override to make warm scheduler steps allocation-free (see
    /// `engine::scheduler::StepArena`).
    fn draft_into(&self, tokens: &[i32], batch: usize,
                  state: &mut Option<Self::State>, logits: &mut Vec<f32>) {
        let (s, l) = self.draft(tokens, batch);
        *state = Some(s);
        *logits = l;
    }

    /// Buffer-reusing verify; same contract as [`HybridModel::draft_into`].
    fn verify_into(&self, state: &Self::State, tokens: &[i32],
                   sigma: &[i32], batch: usize, logits: &mut Vec<f32>) {
        *logits = self.verify(state, tokens, sigma, batch);
    }

    /// Whether the checkpoint has a causal half (SDTT exports are
    /// draft-only and can only be sampled with the MDM algorithm).
    fn has_verify(&self) -> bool {
        true
    }

    /// NFE cost of one non-causal pass followed by `n_verify` causal
    /// passes, in units of one full forward (Sec. 5.1).
    fn nfe_cost(&self, n_verify: usize) -> f64 {
        let l = (self.n_noncausal() + self.n_causal()) as f64;
        (self.n_noncausal() as f64 + n_verify as f64 * self.n_causal() as f64)
            / l
    }
}

/// A prompt: revealed positions of the sequence (infilling / conditioning).
/// `None` entries are generated; `Some(tok)` are fixed and never resampled.
#[derive(Clone, Debug, Default)]
pub struct Prompt(pub Vec<Option<i32>>);

impl Prompt {
    pub fn empty(seq_len: usize) -> Prompt {
        Prompt(vec![None; seq_len])
    }

    pub fn n_revealed(&self) -> usize {
        self.0.iter().filter(|x| x.is_some()).count()
    }
}

/// Output of one sampled sequence.
#[derive(Clone, Debug)]
pub struct Sample {
    /// Sampled tokens, one per position, in `0..vocab` — except when the
    /// sequence was cut off by the `max_outer` safety valve, in which
    /// case every undecided position holds the mask id (`== vocab`),
    /// marking the sample as incomplete. Before feeding tokens to
    /// vocab-indexed consumers (e.g. the likelihood tables), check that
    /// no token equals the mask id; prompt-revealed positions never
    /// count toward `accepted`/`rejected`, so those tallies are not a
    /// completeness check.
    pub tokens: Vec<i32>,
    /// Function evaluations consumed, fractional (Sec. 5.1 accounting).
    pub nfe: f64,
    /// Number of outer (draft) loops this sequence participated in.
    pub outer_loops: usize,
    /// Accepted / rejected draft-token counts (speculative only).
    pub accepted: usize,
    pub rejected: usize,
}

#[cfg(test)]
mod tests {
    use super::*;

    struct Dummy;
    impl HybridModel for Dummy {
        type State = ();
        fn seq_len(&self) -> usize {
            4
        }
        fn vocab(&self) -> usize {
            3
        }
        fn n_noncausal(&self) -> usize {
            11
        }
        fn n_causal(&self) -> usize {
            1
        }
        fn buckets(&self) -> Vec<usize> {
            vec![1]
        }
        fn draft(&self, _: &[i32], _: usize) -> ((), Vec<f32>) {
            ((), vec![])
        }
        fn verify(&self, _: &(), _: &[i32], _: &[i32], _: usize) -> Vec<f32> {
            vec![]
        }
    }

    #[test]
    fn nfe_cost_matches_paper_example() {
        // Paper Sec. 5.1: 11nc+1c with 7 causal passes = 18/12 = 1.5 NFE.
        let d = Dummy;
        assert!((d.nfe_cost(7) - 1.5).abs() < 1e-12);
        assert!((d.nfe_cost(1) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn prompt_counts() {
        let mut p = Prompt::empty(5);
        assert_eq!(p.n_revealed(), 0);
        p.0[2] = Some(7);
        assert_eq!(p.n_revealed(), 1);
    }
}
