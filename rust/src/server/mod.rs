//! Threaded HTTP/1.1 server + JSON API (tokio/hyper unavailable offline).
//!
//! Endpoints:
//!   GET  /healthz   -> {"ok":true}
//!   GET  /metrics   -> metrics registry snapshot
//!   GET  /models    -> per-model config/buckets
//!   POST /generate  -> run a sampling request (see request::GenRequest)
//!   POST /score     -> exact likelihood + rejection posterior (Prop 3.1/C.2)

pub mod http;

use std::io::Write;
use std::net::{TcpListener, TcpStream};
use std::sync::Arc;

use anyhow::Result;

use crate::coordinator::{Coordinator, GenRequest, ScoreRequest};
use crate::util::json::Json;
use http::{read_request, Request, Response};

pub struct Server {
    coordinator: Coordinator,
}

impl Server {
    pub fn new(coordinator: Coordinator) -> Server {
        Server { coordinator }
    }

    /// Bind and serve forever (thread per connection).
    pub fn serve(self, addr: &str) -> Result<()> {
        let listener = TcpListener::bind(addr)?;
        eprintln!("ssmd serving on http://{addr}");
        let this = Arc::new(self);
        for stream in listener.incoming() {
            let stream = match stream {
                Ok(s) => s,
                Err(_) => continue,
            };
            let srv = this.clone();
            std::thread::spawn(move || {
                let _ = srv.handle_conn(stream);
            });
        }
        Ok(())
    }

    /// Serve until `stop` returns true, polling between accepts (tests).
    pub fn serve_until(self, addr: &str,
                       stop: impl Fn() -> bool + Send + 'static)
                       -> Result<()> {
        let listener = TcpListener::bind(addr)?;
        listener.set_nonblocking(true)?;
        let this = Arc::new(self);
        loop {
            if stop() {
                return Ok(());
            }
            match listener.accept() {
                Ok((stream, _)) => {
                    stream.set_nonblocking(false).ok();
                    let srv = this.clone();
                    std::thread::spawn(move || {
                        let _ = srv.handle_conn(stream);
                    });
                }
                Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    // lint: allow(clock-discipline) — accept-loop backoff
                    // on a real nonblocking socket.
                    std::thread::sleep(std::time::Duration::from_millis(5));
                }
                Err(_) => {}
            }
        }
    }

    fn handle_conn(&self, mut stream: TcpStream) -> Result<()> {
        // keep-alive loop: serve requests until the peer closes.
        loop {
            let req = match read_request(&mut stream) {
                Ok(Some(r)) => r,
                Ok(None) | Err(_) => return Ok(()),
            };
            let keep_alive = req.keep_alive();
            let resp = self.route(&req);
            stream.write_all(&resp.serialize())?;
            stream.flush()?;
            if !keep_alive {
                return Ok(());
            }
        }
    }

    pub fn route(&self, req: &Request) -> Response {
        match (req.method.as_str(), req.path.as_str()) {
            ("GET", "/healthz") => {
                Response::json(200, &Json::obj(vec![("ok", Json::Bool(true))]))
            }
            ("GET", "/metrics") => {
                Response::json(200, &self.coordinator.metrics.snapshot())
            }
            ("GET", "/models") => match self.coordinator.models_info() {
                Ok(info) => Response::json(200, &info),
                Err(e) => Response::error(500, &e.to_string()),
            },
            ("POST", "/generate") => self.handle_generate(req),
            ("POST", "/score") => self.handle_score(req),
            _ => Response::error(404, "not found"),
        }
    }

    fn handle_generate(&self, req: &Request) -> Response {
        let body = match Json::parse(&req.body_str()) {
            Ok(v) => v,
            Err(e) => return Response::error(400, &format!("bad json: {e}")),
        };
        let gen_req = match GenRequest::from_json(&body) {
            Ok(r) => r,
            Err(e) => return Response::error(400, &e),
        };
        match self.coordinator.generate(gen_req) {
            Ok(resp) => Response::json(200, &resp.to_json()),
            Err(e) => {
                let msg = e.to_string();
                // Admission-backpressure sheds are overload, not server
                // faults: surface 429 so load balancers / retry
                // middleware back off instead of treating the engine as
                // crashed. The shed path is recognized by the shared
                // `SHED_ERROR_SUFFIX` constant (the vendored anyhow shim
                // has no typed variants); client-echoed values in other
                // errors are always single-quoted, so they cannot forge
                // the suffix.
                let status =
                    if msg.ends_with(crate::coordinator::SHED_ERROR_SUFFIX)
                    {
                        429
                    } else {
                        500
                    };
                Response::error(status, &msg)
            }
        }
    }

    fn handle_score(&self, req: &Request) -> Response {
        let body = match Json::parse(&req.body_str()) {
            Ok(v) => v,
            Err(e) => return Response::error(400, &format!("bad json: {e}")),
        };
        let score_req = match ScoreRequest::from_json(&body) {
            Ok(r) => r,
            Err(e) => return Response::error(400, &e),
        };
        match self.coordinator.score(score_req) {
            Ok(resp) => Response::json(200, &resp.to_json()),
            Err(e) => Response::error(500, &e.to_string()),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{BatcherConfig, EngineModel, ModelMap};
    use crate::engine::mock::MockModel;
    use std::collections::BTreeMap;
    use std::time::Duration;

    fn test_server() -> Server {
        let c = Coordinator::start(
            || {
                let mut m: ModelMap = BTreeMap::new();
                m.insert(
                    "mock".into(),
                    Box::new(MockModel::new(8, 4, 5)) as Box<dyn EngineModel>,
                );
                Ok(m)
            },
            BatcherConfig {
                max_wait: Duration::from_millis(1),
                ..Default::default()
            },
        )
        .unwrap();
        Server::new(c)
    }

    fn post(path: &str, body: &str) -> Request {
        Request {
            method: "POST".into(),
            path: path.into(),
            headers: vec![],
            body: body.as_bytes().to_vec(),
        }
    }

    fn get(path: &str) -> Request {
        Request {
            method: "GET".into(),
            path: path.into(),
            headers: vec![],
            body: vec![],
        }
    }

    #[test]
    fn healthz() {
        let s = test_server();
        let r = s.route(&get("/healthz"));
        assert_eq!(r.status, 200);
        assert!(String::from_utf8_lossy(&r.body).contains("true"));
    }

    #[test]
    fn generate_endpoint() {
        let s = test_server();
        let r = s.route(&post("/generate",
                              r#"{"model":"mock","n":2,"seed":3}"#));
        assert_eq!(r.status, 200, "{}", String::from_utf8_lossy(&r.body));
        let v = Json::parse(&String::from_utf8_lossy(&r.body)).unwrap();
        assert_eq!(v.get("samples").unwrap().as_arr().unwrap().len(), 2);
    }

    #[test]
    fn score_endpoint() {
        let s = test_server();
        let r = s.route(&post(
            "/score",
            r#"{"model":"mock","tokens":[0,1,2,3,0,1,2,3],"seed":1,
                "with_posterior":true}"#,
        ));
        assert_eq!(r.status, 200, "{}", String::from_utf8_lossy(&r.body));
        let v = Json::parse(&String::from_utf8_lossy(&r.body)).unwrap();
        assert!(v.get("log_likelihood").unwrap().as_f64().unwrap() < 0.0);
    }

    #[test]
    fn shed_requests_get_429() {
        use crate::coordinator::{QueuePolicy, SchedConfig};
        let mut sched = SchedConfig::default();
        // Depth bound 1 with shed: a 3-sample request can never fit and
        // is rejected deterministically even on an idle engine.
        sched.per_model.insert("mock".into(), QueuePolicy {
            max_pending: 1,
            shed_on_full: true,
            ..QueuePolicy::default()
        });
        let c = Coordinator::start(
            || {
                let mut m: ModelMap = BTreeMap::new();
                m.insert(
                    "mock".into(),
                    Box::new(MockModel::new(8, 4, 5)) as Box<dyn EngineModel>,
                );
                Ok(m)
            },
            BatcherConfig {
                max_wait: Duration::from_millis(1),
                sched,
                ..Default::default()
            },
        )
        .unwrap();
        let s = Server::new(c);
        let r = s.route(&post("/generate", r#"{"model":"mock","n":3}"#));
        assert_eq!(r.status, 429, "{}", String::from_utf8_lossy(&r.body));
        assert!(String::from_utf8_lossy(&r.body).contains("shed"));
        // Within the bound, admission (and the request) still succeeds.
        let ok = s.route(&post("/generate", r#"{"model":"mock","n":1}"#));
        assert_eq!(ok.status, 200, "{}",
                   String::from_utf8_lossy(&ok.body));
    }

    #[test]
    fn generate_accepts_priority_and_exports_preempt_counters() {
        let s = test_server();
        let r = s.route(&post(
            "/generate",
            r#"{"model":"mock","n":1,"priority":5,"seed":4}"#,
        ));
        assert_eq!(r.status, 200, "{}", String::from_utf8_lossy(&r.body));
        let m = s.route(&get("/metrics"));
        let v = Json::parse(&String::from_utf8_lossy(&m.body)).unwrap();
        let counters = v.get("counters").unwrap();
        for key in ["preemptions", "resume_steps", "preempt_fires",
                    "shed_seqs"] {
            assert!(counters.get(key).is_some(), "missing counter {key}");
        }
    }

    #[test]
    fn bad_requests_get_4xx() {
        let s = test_server();
        assert_eq!(s.route(&post("/generate", "{not json")).status, 400);
        assert_eq!(s.route(&post("/generate", r#"{"n":1}"#)).status, 400);
        assert_eq!(s.route(&get("/bogus")).status, 404);
    }

    #[test]
    fn metrics_and_models_endpoints() {
        let s = test_server();
        s.route(&post("/generate", r#"{"model":"mock","n":1}"#));
        let m = s.route(&get("/metrics"));
        assert_eq!(m.status, 200);
        let v = Json::parse(&String::from_utf8_lossy(&m.body)).unwrap();
        assert!(v.get("counters").is_some());
        let models = s.route(&get("/models"));
        assert!(String::from_utf8_lossy(&models.body).contains("seq_len"));
    }

    #[test]
    fn end_to_end_over_tcp() {
        use std::io::{Read, Write};
        let s = test_server();
        let stop = std::sync::Arc::new(
            std::sync::atomic::AtomicBool::new(false));
        let stop2 = stop.clone();
        let addr = "127.0.0.1:39471";
        let handle = std::thread::spawn(move || {
            s.serve_until(addr, move || {
                stop2.load(std::sync::atomic::Ordering::Relaxed)
            })
            .unwrap();
        });
        // lint: allow(clock-discipline) — test waits for a real TCP
        // listener to come up.
        std::thread::sleep(Duration::from_millis(50));
        let mut conn = std::net::TcpStream::connect(addr).unwrap();
        let body = r#"{"model":"mock","n":1}"#;
        write!(
            conn,
            "POST /generate HTTP/1.1\r\nHost: x\r\nContent-Length: {}\r\n\
             Connection: close\r\n\r\n{}",
            body.len(),
            body
        )
        .unwrap();
        let mut out = String::new();
        conn.read_to_string(&mut out).unwrap();
        assert!(out.starts_with("HTTP/1.1 200"), "{out}");
        assert!(out.contains("tokens"), "{out}");
        stop.store(true, std::sync::atomic::Ordering::Relaxed);
        handle.join().unwrap();
    }
}
