//! Threaded HTTP/1.1 server + JSON API (tokio/hyper unavailable offline).
//!
//! Endpoints:
//!   GET  /healthz   -> engine + per-model breaker state (503 when degraded)
//!   GET  /metrics   -> metrics registry snapshot
//!   GET  /models    -> per-model config/buckets
//!   POST /generate  -> run a sampling request (see request::GenRequest)
//!   POST /score     -> exact likelihood + rejection posterior (Prop 3.1/C.2)
//!
//! Failure mapping (see `coordinator` suffix constants): backpressure
//! sheds -> 429, circuit-breaker fast rejections -> 503 + `Retry-After`,
//! deadline expiry -> 504, unknown model -> 404; everything else the
//! engine reports is a 500.

pub mod http;

use std::io::Write;
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Duration;

use anyhow::Result;

use crate::coordinator::{Coordinator, GenRequest, ScoreRequest};
use crate::util::json::Json;
use http::{read_request, Request, Response};

pub struct Server {
    coordinator: Coordinator,
    /// Concurrent-connection budget. Accepts over the cap are answered
    /// 503 + `Retry-After` and closed instead of spawning yet another
    /// thread — an unbounded thread-per-connection accept loop let a
    /// connection flood exhaust the process.
    max_conns: usize,
    /// Per-stream read/write timeout. Without one, an idle keep-alive
    /// peer (or a slow-header client) pinned its thread forever.
    io_timeout: Duration,
    conns: Arc<AtomicUsize>,
}

/// RAII share of the connection budget: decrements the live-connection
/// count when the serving thread finishes, however it exits.
struct ConnPermit {
    conns: Arc<AtomicUsize>,
}

impl Drop for ConnPermit {
    fn drop(&mut self) {
        self.conns.fetch_sub(1, Ordering::SeqCst);
    }
}

impl Server {
    pub fn new(coordinator: Coordinator) -> Server {
        Server {
            coordinator,
            max_conns: 256,
            io_timeout: Duration::from_secs(30),
            conns: Arc::new(AtomicUsize::new(0)),
        }
    }

    /// Override the connection budget and per-stream I/O timeout
    /// (`--max-conns` / `--io-timeout-ms`).
    pub fn with_limits(mut self, max_conns: usize, io_timeout: Duration)
                       -> Server {
        self.max_conns = max_conns;
        self.io_timeout = io_timeout;
        self
    }

    /// Bind and serve forever (thread per connection, budget-capped).
    pub fn serve(self, addr: &str) -> Result<()> {
        let listener = TcpListener::bind(addr)?;
        eprintln!("ssmd serving on http://{addr}");
        let this = Arc::new(self);
        for stream in listener.incoming() {
            match stream {
                Ok(s) => this.accept_one(s),
                Err(_) => continue,
            }
        }
        Ok(())
    }

    /// Serve until `stop` returns true, polling between accepts (tests).
    pub fn serve_until(self, addr: &str,
                       stop: impl Fn() -> bool + Send + 'static)
                       -> Result<()> {
        let listener = TcpListener::bind(addr)?;
        listener.set_nonblocking(true)?;
        let this = Arc::new(self);
        loop {
            if stop() {
                return Ok(());
            }
            match listener.accept() {
                Ok((stream, _)) => {
                    stream.set_nonblocking(false).ok();
                    this.accept_one(stream);
                }
                Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    // lint: allow(clock-discipline) — accept-loop backoff
                    // on a real nonblocking socket.
                    std::thread::sleep(std::time::Duration::from_millis(5));
                }
                Err(_) => {}
            }
        }
    }

    /// Apply stream limits, claim a budget slot, and hand the connection
    /// to its serving thread — or reject it 503 when over the cap.
    fn accept_one(self: &Arc<Self>, stream: TcpStream) {
        stream.set_read_timeout(Some(self.io_timeout)).ok();
        stream.set_write_timeout(Some(self.io_timeout)).ok();
        let prev = self.conns.fetch_add(1, Ordering::SeqCst);
        if prev >= self.max_conns {
            self.conns.fetch_sub(1, Ordering::SeqCst);
            reject_over_capacity(stream);
            return;
        }
        let permit = ConnPermit { conns: self.conns.clone() };
        let srv = self.clone();
        std::thread::spawn(move || {
            let _ = srv.handle_conn(stream, permit);
        });
    }

    fn handle_conn(&self, mut stream: TcpStream, _permit: ConnPermit)
                   -> Result<()> {
        // keep-alive loop: serve requests until the peer closes. `carry`
        // holds read-ahead bytes between pipelined requests.
        let mut carry = Vec::new();
        loop {
            let req = match read_request(&mut stream, &mut carry) {
                Ok(Some(r)) => r,
                Ok(None) => return Ok(()),
                Err(e) if e.kind() == std::io::ErrorKind::InvalidData => {
                    // Unframeable input: tell the client why, then close
                    // (resyncing a corrupt HTTP stream is hopeless).
                    let resp = Response::error(400, &e.to_string())
                        .with_header("Connection", "close".into());
                    let _ = stream.write_all(&resp.serialize());
                    return Ok(());
                }
                // Timeouts and half-finished requests: just drop.
                Err(_) => return Ok(()),
            };
            let keep_alive = req.keep_alive();
            let resp = self.route(&req);
            let resp = if keep_alive {
                resp
            } else {
                resp.with_header("Connection", "close".into())
            };
            stream.write_all(&resp.serialize())?;
            stream.flush()?;
            if !keep_alive {
                return Ok(());
            }
        }
    }

    // lint: serve-region — request handling must never panic a
    // connection thread; a stray unwrap here turns a bad request or an
    // engine fault into a dropped connection instead of an error body.
    pub fn route(&self, req: &Request) -> Response {
        match (req.method.as_str(), req.path.as_str()) {
            ("GET", "/healthz") => self.handle_health(),
            ("GET", "/metrics") => {
                Response::json(200, &self.coordinator.metrics.snapshot())
            }
            ("GET", "/models") => match self.coordinator.models_info() {
                Ok(info) => Response::json(200, &info),
                Err(e) => Response::error(500, &e.to_string()),
            },
            ("POST", "/generate") => self.handle_generate(req),
            ("POST", "/score") => self.handle_score(req),
            _ => Response::error(404, "not found"),
        }
    }

    /// Live health: the engine reports per-model circuit-breaker state.
    /// Any open breaker (or a dead engine thread) degrades the endpoint
    /// to 503 so load balancers rotate traffic away, while the JSON body
    /// still names which models are affected.
    fn handle_health(&self) -> Response {
        match self.coordinator.health() {
            Ok(h) => {
                let ok = h.get("ok").and_then(|b| b.as_bool())
                    .unwrap_or(false);
                Response::json(if ok { 200 } else { 503 }, &h)
            }
            Err(e) => Response::json(
                503,
                &Json::obj(vec![
                    ("ok", Json::Bool(false)),
                    ("error", Json::str(e.to_string())),
                ]),
            ),
        }
    }

    fn handle_generate(&self, req: &Request) -> Response {
        let body = match Json::parse(&req.body_str()) {
            Ok(v) => v,
            Err(e) => return Response::error(400, &format!("bad json: {e}")),
        };
        let gen_req = match GenRequest::from_json(&body) {
            Ok(r) => r,
            Err(e) => return Response::error(400, &e),
        };
        match self.coordinator.generate(gen_req) {
            Ok(resp) => Response::json(200, &resp.to_json()),
            Err(e) => map_engine_error(&e.to_string()),
        }
    }

    fn handle_score(&self, req: &Request) -> Response {
        let body = match Json::parse(&req.body_str()) {
            Ok(v) => v,
            Err(e) => return Response::error(400, &format!("bad json: {e}")),
        };
        let score_req = match ScoreRequest::from_json(&body) {
            Ok(r) => r,
            Err(e) => return Response::error(400, &e),
        };
        match self.coordinator.score(score_req) {
            Ok(resp) => Response::json(200, &resp.to_json()),
            Err(e) => map_engine_error(&e.to_string()),
        }
    }
}

/// Map an engine error string to an HTTP response. The vendored anyhow
/// shim has no typed variants, so the coordinator tags its well-known
/// failure classes with exact message suffixes (client-echoed values are
/// always single-quoted, so they cannot forge a suffix):
///   - `SHED_ERROR_SUFFIX` — admission backpressure. 429 so load
///     balancers / retry middleware back off instead of treating the
///     engine as crashed.
///   - `BREAKER_ERROR_SUFFIX` — circuit breaker open. 503 plus a
///     `Retry-After` header derived from the breaker cooldown.
///   - `DEADLINE_ERROR_SUFFIX` — the request's deadline expired before
///     it finished. 504: the upstream ran out of time, retrying
///     immediately with the same budget will likely time out again.
///   - `unknown model '…'` prefix — 404, a client addressing error.
/// Anything else is an internal fault: 500.
fn map_engine_error(msg: &str) -> Response {
    use crate::coordinator::{
        BREAKER_ERROR_SUFFIX, DEADLINE_ERROR_SUFFIX, SHED_ERROR_SUFFIX,
    };
    if msg.ends_with(SHED_ERROR_SUFFIX) {
        Response::error(429, msg)
    } else if msg.ends_with(BREAKER_ERROR_SUFFIX) {
        Response::error(503, msg)
            .with_header("Retry-After", retry_after_seconds(msg))
    } else if msg.ends_with(DEADLINE_ERROR_SUFFIX) {
        Response::error(504, msg)
    } else if msg.starts_with("unknown model '") {
        Response::error(404, msg)
    } else {
        Response::error(500, msg)
    }
}

/// Pull the `retry after <N>s` hint out of a breaker rejection for the
/// `Retry-After` header. Only a message that actually contains the
/// marker yields a parsed hint — `rsplit(..).next()` returned the whole
/// message when the marker was absent (its `None` arm was dead code), so
/// any rejection that happened to *start* with digits produced a bogus
/// backoff. Falls back to "1": the header must always accompany the 503
/// so well-behaved clients back off a bounded amount.
fn retry_after_seconds(msg: &str) -> String {
    let tail = match msg.rsplit_once("retry after ") {
        Some((_, tail)) => tail,
        None => return "1".to_string(),
    };
    let digits: String =
        tail.chars().take_while(|c| c.is_ascii_digit()).collect();
    if digits.is_empty() { "1".to_string() } else { digits }
}

/// Answer an over-budget accept with a 503 the client can act on, then
/// drop the stream without ever spawning a serving thread for it.
fn reject_over_capacity(mut stream: TcpStream) {
    let resp = Response::error(503, "server at connection capacity")
        .with_header("Retry-After", "1".into())
        .with_header("Connection", "close".into());
    let _ = stream.write_all(&resp.serialize());
}
// lint: end-serve-region

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{BatcherConfig, EngineModel, ModelMap};
    use crate::engine::mock::MockModel;
    use std::collections::BTreeMap;
    use std::time::Duration;

    fn test_server() -> Server {
        let c = Coordinator::start(
            || {
                let mut m: ModelMap = BTreeMap::new();
                m.insert(
                    "mock".into(),
                    Box::new(MockModel::new(8, 4, 5)) as Box<dyn EngineModel>,
                );
                Ok(m)
            },
            BatcherConfig {
                max_wait: Duration::from_millis(1),
                ..Default::default()
            },
        )
        .unwrap();
        Server::new(c)
    }

    /// Two-model server with a fault plan that kills `tiny`'s first step
    /// and a hair-trigger breaker (threshold 1, long cooldown).
    fn chaos_server() -> Server {
        use crate::coordinator::SchedConfig;
        let mut sched = SchedConfig::default();
        sched.supervise.breaker_threshold = 1;
        sched.supervise.breaker_cooldown_s = 100.0;
        let c = Coordinator::start(
            || {
                let mut m: ModelMap = BTreeMap::new();
                m.insert(
                    "mock".into(),
                    Box::new(MockModel::new(8, 4, 5)) as Box<dyn EngineModel>,
                );
                m.insert(
                    "tiny".into(),
                    Box::new(MockModel::new(8, 4, 5)) as Box<dyn EngineModel>,
                );
                Ok(m)
            },
            BatcherConfig {
                max_wait: Duration::from_millis(1),
                sched,
                faults: crate::engine::fault::parse_fault_cli("tiny=panic@1")
                    .unwrap(),
                ..Default::default()
            },
        )
        .unwrap();
        Server::new(c)
    }

    fn post(path: &str, body: &str) -> Request {
        Request {
            method: "POST".into(),
            path: path.into(),
            http10: false,
            headers: vec![],
            body: body.as_bytes().to_vec(),
        }
    }

    fn get(path: &str) -> Request {
        Request {
            method: "GET".into(),
            path: path.into(),
            http10: false,
            headers: vec![],
            body: vec![],
        }
    }

    /// Run a server on `addr` in a background thread until the returned
    /// stop flag is set. Waits for the listener before returning.
    fn spawn_server(s: Server, addr: &'static str)
                    -> (Arc<std::sync::atomic::AtomicBool>,
                        std::thread::JoinHandle<()>) {
        let stop = Arc::new(std::sync::atomic::AtomicBool::new(false));
        let stop2 = stop.clone();
        let handle = std::thread::spawn(move || {
            s.serve_until(addr, move || {
                stop2.load(std::sync::atomic::Ordering::Relaxed)
            })
            .unwrap();
        });
        // lint: allow(clock-discipline) — test waits for a real TCP
        // listener to come up.
        std::thread::sleep(Duration::from_millis(50));
        (stop, handle)
    }

    #[test]
    fn healthz() {
        let s = test_server();
        let r = s.route(&get("/healthz"));
        assert_eq!(r.status, 200);
        assert!(String::from_utf8_lossy(&r.body).contains("true"));
    }

    #[test]
    fn generate_endpoint() {
        let s = test_server();
        let r = s.route(&post("/generate",
                              r#"{"model":"mock","n":2,"seed":3}"#));
        assert_eq!(r.status, 200, "{}", String::from_utf8_lossy(&r.body));
        let v = Json::parse(&String::from_utf8_lossy(&r.body)).unwrap();
        assert_eq!(v.get("samples").unwrap().as_arr().unwrap().len(), 2);
    }

    #[test]
    fn score_endpoint() {
        let s = test_server();
        let r = s.route(&post(
            "/score",
            r#"{"model":"mock","tokens":[0,1,2,3,0,1,2,3],"seed":1,
                "with_posterior":true}"#,
        ));
        assert_eq!(r.status, 200, "{}", String::from_utf8_lossy(&r.body));
        let v = Json::parse(&String::from_utf8_lossy(&r.body)).unwrap();
        assert!(v.get("log_likelihood").unwrap().as_f64().unwrap() < 0.0);
    }

    #[test]
    fn shed_requests_get_429() {
        use crate::coordinator::{QueuePolicy, SchedConfig};
        let mut sched = SchedConfig::default();
        // Depth bound 1 with shed: a 3-sample request can never fit and
        // is rejected deterministically even on an idle engine.
        sched.per_model.insert("mock".into(), QueuePolicy {
            max_pending: 1,
            shed_on_full: true,
            ..QueuePolicy::default()
        });
        let c = Coordinator::start(
            || {
                let mut m: ModelMap = BTreeMap::new();
                m.insert(
                    "mock".into(),
                    Box::new(MockModel::new(8, 4, 5)) as Box<dyn EngineModel>,
                );
                Ok(m)
            },
            BatcherConfig {
                max_wait: Duration::from_millis(1),
                sched,
                ..Default::default()
            },
        )
        .unwrap();
        let s = Server::new(c);
        let r = s.route(&post("/generate", r#"{"model":"mock","n":3}"#));
        assert_eq!(r.status, 429, "{}", String::from_utf8_lossy(&r.body));
        assert!(String::from_utf8_lossy(&r.body).contains("shed"));
        // Within the bound, admission (and the request) still succeeds.
        let ok = s.route(&post("/generate", r#"{"model":"mock","n":1}"#));
        assert_eq!(ok.status, 200, "{}",
                   String::from_utf8_lossy(&ok.body));
    }

    #[test]
    fn generate_accepts_priority_and_exports_preempt_counters() {
        let s = test_server();
        let r = s.route(&post(
            "/generate",
            r#"{"model":"mock","n":1,"priority":5,"seed":4}"#,
        ));
        assert_eq!(r.status, 200, "{}", String::from_utf8_lossy(&r.body));
        let m = s.route(&get("/metrics"));
        let v = Json::parse(&String::from_utf8_lossy(&m.body)).unwrap();
        let counters = v.get("counters").unwrap();
        for key in ["preemptions", "resume_steps", "preempt_fires",
                    "shed_seqs", "engine_faults", "retries",
                    "deadline_sheds", "breaker_state"] {
            assert!(counters.get(key).is_some(), "missing counter {key}");
        }
    }

    #[test]
    fn bad_requests_get_4xx() {
        let s = test_server();
        assert_eq!(s.route(&post("/generate", "{not json")).status, 400);
        assert_eq!(s.route(&post("/generate", r#"{"n":1}"#)).status, 400);
        assert_eq!(s.route(&get("/bogus")).status, 404);
    }

    /// Client mistakes on /generate and /score always get a 4xx with a
    /// JSON error body — never a 500 or a dropped connection.
    #[test]
    fn error_bodies_are_json_4xx() {
        let s = test_server();
        for (path, body, status) in [
            ("/generate", "{not json", 400),
            ("/score", "{not json", 400),
            ("/generate", r#"{"model":"nope","n":1}"#, 404),
            ("/score",
             r#"{"model":"nope","tokens":[0,1,2,3,0,1,2,3]}"#, 404),
            ("/generate", r#"{"model":"mock","n":1,"priority":9999}"#, 400),
            ("/generate", r#"{"model":"mock","n":1,"priority":0.5}"#, 400),
            ("/generate", r#"{"model":"mock","n":1,"deadline_ms":0}"#, 400),
            ("/generate",
             r#"{"model":"mock","n":1,"deadline_ms":"soon"}"#, 400),
        ] {
            let r = s.route(&post(path, body));
            let text = String::from_utf8_lossy(&r.body).to_string();
            assert_eq!(r.status, status, "{path} {body}: {text}");
            let v = Json::parse(&text).unwrap();
            assert!(v.get("error").is_some(),
                    "{path} {body}: error body must be JSON, got {text}");
        }
    }

    /// Pure mapping: each tagged engine-error class gets its status, and
    /// the breaker 503 carries the parsed Retry-After hint.
    #[test]
    fn engine_error_suffixes_map_to_statuses() {
        use crate::coordinator::{
            BREAKER_ERROR_SUFFIX, DEADLINE_ERROR_SUFFIX, SHED_ERROR_SUFFIX,
        };
        assert_eq!(map_engine_error(&format!("x{SHED_ERROR_SUFFIX}")).status,
                   429);
        let r = map_engine_error(&format!(
            "model 'm' unhealthy: circuit breaker open, retry after 7s\
             {BREAKER_ERROR_SUFFIX}"
        ));
        assert_eq!(r.status, 503);
        assert_eq!(r.extra_headers,
                   vec![("Retry-After", "7".to_string())]);
        assert_eq!(
            map_engine_error(&format!("x{DEADLINE_ERROR_SUFFIX}")).status,
            504);
        assert_eq!(map_engine_error("unknown model 'nope'").status, 404);
        assert_eq!(map_engine_error("wat").status, 500);
        // Malformed hint still yields a bounded backoff.
        assert_eq!(retry_after_seconds("no hint here"), "1");
    }

    #[test]
    fn breaker_open_maps_to_503_with_retry_after_and_degraded_health() {
        let s = chaos_server();
        // First request trips tiny's injected panic: a definitive engine
        // fault, surfaced as a 500 with the failure message.
        let r = s.route(&post("/generate", r#"{"model":"tiny","n":1}"#));
        assert_eq!(r.status, 500, "{}", String::from_utf8_lossy(&r.body));
        assert!(String::from_utf8_lossy(&r.body)
                    .contains("failed while serving"));
        // Breaker now open: new admits fast-fail 503 with Retry-After.
        let r = s.route(&post("/generate", r#"{"model":"tiny","n":1}"#));
        assert_eq!(r.status, 503, "{}", String::from_utf8_lossy(&r.body));
        let ra = r
            .extra_headers
            .iter()
            .find(|(k, _)| *k == "Retry-After")
            .map(|(_, v)| v.clone())
            .expect("503 must carry Retry-After");
        assert!(ra.parse::<u64>().unwrap() >= 1, "Retry-After: {ra}");
        // /healthz degrades to 503 and names the open breaker.
        let h = s.route(&get("/healthz"));
        assert_eq!(h.status, 503);
        let v = Json::parse(&String::from_utf8_lossy(&h.body)).unwrap();
        assert_eq!(v.get("ok").and_then(|b| b.as_bool()), Some(false));
        assert_eq!(
            v.get("models").unwrap().get("tiny").and_then(|s| s.as_str()),
            Some("open"));
        // The healthy model keeps serving through the degradation.
        let ok = s.route(&post("/generate", r#"{"model":"mock","n":1}"#));
        assert_eq!(ok.status, 200, "{}",
                   String::from_utf8_lossy(&ok.body));
    }

    #[test]
    fn metrics_and_models_endpoints() {
        let s = test_server();
        s.route(&post("/generate", r#"{"model":"mock","n":1}"#));
        let m = s.route(&get("/metrics"));
        assert_eq!(m.status, 200);
        let v = Json::parse(&String::from_utf8_lossy(&m.body)).unwrap();
        assert!(v.get("counters").is_some());
        let models = s.route(&get("/models"));
        assert!(String::from_utf8_lossy(&models.body).contains("seq_len"));
    }

    #[test]
    fn end_to_end_over_tcp() {
        use std::io::{Read, Write};
        let s = test_server();
        let stop = std::sync::Arc::new(
            std::sync::atomic::AtomicBool::new(false));
        let stop2 = stop.clone();
        let addr = "127.0.0.1:39471";
        let handle = std::thread::spawn(move || {
            s.serve_until(addr, move || {
                stop2.load(std::sync::atomic::Ordering::Relaxed)
            })
            .unwrap();
        });
        // lint: allow(clock-discipline) — test waits for a real TCP
        // listener to come up.
        std::thread::sleep(Duration::from_millis(50));
        let mut conn = std::net::TcpStream::connect(addr).unwrap();
        let body = r#"{"model":"mock","n":1}"#;
        write!(
            conn,
            "POST /generate HTTP/1.1\r\nHost: x\r\nContent-Length: {}\r\n\
             Connection: close\r\n\r\n{}",
            body.len(),
            body
        )
        .unwrap();
        let mut out = String::new();
        conn.read_to_string(&mut out).unwrap();
        assert!(out.starts_with("HTTP/1.1 200"), "{out}");
        assert!(out.contains("tokens"), "{out}");
        stop.store(true, std::sync::atomic::Ordering::Relaxed);
        handle.join().unwrap();
    }

    /// The Retry-After hint is only scraped when the marker is present;
    /// a rejection that merely starts with digits must not leak them.
    #[test]
    fn retry_after_requires_marker() {
        assert_eq!(retry_after_seconds("42 failures, cooling down"), "1");
        assert_eq!(retry_after_seconds("retry after 12s"), "12");
        assert_eq!(retry_after_seconds("retry after soon"), "1");
    }

    /// With a zero connection budget every accept is answered 503 with
    /// Retry-After and Connection: close instead of being served.
    #[test]
    fn connection_budget_rejects_with_503() {
        use std::io::Read;
        let s = test_server().with_limits(0, Duration::from_secs(5));
        let addr = "127.0.0.1:39472";
        let (stop, handle) = spawn_server(s, addr);
        let mut conn = std::net::TcpStream::connect(addr).unwrap();
        conn.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
        let mut out = String::new();
        conn.read_to_string(&mut out).unwrap();
        assert!(out.starts_with("HTTP/1.1 503"), "{out}");
        assert!(out.contains("connection capacity"), "{out}");
        assert!(out.contains("Retry-After: 1"), "{out}");
        assert!(out.contains("Connection: close"), "{out}");
        stop.store(true, std::sync::atomic::Ordering::Relaxed);
        handle.join().unwrap();
    }

    /// An idle keep-alive peer is cut loose once the read timeout fires
    /// instead of pinning its serving thread forever.
    #[test]
    fn idle_connection_times_out() {
        use std::io::Read;
        let s = test_server().with_limits(8, Duration::from_millis(100));
        let addr = "127.0.0.1:39473";
        let (stop, handle) = spawn_server(s, addr);
        let mut conn = std::net::TcpStream::connect(addr).unwrap();
        conn.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
        // Send nothing: the server must hang up on its own.
        let mut buf = [0u8; 16];
        let n = conn.read(&mut buf).unwrap();
        assert_eq!(n, 0, "server must close an idle connection");
        stop.store(true, std::sync::atomic::Ordering::Relaxed);
        handle.join().unwrap();
    }

    /// Two pipelined requests in one write get two responses on the one
    /// connection (the second's bytes used to be truncated away), and
    /// the final response carries Connection: close.
    #[test]
    fn pipelined_requests_over_tcp() {
        use std::io::{Read, Write};
        let s = test_server();
        let addr = "127.0.0.1:39474";
        let (stop, handle) = spawn_server(s, addr);
        let mut conn = std::net::TcpStream::connect(addr).unwrap();
        conn.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
        write!(
            conn,
            "GET /healthz HTTP/1.1\r\n\r\n\
             GET /healthz HTTP/1.1\r\nConnection: close\r\n\r\n"
        )
        .unwrap();
        let mut out = String::new();
        conn.read_to_string(&mut out).unwrap();
        assert_eq!(out.matches("HTTP/1.1 200").count(), 2, "{out}");
        assert!(out.contains("Connection: close"), "{out}");
        stop.store(true, std::sync::atomic::Ordering::Relaxed);
        handle.join().unwrap();
    }

    /// A garbage request line gets a 400 with Connection: close rather
    /// than a silently dropped connection.
    #[test]
    fn bad_request_line_gets_400_and_close() {
        use std::io::{Read, Write};
        let s = test_server();
        let addr = "127.0.0.1:39475";
        let (stop, handle) = spawn_server(s, addr);
        let mut conn = std::net::TcpStream::connect(addr).unwrap();
        conn.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
        write!(conn, "GARBAGE\r\n\r\n").unwrap();
        let mut out = String::new();
        conn.read_to_string(&mut out).unwrap();
        assert!(out.starts_with("HTTP/1.1 400"), "{out}");
        assert!(out.contains("Connection: close"), "{out}");
        stop.store(true, std::sync::atomic::Ordering::Relaxed);
        handle.join().unwrap();
    }
}
