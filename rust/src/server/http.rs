//! Minimal HTTP/1.1 message parsing and serialization.
//!
//! Supports what the API needs: request line, headers, Content-Length
//! bodies, keep-alive. Not a general server — no chunked encoding, no TLS.

use std::io::Read;
use std::net::TcpStream;

use crate::util::json::Json;

#[derive(Debug, Clone)]
pub struct Request {
    pub method: String,
    pub path: String,
    pub headers: Vec<(String, String)>,
    pub body: Vec<u8>,
}

impl Request {
    pub fn header(&self, name: &str) -> Option<&str> {
        self.headers
            .iter()
            .find(|(k, _)| k.eq_ignore_ascii_case(name))
            .map(|(_, v)| v.as_str())
    }

    pub fn keep_alive(&self) -> bool {
        !matches!(self.header("connection"),
                  Some(v) if v.eq_ignore_ascii_case("close"))
    }

    pub fn body_str(&self) -> String {
        String::from_utf8_lossy(&self.body).to_string()
    }
}

#[derive(Debug)]
pub struct Response {
    pub status: u16,
    pub content_type: &'static str,
    pub body: Vec<u8>,
    /// Extra response headers (e.g. `Retry-After` on 503s). Names are
    /// static — the API only emits a fixed vocabulary of headers.
    pub extra_headers: Vec<(&'static str, String)>,
}

impl Response {
    pub fn json(status: u16, v: &Json) -> Response {
        Response {
            status,
            content_type: "application/json",
            body: v.to_string().into_bytes(),
            extra_headers: Vec::new(),
        }
    }

    pub fn error(status: u16, msg: &str) -> Response {
        Response::json(
            status,
            &Json::obj(vec![("error", Json::str(msg.to_string()))]),
        )
    }

    /// Builder: attach an extra header.
    pub fn with_header(mut self, name: &'static str, value: String)
                       -> Response {
        self.extra_headers.push((name, value));
        self
    }

    pub fn serialize(&self) -> Vec<u8> {
        let reason = match self.status {
            200 => "OK",
            400 => "Bad Request",
            404 => "Not Found",
            429 => "Too Many Requests",
            500 => "Internal Server Error",
            503 => "Service Unavailable",
            504 => "Gateway Timeout",
            _ => "Status",
        };
        let mut head = format!(
            "HTTP/1.1 {} {}\r\nContent-Type: {}\r\nContent-Length: {}\r\n",
            self.status,
            reason,
            self.content_type,
            self.body.len()
        );
        for (k, v) in &self.extra_headers {
            head.push_str(&format!("{k}: {v}\r\n"));
        }
        head.push_str("\r\n");
        let mut out = head.into_bytes();
        out.extend_from_slice(&self.body);
        out
    }
}

/// Parse one request from a stream. Returns Ok(None) on clean EOF.
pub fn read_request(stream: &mut TcpStream)
                    -> std::io::Result<Option<Request>> {
    let mut buf = Vec::new();
    let mut tmp = [0u8; 4096];
    // Read until the header terminator.
    let header_end = loop {
        if let Some(pos) = find_subslice(&buf, b"\r\n\r\n") {
            break pos;
        }
        let n = stream.read(&mut tmp)?;
        if n == 0 {
            if buf.is_empty() {
                return Ok(None);
            }
            return Err(std::io::Error::new(
                std::io::ErrorKind::UnexpectedEof,
                "eof in headers",
            ));
        }
        buf.extend_from_slice(&tmp[..n]);
        if buf.len() > 1 << 20 {
            return Err(std::io::Error::new(
                std::io::ErrorKind::InvalidData,
                "headers too large",
            ));
        }
    };

    let header_text = String::from_utf8_lossy(&buf[..header_end]).to_string();
    let mut lines = header_text.split("\r\n");
    let request_line = lines.next().unwrap_or_default();
    let mut parts = request_line.split_whitespace();
    let method = parts.next().unwrap_or_default().to_string();
    let path = parts.next().unwrap_or_default().to_string();
    if method.is_empty() || path.is_empty() {
        return Err(std::io::Error::new(
            std::io::ErrorKind::InvalidData,
            "bad request line",
        ));
    }
    let headers: Vec<(String, String)> = lines
        .filter_map(|l| {
            l.split_once(':')
                .map(|(k, v)| (k.trim().to_string(), v.trim().to_string()))
        })
        .collect();
    let content_length: usize = headers
        .iter()
        .find(|(k, _)| k.eq_ignore_ascii_case("content-length"))
        .and_then(|(_, v)| v.parse().ok())
        .unwrap_or(0);

    let mut body = buf[header_end + 4..].to_vec();
    while body.len() < content_length {
        let n = stream.read(&mut tmp)?;
        if n == 0 {
            return Err(std::io::Error::new(
                std::io::ErrorKind::UnexpectedEof,
                "eof in body",
            ));
        }
        body.extend_from_slice(&tmp[..n]);
    }
    body.truncate(content_length);
    Ok(Some(Request { method, path, headers, body }))
}

fn find_subslice(haystack: &[u8], needle: &[u8]) -> Option<usize> {
    haystack
        .windows(needle.len())
        .position(|w| w == needle)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn response_serializes() {
        let r = Response::json(200, &Json::obj(vec![("a", Json::num(1.0))]));
        let s = String::from_utf8(r.serialize()).unwrap();
        assert!(s.starts_with("HTTP/1.1 200 OK\r\n"));
        assert!(s.contains("Content-Length: 7"));
        assert!(s.ends_with("{\"a\":1}"));
    }

    #[test]
    fn extra_headers_serialize_before_the_body() {
        let r = Response::error(503, "unavailable")
            .with_header("Retry-After", "2".into());
        let s = String::from_utf8(r.serialize()).unwrap();
        assert!(s.starts_with("HTTP/1.1 503 Service Unavailable\r\n"), "{s}");
        assert!(s.contains("Retry-After: 2\r\n"), "{s}");
        let head_end = s.find("\r\n\r\n").unwrap();
        assert!(s[..head_end].contains("Retry-After"),
                "header must be in the head, not the body");
    }

    #[test]
    fn error_has_json_body() {
        let r = Response::error(400, "nope");
        assert_eq!(r.status, 400);
        assert!(String::from_utf8(r.body).unwrap().contains("nope"));
    }

    #[test]
    fn find_subslice_works() {
        assert_eq!(find_subslice(b"abcd", b"cd"), Some(2));
        assert_eq!(find_subslice(b"abcd", b"xy"), None);
        assert_eq!(find_subslice(b"", b"x"), None);
    }

    #[test]
    fn request_header_lookup_case_insensitive() {
        let r = Request {
            method: "GET".into(),
            path: "/".into(),
            headers: vec![("Content-Type".into(), "text/plain".into())],
            body: vec![],
        };
        assert_eq!(r.header("content-type"), Some("text/plain"));
        assert!(r.keep_alive());
    }
}
