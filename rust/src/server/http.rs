//! Minimal HTTP/1.1 message parsing and serialization.
//!
//! Supports what the API needs: request line, headers, Content-Length
//! bodies, keep-alive (HTTP/1.0 default-close honored), pipelining (bytes
//! past one request's body carry over to the next parse). Not a general
//! server — no chunked encoding, no TLS.

use std::io::Read;

use crate::util::json::Json;

#[derive(Debug, Clone)]
pub struct Request {
    pub method: String,
    pub path: String,
    /// True when the request line announced HTTP/1.0, whose default
    /// (absent a `Connection` header) is close-after-response — the
    /// opposite of HTTP/1.1's keep-alive default.
    pub http10: bool,
    pub headers: Vec<(String, String)>,
    pub body: Vec<u8>,
}

impl Request {
    pub fn header(&self, name: &str) -> Option<&str> {
        self.headers
            .iter()
            .find(|(k, _)| k.eq_ignore_ascii_case(name))
            .map(|(_, v)| v.as_str())
    }

    /// Whether the connection survives this exchange. An explicit
    /// `Connection` header always wins; otherwise the version default
    /// applies (HTTP/1.1 keep-alive, HTTP/1.0 close).
    pub fn keep_alive(&self) -> bool {
        match self.header("connection") {
            Some(v) if v.eq_ignore_ascii_case("close") => false,
            Some(v) if v.eq_ignore_ascii_case("keep-alive") => true,
            _ => !self.http10,
        }
    }

    pub fn body_str(&self) -> String {
        String::from_utf8_lossy(&self.body).to_string()
    }
}

#[derive(Debug)]
pub struct Response {
    pub status: u16,
    pub content_type: &'static str,
    pub body: Vec<u8>,
    /// Extra response headers (e.g. `Retry-After` on 503s). Names are
    /// static — the API only emits a fixed vocabulary of headers.
    pub extra_headers: Vec<(&'static str, String)>,
}

impl Response {
    pub fn json(status: u16, v: &Json) -> Response {
        Response {
            status,
            content_type: "application/json",
            body: v.to_string().into_bytes(),
            extra_headers: Vec::new(),
        }
    }

    pub fn error(status: u16, msg: &str) -> Response {
        Response::json(
            status,
            &Json::obj(vec![("error", Json::str(msg.to_string()))]),
        )
    }

    /// Builder: attach an extra header.
    pub fn with_header(mut self, name: &'static str, value: String)
                       -> Response {
        self.extra_headers.push((name, value));
        self
    }

    pub fn serialize(&self) -> Vec<u8> {
        let reason = match self.status {
            200 => "OK",
            400 => "Bad Request",
            404 => "Not Found",
            429 => "Too Many Requests",
            500 => "Internal Server Error",
            503 => "Service Unavailable",
            504 => "Gateway Timeout",
            _ => "Status",
        };
        let mut head = format!(
            "HTTP/1.1 {} {}\r\nContent-Type: {}\r\nContent-Length: {}\r\n",
            self.status,
            reason,
            self.content_type,
            self.body.len()
        );
        for (k, v) in &self.extra_headers {
            head.push_str(&format!("{k}: {v}\r\n"));
        }
        head.push_str("\r\n");
        let mut out = head.into_bytes();
        out.extend_from_slice(&self.body);
        out
    }
}

/// Parse one request from a stream. Returns Ok(None) on clean EOF.
///
/// `carry` is the connection's read-ahead buffer: bytes past this
/// request's body (a pipelined next request) are left in it for the next
/// call, which consumes them before touching the stream again.
/// Historically those bytes were silently truncated away, so the second
/// of two pipelined keep-alive requests hung until the client sent more
/// data. The caller owns one `carry` per connection.
pub fn read_request<R: Read>(stream: &mut R, carry: &mut Vec<u8>)
                             -> std::io::Result<Option<Request>> {
    let mut buf = std::mem::take(carry);
    let mut tmp = [0u8; 4096];
    // Read until the header terminator (read-ahead bytes first).
    let header_end = loop {
        if let Some(pos) = find_subslice(&buf, b"\r\n\r\n") {
            break pos;
        }
        if buf.len() > 1 << 20 {
            return Err(std::io::Error::new(
                std::io::ErrorKind::InvalidData,
                "headers too large",
            ));
        }
        let n = stream.read(&mut tmp)?;
        if n == 0 {
            if buf.is_empty() {
                return Ok(None);
            }
            return Err(std::io::Error::new(
                std::io::ErrorKind::UnexpectedEof,
                "eof in headers",
            ));
        }
        buf.extend_from_slice(&tmp[..n]);
    };

    let header_text = String::from_utf8_lossy(&buf[..header_end]).to_string();
    let mut lines = header_text.split("\r\n");
    let request_line = lines.next().unwrap_or_default();
    let mut parts = request_line.split_whitespace();
    let method = parts.next().unwrap_or_default().to_string();
    let path = parts.next().unwrap_or_default().to_string();
    let http10 = parts
        .next()
        .is_some_and(|v| v.eq_ignore_ascii_case("HTTP/1.0"));
    if method.is_empty() || path.is_empty() {
        return Err(std::io::Error::new(
            std::io::ErrorKind::InvalidData,
            "bad request line",
        ));
    }
    let headers: Vec<(String, String)> = lines
        .filter_map(|l| {
            l.split_once(':')
                .map(|(k, v)| (k.trim().to_string(), v.trim().to_string()))
        })
        .collect();
    let content_length: usize = headers
        .iter()
        .find(|(k, _)| k.eq_ignore_ascii_case("content-length"))
        .and_then(|(_, v)| v.parse().ok())
        .unwrap_or(0);

    let body_start = header_end + 4;
    while buf.len() < body_start + content_length {
        let n = stream.read(&mut tmp)?;
        if n == 0 {
            // Content-Length promised more bytes than the peer sent:
            // a framing mismatch, not a clean close.
            return Err(std::io::Error::new(
                std::io::ErrorKind::UnexpectedEof,
                "eof in body",
            ));
        }
        buf.extend_from_slice(&tmp[..n]);
    }
    // Everything past this request's body belongs to the next one.
    *carry = buf.split_off(body_start + content_length);
    let body = buf.split_off(body_start);
    Ok(Some(Request { method, path, http10, headers, body }))
}

fn find_subslice(haystack: &[u8], needle: &[u8]) -> Option<usize> {
    haystack
        .windows(needle.len())
        .position(|w| w == needle)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn response_serializes() {
        let r = Response::json(200, &Json::obj(vec![("a", Json::num(1.0))]));
        let s = String::from_utf8(r.serialize()).unwrap();
        assert!(s.starts_with("HTTP/1.1 200 OK\r\n"));
        assert!(s.contains("Content-Length: 7"));
        assert!(s.ends_with("{\"a\":1}"));
    }

    #[test]
    fn extra_headers_serialize_before_the_body() {
        let r = Response::error(503, "unavailable")
            .with_header("Retry-After", "2".into());
        let s = String::from_utf8(r.serialize()).unwrap();
        assert!(s.starts_with("HTTP/1.1 503 Service Unavailable\r\n"), "{s}");
        assert!(s.contains("Retry-After: 2\r\n"), "{s}");
        let head_end = s.find("\r\n\r\n").unwrap();
        assert!(s[..head_end].contains("Retry-After"),
                "header must be in the head, not the body");
    }

    #[test]
    fn error_has_json_body() {
        let r = Response::error(400, "nope");
        assert_eq!(r.status, 400);
        assert!(String::from_utf8(r.body).unwrap().contains("nope"));
    }

    #[test]
    fn find_subslice_works() {
        assert_eq!(find_subslice(b"abcd", b"cd"), Some(2));
        assert_eq!(find_subslice(b"abcd", b"xy"), None);
        assert_eq!(find_subslice(b"", b"x"), None);
    }

    #[test]
    fn request_header_lookup_case_insensitive() {
        let r = Request {
            method: "GET".into(),
            path: "/".into(),
            http10: false,
            headers: vec![("Content-Type".into(), "text/plain".into())],
            body: vec![],
        };
        assert_eq!(r.header("content-type"), Some("text/plain"));
        assert!(r.keep_alive());
    }

    fn req(raw: &[u8], carry: &mut Vec<u8>)
           -> std::io::Result<Option<Request>> {
        let mut cursor = raw;
        read_request(&mut cursor, carry)
    }

    #[test]
    fn parses_request_line_version_and_body() {
        let mut carry = Vec::new();
        let r = req(
            b"POST /generate HTTP/1.1\r\nContent-Length: 4\r\n\r\nabcd",
            &mut carry,
        )
        .unwrap()
        .unwrap();
        assert_eq!(r.method, "POST");
        assert_eq!(r.path, "/generate");
        assert!(!r.http10);
        assert_eq!(r.body, b"abcd");
        assert!(r.keep_alive(), "HTTP/1.1 defaults to keep-alive");
        assert!(carry.is_empty());
    }

    /// HTTP/1.0 default is close-after-response; an explicit
    /// `Connection: keep-alive` opts back in. HTTP/1.1 is the reverse.
    #[test]
    fn http10_defaults_to_close() {
        let mut carry = Vec::new();
        let r = req(b"GET / HTTP/1.0\r\n\r\n", &mut carry)
            .unwrap()
            .unwrap();
        assert!(r.http10);
        assert!(!r.keep_alive(), "HTTP/1.0 without Connection must close");
        let r = req(
            b"GET / HTTP/1.0\r\nConnection: keep-alive\r\n\r\n",
            &mut carry,
        )
        .unwrap()
        .unwrap();
        assert!(r.keep_alive(), "explicit keep-alive overrides the default");
        let r = req(
            b"GET / HTTP/1.1\r\nConnection: close\r\n\r\n",
            &mut carry,
        )
        .unwrap()
        .unwrap();
        assert!(!r.keep_alive());
    }

    /// Two pipelined requests on one connection: the bytes of the second
    /// must survive in `carry` (they were historically truncated away)
    /// and parse without touching the stream again.
    #[test]
    fn pipelined_requests_carry_over() {
        let mut carry = Vec::new();
        let raw = b"POST /a HTTP/1.1\r\nContent-Length: 3\r\n\r\nxyz\
                    GET /b HTTP/1.1\r\n\r\n";
        let mut cursor = &raw[..];
        let first = read_request(&mut cursor, &mut carry)
            .unwrap()
            .unwrap();
        assert_eq!(first.path, "/a");
        assert_eq!(first.body, b"xyz");
        assert!(carry.starts_with(b"GET /b"), "read-ahead must be kept");
        // The stream is at EOF; the second request parses from carry.
        let second = read_request(&mut cursor, &mut carry)
            .unwrap()
            .unwrap();
        assert_eq!(second.path, "/b");
        assert!(second.body.is_empty());
        assert!(carry.is_empty());
        // Third call: clean EOF.
        assert!(read_request(&mut cursor, &mut carry).unwrap().is_none());
    }

    /// Headers that never terminate within the 1 MiB bound are rejected
    /// as InvalidData (the caller answers 400), not read forever.
    #[test]
    fn oversized_headers_rejected() {
        let mut raw = b"GET / HTTP/1.1\r\n".to_vec();
        raw.extend(std::iter::repeat(b'x').take((1 << 20) + 16));
        let mut carry = Vec::new();
        let err = req(&raw, &mut carry).unwrap_err();
        assert_eq!(err.kind(), std::io::ErrorKind::InvalidData);
        assert!(err.to_string().contains("headers too large"));
    }

    /// Content-Length larger than the bytes actually sent is a framing
    /// mismatch: UnexpectedEof, never a short body passed to a handler.
    #[test]
    fn content_length_mismatch_is_unexpected_eof() {
        let mut carry = Vec::new();
        let err = req(
            b"POST /a HTTP/1.1\r\nContent-Length: 10\r\n\r\nabc",
            &mut carry,
        )
        .unwrap_err();
        assert_eq!(err.kind(), std::io::ErrorKind::UnexpectedEof);
        assert!(err.to_string().contains("eof in body"));
    }
}
