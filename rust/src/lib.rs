//! # ssmd — Self-Speculative Masked Diffusions
//!
//! A three-layer reproduction of *Self-Speculative Masked Diffusions*
//! (Campbell et al., 2025):
//!
//! * **L3 (this crate)** — the serving coordinator: the paper's speculative
//!   sampling algorithms (Alg. 1–3), window schedules (App. D), exact
//!   likelihood recursions (Prop. 3.1 / C.2), NFE accounting, a dynamic
//!   batcher with batch-size buckets, and a threaded HTTP server. Rust owns
//!   the entire request path.
//! * **L2/L1 (python/, build time only)** — the hybrid non-causal / causal
//!   transformer in JAX with a Pallas fused-attention kernel, trained on
//!   synthetic corpora and AOT-lowered to HLO text artifacts.
//! * **runtime** — a PJRT wrapper (via the `xla` crate) that loads
//!   `artifacts/*.hlo.txt` and executes them on the request path.
//!
//! Offline-substrate note: tokio / serde / clap / criterion / proptest are
//! unavailable in this environment, so `util` contains from-scratch
//! equivalents (threaded server, JSON codec, arg parser, bench-lite,
//! property-test helper) — see DESIGN.md §2.

pub mod coordinator;
pub mod engine;
pub mod harness;
pub mod flops;
pub mod lint;
pub mod likelihood;
pub mod oracle;
pub mod runtime;
pub mod server;
pub mod sim;
pub mod util;
