//! Engine supervision policy: bounded retries with Clock-driven backoff
//! and a per-model circuit breaker.
//!
//! Pure state machines — no threads, no wall clock. Every transition is
//! driven by an explicit `now: f64` argument read from the caller's
//! injected `Clock`, so the virtual-time chaos sim replays supervision
//! decisions (backoff windows, breaker cooldowns) deterministically.
//!
//! Semantics (README §"Failure semantics"):
//! * A **transient** step failure retries after `backoff_s · mult^(k-1)`
//!   seconds (k = 1-based retry index), at most `max_retries` times per
//!   failure burst; a successful step resets the burst.
//! * A **fatal** failure, or a burst exhausting its retries, quarantines
//!   the run queue and records one failure on the model's breaker.
//! * `breaker_threshold` consecutive failures open the breaker: new
//!   admissions for that model fail fast (503 at the HTTP layer) without
//!   touching the engine. After `breaker_cooldown_s` the breaker
//!   half-opens: the next admission goes through as a probe; a
//!   subsequent engine success closes the breaker, another failure
//!   re-opens it for a fresh cooldown.

/// Supervision knobs, carried on `SchedConfig` so the engine loop, CLI,
/// and sim all share one source of truth.
#[derive(Clone, Debug, PartialEq)]
pub struct SupervisePolicy {
    /// Retries per transient-failure burst before quarantining.
    pub max_retries: u32,
    /// Base backoff before the first retry, seconds.
    pub backoff_s: f64,
    /// Multiplier on each subsequent retry's backoff.
    pub backoff_mult: f64,
    /// Consecutive model failures that open the breaker.
    pub breaker_threshold: u32,
    /// Seconds an open breaker waits before half-opening.
    pub breaker_cooldown_s: f64,
}

impl Default for SupervisePolicy {
    fn default() -> Self {
        SupervisePolicy {
            max_retries: 2,
            backoff_s: 0.05,
            backoff_mult: 2.0,
            breaker_threshold: 3,
            breaker_cooldown_s: 1.0,
        }
    }
}

impl SupervisePolicy {
    /// Backoff before retry `k` (1-based) of a burst.
    pub fn backoff_for(&self, k: u32) -> f64 {
        self.backoff_s * self.backoff_mult.powi(k.saturating_sub(1) as i32)
    }
}

/// Externally-observable breaker state (exported via `/healthz`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BreakerState {
    /// Healthy: admissions flow.
    Closed,
    /// Tripped: admissions fail fast until the cooldown elapses.
    Open,
    /// Cooldown elapsed: admissions probe the engine; the next recorded
    /// success closes, the next failure re-opens.
    HalfOpen,
}

impl BreakerState {
    pub fn as_str(&self) -> &'static str {
        match self {
            BreakerState::Closed => "closed",
            BreakerState::Open => "open",
            BreakerState::HalfOpen => "half-open",
        }
    }
}

/// Per-model circuit breaker. Time never advances internally: `state`
/// derives Open vs HalfOpen lazily from `now`, so an idle breaker
/// half-opens exactly when the next admission looks at it.
#[derive(Clone, Debug)]
pub struct Breaker {
    threshold: u32,
    cooldown_s: f64,
    consecutive_failures: u32,
    /// Set when the breaker trips; `None` while closed.
    opened_at: Option<f64>,
}

impl Breaker {
    pub fn new(policy: &SupervisePolicy) -> Breaker {
        Breaker {
            threshold: policy.breaker_threshold.max(1),
            cooldown_s: policy.breaker_cooldown_s,
            consecutive_failures: 0,
            opened_at: None,
        }
    }

    pub fn state(&self, now: f64) -> BreakerState {
        match self.opened_at {
            None => BreakerState::Closed,
            Some(t) if now - t >= self.cooldown_s => BreakerState::HalfOpen,
            Some(_) => BreakerState::Open,
        }
    }

    /// Whether a new admission may proceed at `now` (Closed, or a
    /// HalfOpen probe).
    pub fn admit_allowed(&self, now: f64) -> bool {
        self.state(now) != BreakerState::Open
    }

    /// Seconds until the breaker half-opens (`Retry-After` hint); 0 when
    /// not Open.
    pub fn retry_after_s(&self, now: f64) -> f64 {
        match self.opened_at {
            Some(t) if self.state(now) == BreakerState::Open => {
                (t + self.cooldown_s - now).max(0.0)
            }
            _ => 0.0,
        }
    }

    /// Record a definitive model failure (fatal step, or a transient
    /// burst that exhausted its retries).
    pub fn record_failure(&mut self, now: f64) {
        match self.state(now) {
            // A half-open probe failing re-opens for a fresh cooldown.
            BreakerState::HalfOpen => self.opened_at = Some(now),
            BreakerState::Open => {}
            BreakerState::Closed => {
                self.consecutive_failures += 1;
                if self.consecutive_failures >= self.threshold {
                    self.opened_at = Some(now);
                }
            }
        }
    }

    /// Record a successful engine step for this model.
    pub fn record_success(&mut self, now: f64) {
        // A success while Open can only come from work admitted before
        // the trip; it proves the model lives, so close either way.
        let _ = now;
        self.consecutive_failures = 0;
        self.opened_at = None;
    }
}

/// Replica-level supervisor: decides whether a dead engine thread is
/// respawned and after how long. Pure state like [`Breaker`] — the
/// sharded coordinator's supervisor thread and the fleet sim both drive
/// it, one in wall time, one in virtual time.
///
/// Each replica gets a bounded restart budget (`max_retries` from the
/// shared [`SupervisePolicy`]); each accepted exit backs off
/// geometrically via [`SupervisePolicy::backoff_for`] before the
/// respawn. A replica that exhausts its budget stays Down permanently —
/// the router routes around it and brown-out only fires when every
/// replica is gone.
#[derive(Clone, Debug)]
pub struct ReplicaSupervisor {
    policy: SupervisePolicy,
    restarts: Vec<u32>,
    /// Marked when the supervisor gives up on a replica for good — a
    /// declined exit (budget exhausted) or a failed respawn. Once every
    /// replica is marked, no engine thread will ever run again and the
    /// fleet must drain its shared state (see `drain_dead_fleet`).
    gone: Vec<bool>,
}

impl ReplicaSupervisor {
    pub fn new(n_replicas: usize, policy: SupervisePolicy) -> Self {
        ReplicaSupervisor {
            policy,
            restarts: vec![0; n_replicas],
            gone: vec![false; n_replicas],
        }
    }

    /// Restart budget per replica (how many respawns are allowed).
    pub fn budget(&self) -> u32 {
        self.policy.max_retries
    }

    /// An engine thread for replica `e` exited. Returns
    /// `Some(backoff_s)` — wait that long, then respawn — while the
    /// replica has budget left; `None` once the budget is exhausted
    /// (leave it Down).
    pub fn on_exit(&mut self, e: usize) -> Option<f64> {
        let k = match self.restarts.get_mut(e) {
            Some(k) => k,
            None => return None,
        };
        if *k >= self.policy.max_retries {
            return None;
        }
        *k += 1;
        Some(self.policy.backoff_for(*k))
    }

    /// Respawns granted so far for replica `e`.
    pub fn restarts_of(&self, e: usize) -> u32 {
        self.restarts.get(e).copied().unwrap_or(0)
    }

    /// Record that replica `e` is permanently down: its exit was
    /// declined ([`Self::on_exit`] returned `None`) or its respawn
    /// factory failed. Idempotent.
    pub fn mark_gone(&mut self, e: usize) {
        if let Some(g) = self.gone.get_mut(e) {
            *g = true;
        }
    }

    /// Whether replica `e` was marked permanently down.
    pub fn is_gone(&self, e: usize) -> bool {
        self.gone.get(e).copied().unwrap_or(false)
    }

    /// Every replica is permanently down: no engine thread exists or
    /// will ever be respawned. The caller must drain shared fleet state
    /// (migration board, evacuation records) — nobody else ever will.
    pub fn all_gone(&self) -> bool {
        self.gone.iter().all(|&g| g)
    }

    /// Respawns granted so far across the fleet.
    pub fn total_restarts(&self) -> u64 {
        self.restarts.iter().map(|&k| k as u64).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn policy() -> SupervisePolicy {
        SupervisePolicy {
            max_retries: 2,
            backoff_s: 0.1,
            backoff_mult: 2.0,
            breaker_threshold: 3,
            breaker_cooldown_s: 5.0,
        }
    }

    #[test]
    fn backoff_grows_geometrically() {
        let p = policy();
        assert!((p.backoff_for(1) - 0.1).abs() < 1e-12);
        assert!((p.backoff_for(2) - 0.2).abs() < 1e-12);
        assert!((p.backoff_for(3) - 0.4).abs() < 1e-12);
    }

    #[test]
    fn breaker_opens_after_threshold_and_half_opens_after_cooldown() {
        let mut b = Breaker::new(&policy());
        assert_eq!(b.state(0.0), BreakerState::Closed);
        b.record_failure(1.0);
        b.record_failure(2.0);
        assert_eq!(b.state(2.0), BreakerState::Closed);
        assert!(b.admit_allowed(2.0));
        b.record_failure(3.0);
        assert_eq!(b.state(3.0), BreakerState::Open);
        assert!(!b.admit_allowed(3.0));
        assert!((b.retry_after_s(4.0) - 4.0).abs() < 1e-12);
        // Cooldown elapses lazily: same breaker, later clock.
        assert_eq!(b.state(8.0), BreakerState::HalfOpen);
        assert!(b.admit_allowed(8.0));
        assert_eq!(b.retry_after_s(8.0), 0.0);
    }

    #[test]
    fn half_open_probe_outcome_closes_or_reopens() {
        let mut b = Breaker::new(&policy());
        for t in 0..3 {
            b.record_failure(t as f64);
        }
        assert_eq!(b.state(10.0), BreakerState::HalfOpen);
        // Probe fails: re-open with a fresh cooldown window.
        b.record_failure(10.0);
        assert_eq!(b.state(11.0), BreakerState::Open);
        assert_eq!(b.state(15.0), BreakerState::HalfOpen);
        // Probe succeeds: fully closed, failure count reset.
        b.record_success(15.0);
        assert_eq!(b.state(15.0), BreakerState::Closed);
        b.record_failure(16.0);
        assert_eq!(b.state(16.0), BreakerState::Closed,
                   "one failure after close must not trip");
    }

    #[test]
    fn replica_supervisor_backs_off_geometrically_within_budget() {
        let mut s = ReplicaSupervisor::new(2, policy());
        assert_eq!(s.budget(), 2);
        // First exit of replica 1: first backoff step.
        let d1 = s.on_exit(1).expect("budget available");
        assert!((d1 - 0.1).abs() < 1e-12);
        // Second exit: doubled backoff; budget now exhausted.
        let d2 = s.on_exit(1).expect("budget available");
        assert!((d2 - 0.2).abs() < 1e-12);
        assert_eq!(s.on_exit(1), None, "budget of 2 exhausted");
        assert_eq!(s.restarts_of(1), 2);
        // Budgets are per replica: replica 0 is untouched.
        assert!(s.on_exit(0).is_some());
        assert_eq!(s.total_restarts(), 3);
        // Out-of-range replica ids never respawn.
        assert_eq!(s.on_exit(7), None);
    }

    #[test]
    fn gone_marks_accumulate_until_all_gone() {
        let mut s = ReplicaSupervisor::new(2, policy());
        assert!(!s.all_gone(), "fresh fleet is not gone");
        s.mark_gone(0);
        assert!(s.is_gone(0));
        assert!(!s.is_gone(1));
        assert!(!s.all_gone(), "one survivor keeps the fleet alive");
        s.mark_gone(0); // idempotent
        s.mark_gone(1);
        assert!(s.all_gone(), "every replica marked: fleet is gone");
        // Out-of-range marks are ignored, not panics.
        s.mark_gone(9);
        assert!(!s.is_gone(9));
    }

    #[test]
    fn success_resets_consecutive_failures() {
        let mut b = Breaker::new(&policy());
        b.record_failure(0.0);
        b.record_failure(1.0);
        b.record_success(2.0);
        b.record_failure(3.0);
        b.record_failure(4.0);
        assert_eq!(b.state(4.0), BreakerState::Closed);
    }
}
