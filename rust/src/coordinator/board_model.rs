//! Bounded exhaustive model checker for the migration-board protocol
//! (kill → evacuate → adopt / supervised restart / board poisoning) — a
//! hand-rolled mini-loom in the style of
//! [`engine::pool_model`](crate::engine::pool_model).
//!
//! ## Why critical-section granularity is sound
//!
//! Every shared structure of the protocol is touched only inside one
//! short critical section at a time: the board is a `Mutex<Vec<Migrant>>`
//! whose operations are a single push (`post`), a prefix drain (`take`),
//! or a whole-vec take (`take_all`); liveness state lives behind its own
//! `Mutex` with single-call sections (`beat`, `state`, `mark_restarting`);
//! and the supervisor serializes replica exits through one mpsc channel,
//! processing them strictly in arrival order on a single thread. Any real
//! execution is therefore a serialization of these atomic sections, and
//! exhaustively interleaving them — one transition per section — covers
//! every behavior of the real protocol up to the model's bounds.
//!
//! The model does **not** re-implement the two decision procedures it
//! pins. Liveness verdicts come from the production [`Liveness`] struct
//! (rebuilt from the state's beat ticks each check, so the strict
//! `now - beat > timeout` threshold is the production code path), and
//! every supervisor decision replays the production
//! [`ReplicaSupervisor`] (reconstructed from the state's restart counts,
//! so budget exhaustion and the `all_gone` drain trigger are the
//! production logic).
//!
//! Properties pinned on every reachable interleaving:
//!
//! * **no lost checkpoint** — every checkpoint is always in exactly one
//!   place (resident, board, adopted, or answered), and in terminal
//!   states every checkpoint's request has been answered exactly once
//!   unless it is still resident/adopted on a live replica (the bounded
//!   horizon cut it off mid-flight). A checkpoint stranded on the board
//!   with no replica left to adopt it is the stranded-client bug the
//!   `final_drain` flag exists to demonstrate.
//! * **exactly-once adoption** — an adoption always takes a checkpoint
//!   in the `Board` state; a drained migrant can never be re-adopted,
//!   and answering is guarded by an explicit at-most-once ledger.
//! * **no adopt-after-poison loss** — a replica panicking while holding
//!   the board lock (mid-`post`; `Vec::push` is never torn) poisons the
//!   lock; the recovery contract (rebuild, keep contents) must hand
//!   every surviving migrant to exactly one adopter. The
//!   `poison_drops_board` leg shows the checker catches the "tolerate
//!   poison by starting empty" anti-policy as a lost checkpoint.
//! * **supervisor/router quiescence** — terminal states have no queued
//!   exit messages and no replica parked in `Restarting`; the
//!   production `Liveness` never calls a currently-beating replica
//!   `Down`, always detects a dead one once the strict threshold
//!   passes, and reports brown-out (`any_up == false`) exactly when no
//!   replica is live — so the router's admission view agrees with
//!   ground truth in every interleaving.
//!
//! Run with `cargo test board_model` — the legs are ordinary unit
//! tests; the largest (poisoned-board recovery) explores ~15k distinct
//! states, the headline two-replica leg ~4k, all in well under a second.

use std::collections::BTreeSet;

use super::router::Liveness;
use super::supervise::{ReplicaSupervisor, SupervisePolicy};
use super::ReplicaState;

/// One bounded scenario.
#[derive(Clone)]
pub struct BoardCfg {
    /// Engine replicas in the fleet.
    pub replicas: usize,
    /// Initial owner replica of each checkpoint (`owners[i]` holds
    /// checkpoint `i` resident at t = 0).
    pub owners: Vec<usize>,
    /// Clock ticks explored (each tick is one liveness-visible instant).
    pub horizon: u32,
    /// Heartbeat timeout in ticks (production `Liveness` threshold:
    /// strictly more than this many ticks without a beat is `Down`).
    pub timeout_ticks: u32,
    /// Restart budget per replica (production `SupervisePolicy`
    /// `max_retries`).
    pub restart_budget: u32,
    /// Total kill events enumerated across the run.
    pub max_kills: u32,
    /// Also enumerate kills that poison the board lock (the replica
    /// panicked while holding it, right after its push completed).
    pub poison_kill: bool,
    /// Model the production all-gone drain: when the supervisor marks
    /// the last replica permanently down it fails every migrant still
    /// on the board. `false` demonstrates the stranded-client bug the
    /// drain fixes (see `missing_final_drain_strands_evacuated_clients`).
    pub final_drain: bool,
    /// Anti-policy leg: poison recovery *drops* the board instead of
    /// keeping it. Must be caught as a lost checkpoint.
    pub poison_drops_board: bool,
}

impl BoardCfg {
    pub fn new(replicas: usize, owners: &[usize]) -> BoardCfg {
        BoardCfg {
            replicas,
            owners: owners.to_vec(),
            horizon: 3,
            timeout_ticks: 1,
            restart_budget: 1,
            max_kills: 2,
            poison_kill: false,
            final_drain: true,
            poison_drops_board: false,
        }
    }

    fn policy(&self) -> SupervisePolicy {
        SupervisePolicy {
            max_retries: self.restart_budget,
            // Backoff durations are real-time concerns; the model's
            // `Respawn` transition already interleaves the respawn
            // against every other event, which subsumes any duration.
            backoff_s: 0.01,
            backoff_mult: 2.0,
            breaker_threshold: 3,
            breaker_cooldown_s: 1.0,
        }
    }
}

/// Replica lifecycle as the supervisor sees it. `Dead` means the engine
/// thread exited and its `ReplicaExit` message is queued; `Gone` is
/// permanently down (budget declined).
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
enum Rep {
    Up,
    Dead,
    Restarting,
    Gone,
}

/// Where one checkpoint currently lives — exactly one place at a time,
/// which *is* the conservation invariant.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
enum Ck {
    /// Resident on replica `e` (original placement).
    Held(u8),
    /// Posted on the migration board, awaiting adoption.
    Board,
    /// Adopted by replica `e` after a board drain.
    Adopted(u8),
    /// Request answered with a finished sample.
    Done,
    /// Request answered with a definitive error (all-gone drain).
    Failed,
}

#[derive(Clone, Debug, PartialEq, Eq, PartialOrd, Ord)]
struct State {
    clock: u32,
    /// Last beat tick per replica (monotone, production `Liveness::beat`).
    beats: Vec<u32>,
    reps: Vec<Rep>,
    cks: Vec<Ck>,
    /// Board contents in posting order (`take` drains a prefix, so FIFO
    /// order is observable).
    board: Vec<u8>,
    /// The supervisor's exit channel: engine ids in arrival order.
    exits: Vec<u8>,
    /// Respawns granted per replica — replayed through the production
    /// `ReplicaSupervisor` for every new decision.
    restarts: Vec<u32>,
    kills: u32,
    /// Board lock currently poisoned (panicking push completed).
    poisoned: bool,
    /// Poison recoveries performed (`board_poisoned` counter mirror).
    recoveries: u32,
    /// Times each checkpoint's request was answered (must never pass 1).
    answers: Vec<u8>,
}

/// Rebuild the production supervisor from the state's ledger so the
/// next decision runs the real budget / all-gone logic.
fn rebuild_supervisor(s: &State, cfg: &BoardCfg) -> ReplicaSupervisor {
    let mut sup = ReplicaSupervisor::new(cfg.replicas, cfg.policy());
    for e in 0..cfg.replicas {
        for _ in 0..s.restarts[e] {
            assert!(sup.on_exit(e).is_some(),
                    "restart ledger exceeds the production budget:\n{s:#?}");
        }
        if s.reps[e] == Rep::Gone {
            sup.mark_gone(e);
        }
    }
    sup
}

/// Rebuild the production liveness view from the state's beat ticks.
fn rebuild_liveness(s: &State, cfg: &BoardCfg) -> Liveness {
    let mut lv = Liveness::new(cfg.replicas, cfg.timeout_ticks as f64);
    for e in 0..cfg.replicas {
        lv.beat(e, s.beats[e] as f64);
        if s.reps[e] == Rep::Restarting {
            lv.mark_restarting(e);
        }
    }
    lv
}

/// Answer checkpoint `i` (exactly-once ledger).
fn answer(s: &mut State, i: usize, ok: bool) {
    assert_eq!(s.answers[i], 0,
               "checkpoint {i} answered twice:\n{s:#?}");
    s.answers[i] = 1;
    s.cks[i] = if ok { Ck::Done } else { Ck::Failed };
}

/// Take the board lock: a poisoned lock is recovered first, keeping the
/// surviving contents (the production `lock_recover_or` contract) — or
/// dropping them under the `poison_drops_board` anti-policy leg.
fn board_access(s: &mut State, cfg: &BoardCfg) {
    if !s.poisoned {
        return;
    }
    s.poisoned = false;
    s.recoveries += 1;
    if cfg.poison_drops_board {
        // Anti-policy: "recover" by starting empty. The dropped
        // migrants stay in `Ck::Board` with no board entry — the
        // conservation check below reports them as lost.
        s.board.clear();
    }
}

/// Invariants that must hold in *every* reachable state.
fn check_state(s: &State, cfg: &BoardCfg) {
    // Conservation: the board FIFO lists exactly the checkpoints whose
    // location is `Board`, each once. (The poison-drops anti-policy
    // violates exactly this.)
    let on_board: BTreeSet<u8> = s.board.iter().copied().collect();
    assert_eq!(on_board.len(), s.board.len(),
               "board lists a checkpoint twice:\n{s:#?}");
    for (i, ck) in s.cks.iter().enumerate() {
        let listed = on_board.contains(&(i as u8));
        assert_eq!(matches!(ck, Ck::Board), listed,
                   "checkpoint {i} lost or duplicated between its \
                    location ({ck:?}) and the board FIFO:\n{s:#?}");
        // A checkpoint can only sit on a replica that is actually up —
        // kills evacuate everything atomically with the death.
        if let Ck::Held(e) | Ck::Adopted(e) = ck {
            assert_eq!(s.reps[*e as usize], Rep::Up,
                       "checkpoint {i} rides a dead replica:\n{s:#?}");
        }
        // Answer ledger agrees with the location enum.
        let answered = matches!(ck, Ck::Done | Ck::Failed);
        assert_eq!(s.answers[i] == 1, answered,
                   "answer ledger out of sync for checkpoint \
                    {i}:\n{s:#?}");
    }
    // Router agreement, through the production Liveness: a beating
    // replica is never misdeclared, a dead one is detected once the
    // strict threshold passes, and brown-out is total exactly when no
    // replica is live.
    let lv = rebuild_liveness(s, cfg);
    let now = s.clock as f64;
    for e in 0..cfg.replicas {
        match s.reps[e] {
            Rep::Up if s.beats[e] == s.clock => {
                assert_eq!(lv.state(e, now), ReplicaState::Up,
                           "freshly-beating replica {e} misdeclared:\n{s:#?}");
            }
            Rep::Restarting => {
                assert_eq!(lv.state(e, now), ReplicaState::Restarting,
                           "supervisor-marked replica {e} not shown \
                            Restarting:\n{s:#?}");
            }
            Rep::Dead | Rep::Gone
                if s.clock - s.beats[e] > cfg.timeout_ticks =>
            {
                assert_eq!(lv.state(e, now), ReplicaState::Down,
                           "dead replica {e} undetected past the \
                            threshold:\n{s:#?}");
            }
            _ => {}
        }
    }
    if s.reps.iter().all(|&r| r != Rep::Up)
        && (0..cfg.replicas)
            .all(|e| s.clock - s.beats[e] > cfg.timeout_ticks)
    {
        assert!(!lv.any_up(now),
                "no replica lives yet the router would still route \
                 (brown-out must be total):\n{s:#?}");
    }
}

/// Terminal-state invariants (no enabled transition).
fn check_terminal(s: &State) {
    assert!(s.exits.is_empty(),
            "supervisor left an exit unprocessed:\n{s:#?}");
    assert!(s.reps.iter().all(|&r| r != Rep::Restarting),
            "a respawn never happened:\n{s:#?}");
    for (i, ck) in s.cks.iter().enumerate() {
        match ck {
            Ck::Done | Ck::Failed => {}
            // Mid-flight on a live replica: the bounded horizon cut the
            // run short, which is fine — the replica would finish it.
            Ck::Held(e) | Ck::Adopted(e) => {
                assert_eq!(s.reps[*e as usize], Rep::Up,
                           "in-flight checkpoint {i} on a dead \
                            replica:\n{s:#?}");
            }
            Ck::Board => panic!(
                "checkpoint {i} stranded on the board with nobody left \
                 to adopt it — its client hangs forever:\n{s:#?}"
            ),
        }
    }
}

/// All states reachable in one atomic transition.
fn successors(s: &State, cfg: &BoardCfg) -> Vec<State> {
    let mut out = Vec::new();

    // Clock tick: liveness thresholds are the only timed behavior.
    if s.clock < cfg.horizon {
        let mut t = s.clone();
        t.clock += 1;
        out.push(t);
    }

    for e in 0..cfg.replicas {
        match s.reps[e] {
            Rep::Up => {
                // Heartbeat (engine loop publish), through the
                // production monotone beat.
                if s.beats[e] < s.clock {
                    let mut t = s.clone();
                    let mut lv = rebuild_liveness(s, cfg);
                    lv.beat(e, t.clock as f64);
                    t.beats[e] =
                        (lv.down_at(e) - cfg.timeout_ticks as f64) as u32;
                    out.push(t);
                }
                // Kill: evacuate every held/adopted checkpoint onto the
                // board (one atomic section — `evacuate_replica` posts
                // before the thread exits), queue the exit message.
                if s.kills < cfg.max_kills {
                    let mut t = s.clone();
                    for (i, ck) in t.cks.iter_mut().enumerate() {
                        if matches!(ck, Ck::Held(x) | Ck::Adopted(x)
                                    if *x as usize == e)
                        {
                            *ck = Ck::Board;
                            t.board.push(i as u8);
                        }
                    }
                    t.reps[e] = Rep::Dead;
                    t.exits.push(e as u8);
                    t.kills += 1;
                    if cfg.poison_kill {
                        // Same death, but the panic hit while the board
                        // lock was held (push itself never tears).
                        let mut p = t.clone();
                        p.poisoned = true;
                        out.push(p);
                    }
                    out.push(t);
                }
                // Adopt the board's FIFO-front migrant (idle-replica
                // poll; production `take` drains a prefix — one at a
                // time maximizes the interleavings covered).
                if !s.board.is_empty() {
                    let mut t = s.clone();
                    board_access(&mut t, cfg);
                    if let Some(&i) = t.board.first() {
                        t.board.remove(0);
                        assert_eq!(t.cks[i as usize], Ck::Board,
                                   "adopting checkpoint {i} that is not \
                                    on the board:\n{s:#?}");
                        t.cks[i as usize] = Ck::Adopted(e as u8);
                    }
                    out.push(t);
                }
                // Finish a resident or adopted sequence: the request is
                // answered exactly once with a sample.
                for i in 0..s.cks.len() {
                    if matches!(s.cks[i], Ck::Held(x) | Ck::Adopted(x)
                                if x as usize == e)
                    {
                        let mut t = s.clone();
                        answer(&mut t, i, true);
                        out.push(t);
                    }
                }
            }
            Rep::Restarting => {
                // Supervisor respawn completes: the engine re-registers
                // with an immediate beat.
                let mut t = s.clone();
                t.reps[e] = Rep::Up;
                t.beats[e] = t.clock;
                out.push(t);
            }
            Rep::Dead | Rep::Gone => {}
        }
    }

    // Supervisor processes the oldest queued exit, with the production
    // decision procedure.
    if let Some(&e) = s.exits.first() {
        let e = e as usize;
        let mut t = s.clone();
        t.exits.remove(0);
        let mut sup = rebuild_supervisor(s, cfg);
        match sup.on_exit(e) {
            Some(_backoff) => {
                t.restarts[e] += 1;
                t.reps[e] = Rep::Restarting;
            }
            None => {
                t.reps[e] = Rep::Gone;
                sup.mark_gone(e);
                if sup.all_gone() && cfg.final_drain {
                    // Production drain: fail every stranded migrant
                    // home, exactly once, through the board lock
                    // (recovering poison like any other access).
                    board_access(&mut t, cfg);
                    for i in std::mem::take(&mut t.board) {
                        answer(&mut t, i as usize, false);
                    }
                }
            }
        }
        out.push(t);
    }

    out
}

/// Runaway backstop, far above any bounded config in the tests.
const STATE_CAP: usize = 1_000_000;

/// Exhaustively explore every interleaving of `cfg`, panicking (with
/// the offending state) on any protocol violation. Returns the number
/// of distinct states visited.
pub fn explore(cfg: &BoardCfg) -> usize {
    assert!(cfg.replicas >= 1 && cfg.replicas <= 8);
    assert!(cfg.owners.iter().all(|&e| e < cfg.replicas),
            "checkpoint owner out of range");
    let init = State {
        clock: 0,
        beats: vec![0; cfg.replicas],
        reps: vec![Rep::Up; cfg.replicas],
        cks: cfg.owners.iter().map(|&e| Ck::Held(e as u8)).collect(),
        board: Vec::new(),
        exits: Vec::new(),
        restarts: vec![0; cfg.replicas],
        kills: 0,
        poisoned: false,
        recoveries: 0,
        answers: vec![0; cfg.owners.len()],
    };

    let mut visited: BTreeSet<State> = BTreeSet::new();
    let mut stack = vec![init.clone()];
    visited.insert(init);
    while let Some(s) = stack.pop() {
        check_state(&s, cfg);
        let succ = successors(&s, cfg);
        if succ.is_empty() {
            check_terminal(&s);
        }
        for t in succ {
            if !visited.contains(&t) {
                visited.insert(t.clone());
                stack.push(t);
            }
        }
        assert!(visited.len() <= STATE_CAP,
                "state-space cap exceeded — unbounded model?");
    }
    visited.len()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn two_replicas_two_checkpoints_full_protocol() {
        // The headline leg: both replicas killable, both checkpoints
        // evacuating/adopting across restarts — covers kill/adopt
        // races, exit-order handling, and the liveness view throughout.
        let n = explore(&BoardCfg::new(2, &[0, 1]));
        assert!(n > 1_000, "suspiciously small state space: {n}");
    }

    #[test]
    fn poisoned_board_recovery_preserves_migrants() {
        // Kills may poison the board lock; recovery keeps the contents
        // and every surviving migrant still reaches exactly one adopter.
        let mut cfg = BoardCfg::new(2, &[0, 1]);
        cfg.poison_kill = true;
        explore(&cfg);
    }

    #[test]
    fn restart_budget_exhaustion_drains_the_board() {
        // Budget 0: every exit is declined. The all-gone drain must
        // answer every evacuated checkpoint with an error — no client
        // may hang.
        let mut cfg = BoardCfg::new(2, &[0, 0, 1]);
        cfg.restart_budget = 0;
        explore(&cfg);
    }

    #[test]
    fn single_replica_fleet_restarts_then_drains() {
        // One replica, budget 1: first kill restarts, second kill is
        // declined and the drain answers whatever was evacuated.
        let mut cfg = BoardCfg::new(1, &[0, 0]);
        cfg.restart_budget = 1;
        cfg.max_kills = 2;
        explore(&cfg);
    }

    #[test]
    fn no_kills_every_checkpoint_finishes_locally() {
        let mut cfg = BoardCfg::new(2, &[0, 1]);
        cfg.max_kills = 0;
        explore(&cfg);
    }

    #[test]
    fn missing_final_drain_strands_evacuated_clients() {
        // Negative leg: without the all-gone drain, a declined exit
        // leaves evacuated checkpoints on a board nobody will ever
        // drain — the checker must catch the stranded client.
        let mut cfg = BoardCfg::new(1, &[0]);
        cfg.restart_budget = 0;
        cfg.max_kills = 1;
        cfg.final_drain = false;
        let r = std::panic::catch_unwind(
            std::panic::AssertUnwindSafe(|| explore(&cfg)));
        assert!(r.is_err(),
                "the checker failed to catch the stranded-client bug");
    }

    #[test]
    fn poison_drop_anti_policy_is_caught_as_lost_checkpoints() {
        // Negative leg: "recovering" a poisoned board by starting
        // empty silently loses migrants — conservation must fire.
        let mut cfg = BoardCfg::new(2, &[0]);
        cfg.poison_kill = true;
        cfg.poison_drops_board = true;
        let r = std::panic::catch_unwind(
            std::panic::AssertUnwindSafe(|| explore(&cfg)));
        assert!(r.is_err(),
                "the checker failed to catch the dropped-board policy");
    }
}
