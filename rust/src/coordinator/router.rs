//! Cross-engine router: extends the virtual-time selector across
//! replicas. Shared by every engine thread spawned by
//! `Coordinator::start_sharded` and by the caller-side admission path.
//!
//! Four mechanisms, all built on per-replica load gauges the engine
//! loops publish once per outer iteration:
//!   * **admission routing** — a new request goes to the least-loaded
//!     *live* replica (ties to the lowest engine id, keeping placement
//!     deterministic for a given load vector);
//!   * **death detection** — publishing a load gauge doubles as a
//!     heartbeat ([`Liveness`]); a replica whose last beat is older than
//!     the missed-beat threshold is [`ReplicaState::Down`] and admission
//!     skips it. When *every* replica is down the caller sheds with
//!     503 + `Retry-After` (brown-out) instead of routing into a void;
//!   * **work stealing / migration / evacuation** — a hot replica evicts
//!     a resident mid-sequence as a `SeqCheckpoint` and posts it on the
//!     board; an idle replica adopts it (`SpecScheduler::adopt` re-mints
//!     the slot id locally) and routes the finished sample through the
//!     migrant's [home](super::MigrantHome) — the origin engine's job
//!     channel, or, for checkpoints evacuated off a dying replica, a
//!     shared `EvacRecord` that answers the client directly. Checkpoints
//!     carry the per-sequence RNG stream, so a migrated or evacuated
//!     sequence's token stream is bitwise identical to an undisturbed
//!     same-seed run.
//!
//! The board is a plain mutexed vec — migrations are rare (only fired
//! when another replica sits idle, or when a replica dies) and the
//! critical sections are a push/drain, so contention is negligible next
//! to a model step. A poisoned board (a replica panicked mid-push) is
//! **rebuilt, not tolerated**: the lock is un-poisoned, the surviving
//! contents kept, and the event counted in `board_poisoned` — silently
//! dropping posted migrants would strand their requests.

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Mutex, MutexGuard};
use std::time::Instant;

use crate::engine::SeqCheckpoint;
use crate::util::sync::{lock_recover, lock_recover_or};

use super::request::GenRequest;
use super::MigrantHome;

/// One mid-sequence checkpoint in transit between replicas.
pub(crate) struct Migrant {
    /// The evicted sequence (RNG stream and progress included).
    pub ck: SeqCheckpoint,
    /// A request with the same `batch_key` as the sequence's run queue —
    /// the adopter rebuilds a matching stepper (model + sampler) from
    /// it. The checkpoint itself carries all per-sequence state, so any
    /// same-key request works as the prototype.
    pub proto: GenRequest,
    /// Where the finished sample reports: the origin engine's job
    /// channel (load-balancing migration) or a shared evacuation record
    /// that answers the client directly (the origin is dead).
    pub home: MigrantHome,
    /// Router-epoch instant the checkpoint was posted (stamped by
    /// [`RouterState::post`]); adopters observe `now - posted_at` as
    /// `evacuation_latency_s` for evacuated migrants.
    pub posted_at: f64,
    /// True when this checkpoint was evacuated off a dying replica
    /// (counted in `evacuations` at adoption) rather than posted by the
    /// load-balancing `migrate_out` path.
    pub evacuated: bool,
}

/// Replica lifecycle as the router sees it: `Up` (beating), `Down`
/// (missed-beat threshold exceeded, or its engine thread exited),
/// `Restarting` (the supervisor accepted the exit and is backing off
/// before respawn).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ReplicaState {
    Up,
    Down,
    Restarting,
}

impl ReplicaState {
    pub fn as_str(&self) -> &'static str {
        match self {
            ReplicaState::Up => "up",
            ReplicaState::Down => "down",
            ReplicaState::Restarting => "restarting",
        }
    }
}

/// Pure per-replica heartbeat state, driven by an explicit `now` (the
/// same lazy-deadline style as `Breaker`): no threads, no wall clock, so
/// the fleet sim drives it in virtual time and the live router feeds it
/// its own epoch seconds. A replica is `Down` when its last beat is
/// *strictly* older than `timeout_s` — exactly at the threshold it is
/// still `Up` (pinned by `tests/fleet_sim.rs`). Clock skew between
/// replicas cannot exist: every reading comes from one shared clock
/// (the router's epoch live, one `SimClock` in the sim).
pub struct Liveness {
    timeout_s: f64,
    beats: Vec<f64>,
    restarting: Vec<bool>,
}

impl Liveness {
    /// `n` replicas, all considered freshly beaten at `t = 0` (startup
    /// grace: a replica has `timeout_s` to publish its first beat).
    pub fn new(n: usize, timeout_s: f64) -> Liveness {
        Liveness {
            timeout_s,
            beats: vec![0.0; n],
            restarting: vec![false; n],
        }
    }

    pub fn n(&self) -> usize {
        self.beats.len()
    }

    pub fn timeout_s(&self) -> f64 {
        self.timeout_s
    }

    /// Record a heartbeat. Beats never move backwards, and a beat from a
    /// respawned engine clears its `Restarting` mark.
    pub fn beat(&mut self, e: usize, now: f64) {
        if let Some(b) = self.beats.get_mut(e) {
            if now > *b {
                *b = now;
            }
        }
        if let Some(r) = self.restarting.get_mut(e) {
            *r = false;
        }
    }

    /// Mark a replica as accepted-for-restart (the supervisor is backing
    /// off before respawn). Cleared by its next beat.
    pub fn mark_restarting(&mut self, e: usize) {
        if let Some(r) = self.restarting.get_mut(e) {
            *r = true;
        }
    }

    pub fn state(&self, e: usize, now: f64) -> ReplicaState {
        if self.restarting.get(e).copied().unwrap_or(false) {
            return ReplicaState::Restarting;
        }
        let beat = self.beats.get(e).copied().unwrap_or(f64::NEG_INFINITY);
        // Strictly-greater: exactly at the threshold the replica is
        // still Up (a beat every `timeout_s` keeps it alive forever).
        if now - beat > self.timeout_s {
            ReplicaState::Down
        } else {
            ReplicaState::Up
        }
    }

    pub fn is_up(&self, e: usize, now: f64) -> bool {
        self.state(e, now) == ReplicaState::Up
    }

    pub fn any_up(&self, now: f64) -> bool {
        (0..self.n()).any(|e| self.is_up(e, now))
    }

    pub fn all_down(&self, now: f64) -> bool {
        !self.any_up(now)
    }

    /// The last instant at which replica `e` still counts as `Up`
    /// (strictly after this it is `Down`) — the sim's wake-time hook.
    pub fn down_at(&self, e: usize) -> f64 {
        self.beats.get(e).copied().unwrap_or(0.0) + self.timeout_s
    }
}

/// State shared between the replicas of one sharded coordinator.
pub struct RouterState {
    /// Per-replica load gauges: resident residual + pending count,
    /// published by each engine loop once per outer iteration. Relaxed
    /// ordering everywhere — the values are advisory (a stale read
    /// routes one request slightly unevenly, nothing breaks).
    loads: Vec<AtomicUsize>,
    /// Per-replica heartbeat state; `publish` doubles as the beat.
    liveness: Mutex<Liveness>,
    /// Wall anchor for `now_s` — all liveness reads share this epoch, so
    /// replica-to-replica clock skew is structurally impossible.
    epoch: Instant,
    /// Migration board: checkpoints posted by hot replicas, waiting for
    /// an idle replica to adopt them.
    board: Mutex<Vec<Migrant>>,
    /// Sequences posted for migration (each post counts once).
    migrations: AtomicU64,
    /// Board drains by an adopting replica that got >= 1 migrant.
    steals: AtomicU64,
    /// Checkpoints evacuated off dying replicas and adopted elsewhere.
    evacuations: AtomicU64,
    /// Supervised engine-thread respawns.
    replica_restarts: AtomicU64,
    /// Poisoned-board recoveries (a replica panicked holding the lock).
    board_poisoned: AtomicU64,
}

// lint: serve-region — admission routing, liveness, and the migration
// board sit on every sharded request path; a panic here strands
// checkpoints (and the requests routed through them) fleet-wide.
impl RouterState {
    pub fn new(n_engines: usize, heartbeat_timeout_s: f64) -> RouterState {
        RouterState {
            loads: (0..n_engines).map(|_| AtomicUsize::new(0)).collect(),
            liveness: Mutex::new(Liveness::new(n_engines,
                                               heartbeat_timeout_s)),
            // lint: allow(clock-discipline) — the live router's liveness
            // epoch is wall time by definition; the sim drives the pure
            // Liveness struct on its SimClock instead.
            epoch: Instant::now(),
            board: Mutex::new(Vec::new()),
            migrations: AtomicU64::new(0),
            steals: AtomicU64::new(0),
            evacuations: AtomicU64::new(0),
            replica_restarts: AtomicU64::new(0),
            board_poisoned: AtomicU64::new(0),
        }
    }

    pub fn n_engines(&self) -> usize {
        self.loads.len()
    }

    /// Seconds since this router was created — the shared timeline every
    /// liveness decision reads (one clock, no skew).
    pub fn now_s(&self) -> f64 {
        self.epoch.elapsed().as_secs_f64()
    }

    /// The liveness lock never poisons in practice (no callee panics),
    /// but recover rather than propagate if it ever does: heartbeat
    /// state is monotone and always safe to keep.
    fn live(&self) -> MutexGuard<'_, Liveness> {
        lock_recover(&self.liveness)
    }

    /// Least-loaded admission routing among `Up` replicas (ties to the
    /// lowest engine id). `None` means brown-out: every replica is down
    /// (or restarting) and the caller should shed with 503 +
    /// `Retry-After` instead of queueing into a void.
    pub fn route(&self) -> Option<usize> {
        let now = self.now_s();
        let live = self.live();
        let mut best: Option<usize> = None;
        let mut best_load = usize::MAX;
        for (i, l) in self.loads.iter().enumerate() {
            if !live.is_up(i, now) {
                continue;
            }
            let v = l.load(Ordering::Relaxed);
            if v < best_load {
                best = Some(i);
                best_load = v;
            }
        }
        best
    }

    /// Publish a replica's current load (engine loop, once per round).
    /// Doubles as the replica's heartbeat. Out-of-range ids are ignored
    /// rather than indexed — the router must never panic an engine
    /// thread.
    pub(crate) fn publish(&self, engine: usize, load: usize) {
        if let Some(l) = self.loads.get(engine) {
            l.store(load, Ordering::Relaxed);
        }
        let now = self.now_s();
        self.live().beat(engine, now);
    }

    /// Record a heartbeat without touching the load gauge (supervisor
    /// re-registration after a respawn).
    pub(crate) fn beat(&self, engine: usize) {
        let now = self.now_s();
        self.live().beat(engine, now);
    }

    /// Mark a replica as supervisor-accepted for restart.
    pub(crate) fn mark_restarting(&self, engine: usize) {
        // lint: allow(lock-order) — delegation wrapper shares the
        // callee's name+arity, so the call graph unions this fn's own
        // `liveness` acquisition into the callee's set; the guard
        // method mutates already-locked state and acquires nothing.
        self.live().mark_restarting(engine);
    }

    pub fn replica_state(&self, engine: usize) -> ReplicaState {
        let now = self.now_s();
        self.live().state(engine, now)
    }

    pub fn any_up(&self) -> bool {
        let now = self.now_s();
        self.live().any_up(now)
    }

    pub fn heartbeat_timeout_s(&self) -> f64 {
        self.live().timeout_s()
    }

    pub fn load_of(&self, engine: usize) -> usize {
        self.loads
            .get(engine)
            .map(|l| l.load(Ordering::Relaxed))
            .unwrap_or(0)
    }

    /// True when some *other* live replica is idle — the signal a busy
    /// replica uses to decide migration is worth the evict/adopt cost.
    /// Dead replicas are excluded: their stale zero gauge must not
    /// attract checkpoints nobody will adopt.
    pub(crate) fn someone_else_idle(&self, engine: usize) -> bool {
        let now = self.now_s();
        let live = self.live();
        self.loads.iter().enumerate().any(|(i, l)| {
            i != engine
                && live.is_up(i, now)
                && l.load(Ordering::Relaxed) == 0
        })
    }

    /// Lock the board, rebuilding it if a replica panicked while holding
    /// the lock: clear the poison, keep the surviving contents (pushes
    /// are single `Vec::push` calls, so the vec is never torn), and
    /// count the recovery. Tolerating the poison instead would silently
    /// strand every migrant posted afterwards.
    fn board_lock(&self) -> MutexGuard<'_, Vec<Migrant>> {
        lock_recover_or(&self.board, || {
            self.board_poisoned.fetch_add(1, Ordering::Relaxed);
        })
    }

    /// Post a checkpoint for adoption (stamps `posted_at`).
    pub(crate) fn post(&self, mut m: Migrant) {
        self.migrations.fetch_add(1, Ordering::Relaxed);
        m.posted_at = self.now_s();
        self.board_lock().push(m);
    }

    /// Adopt up to `max` posted checkpoints (idle replicas call this).
    pub(crate) fn take(&self, max: usize) -> Vec<Migrant> {
        let mut b = self.board_lock();
        let k = b.len().min(max);
        let taken: Vec<Migrant> = b.drain(..k).collect();
        if !taken.is_empty() {
            self.steals.fetch_add(1, Ordering::Relaxed);
        }
        taken
    }

    /// Drain the whole board: fleet teardown, every replica permanently
    /// down — the caller fails each migrant home. Not counted as a
    /// steal (nothing gets adopted).
    pub(crate) fn take_all(&self) -> Vec<Migrant> {
        std::mem::take(&mut *self.board_lock())
    }

    /// Checkpoints currently parked on the board.
    pub fn board_depth(&self) -> usize {
        self.board_lock().len()
    }

    pub fn migrations(&self) -> u64 {
        self.migrations.load(Ordering::Relaxed)
    }

    pub fn steals(&self) -> u64 {
        self.steals.load(Ordering::Relaxed)
    }

    pub(crate) fn count_evacuation(&self) {
        self.evacuations.fetch_add(1, Ordering::Relaxed);
    }

    pub fn evacuations(&self) -> u64 {
        self.evacuations.load(Ordering::Relaxed)
    }

    pub(crate) fn count_replica_restart(&self) {
        self.replica_restarts.fetch_add(1, Ordering::Relaxed);
    }

    pub fn replica_restarts(&self) -> u64 {
        self.replica_restarts.load(Ordering::Relaxed)
    }

    pub fn board_poisoned(&self) -> u64 {
        self.board_poisoned.load(Ordering::Relaxed)
    }
}
// lint: end-serve-region

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn route_picks_least_loaded_with_low_id_ties() {
        let r = RouterState::new(3, 60.0);
        assert_eq!(r.route(), Some(0), "all-zero loads tie to engine 0");
        r.publish(0, 5);
        r.publish(1, 2);
        r.publish(2, 2);
        assert_eq!(r.route(), Some(1), "tie between 1 and 2 goes low");
        r.publish(1, 9);
        assert_eq!(r.route(), Some(2));
    }

    #[test]
    fn idle_detection_excludes_self() {
        let r = RouterState::new(2, 60.0);
        r.publish(0, 7);
        r.publish(1, 0);
        assert!(r.someone_else_idle(0));
        assert!(!r.someone_else_idle(1), "own idleness does not count");
        r.publish(1, 3);
        assert!(!r.someone_else_idle(0));
    }

    #[test]
    fn liveness_threshold_is_strict() {
        // Exactly at the missed-beat threshold a replica is still Up;
        // strictly past it, Down. Beating exactly every `timeout_s`
        // therefore keeps a replica alive forever.
        let mut l = Liveness::new(2, 0.5);
        l.beat(0, 1.0);
        assert_eq!(l.state(0, 1.5), ReplicaState::Up,
                   "exactly at threshold must still be Up");
        assert_eq!(l.state(0, 1.5 + 1e-9), ReplicaState::Down);
        assert_eq!(l.down_at(0), 1.5);
        // Replica 1 never beat after construction: Up through t=0.5,
        // Down after (startup grace).
        assert_eq!(l.state(1, 0.5), ReplicaState::Up);
        assert_eq!(l.state(1, 0.6), ReplicaState::Down);
        assert!(!l.all_down(0.5));
        assert!(l.all_down(2.0));
    }

    #[test]
    fn restarting_is_marked_until_next_beat() {
        let mut l = Liveness::new(1, 0.1);
        l.mark_restarting(0);
        assert_eq!(l.state(0, 0.0), ReplicaState::Restarting);
        assert!(!l.any_up(0.0), "restarting replicas take no traffic");
        l.beat(0, 5.0);
        assert_eq!(l.state(0, 5.0), ReplicaState::Up);
        // Beats are monotone: a stale publish cannot move time backwards.
        l.beat(0, 1.0);
        assert_eq!(l.down_at(0), 5.1);
    }

    #[test]
    fn route_skips_down_replicas_and_brown_out_is_total() {
        let r = RouterState::new(2, 600.0);
        r.publish(0, 5);
        r.publish(1, 9);
        assert_eq!(r.route(), Some(0));
        // Mark 0 restarting: routing falls over to the loaded survivor.
        r.mark_restarting(0);
        assert_eq!(r.route(), Some(1));
        assert_eq!(r.replica_state(0), ReplicaState::Restarting);
        // Both out: brown-out (route is None, any_up false).
        r.mark_restarting(1);
        assert_eq!(r.route(), None);
        assert!(!r.any_up());
        // A beat (re-registration) restores routing.
        r.beat(1);
        assert_eq!(r.route(), Some(1));
    }
}
