//! Cross-engine router: extends the virtual-time selector across
//! replicas. Shared by every engine thread spawned by
//! `Coordinator::start_sharded` and by the caller-side admission path.
//!
//! Three mechanisms, all built on per-replica load gauges the engine
//! loops publish once per outer iteration:
//!   * **admission routing** — a new request goes to the least-loaded
//!     replica (ties to the lowest engine id, keeping placement
//!     deterministic for a given load vector);
//!   * **work stealing / migration** — a hot replica evicts a resident
//!     mid-sequence as a `SeqCheckpoint` and posts it on the board; an
//!     idle replica adopts it (`SpecScheduler::adopt` re-mints the slot
//!     id locally) and sends the finished sample back to the origin
//!     engine, which owns the request's responder. Checkpoints carry
//!     the per-sequence RNG stream, so a migrated sequence's token
//!     stream is bitwise identical to an unmigrated same-seed run.
//!
//! The board is a plain mutexed vec — migrations are rare (only fired
//! when another replica sits idle) and the critical sections are a
//! push/drain, so contention is negligible next to a model step.

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc;
use std::sync::Mutex;

use crate::engine::SeqCheckpoint;

use super::request::GenRequest;
use super::Job;

/// One mid-sequence checkpoint in transit between replicas.
pub(crate) struct Migrant {
    /// The evicted sequence (RNG stream and progress included).
    pub ck: SeqCheckpoint,
    /// A request with the same `batch_key` as the sequence's run queue —
    /// the adopter rebuilds a matching stepper (model + sampler) from
    /// it. The checkpoint itself carries all per-sequence state, so any
    /// same-key request works as the prototype.
    pub proto: GenRequest,
    /// Origin-side request id / sample index the result routes back to.
    pub rid: u64,
    pub idx: usize,
    /// The origin engine's job channel (`Job::Remote` return path).
    pub origin: mpsc::Sender<Job>,
}

/// State shared between the replicas of one sharded coordinator.
pub struct RouterState {
    /// Per-replica load gauges: resident residual + pending count,
    /// published by each engine loop once per outer iteration. Relaxed
    /// ordering everywhere — the values are advisory (a stale read
    /// routes one request slightly unevenly, nothing breaks).
    loads: Vec<AtomicUsize>,
    /// Migration board: checkpoints posted by hot replicas, waiting for
    /// an idle replica to adopt them.
    board: Mutex<Vec<Migrant>>,
    /// Sequences posted for migration (each post counts once).
    migrations: AtomicU64,
    /// Board drains by an adopting replica that got >= 1 migrant.
    steals: AtomicU64,
}

// lint: serve-region — admission routing and the migration board sit on
// every sharded request path; a panic here strands checkpoints (and the
// requests routed through them) fleet-wide.
impl RouterState {
    pub fn new(n_engines: usize) -> RouterState {
        RouterState {
            loads: (0..n_engines).map(|_| AtomicUsize::new(0)).collect(),
            board: Mutex::new(Vec::new()),
            migrations: AtomicU64::new(0),
            steals: AtomicU64::new(0),
        }
    }

    pub fn n_engines(&self) -> usize {
        self.loads.len()
    }

    /// Least-loaded admission routing (ties to the lowest engine id).
    pub fn route(&self) -> usize {
        let mut best = 0usize;
        let mut best_load = usize::MAX;
        for (i, l) in self.loads.iter().enumerate() {
            let v = l.load(Ordering::Relaxed);
            if v < best_load {
                best = i;
                best_load = v;
            }
        }
        best
    }

    /// Publish a replica's current load (engine loop, once per round).
    /// Out-of-range ids are ignored rather than indexed — the router
    /// must never panic an engine thread.
    pub(crate) fn publish(&self, engine: usize, load: usize) {
        if let Some(l) = self.loads.get(engine) {
            l.store(load, Ordering::Relaxed);
        }
    }

    pub fn load_of(&self, engine: usize) -> usize {
        self.loads
            .get(engine)
            .map(|l| l.load(Ordering::Relaxed))
            .unwrap_or(0)
    }

    /// True when some *other* replica is idle — the signal a busy
    /// replica uses to decide migration is worth the evict/adopt cost.
    pub(crate) fn someone_else_idle(&self, engine: usize) -> bool {
        self.loads
            .iter()
            .enumerate()
            .any(|(i, l)| i != engine && l.load(Ordering::Relaxed) == 0)
    }

    /// Post a checkpoint for adoption.
    pub(crate) fn post(&self, m: Migrant) {
        self.migrations.fetch_add(1, Ordering::Relaxed);
        match self.board.lock() {
            Ok(mut b) => b.push(m),
            // A poisoned board means a replica panicked mid-push; the
            // migrant is lost, but its Responder-backed request still
            // gets a teardown answer from the origin engine's exit.
            Err(_) => {}
        }
    }

    /// Adopt up to `max` posted checkpoints (idle replicas call this).
    pub(crate) fn take(&self, max: usize) -> Vec<Migrant> {
        let mut b = match self.board.lock() {
            Ok(b) => b,
            Err(_) => return Vec::new(),
        };
        let k = b.len().min(max);
        let taken: Vec<Migrant> = b.drain(..k).collect();
        if !taken.is_empty() {
            self.steals.fetch_add(1, Ordering::Relaxed);
        }
        taken
    }

    /// Checkpoints currently parked on the board.
    pub fn board_depth(&self) -> usize {
        self.board.lock().map(|b| b.len()).unwrap_or(0)
    }

    pub fn migrations(&self) -> u64 {
        self.migrations.load(Ordering::Relaxed)
    }

    pub fn steals(&self) -> u64 {
        self.steals.load(Ordering::Relaxed)
    }
}
// lint: end-serve-region

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn route_picks_least_loaded_with_low_id_ties() {
        let r = RouterState::new(3);
        assert_eq!(r.route(), 0, "all-zero loads tie to engine 0");
        r.publish(0, 5);
        r.publish(1, 2);
        r.publish(2, 2);
        assert_eq!(r.route(), 1, "tie between 1 and 2 goes low");
        r.publish(1, 9);
        assert_eq!(r.route(), 2);
    }

    #[test]
    fn idle_detection_excludes_self() {
        let r = RouterState::new(2);
        r.publish(0, 7);
        r.publish(1, 0);
        assert!(r.someone_else_idle(0));
        assert!(!r.someone_else_idle(1), "own idleness does not count");
        r.publish(1, 3);
        assert!(!r.someone_else_idle(0));
    }
}
