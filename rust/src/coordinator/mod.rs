//! L3 coordinator: request queue, dynamic batcher, engine thread.
//!
//! PJRT executables are not `Send`, so the coordinator follows the classic
//! accelerator-worker design (cf. vLLM's engine loop): a single **engine
//! thread** owns all compiled models; callers submit `Job`s over an mpsc
//! channel and wait on per-request reply channels. The batcher groups
//! compatible requests (same model + sampler settings) arriving within a
//! small window into one flattened engine call, padding up to the model's
//! batch-size buckets — XLA shapes are static, so buckets are the dynamic-
//! batching unit.

pub mod batcher;
pub mod request;

use std::collections::BTreeMap;
use std::sync::mpsc;
use std::sync::Arc;
use std::time::Instant;

use anyhow::{anyhow, Result};

use crate::engine::{
    mdm_sample, speculative_sample, HybridModel, Prompt, Sample,
};
use crate::likelihood::{log_likelihood, rejection_posterior, SpecTable};
use crate::util::json::Json;
use crate::util::metrics::Registry;
use crate::util::rng::Pcg;

pub use batcher::BatcherConfig;
pub use request::{GenRequest, GenResponse, SamplerChoice, ScoreRequest,
                  ScoreResponse};

/// Object-safe erasure of `HybridModel` (hides the associated State type)
/// plus the operations the coordinator exposes.
pub trait EngineModel {
    fn seq_len(&self) -> usize;
    fn vocab(&self) -> usize;
    fn has_verify(&self) -> bool;
    fn max_bucket(&self) -> usize;
    fn info(&self) -> Json;
    fn sample(&self, prompts: &[Prompt], sampler: &SamplerChoice,
              rng: &mut Pcg) -> Result<Vec<Sample>>;
    fn log_likelihood(&self, tokens: &[i32], sigma: &[i32]) -> Result<f64>;
    fn rejection_posterior(&self, tokens: &[i32], sigma: &[i32])
                           -> Result<Vec<f64>>;
}

impl<M: HybridModel> EngineModel for M {
    fn seq_len(&self) -> usize {
        HybridModel::seq_len(self)
    }

    fn vocab(&self) -> usize {
        HybridModel::vocab(self)
    }

    fn has_verify(&self) -> bool {
        HybridModel::has_verify(self)
    }

    fn max_bucket(&self) -> usize {
        self.buckets().into_iter().max().unwrap_or(1)
    }

    fn info(&self) -> Json {
        Json::obj(vec![
            ("seq_len", Json::num(HybridModel::seq_len(self) as f64)),
            ("vocab", Json::num(HybridModel::vocab(self) as f64)),
            ("has_verify", Json::Bool(HybridModel::has_verify(self))),
            (
                "buckets",
                Json::arr(
                    self.buckets().into_iter().map(|b| Json::num(b as f64)),
                ),
            ),
        ])
    }

    fn sample(&self, prompts: &[Prompt], sampler: &SamplerChoice,
              rng: &mut Pcg) -> Result<Vec<Sample>> {
        match sampler {
            SamplerChoice::Speculative(p) => {
                if !HybridModel::has_verify(self) {
                    return Err(anyhow!(
                        "model has no causal half; use the mdm sampler"
                    ));
                }
                Ok(speculative_sample(self, prompts, p, rng).0)
            }
            SamplerChoice::Mdm(p) => Ok(mdm_sample(self, prompts, p, rng)),
        }
    }

    fn log_likelihood(&self, tokens: &[i32], sigma: &[i32]) -> Result<f64> {
        if !HybridModel::has_verify(self) {
            return Err(anyhow!("likelihood needs the causal half"));
        }
        Ok(log_likelihood(&SpecTable::from_model(self, tokens, sigma)))
    }

    fn rejection_posterior(&self, tokens: &[i32], sigma: &[i32])
                           -> Result<Vec<f64>> {
        if !HybridModel::has_verify(self) {
            return Err(anyhow!("posterior needs the causal half"));
        }
        Ok(rejection_posterior(&SpecTable::from_model(self, tokens, sigma)))
    }
}

pub type ModelMap = BTreeMap<String, Box<dyn EngineModel>>;

enum Job {
    Generate {
        req: GenRequest,
        reply: mpsc::Sender<Result<GenResponse>>,
        enqueued: Instant,
    },
    Score {
        req: ScoreRequest,
        reply: mpsc::Sender<Result<ScoreResponse>>,
    },
    Info {
        reply: mpsc::Sender<Json>,
    },
    Shutdown,
}

/// Handle used by the server / examples; cheaply cloneable.
#[derive(Clone)]
pub struct Coordinator {
    tx: mpsc::Sender<Job>,
    pub metrics: Arc<Registry>,
}

impl Coordinator {
    /// Spawn the engine thread. `factory` runs *inside* the thread and
    /// builds the model map there (PJRT handles are not Send).
    pub fn start<F>(factory: F, batcher: BatcherConfig) -> Result<Coordinator>
    where
        F: FnOnce() -> Result<ModelMap> + Send + 'static,
    {
        let (tx, rx) = mpsc::channel::<Job>();
        let metrics = Arc::new(Registry::default());
        let m = metrics.clone();
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
        std::thread::Builder::new()
            .name("ssmd-engine".into())
            .spawn(move || {
                let models = match factory() {
                    Ok(models) => {
                        let _ = ready_tx.send(Ok(()));
                        models
                    }
                    Err(e) => {
                        let _ = ready_tx.send(Err(e));
                        return;
                    }
                };
                engine_loop(models, rx, m, batcher);
            })
            .expect("spawn engine thread");
        ready_rx
            .recv()
            .map_err(|_| anyhow!("engine thread died during startup"))??;
        Ok(Coordinator { tx, metrics })
    }

    pub fn generate(&self, req: GenRequest) -> Result<GenResponse> {
        let (reply, wait) = mpsc::channel();
        self.tx
            .send(Job::Generate { req, reply, enqueued: Instant::now() })
            .map_err(|_| anyhow!("engine thread gone"))?;
        wait.recv().map_err(|_| anyhow!("engine dropped reply"))?
    }

    pub fn score(&self, req: ScoreRequest) -> Result<ScoreResponse> {
        let (reply, wait) = mpsc::channel();
        self.tx
            .send(Job::Score { req, reply })
            .map_err(|_| anyhow!("engine thread gone"))?;
        wait.recv().map_err(|_| anyhow!("engine dropped reply"))?
    }

    pub fn models_info(&self) -> Result<Json> {
        let (reply, wait) = mpsc::channel();
        self.tx
            .send(Job::Info { reply })
            .map_err(|_| anyhow!("engine thread gone"))?;
        wait.recv().map_err(|_| anyhow!("engine dropped reply"))
    }

    pub fn shutdown(&self) {
        let _ = self.tx.send(Job::Shutdown);
    }
}

fn engine_loop(models: ModelMap, rx: mpsc::Receiver<Job>,
               metrics: Arc<Registry>, cfg: BatcherConfig) {
    let h_latency = metrics.histogram("generate_latency_s");
    let h_queue = metrics.histogram("queue_wait_s");
    let h_batch = metrics.histogram("batch_size");
    let h_nfe = metrics.histogram("nfe_per_sample");
    let c_reqs = metrics.counter("requests");
    let c_samples = metrics.counter("samples");
    let c_errors = metrics.counter("errors");

    let mut rng = Pcg::new(0x55d);
    let mut stash: Option<Job> = None;

    loop {
        let first = match stash.take() {
            Some(j) => j,
            None => match rx.recv() {
                Ok(j) => j,
                Err(_) => return,
            },
        };
        let mut batch = Vec::new();
        match first {
            Job::Shutdown => return,
            Job::Info { reply } => {
                let obj = Json::Obj(
                    models.iter().map(|(k, v)| (k.clone(), v.info())).collect(),
                );
                let _ = reply.send(obj);
                continue;
            }
            Job::Score { req, reply } => {
                let _ = reply.send(run_score(&models, &req, &mut rng));
                continue;
            }
            Job::Generate { req, reply, enqueued } => {
                batch.push((req, reply, enqueued));
            }
        }

        // ---- dynamic batching window ------------------------------------
        let cap = models
            .get(&batch[0].0.model)
            .map(|m| m.max_bucket())
            .unwrap_or(1);
        let deadline = Instant::now() + cfg.max_wait;
        while batch.iter().map(|(r, _, _)| r.total_samples()).sum::<usize>()
            < cap
        {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match rx.recv_timeout(deadline - now) {
                Ok(Job::Generate { req, reply, enqueued })
                    if req.batch_key() == batch[0].0.batch_key() =>
                {
                    batch.push((req, reply, enqueued));
                }
                Ok(other) => {
                    stash = Some(other);
                    break;
                }
                Err(mpsc::RecvTimeoutError::Timeout) => break,
                Err(mpsc::RecvTimeoutError::Disconnected) => break,
            }
        }

        // ---- execute ------------------------------------------------------
        c_reqs.add(batch.len() as u64);
        let started = Instant::now();
        for (_, _, enq) in &batch {
            h_queue.observe(started.duration_since(*enq).as_secs_f64());
        }
        let key_req = batch[0].0.clone();
        let result = run_generate_batch(&models, &key_req, &batch, &mut rng);
        let elapsed = started.elapsed().as_secs_f64();
        h_latency.observe(elapsed);

        match result {
            Ok(mut per_request) => {
                h_batch.observe(
                    per_request.iter().map(|s| s.len()).sum::<usize>() as f64,
                );
                for (i, (_, reply, _)) in batch.iter().enumerate() {
                    let samples = std::mem::take(&mut per_request[i]);
                    c_samples.add(samples.len() as u64);
                    for s in &samples {
                        h_nfe.observe(s.nfe);
                    }
                    let _ = reply.send(Ok(GenResponse {
                        model: key_req.model.clone(),
                        samples,
                        wall_s: elapsed,
                    }));
                }
            }
            Err(e) => {
                c_errors.inc();
                for (_, reply, _) in &batch {
                    let _ = reply.send(Err(anyhow!("{e}")));
                }
            }
        }
    }
}

type PendingGen = (GenRequest, mpsc::Sender<Result<GenResponse>>, Instant);

/// Flatten all requests of a compatible batch into one engine call and
/// split the samples back out per request.
fn run_generate_batch(models: &ModelMap, key_req: &GenRequest,
                      batch: &[PendingGen], rng: &mut Pcg)
                      -> Result<Vec<Vec<Sample>>> {
    let model = models
        .get(&key_req.model)
        .ok_or_else(|| anyhow!("unknown model '{}'", key_req.model))?;
    let d = model.seq_len();
    let mut prompts = Vec::new();
    let mut counts = Vec::new();
    for (req, _, _) in batch {
        let prompt = req.prompt.clone().unwrap_or_else(|| Prompt::empty(d));
        if prompt.0.len() != d {
            return Err(anyhow!("prompt length {} != D {d}", prompt.0.len()));
        }
        for _ in 0..req.n_samples {
            prompts.push(prompt.clone());
        }
        counts.push(req.n_samples);
    }
    let mut seeded = Pcg::new(key_req.seed ^ rng.next_u64());
    let seed_rng = if key_req.deterministic {
        Pcg::new(key_req.seed)
    } else {
        seeded.split()
    };
    let mut r = seed_rng;
    let samples = model.sample(&prompts, &key_req.sampler, &mut r)?;
    let mut out = Vec::with_capacity(counts.len());
    let mut off = 0;
    for c in counts {
        out.push(samples[off..off + c].to_vec());
        off += c;
    }
    Ok(out)
}

fn run_score(models: &ModelMap, req: &ScoreRequest, rng: &mut Pcg)
             -> Result<ScoreResponse> {
    let model = models
        .get(&req.model)
        .ok_or_else(|| anyhow!("unknown model '{}'", req.model))?;
    let d = model.seq_len();
    if req.tokens.len() != d {
        return Err(anyhow!("tokens length {} != D {d}", req.tokens.len()));
    }
    let sigma = match &req.sigma {
        Some(s) => s.clone(),
        None => Pcg::new(req.seed.unwrap_or_else(|| rng.next_u64()))
            .permutation(d),
    };
    let ll = model.log_likelihood(&req.tokens, &sigma)?;
    let posterior = if req.with_posterior {
        Some(model.rejection_posterior(&req.tokens, &sigma)?)
    } else {
        None
    };
    Ok(ScoreResponse { log_likelihood: ll, sigma, rejection_posterior: posterior })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::mock::MockModel;
    use crate::engine::{MdmParams, SpecParams};
    use std::time::Duration;

    fn mock_coordinator() -> Coordinator {
        Coordinator::start(
            || {
                let mut m: ModelMap = BTreeMap::new();
                m.insert(
                    "mock".into(),
                    Box::new(MockModel::new(8, 4, 5)) as Box<dyn EngineModel>,
                );
                Ok(m)
            },
            BatcherConfig { max_wait: Duration::from_millis(1) },
        )
        .unwrap()
    }

    #[test]
    fn generate_speculative_roundtrip() {
        let c = mock_coordinator();
        let resp = c
            .generate(GenRequest {
                model: "mock".into(),
                n_samples: 3,
                sampler: SamplerChoice::Speculative(SpecParams::default()),
                ..Default::default()
            })
            .unwrap();
        assert_eq!(resp.samples.len(), 3);
        assert!(resp.samples[0].nfe > 0.0);
        c.shutdown();
    }

    #[test]
    fn generate_mdm_roundtrip() {
        let c = mock_coordinator();
        let resp = c
            .generate(GenRequest {
                model: "mock".into(),
                n_samples: 2,
                sampler: SamplerChoice::Mdm(MdmParams::default()),
                ..Default::default()
            })
            .unwrap();
        assert_eq!(resp.samples.len(), 2);
        c.shutdown();
    }

    #[test]
    fn unknown_model_errors() {
        let c = mock_coordinator();
        let err = c
            .generate(GenRequest {
                model: "nope".into(),
                n_samples: 1,
                ..Default::default()
            })
            .unwrap_err();
        assert!(err.to_string().contains("unknown model"));
        c.shutdown();
    }

    #[test]
    fn score_roundtrip_and_posterior_sums_to_one() {
        let c = mock_coordinator();
        let resp = c
            .score(ScoreRequest {
                model: "mock".into(),
                tokens: vec![0, 1, 2, 3, 0, 1, 2, 3],
                sigma: None,
                seed: Some(7),
                with_posterior: true,
            })
            .unwrap();
        assert!(resp.log_likelihood < 0.0);
        let post = resp.rejection_posterior.unwrap();
        assert!((post.iter().sum::<f64>() - 1.0).abs() < 1e-9);
        c.shutdown();
    }

    #[test]
    fn concurrent_requests_all_served() {
        let c = mock_coordinator();
        let mut handles = Vec::new();
        for i in 0..6 {
            let cc = c.clone();
            handles.push(std::thread::spawn(move || {
                cc.generate(GenRequest {
                    model: "mock".into(),
                    n_samples: 1,
                    seed: i,
                    ..Default::default()
                })
                .unwrap()
            }));
        }
        for h in handles {
            let r = h.join().unwrap();
            assert_eq!(r.samples.len(), 1);
        }
        assert!(c.metrics.counter("requests").get() >= 6);
        c.shutdown();
    }

    #[test]
    fn deterministic_requests_reproduce() {
        let c = mock_coordinator();
        let req = GenRequest {
            model: "mock".into(),
            n_samples: 2,
            seed: 99,
            deterministic: true,
            ..Default::default()
        };
        let a = c.generate(req.clone()).unwrap();
        let b = c.generate(req).unwrap();
        assert_eq!(a.samples[0].tokens, b.samples[0].tokens);
        c.shutdown();
    }
}
