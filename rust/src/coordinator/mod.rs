//! L3 coordinator: request queue, continuous-batching engine thread.
//!
//! PJRT executables are not `Send`, so the coordinator follows the classic
//! accelerator-worker design (cf. vLLM's engine loop): a single **engine
//! thread** owns all compiled models; callers submit `Job`s over an mpsc
//! channel and wait on per-request reply channels.
//!
//! Scheduling is **continuous batching** over the engine's step API
//! (`engine::scheduler`): requests are admitted into per-model run queues
//! keyed by `batch_key` (model + sampler settings), each queue drives a
//! slot table sized to the model's bucket ladder, and the loop interleaves
//! channel admission *between scheduler steps* — so a short request never
//! waits for the longest sequence in its batch, finished sequences retire
//! immediately, freed slots are backfilled from the pending queue, and a
//! request with more samples than the largest bucket is chunked across
//! steps instead of being handed to an uncompiled batch size. The old
//! one-shot `max_wait` window survives only as a brief admission window
//! when the engine is otherwise idle (it lets near-simultaneous requests
//! share their first step).
//!
//! **Cross-queue selection** is weighted and SLO-aware (`sched`): each
//! *model* carries a [`QueuePolicy`] resolved from the server-level
//! [`SchedConfig`] (weight, optional `slo_p95_s`, burst bound, pending
//! bound), shared by all of the model's batch-key run queues — so a
//! client cannot multiply a model's service share by fanning out
//! sampler/seed variants, and selector state is bounded by the model
//! count. The selector serves backlogged models in proportion to their
//! weights using the step costs the engine reports back after every
//! step (a rotation cursor spreads a model's steps across its ready run
//! queues), models whose observed `queue_wait_s` EWMA blows their SLO
//! get boosted, and admission backpressure (bounded pending depth with
//! a shed-or-queue policy) rides on the same state. The selector core
//! is pure state driven by an injected `Clock`, so
//! `tests/sched_sim.rs` replays scripted multi-queue traces against it
//! in exact virtual time; the engine thread drives it with wall time.
//!
//! **Preemption & priority.** Requests carry a `priority` class ordering
//! work *within* a model's run queues (higher overtakes queued pending
//! sequences; cross-queue shares stay weight-governed). When an SLO
//! queue's pressure sits at its boost ceiling for
//! `SchedConfig::preempt_after` rounds with work still waiting, the
//! selector names the most over-entitlement `preempt:on` model as a
//! victim ([`CrossQueueScheduler::preempt_check`]): the engine loop
//! evicts that model's busiest run queue's residents **mid-sequence**
//! as `engine::SeqCheckpoint`s (lowest priority first) and pauses the
//! queue until the pressure clears — or unconditionally on drain, so
//! shutdown answers every checkpointed sequence. Resumed sequences
//! continue with bitwise-identical token streams (the checkpoint
//! carries each sequence's counter-based RNG stream) and their
//! `queue_wait_s` is observed only once, at the original placement.
//!
//! Metric notes: `queue_wait_s` observes one value per *sequence* at its
//! slot-placement instant (enqueue → execution start, so pending-queue
//! congestion and cross-queue waiting are both visible), while
//! `GenResponse::wall_s` spans the whole request (enqueue → last sample
//! done) — under weighted scheduling a low-weight queue's `wall_s`
//! includes the service its weight conceded to other queues even when
//! its `queue_wait_s` stays small. `queue_credit` samples the stepped
//! queue's entitlement lag, `slo_violations` counts waits above their
//! queue's SLO, and `shed_requests` counts admissions rejected by
//! backpressure.

pub mod batcher;
pub mod board_model;
pub mod request;
pub mod router;
pub mod sched;
pub mod supervise;

use std::collections::{BTreeMap, BTreeSet};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::time::Instant;

use anyhow::{anyhow, Result};

use crate::engine::{
    mdm_sample, speculative_sample, BoundStepper, FaultyStepper,
    HybridModel, Prompt, Sample, SeqCheckpoint, SeqParams, SlotId,
    StepError, StepPhases, StepPool, Stepper,
};
use crate::sim::TraceEvent;
use crate::likelihood::{log_likelihood, rejection_posterior, SpecTable};
use crate::util::json::Json;
use crate::util::metrics::{Counter, Histogram, Registry};
use crate::util::rng::Pcg;
use crate::util::simclock::MonotonicClock;
use crate::util::sync::lock_recover;

pub use batcher::BatcherConfig;
pub use request::{GenRequest, GenResponse, SamplerChoice, ScoreRequest,
                  ScoreResponse};
pub use router::{Liveness, ReplicaState, RouterState};
pub use sched::{CrossQueueScheduler, QueueId, QueuePolicy, SchedConfig};
pub use supervise::{Breaker, BreakerState, ReplicaSupervisor,
                    SupervisePolicy};

use router::Migrant;

/// Exact suffix of admission-backpressure rejection messages. The HTTP
/// layer keys its 429 mapping on it (the vendored anyhow shim has no
/// typed errors), so the coordinator and server must agree on this one
/// literal — change it here, nowhere else. Client-echoed values in
/// error messages are always single-quoted, so they cannot forge any of
/// the three suffixes.
pub const SHED_ERROR_SUFFIX: &str = ": request shed";

/// Exact suffix of circuit-breaker fast rejections (model unhealthy).
/// The HTTP layer maps it to 503 + `Retry-After`; the message carries
/// `retry after <N>s` for the header value.
pub const BREAKER_ERROR_SUFFIX: &str = ": model unavailable";

/// Exact suffix of deadline-expiry rejections (admission or in-flight).
/// The HTTP layer maps it to 504; `deadline_sheds` counts these apart
/// from the 429 backpressure sheds.
pub const DEADLINE_ERROR_SUFFIX: &str = ": deadline expired";

/// Object-safe erasure of `HybridModel` (hides the associated State type)
/// plus the operations the coordinator exposes.
pub trait EngineModel {
    fn seq_len(&self) -> usize;
    fn vocab(&self) -> usize;
    fn has_verify(&self) -> bool;
    fn max_bucket(&self) -> usize;
    fn info(&self) -> Json;
    /// One-shot convenience used by harnesses/examples: drive a whole
    /// prompt set to completion.
    fn sample(&self, prompts: &[Prompt], sampler: &SamplerChoice,
              rng: &mut Pcg) -> Result<Vec<Sample>>;
    /// Continuous-batching entry point: a scheduler bound to this model
    /// for one sampler setting (validated here — speculative sampling
    /// needs the causal half). The scheduler's planar phases run on
    /// `pool`, the engine's shared step pool (spawned once per engine
    /// thread; see `engine::pool`).
    fn stepper<'a>(&'a self, sampler: &SamplerChoice, pool: Arc<StepPool>)
                   -> Result<Box<dyn Stepper + 'a>>;
    fn log_likelihood(&self, tokens: &[i32], sigma: &[i32]) -> Result<f64>;
    fn rejection_posterior(&self, tokens: &[i32], sigma: &[i32])
                           -> Result<Vec<f64>>;
}

impl<M: HybridModel> EngineModel for M {
    fn seq_len(&self) -> usize {
        HybridModel::seq_len(self)
    }

    fn vocab(&self) -> usize {
        HybridModel::vocab(self)
    }

    fn has_verify(&self) -> bool {
        HybridModel::has_verify(self)
    }

    fn max_bucket(&self) -> usize {
        self.buckets().into_iter().max().unwrap_or(1)
    }

    fn info(&self) -> Json {
        Json::obj(vec![
            ("seq_len", Json::num(HybridModel::seq_len(self) as f64)),
            ("vocab", Json::num(HybridModel::vocab(self) as f64)),
            ("has_verify", Json::Bool(HybridModel::has_verify(self))),
            (
                "buckets",
                Json::arr(
                    self.buckets().into_iter().map(|b| Json::num(b as f64)),
                ),
            ),
        ])
    }

    fn sample(&self, prompts: &[Prompt], sampler: &SamplerChoice,
              rng: &mut Pcg) -> Result<Vec<Sample>> {
        match sampler {
            SamplerChoice::Speculative(p) => {
                if !HybridModel::has_verify(self) {
                    return Err(anyhow!(
                        "model has no causal half; use the mdm sampler"
                    ));
                }
                Ok(speculative_sample(self, prompts, p, rng).0)
            }
            SamplerChoice::Mdm(p) => Ok(mdm_sample(self, prompts, p, rng)),
        }
    }

    fn stepper<'a>(&'a self, sampler: &SamplerChoice, pool: Arc<StepPool>)
                   -> Result<Box<dyn Stepper + 'a>> {
        let params = match sampler {
            SamplerChoice::Speculative(p) => {
                if !HybridModel::has_verify(self) {
                    return Err(anyhow!(
                        "model has no causal half; use the mdm sampler"
                    ));
                }
                SeqParams::Spec(p.clone())
            }
            SamplerChoice::Mdm(p) => SeqParams::Mdm(p.clone()),
        };
        Ok(Box::new(BoundStepper::with_pool(self, params, pool)))
    }

    fn log_likelihood(&self, tokens: &[i32], sigma: &[i32]) -> Result<f64> {
        if !HybridModel::has_verify(self) {
            return Err(anyhow!("likelihood needs the causal half"));
        }
        Ok(log_likelihood(&SpecTable::from_model(self, tokens, sigma)))
    }

    fn rejection_posterior(&self, tokens: &[i32], sigma: &[i32])
                           -> Result<Vec<f64>> {
        if !HybridModel::has_verify(self) {
            return Err(anyhow!("posterior needs the causal half"));
        }
        Ok(rejection_posterior(&SpecTable::from_model(self, tokens, sigma)))
    }
}

pub type ModelMap = BTreeMap<String, Box<dyn EngineModel>>;

pub(crate) enum Job {
    Generate {
        req: GenRequest,
        reply: mpsc::Sender<Result<GenResponse>>,
        enqueued: Instant,
    },
    Score {
        req: ScoreRequest,
        reply: mpsc::Sender<Result<ScoreResponse>>,
    },
    Info {
        reply: mpsc::Sender<Json>,
    },
    Health {
        reply: mpsc::Sender<Json>,
    },
    /// A sample finished (or definitively failed) on a replica that
    /// adopted the sequence via checkpoint migration, delivered back to
    /// the origin engine that owns the request's responder. `Err` is a
    /// flattened message (the vendored anyhow has no typed errors).
    Remote {
        rid: u64,
        idx: usize,
        result: std::result::Result<Sample, String>,
    },
    Shutdown,
}

/// Reply-channel guard: every admitted request is answered **exactly
/// once**. `send` consumes the responder; if one is instead dropped —
/// an engine bug path, or the engine thread unwinding with requests in
/// flight — the `Drop` impl delivers an explicit teardown `Err`, so
/// `Coordinator::generate` returns an error instead of surfacing a bare
/// channel disconnect (and can never hang on a reply that was silently
/// thrown away).
struct Responder {
    tx: Option<mpsc::Sender<Result<GenResponse>>>,
}

impl Responder {
    fn new(tx: mpsc::Sender<Result<GenResponse>>) -> Responder {
        Responder { tx: Some(tx) }
    }

    /// Deliver the request's one definitive response.
    fn send(mut self, r: Result<GenResponse>) {
        if let Some(tx) = self.tx.take() {
            let _ = tx.send(r);
        }
    }
}

impl Drop for Responder {
    fn drop(&mut self) {
        if let Some(tx) = self.tx.take() {
            let _ = tx.send(Err(anyhow!(
                "request dropped by engine teardown (engine thread exited \
                 with the request in flight)"
            )));
        }
    }
}

// lint: serve-region — evacuation plumbing: these types carry live
// responders across a dying replica's teardown; a panic or a dropped
// path here loses a client's one answer.

/// Where an adopted (migrated-in) sequence's finished sample reports.
pub(crate) enum MigrantHome {
    /// Load-balancing migration: the origin engine still runs and owns
    /// the request's responder; the sample travels back as
    /// `Job::Remote`.
    Engine {
        rid: u64,
        idx: usize,
        origin: mpsc::Sender<Job>,
    },
    /// Evacuation: the origin replica died. The shared record owns the
    /// responder and answers the client directly from whichever replica
    /// finishes the last sample — the route outlives the origin's
    /// teardown.
    Evac { rec: Arc<EvacRecord>, idx: usize },
}

/// An in-flight request whose owning replica died: the responder and
/// partial samples move out of the dead engine's `Inflight` table into
/// this shared record, and the request's evacuated sequences carry
/// `Arc` handles to it through the migration board. Completion is
/// exactly-once by construction — the responder is `take`n under the
/// lock by whoever fills the last sample (or fails first).
pub(crate) struct EvacRecord {
    reply: Mutex<Option<Responder>>,
    got: Mutex<Vec<Option<Sample>>>,
    remaining: AtomicUsize,
    model: String,
    enqueued: Instant,
}

impl EvacRecord {
    fn from_inflight(inf: Inflight) -> EvacRecord {
        EvacRecord {
            reply: Mutex::new(Some(inf.reply)),
            got: Mutex::new(inf.got),
            remaining: AtomicUsize::new(inf.remaining),
            model: inf.model,
            enqueued: inf.enqueued,
        }
    }

    /// The record's locks guard plain vec/option state and no callee
    /// panics while holding them; recover rather than propagate poison —
    /// losing the responder here would hang a client forever.
    fn reply_lock(&self) -> std::sync::MutexGuard<'_, Option<Responder>> {
        lock_recover(&self.reply)
    }

    /// Fill sample `idx`; the filler of the last outstanding sample
    /// answers the client.
    pub(crate) fn complete(&self, idx: usize, sample: Sample) {
        // The samples are assembled and the `got` guard dropped before
        // the responder send: the reply is a channel hop and must not
        // pin this record's lock (repolint guard-blocking).
        let samples: Vec<Sample> = {
            let mut got = lock_recover(&self.got);
            if idx >= got.len() || got[idx].is_some() {
                debug_assert!(false, "evacuated result misrouted");
                return;
            }
            got[idx] = Some(sample);
            if self.remaining.fetch_sub(1, Ordering::AcqRel) != 1 {
                return;
            }
            std::mem::take(&mut *got).into_iter().flatten().collect()
        };
        let Some(reply) = self.reply_lock().take() else { return };
        let wall = self.enqueued.elapsed().as_secs_f64();
        reply.send(Ok(GenResponse {
            model: self.model.clone(),
            samples,
            wall_s: wall,
        }));
    }

    /// A definitive failure on any evacuated sequence answers the whole
    /// request with an error (once; later completions are dropped).
    pub(crate) fn fail(&self, msg: &str) {
        let Some(reply) = self.reply_lock().take() else { return };
        reply.send(Err(anyhow!(
            "model '{}' failed after evacuation from a dead replica: \
             {msg}",
            self.model
        )));
    }

    /// True once the request was answered (completed or failed).
    pub(crate) fn done(&self) -> bool {
        self.reply_lock().is_none()
    }
}

/// Sent by a dying replica's engine thread to the fleet supervisor: the
/// still-open job receiver (queued jobs and in-transit `Job::Remote`
/// results survive the death) and the evacuation records of the
/// requests it re-homed, so a respawned engine on the same channel can
/// route late remote results into them.
pub(crate) struct ReplicaExit {
    engine_id: usize,
    rx: mpsc::Receiver<Job>,
    evac_homes: BTreeMap<u64, Arc<EvacRecord>>,
}
// lint: end-serve-region

/// Handle used by the server / examples; cheaply cloneable. One job
/// channel per engine replica (`Coordinator::start` spawns one,
/// [`Coordinator::start_sharded`] N); in sharded mode the shared
/// [`RouterState`] picks the replica for each admission.
#[derive(Clone)]
pub struct Coordinator {
    txs: Vec<mpsc::Sender<Job>>,
    router: Option<Arc<RouterState>>,
    pub metrics: Arc<Registry>,
}

impl Coordinator {
    /// Spawn the engine thread. `factory` runs *inside* the thread and
    /// builds the model map there (PJRT handles are not Send).
    pub fn start<F>(factory: F, batcher: BatcherConfig) -> Result<Coordinator>
    where
        F: FnOnce() -> Result<ModelMap> + Send + 'static,
    {
        let metrics = Arc::new(Registry::default());
        let tx = spawn_engine(factory, batcher, metrics.clone(), None)?;
        Ok(Coordinator { txs: vec![tx], router: None, metrics })
    }

    /// Spawn `n_engines` replica engine threads behind a shared router.
    /// `factory` runs inside *each* thread (PJRT handles are not Send),
    /// so every replica owns an identical model map, its own slot
    /// tables, `StepPool`, and run queues. Admissions are routed
    /// least-loaded; replicas publish load every loop, steal queued
    /// work, and migrate mid-sequence checkpoints through the router's
    /// board (migrated token streams stay bitwise identical — see
    /// `SpecScheduler::adopt`). Replica `e`'s metrics are exported with
    /// an `_e{e}` name suffix alongside a shared `migrations` counter.
    pub fn start_sharded<F>(factory: F, batcher: BatcherConfig,
                            n_engines: usize) -> Result<Coordinator>
    where
        F: Fn() -> Result<ModelMap> + Send + Clone + 'static,
    {
        let n = n_engines.max(1);
        if n == 1 {
            return Coordinator::start(factory, batcher);
        }
        let metrics = Arc::new(Registry::default());
        let router =
            Arc::new(RouterState::new(n, batcher.heartbeat_timeout_s));
        // Fleet-level counters registered eagerly so `/metrics` exposes
        // them from the first scrape, not the first failure.
        let c_restarts = metrics.counter("replica_restarts");
        metrics.counter("evacuations");
        let (exit_tx, exit_rx) = mpsc::channel::<ReplicaExit>();
        let mut txs = Vec::with_capacity(n);
        for e in 0..n {
            let (tx, rx) = mpsc::channel::<Job>();
            let ctx = EngineCtx {
                router: router.clone(),
                engine_id: e,
                tx: tx.clone(),
                exit: exit_tx.clone(),
            };
            let tx = spawn_engine_on(factory.clone(), batcher.clone(),
                                     metrics.clone(), Some(ctx), tx, rx,
                                     BTreeMap::new())?;
            txs.push(tx);
        }
        // Replica supervisor: a killed engine thread evacuates its
        // checkpoints and sends its still-open job receiver here; the
        // supervisor backs off geometrically (bounded restart budget per
        // replica), respawns the engine on the *same* channel (queued
        // jobs and in-transit remote results survive the death), and
        // re-registers it with the router. A replica out of budget stays
        // Down: its receiver is dropped, so queued jobs answer with
        // channel errors instead of hanging. The thread parks on `recv`
        // for the process lifetime (it holds an exit sender for respawned
        // contexts, so the channel never disconnects) — one idle blocked
        // thread per sharded coordinator.
        {
            let router = router.clone();
            let factory = factory.clone();
            let batcher_s = batcher.clone();
            let metrics_s = metrics.clone();
            let txs_s = txs.clone();
            let policy = batcher.sched.supervise.clone();
            std::thread::Builder::new()
                .name("ssmd-supervisor".into())
                .spawn(move || {
                    let mut sup = ReplicaSupervisor::new(n, policy);
                    while let Ok(exit) = exit_rx.recv() {
                        let e = exit.engine_id;
                        let Some(backoff) = sup.on_exit(e) else {
                            // Budget exhausted: drop the receiver; the
                            // router routes around the permanently-Down
                            // replica from here on. If this was the
                            // last replica, nobody will ever drain the
                            // board again — fail its migrants now
                            // (pinned by `board_model`: a checkpoint
                            // stranded on the board hangs its client
                            // forever).
                            sup.mark_gone(e);
                            if sup.all_gone() {
                                drain_dead_fleet(&router, exit.evac_homes);
                            }
                            continue;
                        };
                        router.mark_restarting(e);
                        // lint: allow(clock-discipline) — real restart
                        // backoff on the live supervisor thread; the
                        // fleet sim proves the policy in virtual time.
                        std::thread::sleep(
                            std::time::Duration::from_secs_f64(backoff));
                        let ctx = EngineCtx {
                            router: router.clone(),
                            engine_id: e,
                            tx: txs_s[e].clone(),
                            exit: exit_tx.clone(),
                        };
                        match spawn_engine_on(factory.clone(),
                                              batcher_s.clone(),
                                              metrics_s.clone(), Some(ctx),
                                              txs_s[e].clone(), exit.rx,
                                              exit.evac_homes) {
                            Ok(_) => {
                                // Re-registration: beat immediately so
                                // admission stops skipping the replica
                                // before its first load publish.
                                router.beat(e);
                                router.count_replica_restart();
                                c_restarts.inc();
                            }
                            Err(_) => {
                                // Factory failed on respawn: the
                                // replica is permanently Down (its
                                // thread is gone, no future exit will
                                // arrive). The evacuation records were
                                // consumed by the failed spawn — their
                                // responders answered on drop — but a
                                // last-replica failure must still
                                // drain the board.
                                sup.mark_gone(e);
                                if sup.all_gone() {
                                    drain_dead_fleet(&router,
                                                     BTreeMap::new());
                                }
                            }
                        }
                    }
                })
                .expect("spawn supervisor thread");
        }
        Ok(Coordinator { txs, router: Some(router), metrics })
    }

    /// Number of engine replicas behind this handle.
    pub fn n_engines(&self) -> usize {
        self.txs.len()
    }

    /// Shared router state (None in single-engine mode).
    pub fn router(&self) -> Option<&Arc<RouterState>> {
        self.router.as_ref()
    }

    // lint: serve-region — caller-side request paths: every failure
    // mode (engine gone, reply dropped) must surface as an `Err`, never
    // a panic or a hang.
    /// Sharded admission routing with brown-out: the least-loaded *Up*
    /// replica takes the admission (ties to the lowest engine id);
    /// `Err` — mapped to 503 + `Retry-After` by the HTTP layer — only
    /// when every replica is down. Single-engine: the one channel.
    fn route_admission(&self) -> Result<usize> {
        let Some(r) = self.router.as_ref() else { return Ok(0) };
        match r.route() {
            Some(e) => Ok(e),
            None => {
                self.metrics.counter("brownout_shed").inc();
                let ra =
                    r.heartbeat_timeout_s().ceil().max(1.0) as u64;
                Err(anyhow!(
                    "fleet unavailable: every replica is down, retry \
                     after {ra}s{BREAKER_ERROR_SUFFIX}"
                ))
            }
        }
    }

    pub fn generate(&self, req: GenRequest) -> Result<GenResponse> {
        let (reply, wait) = mpsc::channel();
        let e = self.route_admission()?;
        self.txs[e]
            // lint: allow(clock-discipline) — caller-side wall stamp: the
            // engine backdates channel transit from it, and the caller
            // thread has no injected clock to share with the engine.
            .send(Job::Generate { req, reply, enqueued: Instant::now() })
            .map_err(|_| anyhow!("engine thread gone"))?;
        wait.recv().map_err(|_| anyhow!("engine dropped reply"))?
    }

    pub fn score(&self, req: ScoreRequest) -> Result<ScoreResponse> {
        let (reply, wait) = mpsc::channel();
        let e = self.route_admission()?;
        self.txs[e]
            .send(Job::Score { req, reply })
            .map_err(|_| anyhow!("engine thread gone"))?;
        wait.recv().map_err(|_| anyhow!("engine dropped reply"))?
    }

    pub fn models_info(&self) -> Result<Json> {
        let (reply, wait) = mpsc::channel();
        // Replicas are built from one factory: any replica's map serves.
        self.txs[0]
            .send(Job::Info { reply })
            .map_err(|_| anyhow!("engine thread gone"))?;
        wait.recv().map_err(|_| anyhow!("engine dropped reply"))
    }

    /// Per-model supervision state for `/healthz`:
    /// `{"ok": <no breaker open>, "models": {name: "closed" | "open" |
    /// "half-open"}}`. `Err` means the engine thread itself is gone.
    /// Sharded mode merges every replica (worst state per model wins)
    /// and adds an `engines` array with each replica's own view plus
    /// the router's migration/steal counters.
    pub fn health(&self) -> Result<Json> {
        let Some(router) = self.router.as_ref() else {
            let (reply, wait) = mpsc::channel();
            self.txs[0]
                .send(Job::Health { reply })
                .map_err(|_| anyhow!("engine thread gone"))?;
            return wait
                .recv()
                .map_err(|_| anyhow!("engine dropped reply"));
        };
        let mut ok = true;
        let mut merged: BTreeMap<String, Json> = BTreeMap::new();
        let mut engines = Vec::new();
        let mut replicas = Vec::new();
        for (e, tx) in self.txs.iter().enumerate() {
            let state = router.replica_state(e);
            replicas.push(Json::str(state.as_str()));
            // Down/Restarting replicas cannot answer a health probe (and
            // an undetected-dead one would stall it): report liveness
            // from the router instead of querying, and degrade likewise
            // when an apparently-Up replica's channel is gone or slow.
            let h = if state != ReplicaState::Up {
                None
            } else {
                let (reply, wait) = mpsc::channel();
                tx.send(Job::Health { reply }).ok().and_then(|()| {
                    wait.recv_timeout(
                        std::time::Duration::from_secs(2)).ok()
                })
            };
            let h = h.unwrap_or_else(|| {
                Json::obj(vec![
                    ("ok", Json::Bool(false)),
                    ("state", Json::str(state.as_str())),
                ])
            });
            if !h.get("ok").and_then(|b| b.as_bool()).unwrap_or(false) {
                ok = false;
            }
            if let Some(Json::Obj(models)) = h.get("models") {
                for (name, st) in models.iter() {
                    let worse = match (
                        merged.get(name).and_then(|s| s.as_str()),
                        st.as_str(),
                    ) {
                        // Worst state per model across replicas:
                        // open > half-open > closed.
                        (Some("open"), _) => false,
                        (Some("half-open"), Some("open")) => true,
                        (Some("half-open"), _) => false,
                        _ => true,
                    };
                    if worse {
                        merged.insert(name.clone(), st.clone());
                    }
                }
            }
            engines.push(h);
        }
        Ok(Json::obj(vec![
            ("ok", Json::Bool(ok)),
            ("models", Json::Obj(merged)),
            ("engines", Json::arr(engines)),
            ("replicas", Json::arr(replicas)),
            ("migrations", Json::num(router.migrations() as f64)),
            ("steals", Json::num(router.steals() as f64)),
            ("evacuations", Json::num(router.evacuations() as f64)),
            ("replica_restarts",
             Json::num(router.replica_restarts() as f64)),
            ("board_poisoned",
             Json::num(router.board_poisoned() as f64)),
        ]))
    }

    pub fn shutdown(&self) {
        for tx in &self.txs {
            let _ = tx.send(Job::Shutdown);
        }
    }
    // lint: end-serve-region
}

/// Spawn one engine thread with a fresh channel (single-engine path).
fn spawn_engine<F>(factory: F, batcher: BatcherConfig,
                   metrics: Arc<Registry>, ctx: Option<EngineCtx>)
                   -> Result<mpsc::Sender<Job>>
where
    F: FnOnce() -> Result<ModelMap> + Send + 'static,
{
    let (tx, rx) = mpsc::channel::<Job>();
    spawn_engine_on(factory, batcher, metrics, ctx, tx, rx,
                    BTreeMap::new())
}

/// Spawn one engine thread on an existing channel (sharded replicas
/// pre-create theirs so the ctx can carry a clone of its own sender as
/// the migration return address; supervised respawns reuse the dead
/// replica's channel so queued jobs survive). `evac_homes` is non-empty
/// only on respawn: the dead predecessor's evacuation records, consulted
/// when late `Job::Remote` results arrive for requests it re-homed.
fn spawn_engine_on<F>(factory: F, batcher: BatcherConfig,
                      metrics: Arc<Registry>, ctx: Option<EngineCtx>,
                      tx: mpsc::Sender<Job>, rx: mpsc::Receiver<Job>,
                      evac_homes: BTreeMap<u64, Arc<EvacRecord>>)
                      -> Result<mpsc::Sender<Job>>
where
    F: FnOnce() -> Result<ModelMap> + Send + 'static,
{
    let name = match &ctx {
        Some(c) => format!("ssmd-engine-{}", c.engine_id),
        None => "ssmd-engine".into(),
    };
    let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
    std::thread::Builder::new()
        .name(name)
        .spawn(move || {
            let models = match factory() {
                Ok(models) => {
                    let _ = ready_tx.send(Ok(()));
                    models
                }
                Err(e) => {
                    let _ = ready_tx.send(Err(e));
                    return;
                }
            };
            engine_loop(models, rx, metrics, batcher, ctx, evac_homes);
        })
        .expect("spawn engine thread");
    ready_rx
        .recv()
        .map_err(|_| anyhow!("engine thread died during startup"))??;
    Ok(tx)
}

/// Sharded-mode context handed to each replica's engine loop.
pub(crate) struct EngineCtx {
    /// Shared router: load gauges, liveness, the migration board,
    /// counters.
    router: Arc<RouterState>,
    /// This replica's index (metric suffix, `SlotId` namespace base).
    engine_id: usize,
    /// This replica's own job sender — the migration return address
    /// stamped into every `Migrant` it posts.
    tx: mpsc::Sender<Job>,
    /// Fleet supervisor channel: a killed engine thread evacuates its
    /// checkpoints, then sends its receiver (and evacuation records)
    /// here for supervised respawn.
    exit: mpsc::Sender<ReplicaExit>,
}

/// Metric handles shared across the engine loop helpers.
struct EngineMetrics {
    h_latency: Arc<Histogram>,
    h_queue: Arc<Histogram>,
    h_batch: Arc<Histogram>,
    h_nfe: Arc<Histogram>,
    h_occupancy: Arc<Histogram>,
    h_step: Arc<Histogram>,
    /// Per-phase step cost (one observation per step, seconds): the
    /// model forward passes vs the three planar sampling phases.
    h_step_model: Arc<Histogram>,
    h_step_draw: Arc<Histogram>,
    h_step_lse: Arc<Histogram>,
    h_step_accept: Arc<Histogram>,
    h_pending: Arc<Histogram>,
    h_credit: Arc<Histogram>,
    c_reqs: Arc<Counter>,
    c_samples: Arc<Counter>,
    c_errors: Arc<Counter>,
    c_backfills: Arc<Counter>,
    c_steps: Arc<Counter>,
    c_slo: Arc<Counter>,
    c_shed: Arc<Counter>,
    /// Sequences refused by admission backpressure (the request-level
    /// companion is `shed_requests` — one shed request sheds all of its
    /// sequences, and the two units must never be conflated).
    c_shed_seqs: Arc<Counter>,
    /// Sequences evicted mid-run by preemption / resumed checkpoints
    /// placed back into slots / policy-level preemption fires.
    c_preempt: Arc<Counter>,
    c_resume: Arc<Counter>,
    c_preempt_fires: Arc<Counter>,
    /// Steps whose failure became definitive (fatal, or a transient
    /// burst out of retries) and quarantined a run queue.
    c_engine_faults: Arc<Counter>,
    /// Transient step failures scheduled for a backed-off retry.
    c_retries: Arc<Counter>,
    /// Requests answered with a deadline-expiry error (admission or
    /// in-flight) — deliberately separate from the 429 `shed_requests`.
    c_deadline_sheds: Arc<Counter>,
    /// Gauge: number of models whose breaker is currently not closed.
    c_breaker_state: Arc<Counter>,
    /// Sequences migrated out to another replica mid-run (sharded mode;
    /// stays 0 on a single engine).
    c_migrations: Arc<Counter>,
    /// Evacuated checkpoints this replica *adopted* off dead peers.
    c_evacuations: Arc<Counter>,
    /// Board time of adopted evacuees: death-side post → adoption.
    h_evac_latency: Arc<Histogram>,
}

impl EngineMetrics {
    fn new(metrics: &Registry) -> EngineMetrics {
        EngineMetrics::with_suffix(metrics, "")
    }

    /// Registry has name-keyed series only (no labels), so per-replica
    /// metrics are the same names suffixed `_e{engine_id}`. The
    /// single-engine path uses the empty suffix — every historical name
    /// (and every test pinned on one) is unchanged.
    fn with_suffix(metrics: &Registry, s: &str) -> EngineMetrics {
        EngineMetrics {
            h_latency: metrics.histogram(&format!("generate_latency_s{s}")),
            h_queue: metrics.histogram(&format!("queue_wait_s{s}")),
            h_batch: metrics.histogram(&format!("batch_size{s}")),
            h_nfe: metrics.histogram(&format!("nfe_per_sample{s}")),
            h_occupancy: metrics.histogram(&format!("slot_occupancy{s}")),
            h_step: metrics.histogram(&format!("step_latency_s{s}")),
            h_step_model: metrics.histogram(&format!("step_model_s{s}")),
            h_step_draw: metrics.histogram(&format!("step_draw_s{s}")),
            h_step_lse: metrics.histogram(&format!("step_lse_s{s}")),
            h_step_accept: metrics.histogram(&format!("step_accept_s{s}")),
            h_pending: metrics.histogram(&format!("pending_depth{s}")),
            h_credit: metrics.histogram(&format!("queue_credit{s}")),
            c_reqs: metrics.counter(&format!("requests{s}")),
            c_samples: metrics.counter(&format!("samples{s}")),
            c_errors: metrics.counter(&format!("errors{s}")),
            c_backfills: metrics.counter(&format!("backfills{s}")),
            c_steps: metrics.counter(&format!("scheduler_steps{s}")),
            c_slo: metrics.counter(&format!("slo_violations{s}")),
            c_shed: metrics.counter(&format!("shed_requests{s}")),
            c_shed_seqs: metrics.counter(&format!("shed_seqs{s}")),
            c_preempt: metrics.counter(&format!("preemptions{s}")),
            c_resume: metrics.counter(&format!("resume_steps{s}")),
            c_preempt_fires: metrics.counter(&format!("preempt_fires{s}")),
            c_engine_faults: metrics.counter(&format!("engine_faults{s}")),
            c_retries: metrics.counter(&format!("retries{s}")),
            c_deadline_sheds:
                metrics.counter(&format!("deadline_sheds{s}")),
            c_breaker_state: metrics.counter(&format!("breaker_state{s}")),
            c_migrations: metrics.counter(&format!("migrations{s}")),
            c_evacuations: metrics.counter(&format!("evacuations{s}")),
            h_evac_latency:
                metrics.histogram(&format!("evacuation_latency_s{s}")),
        }
    }
}

/// A request whose samples are in flight across scheduler steps.
struct Inflight {
    reply: Responder,
    enqueued: Instant,
    model: String,
    got: Vec<Option<Sample>>,
    remaining: usize,
    /// Absolute expiry instant on the selector's clock (`xq.now()`
    /// terms), derived from `deadline_ms` at admission; `None` = no
    /// deadline. Checked between outer-loop steps and lazily at pick
    /// time — an expired request is answered with a deadline error and
    /// its sequences are removed wherever they sit (pending, resident,
    /// or parked).
    deadline: Option<f64>,
}

/// One continuous-batching run queue: all admitted sequences share a
/// `batch_key` (model + sampler settings + determinism class).
struct RunQueue<'m> {
    key: String,
    stepper: Box<dyn Stepper + 'm>,
    /// Handle into the cross-queue selector (policy, credit, wait EWMA,
    /// pending arrival stamps), keyed by *model*: all batch-key run
    /// queues of one model share it, and it outlives them all — an idle
    /// model's history survives drop/recreate cycles, and selector
    /// state stays bounded by the model count (batch keys embed
    /// client-supplied seeds and are unbounded).
    sched_id: QueueId,
    /// Arrival-stamp lane within the model's selector queue (the id of
    /// the request that created this run queue — unique and stable):
    /// placements pop their own lane's FIFO, so per-sequence
    /// `queue_wait_s` values pair exactly even with several batch-key
    /// siblings concurrently backlogged.
    lane: u64,
    /// slot -> (request id, sample index within the request).
    routes: BTreeMap<SlotId, (u64, usize)>,
    /// Adopted (migrated-in) sequences: local slot id -> the migrant's
    /// home (origin engine channel or evacuation record). Kept apart
    /// from `routes` — these requests live in *another* replica's
    /// inflight table (or a dead one's `EvacRecord`), and their
    /// finished samples travel home instead of answering locally.
    remote_routes: BTreeMap<SlotId, MigrantHome>,
    /// First request admitted on this batch key, kept as the migration
    /// prototype: an adopter rebuilds an identical stepper from its
    /// model + sampler (the checkpoint carries all per-sequence state,
    /// so any same-key request serves).
    proto: GenRequest,
    /// Whether the formation-time batch size was recorded.
    formed: bool,
    /// Checkpoints of residents evicted by preemption, held here — off
    /// the stepper — while the queue is **paused**: a queue with parked
    /// work is excluded from the ready set, so engine steps go to the
    /// pressured SLO queue instead of immediately backfilling the freed
    /// slots. Resumed (ahead of equal-priority fresh pending work, with
    /// bitwise-identical continuation) once the trigger clears, and
    /// unconditionally on drain. Checkpoints keep their `SlotId`, so
    /// `routes` stays valid across the park/resume cycle and
    /// `queue_wait_s` is never observed twice for a sequence.
    parked: Vec<SeqCheckpoint>,
    /// The SLO queue whose pressure caused the parking.
    parked_trigger: Option<QueueId>,
    /// Transient step failures in the current burst (reset by the first
    /// successful step; a burst exceeding the supervision policy's
    /// `max_retries` quarantines the queue).
    retries: u32,
    /// Retry backoff gate on the selector's clock: the queue is not
    /// ready before this instant. 0.0 = no backoff pending.
    not_before: f64,
}

// lint: serve-region — the engine loop owns every in-flight responder;
// a panic here (or a skipped reply) breaks answer-exactly-once.
fn engine_loop(models: ModelMap, rx: mpsc::Receiver<Job>,
               metrics: Arc<Registry>, cfg: BatcherConfig,
               ctx: Option<EngineCtx>,
               mut evac_homes: BTreeMap<u64, Arc<EvacRecord>>) {
    let m = match &ctx {
        Some(c) => EngineMetrics::with_suffix(
            &metrics, &format!("_e{}", c.engine_id)),
        None => EngineMetrics::new(&metrics),
    };
    // Fleet-wide evacuation counter (unsuffixed), alongside the
    // per-replica `evacuations_e{id}` in `m`.
    let c_evac_global = metrics.counter("evacuations");
    // Replica `e` mints SlotIds from `e << 40` upward: migrated
    // checkpoints keep globally-unique ids in traces, and the adopter
    // re-mints on arrival (`Stepper::adopt`) so routing tables never
    // collide either way. Single-engine base stays 0 — id sequences
    // (and the token-stream pins keyed on them) are unchanged.
    let id_base = ctx
        .as_ref()
        .map(|c| (c.engine_id as u64) << 40)
        .unwrap_or(0);
    // Engine entropy diverges per replica (id_base mixes in) so two
    // replicas' live-mode requests never share a stream; single-engine
    // (base 0) keeps the historical seed exactly.
    let mut rng = Pcg::new(0x55d ^ id_base);
    let mut req_counter: u64 = 0;
    let mut inflight: BTreeMap<u64, Inflight> = BTreeMap::new();
    let mut queues: Vec<RunQueue<'_>> = Vec::new();
    // Per-model circuit breakers (supervision layer): entries appear at
    // the first definitive model failure and gate admissions from then
    // on. Missing entry = closed.
    let mut breakers: BTreeMap<String, Breaker> = BTreeMap::new();
    // The engine's shared step pool: workers spawned once here, shared
    // by every run queue's scheduler (`--step-threads`; 1 = the exact
    // single-threaded code path). Thread count never changes results —
    // token streams are bitwise identical (see engine::pool).
    let pool = Arc::new(StepPool::new(cfg.sched.step_threads.max(1)));
    // Weighted SLO-aware cross-queue selector, on wall time here (the
    // simulation harness drives the same core on virtual time).
    let mut xq = CrossQueueScheduler::new(
        Box::new(MonotonicClock::new()), &cfg.sched);
    let mut ready_buf: Vec<QueueId> = Vec::new();
    // Preemption candidates (models with evictable residents, paired
    // with their total residual work), rebuilt each round like
    // ready_buf — the selector prefers high-residual victims among the
    // over-entitled, so a nearly-finished batch is evicted last.
    let mut cand_buf: Vec<(QueueId, u64)> = Vec::new();
    // Intra-model rotation cursors: the selector picks a *model*; that
    // model's own cursor rotates among its ready run queues (batch-key
    // variants) so they share the model's allocation fairly. The cursor
    // must be per-model — a single shared cursor can realign on every
    // other model's step and systematically skip one variant, starving
    // it even though its model is being served.
    let mut rr: BTreeMap<QueueId, usize> = BTreeMap::new();
    let mut slo_seen: u64 = 0;
    let mut disconnected = false;
    // Shutdown drains: stop reading the channel but finish (and reply to)
    // every request already admitted before returning.
    let mut draining = false;

    loop {
        // Resume parked checkpoints whose trigger pressure cleared —
        // and always on drain/disconnect, so shutdown answers every
        // checkpointed sequence before the loop exits.
        for q in queues.iter_mut() {
            if q.parked.is_empty() {
                continue;
            }
            let clear = draining
                || disconnected
                || q.parked_trigger
                    .map(|t| xq.preempt_cleared(t))
                    .unwrap_or(true);
            if clear {
                for ck in q.parked.drain(..) {
                    q.stepper.resume(ck);
                }
                q.parked_trigger = None;
            }
        }
        // Enforce request deadlines between steps (with lazy in-queue
        // expiry): expired in-flight requests are answered now, and
        // their sequences removed wherever they sit — pending, resident,
        // or parked.
        sweep_deadlines(&mut queues, &mut inflight, &mut xq, &m);
        // Records whose requests were answered (by adopters completing
        // directly, or by a failure) are finished business.
        evac_homes.retain(|_, rec| !rec.done());
        // Sharded: a replica whose sequences all migrated out has idle
        // queues but a non-empty inflight table — it must keep looping
        // to receive the `Job::Remote` results that answer them. A
        // respawned replica likewise stays up for the requests its dead
        // predecessor re-homed (`evac_homes`) until each is answered.
        let busy = queues
            .iter()
            .any(|q| !q.stepper.is_idle() || !q.parked.is_empty())
            || (ctx.is_some()
                && (!inflight.is_empty() || !evac_homes.is_empty()));
        if (draining || disconnected) && !busy {
            return; // nothing left to finish
        }
        // Publish this replica's load before blocking or stepping, so
        // admission routing and peers' migration decisions see it.
        if let Some(c) = &ctx {
            let load: usize = queues
                .iter()
                .map(|q| q.stepper.residual() + q.stepper.n_pending())
                .sum();
            c.router.publish(c.engine_id, load);
        }
        if !draining && !busy {
            if let Some(c) = &ctx {
                // Sharded idle: poll for jobs *and* adoptable
                // checkpoints — a blocking recv would never see the
                // migration board.
                match rx.recv_timeout(std::time::Duration::from_millis(1))
                {
                    Ok(job) => {
                        if handle_job(job, &models, &mut queues,
                                      &mut inflight, &mut rng,
                                      &mut req_counter, &m, &cfg,
                                      &mut xq, &pool, &breakers, id_base,
                                      &mut evac_homes) {
                            draining = true;
                        }
                    }
                    Err(mpsc::RecvTimeoutError::Timeout) => {
                        adopt_migrants(c, &models, &mut queues, &mut xq,
                                       &pool, &cfg, id_base, &m,
                                       &c_evac_global);
                    }
                    Err(mpsc::RecvTimeoutError::Disconnected) => {
                        disconnected = true;
                    }
                }
            } else {
                // Idle: block for work, then hold a brief admission
                // window so near-simultaneous requests share their
                // first step.
                match rx.recv() {
                    Ok(job) => {
                        if handle_job(job, &models, &mut queues,
                                      &mut inflight, &mut rng,
                                      &mut req_counter, &m, &cfg,
                                      &mut xq, &pool, &breakers, id_base,
                                      &mut evac_homes) {
                            draining = true;
                        }
                    }
                    Err(_) => return,
                }
                // lint: allow(clock-discipline) — anchors a real OS
                // recv_timeout deadline; virtual time cannot wake a
                // channel.
                let deadline = Instant::now() + cfg.max_wait;
                while !draining {
                    // lint: allow(clock-discipline) — remaining OS
                    // timeout for recv_timeout against the deadline
                    // above.
                    let now = Instant::now();
                    if now >= deadline {
                        break;
                    }
                    match rx.recv_timeout(deadline - now) {
                        Ok(job) => {
                            if handle_job(job, &models, &mut queues,
                                          &mut inflight, &mut rng,
                                          &mut req_counter, &m, &cfg,
                                          &mut xq, &pool, &breakers,
                                          id_base, &mut evac_homes) {
                                draining = true;
                            }
                        }
                        Err(mpsc::RecvTimeoutError::Timeout) => break,
                        Err(mpsc::RecvTimeoutError::Disconnected) => {
                            disconnected = true;
                            break;
                        }
                    }
                }
            }
        } else if !draining {
            // Busy: admit whatever is queued *between* scheduler steps —
            // this is what lets a new request join a running batch.
            loop {
                match rx.try_recv() {
                    Ok(job) => {
                        if handle_job(job, &models, &mut queues,
                                      &mut inflight, &mut rng,
                                      &mut req_counter, &m, &cfg,
                                      &mut xq, &pool, &breakers, id_base,
                                      &mut evac_homes) {
                            draining = true;
                            break;
                        }
                    }
                    Err(mpsc::TryRecvError::Empty) => break,
                    Err(mpsc::TryRecvError::Disconnected) => {
                        disconnected = true;
                        break;
                    }
                }
            }
        } else if ctx.is_some() {
            // Draining, sharded: the channel stays open only for the
            // `Job::Remote` results that answer requests whose
            // sequences migrated out. New work is refused (its reply
            // sender drops, answering "engine dropped reply").
            loop {
                match rx.try_recv() {
                    Ok(Job::Remote { rid, idx, result }) => {
                        deliver_remote(rid, idx, result, &mut queues,
                                       &mut inflight, &mut xq, &m,
                                       &mut evac_homes);
                    }
                    Ok(_) => {}
                    Err(mpsc::TryRecvError::Empty) => break,
                    Err(mpsc::TryRecvError::Disconnected) => {
                        disconnected = true;
                        break;
                    }
                }
            }
        }

        // One scheduler step: the weighted selector picks a model among
        // everything with resident or pending work, then the rotation
        // cursor picks one of that model's ready run queues. Queues with
        // parked checkpoints are paused — not ready — until resumed, and
        // queues inside a retry-backoff window sit out until it elapses.
        ready_buf.clear();
        let t_ready = xq.now();
        for q in queues.iter() {
            if !q.stepper.is_idle()
                && q.parked.is_empty()
                && t_ready >= q.not_before
                && !ready_buf.contains(&q.sched_id)
            {
                ready_buf.push(q.sched_id);
            }
        }
        let mut stepped = false;
        if let Some(sid) = xq.pick(&ready_buf) {
            let n = queues.len();
            let start = rr.get(&sid).copied().unwrap_or(0);
            let mut picked = None;
            for off in 0..n {
                let i = (start + off) % n;
                if queues[i].sched_id == sid
                    && !queues[i].stepper.is_idle()
                    && queues[i].parked.is_empty()
                    && t_ready >= queues[i].not_before
                {
                    picked = Some(i);
                    break;
                }
            }
            // A pick without a matching ready queue would be an engine
            // bug (ready_buf was built from the same predicate); skip
            // the step rather than panic with responders in flight.
            let Some(qi) = picked else {
                debug_assert!(false, "picked model has no ready queue");
                continue;
            };
            // Advance past the served queue: the next scan for this
            // model starts after it, so every ready sibling is reached
            // within one cycle of the model's picks (index shifts from
            // `retain` below only rotate the origin, never skip).
            rr.insert(sid, (qi + 1) % n.max(1));
            stepped = true;
            match step_queue(&mut queues[qi], &mut inflight, &mut xq, &m,
                             cfg.trace.as_ref()) {
                Ok(()) => {
                    // A successful step ends any retry burst and closes
                    // the model's breaker (half-open probes included).
                    let q = &mut queues[qi];
                    q.retries = 0;
                    q.not_before = 0.0;
                    let name = xq.key_of(sid).to_string();
                    if let Some(b) = breakers.get_mut(&name) {
                        b.record_success(xq.now());
                    }
                }
                Err(StepError::Killed(msg)) => {
                    // Replica death (deterministic `kill@N` injection).
                    // Sharded: evacuate everything this replica holds
                    // onto the migration board — survivors adopt the
                    // checkpoints and answer the re-homed requests —
                    // then hand the channel to the supervisor and exit
                    // the thread. Single-engine: no fleet to evacuate
                    // onto; degrade to a definitive queue failure.
                    m.c_engine_faults.inc();
                    if let Some(c) = &ctx {
                        let mut homes = evacuate_replica(
                            c, &mut queues, &mut inflight, &mut xq, &m);
                        // A twice-killed respawn still owes its
                        // predecessor's re-homed requests: carry their
                        // records forward too.
                        homes.append(&mut evac_homes);
                        let _ = c.exit.send(ReplicaExit {
                            engine_id: c.engine_id,
                            rx,
                            evac_homes: homes,
                        });
                        return;
                    }
                    let name = xq.key_of(sid).to_string();
                    let now = xq.now();
                    breakers
                        .entry(name)
                        .or_insert_with(|| {
                            Breaker::new(&cfg.sched.supervise)
                        })
                        .record_failure(now);
                    quarantine_queue(&mut queues[qi], &mut inflight,
                                     &mut xq, &m, &msg);
                }
                Err(StepError::Transient(_))
                    if queues[qi].retries
                        < cfg.sched.supervise.max_retries =>
                {
                    // Transient fault with retries left: back the queue
                    // off (bounded, Clock-driven) and try again later.
                    // Scheduler state survives the failed step intact —
                    // see the unwind-safety argument on
                    // `BoundStepper::step`.
                    let q = &mut queues[qi];
                    q.retries += 1;
                    q.not_before = xq.now()
                        + cfg.sched.supervise.backoff_for(q.retries);
                    m.c_retries.inc();
                }
                Err(e) => {
                    // Definitive failure — fatal, or a transient burst
                    // out of retries: quarantine this run queue only
                    // (surviving queues' streams stay bitwise identical
                    // to a fault-free run) and record the failure on the
                    // model's breaker.
                    m.c_engine_faults.inc();
                    let name = xq.key_of(sid).to_string();
                    let now = xq.now();
                    breakers
                        .entry(name)
                        .or_insert_with(|| {
                            Breaker::new(&cfg.sched.supervise)
                        })
                        .record_failure(now);
                    quarantine_queue(&mut queues[qi], &mut inflight,
                                     &mut xq, &m, e.message());
                }
            }
            // Export the selector's violation count as a monotonic
            // counter delta.
            let v = xq.slo_violations();
            m.c_slo.add(v - slo_seen);
            slo_seen = v;

            // Preemption: a pressured SLO queue stuck at its boost
            // ceiling for preempt_after rounds evicts the residents of
            // the most over-entitlement preemptible model. The victim's
            // busiest run queue is parked wholesale (checkpoints held in
            // `parked`, the queue paused) until the trigger clears —
            // see `RunQueue::parked`.
            cand_buf.clear();
            for q in queues.iter() {
                if q.parked.is_empty() && q.stepper.n_active() > 0 {
                    let res = q.stepper.residual() as u64;
                    match cand_buf
                        .iter_mut()
                        .find(|(sid, _)| *sid == q.sched_id)
                    {
                        // A model's residual is summed across its
                        // batch-key run queues — the victim policy
                        // ranks models, not individual queues.
                        Some((_, r)) => *r += res,
                        None => cand_buf.push((q.sched_id, res)),
                    }
                }
            }
            if let Some((trigger, victim)) = xq.preempt_check(&cand_buf) {
                let mut best: Option<usize> = None;
                for (i, q) in queues.iter().enumerate() {
                    if q.sched_id == victim
                        && q.parked.is_empty()
                        && q.stepper.n_active() > 0
                    {
                        let better = match best {
                            None => true,
                            Some(j) => q.stepper.n_active()
                                > queues[j].stepper.n_active(),
                        };
                        if better {
                            best = Some(i);
                        }
                    }
                }
                if let Some(vi) = best {
                    let q = &mut queues[vi];
                    while let Some(ck) = q.stepper.evict_lowest() {
                        q.parked.push(ck);
                    }
                    m.c_preempt.add(q.parked.len() as u64);
                    m.c_preempt_fires.inc();
                    q.parked_trigger = Some(trigger);
                    // Charge the victim's checkpoint budget with the
                    // redo work just parked (progress a resume must
                    // replay): a queue evicted past
                    // `SchedConfig::checkpoint_budget` stops being a
                    // victim, so evict/resume cycles cannot livelock it.
                    let redo: u64 = q
                        .parked
                        .iter()
                        .map(|ck| ck.progress() as u64)
                        .sum();
                    xq.charge_preemption(victim, redo);
                }
            }
        }
        // Migration: while peers sit idle and the board is clear, shed
        // one resident per round to the fleet. Eviction/adoption is
        // bitwise-identical continuation, so this trades only a little
        // checkpoint plumbing for a whole extra engine's throughput.
        if let Some(c) = &ctx {
            if !draining
                && !disconnected
                && c.router.someone_else_idle(c.engine_id)
                && c.router.board_depth() == 0
            {
                migrate_out(c, &mut queues, &inflight, &m);
            }
        }
        if !stepped && busy {
            // Everything runnable is gated (retry backoff windows,
            // parked checkpoints): sleep briefly instead of hot-spinning
            // on try_recv until a gate opens.
            // lint: allow(clock-discipline) — bounds a real busy-wait on
            // the live engine thread; no virtual clock can advance it.
            std::thread::sleep(std::time::Duration::from_millis(1));
        }
        // Gauge: models currently degraded (breaker not closed).
        let t_gauge = xq.now();
        m.c_breaker_state.set(breakers
            .values()
            .filter(|b| b.state(t_gauge) != BreakerState::Closed)
            .count() as u64);
        queues.retain(|q| !q.stepper.is_idle() || !q.parked.is_empty());
    }
}

/// Dispatch one job; returns true on shutdown.
#[allow(clippy::too_many_arguments)]
fn handle_job<'m>(job: Job, models: &'m ModelMap,
                  queues: &mut Vec<RunQueue<'m>>,
                  inflight: &mut BTreeMap<u64, Inflight>, rng: &mut Pcg,
                  req_counter: &mut u64, m: &EngineMetrics,
                  cfg: &BatcherConfig, xq: &mut CrossQueueScheduler,
                  pool: &Arc<StepPool>,
                  breakers: &BTreeMap<String, Breaker>,
                  id_base: u64,
                  evac_homes: &mut BTreeMap<u64, Arc<EvacRecord>>)
                  -> bool {
    match job {
        Job::Shutdown => true,
        Job::Remote { rid, idx, result } => {
            deliver_remote(rid, idx, result, queues, inflight, xq, m,
                           evac_homes);
            false
        }
        Job::Info { reply } => {
            let obj = Json::Obj(
                models.iter().map(|(k, v)| (k.clone(), v.info())).collect(),
            );
            let _ = reply.send(obj);
            false
        }
        Job::Health { reply } => {
            // `/healthz` body: overall ok = no breaker fully open (a
            // half-open breaker is probing, so the model is admitting).
            let now = xq.now();
            let mut ok = true;
            let mut states: BTreeMap<String, Json> = BTreeMap::new();
            for name in models.keys() {
                let st = breakers
                    .get(name)
                    .map(|b| b.state(now))
                    .unwrap_or(BreakerState::Closed);
                if st == BreakerState::Open {
                    ok = false;
                }
                states.insert(name.clone(), Json::str(st.as_str()));
            }
            let _ = reply.send(Json::obj(vec![
                ("ok", Json::Bool(ok)),
                ("models", Json::Obj(states)),
            ]));
            false
        }
        Job::Score { req, reply } => {
            let _ = reply.send(run_score(models, &req, rng));
            false
        }
        Job::Generate { req, reply, enqueued } => {
            admit_generate(models, queues, inflight, rng, req_counter, m,
                           cfg, xq, pool, breakers, req, reply, enqueued,
                           id_base);
            false
        }
    }
}

/// Validate a generate request, apply admission backpressure, and admit
/// its samples into the matching run queue (creating the queue on first
/// use with a policy resolved from the server-level `SchedConfig`).
#[allow(clippy::too_many_arguments)]
fn admit_generate<'m>(models: &'m ModelMap, queues: &mut Vec<RunQueue<'m>>,
                      inflight: &mut BTreeMap<u64, Inflight>, rng: &mut Pcg,
                      req_counter: &mut u64, m: &EngineMetrics,
                      cfg: &BatcherConfig, xq: &mut CrossQueueScheduler,
                      pool: &Arc<StepPool>,
                      breakers: &BTreeMap<String, Breaker>, req: GenRequest,
                      reply: mpsc::Sender<Result<GenResponse>>,
                      enqueued: Instant, id_base: u64) {
    // Guard the reply channel immediately: every path out of admission
    // either sends explicitly or drops the responder, which itself sends
    // a teardown error — the client is answered exactly once, always.
    let reply = Responder::new(reply);
    m.c_reqs.inc();
    let rid = *req_counter;
    *req_counter += 1;

    let model = match models.get(&req.model) {
        Some(model) => model,
        None => {
            m.c_errors.inc();
            reply.send(Err(anyhow!("unknown model '{}'", req.model)));
            return;
        }
    };
    // Circuit breaker: an unhealthy model fails fast at admission (503
    // at the HTTP layer) instead of queueing work behind a failing
    // backend. Half-open lets the admission through as a probe.
    if let Some(b) = breakers.get(&req.model) {
        let now = xq.now();
        if !b.admit_allowed(now) {
            let ra = b.retry_after_s(now).ceil().max(1.0) as u64;
            m.c_errors.inc();
            reply.send(Err(anyhow!(
                "model '{}' unhealthy: circuit breaker open, retry after \
                 {ra}s{BREAKER_ERROR_SUFFIX}",
                req.model
            )));
            return;
        }
    }
    let d = model.seq_len();
    let prompt = req.prompt.clone().unwrap_or_else(|| Prompt::empty(d));
    if prompt.0.len() != d {
        m.c_errors.inc();
        reply.send(Err(anyhow!(
            "prompt length {} != D {d}", prompt.0.len()
        )));
        return;
    }

    // Per-request base RNG:
    //  * deterministic — derived from the seed alone, so the response
    //    depends only on the request (not on queue neighbours, admission
    //    order, or engine history), and the engine stream is untouched;
    //  * live — engine entropy XOR seed, with the monotonically increasing
    //    request index mixed into the PCG stream so two live requests with
    //    the same seed still draw from distinct streams.
    let mut base = if req.deterministic {
        Pcg::new(req.seed)
    } else {
        Pcg::with_stream(rng.next_u64() ^ req.seed, rid)
    };

    let key = req.batch_key();
    let existing = queues.iter().position(|q| q.key == key);

    let n = req.n_samples;
    if n == 0 {
        reply.send(Ok(GenResponse {
            model: req.model.clone(),
            samples: Vec::new(),
            wall_s: 0.0,
        }));
        return;
    }

    // One selector queue per *model*, shared by every batch-key run
    // queue of that model: weights, SLO state, and the pending bound
    // apply to the model as a whole, so spawning sampler/seed variants
    // (each a distinct batch_key — deterministic seeds alone are
    // unbounded) can neither multiply a model's service share nor grow
    // selector state beyond the model count.
    let sched_id =
        xq.register(&req.model, cfg.sched.resolve(&req.model));
    // Admission backpressure BEFORE stepper construction: a shed request
    // on a cold batch key must not pay arena allocation or leave a dead
    // RunQueue behind. The request's channel transit time is backdated
    // into its arrival stamps so queue_wait_s still measures from the
    // caller-side enqueue; the stamps are tagged with the request id so
    // a rollback removes exactly this request's entries.
    let lane = match existing {
        Some(qi) => queues[qi].lane,
        None => rid,
    };
    let age = enqueued.elapsed().as_secs_f64();
    // Deadline: measured from the caller-side enqueue instant, projected
    // onto the selector's clock. Enforced here at admission, then
    // between steps by the engine loop's sweep.
    let deadline_ms = req.deadline_ms.or(cfg.default_deadline_ms);
    let deadline = deadline_ms.map(|ms| xq.now() - age + ms as f64 / 1000.0);
    if let Some(dl) = deadline {
        if xq.now() >= dl {
            m.c_deadline_sheds.inc();
            m.c_errors.inc();
            reply.send(Err(anyhow!(
                "request spent {age:.3}s reaching the engine, past its \
                 {}ms deadline{DEADLINE_ERROR_SUFFIX}",
                deadline_ms.unwrap_or(0)
            )));
            return;
        }
    }
    // Priority class: orders this request within its queue's pending
    // work (and makes it a late preemption victim); cross-queue shares
    // stay governed by the model's QueuePolicy weight. Resolved before
    // backpressure so shedding can be priority-aware.
    let priority = req.priority.unwrap_or(cfg.sched.default_priority);
    // Priority-aware shedding: before refusing a higher-priority
    // arrival, shed the lowest-priority *fully pending* request of
    // the same model (429 to its client) — the lowest class loses
    // first; arrival order breaks ties only within a class. Only
    // requests with no placed, parked, or remote work qualify: a
    // shed must never discard service already rendered. Displacement
    // runs *before* the counting `try_enqueue`, so an arrival that
    // wins a spot this way is never also counted shed by the selector.
    while xq.is_full(sched_id, n) {
        if !shed_lowest_pending(queues, inflight, xq, m, sched_id,
                                priority) {
            break;
        }
    }
    if !xq.try_enqueue(sched_id, lane, rid, n, age) {
        m.c_shed.inc();
        m.c_shed_seqs.add(n as u64);
        m.c_errors.inc();
        reply.send(Err(anyhow!(
            "model '{}' queue is full: {} sequences requested, {}/{} \
             pending{SHED_ERROR_SUFFIX}",
            req.model,
            n,
            xq.pending_depth(sched_id),
            xq.policy_of(sched_id).max_pending
        )));
        return;
    }

    let qi = match existing {
        Some(qi) => qi,
        None => match model.stepper(&req.sampler, pool.clone()) {
            Ok(mut stepper) => {
                // Per-replica SlotId namespace (base 0 single-engine).
                stepper.set_id_base(id_base);
                // `--fault-plan` wiring: a scripted plan for this model
                // wraps the fresh run queue's stepper, firing at step
                // granularity (each run queue counts its own steps).
                let stepper = match cfg.faults.get(&req.model) {
                    Some(plan) => Box::new(FaultyStepper::new(
                        stepper, plan.clone())) as Box<dyn Stepper + 'm>,
                    None => stepper,
                };
                queues.push(RunQueue {
                    key: key.clone(),
                    stepper,
                    sched_id,
                    lane,
                    routes: BTreeMap::new(),
                    remote_routes: BTreeMap::new(),
                    proto: req.clone(),
                    formed: false,
                    parked: Vec::new(),
                    parked_trigger: None,
                    retries: 0,
                    not_before: 0.0,
                });
                queues.len() - 1
            }
            Err(e) => {
                // Roll back exactly this request's optimistic stamps.
                xq.cancel_enqueue(sched_id, lane, rid, n);
                m.c_errors.inc();
                reply.send(Err(e));
                return;
            }
        },
    };
    if let Some(tr) = &cfg.trace {
        let _ = tr.send(TraceEvent::Arrival {
            t: xq.now() - age,
            model: req.model.clone(),
            n,
            seed: req.seed,
            priority,
        });
    }
    let q = &mut queues[qi];
    for k in 0..n {
        let sid = q.stepper.admit_prio(&prompt, base.split(), priority);
        q.routes.insert(sid, (rid, k));
    }
    inflight.insert(rid, Inflight {
        reply,
        enqueued,
        model: req.model,
        got: vec![None; n],
        remaining: n,
        deadline,
    });
}

/// Priority-aware backpressure victim: shed the lowest-priority fully
/// pending request of model `sched_id` whose class is strictly below
/// `prio`, freeing queue depth for the arriving request. Returns false
/// when no eligible victim exists (the arrival itself sheds then).
/// Eligible means every sequence of the victim is still in its run
/// queue's pending queue — nothing placed, parked, or migrated — so the
/// 429 discards no rendered service and the selector rollback
/// (`cancel_enqueue`) accounts for every sequence exactly.
fn shed_lowest_pending(queues: &mut [RunQueue<'_>],
                       inflight: &mut BTreeMap<u64, Inflight>,
                       xq: &mut CrossQueueScheduler, m: &EngineMetrics,
                       sched_id: QueueId, prio: i32) -> bool {
    let mut best: Option<(usize, u64, i32)> = None;
    for (qi, q) in queues.iter().enumerate() {
        if q.sched_id != sched_id {
            continue;
        }
        let Some((sid, vprio)) = q.stepper.lowest_pending() else {
            continue;
        };
        if vprio >= prio {
            continue;
        }
        let Some(&(vrid, _)) = q.routes.get(&sid) else { continue };
        let fully_pending = q
            .routes
            .iter()
            .filter(|&(_, &(r, _))| r == vrid)
            .all(|(&s, _)| q.stepper.is_pending(s));
        if !fully_pending || !inflight.contains_key(&vrid) {
            continue;
        }
        if best.map(|(_, _, bp)| vprio < bp).unwrap_or(true) {
            best = Some((qi, vrid, vprio));
        }
    }
    let Some((qi, vrid, _)) = best else { return false };
    let q = &mut queues[qi];
    let sids: Vec<SlotId> = q
        .routes
        .iter()
        .filter(|&(_, &(r, _))| r == vrid)
        .map(|(&s, _)| s)
        .collect();
    let mut removed = 0usize;
    for &s in &sids {
        if q.stepper.remove_pending(s) {
            removed += 1;
        }
        q.routes.remove(&s);
    }
    xq.cancel_enqueue(q.sched_id, q.lane, vrid, removed);
    xq.count_shed(q.sched_id, removed as u64, 1);
    m.c_shed.inc();
    m.c_shed_seqs.add(removed as u64);
    m.c_errors.inc();
    if let Some(inf) = inflight.remove(&vrid) {
        inf.reply.send(Err(anyhow!(
            "model '{}' queue is full: shed for a higher-priority \
             arrival{SHED_ERROR_SUFFIX}",
            inf.model
        )));
    }
    removed > 0
}

/// Run one scheduler step on a queue, report its cost to the selector,
/// and deliver whatever completed. A step failure is returned for the
/// engine loop's supervision (retry/backoff or quarantine) — this
/// function itself never answers a request with an error.
fn step_queue(q: &mut RunQueue<'_>, inflight: &mut BTreeMap<u64, Inflight>,
              xq: &mut CrossQueueScheduler, m: &EngineMetrics,
              trace: Option<&mpsc::Sender<TraceEvent>>)
              -> std::result::Result<(), StepError> {
    if !q.formed {
        q.formed = true;
        // Batch size at formation time: sequences gathered before the
        // queue's first step (each formation consumes >= 1 request, so
        // this histogram's count never exceeds the request counter).
        // One observation per queue lifetime by design — the per-step
        // executed batch view lives in `slot_occupancy`.
        m.h_batch
            .observe((q.stepper.n_active() + q.stepper.n_pending()) as f64);
    }
    let backfills_before = q.stepper.backfills();
    let resumes_before = q.stepper.resumes();
    // Entitlement lag of the queue the selector just chose (how far
    // behind its weighted share it was when served).
    m.h_credit.observe(xq.credit(q.sched_id));
    let t0 = xq.now();
    let stepped = q.stepper.step();
    // Cost on the selector's injected clock (wall time in production,
    // virtual time under test) — the engine loop has no raw Instant.
    let cost = xq.now() - t0;
    m.h_step.observe(cost);
    m.c_steps.inc();
    if let Some(tr) = trace {
        let _ = tr.send(TraceEvent::Step {
            model: xq.key_of(q.sched_id).to_string(),
            cost_s: cost,
        });
    }
    let finished = match stepped {
        Ok(finished) => finished,
        Err(e) => {
            // Charge the failed step's cost so the entitlement ledger
            // stays consistent, then hand the error up. Placements the
            // failed step already made stay undrained here: a retry's
            // next successful step (or the quarantine path) drains them
            // and pops their arrival stamps.
            xq.report_step_phases(q.sched_id, cost, &StepPhases::default());
            return Err(e);
        }
    };
    // Step-cost feedback, now per-phase: the weighted selector charges
    // this queue for the total service it just consumed and retains the
    // model/draw/LSE/accept split; the same split is exported as
    // histograms so an operator can see whether steps are model-bound
    // or sampling-bound (the part `--step-threads` scales).
    let phases: StepPhases = q.stepper.take_phases();
    m.h_step_model.observe(phases.model_s);
    m.h_step_draw.observe(phases.draw_s);
    m.h_step_lse.observe(phases.lse_s);
    m.h_step_accept.observe(phases.accept_s);
    xq.report_step_phases(q.sched_id, cost, &phases);
    // queue_wait_s = enqueue -> sequence placed into a slot, one value
    // per sequence, so pending-queue congestion and cross-queue waiting
    // are visible under load. Placement is the first thing step() does
    // (backfill precedes the forward pass), so the pre-step reading `t0`
    // is the placement instant — using now() here would bill the whole
    // first step as wait.
    let placed = q.stepper.take_placements();
    observe_placements(q, &placed, xq, m, t0);
    m.h_occupancy.observe(q.stepper.n_active() as f64);
    m.h_pending.observe(q.stepper.n_pending() as f64);
    m.c_backfills.add(q.stepper.backfills() - backfills_before);
    // Resumed checkpoints re-entering slots this step. Their queue wait
    // was observed at the original placement, so `take_placements`
    // (above) deliberately excluded them — `queue_wait_s` pairs each
    // sequence with exactly one wait even across a park/resume cycle.
    m.c_resume.add(q.stepper.resumes() - resumes_before);

    for (sid, sample) in finished {
        // Adopted (migrated-in) sequence: the sample travels home — to
        // the origin engine that owns the request's responder, or
        // straight into a dead origin's evacuation record. A closed
        // origin channel means that engine tore down without evacuating
        // (budget-exhausted restart) and already answered — drop.
        if let Some(home) = q.remote_routes.remove(&sid) {
            match home {
                MigrantHome::Engine { rid, idx, origin } => {
                    let _ = origin.send(Job::Remote {
                        rid,
                        idx,
                        result: Ok(sample),
                    });
                }
                MigrantHome::Evac { rec, idx } => {
                    m.h_nfe.observe(sample.nfe);
                    rec.complete(idx, sample);
                }
            }
            continue;
        }
        // Routing desyncs would be engine bugs; a panic here would tear
        // down every in-flight request, so degrade to dropping the one
        // sample instead (debug builds still assert).
        let Some((rid, idx)) = q.routes.remove(&sid) else {
            debug_assert!(false, "finished slot is not routed");
            continue;
        };
        let completed = {
            let Some(inf) = inflight.get_mut(&rid) else {
                debug_assert!(false, "routed request is not in flight");
                continue;
            };
            m.h_nfe.observe(sample.nfe);
            inf.got[idx] = Some(sample);
            inf.remaining -= 1;
            inf.remaining == 0
        };
        if completed {
            let Some(inf) = inflight.remove(&rid) else { continue };
            let wall = inf.enqueued.elapsed().as_secs_f64();
            m.h_latency.observe(wall);
            m.c_samples.add(inf.got.len() as u64);
            // `remaining == 0` ⇒ every slot is Some; flatten rather than
            // unwrap per-sample so a miscount cannot panic the engine.
            let samples: Vec<Sample> =
                inf.got.into_iter().flatten().collect();
            inf.reply.send(Ok(GenResponse {
                model: inf.model,
                samples,
                wall_s: wall,
            }));
        }
    }
    Ok(())
}

/// Pop lane-FIFO arrival stamps for freshly placed sequences, one
/// queue-wait observation per sequence, grouped per *request tag* (the
/// rid each placed slot routes to): priority classes let a later
/// high-priority request's sequences enter slots before an earlier
/// low-priority request's, so placement order within a run queue no
/// longer follows admission order across requests — a plain lane-FIFO
/// pop would hand the overtaker the overtaken request's older stamp,
/// corrupting queue_wait_s and the SLO EWMA/violations (and thus the
/// preemption trigger). Within one request placements stay
/// admission-ordered, so oldest-of-tag pairs each wait exactly.
fn observe_placements(q: &mut RunQueue<'_>, placed: &[SlotId],
                      xq: &mut CrossQueueScheduler, m: &EngineMetrics,
                      t0: f64) {
    let h_queue = &m.h_queue;
    let mut i = 0;
    while i < placed.len() {
        let Some(rid) = q.routes.get(&placed[i]).map(|&(rid, _)| rid)
        else {
            debug_assert!(false, "placed slot is not routed");
            i += 1;
            continue;
        };
        let mut j = i + 1;
        while j < placed.len()
            && q.routes.get(&placed[j]).map(|&(r, _)| r) == Some(rid)
        {
            j += 1;
        }
        xq.placed_at_tag(q.sched_id, q.lane, rid, j - i, t0,
                         |w| h_queue.observe(w));
        i = j;
    }
}

/// Quarantine a run queue after a definitive step failure: remove every
/// resident and pending sequence and answer each routed request with an
/// explicit error, exactly once. Only this queue is touched — surviving
/// queues' token streams stay bitwise identical to a fault-free run.
fn quarantine_queue(q: &mut RunQueue<'_>,
                    inflight: &mut BTreeMap<u64, Inflight>,
                    xq: &mut CrossQueueScheduler, m: &EngineMetrics,
                    msg: &str) {
    // Only ready queues are stepped, and ready requires no parked
    // checkpoints — quarantine never has parked work to dispose of.
    debug_assert!(q.parked.is_empty());
    // The failed step's placements were never drained; pop their stamps
    // first (placement did happen, the wait is real) so the selector's
    // lane FIFO holds no entries for rids that will never place again.
    let placed = q.stepper.take_placements();
    let t_now = xq.now();
    observe_placements(q, &placed, xq, m, t_now);
    // Residents: evict and drop the checkpoints (their stamps were
    // popped at placement).
    while q.stepper.evict_lowest().is_some() {}
    // Pending sequences never placed: their stamps are still queued in
    // the selector — roll them back per request, as a shed does.
    let mut unplaced: BTreeMap<u64, usize> = BTreeMap::new();
    for sid in q.stepper.take_pending_ids() {
        if let Some(&(rid, _)) = q.routes.get(&sid) {
            *unplaced.entry(rid).or_insert(0) += 1;
        }
    }
    for (&rid, &k) in unplaced.iter() {
        xq.cancel_enqueue(q.sched_id, q.lane, rid, k);
    }
    // Adopted sequences belong to requests re-homed elsewhere: report
    // the failure home (origin engine or evacuation record) instead of
    // answering locally. A closed origin channel means that engine
    // already tore down (and answered its requests on exit).
    for (_, home) in std::mem::take(&mut q.remote_routes) {
        home_fail(home, msg.to_string());
    }
    // Answer every request routed through this queue, exactly once. The
    // queue is idle afterwards, so the engine loop's retain drops it;
    // a later request on the same batch key builds a fresh stepper.
    let routed: BTreeSet<u64> = std::mem::take(&mut q.routes)
        .into_values()
        .map(|(rid, _)| rid)
        .collect();
    for rid in routed {
        let Some(inf) = inflight.remove(&rid) else {
            debug_assert!(false, "routed request is not in flight");
            continue;
        };
        m.c_errors.inc();
        inf.reply.send(Err(anyhow!(
            "model '{}' failed while serving this request: {msg}",
            inf.model
        )));
    }
}

/// Answer every in-flight request whose deadline has passed and remove
/// its sequences wherever they sit: resident slots are evicted (the
/// checkpoint dropped), pending sequences are removed with their arrival
/// stamps rolled back, parked checkpoints are discarded.
fn sweep_deadlines(queues: &mut Vec<RunQueue<'_>>,
                   inflight: &mut BTreeMap<u64, Inflight>,
                   xq: &mut CrossQueueScheduler, m: &EngineMetrics) {
    let now = xq.now();
    let expired: Vec<u64> = inflight
        .iter()
        .filter(|(_, inf)| inf.deadline.map(|d| now >= d).unwrap_or(false))
        .map(|(&rid, _)| rid)
        .collect();
    for rid in expired {
        for q in queues.iter_mut() {
            let sids: Vec<SlotId> = q
                .routes
                .iter()
                .filter(|&(_, &(r, _))| r == rid)
                .map(|(&sid, _)| sid)
                .collect();
            if sids.is_empty() {
                continue;
            }
            let mut unplaced = 0usize;
            for &sid in &sids {
                if q.stepper.evict(sid).is_some() {
                    // Resident: stamp was popped at placement.
                } else if q.stepper.remove_pending(sid) {
                    unplaced += 1;
                } else {
                    // Parked mid-preemption: drop the checkpoint.
                    q.parked.retain(|ck| ck.id() != sid);
                }
                q.routes.remove(&sid);
            }
            if unplaced > 0 {
                xq.cancel_enqueue(q.sched_id, q.lane, rid, unplaced);
            }
        }
        let Some(inf) = inflight.remove(&rid) else { continue };
        m.c_deadline_sheds.inc();
        m.c_errors.inc();
        inf.reply.send(Err(anyhow!(
            "model '{}' request exceeded its deadline after {:.3}s\
             {DEADLINE_ERROR_SUFFIX}",
            inf.model,
            inf.enqueued.elapsed().as_secs_f64()
        )));
    }
}

/// Shed one resident sequence to the migration board. Policy: evict the
/// lowest-progress resident of the busiest eligible queue (>= 2 active,
/// so a local resident always remains and the queue keeps stepping).
/// Only deadline-less requests migrate — the deadline sweep needs the
/// sequence local to enforce its budget. Eviction/adoption preserves the
/// per-sequence RNG stream, so the migrated token stream stays bitwise
/// identical to an unmigrated same-seed run.
fn migrate_out(ctx: &EngineCtx, queues: &mut [RunQueue<'_>],
               inflight: &BTreeMap<u64, Inflight>, m: &EngineMetrics) {
    let mut best: Option<usize> = None;
    for (i, q) in queues.iter().enumerate() {
        if q.parked.is_empty() && q.stepper.n_active() >= 2 {
            let better = match best {
                None => true,
                Some(j) => {
                    q.stepper.n_active() > queues[j].stepper.n_active()
                }
            };
            if better {
                best = Some(i);
            }
        }
    }
    let Some(qi) = best else { return };
    let q = &mut queues[qi];
    let Some(ck) = q.stepper.evict_lowest() else { return };
    let sid = ck.id();
    // Eligibility is only knowable after the evict names the victim;
    // an ineligible sequence resumes in place, which is bitwise-free.
    let eligible = q
        .routes
        .get(&sid)
        .and_then(|&(rid, _)| inflight.get(&rid))
        .map(|inf| inf.deadline.is_none())
        .unwrap_or(false);
    if !eligible {
        q.stepper.resume(ck);
        return;
    }
    let Some((rid, idx)) = q.routes.remove(&sid) else {
        debug_assert!(false, "eligible migrant lost its route");
        q.stepper.resume(ck);
        return;
    };
    m.c_migrations.inc();
    ctx.router.post(Migrant {
        ck,
        proto: q.proto.clone(),
        home: MigrantHome::Engine {
            rid,
            idx,
            origin: ctx.tx.clone(),
        },
        posted_at: 0.0,
        evacuated: false,
    });
}

/// Report a definitive failure to a migrant's home (the counterpart of
/// the success path in `step_queue`): `Job::Remote` to a live origin
/// engine, or directly into a dead origin's evacuation record.
fn home_fail(home: MigrantHome, msg: String) {
    match home {
        MigrantHome::Engine { rid, idx, origin } => {
            let _ = origin.send(Job::Remote {
                rid,
                idx,
                result: Err(msg),
            });
        }
        MigrantHome::Evac { rec, .. } => rec.fail(&msg),
    }
}

/// Every replica is permanently down (budget-exhausted declines and/or
/// failed respawns): no engine thread will ever poll the board or finish
/// an evacuated sequence again. Fail every stranded migrant home and
/// every handed-over evacuation record, so each re-homed request gets
/// its one definitive error instead of hanging on a responder nobody
/// owns. Exactly-once is preserved: `take_all` empties the board under
/// its lock and record failure `take`s the responder. Exhaustively
/// pinned by [`board_model`] (`restart_budget_exhaustion_drains_the_board`
/// and the `final_drain: false` negative leg).
fn drain_dead_fleet(router: &RouterState,
                    homes: BTreeMap<u64, Arc<EvacRecord>>) {
    const MSG: &str = "every replica is permanently down; the fleet \
                       cannot finish this sequence";
    for mig in router.take_all() {
        home_fail(mig.home, MSG.to_string());
    }
    for (_, rec) in homes {
        rec.fail(MSG);
    }
}

/// A replica's engine thread is dying on an injected `kill`: drain every
/// checkpoint it holds — residents (evicted mid-sequence), never-placed
/// pending sequences, and parked preemption checkpoints — onto the
/// migration board for surviving replicas to adopt, and re-home every
/// local in-flight responder into a shared [`EvacRecord`] so the answer
/// survives this thread's teardown. Checkpoints carry their per-sequence
/// RNG streams, so evacuated token streams stay bitwise identical to an
/// undisturbed same-seed run. Deadline-carrying requests do not ride
/// along (no survivor enforces their budget): they are answered now by
/// their responders' teardown guarantee. Returns the evacuation records
/// keyed by request id for the supervisor's respawn handover.
fn evacuate_replica(ctx: &EngineCtx, queues: &mut Vec<RunQueue<'_>>,
                    inflight: &mut BTreeMap<u64, Inflight>,
                    xq: &mut CrossQueueScheduler, m: &EngineMetrics)
                    -> BTreeMap<u64, Arc<EvacRecord>> {
    // Deadline-carrying requests: purge their sequences and answer with
    // the teardown error (dropping the responder sends it).
    let doomed: Vec<u64> = inflight
        .iter()
        .filter(|(_, inf)| inf.deadline.is_some())
        .map(|(&rid, _)| rid)
        .collect();
    for rid in doomed {
        purge_request(rid, queues, xq);
        if inflight.remove(&rid).is_some() {
            m.c_errors.inc();
        }
    }
    // Every surviving local request re-homes into an evacuation record.
    let mut homes: BTreeMap<u64, Arc<EvacRecord>> = BTreeMap::new();
    for (rid, inf) in std::mem::take(inflight) {
        homes.insert(rid, Arc::new(EvacRecord::from_inflight(inf)));
    }
    for q in queues.iter_mut() {
        // Stamps of placements a failed retry burst left undrained are
        // popped first, mirroring `quarantine_queue` (the kill itself
        // fires before any placement).
        let placed = q.stepper.take_placements();
        let t_now = xq.now();
        observe_placements(q, &placed, xq, m, t_now);
        let mut cks: Vec<SeqCheckpoint> = Vec::new();
        while let Some(ck) = q.stepper.evict_lowest() {
            cks.push(ck);
        }
        cks.extend(q.stepper.take_pending());
        cks.append(&mut q.parked);
        q.parked_trigger = None;
        for ck in cks {
            let sid = ck.id();
            let home = if let Some(h) = q.remote_routes.remove(&sid) {
                // Adopted sequence: it keeps its existing home (a live
                // origin engine, or another dead replica's record).
                h
            } else if let Some((rid, idx)) = q.routes.remove(&sid) {
                match homes.get(&rid) {
                    Some(rec) => MigrantHome::Evac {
                        rec: rec.clone(),
                        idx,
                    },
                    // Deadline-carrying rids were purged above, so this
                    // is unreachable; drop defensively rather than
                    // strand a checkpoint nobody will answer for.
                    None => continue,
                }
            } else {
                debug_assert!(false, "evacuated checkpoint is unrouted");
                continue;
            };
            ctx.router.post(Migrant {
                ck,
                proto: q.proto.clone(),
                home,
                posted_at: 0.0,
                evacuated: true,
            });
        }
    }
    homes
}

/// Adopt checkpoints posted on the migration board: rebuild (or reuse) a
/// run queue matching each migrant's batch key, re-mint its slot id in
/// this replica's namespace, and record the origin-engine return route.
/// Returns the number adopted (an idle replica uses it to decide whether
/// this poll round found work).
fn adopt_migrants<'m>(ctx: &EngineCtx, models: &'m ModelMap,
                      queues: &mut Vec<RunQueue<'m>>,
                      xq: &mut CrossQueueScheduler, pool: &Arc<StepPool>,
                      cfg: &BatcherConfig, id_base: u64,
                      m: &EngineMetrics, c_evac_global: &Arc<Counter>)
                      -> usize {
    let migrants = ctx.router.take(8);
    let mut adopted = 0usize;
    for mig in migrants {
        let Some(model) = models.get(&mig.proto.model) else {
            // Replicas share one factory, so this is defensive: report
            // home rather than strand the request.
            home_fail(mig.home, format!(
                "migration target lacks model '{}'", mig.proto.model
            ));
            continue;
        };
        let key = mig.proto.batch_key();
        let qi = match queues.iter().position(|q| q.key == key) {
            Some(qi) => qi,
            None => match model.stepper(&mig.proto.sampler, pool.clone()) {
                Ok(mut stepper) => {
                    stepper.set_id_base(id_base);
                    let stepper = match cfg.faults.get(&mig.proto.model) {
                        Some(plan) => Box::new(FaultyStepper::new(
                            stepper, plan.clone()))
                            as Box<dyn Stepper + 'm>,
                        None => stepper,
                    };
                    let sched_id = xq.register(
                        &mig.proto.model,
                        cfg.sched.resolve(&mig.proto.model),
                    );
                    // Local request ids count up from 0; keep the
                    // adopted queue's lane disjoint from them.
                    let lane_seed = match &mig.home {
                        MigrantHome::Engine { rid, .. } => *rid,
                        MigrantHome::Evac { idx, .. } => *idx as u64,
                    };
                    queues.push(RunQueue {
                        key,
                        stepper,
                        sched_id,
                        lane: u64::MAX ^ lane_seed,
                        routes: BTreeMap::new(),
                        remote_routes: BTreeMap::new(),
                        proto: mig.proto.clone(),
                        // No local admission will observe formation:
                        // skip the batch-size observation on first step.
                        formed: true,
                        parked: Vec::new(),
                        parked_trigger: None,
                        retries: 0,
                        not_before: 0.0,
                    });
                    queues.len() - 1
                }
                Err(e) => {
                    home_fail(mig.home, e.to_string());
                    continue;
                }
            },
        };
        let q = &mut queues[qi];
        let sid = q.stepper.adopt(mig.ck);
        if mig.evacuated {
            // Adoption completes an evacuation: the sequence survived
            // its replica. Latency = board time from the death-side
            // post to this adoption.
            ctx.router.count_evacuation();
            m.c_evacuations.inc();
            c_evac_global.inc();
            m.h_evac_latency
                .observe((ctx.router.now_s() - mig.posted_at).max(0.0));
        }
        q.remote_routes.insert(sid, mig.home);
        adopted += 1;
    }
    adopted
}

/// Deliver a `Job::Remote` result on the origin engine: fill the sample
/// slot of the request that migrated the sequence out, answering the
/// request when its last sample lands. A remote failure purges the
/// request's remaining local sequences and answers with an error, once —
/// mirroring what `quarantine_queue` does for a local failure.
#[allow(clippy::too_many_arguments)]
fn deliver_remote(rid: u64, idx: usize,
                  result: std::result::Result<Sample, String>,
                  queues: &mut Vec<RunQueue<'_>>,
                  inflight: &mut BTreeMap<u64, Inflight>,
                  xq: &mut CrossQueueScheduler, m: &EngineMetrics,
                  evac_homes: &mut BTreeMap<u64, Arc<EvacRecord>>) {
    // A request a dead predecessor re-homed on this channel: its
    // evacuation record owns the responder now; route the late remote
    // result into it instead of the (empty) local inflight table.
    if !inflight.contains_key(&rid) {
        if let Some(rec) = evac_homes.get(&rid) {
            match result {
                Ok(sample) => {
                    m.h_nfe.observe(sample.nfe);
                    rec.complete(idx, sample);
                }
                Err(msg) => rec.fail(&msg),
            }
            if rec.done() {
                evac_homes.remove(&rid);
            }
            return;
        }
    }
    match result {
        Ok(sample) => {
            let completed = {
                // A missing request means a deadline sweep or quarantine
                // already answered it; the late sample is dropped.
                let Some(inf) = inflight.get_mut(&rid) else { return };
                if idx >= inf.got.len() || inf.got[idx].is_some() {
                    debug_assert!(false, "remote result misrouted");
                    return;
                }
                m.h_nfe.observe(sample.nfe);
                inf.got[idx] = Some(sample);
                inf.remaining -= 1;
                inf.remaining == 0
            };
            if completed {
                let Some(inf) = inflight.remove(&rid) else { return };
                let wall = inf.enqueued.elapsed().as_secs_f64();
                m.h_latency.observe(wall);
                m.c_samples.add(inf.got.len() as u64);
                let samples: Vec<Sample> =
                    inf.got.into_iter().flatten().collect();
                inf.reply.send(Ok(GenResponse {
                    model: inf.model,
                    samples,
                    wall_s: wall,
                }));
            }
        }
        Err(msg) => {
            purge_request(rid, queues, xq);
            let Some(inf) = inflight.remove(&rid) else { return };
            m.c_errors.inc();
            inf.reply.send(Err(anyhow!(
                "model '{}' failed while serving this request on a \
                 migration target: {msg}",
                inf.model
            )));
        }
    }
}

/// Remove every local sequence of one request, wherever it sits —
/// the per-request inner loop of `sweep_deadlines`, reused when a
/// migrated-out sibling fails remotely.
fn purge_request(rid: u64, queues: &mut Vec<RunQueue<'_>>,
                 xq: &mut CrossQueueScheduler) {
    for q in queues.iter_mut() {
        let sids: Vec<SlotId> = q
            .routes
            .iter()
            .filter(|&(_, &(r, _))| r == rid)
            .map(|(&sid, _)| sid)
            .collect();
        if sids.is_empty() {
            continue;
        }
        let mut unplaced = 0usize;
        for &sid in &sids {
            if q.stepper.evict(sid).is_some() {
                // Resident: stamp was popped at placement.
            } else if q.stepper.remove_pending(sid) {
                unplaced += 1;
            } else {
                q.parked.retain(|ck| ck.id() != sid);
            }
            q.routes.remove(&sid);
        }
        if unplaced > 0 {
            xq.cancel_enqueue(q.sched_id, q.lane, rid, unplaced);
        }
    }
}
// lint: end-serve-region

fn run_score(models: &ModelMap, req: &ScoreRequest, rng: &mut Pcg)
             -> Result<ScoreResponse> {
    let model = models
        .get(&req.model)
        .ok_or_else(|| anyhow!("unknown model '{}'", req.model))?;
    let d = model.seq_len();
    if req.tokens.len() != d {
        return Err(anyhow!("tokens length {} != D {d}", req.tokens.len()));
    }
    // Range-check before the likelihood tables index logits rows with
    // these values (a mask id from a max_outer-cut sample, or any
    // out-of-range token, must error here instead of panicking the
    // engine thread).
    let v = model.vocab() as i32;
    if let Some(t) = req.tokens.iter().find(|&&t| t < 0 || t >= v) {
        return Err(anyhow!(
            "token {t} out of range 0..{v} (incomplete samples carry the \
             mask id and cannot be scored)"
        ));
    }
    let sigma = match &req.sigma {
        Some(s) => {
            if s.len() != d {
                return Err(anyhow!("sigma length {} != D {d}", s.len()));
            }
            let mut seen = vec![false; d];
            for &p in s {
                if p < 0 || p >= d as i32 || seen[p as usize] {
                    return Err(anyhow!(
                        "sigma must be a permutation of 0..{d}"
                    ));
                }
                seen[p as usize] = true;
            }
            s.clone()
        }
        None => Pcg::new(req.seed.unwrap_or_else(|| rng.next_u64()))
            .permutation(d),
    };
    let ll = model.log_likelihood(&req.tokens, &sigma)?;
    let posterior = if req.with_posterior {
        Some(model.rejection_posterior(&req.tokens, &sigma)?)
    } else {
        None
    };
    Ok(ScoreResponse { log_likelihood: ll, sigma, rejection_posterior: posterior })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::mock::MockModel;
    use crate::engine::{MdmParams, SpecParams};
    use std::time::Duration;

    fn mock_coordinator_with(sched: SchedConfig) -> Coordinator {
        Coordinator::start(
            || {
                let mut m: ModelMap = BTreeMap::new();
                m.insert(
                    "mock".into(),
                    Box::new(MockModel::new(8, 4, 5)) as Box<dyn EngineModel>,
                );
                let mut tiny = MockModel::new(8, 4, 5);
                tiny.buckets = vec![1, 2, 4];
                m.insert("tiny".into(),
                         Box::new(tiny) as Box<dyn EngineModel>);
                Ok(m)
            },
            BatcherConfig {
                max_wait: Duration::from_millis(1),
                sched,
                ..Default::default()
            },
        )
        .unwrap()
    }

    fn mock_coordinator() -> Coordinator {
        mock_coordinator_with(SchedConfig::default())
    }

    #[test]
    fn generate_speculative_roundtrip() {
        let c = mock_coordinator();
        let resp = c
            .generate(GenRequest {
                model: "mock".into(),
                n_samples: 3,
                sampler: SamplerChoice::Speculative(SpecParams::default()),
                ..Default::default()
            })
            .unwrap();
        assert_eq!(resp.samples.len(), 3);
        assert!(resp.samples[0].nfe > 0.0);
        c.shutdown();
    }

    #[test]
    fn generate_mdm_roundtrip() {
        let c = mock_coordinator();
        let resp = c
            .generate(GenRequest {
                model: "mock".into(),
                n_samples: 2,
                sampler: SamplerChoice::Mdm(MdmParams::default()),
                ..Default::default()
            })
            .unwrap();
        assert_eq!(resp.samples.len(), 2);
        c.shutdown();
    }

    #[test]
    fn unknown_model_errors() {
        let c = mock_coordinator();
        let err = c
            .generate(GenRequest {
                model: "nope".into(),
                n_samples: 1,
                ..Default::default()
            })
            .unwrap_err();
        assert!(err.to_string().contains("unknown model"));
        c.shutdown();
    }

    #[test]
    fn oversized_request_chunks_through_bucket_ladder() {
        // 9 samples on a model whose largest bucket is 4: the scheduler
        // parks the overflow in its pending queue and backfills — the
        // request round-trips fully instead of truncating or inventing an
        // uncompiled batch size.
        let c = mock_coordinator();
        let resp = c
            .generate(GenRequest {
                model: "tiny".into(),
                n_samples: 9,
                ..Default::default()
            })
            .unwrap();
        assert_eq!(resp.samples.len(), 9);
        for s in &resp.samples {
            assert_eq!(s.tokens.len(), 8);
            assert!(s.tokens.iter().all(|&t| (0..4).contains(&t)));
        }
        assert!(c.metrics.counter("backfills").get() >= 5,
                "expected pending-queue backfills");
        c.shutdown();
    }

    #[test]
    fn score_roundtrip_and_posterior_sums_to_one() {
        let c = mock_coordinator();
        let resp = c
            .score(ScoreRequest {
                model: "mock".into(),
                tokens: vec![0, 1, 2, 3, 0, 1, 2, 3],
                sigma: None,
                seed: Some(7),
                with_posterior: true,
            })
            .unwrap();
        assert!(resp.log_likelihood < 0.0);
        let post = resp.rejection_posterior.unwrap();
        assert!((post.iter().sum::<f64>() - 1.0).abs() < 1e-9);
        c.shutdown();
    }

    #[test]
    fn concurrent_requests_all_served() {
        let c = mock_coordinator();
        let mut handles = Vec::new();
        for i in 0..6 {
            let cc = c.clone();
            handles.push(std::thread::spawn(move || {
                cc.generate(GenRequest {
                    model: "mock".into(),
                    n_samples: 1,
                    seed: i,
                    ..Default::default()
                })
                .unwrap()
            }));
        }
        for h in handles {
            let r = h.join().unwrap();
            assert_eq!(r.samples.len(), 1);
        }
        assert!(c.metrics.counter("requests").get() >= 6);
        assert!(c.metrics.counter("scheduler_steps").get() >= 1);
        c.shutdown();
    }

    #[test]
    fn deterministic_requests_reproduce() {
        let c = mock_coordinator();
        let req = GenRequest {
            model: "mock".into(),
            n_samples: 2,
            seed: 99,
            deterministic: true,
            ..Default::default()
        };
        let a = c.generate(req.clone()).unwrap();
        let b = c.generate(req).unwrap();
        assert_eq!(a.samples[0].tokens, b.samples[0].tokens);
        assert_eq!(a.samples[1].tokens, b.samples[1].tokens);
        c.shutdown();
    }

    #[test]
    fn deterministic_requests_are_immune_to_interleaving() {
        // A deterministic request must produce identical samples whether
        // or not unrelated live traffic consumed engine entropy first —
        // the old path burned `rng.next_u64()` even when deterministic.
        let c = mock_coordinator();
        let det = GenRequest {
            model: "mock".into(),
            n_samples: 2,
            seed: 1234,
            deterministic: true,
            ..Default::default()
        };
        let a = c.generate(det.clone()).unwrap();
        for i in 0..3 {
            c.generate(GenRequest {
                model: "mock".into(),
                n_samples: 1,
                seed: i,
                ..Default::default()
            })
            .unwrap();
        }
        let b = c.generate(det).unwrap();
        for (x, y) in a.samples.iter().zip(&b.samples) {
            assert_eq!(x.tokens, y.tokens);
        }
        c.shutdown();
    }

    #[test]
    fn live_requests_with_same_seed_differ() {
        // Non-deterministic requests mix the request index into their RNG
        // stream: same seed twice must not replay the same samples.
        let c = mock_coordinator();
        let req = GenRequest {
            model: "mock".into(),
            n_samples: 2,
            seed: 7,
            ..Default::default()
        };
        let a = c.generate(req.clone()).unwrap();
        let b = c.generate(req).unwrap();
        assert_ne!(
            (a.samples[0].tokens.clone(), a.samples[1].tokens.clone()),
            (b.samples[0].tokens.clone(), b.samples[1].tokens.clone())
        );
        c.shutdown();
    }

    #[test]
    fn shutdown_drains_admitted_requests() {
        // A request the engine has already admitted must still be answered
        // after shutdown() — the loop drains in-flight work before exiting.
        let c = mock_coordinator();
        let cc = c.clone();
        let h = std::thread::spawn(move || {
            cc.generate(GenRequest {
                model: "tiny".into(),
                n_samples: 9,
                ..Default::default()
            })
        });
        while c.metrics.counter("requests").get() < 1 {
            // lint: allow(clock-discipline) — test polls a live engine
            // thread; no virtual clock drives it.
            std::thread::sleep(Duration::from_millis(1));
        }
        c.shutdown();
        let resp = h.join().unwrap().unwrap();
        assert_eq!(resp.samples.len(), 9);
    }

    #[test]
    fn scheduler_metrics_are_exported() {
        let c = mock_coordinator();
        c.generate(GenRequest {
            model: "mock".into(),
            n_samples: 2,
            ..Default::default()
        })
        .unwrap();
        let snap = c.metrics.snapshot();
        let hists = snap.get("histograms").unwrap();
        for key in ["slot_occupancy", "step_latency_s", "pending_depth",
                    "queue_credit", "queue_wait_s", "step_model_s",
                    "step_draw_s", "step_lse_s", "step_accept_s"] {
            let count = hists
                .get(key)
                .and_then(|h| h.get("count"))
                .and_then(|c| c.as_f64())
                .unwrap_or(0.0);
            assert!(count >= 1.0, "missing histogram {key}");
        }
        let counters = snap.get("counters").unwrap();
        for key in ["slo_violations", "shed_requests", "shed_seqs",
                    "preemptions", "resume_steps", "preempt_fires",
                    "engine_faults", "retries", "deadline_sheds",
                    "breaker_state"] {
            assert!(counters.get(key).and_then(|c| c.as_f64()).is_some(),
                    "missing counter {key}");
        }
        c.shutdown();
    }

    #[test]
    fn backpressure_sheds_over_full_queue() {
        // tiny's policy bounds pending depth at 5 and sheds. A request
        // with more sequences than the bound can never fit, so it is
        // rejected deterministically no matter how fast the engine
        // drains — no wall-clock race. Requests within the bound are
        // served; dynamic shed-under-load timing is covered in exact
        // virtual time by tests/sched_sim.rs.
        let mut sched = SchedConfig::default();
        sched.per_model.insert("tiny".into(), QueuePolicy {
            max_pending: 5,
            shed_on_full: true,
            ..QueuePolicy::default()
        });
        let c = mock_coordinator_with(sched);
        let err = c
            .generate(GenRequest {
                model: "tiny".into(),
                n_samples: 6,
                ..Default::default()
            })
            .unwrap_err();
        // Exact suffix: the HTTP layer's 429 mapping keys on it.
        assert!(err.to_string().ends_with(SHED_ERROR_SUFFIX), "{err}");
        assert!(err.to_string().contains("6 sequences requested"), "{err}");
        // Both shed granularities: 1 request carrying 6 sequences.
        assert_eq!(c.metrics.counter("shed_requests").get(), 1);
        assert_eq!(c.metrics.counter("shed_seqs").get(), 6);
        // Within the bound, admission (and the request) succeeds.
        let ok = c
            .generate(GenRequest {
                model: "tiny".into(),
                n_samples: 5,
                seed: 2,
                ..Default::default()
            })
            .unwrap();
        assert_eq!(ok.samples.len(), 5);
        assert_eq!(c.metrics.counter("shed_requests").get(), 1);
        c.shutdown();
    }

    #[test]
    fn sibling_batch_keys_share_their_models_allocation() {
        // Two batch keys of one model (deterministic + live, which never
        // share a run queue) in flight concurrently with a second model:
        // the per-model rotation cursor must reach every variant, so all
        // three requests drain (a starved variant would hang its client
        // forever on the blocking reply channel).
        let c = mock_coordinator();
        let mut handles = Vec::new();
        for (model, det) in [("mock", true), ("mock", false),
                             ("tiny", false)] {
            let cc = c.clone();
            handles.push(std::thread::spawn(move || {
                cc.generate(GenRequest {
                    model: model.into(),
                    n_samples: 40,
                    seed: 9,
                    deterministic: det,
                    ..Default::default()
                })
                .unwrap()
            }));
        }
        for h in handles {
            assert_eq!(h.join().unwrap().samples.len(), 40);
        }
        c.shutdown();
    }

    /// Priority classes order work within one run queue: a later
    /// high-priority request overtakes an earlier low-priority one's
    /// queued sequences. The high-priority request is sent only after
    /// the low one's admission is observed (requests counter), and the
    /// engine's idle admission window (500ms, measured from that same
    /// admission) holds the first step back until both are queued — so
    /// the ordering decision is purely the pending queue's, not a
    /// wall-clock race. (The exact-ordering pin without any window
    /// machinery lives at the scheduler level:
    /// `engine::scheduler::tests::priority_orders_pending_within_queue`.)
    #[test]
    fn priority_overtakes_within_a_run_queue() {
        let c = Coordinator::start(
            || {
                let mut m: ModelMap = BTreeMap::new();
                let mut tiny = MockModel::new(8, 4, 5);
                tiny.buckets = vec![1];
                m.insert("tiny".into(),
                         Box::new(tiny) as Box<dyn EngineModel>);
                Ok(m)
            },
            BatcherConfig {
                max_wait: Duration::from_millis(500),
                ..Default::default()
            },
        )
        .unwrap();
        let low = c.clone();
        let t_low = std::thread::spawn(move || {
            let r = low
                .generate(GenRequest {
                    model: "tiny".into(),
                    n_samples: 4,
                    seed: 1,
                    priority: Some(0),
                    ..Default::default()
                })
                .unwrap();
            // lint: allow(clock-discipline) — test compares real reply
            // completion order across threads.
            (Instant::now(), r)
        });
        // Wait until the engine has admitted the low-priority request
        // (its 500ms pre-step window starts there), then enter the same
        // live run queue with a higher priority class.
        while c.metrics.counter("requests").get() < 1 {
            // lint: allow(clock-discipline) — test polls a live engine
            // thread; no virtual clock drives it.
            std::thread::sleep(Duration::from_millis(1));
        }
        let hi = c.clone();
        let t_hi = std::thread::spawn(move || {
            let r = hi
                .generate(GenRequest {
                    model: "tiny".into(),
                    n_samples: 1,
                    seed: 2,
                    priority: Some(9),
                    ..Default::default()
                })
                .unwrap();
            // lint: allow(clock-discipline) — test compares real reply
            // completion order across threads.
            (Instant::now(), r)
        });
        let (done_low, r_low) = t_low.join().unwrap();
        let (done_hi, r_hi) = t_hi.join().unwrap();
        assert_eq!(r_low.samples.len(), 4);
        assert_eq!(r_hi.samples.len(), 1);
        // Capacity 1: the priority-9 sequence runs before the
        // priority-0 request's queued tail, so its reply lands first.
        assert!(done_hi < done_low,
                "high-priority request must finish before the \
                 low-priority bulk request");
        c.shutdown();
    }

    /// Graceful shutdown with preempted residents: a drain must resume
    /// and answer every checkpointed sequence — nothing lost, nothing
    /// answered twice (a double answer would panic the routing table).
    #[test]
    fn shutdown_drains_preempted_checkpoints() {
        // Any observed wait blows a 1ns SLO's boost ceiling, and one
        // pressured round suffices: preemption fires as soon as the slo
        // queue has a placement behind pending work.
        let mut sched =
            SchedConfig { preempt_after: 1, ..SchedConfig::default() };
        sched.per_model.insert("slo".into(), QueuePolicy {
            weight: 4.0,
            slo_p95_s: Some(1e-9),
            ..QueuePolicy::default()
        });
        sched.per_model.insert("bulk".into(), QueuePolicy {
            preempt: true,
            ..QueuePolicy::default()
        });
        let c = Coordinator::start(
            || {
                let mut m: ModelMap = BTreeMap::new();
                let mut bulk = MockModel::new(64, 4, 5);
                bulk.buckets = vec![1, 2, 4, 8, 16];
                m.insert("bulk".into(),
                         Box::new(bulk) as Box<dyn EngineModel>);
                let mut slo = MockModel::new(8, 4, 9);
                slo.buckets = vec![1];
                m.insert("slo".into(),
                         Box::new(slo) as Box<dyn EngineModel>);
                Ok(m)
            },
            BatcherConfig {
                max_wait: Duration::from_millis(1),
                sched,
                ..Default::default()
            },
        )
        .unwrap();
        // Long bulk request: 32 sequences of 64 positions (bucket 16 +
        // pending overflow) keep residents mid-sequence long past the
        // SLO burst's arrival.
        let bulk = c.clone();
        let t_bulk = std::thread::spawn(move || {
            bulk.generate(GenRequest {
                model: "bulk".into(),
                n_samples: 32,
                sampler: SamplerChoice::Speculative(SpecParams {
                    window: crate::engine::Window::Constant(1),
                    ..Default::default()
                }),
                ..Default::default()
            })
        });
        while c.metrics.counter("scheduler_steps").get() < 1 {
            // lint: allow(clock-discipline) — test polls a live engine
            // thread; no virtual clock drives it.
            std::thread::sleep(Duration::from_millis(1));
        }
        // SLO burst: its first placements arm the (unmeetable) SLO and
        // trigger preemption of the bulk residents.
        let slo = c.clone();
        let t_slo = std::thread::spawn(move || {
            slo.generate(GenRequest {
                model: "slo".into(),
                n_samples: 8,
                sampler: SamplerChoice::Speculative(SpecParams {
                    window: crate::engine::Window::Constant(1),
                    ..Default::default()
                }),
                seed: 3,
                ..Default::default()
            })
        });
        // Wait for the preemption to actually fire, then shut down while
        // the checkpoints are (likely still) parked.
        // lint: allow(clock-discipline) — real-time watchdog for a test
        // that would otherwise hang on a regression.
        let t0 = Instant::now();
        while c.metrics.counter("preemptions").get() == 0 {
            assert!(t0.elapsed() < Duration::from_secs(30),
                    "preemption never fired");
            // lint: allow(clock-discipline) — test polls a live engine
            // thread; no virtual clock drives it.
            std::thread::sleep(Duration::from_millis(1));
        }
        c.shutdown();
        let r_bulk = t_bulk.join().unwrap().unwrap();
        let r_slo = t_slo.join().unwrap().unwrap();
        // Every checkpointed sequence was resumed and answered exactly
        // once (token lengths prove completion, not valve cut-off:
        // Constant(1) windows never hit max_outer at these depths).
        assert_eq!(r_bulk.samples.len(), 32);
        assert_eq!(r_slo.samples.len(), 8);
        for s in r_bulk.samples.iter() {
            assert!(s.tokens.iter().all(|&t| (0..4).contains(&t)),
                    "preempted sequence retired incomplete: {:?}",
                    s.tokens);
        }
        assert!(c.metrics.counter("preemptions").get() >= 1);
        assert!(c.metrics.counter("resume_steps").get() >= 1,
                "drain must place resumed checkpoints back into slots");
        c.shutdown();
    }

    #[test]
    fn per_model_policy_does_not_change_results() {
        // Weighted scheduling must be behavior-preserving for request
        // semantics: a deterministic request returns identical samples
        // under an aggressive per-model policy and under the default.
        let mut sched = SchedConfig::default();
        sched
            .apply_cli("mock=weight:8,slo:0.001,burst:1; tiny=weight:1")
            .unwrap();
        let weighted = mock_coordinator_with(sched);
        let plain = mock_coordinator();
        let req = GenRequest {
            model: "mock".into(),
            n_samples: 2,
            seed: 4242,
            deterministic: true,
            ..Default::default()
        };
        let a = weighted.generate(req.clone()).unwrap();
        let b = plain.generate(req).unwrap();
        for (x, y) in a.samples.iter().zip(&b.samples) {
            assert_eq!(x.tokens, y.tokens);
        }
        weighted.shutdown();
        plain.shutdown();
    }

    /// Mock two-model coordinator with a `--fault-plan`-style spec.
    fn chaos_coordinator(faults: &str, sched: SchedConfig) -> Coordinator {
        Coordinator::start(
            || {
                let mut m: ModelMap = BTreeMap::new();
                m.insert(
                    "mock".into(),
                    Box::new(MockModel::new(8, 4, 5)) as Box<dyn EngineModel>,
                );
                let mut tiny = MockModel::new(8, 4, 5);
                tiny.buckets = vec![1, 2, 4];
                m.insert("tiny".into(),
                         Box::new(tiny) as Box<dyn EngineModel>);
                Ok(m)
            },
            BatcherConfig {
                max_wait: Duration::from_millis(1),
                sched,
                faults: crate::engine::fault::parse_fault_cli(faults)
                    .unwrap(),
                ..Default::default()
            },
        )
        .unwrap()
    }

    #[test]
    fn transient_fault_retries_and_succeeds() {
        let mut sched = SchedConfig::default();
        sched.supervise.backoff_s = 0.001;
        let c = chaos_coordinator("mock=err@1", sched);
        let resp = c
            .generate(GenRequest {
                model: "mock".into(),
                n_samples: 2,
                ..Default::default()
            })
            .unwrap();
        assert_eq!(resp.samples.len(), 2);
        assert!(c.metrics.counter("retries").get() >= 1,
                "transient fault must be retried");
        assert_eq!(c.metrics.counter("engine_faults").get(), 0,
                   "a recovered burst is not a definitive fault");
        c.shutdown();
    }

    #[test]
    fn fatal_fault_quarantines_only_its_queue() {
        // A fault-free reference run for the surviving request.
        let det = GenRequest {
            model: "mock".into(),
            n_samples: 2,
            seed: 77,
            deterministic: true,
            ..Default::default()
        };
        let clean = mock_coordinator();
        let want = clean.generate(det.clone()).unwrap();
        clean.shutdown();

        // tiny's first step dies fatally; mock shares the engine thread.
        let c = chaos_coordinator("tiny=panic@1", SchedConfig::default());
        let cc = c.clone();
        let doomed = std::thread::spawn(move || {
            cc.generate(GenRequest {
                model: "tiny".into(),
                n_samples: 2,
                ..Default::default()
            })
        });
        let got = c.generate(det).unwrap();
        let err = doomed.join().unwrap().unwrap_err();
        assert!(err.to_string().contains("failed while serving"), "{err}");
        assert!(c.metrics.counter("engine_faults").get() >= 1);
        // The surviving request's token streams are bitwise identical
        // to the fault-free run — quarantine touched one queue only.
        assert_eq!(want.samples.len(), got.samples.len());
        for (x, y) in want.samples.iter().zip(&got.samples) {
            assert_eq!(x.tokens, y.tokens);
        }
        c.shutdown();
    }

    #[test]
    fn breaker_trips_fast_fails_and_reports_health() {
        let mut sched = SchedConfig::default();
        sched.supervise.breaker_threshold = 1;
        sched.supervise.breaker_cooldown_s = 100.0;
        let c = chaos_coordinator("tiny=panic@1", sched);
        let err = c
            .generate(GenRequest {
                model: "tiny".into(),
                n_samples: 1,
                ..Default::default()
            })
            .unwrap_err();
        assert!(err.to_string().contains("failed while serving"), "{err}");
        // Breaker open: new admits fail fast with the 503 suffix and a
        // retry hint, without touching the engine's queues.
        let err = c
            .generate(GenRequest {
                model: "tiny".into(),
                n_samples: 1,
                ..Default::default()
            })
            .unwrap_err();
        assert!(err.to_string().ends_with(BREAKER_ERROR_SUFFIX), "{err}");
        assert!(err.to_string().contains("retry after"), "{err}");
        // /healthz degrades: overall not ok, per-model states reported.
        let h = c.health().unwrap();
        assert_eq!(h.get("ok").and_then(|b| b.as_bool()), Some(false));
        let models = h.get("models").unwrap();
        assert_eq!(models.get("tiny").and_then(|s| s.as_str()),
                   Some("open"));
        assert_eq!(models.get("mock").and_then(|s| s.as_str()),
                   Some("closed"));
        // Healthy models keep serving while tiny's breaker is open.
        let ok = c
            .generate(GenRequest {
                model: "mock".into(),
                n_samples: 1,
                ..Default::default()
            })
            .unwrap();
        assert_eq!(ok.samples.len(), 1);
        c.shutdown();
    }

    #[test]
    fn deadline_expiry_answers_with_deadline_error() {
        // tiny's first step stalls 300ms; the request's 100ms deadline
        // expires mid-flight, so the between-steps sweep answers it.
        // Constant(1) windows on D=8 guarantee the stalled step cannot
        // finish the sequences first.
        let c =
            chaos_coordinator("tiny=stall@1:0.3", SchedConfig::default());
        let err = c
            .generate(GenRequest {
                model: "tiny".into(),
                n_samples: 2,
                sampler: SamplerChoice::Speculative(SpecParams {
                    window: crate::engine::Window::Constant(1),
                    ..Default::default()
                }),
                deadline_ms: Some(100),
                ..Default::default()
            })
            .unwrap_err();
        assert!(err.to_string().ends_with(DEADLINE_ERROR_SUFFIX), "{err}");
        assert_eq!(c.metrics.counter("deadline_sheds").get(), 1);
        assert_eq!(c.metrics.counter("shed_requests").get(), 0,
                   "deadline sheds are not backpressure sheds");
        c.shutdown();
    }

    /// Engine model whose stepper construction panics — an uncontained
    /// admission-path crash that kills the whole engine thread.
    struct PanickingModel;

    impl EngineModel for PanickingModel {
        fn seq_len(&self) -> usize {
            8
        }
        fn vocab(&self) -> usize {
            4
        }
        fn has_verify(&self) -> bool {
            true
        }
        fn max_bucket(&self) -> usize {
            4
        }
        fn info(&self) -> Json {
            Json::obj(vec![])
        }
        fn sample(&self, _: &[Prompt], _: &SamplerChoice, _: &mut Pcg)
                  -> Result<Vec<Sample>> {
            Err(anyhow!("unused"))
        }
        fn stepper<'a>(&'a self, _: &SamplerChoice, _: Arc<StepPool>)
                       -> Result<Box<dyn Stepper + 'a>> {
            panic!("injected: stepper construction exploded")
        }
        fn log_likelihood(&self, _: &[i32], _: &[i32]) -> Result<f64> {
            Err(anyhow!("unused"))
        }
        fn rejection_posterior(&self, _: &[i32], _: &[i32])
                               -> Result<Vec<f64>> {
            Err(anyhow!("unused"))
        }
    }

    /// The orphaned-client pin: an engine thread dying with a request in
    /// flight must surface as an explicit `Err` from `generate()` (the
    /// responder guard fires during unwind) — never a hang — and later
    /// requests must see the dead engine as an error too.
    #[test]
    fn engine_death_answers_inflight_with_error() {
        let c = Coordinator::start(
            || {
                let mut m: ModelMap = BTreeMap::new();
                m.insert("bad".into(),
                         Box::new(PanickingModel) as Box<dyn EngineModel>);
                Ok(m)
            },
            BatcherConfig {
                max_wait: Duration::from_millis(1),
                ..Default::default()
            },
        )
        .unwrap();
        let err = c
            .generate(GenRequest {
                model: "bad".into(),
                n_samples: 1,
                ..Default::default()
            })
            .unwrap_err();
        assert!(err.to_string().contains("engine teardown"), "{err}");
        let err = c
            .generate(GenRequest {
                model: "bad".into(),
                n_samples: 1,
                ..Default::default()
            })
            .unwrap_err();
        assert!(
            err.to_string().contains("engine thread gone")
                || err.to_string().contains("engine dropped reply"),
            "{err}"
        );
    }

    /// Cloneable factory for sharded starts: each replica thread builds
    /// its own identical model map.
    fn sharded_mock(n: usize) -> Coordinator {
        Coordinator::start_sharded(
            || {
                let mut m: ModelMap = BTreeMap::new();
                m.insert(
                    "mock".into(),
                    Box::new(MockModel::new(8, 4, 5)) as Box<dyn EngineModel>,
                );
                Ok(m)
            },
            BatcherConfig {
                max_wait: Duration::from_millis(1),
                ..Default::default()
            },
            n,
        )
        .unwrap()
    }

    #[test]
    fn sharded_roundtrips_and_answers_every_request_once() {
        let c = sharded_mock(2);
        assert_eq!(c.n_engines(), 2);
        // Concurrent clients spread across replicas by the router; every
        // request must come back answered, exactly once each.
        let mut handles = Vec::new();
        for k in 0..8u64 {
            let c = c.clone();
            handles.push(std::thread::spawn(move || {
                c.generate(GenRequest {
                    model: "mock".into(),
                    n_samples: 2,
                    seed: k,
                    deterministic: true,
                    ..Default::default()
                })
            }));
        }
        for h in handles {
            let resp = h.join().unwrap().unwrap();
            assert_eq!(resp.samples.len(), 2);
        }
        c.shutdown();
    }

    /// The response of a deterministic request depends only on the
    /// request — not on which replica served it. Sharding (including its
    /// per-replica SlotId namespace and RNG stream split) must leave
    /// token streams bitwise identical to the single-engine path.
    #[test]
    fn sharded_deterministic_response_matches_single_engine() {
        let req = || GenRequest {
            model: "mock".into(),
            n_samples: 3,
            seed: 1234,
            deterministic: true,
            ..Default::default()
        };
        let single = mock_coordinator();
        let a = single.generate(req()).unwrap();
        single.shutdown();
        let sharded = sharded_mock(3);
        let b = sharded.generate(req()).unwrap();
        sharded.shutdown();
        let toks =
            |r: &GenResponse| -> Vec<Vec<i32>> {
                r.samples.iter().map(|s| s.tokens.clone()).collect()
            };
        assert_eq!(toks(&a), toks(&b),
                   "replica choice changed a deterministic token stream");
    }

    /// Sharded `/healthz` merges replica views: per-replica entries under
    /// `engines`, worst-per-model summary on top, router counters along.
    #[test]
    fn sharded_health_reports_per_replica_views() {
        let c = sharded_mock(2);
        let h = c.health().unwrap();
        assert_eq!(h.get("ok").and_then(|b| b.as_bool()), Some(true));
        let Some(Json::Arr(engines)) = h.get("engines") else {
            panic!("missing engines array: {h:?}")
        };
        assert_eq!(engines.len(), 2);
        for e in engines {
            assert_eq!(e.get("ok").and_then(|b| b.as_bool()), Some(true));
        }
        assert!(h.get("migrations").is_some());
        assert!(h.get("steals").is_some());
        c.shutdown();
    }

    /// Replica loss end to end on the live sharded path: a `kill@2`
    /// fault terminates the serving replica mid-request; its resident
    /// checkpoints evacuate through the router board, a survivor adopts
    /// them and answers the re-homed request directly, and the
    /// supervisor respawns the victim under backoff. The caller sees a
    /// normal response, bitwise identical to a fault-free single-engine
    /// run — the death is invisible. (The kill plan re-arms on every
    /// fresh run queue — adopters included — so the generous restart
    /// budget lets the fleet grind through repeated deaths; each engine
    /// lifetime makes at least one step of progress before its kill.)
    #[test]
    fn sharded_kill_evacuates_to_survivor_and_restarts() {
        let req = || GenRequest {
            model: "mock".into(),
            n_samples: 3,
            seed: 4321,
            deterministic: true,
            ..Default::default()
        };
        let calm = mock_coordinator();
        let want = calm.generate(req()).unwrap();
        calm.shutdown();

        let mut sched = SchedConfig::default();
        sched.supervise.max_retries = 10;
        sched.supervise.backoff_s = 0.005;
        sched.supervise.backoff_mult = 1.0;
        let c = Coordinator::start_sharded(
            || {
                let mut m: ModelMap = BTreeMap::new();
                m.insert(
                    "mock".into(),
                    Box::new(MockModel::new(8, 4, 5)) as Box<dyn EngineModel>,
                );
                Ok(m)
            },
            BatcherConfig {
                max_wait: Duration::from_millis(1),
                sched,
                faults: crate::engine::fault::parse_fault_cli("mock=kill@2")
                    .unwrap(),
                ..Default::default()
            },
            2,
        )
        .unwrap();
        let resp = c.generate(req()).unwrap();
        assert_eq!(resp.samples.len(), 3);
        let toks = |r: &GenResponse| -> Vec<Vec<i32>> {
            r.samples.iter().map(|s| s.tokens.clone()).collect()
        };
        assert_eq!(toks(&want), toks(&resp),
                   "evacuated streams must be bitwise identical to a \
                    fault-free run");
        assert!(c.metrics.counter("evacuations").get() >= 1,
                "the kill must evacuate checkpoints onto the board");
        // The supervisor grants the respawn after its backoff; poll
        // bounded so a dead supervisor fails the test instead of
        // hanging it.
        let mut restarted = false;
        for _ in 0..2000 {
            if c.metrics.counter("replica_restarts").get() >= 1 {
                restarted = true;
                break;
            }
            // lint: allow(clock-discipline) — test polls the live
            // supervisor thread; no virtual clock drives it.
            std::thread::sleep(Duration::from_millis(5));
        }
        assert!(restarted,
                "the killed replica never restarted under supervision");
        c.shutdown();
    }

    /// `start_sharded(.., 1)` collapses to the single-engine path: no
    /// router, and metric names keep their historical (unsuffixed) form.
    #[test]
    fn sharded_n1_is_single_engine() {
        let c = sharded_mock(1);
        assert_eq!(c.n_engines(), 1);
        assert!(c.router().is_none());
        let resp = c
            .generate(GenRequest {
                model: "mock".into(),
                n_samples: 1,
                ..Default::default()
            })
            .unwrap();
        assert_eq!(resp.samples.len(), 1);
        assert!(c.metrics.counter("requests").get() >= 1);
        c.shutdown();
    }
}
