//! Weighted SLO-aware cross-queue scheduling core.
//!
//! The engine thread drives one continuous-batching run queue per
//! `batch_key`, but until this module the *selector* across queues was
//! plain round-robin: a latency-sensitive small-vocab queue could stall
//! behind a bulk GPT2-scale queue regardless of traffic mix. This module
//! is that selector, factored out of the engine loop into **pure state
//! plus an injected [`Clock`]** so the same code is driven by wall time
//! in production and by virtual time in `tests/sched_sim.rs` (exact,
//! sleep-free latency/fairness assertions).
//!
//! ## Algorithm
//!
//! Deficit-style weighted fair queuing in virtual-time form. Every queue
//! accrues service entitlement proportional to its [`QueuePolicy::weight`];
//! we track the inverse — normalized consumed service
//! `vtime_q = Σ step_cost / (weight_q · boost_q)` — and each round serve
//! the ready queue with the smallest adjusted `vtime`. This is equivalent
//! to credit accrual with an adaptive top-up (the queue farthest below its
//! entitlement is exactly the one with minimal `vtime`) without the
//! top-up loop, and it converges long-run *time* shares to the configured
//! weight ratios under any mix of per-step costs. The engine reports each
//! step's observed cost back via [`CrossQueueScheduler::report_step`];
//! the simulation harness reports synthetic costs.
//!
//! Layered on the base policy:
//!
//! * **SLO boost** — each queue keeps an EWMA of its observed queue waits
//!   (enqueue → first slot placement, fed by
//!   [`CrossQueueScheduler::placed`]). A queue whose EWMA exceeds its
//!   `slo_p95_s` is charged at `weight · boost` (boost = EWMA/SLO, capped
//!   at `max_boost`) and gets a pick-time priority bonus, so it wins
//!   rounds until its waits recover; every individual wait above the SLO
//!   increments the `slo_violations` counter.
//! * **Burst bound** — `max_consecutive` caps how many rounds one queue
//!   can win back-to-back while another queue is ready, bounding the
//!   service gap a high-weight queue can impose.
//! * **Starvation backstop** — a ready queue passed over `starve_after`
//!   consecutive rounds is served unconditionally (most-starved first,
//!   one per round), so with `k` simultaneously starved queues no
//!   non-empty queue ever waits more than `starve_after + k - 1` rounds
//!   — bounded by `starve_after + n_queues` regardless of weights,
//!   boosts, or costs (property-tested in `tests/sched_sim.rs`).
//! * **Admission backpressure** — [`CrossQueueScheduler::try_enqueue`]
//!   bounds per-queue pending depth at `max_pending`; an over-full queue
//!   either sheds the request (`shed_on_full`, counted at both
//!   granularities: `shed_requests` requests / `shed_seqs` sequences) or
//!   keeps queueing.
//! * **Preemption** — when an SLO queue's pressure sits at its boost
//!   ceiling (wait EWMA >= slo · `max_boost`) with pending work for
//!   [`SchedConfig::preempt_after`] consecutive rounds — boosting alone
//!   freed nothing — [`CrossQueueScheduler::preempt_check`] names a
//!   `preempt:on` victim: over-entitlement candidates (vtime above the
//!   trigger's) outrank the rest, and within a class the queue with the
//!   most caller-reported **residual work** wins — evicting a
//!   nearly-finished resident parks the most completed work for the
//!   least freed capacity, so low-residual queues are preempted last.
//!   The *caller* (engine loop / sim harness) evicts that queue's
//!   residents as `engine::SeqCheckpoint`s, pauses it, reports the
//!   parked progress via [`CrossQueueScheduler::charge_preemption`],
//!   and resumes the checkpoints once
//!   [`CrossQueueScheduler::preempt_cleared`] reports the trigger's
//!   pressure gone (always on drain). A per-queue **checkpoint budget**
//!   ([`SchedConfig::checkpoint_budget`]) caps the cumulative charged
//!   redo steps: a queue past its budget stops being a victim, so
//!   repeated evict/resume cycles cannot livelock a bulk queue.
//!   Checkpoint/resume is bitwise deterministic, so preemption trades
//!   only *when* bulk work runs, never *what* it produces.
//!
//! A queue that goes idle keeps its state but has its `vtime` caught up
//! to the ready frontier when it next becomes ready, so parked
//! entitlement cannot be spent as an unbounded burst.
//!
//! All per-round state lives in fixed per-queue slots: `pick`,
//! `report_step` and `placed` allocate nothing, preserving the
//! zero-allocation warm-step invariant (`tests/alloc_regression.rs`
//! pins the multi-queue path).

use std::collections::BTreeMap;
use std::collections::VecDeque;

use crate::engine::scheduler::StepPhases;
use crate::util::simclock::Clock;

/// Handle to a registered queue; stable for the scheduler's lifetime.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct QueueId(pub usize);

/// Per-queue scheduling policy, resolved from [`SchedConfig`] when the
/// coordinator creates a run queue.
#[derive(Clone, Debug, PartialEq)]
pub struct QueuePolicy {
    /// Relative service share (> 0). Long-run step-time shares of
    /// backlogged queues converge to the weight ratios.
    pub weight: f64,
    /// Optional p95 queue-wait target, seconds. When the observed wait
    /// EWMA exceeds it the queue is boosted and violations are counted.
    pub slo_p95_s: Option<f64>,
    /// Max rounds this queue may win back-to-back while others are ready.
    pub max_consecutive: u32,
    /// Bound on pending (admitted but not yet placed) sequences. A hard
    /// cap, not a high-water mark: a single request carrying more
    /// sequences than this can never be admitted.
    pub max_pending: usize,
    /// When the pending bound is hit: shed the request (true) or keep
    /// queueing anyway (false).
    pub shed_on_full: bool,
    /// Whether this queue's residents may be **preempted** (evicted
    /// mid-sequence as checkpoints) when an SLO queue's pressure sits at
    /// its boost ceiling for [`SchedConfig::preempt_after`] rounds
    /// without relief. Spec option `preempt:on` / `preempt:off`. Mark
    /// bulk queues preemptible; the pressured SLO queue itself is never
    /// a victim.
    pub preempt: bool,
}

impl Default for QueuePolicy {
    fn default() -> Self {
        QueuePolicy {
            weight: 1.0,
            slo_p95_s: None,
            max_consecutive: 4,
            max_pending: usize::MAX,
            shed_on_full: false,
            preempt: false,
        }
    }
}

impl QueuePolicy {
    /// Apply a comma-separated option list onto this policy, e.g.
    /// `"weight:4,slo:0.05,burst:2,pending:64,shed"`.
    pub fn apply_spec(&mut self, spec: &str) -> Result<(), String> {
        for part in spec.split(',').map(str::trim).filter(|s| !s.is_empty())
        {
            match part.split_once(':') {
                Some(("weight", v)) => {
                    let w: f64 = v
                        .trim()
                        .parse()
                        .map_err(|_| format!("bad weight '{v}'"))?;
                    if !w.is_finite() || w <= 0.0 {
                        return Err(format!(
                            "weight must be finite and > 0, got {v}"
                        ));
                    }
                    self.weight = w;
                }
                Some(("slo", v)) => {
                    let s: f64 = v
                        .trim()
                        .parse()
                        .map_err(|_| format!("bad slo '{v}'"))?;
                    if !s.is_finite() || s <= 0.0 {
                        return Err(format!(
                            "slo must be finite and > 0, got {v}"
                        ));
                    }
                    self.slo_p95_s = Some(s);
                }
                Some(("burst", v)) => {
                    let b: u32 = v
                        .trim()
                        .parse()
                        .map_err(|_| format!("bad burst '{v}'"))?;
                    if b == 0 {
                        return Err("burst must be >= 1".into());
                    }
                    self.max_consecutive = b;
                }
                Some(("pending", v)) => {
                    let p: usize = v
                        .trim()
                        .parse()
                        .map_err(|_| format!("bad pending '{v}'"))?;
                    if p == 0 {
                        return Err("pending must be >= 1".into());
                    }
                    self.max_pending = p;
                }
                Some(("preempt", v)) => match v.trim() {
                    "on" => self.preempt = true,
                    "off" => self.preempt = false,
                    other => {
                        return Err(format!(
                            "bad preempt '{other}' (expected on|off)"
                        ))
                    }
                },
                None if part == "shed" => self.shed_on_full = true,
                None if part == "queue" => self.shed_on_full = false,
                _ => {
                    return Err(format!(
                        "bad queue-policy option '{part}' (expected \
                         weight:W, slo:S, burst:N, pending:N, \
                         preempt:on|off, shed, queue)"
                    ))
                }
            }
        }
        Ok(())
    }
}

/// Server-level scheduling configuration: a default policy, per-model
/// overrides, and the selector tuning knobs.
#[derive(Clone, Debug)]
pub struct SchedConfig {
    pub default_policy: QueuePolicy,
    pub per_model: BTreeMap<String, QueuePolicy>,
    /// Starvation backstop: a ready queue passed over this many rounds is
    /// served unconditionally.
    pub starve_after: u64,
    /// Smoothing factor of the per-queue wait EWMA in (0, 1].
    pub wait_alpha: f64,
    /// Cap on the SLO charge-rate boost.
    pub max_boost: f64,
    /// Worker-thread count of the engine's shared step pool
    /// (`engine::pool::StepPool`): the scheduler's planar phases run
    /// chunked across this many executors. `1` (the default) is the
    /// exact single-threaded code path; token streams are bitwise
    /// identical for any value. CLI: `--step-threads N`.
    pub step_threads: usize,
    /// Preemption trigger patience: rounds an SLO queue must sit at its
    /// boost ceiling (wait EWMA >= slo · max_boost) with pending work —
    /// i.e. boosting alone freed no slot — before
    /// [`CrossQueueScheduler::preempt_check`] names a victim. CLI:
    /// `--preempt-after N`.
    pub preempt_after: u64,
    /// Per-queue preemption redo budget: cumulative evicted progress
    /// (ordering positions parked behind checkpoints, reported by the
    /// caller via [`CrossQueueScheduler::charge_preemption`]) beyond
    /// which a queue stops being named a preemption victim. Bounds the
    /// total completed work evict/resume cycles can park for any one
    /// queue — without it, sustained SLO pressure can livelock a bulk
    /// queue by re-evicting it forever. `0` disables preemption
    /// entirely (every candidate counts as already exhausted). CLI:
    /// `--checkpoint-budget N`.
    pub checkpoint_budget: u64,
    /// Retry / circuit-breaker policy of the engine's supervision layer
    /// (see `coordinator::supervise`).
    pub supervise: crate::coordinator::supervise::SupervisePolicy,
    /// Priority class assigned to requests that don't carry one
    /// (higher = served earlier within a queue). CLI:
    /// `--default-priority N`.
    pub default_priority: i32,
}

impl Default for SchedConfig {
    fn default() -> Self {
        SchedConfig {
            default_policy: QueuePolicy::default(),
            per_model: BTreeMap::new(),
            starve_after: 64,
            wait_alpha: 0.2,
            max_boost: 8.0,
            step_threads: 1,
            preempt_after: 4,
            checkpoint_budget: 4096,
            supervise:
                crate::coordinator::supervise::SupervisePolicy::default(),
            default_priority: 0,
        }
    }
}

impl SchedConfig {
    /// Policy for a run queue serving `model` (per-model override wins;
    /// queues created for the same model under different sampler settings
    /// share the model's policy).
    pub fn resolve(&self, model: &str) -> QueuePolicy {
        self.per_model
            .get(model)
            .cloned()
            .unwrap_or_else(|| self.default_policy.clone())
    }

    /// Apply a CLI spec: `;`-separated entries, each either
    /// `model=<options>` (per-model override on top of the default) or a
    /// bare `<options>` list editing the default policy. Bare entries
    /// are applied first regardless of position, so the outcome is
    /// order-independent: overrides always layer on the fully-edited
    /// default. See [`QueuePolicy::apply_spec`] for the option grammar.
    pub fn apply_cli(&mut self, spec: &str) -> Result<(), String> {
        let entries = || {
            spec.split(';').map(str::trim).filter(|s| !s.is_empty())
        };
        for entry in entries() {
            if entry.split_once('=').is_none() {
                self.default_policy.apply_spec(entry)?;
            }
        }
        for entry in entries() {
            if let Some((model, opts)) = entry.split_once('=') {
                let mut p = self.resolve(model.trim());
                p.apply_spec(opts)?;
                self.per_model.insert(model.trim().to_string(), p);
            }
        }
        Ok(())
    }
}

/// Fixed per-queue selector state (no per-round allocations).
struct QueueState {
    key: String,
    policy: QueuePolicy,
    /// Normalized consumed service Σ cost / (weight · boost).
    vtime: f64,
    /// EWMA of observed queue waits (seconds).
    wait_ewma: f64,
    waits_seen: u64,
    /// Arrival timestamps of pending (admitted, unplaced) sequences,
    /// keyed by caller-chosen *lane* (the coordinator uses one lane per
    /// batch-key run queue): placements pop their own lane's FIFO, so
    /// per-sequence waits pair exactly even when several lanes of one
    /// queue are concurrently backlogged. Emptied lanes are removed, so
    /// the map is bounded by concurrently-pending lanes. Each stamp
    /// carries the caller's per-request `tag`, so a rollback
    /// ([`CrossQueueScheduler::cancel_enqueue`]) removes exactly the
    /// canceled request's entries even if another same-lane request was
    /// admitted between the optimistic enqueue and the cancel.
    arrivals: BTreeMap<u64, VecDeque<(u64, f64)>>,
    /// Total pending sequences across lanes (the `max_pending` subject).
    pending: usize,
    /// Consecutive pick rounds this queue was ready but passed over.
    since_pick: u64,
    /// Last pick round in which this queue was ready (newly-ready
    /// detection for the vtime catch-up).
    ready_gen: u64,
    steps: u64,
    cost_total: f64,
    /// Cumulative per-phase step cost (model/draw/LSE/accept seconds),
    /// fed by [`CrossQueueScheduler::report_step_phases`]. Fixed-size —
    /// no per-round allocation.
    phase_cost: StepPhases,
    slo_violations: u64,
    /// Admission-backpressure sheds, tracked at BOTH granularities: a
    /// shed *request* rejects all `n` of its *sequences* at once, and
    /// the two denominators answer different questions (how many callers
    /// were turned away vs how much work was refused) — conflating them
    /// was the historical bug.
    shed_seqs: u64,
    shed_reqs: u64,
    /// Consecutive pick rounds this queue's SLO pressure sat at the
    /// boost ceiling with pending work (preemption trigger streak).
    pressure_rounds: u64,
    /// Times this queue's pressure triggered a preemption.
    preempt_fires: u64,
    /// Cumulative redo steps charged against this queue by preemptions
    /// it was the victim of (the checkpoint-budget subject).
    redo_charged: u64,
}

/// The cross-queue selector: pure state + an injected clock.
pub struct CrossQueueScheduler {
    clock: Box<dyn Clock>,
    starve_after: u64,
    wait_alpha: f64,
    max_boost: f64,
    preempt_after: u64,
    checkpoint_budget: u64,
    queues: Vec<QueueState>,
    /// Ready-frontier virtual time (max vtime ever charged).
    vnow: f64,
    /// EWMA of reported step costs; scales the SLO pick-time bonus.
    cost_ewma: f64,
    pick_gen: u64,
    last_pick: Option<usize>,
    consecutive: u32,
    slo_violations: u64,
    shed_requests: u64,
    shed_seqs: u64,
    preempt_fires: u64,
}

impl CrossQueueScheduler {
    pub fn new(clock: Box<dyn Clock>, cfg: &SchedConfig)
               -> CrossQueueScheduler {
        CrossQueueScheduler {
            clock,
            starve_after: cfg.starve_after.max(1),
            wait_alpha: cfg.wait_alpha.clamp(1e-6, 1.0),
            max_boost: cfg.max_boost.max(1.0),
            preempt_after: cfg.preempt_after.max(1),
            checkpoint_budget: cfg.checkpoint_budget,
            queues: Vec::new(),
            vnow: 0.0,
            cost_ewma: 0.0,
            pick_gen: 0,
            last_pick: None,
            consecutive: 0,
            slo_violations: 0,
            shed_requests: 0,
            shed_seqs: 0,
            preempt_fires: 0,
        }
    }

    /// Current reading of the injected clock (seconds since its epoch).
    pub fn now(&self) -> f64 {
        self.clock.now()
    }

    /// Register (or re-resolve the policy of) the queue for `key`. State
    /// persists across run-queue drop/recreate cycles, so a queue's wait
    /// EWMA and service history survive idleness.
    pub fn register(&mut self, key: &str, policy: QueuePolicy) -> QueueId {
        if let Some(i) = self.queues.iter().position(|q| q.key == key) {
            self.queues[i].policy = policy;
            return QueueId(i);
        }
        self.queues.push(QueueState {
            key: key.to_string(),
            policy,
            vtime: self.vnow,
            wait_ewma: 0.0,
            waits_seen: 0,
            arrivals: BTreeMap::new(),
            pending: 0,
            since_pick: 0,
            ready_gen: 0,
            steps: 0,
            cost_total: 0.0,
            phase_cost: StepPhases::default(),
            slo_violations: 0,
            shed_seqs: 0,
            shed_reqs: 0,
            pressure_rounds: 0,
            preempt_fires: 0,
            redo_charged: 0,
        });
        QueueId(self.queues.len() - 1)
    }

    /// Admission backpressure: record one request's `n` sequences
    /// arriving now on `lane` (minus `age_s`, the time the request
    /// already spent in transit before the engine saw it), stamped with
    /// the caller's request `tag` so a later rollback can identify
    /// exactly these entries. Returns false — and counts a shed — when
    /// the queue is over its pending bound and its policy sheds; sheds
    /// are tracked at both granularities (one *request* carrying `n`
    /// *sequences*). The bound spans all lanes of the queue.
    pub fn try_enqueue(&mut self, qid: QueueId, lane: u64, tag: u64,
                       n: usize, age_s: f64) -> bool {
        let now = self.clock.now();
        let q = &mut self.queues[qid.0];
        if q.pending.saturating_add(n) > q.policy.max_pending
            && q.policy.shed_on_full
        {
            q.shed_seqs += n as u64;
            q.shed_reqs += 1;
            self.shed_seqs += n as u64;
            self.shed_requests += 1;
            return false;
        }
        let t = now - age_s.max(0.0);
        let dq = q.arrivals.entry(lane).or_default();
        for _ in 0..n {
            dq.push_back((tag, t));
        }
        q.pending += n;
        true
    }

    /// Non-counting capacity probe: would a request of `n` sequences be
    /// refused by [`CrossQueueScheduler::try_enqueue`] right now? Lets
    /// priority-aware shedding displace a victim *before* the final
    /// `try_enqueue` — whose failure is what counts the shed — so a
    /// displaced-then-admitted arrival is never also counted shed.
    pub fn is_full(&self, qid: QueueId, n: usize) -> bool {
        let q = &self.queues[qid.0];
        q.pending.saturating_add(n) > q.policy.max_pending
            && q.policy.shed_on_full
    }

    /// Count a shed decided *outside* `try_enqueue` — priority-aware
    /// shedding evicts an already-admitted victim (whose stamps the
    /// caller rolls back via [`CrossQueueScheduler::cancel_enqueue`]) to
    /// make room for a higher-priority arrival, and this keeps the
    /// per-queue and global shed counters truthful for that path.
    pub fn count_shed(&mut self, qid: QueueId, seqs: u64, reqs: u64) {
        let q = &mut self.queues[qid.0];
        q.shed_seqs += seqs;
        q.shed_reqs += reqs;
        self.shed_seqs += seqs;
        self.shed_requests += reqs;
    }

    /// Report `n` sequences of `lane` entering slots (execution start).
    /// Pops that lane's arrival stamps, updates the wait EWMA, counts
    /// SLO violations, and hands each wait to `observe` (the coordinator
    /// feeds its `queue_wait_s` histogram; the sim harness records waits
    /// for exact assertions). Allocation-free with `n == 0` or a warm
    /// lane.
    pub fn placed(&mut self, qid: QueueId, lane: u64, n: usize,
                  observe: impl FnMut(f64)) {
        let now = self.clock.now();
        self.placed_at(qid, lane, n, now, observe);
    }

    /// [`CrossQueueScheduler::placed`] with an explicit placement time:
    /// placement happens at step *start* (backfill precedes the forward
    /// pass), so the engine loop passes its pre-step clock reading rather
    /// than billing the whole first step as queue wait. Pops the lane's
    /// oldest stamps regardless of tag — only correct while placements
    /// follow admission order; under priority classes use
    /// [`CrossQueueScheduler::placed_at_tag`].
    pub fn placed_at(&mut self, qid: QueueId, lane: u64, n: usize,
                     now: f64, observe: impl FnMut(f64)) {
        self.placed_impl(qid, lane, None, n, now, observe);
    }

    /// [`CrossQueueScheduler::placed_at`] popping the oldest stamps
    /// belonging to request `tag`. Priority classes reorder placements
    /// *across* requests within one run queue (a later high-priority
    /// request's sequences can enter slots before an earlier
    /// low-priority request's), so the lane FIFO alone would mis-pair
    /// waits — inflating the overtaker's wait with the overtaken
    /// request's older stamp and deflating the latter's, corrupting
    /// `queue_wait_s`, the SLO EWMA, violation counts, and the
    /// preemption trigger they feed. Within one request placements stay
    /// admission-ordered (its sequences share a priority class), so
    /// oldest-of-tag pairs exactly.
    pub fn placed_at_tag(&mut self, qid: QueueId, lane: u64, tag: u64,
                         n: usize, now: f64, observe: impl FnMut(f64)) {
        self.placed_impl(qid, lane, Some(tag), n, now, observe);
    }

    fn placed_impl(&mut self, qid: QueueId, lane: u64, tag: Option<u64>,
                   n: usize, now: f64, mut observe: impl FnMut(f64)) {
        if n == 0 {
            return;
        }
        let alpha = self.wait_alpha;
        let q = &mut self.queues[qid.0];
        let mut drained = false;
        if let Some(dq) = q.arrivals.get_mut(&lane) {
            for _ in 0..n {
                let t = match tag {
                    None => dq.pop_front().map(|(_, t)| t),
                    Some(tag) => {
                        let idx =
                            dq.iter().position(|&(g, _)| g == tag);
                        idx.and_then(|i| dq.remove(i)).map(|(_, t)| t)
                    }
                }
                .unwrap_or(now);
                let wait = (now - t).max(0.0);
                q.wait_ewma = if q.waits_seen == 0 {
                    wait
                } else {
                    (1.0 - alpha) * q.wait_ewma + alpha * wait
                };
                q.waits_seen += 1;
                if let Some(slo) = q.policy.slo_p95_s {
                    if wait > slo {
                        q.slo_violations += 1;
                        self.slo_violations += 1;
                    }
                }
                observe(wait);
            }
            drained = dq.is_empty();
        }
        q.pending = q.pending.saturating_sub(n);
        if drained {
            q.arrivals.remove(&lane);
        }
    }

    /// Roll back up to `n` admission stamps of request `tag` on `lane`
    /// without observing waits (the coordinator uses this when a request
    /// was optimistically admitted but its run queue could not be
    /// created). Keying the rollback on `tag` removes exactly the
    /// canceled request's entries: blindly popping the lane's most
    /// recent stamps would corrupt the `queue_wait_s` of any same-lane
    /// request admitted between the optimistic enqueue and the cancel
    /// (pinned by `cancel_is_exact_under_interleaved_admissions`).
    pub fn cancel_enqueue(&mut self, qid: QueueId, lane: u64, tag: u64,
                          n: usize) {
        let q = &mut self.queues[qid.0];
        let mut removed = 0usize;
        let mut drained = false;
        if let Some(dq) = q.arrivals.get_mut(&lane) {
            let mut i = dq.len();
            while i > 0 && removed < n {
                i -= 1;
                if dq[i].0 == tag {
                    dq.remove(i);
                    removed += 1;
                }
            }
            drained = dq.is_empty();
        }
        if drained {
            q.arrivals.remove(&lane);
        }
        q.pending = q.pending.saturating_sub(removed);
    }

    /// [`CrossQueueScheduler::report_step`] with the engine's per-phase
    /// cost breakdown (model forward / draw / batched LSE / accept —
    /// `engine::scheduler::StepPhases`): the total wall cost drives the
    /// virtual-time charge exactly as before, while the per-phase
    /// cumulative totals are retained per queue and readable via
    /// [`CrossQueueScheduler::phase_cost_of`] — the per-queue
    /// attribution of service time to model vs sampling phases (the
    /// registry histograms in the coordinator aggregate across queues
    /// and lose that split). Allocation-free.
    pub fn report_step_phases(&mut self, qid: QueueId, cost_s: f64,
                              phases: &StepPhases) {
        {
            let q = &mut self.queues[qid.0];
            q.phase_cost.model_s += phases.model_s;
            q.phase_cost.draw_s += phases.draw_s;
            q.phase_cost.lse_s += phases.lse_s;
            q.phase_cost.accept_s += phases.accept_s;
        }
        self.report_step(qid, cost_s);
    }

    /// Cumulative per-phase step cost reported for `qid`.
    pub fn phase_cost_of(&self, qid: QueueId) -> StepPhases {
        self.queues[qid.0].phase_cost
    }

    /// Charge one executed step of `qid` at its observed cost (seconds).
    /// The engine loop reports wall time; the sim reports synthetic
    /// costs. Boosted queues are charged at a discounted rate, which is
    /// what converts SLO pressure into extra service share.
    pub fn report_step(&mut self, qid: QueueId, cost_s: f64) {
        let cost = cost_s.max(1e-12);
        let boost = self.boost(qid.0);
        self.cost_ewma = if self.cost_ewma == 0.0 {
            cost
        } else {
            0.9 * self.cost_ewma + 0.1 * cost
        };
        let alpha = self.wait_alpha;
        let q = &mut self.queues[qid.0];
        q.steps += 1;
        q.cost_total += cost;
        q.vtime += cost / (q.policy.weight.max(1e-6) * boost);
        if q.vtime > self.vnow {
            self.vnow = q.vtime;
        }
        // SLO pressure must not freeze at its burst-time value: with no
        // pending arrivals nothing is waiting, so the wait EWMA decays
        // each served step instead of granting the boost indefinitely
        // to a queue running only resident work.
        if q.arrivals.is_empty() {
            q.wait_ewma *= 1.0 - alpha;
        }
    }

    /// Select the next queue to step among `ready` (queues with resident
    /// or pending work). Deterministic, allocation-free. Returns `None`
    /// iff `ready` is empty.
    pub fn pick(&mut self, ready: &[QueueId]) -> Option<QueueId> {
        if ready.is_empty() {
            return None;
        }
        self.pick_gen += 1;
        let cur_gen = self.pick_gen;

        // Preemption-pressure streaks: one update per pick round. A
        // queue is "at the ceiling" when its SLO boost is already capped
        // (EWMA >= slo · max_boost — more boost cannot help) while work
        // is still waiting; `preempt_check` fires once a streak reaches
        // `preempt_after`. Fixed per-queue state, allocation-free.
        for q in self.queues.iter_mut() {
            let at_ceiling = match q.policy.slo_p95_s {
                Some(slo) => {
                    q.pending > 0 && q.wait_ewma >= slo * self.max_boost
                }
                None => false,
            };
            if at_ceiling {
                q.pressure_rounds += 1;
            } else {
                q.pressure_rounds = 0;
            }
        }

        // Newly-ready catch-up: a queue that sat idle must re-enter at
        // the ready frontier, not spend its parked entitlement as a
        // burst. The frontier is the min vtime among continuously-ready
        // queues (falling back to the global frontier).
        let mut vfloor = f64::INFINITY;
        for &QueueId(i) in ready {
            let q = &self.queues[i];
            if q.ready_gen + 1 == cur_gen {
                vfloor = vfloor.min(q.vtime);
            }
        }
        if !vfloor.is_finite() {
            vfloor = self.vnow;
        }
        for &QueueId(i) in ready {
            let q = &mut self.queues[i];
            if q.ready_gen + 1 != cur_gen {
                q.vtime = q.vtime.max(vfloor);
                q.since_pick = 0;
            }
            q.ready_gen = cur_gen;
        }

        // Starvation backstop: a queue passed over starve_after rounds is
        // served unconditionally (the most-starved one, ties to the
        // lowest id).
        let mut starved: Option<usize> = None;
        for &QueueId(i) in ready {
            let s = self.queues[i].since_pick;
            let more_starved = match starved {
                None => s >= self.starve_after,
                Some(j) => s > self.queues[j].since_pick,
            };
            if more_starved {
                starved = Some(i);
            }
        }

        let chosen = match starved {
            Some(i) => i,
            None => {
                // Burst bound: after max_consecutive back-to-back wins
                // the incumbent yields to the best other ready queue.
                let blocked = match self.last_pick {
                    Some(lp)
                        if ready.len() > 1
                            && ready.contains(&QueueId(lp))
                            && self.consecutive
                                >= self.queues[lp].policy.max_consecutive =>
                    {
                        Some(lp)
                    }
                    _ => None,
                };
                let cost_ref = self.cost_ewma.max(1e-9);
                let mut best: Option<(usize, f64)> = None;
                for &QueueId(i) in ready {
                    if Some(i) == blocked {
                        continue;
                    }
                    let key = self.pick_key(i, cost_ref);
                    match best {
                        Some((_, bk)) if bk <= key => {}
                        _ => best = Some((i, key)),
                    }
                }
                best.expect("ready set non-empty").0
            }
        };

        for &QueueId(i) in ready {
            if i != chosen {
                self.queues[i].since_pick += 1;
            }
        }
        self.queues[chosen].since_pick = 0;
        self.consecutive = if self.last_pick == Some(chosen) {
            self.consecutive.saturating_add(1)
        } else {
            1
        };
        self.last_pick = Some(chosen);
        Some(QueueId(chosen))
    }

    /// Pick ordering key: smaller wins. Base is the queue's vtime; a
    /// queue blowing its SLO gets an immediate bonus proportional to how
    /// far its wait EWMA overshoots, scaled by a typical step cost so the
    /// bonus is commensurate with vtime increments.
    fn pick_key(&self, i: usize, cost_ref: f64) -> f64 {
        let q = &self.queues[i];
        let pressure = match q.policy.slo_p95_s {
            Some(slo) if q.wait_ewma > slo => {
                (q.wait_ewma / slo - 1.0).min(self.max_boost - 1.0)
                    * cost_ref
            }
            _ => 0.0,
        };
        q.vtime - pressure
    }

    /// Preemption policy: returns `(trigger, victim)` when some SLO
    /// queue's wait-EWMA pressure has sat at its boost ceiling with
    /// pending work for at least `preempt_after` consecutive rounds —
    /// i.e. boosting alone is not freeing slots fast enough — and a
    /// preemptible victim exists. `candidates` are the queues the caller
    /// knows to hold evictable residents, each paired with its
    /// **residual work** (ordering positions its residents still have to
    /// decide — `engine` callers read `Stepper::residual`). Among those
    /// with `QueuePolicy::preempt` (the trigger excluded, queues past
    /// their [`SchedConfig::checkpoint_budget`] skipped), candidates
    /// **over their entitlement** (vtime above the trigger's — they
    /// consumed more weighted service than the pressured queue) outrank
    /// the rest; within a class the largest residual wins (a
    /// nearly-finished victim would park the most completed work for the
    /// least freed capacity), ties to the largest vtime. Firing resets
    /// the trigger's streak, so the next fire needs `preempt_after`
    /// fresh rounds of sustained pressure (bounded thrash).
    pub fn preempt_check(&mut self, candidates: &[(QueueId, u64)])
                         -> Option<(QueueId, QueueId)> {
        let mut trigger: Option<usize> = None;
        for (i, q) in self.queues.iter().enumerate() {
            if q.policy.slo_p95_s.is_some()
                && q.pressure_rounds >= self.preempt_after
            {
                let better = match trigger {
                    None => true,
                    Some(j) => {
                        q.pressure_rounds > self.queues[j].pressure_rounds
                    }
                };
                if better {
                    trigger = Some(i);
                }
            }
        }
        let trigger = trigger?;
        let trigger_vtime = self.queues[trigger].vtime;
        // (index, over-entitlement, residual) of the best victim so far.
        let mut victim: Option<(usize, bool, u64)> = None;
        for &(QueueId(i), residual) in candidates {
            if i == trigger || !self.queues[i].policy.preempt {
                continue;
            }
            if self.queues[i].redo_charged >= self.checkpoint_budget {
                continue;
            }
            let over = self.queues[i].vtime > trigger_vtime;
            let better = match victim {
                None => true,
                Some((j, j_over, j_res)) => {
                    if over != j_over {
                        over
                    } else if residual != j_res {
                        residual > j_res
                    } else {
                        self.queues[i].vtime > self.queues[j].vtime
                    }
                }
            };
            if better {
                victim = Some((i, over, residual));
            }
        }
        let (victim, _, _) = victim?;
        self.queues[trigger].pressure_rounds = 0;
        self.queues[trigger].preempt_fires += 1;
        self.preempt_fires += 1;
        Some((QueueId(trigger), QueueId(victim)))
    }

    /// Report the redo cost of a preemption the caller just executed:
    /// `redo_steps` is the parked progress (Σ `SeqCheckpoint::progress`)
    /// of the checkpoints evicted from `victim`. Accumulates against the
    /// victim's [`SchedConfig::checkpoint_budget`]; once the budget is
    /// exhausted [`CrossQueueScheduler::preempt_check`] stops naming the
    /// queue, so evict/resume cycles cannot starve it of forward
    /// progress indefinitely.
    pub fn charge_preemption(&mut self, victim: QueueId, redo_steps: u64) {
        let q = &mut self.queues[victim.0];
        q.redo_charged = q.redo_charged.saturating_add(redo_steps);
    }

    /// True when `trigger`'s preemption pressure has cleared — nothing
    /// of it is pending anymore, or its wait EWMA recovered to its SLO —
    /// at which point the caller resumes the checkpoints it parked.
    /// (Callers additionally resume unconditionally on drain/shutdown.)
    pub fn preempt_cleared(&self, trigger: QueueId) -> bool {
        let q = &self.queues[trigger.0];
        match q.policy.slo_p95_s {
            Some(slo) => q.pending == 0 || q.wait_ewma <= slo,
            None => true,
        }
    }

    /// SLO charge-rate boost of queue `i` (1.0 when within SLO).
    fn boost(&self, i: usize) -> f64 {
        let q = &self.queues[i];
        match q.policy.slo_p95_s {
            Some(slo) if q.wait_ewma > slo => {
                (q.wait_ewma / slo).min(self.max_boost)
            }
            _ => 1.0,
        }
    }

    // ---- observability ---------------------------------------------------

    /// Entitlement lag of a queue in weighted seconds: how far behind the
    /// ready frontier its consumed service is (>= 0; larger = more owed).
    pub fn credit(&self, qid: QueueId) -> f64 {
        (self.vnow - self.queues[qid.0].vtime).max(0.0)
    }

    pub fn wait_ewma(&self, qid: QueueId) -> f64 {
        self.queues[qid.0].wait_ewma
    }

    pub fn pending_depth(&self, qid: QueueId) -> usize {
        self.queues[qid.0].pending
    }

    pub fn steps_of(&self, qid: QueueId) -> u64 {
        self.queues[qid.0].steps
    }

    /// Per-queue waits observed above this queue's SLO.
    pub fn slo_violations_of(&self, qid: QueueId) -> u64 {
        self.queues[qid.0].slo_violations
    }

    /// Per-queue *sequences* rejected by admission backpressure.
    pub fn shed_of(&self, qid: QueueId) -> u64 {
        self.queues[qid.0].shed_seqs
    }

    /// Per-queue *requests* rejected by admission backpressure (each
    /// shed request sheds all of its sequences at once; see
    /// [`CrossQueueScheduler::shed_of`] for the sequence denominator).
    pub fn shed_requests_of(&self, qid: QueueId) -> u64 {
        self.queues[qid.0].shed_reqs
    }

    /// Per-queue preemption fires this queue's SLO pressure triggered.
    pub fn preempt_fires_of(&self, qid: QueueId) -> u64 {
        self.queues[qid.0].preempt_fires
    }

    /// Cumulative redo steps charged against this queue as a preemption
    /// victim (see [`CrossQueueScheduler::charge_preemption`]).
    pub fn redo_charged_of(&self, qid: QueueId) -> u64 {
        self.queues[qid.0].redo_charged
    }

    pub fn cost_of(&self, qid: QueueId) -> f64 {
        self.queues[qid.0].cost_total
    }

    pub fn key_of(&self, qid: QueueId) -> &str {
        &self.queues[qid.0].key
    }

    pub fn policy_of(&self, qid: QueueId) -> &QueuePolicy {
        &self.queues[qid.0].policy
    }

    pub fn n_queues(&self) -> usize {
        self.queues.len()
    }

    /// Total waits observed above their queue's SLO.
    pub fn slo_violations(&self) -> u64 {
        self.slo_violations
    }

    /// Total *requests* rejected by admission backpressure.
    pub fn shed_requests(&self) -> u64 {
        self.shed_requests
    }

    /// Total *sequences* rejected by admission backpressure.
    pub fn shed_seqs(&self) -> u64 {
        self.shed_seqs
    }

    /// Total preemptions fired by [`CrossQueueScheduler::preempt_check`].
    pub fn preempt_fires(&self) -> u64 {
        self.preempt_fires
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::simclock::SimClock;

    fn sched(cfg: &SchedConfig) -> (SimClock, CrossQueueScheduler) {
        let clock = SimClock::new();
        let s = CrossQueueScheduler::new(Box::new(clock.clone()), cfg);
        (clock, s)
    }

    fn policy(weight: f64) -> QueuePolicy {
        QueuePolicy { weight, ..QueuePolicy::default() }
    }

    #[test]
    fn register_reuses_by_key_and_updates_policy() {
        let (_c, mut s) = sched(&SchedConfig::default());
        let a = s.register("a", policy(1.0));
        let b = s.register("b", policy(2.0));
        assert_ne!(a, b);
        let a2 = s.register("a", policy(3.0));
        assert_eq!(a, a2);
        assert_eq!(s.n_queues(), 2);
        assert_eq!(s.key_of(a), "a");
    }

    #[test]
    fn phased_reports_charge_vtime_and_accumulate_per_queue() {
        // report_step_phases must be exactly report_step on the selector
        // side (same vtime charge, same step count) while additionally
        // retaining the per-queue model/draw/LSE/accept split.
        let (_c, mut s) = sched(&SchedConfig::default());
        let a = s.register("a", policy(1.0));
        let b = s.register("b", policy(1.0));
        let phases = StepPhases {
            model_s: 0.006,
            draw_s: 0.002,
            lse_s: 0.001,
            accept_s: 0.001,
        };
        s.report_step_phases(a, phases.total_s(), &phases);
        s.report_step_phases(a, phases.total_s(), &phases);
        s.report_step(b, 0.01);
        assert_eq!(s.steps_of(a), 2);
        assert!((s.cost_of(a) - 0.02).abs() < 1e-12);
        assert!((s.cost_of(b) - 0.01).abs() < 1e-12);
        let split = s.phase_cost_of(a);
        assert!((split.model_s - 0.012).abs() < 1e-12);
        assert!((split.draw_s - 0.004).abs() < 1e-12);
        assert!((split.lse_s - 0.002).abs() < 1e-12);
        assert!((split.accept_s - 0.002).abs() < 1e-12);
        assert_eq!(s.phase_cost_of(b), StepPhases::default());
    }

    #[test]
    fn weighted_shares_converge_under_equal_costs() {
        let (_c, mut s) = sched(&SchedConfig::default());
        let a = s.register("a", policy(3.0));
        let b = s.register("b", policy(1.0));
        let ready = [a, b];
        let mut picks = [0u64; 2];
        for _ in 0..400 {
            let q = s.pick(&ready).unwrap();
            picks[q.0] += 1;
            s.report_step(q, 0.01);
        }
        let ratio = picks[0] as f64 / picks[1] as f64;
        assert!(
            (ratio - 3.0).abs() < 0.3,
            "3:1 weights gave step ratio {ratio} ({picks:?})"
        );
    }

    #[test]
    fn time_shares_follow_weights_under_unequal_costs() {
        // Queue a's steps cost 4x queue b's; equal weights must still
        // split *time* roughly evenly, i.e. b steps ~4x as often.
        let (_c, mut s) = sched(&SchedConfig::default());
        let a = s.register("a", policy(1.0));
        let b = s.register("b", policy(1.0));
        let ready = [a, b];
        for _ in 0..500 {
            let q = s.pick(&ready).unwrap();
            s.report_step(q, if q == a { 0.04 } else { 0.01 });
        }
        let share_a = s.cost_of(a) / (s.cost_of(a) + s.cost_of(b));
        assert!(
            (share_a - 0.5).abs() < 0.1,
            "equal weights gave time share {share_a}"
        );
        assert!(s.steps_of(b) > 3 * s.steps_of(a));
    }

    #[test]
    fn slo_pressure_wins_the_pick_and_counts_violations() {
        let (clock, mut s) = sched(&SchedConfig::default());
        let a = s.register("bulk", policy(1.0));
        let slo = QueuePolicy {
            slo_p95_s: Some(0.01),
            ..QueuePolicy::default()
        };
        let b = s.register("latency", slo);
        // One sequence waits 0.1s before placement: EWMA blows the SLO.
        assert!(s.try_enqueue(b, 0, 0, 1, 0.0));
        clock.advance(0.1);
        let mut waits = 0;
        s.placed(b, 0, 1, |w| {
            assert!((w - 0.1).abs() < 1e-12);
            waits += 1;
        });
        assert_eq!(waits, 1);
        assert_eq!(s.slo_violations(), 1);
        assert_eq!(s.slo_violations_of(b), 1);
        assert_eq!(s.slo_violations_of(a), 0);
        assert!(s.wait_ewma(b) > 0.05);
        // Fresh vtimes tie at 0; the SLO-violating queue must win it.
        assert_eq!(s.pick(&[a, b]), Some(b));
    }

    #[test]
    fn slo_pressure_decays_without_pending_work() {
        let (clock, mut s) = sched(&SchedConfig::default());
        let b = s.register("latency", QueuePolicy {
            slo_p95_s: Some(0.01),
            ..QueuePolicy::default()
        });
        assert!(s.try_enqueue(b, 0, 0, 1, 0.0));
        clock.advance(0.5);
        s.placed(b, 0, 1, |_| {});
        assert!(s.wait_ewma(b) > 0.01, "EWMA must be blown");
        // Resident-only service (no pending arrivals): the pressure
        // relaxes instead of granting the boost forever.
        for _ in 0..60 {
            s.report_step(b, 0.01);
        }
        assert!(
            s.wait_ewma(b) < 0.01,
            "EWMA {} must decay below the SLO",
            s.wait_ewma(b)
        );
    }

    #[test]
    fn burst_bound_forces_interleave() {
        let cfg = SchedConfig::default();
        let (_c, mut s) = sched(&cfg);
        let a = s.register("heavy", QueuePolicy {
            weight: 100.0,
            max_consecutive: 2,
            ..QueuePolicy::default()
        });
        let b = s.register("light", policy(1.0));
        let ready = [a, b];
        let mut run = 0u32;
        let mut max_run = 0u32;
        for _ in 0..100 {
            let q = s.pick(&ready).unwrap();
            s.report_step(q, 0.01);
            if q == a {
                run += 1;
                max_run = max_run.max(run);
            } else {
                run = 0;
            }
        }
        assert!(max_run <= 2, "run of {max_run} exceeds burst bound");
    }

    #[test]
    fn starvation_backstop_bounds_pick_gaps() {
        let cfg = SchedConfig { starve_after: 4, ..SchedConfig::default() };
        let (_c, mut s) = sched(&cfg);
        let a = s.register("a", QueuePolicy {
            weight: 1000.0,
            max_consecutive: u32::MAX,
            ..QueuePolicy::default()
        });
        let b = s.register("b", policy(0.001));
        let ready = [a, b];
        let mut gap = 0u64;
        let mut max_gap = 0u64;
        for _ in 0..200 {
            let q = s.pick(&ready).unwrap();
            s.report_step(q, 0.01);
            if q == b {
                gap = 0;
            } else {
                gap += 1;
                max_gap = max_gap.max(gap);
            }
        }
        assert!(
            max_gap <= cfg.starve_after + 1,
            "queue b starved for {max_gap} rounds"
        );
    }

    #[test]
    fn shed_policy_bounds_pending_depth() {
        let (_c, mut s) = sched(&SchedConfig::default());
        let a = s.register("a", QueuePolicy {
            max_pending: 2,
            shed_on_full: true,
            ..QueuePolicy::default()
        });
        assert!(s.try_enqueue(a, 0, 0, 2, 0.0));
        assert!(!s.try_enqueue(a, 0, 0, 1, 0.0));
        assert_eq!(s.shed_requests(), 1);
        assert_eq!(s.shed_of(a), 1);
        assert_eq!(s.pending_depth(a), 2);
        // Queue-on-full policy admits past the bound instead.
        let b = s.register("b", QueuePolicy {
            max_pending: 1,
            shed_on_full: false,
            ..QueuePolicy::default()
        });
        assert!(s.try_enqueue(b, 0, 0, 5, 0.0));
        assert_eq!(s.pending_depth(b), 5);
        assert_eq!(s.shed_requests(), 1);
    }

    #[test]
    fn newly_ready_queue_rejoins_at_the_frontier() {
        let (_c, mut s) = sched(&SchedConfig::default());
        let a = s.register("a", policy(1.0));
        let b = s.register("b", policy(1.0));
        // b runs alone for a while: its vtime races ahead of idle a.
        for _ in 0..50 {
            let q = s.pick(&[b]).unwrap();
            s.report_step(q, 0.01);
        }
        assert!(s.credit(a) > 0.4, "idle queue accrued lag {}", s.credit(a));
        // When a becomes ready it is caught up: it gets priority once
        // (its vtime equals the floor, tie-break by id), but not a
        // monopolizing burst — b is served again within its burst bound.
        let ready = [a, b];
        let mut first_b = None;
        for round in 0..10 {
            let q = s.pick(&ready).unwrap();
            s.report_step(q, 0.01);
            if q == b {
                first_b = Some(round);
                break;
            }
        }
        let first_b = first_b.expect("b starved after a rejoined");
        assert!(
            first_b <= 4,
            "rejoining queue burst for {first_b} rounds"
        );
    }

    #[test]
    fn lanes_pair_waits_exactly_across_siblings() {
        // Two lanes of one queue backlogged concurrently: each
        // placement must pop its OWN lane's stamp, not the queue-global
        // oldest — otherwise a late-arriving sibling inherits the early
        // lane's wait (spurious SLO violation) and the early lane's
        // wait is undercounted.
        let (clock, mut s) = sched(&SchedConfig::default());
        let q = s.register("m", QueuePolicy {
            slo_p95_s: Some(5.0),
            ..QueuePolicy::default()
        });
        assert!(s.try_enqueue(q, 1, 0, 1, 0.0)); // lane 1 arrives at t=0
        clock.advance(10.0);
        assert!(s.try_enqueue(q, 2, 0, 1, 0.0)); // lane 2 arrives at t=10
        assert_eq!(s.pending_depth(q), 2);
        // Lane 2 places immediately: wait must be 0, not 10.
        let mut w2 = f64::NAN;
        s.placed(q, 2, 1, |w| w2 = w);
        assert_eq!(w2, 0.0);
        assert_eq!(s.slo_violations(), 0, "no spurious violation");
        // Lane 1 places at t=30: wait must be the full 30.
        clock.advance(20.0);
        let mut w1 = f64::NAN;
        s.placed(q, 1, 1, |w| w1 = w);
        assert!((w1 - 30.0).abs() < 1e-12, "wait {w1}");
        assert_eq!(s.slo_violations(), 1);
        assert_eq!(s.pending_depth(q), 0);
    }

    #[test]
    fn cancel_enqueue_rolls_back_admission() {
        let (clock, mut s) = sched(&SchedConfig::default());
        let a = s.register("a", policy(1.0));
        assert!(s.try_enqueue(a, 0, 0, 2, 0.0));
        clock.advance(1.0);
        assert!(s.try_enqueue(a, 7, 7, 3, 0.0));
        s.cancel_enqueue(a, 7, 7, 3);
        assert_eq!(s.pending_depth(a), 2);
        // The surviving lane-0 stamps still pair correctly.
        let mut seen = 0;
        s.placed(a, 0, 2, |w| {
            assert!((w - 1.0).abs() < 1e-12, "wait {w}");
            seen += 1;
        });
        assert_eq!(seen, 2);
        assert_eq!(s.pending_depth(a), 0);
    }

    /// Priority classes reorder placements across requests within one
    /// run queue; tag-keyed placement must pop each request's OWN
    /// stamps, or the overtaking request inherits the overtaken one's
    /// older arrival (inflated wait, spurious SLO violation) while the
    /// overtaken request's waits are silently deflated.
    #[test]
    fn tagged_placement_pairs_waits_across_priorities() {
        let (clock, mut s) = sched(&SchedConfig::default());
        let q = s.register("m", QueuePolicy {
            slo_p95_s: Some(5.0),
            ..QueuePolicy::default()
        });
        // Request A (tag 1): 2 sequences at t=0. Request B (tag 2): 1
        // sequence at t=1, higher priority — placed first.
        assert!(s.try_enqueue(q, 0, 1, 2, 0.0));
        clock.advance(1.0);
        assert!(s.try_enqueue(q, 0, 2, 1, 0.0));
        clock.advance(0.5);
        let mut wb = f64::NAN;
        s.placed_at_tag(q, 0, 2, 1, 1.5, |w| wb = w);
        assert!((wb - 0.5).abs() < 1e-12,
                "overtaker's wait mis-paired: {wb}");
        assert_eq!(s.slo_violations(), 0, "no spurious violation");
        // Request A places much later: its waits are the true ones.
        clock.advance(4.5);
        let mut seen = Vec::new();
        s.placed_at_tag(q, 0, 1, 2, 6.0, |w| seen.push(w));
        assert_eq!(seen, vec![6.0, 6.0]);
        assert_eq!(s.slo_violations(), 2);
        assert_eq!(s.pending_depth(q), 0);
    }

    /// The cancel-rollback bug: popping a lane's most recent stamps
    /// blindly would remove an *interloper's* stamps when another
    /// same-lane request was admitted between the optimistic enqueue and
    /// the cancel. Tag-keyed stamps roll back exactly the canceled
    /// request's entries, so the interloper's wait survives intact.
    #[test]
    fn cancel_is_exact_under_interleaved_admissions() {
        let (clock, mut s) = sched(&SchedConfig::default());
        let a = s.register("a", policy(1.0));
        // Request 1 (tag 1) optimistically enqueued at t=0 on lane 0.
        assert!(s.try_enqueue(a, 0, 1, 2, 0.0));
        clock.advance(1.0);
        // Interloper (tag 2) admitted on the SAME lane at t=1, before
        // request 1's admission is rolled back.
        assert!(s.try_enqueue(a, 0, 2, 1, 0.0));
        s.cancel_enqueue(a, 0, 1, 2);
        assert_eq!(s.pending_depth(a), 1);
        // The interloper's stamp must be its own t=1 arrival (wait 2),
        // not an inherited t=0 stamp (wait 3).
        clock.advance(2.0);
        let mut got = f64::NAN;
        s.placed(a, 0, 1, |w| got = w);
        assert!((got - 2.0).abs() < 1e-12,
                "interloper wait corrupted by rollback: {got}");
        assert_eq!(s.pending_depth(a), 0);
        // Canceling more than the tag has stamps removes only its own.
        assert!(s.try_enqueue(a, 0, 9, 1, 0.0));
        s.cancel_enqueue(a, 0, 1, 5);
        assert_eq!(s.pending_depth(a), 1, "foreign stamps must survive");
        s.cancel_enqueue(a, 0, 9, 5);
        assert_eq!(s.pending_depth(a), 0);
    }

    /// Shed accounting is tracked at both granularities: a shed request
    /// rejects 1 *request* and all `n` of its *sequences* (the old code
    /// mixed the units: per-queue sequences vs global requests).
    #[test]
    fn shed_accounting_tracks_both_granularities() {
        let (_c, mut s) = sched(&SchedConfig::default());
        let a = s.register("a", QueuePolicy {
            max_pending: 3,
            shed_on_full: true,
            ..QueuePolicy::default()
        });
        assert!(s.try_enqueue(a, 0, 0, 3, 0.0));
        // One request with 4 sequences: 1 request / 4 sequences shed.
        assert!(!s.try_enqueue(a, 0, 1, 4, 0.0));
        assert_eq!(s.shed_requests(), 1);
        assert_eq!(s.shed_seqs(), 4);
        assert_eq!(s.shed_requests_of(a), 1);
        assert_eq!(s.shed_of(a), 4);
        // A second shed of 2 sequences accumulates both denominators.
        assert!(!s.try_enqueue(a, 0, 2, 2, 0.0));
        assert_eq!(s.shed_requests(), 2);
        assert_eq!(s.shed_seqs(), 6);
        assert_eq!(s.shed_requests_of(a), 2);
        assert_eq!(s.shed_of(a), 6);
        assert_eq!(s.pending_depth(a), 3, "sheds admit nothing");
    }

    /// Preemption trigger: sustained ceiling pressure (EWMA >= slo ·
    /// max_boost with pending work) for `preempt_after` rounds names a
    /// preemptible candidate — over-entitlement queues first, most
    /// residual work within the class; firing resets the streak.
    #[test]
    fn preempt_fires_after_sustained_ceiling_pressure() {
        let cfg = SchedConfig { preempt_after: 3, ..SchedConfig::default() };
        let (clock, mut s) = sched(&cfg);
        let bulk_a = s.register("bulk_a", QueuePolicy {
            preempt: true,
            ..QueuePolicy::default()
        });
        let bulk_b = s.register("bulk_b", QueuePolicy {
            preempt: true,
            ..QueuePolicy::default()
        });
        let slo = s.register("latency", QueuePolicy {
            slo_p95_s: Some(0.01),
            ..QueuePolicy::default()
        });
        // Both bulk queues are over their entitlement (vtime above the
        // idle trigger's 0); bulk_b holds more residual work, so it is
        // the preferred victim even though bulk_a consumed more service
        // — evicting the queue with the least work left would park the
        // most completed progress.
        s.report_step(bulk_a, 0.5);
        s.report_step(bulk_b, 0.1);
        // Blow the SLO queue's EWMA past the ceiling (0.01 * 8 = 0.08)
        // and leave pending work behind it.
        assert!(s.try_enqueue(slo, 0, 0, 3, 0.0));
        clock.advance(0.5);
        s.placed(slo, 0, 1, |_| {});
        assert!(s.wait_ewma(slo) >= 0.08, "EWMA must be at the ceiling");
        let ready = [bulk_a, bulk_b, slo];
        let candidates = [(bulk_a, 4u64), (bulk_b, 40u64)];
        // Streak too short: no fire for the first preempt_after-1 rounds.
        for _ in 0..cfg.preempt_after - 1 {
            s.pick(&ready).unwrap();
            assert_eq!(s.preempt_check(&candidates), None,
                       "fired before the pressure streak matured");
        }
        s.pick(&ready).unwrap();
        assert_eq!(s.preempt_check(&candidates), Some((slo, bulk_b)),
                   "largest-residual over-entitlement queue is the victim");
        assert_eq!(s.preempt_fires(), 1);
        assert_eq!(s.preempt_fires_of(slo), 1);
        // The streak was reset: the very next round cannot re-fire.
        s.pick(&ready).unwrap();
        assert_eq!(s.preempt_check(&candidates), None);
        // With equal residuals, the vtime tie-break names the most
        // over-entitlement queue (the historical rule).
        for _ in 0..cfg.preempt_after {
            s.pick(&ready).unwrap();
        }
        assert_eq!(s.preempt_check(&[(bulk_a, 7), (bulk_b, 7)]),
                   Some((slo, bulk_a)));
        // Non-preemptible candidates are never victims; the trigger
        // itself is excluded even if marked preemptible.
        for _ in 0..cfg.preempt_after {
            s.pick(&ready).unwrap();
        }
        assert_eq!(s.preempt_check(&[(slo, 9)]), None);
        // Pressure clears when the pending work is gone (and again when
        // the EWMA recovers below the SLO).
        assert!(!s.preempt_cleared(slo));
        s.placed(slo, 0, 2, |_| {});
        assert_eq!(s.pending_depth(slo), 0);
        assert!(s.preempt_cleared(slo));
        // A queue with no SLO can never hold preemption pressure.
        assert!(s.preempt_cleared(bulk_a));
    }

    /// Residual ranking applies *within* the over-entitlement class: a
    /// candidate below the trigger's vtime never outranks one above it,
    /// no matter how much residual work it holds.
    #[test]
    fn preempt_prefers_over_entitlement_before_residual() {
        let cfg = SchedConfig { preempt_after: 1, ..SchedConfig::default() };
        let (clock, mut s) = sched(&cfg);
        let lean = s.register("lean", QueuePolicy {
            preempt: true,
            ..QueuePolicy::default()
        });
        let fat = s.register("fat", QueuePolicy {
            preempt: true,
            ..QueuePolicy::default()
        });
        let slo = s.register("latency", QueuePolicy {
            slo_p95_s: Some(0.01),
            ..QueuePolicy::default()
        });
        // Put the trigger's vtime between the two candidates': `lean`
        // stays under-entitled, `fat` over-entitled.
        s.report_step(slo, 0.3);
        s.report_step(fat, 0.6);
        assert!(s.try_enqueue(slo, 0, 0, 2, 0.0));
        clock.advance(0.5);
        s.placed(slo, 0, 1, |_| {});
        s.pick(&[lean, fat, slo]).unwrap();
        assert_eq!(s.preempt_check(&[(lean, 1000), (fat, 1)]),
                   Some((slo, fat)),
                   "under-entitled residual-heavy queue must not outrank \
                    an over-entitled one");
    }

    /// Checkpoint budget: a queue whose charged redo steps reach
    /// `checkpoint_budget` stops being named a victim, so sustained SLO
    /// pressure falls through to the next candidate (or fires nothing)
    /// instead of re-evicting the same bulk queue forever.
    #[test]
    fn checkpoint_budget_retires_exhausted_victims() {
        let cfg = SchedConfig {
            preempt_after: 1,
            checkpoint_budget: 10,
            ..SchedConfig::default()
        };
        let (clock, mut s) = sched(&cfg);
        let bulk_a = s.register("bulk_a", QueuePolicy {
            preempt: true,
            ..QueuePolicy::default()
        });
        let bulk_b = s.register("bulk_b", QueuePolicy {
            preempt: true,
            ..QueuePolicy::default()
        });
        let slo = s.register("latency", QueuePolicy {
            slo_p95_s: Some(0.01),
            ..QueuePolicy::default()
        });
        s.report_step(bulk_a, 0.5);
        s.report_step(bulk_b, 0.4);
        assert!(s.try_enqueue(slo, 0, 0, 2, 0.0));
        clock.advance(0.5);
        s.placed(slo, 0, 1, |_| {});
        let ready = [bulk_a, bulk_b, slo];
        // bulk_a has more residual: first fire names it, the caller
        // charges the parked progress.
        s.pick(&ready).unwrap();
        assert_eq!(s.preempt_check(&[(bulk_a, 30), (bulk_b, 20)]),
                   Some((slo, bulk_a)));
        s.charge_preemption(bulk_a, 10);
        assert_eq!(s.redo_charged_of(bulk_a), 10);
        // Budget exhausted: the next fire must fall through to bulk_b
        // even though bulk_a still ranks first on residual.
        s.pick(&ready).unwrap();
        assert_eq!(s.preempt_check(&[(bulk_a, 30), (bulk_b, 20)]),
                   Some((slo, bulk_b)));
        s.charge_preemption(bulk_b, 10);
        // Every candidate exhausted: pressure no longer fires at all.
        s.pick(&ready).unwrap();
        assert_eq!(s.preempt_check(&[(bulk_a, 30), (bulk_b, 20)]), None);
    }

    #[test]
    fn age_backdates_arrivals() {
        let (clock, mut s) = sched(&SchedConfig::default());
        let a = s.register("a", policy(1.0));
        clock.advance(1.0);
        // The request spent 0.3s in the channel before the engine saw it.
        assert!(s.try_enqueue(a, 0, 0, 1, 0.3));
        clock.advance(0.2);
        let mut got = f64::NAN;
        s.placed(a, 0, 1, |w| got = w);
        assert!((got - 0.5).abs() < 1e-12, "wait {got}");
    }

    #[test]
    fn policy_spec_parsing() {
        let mut p = QueuePolicy::default();
        p.apply_spec("weight:4, slo:0.05, burst:2, pending:64, shed")
            .unwrap();
        assert_eq!(p.weight, 4.0);
        assert_eq!(p.slo_p95_s, Some(0.05));
        assert_eq!(p.max_consecutive, 2);
        assert_eq!(p.max_pending, 64);
        assert!(p.shed_on_full);
        p.apply_spec("queue").unwrap();
        assert!(!p.shed_on_full);
        assert!(!p.preempt);
        p.apply_spec("preempt:on").unwrap();
        assert!(p.preempt);
        p.apply_spec("preempt:off").unwrap();
        assert!(!p.preempt);
        assert!(p.apply_spec("preempt:maybe").is_err());
        assert!(p.apply_spec("weight:-1").is_err());
        assert!(p.apply_spec("weight:inf").is_err());
        assert!(p.apply_spec("slo:inf").is_err());
        assert!(p.apply_spec("burst:0").is_err());
        assert!(p.apply_spec("pending:0").is_err());
        assert!(p.apply_spec("wat:3").is_err());
        assert!(p.apply_spec("shedd").is_err());
    }

    #[test]
    fn sched_config_cli_and_resolution() {
        let mut cfg = SchedConfig::default();
        cfg.apply_cli("pending:128,shed; owt=weight:4,slo:0.02; gpt2=weight:1")
            .unwrap();
        assert_eq!(cfg.default_policy.max_pending, 128);
        assert!(cfg.default_policy.shed_on_full);
        let owt = cfg.resolve("owt");
        assert_eq!(owt.weight, 4.0);
        assert_eq!(owt.slo_p95_s, Some(0.02));
        // Per-model overrides layer on the default active when applied.
        assert_eq!(owt.max_pending, 128);
        assert!(owt.shed_on_full);
        let other = cfg.resolve("unknown");
        assert_eq!(other.weight, 1.0);
        assert_eq!(other.max_pending, 128);
        assert!(cfg.apply_cli("owt=weight:zero").is_err());
        // Order independence: default edits apply before overrides no
        // matter where they appear in the spec.
        let mut flipped = SchedConfig::default();
        flipped
            .apply_cli("owt=weight:4,slo:0.02; gpt2=weight:1; pending:128,shed")
            .unwrap();
        assert_eq!(flipped.resolve("owt"), cfg.resolve("owt"));
        assert_eq!(flipped.resolve("gpt2"), cfg.resolve("gpt2"));
    }
}
