//! Dynamic batching policy.
//!
//! XLA executables have static shapes, so the unit of batching is the
//! bucket ladder compiled per model (e.g. {1, 4, 16}). `pick_bucket` is
//! the **single** bucket-selection policy in the codebase (implemented in
//! `engine::scheduler`, re-exported here): the continuous-batching
//! scheduler applies it every step to find the smallest bucket covering
//! the resident sequences, and sizes its
//! slot table to the largest rung — so overflow parks in the pending queue
//! and the truncating fallback below is never reached from the engine (a
//! model is never handed a batch size it didn't compile). `max_wait` now
//! only bounds the idle-engine admission window (admission otherwise
//! happens between scheduler steps).

use std::time::Duration;

use crate::coordinator::sched::SchedConfig;
use crate::engine::FaultPlan;

#[derive(Clone, Debug)]
pub struct BatcherConfig {
    /// Maximum time to hold the first request of a batch while waiting for
    /// companions.
    pub max_wait: Duration,
    /// Cross-queue scheduling: default queue policy, per-model overrides,
    /// and the weighted-selector tuning knobs (see `coordinator::sched`).
    pub sched: SchedConfig,
    /// Optional live-trace recorder: when set, the engine loop sends one
    /// [`crate::sim::TraceEvent`] per admitted generate request (its
    /// backdated arrival instant, model, sequence count, seed, priority)
    /// and per executed step (model, observed cost). The stream is what
    /// `examples/trace_replay.rs` assembles into a JSONL trace the sim
    /// harness replays deterministically.
    pub trace: Option<std::sync::mpsc::Sender<crate::sim::TraceEvent>>,
    /// Deterministic fault injection (`--fault-plan`): per-model
    /// [`FaultPlan`]s applied to each fresh run queue's stepper (step
    /// granularity; see `engine::fault`). Empty = no faults.
    pub faults: std::collections::BTreeMap<String, FaultPlan>,
    /// Server-wide default request deadline (`--deadline-ms`), applied
    /// when a request carries no `deadline_ms` of its own. `None` = no
    /// default deadline.
    pub default_deadline_ms: Option<u64>,
    /// Sharded mode: seconds without a heartbeat (load-gauge publish)
    /// before the router marks a replica Down and admission routes
    /// around it. Exactly at the threshold a replica is still Up; see
    /// `coordinator::router::Liveness`.
    pub heartbeat_timeout_s: f64,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        BatcherConfig {
            max_wait: Duration::from_millis(5),
            sched: SchedConfig::default(),
            trace: None,
            faults: std::collections::BTreeMap::new(),
            default_deadline_ms: None,
            heartbeat_timeout_s: 30.0,
        }
    }
}

/// Smallest bucket >= n, or the largest available if n exceeds them all.
/// Implemented in the engine (the layer that executes buckets) and
/// re-exported here so L3 code keeps its historical path.
pub use crate::engine::scheduler::pick_bucket;

/// Padding waste of running `n` real rows in bucket `b`.
pub fn padding_waste(bucket: usize, n: usize) -> f64 {
    if bucket == 0 {
        return 0.0;
    }
    (bucket.saturating_sub(n)) as f64 / bucket as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn picks_smallest_fitting() {
        let b = [1, 4, 16];
        assert_eq!(pick_bucket(&b, 1), 1);
        assert_eq!(pick_bucket(&b, 2), 4);
        assert_eq!(pick_bucket(&b, 4), 4);
        assert_eq!(pick_bucket(&b, 5), 16);
    }

    #[test]
    fn oversize_falls_back_to_largest() {
        assert_eq!(pick_bucket(&[1, 4], 9), 4);
    }

    #[test]
    fn empty_buckets_degenerate() {
        assert_eq!(pick_bucket(&[], 3), 3);
    }

    #[test]
    fn waste_fraction() {
        assert_eq!(padding_waste(4, 4), 0.0);
        assert_eq!(padding_waste(4, 1), 0.75);
    }
}
