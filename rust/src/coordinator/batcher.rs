//! Dynamic batching policy.
//!
//! XLA executables have static shapes, so the unit of batching is the
//! bucket ladder compiled per model (e.g. {1, 4, 16}). The engine thread
//! accumulates compatible requests for at most `max_wait`, stopping early
//! once the largest bucket is filled; `pick_bucket` then selects the
//! smallest bucket that fits and the engine pads the remainder with dummy
//! rows. The trade-off mirrors vLLM's batch scheduler: waiting adds queue
//! latency but amortizes the forward pass.

use std::time::Duration;

#[derive(Clone, Copy, Debug)]
pub struct BatcherConfig {
    /// Maximum time to hold the first request of a batch while waiting for
    /// companions.
    pub max_wait: Duration,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        BatcherConfig { max_wait: Duration::from_millis(5) }
    }
}

/// Smallest bucket >= n, or the largest available if n exceeds them all.
pub fn pick_bucket(buckets: &[usize], n: usize) -> usize {
    buckets
        .iter()
        .copied()
        .filter(|&b| b >= n)
        .min()
        .or_else(|| buckets.iter().copied().max())
        .unwrap_or(n.max(1))
}

/// Padding waste of running `n` real rows in bucket `b`.
pub fn padding_waste(bucket: usize, n: usize) -> f64 {
    if bucket == 0 {
        return 0.0;
    }
    (bucket.saturating_sub(n)) as f64 / bucket as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn picks_smallest_fitting() {
        let b = [1, 4, 16];
        assert_eq!(pick_bucket(&b, 1), 1);
        assert_eq!(pick_bucket(&b, 2), 4);
        assert_eq!(pick_bucket(&b, 4), 4);
        assert_eq!(pick_bucket(&b, 5), 16);
    }

    #[test]
    fn oversize_falls_back_to_largest() {
        assert_eq!(pick_bucket(&[1, 4], 9), 4);
    }

    #[test]
    fn empty_buckets_degenerate() {
        assert_eq!(pick_bucket(&[], 3), 3);
    }

    #[test]
    fn waste_fraction() {
        assert_eq!(padding_waste(4, 4), 0.0);
        assert_eq!(padding_waste(4, 1), 0.75);
    }
}
