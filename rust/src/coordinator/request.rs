//! Request / response types of the coordinator API.

use crate::engine::{MdmParams, Prompt, Sample, SpecParams, Window};
use crate::util::json::Json;

/// Which sampling algorithm to run.
#[derive(Clone, Debug)]
pub enum SamplerChoice {
    /// Algorithm 3 (the paper's contribution).
    Speculative(SpecParams),
    /// Standard masked-diffusion baseline.
    Mdm(MdmParams),
}

impl Default for SamplerChoice {
    fn default() -> Self {
        SamplerChoice::Speculative(SpecParams::default())
    }
}

impl SamplerChoice {
    /// Batching key: requests with identical keys can share a run queue.
    /// Derived from the FULL params debug repr — run queues persist across
    /// requests, and the queue creator's params are applied to every
    /// admitted sequence, so any field left out of the key (historically
    /// `max_outer`) would be silently substituted for later requests.
    pub fn key(&self) -> String {
        match self {
            SamplerChoice::Speculative(p) => format!("spec:{p:?}"),
            SamplerChoice::Mdm(p) => format!("mdm:{p:?}"),
        }
    }
}

#[derive(Clone, Debug)]
pub struct GenRequest {
    pub model: String,
    pub n_samples: usize,
    pub sampler: SamplerChoice,
    /// Optional infilling prompt (length D; None slots are generated).
    pub prompt: Option<Prompt>,
    pub seed: u64,
    /// If true the response depends only on `seed` (no per-call entropy) —
    /// used by tests and the reproduction harnesses.
    pub deterministic: bool,
    /// Priority class *within* this model's queue: higher-priority
    /// requests overtake queued lower-priority sequences (admitted but
    /// not yet executing) and are the last chosen as preemption victims.
    /// Priority orders work inside a queue; cross-queue shares stay
    /// governed by `QueuePolicy` weights. `None` takes the server's
    /// `--default-priority`.
    pub priority: Option<i32>,
    /// Request deadline in milliseconds, measured from the caller-side
    /// enqueue instant. Enforced at admission, lazily in pending queues,
    /// and between engine steps; an expired request is answered with a
    /// deadline error (HTTP 504) and counted in `deadline_sheds`. `None`
    /// takes the server's `--deadline-ms` default (possibly none).
    pub deadline_ms: Option<u64>,
}

impl Default for GenRequest {
    fn default() -> Self {
        GenRequest {
            model: String::new(),
            n_samples: 1,
            sampler: SamplerChoice::default(),
            prompt: None,
            seed: 0,
            deterministic: false,
            priority: None,
            deadline_ms: None,
        }
    }
}

impl GenRequest {
    pub fn total_samples(&self) -> usize {
        self.n_samples
    }

    /// Requests batch together iff model + sampler settings + prompt shape
    /// match (deterministic requests never batch with others: their RNG
    /// stream must not depend on queue neighbours).
    pub fn batch_key(&self) -> String {
        let det = if self.deterministic {
            format!("det{}", self.seed)
        } else {
            "live".into()
        };
        format!("{}|{}|{}", self.model, self.sampler.key(), det)
    }
}

#[derive(Clone, Debug)]
pub struct GenResponse {
    pub model: String,
    pub samples: Vec<Sample>,
    /// Per-request enqueue -> completion wall time. Under weighted
    /// cross-queue scheduling this includes the service this queue's
    /// weight conceded to other queues *after* its sequences were placed
    /// — the placement-side wait alone is the per-sequence
    /// `queue_wait_s` metric (which is what `slo_p95_s` policies are
    /// enforced against). A low-weight queue therefore shows small
    /// `queue_wait_s` but stretched `wall_s` under mixed load.
    pub wall_s: f64,
}

#[derive(Clone, Debug)]
pub struct ScoreRequest {
    pub model: String,
    pub tokens: Vec<i32>,
    /// Fixed ordering; random (seeded) if None — Eq. 12's Monte-Carlo ELBO
    /// averages scores over random sigmas.
    pub sigma: Option<Vec<i32>>,
    pub seed: Option<u64>,
    pub with_posterior: bool,
}

#[derive(Clone, Debug)]
pub struct ScoreResponse {
    pub log_likelihood: f64,
    pub sigma: Vec<i32>,
    /// p(N = n | x, sigma) over rejection counts (Prop. C.2).
    pub rejection_posterior: Option<Vec<f64>>,
}

// ---------------------------------------------------------------------------
// JSON (de)serialization for the HTTP API
// ---------------------------------------------------------------------------

impl GenRequest {
    pub fn from_json(v: &Json) -> Result<GenRequest, String> {
        let model = v
            .get("model")
            .and_then(|m| m.as_str())
            .ok_or("missing 'model'")?
            .to_string();
        let n_samples =
            v.get("n").and_then(|n| n.as_usize()).unwrap_or(1).max(1);
        let sampler_name = v
            .get("sampler")
            .and_then(|s| s.as_str())
            .unwrap_or("speculative");
        let temperature =
            v.get("temperature").and_then(|t| t.as_f64()).unwrap_or(1.0);
        let sampler = match sampler_name {
            "speculative" => {
                let window_s = v
                    .get("window")
                    .and_then(|w| w.as_str())
                    .unwrap_or("cosine:0.05")
                    .to_string();
                let window = Window::parse(&window_s)
                    .ok_or(format!("bad window '{window_s}'"))?;
                SamplerChoice::Speculative(SpecParams {
                    window,
                    n_verify: v
                        .get("n_verify")
                        .and_then(|n| n.as_usize())
                        .unwrap_or(1)
                        .max(1),
                    temperature,
                    ..Default::default()
                })
            }
            "mdm" => SamplerChoice::Mdm(MdmParams {
                steps: v
                    .get("steps")
                    .and_then(|s| s.as_usize())
                    .unwrap_or(64)
                    .max(1),
                temperature,
            }),
            other => return Err(format!("unknown sampler '{other}'")),
        };
        let prompt = match v.get("prompt") {
            None | Some(Json::Null) => None,
            Some(Json::Obj(slots)) => {
                let seq_len = v
                    .get("seq_len")
                    .and_then(|d| d.as_usize())
                    .ok_or("prompt requires 'seq_len'")?;
                let mut p = Prompt::empty(seq_len);
                for (k, tok) in slots {
                    let pos: usize =
                        k.parse().map_err(|_| "bad prompt key")?;
                    if pos >= seq_len {
                        return Err("prompt position out of range".into());
                    }
                    p.0[pos] =
                        Some(tok.as_f64().ok_or("bad prompt token")? as i32);
                }
                Some(p)
            }
            _ => return Err("prompt must be an object".into()),
        };
        // Range-validate client-facing knobs here so bad values are a
        // parse error (HTTP 400), not engine behavior.
        let priority = match v.get("priority") {
            None | Some(Json::Null) => None,
            Some(p) => {
                let p = p.as_f64().ok_or("bad 'priority'")?;
                if p.fract() != 0.0 || !(-1000.0..=1000.0).contains(&p) {
                    return Err(format!(
                        "priority {p} out of range [-1000, 1000]"
                    ));
                }
                Some(p as i32)
            }
        };
        let deadline_ms = match v.get("deadline_ms") {
            None | Some(Json::Null) => None,
            Some(d) => {
                let d = d.as_f64().ok_or("bad 'deadline_ms'")?;
                // Bounded above by a day: effectively-infinite deadlines
                // should be expressed by omitting the field.
                if d.fract() != 0.0 || d < 1.0 || d > 86_400_000.0 {
                    return Err(format!(
                        "deadline_ms {d} out of range [1, 86400000]"
                    ));
                }
                Some(d as u64)
            }
        };
        Ok(GenRequest {
            model,
            n_samples,
            sampler,
            prompt,
            seed: v.get("seed").and_then(|s| s.as_f64()).unwrap_or(0.0)
                as u64,
            deterministic: v
                .get("deterministic")
                .and_then(|d| d.as_bool())
                .unwrap_or(false),
            priority,
            deadline_ms,
        })
    }
}

impl GenResponse {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("model", Json::str(self.model.clone())),
            ("wall_s", Json::num(self.wall_s)),
            (
                "samples",
                Json::arr(self.samples.iter().map(|s| {
                    Json::obj(vec![
                        (
                            "tokens",
                            Json::arr(
                                s.tokens
                                    .iter()
                                    .map(|&t| Json::num(t as f64)),
                            ),
                        ),
                        ("nfe", Json::num(s.nfe)),
                        ("outer_loops", Json::num(s.outer_loops as f64)),
                        ("accepted", Json::num(s.accepted as f64)),
                        ("rejected", Json::num(s.rejected as f64)),
                    ])
                })),
            ),
        ])
    }
}

impl ScoreRequest {
    pub fn from_json(v: &Json) -> Result<ScoreRequest, String> {
        let model = v
            .get("model")
            .and_then(|m| m.as_str())
            .ok_or("missing 'model'")?
            .to_string();
        let tokens: Vec<i32> = v
            .get("tokens")
            .and_then(|t| t.as_f64_vec())
            .ok_or("missing 'tokens'")?
            .into_iter()
            .map(|x| x as i32)
            .collect();
        let sigma = v
            .get("sigma")
            .and_then(|s| s.as_f64_vec())
            .map(|s| s.into_iter().map(|x| x as i32).collect());
        Ok(ScoreRequest {
            model,
            tokens,
            sigma,
            seed: v.get("seed").and_then(|s| s.as_f64()).map(|s| s as u64),
            with_posterior: v
                .get("with_posterior")
                .and_then(|b| b.as_bool())
                .unwrap_or(false),
        })
    }
}

impl ScoreResponse {
    pub fn to_json(&self) -> Json {
        let mut fields = vec![
            ("log_likelihood", Json::num(self.log_likelihood)),
            (
                "sigma",
                Json::arr(self.sigma.iter().map(|&s| Json::num(s as f64))),
            ),
        ];
        if let Some(p) = &self.rejection_posterior {
            fields.push((
                "rejection_posterior",
                Json::arr(p.iter().map(|&x| Json::num(x))),
            ));
        }
        Json::obj(fields)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gen_request_json_roundtrip() {
        let v = Json::parse(
            r#"{"model":"owt","n":2,"sampler":"speculative",
                "window":"cosine:0.02","n_verify":3,"seed":7}"#,
        )
        .unwrap();
        let r = GenRequest::from_json(&v).unwrap();
        assert_eq!(r.model, "owt");
        assert_eq!(r.n_samples, 2);
        assert_eq!(r.priority, None, "absent priority stays unset");
        match r.sampler {
            SamplerChoice::Speculative(p) => {
                assert_eq!(p.n_verify, 3);
                assert_eq!(p.window, Window::Cosine { dtau: 0.02 });
            }
            _ => panic!("wrong sampler"),
        }
    }

    #[test]
    fn mdm_request_and_prompt() {
        let v = Json::parse(
            r#"{"model":"owt","sampler":"mdm","steps":16,
                "seq_len":8,"prompt":{"0":5,"3":1}}"#,
        )
        .unwrap();
        let r = GenRequest::from_json(&v).unwrap();
        let p = r.prompt.unwrap();
        assert_eq!(p.0[0], Some(5));
        assert_eq!(p.0[3], Some(1));
        assert_eq!(p.0[1], None);
    }

    #[test]
    fn rejects_bad_requests() {
        for s in [
            r#"{"n":1}"#,
            r#"{"model":"m","sampler":"bogus"}"#,
            r#"{"model":"m","window":"wat"}"#,
            r#"{"model":"m","prompt":{"0":1}}"#,
            r#"{"model":"m","priority":1001}"#,
            r#"{"model":"m","priority":-1001}"#,
            r#"{"model":"m","priority":"high"}"#,
            r#"{"model":"m","priority":0.5}"#,
            r#"{"model":"m","deadline_ms":0}"#,
            r#"{"model":"m","deadline_ms":-5}"#,
            r#"{"model":"m","deadline_ms":86400001}"#,
            r#"{"model":"m","deadline_ms":"soon"}"#,
        ] {
            let v = Json::parse(s).unwrap();
            assert!(GenRequest::from_json(&v).is_err(), "{s}");
        }
    }

    #[test]
    fn deadline_parses_and_does_not_split_batch_keys() {
        let v = Json::parse(
            r#"{"model":"owt","n":1,"deadline_ms":250}"#,
        )
        .unwrap();
        let r = GenRequest::from_json(&v).unwrap();
        assert_eq!(r.deadline_ms, Some(250));
        // Deadlines shape shedding, not sampling: two requests that
        // differ only in deadline must share a run queue.
        let mut other = r.clone();
        other.deadline_ms = None;
        assert_eq!(r.batch_key(), other.batch_key());
    }

    #[test]
    fn priority_parses_and_does_not_split_batch_keys() {
        let v = Json::parse(
            r#"{"model":"owt","n":1,"priority":-3}"#,
        )
        .unwrap();
        let r = GenRequest::from_json(&v).unwrap();
        assert_eq!(r.priority, Some(-3));
        // Priorities order work WITHIN a run queue: two requests that
        // differ only in priority must share a batch key.
        let mut hi = r.clone();
        hi.priority = Some(9);
        assert_eq!(r.batch_key(), hi.batch_key());
    }

    #[test]
    fn batch_keys_separate_incompatible() {
        let a = GenRequest {
            model: "m".into(),
            ..Default::default()
        };
        let mut b = a.clone();
        b.sampler = SamplerChoice::Mdm(MdmParams::default());
        assert_ne!(a.batch_key(), b.batch_key());
        let mut c = a.clone();
        c.deterministic = true;
        assert_ne!(a.batch_key(), c.batch_key());
        assert_eq!(a.batch_key(), a.clone().batch_key());
    }

    #[test]
    fn score_json_roundtrip() {
        let v = Json::parse(
            r#"{"model":"owt","tokens":[1,2,3],"with_posterior":true}"#,
        )
        .unwrap();
        let r = ScoreRequest::from_json(&v).unwrap();
        assert_eq!(r.tokens, vec![1, 2, 3]);
        assert!(r.with_posterior);
        let resp = ScoreResponse {
            log_likelihood: -3.5,
            sigma: vec![0, 2, 1],
            rejection_posterior: Some(vec![0.5, 0.5]),
        };
        let out = resp.to_json().to_string();
        assert!(out.contains("-3.5"));
        assert!(out.contains("rejection_posterior"));
    }
}
