//! Deterministic virtual-time simulation of the cross-queue scheduler,
//! plus the recorded-trace format it replays.
//!
//! This started life inside `tests/sched_sim.rs` (PR 3) and is promoted
//! to the library so **recorded traces from live runs** can be replayed
//! through the exact same harness (`examples/trace_replay.rs`, the CI
//! smoke replay) — the scenario-diversity door the ROADMAP asked for:
//! any traffic shape you can capture, you can re-run bit-exactly.
//!
//! The weighted SLO-aware selector (`coordinator::sched`) is pure state
//! driven by an injected `Clock`, so [`simulate`] replays scripted
//! multi-queue arrival traces against real `BoundStepper`/`MockModel`
//! steppers with synthetic per-step costs on a `SimClock` — every
//! latency/fairness number is exact: no sleeps, no wall time, no flake.
//! The round-robin baseline (the pre-weighted engine-loop policy) runs
//! in the same harness, so selector comparisons hold everything else
//! fixed.
//!
//! **Preemption** mirrors the engine loop: after each step the harness
//! asks `preempt_check` whether a pressured SLO queue should evict the
//! most over-entitlement `preempt:on` queue; victims' residents are
//! checkpointed (`engine::SeqCheckpoint`), the queue is paused, and the
//! checkpoints resume when `preempt_cleared` reports the pressure gone.
//! [`Report::tokens`] records every retired sequence's token stream, so
//! tests can pin the load-bearing invariant: a preempted sequence's
//! tokens are **bitwise identical** to the same-seed unpreempted run.
//!
//! **Chaos** (PR 7): each queue may carry a deterministic [`FaultPlan`]
//! — its MockModel is wrapped in [`FaultyModel`], so injected panics
//! genuinely unwind out of the model boundary and are contained by
//! `BoundStepper`'s `catch_unwind`, the exact production path. The
//! harness then mirrors the engine loop's supervision: transient
//! failures retry with virtual-time backoff, definitive failures
//! quarantine only the affected queue (every in-flight sequence it held
//! is counted `failed`, exactly once) and feed that queue's circuit
//! breaker; open breakers fast-fail admissions. Arrivals may carry a
//! `deadline` (seconds of budget from arrival); expired sequences are
//! swept between steps and counted in `deadline_sheds`. The conservation
//! pin becomes: every admitted sequence is finished, failed, or
//! deadline-shed — exactly one of the three — and surviving queues'
//! token streams stay bitwise identical to a fault-free run.
//!
//! **Fleet** (PR 8): [`simulate_fleet`] runs N replicas — each with its
//! own steppers, selector, and supervision — on one shared `SimClock`,
//! stepping all ready replicas concurrently per round (the clock
//! advances by the max cost, so aggregate throughput scales with
//! replica count). It mirrors the live router policies exactly:
//! least-loaded admission routing and idle-replica checkpoint migration
//! (evict on A, `adopt` on B), with [`FleetReport::tokens`] keyed by
//! (arrival, sequence) so the bitwise-migration pin compares runs
//! across replica counts and migration on/off.
//!
//! **Replica loss** (this PR): [`simulate_fleet_opts`] adds scripted
//! replica kills ([`FleetOptions::replica_faults`], `kill@N` firing on a
//! replica's Nth step attempt). A killed replica evacuates every
//! resident and pending sequence onto a migration board and stops
//! heartbeating; the router twin ([`Liveness`], driven by the same
//! shared `SimClock` — clock skew between replicas is impossible by
//! construction) marks it Down strictly past the missed-beat threshold,
//! sweeps anything that was routed to it inside the detection window,
//! and grants a supervised restart under geometric backoff and a
//! bounded budget. Survivors adopt the board (`Stepper::adopt`
//! re-mints), so evacuated token streams stay bitwise identical to an
//! undisturbed same-seed run — for *any* adopter choice
//! ([`FleetOptions::adopter_offset`]). When no replica is Up, arrivals
//! brown-out (counted, never admitted); conservation stays exact:
//! admitted = finished + failed + deadline-shed.
//!
//! ## Trace format (JSONL)
//!
//! One JSON object per line; [`write_trace`] / [`read_trace`] round-trip
//! it losslessly (u64 seeds travel as decimal strings — f64 JSON numbers
//! would truncate past 2^53):
//!
//! ```text
//! {"kind":"config","starve_after":64,"wait_alpha":0.2,"max_boost":8,
//!  "preempt_after":4,"max_retries":2,"backoff_s":0.05,
//!  "breaker_threshold":3,"breaker_cooldown_s":1}
//! {"kind":"queue","d":16,"vocab":6,"bucket":4,"model_seed":"7",
//!  "step_cost":0.08,"weight":1,"burst":4,"shed":false,"preempt":true}
//! {"kind":"queue","d":8,...,"slo":0.005,"pending":256,
//!  "faults":"err@2,panic@5",...}
//! {"kind":"arrival","t":0.05,"queue":0,"n":2,"seed":"1001","priority":0,
//!  "deadline":0.25}
//! ```
//!
//! `slo`, `pending`, `faults`, and `deadline` are omitted when unset.
//! Arrival lines must be time-sorted (the writer preserves order;
//! [`simulate`] asserts it). Live runs are captured as a [`TraceEvent`]
//! stream (the coordinator's `BatcherConfig::trace` hook) and assembled
//! into this format by [`assemble_trace`].

use std::collections::{BTreeMap, BTreeSet};
use std::io::Write as _;
use std::path::Path;
use std::rc::Rc;

use crate::coordinator::sched::{CrossQueueScheduler, QueueId, QueuePolicy,
                                SchedConfig};
use crate::coordinator::{Breaker, BreakerState, Liveness, ReplicaState};
use crate::engine::fault::{FaultKind, FaultState};
use crate::engine::{BoundStepper, FaultPlan, FaultyModel, MockModel,
                    Prompt, SeqCheckpoint, SeqParams, SlotId, SpecParams,
                    StepError, Stepper, Window};
use crate::util::json::Json;
use crate::util::rng::Pcg;
use crate::util::simclock::{Clock, SimClock};

/// One simulated queue: a MockModel geometry plus its scheduling policy
/// and the synthetic virtual cost of one scheduler step.
#[derive(Clone, Debug)]
pub struct QueueSpec {
    pub d: usize,
    pub vocab: usize,
    pub bucket: usize,
    pub model_seed: u64,
    pub policy: QueuePolicy,
    /// Synthetic virtual cost of one scheduler step of this queue.
    pub step_cost: f64,
    /// Deterministic fault script for this queue's model (fires on the
    /// Nth draft/verify call via [`FaultyModel`]). `None` = fault-free.
    pub fault: Option<FaultPlan>,
}

impl QueueSpec {
    pub fn new(d: usize, bucket: usize, step_cost: f64, policy: QueuePolicy)
               -> QueueSpec {
        QueueSpec {
            d,
            vocab: 6,
            bucket,
            model_seed: 7,
            policy,
            step_cost,
            fault: None,
        }
    }
}

/// One request arrival: `n` sequences land on `queue` at virtual time
/// `t`, seeded with `seed`, in priority class `priority`, optionally
/// carrying `deadline` seconds of completion budget from `t`.
#[derive(Clone, Copy, Debug)]
pub struct Arrival {
    pub t: f64,
    pub queue: usize,
    pub n: usize,
    pub seed: u64,
    pub priority: i32,
    /// Completion budget in virtual seconds from `t`; sequences alive
    /// past `t + deadline` are swept and counted in `deadline_sheds`.
    pub deadline: Option<f64>,
}

impl Default for Arrival {
    fn default() -> Arrival {
        Arrival {
            t: 0.0,
            queue: 0,
            n: 1,
            seed: 0,
            priority: 0,
            deadline: None,
        }
    }
}

#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum Selector {
    RoundRobin,
    Weighted,
}

/// Everything a simulation run observed. `PartialEq` is the determinism
/// pin: two replays of one trace must compare bit-equal.
#[derive(Clone, Debug, PartialEq)]
pub struct Report {
    /// Per queue: one exact virtual-time queue wait per sequence
    /// (admission -> first slot placement), in placement order. Resumed
    /// re-placements are not re-observed.
    pub waits: Vec<Vec<f64>>,
    /// Per queue: scheduler steps executed.
    pub steps: Vec<u64>,
    /// Per queue: steps executed while *every* queue had work (the
    /// window where weighted shares are defined).
    pub busy_steps: Vec<u64>,
    /// Per queue: sequences retired.
    pub finished: Vec<usize>,
    /// Per queue: sequences answered as failed when a definitive fault
    /// quarantined their run queue (each counted exactly once).
    pub failed: Vec<usize>,
    /// Definitive step failures (fatal, or a transient burst out of
    /// retries) — the sim's `engine_faults` counter.
    pub engine_faults: u64,
    /// Transient step failures that were retried after backoff.
    pub retries: u64,
    /// Sequences removed because their deadline expired (at admission or
    /// mid-flight) — distinct from backpressure `shed`.
    pub deadline_sheds: u64,
    /// Sequences fast-failed at admission by an open circuit breaker.
    pub breaker_shed: u64,
    /// Closed->Open breaker transitions observed.
    pub breaker_opens: u64,
    /// Total *sequences* shed by admission backpressure — turned away at
    /// the door, or admitted and later displaced by a strictly
    /// higher-priority arrival (priority-aware shedding).
    pub shed: u64,
    /// Total *requests* shed by admission backpressure (one shed
    /// request sheds all of its sequences — distinct denominators).
    pub shed_requests: u64,
    pub slo_violations: u64,
    /// Largest ready-but-unpicked streak any queue experienced (paused
    /// queues are parked deliberately and do not count).
    pub max_starve: u64,
    /// Sequences evicted mid-run by preemption / resumed into slots /
    /// policy-level preemption fires.
    pub preemptions: u64,
    pub resumes: u64,
    pub preempt_fires: u64,
    /// Per queue: every retired sequence's token stream, keyed by its
    /// stable `SlotId` — the bitwise checkpoint/resume determinism pin.
    pub tokens: Vec<BTreeMap<SlotId, Vec<i32>>>,
    pub t_end: f64,
}

/// Replay `trace` against the queues in `specs` under the given selector,
/// in virtual time, until all admitted work drains. Asserts conservation
/// (every admitted sequence finishes exactly once) internally. Preemption
/// runs only under [`Selector::Weighted`] and only against `preempt:on`
/// queues, mirroring the engine loop's wiring.
pub fn simulate(specs: &[QueueSpec], trace: &[Arrival], selector: Selector,
                cfg: &SchedConfig) -> Report {
    for w in trace.windows(2) {
        assert!(w[0].t <= w[1].t, "trace must be time-sorted");
    }
    // Every model is wrapped in FaultyModel (an empty plan never fires),
    // so injected faults exercise the genuine unwind-containment path
    // through BoundStepper::step.
    let models: Vec<FaultyModel<MockModel>> = specs
        .iter()
        .map(|s| {
            let mut m = MockModel::new(s.d, s.vocab, s.model_seed);
            m.buckets = vec![s.bucket];
            FaultyModel::new(m, s.fault.clone().unwrap_or_default())
        })
        .collect();
    let fault_states: Vec<Rc<FaultState>> =
        models.iter().map(|m| m.fault_state()).collect();
    let params = SpecParams {
        window: Window::Constant(1),
        ..Default::default()
    };
    let mut steppers: Vec<BoundStepper<'_, FaultyModel<MockModel>>> = models
        .iter()
        .map(|m| BoundStepper::new(m, SeqParams::Spec(params.clone())))
        .collect();

    let clock = SimClock::new();
    // Phase accounting inside the steppers runs on the same virtual
    // timeline — the whole simulation is wall-time-free.
    for st in steppers.iter_mut() {
        st.sched.set_clock(Box::new(clock.clone()));
    }
    let mut xq = CrossQueueScheduler::new(Box::new(clock.clone()), cfg);
    let qids: Vec<QueueId> = specs
        .iter()
        .enumerate()
        .map(|(i, s)| xq.register(&format!("q{i}"), s.policy.clone()))
        .collect();
    let weighted = selector == Selector::Weighted;

    let nq = specs.len();
    let mut admit_time: Vec<BTreeMap<SlotId, f64>> =
        vec![BTreeMap::new(); nq];
    // Which request tag (the arrival's admission index) each sequence
    // belongs to: placements are reported per tag so the selector pops
    // the right arrival's stamps even when priority classes reorder
    // placements across arrivals (mirrors the engine loop).
    let mut admit_tag: Vec<BTreeMap<SlotId, u64>> = vec![BTreeMap::new(); nq];
    let mut seen_done: Vec<BTreeSet<SlotId>> = vec![BTreeSet::new(); nq];
    let mut waits: Vec<Vec<f64>> = vec![Vec::new(); nq];
    let mut tokens: Vec<BTreeMap<SlotId, Vec<i32>>> =
        vec![BTreeMap::new(); nq];
    let mut steps = vec![0u64; nq];
    let mut busy_steps = vec![0u64; nq];
    let mut finished = vec![0usize; nq];
    let mut since_pick = vec![0u64; nq];
    let mut max_starve = 0u64;
    let mut harness_shed = 0u64;
    let mut harness_shed_reqs = 0u64;
    let mut parked: Vec<Vec<SeqCheckpoint>> = (0..nq)
        .map(|_| Vec::new())
        .collect();
    let mut parked_trigger: Vec<Option<QueueId>> = vec![None; nq];
    let mut preemptions = 0u64;
    let mut rr = 0usize;
    let mut next = 0usize;
    let mut ready_buf: Vec<QueueId> = Vec::new();
    let mut cand_buf: Vec<(QueueId, u64)> = Vec::new();
    // Supervision state, mirroring the engine loop: per-queue retry
    // bursts with virtual-time backoff, and a per-queue (= per-model)
    // circuit breaker gating admissions.
    let mut q_retries = vec![0u32; nq];
    let mut not_before = vec![0.0f64; nq];
    let mut breakers: Vec<Breaker> =
        (0..nq).map(|_| Breaker::new(&cfg.supervise)).collect();
    let mut failed: Vec<BTreeSet<SlotId>> = vec![BTreeSet::new(); nq];
    let mut deadlined: Vec<BTreeSet<SlotId>> = vec![BTreeSet::new(); nq];
    // Admitted-then-evicted by priority-aware shedding (a strictly
    // higher-priority arrival displaced them from a full queue).
    let mut shed_admitted: Vec<BTreeSet<SlotId>> = vec![BTreeSet::new(); nq];
    let mut deadline_at: Vec<BTreeMap<SlotId, f64>> =
        vec![BTreeMap::new(); nq];
    let mut placed_set: Vec<BTreeSet<SlotId>> = vec![BTreeSet::new(); nq];
    let mut engine_faults = 0u64;
    let mut retries = 0u64;
    let mut deadline_sheds = 0u64;
    let mut breaker_shed = 0u64;
    let mut breaker_opens = 0u64;

    loop {
        // Admit everything due at the current virtual time (requests that
        // arrived while the engine was stepping are backdated, exactly as
        // the coordinator backdates channel transit time).
        while next < trace.len() && trace[next].t <= clock.now() + 1e-12 {
            let a = trace[next];
            next += 1;
            let t_admit = clock.now();
            let age = (t_admit - a.t).max(0.0);
            // Circuit-breaker gate first (the engine's admission order):
            // an open breaker answers the request without queueing it.
            if !breakers[a.queue].admit_allowed(t_admit) {
                breaker_shed += a.n as u64;
                continue;
            }
            // Deadline already burned in transit: a deadline shed, not a
            // backpressure shed.
            if let Some(dl) = a.deadline {
                if age >= dl {
                    deadline_sheds += a.n as u64;
                    continue;
                }
            }
            if weighted {
                let tag = next as u64;
                // Priority-aware shedding: over a full queue, shed the
                // lowest-priority class first instead of turning the
                // arrival away FIFO-blind. The victim must be *strictly*
                // lower-priority and fully pending (no sequence of its
                // request already holds a slot); the whole request is
                // displaced, mirroring the engine loop's
                // `shed_lowest_pending`. Displacement happens *before*
                // the counting `try_enqueue`, so an arrival that wins a
                // spot this way is never also counted shed.
                while xq.is_full(qids[a.queue], a.n) {
                    let qi = a.queue;
                    let Some((vsid, vprio)) = steppers[qi].lowest_pending()
                    else {
                        break;
                    };
                    if vprio >= a.priority {
                        break;
                    }
                    let vtag = admit_tag[qi][&vsid];
                    let victims: Vec<SlotId> = admit_tag[qi]
                        .iter()
                        .filter(|&(sid, &t)| {
                            t == vtag && steppers[qi].is_pending(*sid)
                        })
                        .map(|(&sid, _)| sid)
                        .collect();
                    let fully_pending = admit_tag[qi]
                        .iter()
                        .filter(|&(_, &t)| t == vtag)
                        .all(|(sid, _)| {
                            steppers[qi].is_pending(*sid)
                                || seen_done[qi].contains(sid)
                                || deadlined[qi].contains(sid)
                                || shed_admitted[qi].contains(sid)
                        });
                    if !fully_pending || victims.is_empty() {
                        break;
                    }
                    let mut removed = 0u64;
                    for sid in victims {
                        if steppers[qi].remove_pending(sid)
                            && !placed_set[qi].contains(&sid)
                        {
                            xq.cancel_enqueue(qids[qi], 0, vtag, 1);
                        }
                        deadline_at[qi].remove(&sid);
                        shed_admitted[qi].insert(sid);
                        removed += 1;
                    }
                    xq.count_shed(qids[qi], removed, 1);
                }
                if !xq.try_enqueue(qids[a.queue], 0, tag, a.n, age) {
                    continue; // shed by admission backpressure
                }
            } else {
                let q = &specs[a.queue].policy;
                let over = admit_time[a.queue].len()
                    - seen_done[a.queue].len()
                    - failed[a.queue].len()
                    - deadlined[a.queue].len()
                    - steppers[a.queue].n_active();
                if q.shed_on_full && over + a.n > q.max_pending {
                    harness_shed += a.n as u64;
                    harness_shed_reqs += 1;
                    continue;
                }
            }
            let prompt = Prompt::empty(specs[a.queue].d);
            let mut rng = Pcg::new(a.seed);
            for _ in 0..a.n {
                let sid = steppers[a.queue]
                    .admit_prio(&prompt, rng.split(), a.priority);
                admit_time[a.queue].insert(sid, a.t);
                admit_tag[a.queue].insert(sid, next as u64);
                if let Some(dl) = a.deadline {
                    deadline_at[a.queue].insert(sid, a.t + dl);
                }
            }
        }

        // Resume parked checkpoints whose trigger pressure cleared
        // (mirrors the engine loop's resume pass).
        for i in 0..nq {
            if parked[i].is_empty() {
                continue;
            }
            let clear = parked_trigger[i]
                .map(|t| xq.preempt_cleared(t))
                .unwrap_or(true);
            if clear {
                for ck in parked[i].drain(..) {
                    steppers[i].resume(ck);
                }
                parked_trigger[i] = None;
            }
        }

        // Deadline sweep (the engine's between-steps sweep): expired
        // sequences are removed wherever they live — resident slot,
        // pending queue, or parked checkpoint — and counted as deadline
        // sheds. Surviving sequences are untouched, so their token
        // streams stay bitwise identical to an unswept run.
        let t_sweep = clock.now();
        for i in 0..nq {
            if deadline_at[i].is_empty() {
                continue;
            }
            let expired: Vec<SlotId> = deadline_at[i]
                .iter()
                .filter(|&(_, &dl)| t_sweep >= dl)
                .map(|(&sid, _)| sid)
                .collect();
            for sid in expired {
                deadline_at[i].remove(&sid);
                if steppers[i].evict(sid).is_some() {
                    // Resident: evicted, checkpoint dropped.
                } else if steppers[i].remove_pending(sid) {
                    // Never placed: roll its admission stamp back so the
                    // selector's pending depth stays exact.
                    if weighted && !placed_set[i].contains(&sid) {
                        let tag = admit_tag[i][&sid];
                        xq.cancel_enqueue(qids[i], 0, tag, 1);
                    }
                } else {
                    let before = parked[i].len();
                    parked[i].retain(|ck| ck.id() != sid);
                    assert_eq!(parked[i].len() + 1, before,
                               "expired sequence {sid:?} not found");
                }
                deadlined[i].insert(sid);
                deadline_sheds += 1;
            }
        }

        ready_buf.clear();
        let t_ready = clock.now();
        for (i, st) in steppers.iter().enumerate() {
            if !st.is_idle() && parked[i].is_empty()
                && t_ready + 1e-12 >= not_before[i]
            {
                ready_buf.push(qids[i]);
            }
        }
        if ready_buf.is_empty() {
            // Backstop: nothing runnable but checkpoints still parked
            // (possible only for triggers without pressure semantics) —
            // force-resume so the drain invariant holds.
            if parked.iter().any(|p| !p.is_empty()) {
                for i in 0..nq {
                    for ck in parked[i].drain(..) {
                        steppers[i].resume(ck);
                    }
                    parked_trigger[i] = None;
                }
                continue;
            }
            // Jump virtual time to the next wake instant: the earliest
            // arrival or the earliest backoff expiry of a non-idle queue.
            let wake = steppers
                .iter()
                .enumerate()
                .filter(|(i, st)| !st.is_idle() && parked[*i].is_empty())
                .map(|(i, _)| not_before[i])
                .fold(f64::INFINITY, f64::min);
            let next_t = if next < trace.len() {
                trace[next].t
            } else {
                f64::INFINITY
            };
            let t = wake.min(next_t);
            if !t.is_finite() {
                break;
            }
            clock.set(t.max(clock.now()));
            continue;
        }
        let all_busy = ready_buf.len() == nq;

        let qi = match selector {
            Selector::Weighted => {
                let sid = xq.pick(&ready_buf).expect("ready set non-empty");
                qids.iter().position(|&q| q == sid).unwrap()
            }
            Selector::RoundRobin => {
                // The pre-weighted engine loop: scan from a rotating
                // cursor, step the first ready queue (same readiness
                // gates as the ready set: not parked, not backing off).
                let mut chosen = None;
                for off in 0..nq {
                    let i = (rr + off) % nq;
                    if !steppers[i].is_idle() && parked[i].is_empty()
                        && t_ready + 1e-12 >= not_before[i]
                    {
                        chosen = Some(i);
                        break;
                    }
                }
                let i = chosen.unwrap();
                rr = i + 1;
                i
            }
        };

        // Starvation accounting, same definition as the selector's: a
        // streak counts rounds a queue was ready but unpicked, and resets
        // whenever the queue is picked, goes idle, or is deliberately
        // paused by preemption.
        for (i, st) in steppers.iter().enumerate() {
            if st.is_idle() || !parked[i].is_empty() {
                since_pick[i] = 0;
            } else if i != qi {
                since_pick[i] += 1;
                max_starve = max_starve.max(since_pick[i]);
            }
        }
        since_pick[qi] = 0;

        // One step: placements happen at step start (backfill precedes
        // the forward pass), so waits are measured against t0. Resumed
        // re-placements are excluded from take_placements — a sequence
        // pairs with exactly one wait even across a park/resume cycle.
        let t0 = clock.now();
        let step = steppers[qi].step();
        // Placements persist even through a failed step (backfill
        // precedes the model call; see BoundStepper's unwind-safety
        // argument), so waits and selector stamps are observed on both
        // the success and the failure path.
        let placed = steppers[qi].take_placements();
        for sid in &placed {
            let at = admit_time[qi]
                .get(sid)
                .copied()
                .expect("placed sequence was admitted");
            waits[qi].push(t0 - at);
            placed_set[qi].insert(*sid);
        }
        if weighted {
            // Tag-grouped placement reporting (see the engine loop):
            // priority classes can reorder placements across arrivals,
            // so each run of same-tag placements pops its own arrival's
            // stamps — the EWMA feeding the SLO boost and preemption
            // trigger sees exact waits.
            let mut i = 0;
            while i < placed.len() {
                let tag = admit_tag[qi]
                    .get(&placed[i])
                    .copied()
                    .expect("placed sequence was admitted");
                let mut j = i + 1;
                while j < placed.len()
                    && admit_tag[qi].get(&placed[j]).copied() == Some(tag)
                {
                    j += 1;
                }
                xq.placed_at_tag(qids[qi], 0, tag, j - i, t0, |_| {});
                i = j;
            }
        }
        // Injected stalls accrue virtually: the step happened, but late.
        let cost = specs[qi].step_cost + fault_states[qi].take_stall();
        clock.advance(cost);
        if weighted {
            xq.report_step(qids[qi], cost);
        }
        steps[qi] += 1;
        if all_busy {
            busy_steps[qi] += 1;
        }
        match step {
            Ok(done) => {
                q_retries[qi] = 0;
                not_before[qi] = 0.0;
                breakers[qi].record_success(clock.now());
                for (sid, sample) in done {
                    assert!(seen_done[qi].insert(sid),
                            "sequence {sid:?} answered twice");
                    assert!(admit_time[qi].contains_key(&sid),
                            "retired sequence {sid:?} was never admitted");
                    deadline_at[qi].remove(&sid);
                    finished[qi] += 1;
                    tokens[qi].insert(sid, sample.tokens);
                }
            }
            Err(StepError::Transient(_))
                if q_retries[qi] < cfg.supervise.max_retries =>
            {
                // Transient with retries left: bounded virtual-time
                // backoff, scheduler state intact for the retry.
                q_retries[qi] += 1;
                not_before[qi] =
                    clock.now() + cfg.supervise.backoff_for(q_retries[qi]);
                retries += 1;
            }
            Err(_) => {
                // Definitive failure: quarantine this queue only. Every
                // sequence it holds — resident or pending — is counted
                // failed exactly once; other queues are untouched.
                engine_faults += 1;
                let t_fail = clock.now();
                let was_open =
                    breakers[qi].state(t_fail) == BreakerState::Open;
                breakers[qi].record_failure(t_fail);
                if !was_open
                    && breakers[qi].state(t_fail) == BreakerState::Open
                {
                    breaker_opens += 1;
                }
                while let Some(ck) = steppers[qi].evict_lowest() {
                    let sid = ck.id();
                    deadline_at[qi].remove(&sid);
                    failed[qi].insert(sid);
                }
                for sid in steppers[qi].take_pending_ids() {
                    if weighted && !placed_set[qi].contains(&sid) {
                        let tag = admit_tag[qi][&sid];
                        xq.cancel_enqueue(qids[qi], 0, tag, 1);
                    }
                    deadline_at[qi].remove(&sid);
                    failed[qi].insert(sid);
                }
                q_retries[qi] = 0;
                not_before[qi] = 0.0;
            }
        }

        // Preemption check after the step, mirroring the engine loop:
        // candidates carry their residual work (the victim policy
        // prefers high-residual queues among the over-entitled), and the
        // parked redo work is charged against the victim's checkpoint
        // budget so evict/resume cycles cannot livelock one queue.
        if weighted {
            cand_buf.clear();
            for (i, st) in steppers.iter().enumerate() {
                if parked[i].is_empty() && st.n_active() > 0 {
                    cand_buf.push((qids[i], st.residual() as u64));
                }
            }
            if let Some((trig, victim)) = xq.preempt_check(&cand_buf) {
                let vi = qids.iter().position(|&q| q == victim).unwrap();
                let mut redo = 0u64;
                while let Some(ck) = steppers[vi].evict_lowest() {
                    redo += ck.progress() as u64;
                    parked[vi].push(ck);
                    preemptions += 1;
                }
                xq.charge_preemption(victim, redo);
                parked_trigger[vi] = Some(trig);
            }
        }
    }

    for i in 0..nq {
        // Conservation: every admitted sequence is finished, failed,
        // deadline-shed, or priority-shed — exactly one of the four.
        assert_eq!(finished[i] + failed[i].len() + deadlined[i].len()
                       + shed_admitted[i].len(),
                   admit_time[i].len(),
                   "queue {i}: admitted sequences were lost");
        assert_eq!(waits[i].len(), placed_set[i].len(),
                   "queue {i}: placement accounting out of sync");
    }
    let resumes: u64 = steppers.iter().map(|s| s.resumes()).sum();
    Report {
        waits,
        steps,
        busy_steps,
        finished,
        failed: failed.iter().map(|f| f.len()).collect(),
        engine_faults,
        retries,
        deadline_sheds,
        breaker_shed,
        breaker_opens,
        // Sequence- and request-denominated explicitly on both paths
        // (`shed_of` counts sequences, `shed_requests` counts requests)
        // so conservation arithmetic against per-arrival n stays exact.
        shed: if weighted {
            qids.iter().map(|&q| xq.shed_of(q)).sum()
        } else {
            harness_shed
        },
        shed_requests: if weighted {
            xq.shed_requests()
        } else {
            harness_shed_reqs
        },
        slo_violations: xq.slo_violations(),
        max_starve,
        preemptions,
        resumes,
        preempt_fires: xq.preempt_fires(),
        tokens,
        t_end: clock.now(),
    }
}

/// Everything a fleet (multi-replica) simulation observed. `PartialEq`
/// is the determinism pin, as with [`Report`]. Token streams are keyed
/// by **(arrival index, sequence index within the arrival)** — stable
/// across replica counts and migration choices, unlike `SlotId`s (the
/// adopter re-mints those) — so the bitwise pin compares a migrated run
/// directly against an unmigrated or single-replica one.
#[derive(Clone, Debug, PartialEq)]
pub struct FleetReport {
    /// Per replica: scheduler steps executed.
    pub steps: Vec<u64>,
    /// Per replica: sequences retired *on* it (migrated-in included).
    pub finished: Vec<usize>,
    /// Sequences admitted past backpressure, fleet-wide.
    pub admitted: usize,
    /// Sequences answered failed by a quarantine, fleet-wide.
    pub failed: usize,
    /// Sequences removed by deadline expiry (admission or mid-flight).
    pub deadline_sheds: u64,
    /// Sequences rejected by admission backpressure.
    pub shed: u64,
    /// Mid-sequence checkpoints migrated between replicas.
    pub migrations: u64,
    /// Checkpoints evacuated off killed replicas and adopted by a
    /// survivor (board leftovers nobody could adopt count `failed`
    /// instead).
    pub evacuations: u64,
    /// Supervised respawns granted (each after its backoff elapsed).
    pub replica_restarts: u64,
    /// Sequences answered 503 at admission because *every* replica was
    /// down (total brown-out) — never admitted, excluded from
    /// conservation.
    pub brownout_shed: u64,
    /// (arrival index, sequence index) -> retired token stream.
    pub tokens: BTreeMap<(usize, usize), Vec<i32>>,
    pub t_end: f64,
}

impl FleetReport {
    /// Total tokens retired per virtual second — the aggregate
    /// throughput number the replica-scaling pin compares.
    pub fn token_throughput(&self) -> f64 {
        let toks: usize = self.tokens.values().map(|t| t.len()).sum();
        toks as f64 / self.t_end.max(1e-12)
    }
}

/// Multi-replica mirror of [`simulate`]: `n_engines` replicas, each with
/// its own steppers (one per [`QueueSpec`], `SlotId` base `e << 40`),
/// weighted selector, and retry/quarantine supervision, all on one
/// shared [`SimClock`]. Each round every replica with ready work steps
/// once *concurrently* — the clock advances by the **max** cost among
/// the replicas that stepped, which is what makes aggregate throughput
/// scale with replica count. Mirrors of the live router policies:
///
/// * **admission routing** — each arrival goes whole to the
///   least-loaded replica (resident residual + pending; ties low), the
///   deterministic twin of `RouterState::route`;
/// * **migration** (`migrate = true`) — when a replica sits fully idle
///   while another has a queue with >= 2 residents, the busy replica
///   evicts its lowest-progress resident and the idle one adopts it
///   (`Stepper::adopt` re-mints the slot id), at most one checkpoint in
///   flight per round, deadline-carrying sequences excluded — exactly
///   the live `migrate_out`/`adopt_migrants` policy.
///
/// Intra-replica preemption/parking is deliberately not mirrored here
/// ([`simulate`] owns that single-engine behaviour); the fleet harness
/// isolates the router policies. Conservation is asserted internally:
/// every admitted sequence is finished, failed, or deadline-shed —
/// exactly one of the three, fleet-wide — and no sequence retires twice.
pub fn simulate_fleet(specs: &[QueueSpec], trace: &[Arrival],
                      n_engines: usize, cfg: &SchedConfig, migrate: bool)
                      -> FleetReport {
    simulate_fleet_opts(specs, trace, n_engines, cfg, FleetOptions {
        migrate,
        ..FleetOptions::default()
    })
}

/// Replica-loss knobs for [`simulate_fleet_opts`] — the fleet sim's
/// failure-handling policy surface, mirroring the live coordinator
/// (`BatcherConfig::heartbeat_timeout_s`, `ReplicaSupervisor`, the
/// router's brown-out and evacuation board).
#[derive(Clone, Debug)]
pub struct FleetOptions {
    /// Idle-replica checkpoint migration (the load-balancing policy).
    pub migrate: bool,
    /// Replica-kill scripts: `(replica, plan)`. A plan's `kill@N` entry
    /// fires on that replica's Nth *step attempt* (counted across its
    /// queues, before any model work — the `FaultyStepper` seam's
    /// virtual twin). Non-kill kinds are ignored at replica granularity;
    /// queue-level chaos stays on [`QueueSpec::fault`].
    pub replica_faults: Vec<(usize, FaultPlan)>,
    /// Missed-beat threshold: virtual seconds without a heartbeat before
    /// the router marks a replica Down. Strictly-greater-than, exactly
    /// like the live [`Liveness`].
    pub heartbeat_timeout_s: f64,
    /// Supervised respawns allowed per replica; once exhausted the
    /// replica stays Down permanently.
    pub restart_budget: u32,
    /// Which Up replica adopts evacuated checkpoints: rank
    /// `adopter_offset % |Up|` in least-loaded (ties-low) order. The
    /// bitwise-identity pin must hold for every offset — adopter choice
    /// can never change a token stream.
    pub adopter_offset: usize,
}

impl Default for FleetOptions {
    fn default() -> FleetOptions {
        FleetOptions {
            migrate: false,
            replica_faults: Vec::new(),
            heartbeat_timeout_s: 5.0,
            restart_budget: 2,
            adopter_offset: 0,
        }
    }
}

/// [`simulate_fleet`] with replica-loss handling (see [`FleetOptions`]
/// and the module docs' **Replica loss** section): scripted kills,
/// heartbeat death detection, checkpoint evacuation with bitwise-stable
/// adoption, supervised restart under geometric backoff, and total
/// brown-out when no replica is Up.
pub fn simulate_fleet_opts(specs: &[QueueSpec], trace: &[Arrival],
                           n_engines: usize, cfg: &SchedConfig,
                           opts: FleetOptions) -> FleetReport {
    assert!(n_engines >= 1);
    for w in trace.windows(2) {
        assert!(w[0].t <= w[1].t, "trace must be time-sorted");
    }
    let nq = specs.len();
    let ne = n_engines;
    // Per-replica model instances so fault scripts fire independently
    // per replica (shared call counters would couple them).
    let models: Vec<Vec<FaultyModel<MockModel>>> = (0..ne)
        .map(|_| {
            specs
                .iter()
                .map(|s| {
                    let mut m = MockModel::new(s.d, s.vocab, s.model_seed);
                    m.buckets = vec![s.bucket];
                    FaultyModel::new(m, s.fault.clone().unwrap_or_default())
                })
                .collect()
        })
        .collect();
    let fault_states: Vec<Vec<Rc<FaultState>>> = models
        .iter()
        .map(|row| row.iter().map(|m| m.fault_state()).collect())
        .collect();
    let params = SpecParams {
        window: Window::Constant(1),
        ..Default::default()
    };
    let clock = SimClock::new();
    let mut steppers: Vec<Vec<BoundStepper<'_, FaultyModel<MockModel>>>> =
        models
            .iter()
            .enumerate()
            .map(|(e, row)| {
                row.iter()
                    .map(|m| {
                        let mut st =
                            BoundStepper::new(m, SeqParams::Spec(
                                params.clone()));
                        st.set_id_base((e as u64) << 40);
                        st.sched.set_clock(Box::new(clock.clone()));
                        st
                    })
                    .collect()
            })
            .collect();
    let mut xqs: Vec<CrossQueueScheduler> = (0..ne)
        .map(|_| CrossQueueScheduler::new(Box::new(clock.clone()), cfg))
        .collect();
    let qids: Vec<Vec<QueueId>> = (0..ne)
        .map(|e| {
            specs
                .iter()
                .enumerate()
                .map(|(i, s)| {
                    xqs[e].register(&format!("q{i}"), s.policy.clone())
                })
                .collect()
        })
        .collect();

    // Per-sequence record, keyed (replica, queue, slot): its stable
    // (arrival, sequence) identity, deadline, and arrival tag (for
    // selector-stamp rollbacks). Migration moves the record to the
    // adopter's key.
    struct SeqInfo {
        key: (usize, usize),
        deadline: Option<f64>,
        tag: u64,
    }
    let mut info: Vec<Vec<BTreeMap<SlotId, SeqInfo>>> =
        (0..ne).map(|_| (0..nq).map(|_| BTreeMap::new()).collect())
               .collect();
    // Sequences whose arrival stamp was popped at placement (adopted
    // checkpoints count as placed: their stamp lived — and was popped —
    // on the origin replica's selector).
    let mut placed: Vec<Vec<BTreeSet<SlotId>>> =
        (0..ne).map(|_| (0..nq).map(|_| BTreeSet::new()).collect())
               .collect();
    let mut q_retries: Vec<Vec<u32>> = vec![vec![0u32; nq]; ne];
    let mut not_before: Vec<Vec<f64>> = vec![vec![0.0f64; nq]; ne];
    let mut steps = vec![0u64; ne];
    let mut finished = vec![0usize; ne];
    let mut tokens: BTreeMap<(usize, usize), Vec<i32>> = BTreeMap::new();
    let mut admitted = 0usize;
    let mut failed = 0usize;
    let mut deadline_sheds = 0u64;
    // Post-admission sweeps only (in-transit expiries never admit), so
    // the final conservation assert can be an exact equality.
    let mut dl_inflight = 0usize;
    let mut migrations = 0u64;
    let mut next = 0usize;

    // Replica-loss state. Kill scripts fire deterministically by step
    // count; liveness is the live router's exact state machine, driven
    // here by the one shared SimClock — every replica reads the same
    // timeline, so inter-replica clock skew is impossible by
    // construction (asserted by tests/fleet_sim.rs).
    let kill_plans: Vec<FaultState> = (0..ne)
        .map(|e| {
            let mut plan = FaultPlan::default();
            for (re, p) in &opts.replica_faults {
                if *re == e {
                    plan.faults.extend(p.faults.iter().copied());
                }
            }
            plan.faults.sort_by_key(|f| f.at);
            FaultState::new(plan)
        })
        .collect();
    let mut alive = vec![true; ne];
    // False between a kill and the router's missed-beat detection of it
    // (the window in which admission still routes to the corpse).
    let mut detected = vec![true; ne];
    let mut liveness = Liveness::new(ne, opts.heartbeat_timeout_s);
    let mut restarts = vec![0u32; ne];
    let mut restart_at: Vec<Option<f64>> = vec![None; ne];
    let mut evacuations = 0u64;
    let mut replica_restarts = 0u64;
    let mut brownout_shed = 0u64;
    // The migration board: checkpoints evacuated off dead replicas,
    // waiting for an Up replica to adopt them.
    let mut board: Vec<(usize, SeqCheckpoint, SeqInfo)> = Vec::new();

    // Drain every sequence replica `e` holds — resident or pending —
    // onto the board. Un-placed pending sequences roll their admission
    // stamps back so the dead selector's depth stays exact;
    // deadline-carrying sequences are answered failed instead of risking
    // expiry in transit (the live evacuation does the same).
    fn evacuate_replica_sim<'m>(
        e: usize,
        steppers: &mut [Vec<BoundStepper<'m, FaultyModel<MockModel>>>],
        info: &mut [Vec<BTreeMap<SlotId, SeqInfo>>],
        placed: &[Vec<BTreeSet<SlotId>>],
        xqs: &mut [CrossQueueScheduler],
        qids: &[Vec<QueueId>],
        board: &mut Vec<(usize, SeqCheckpoint, SeqInfo)>,
        failed: &mut usize,
    ) {
        for q in 0..steppers[e].len() {
            let mut cks: Vec<SeqCheckpoint> = Vec::new();
            while let Some(ck) = steppers[e][q].evict_lowest() {
                cks.push(ck);
            }
            cks.extend(steppers[e][q].take_pending());
            for ck in cks {
                let sid = ck.id();
                let Some(rec) = info[e][q].remove(&sid) else { continue };
                if !placed[e][q].contains(&sid) {
                    xqs[e].cancel_enqueue(qids[e][q], 0, rec.tag, 1);
                }
                if rec.deadline.is_some() {
                    *failed += 1;
                } else {
                    board.push((q, ck, rec));
                }
            }
        }
    }

    let load_of = |steppers: &Vec<Vec<BoundStepper<'_, _>>>, e: usize| {
        steppers[e]
            .iter()
            .map(|st| st.residual() + st.n_pending())
            .sum::<usize>()
    };

    loop {
        // Heartbeats: every live replica publishes one per round (the
        // load-gauge path doubles as the beat, as in the live router).
        // Killed replicas simply stop beating — the missed-beat
        // threshold is the only death-detection signal.
        let t_beat = clock.now();
        for e in 0..ne {
            if alive[e] {
                liveness.beat(e, t_beat);
            }
        }

        // Supervised restart: a granted respawn comes back once its
        // backoff elapses, re-registers (its beat clears Restarting),
        // and serves again with fresh retry state.
        for e in 0..ne {
            if let Some(eta) = restart_at[e] {
                if t_beat + 1e-12 >= eta {
                    restart_at[e] = None;
                    alive[e] = true;
                    for q in 0..nq {
                        q_retries[e][q] = 0;
                        not_before[e][q] = 0.0;
                    }
                    liveness.beat(e, t_beat);
                    replica_restarts += 1;
                }
            }
        }

        // Router-side death detection: strictly past the missed-beat
        // threshold the replica flips Down. Sweep anything that was
        // routed to it inside the detection window (admission kept
        // believing it Up, exactly as the live router does), then let
        // the supervisor grant a restart under budget.
        for e in 0..ne {
            if detected[e]
                || liveness.state(e, t_beat) != ReplicaState::Down
            {
                continue;
            }
            evacuate_replica_sim(e, &mut steppers, &mut info, &placed,
                                 &mut xqs, &qids, &mut board, &mut failed);
            detected[e] = true;
            if restarts[e] < opts.restart_budget {
                restarts[e] += 1;
                liveness.mark_restarting(e);
                restart_at[e] =
                    Some(t_beat + cfg.supervise.backoff_for(restarts[e]));
            }
        }

        // Admit due arrivals, each routed whole to the least-loaded
        // replica the router believes Up (ties to the lowest id —
        // RouterState::route's twin). No Up replica at all is a total
        // brown-out: the arrival is answered 503, never admitted.
        while next < trace.len() && trace[next].t <= clock.now() + 1e-12 {
            let a = trace[next];
            let tag = next as u64;
            next += 1;
            let t_admit = clock.now();
            let age = (t_admit - a.t).max(0.0);
            if let Some(dl) = a.deadline {
                if age >= dl {
                    deadline_sheds += a.n as u64;
                    continue;
                }
            }
            let mut e_best = None;
            let mut best = usize::MAX;
            for e in 0..ne {
                if liveness.state(e, t_admit) != ReplicaState::Up {
                    continue;
                }
                let l = load_of(&steppers, e);
                if l < best {
                    best = l;
                    e_best = Some(e);
                }
            }
            let Some(e_best) = e_best else {
                brownout_shed += a.n as u64;
                continue;
            };
            if !xqs[e_best].try_enqueue(qids[e_best][a.queue], 0, tag,
                                        a.n, age) {
                continue; // shed by admission backpressure
            }
            let prompt = Prompt::empty(specs[a.queue].d);
            let mut rng = Pcg::new(a.seed);
            for k in 0..a.n {
                let sid = steppers[e_best][a.queue]
                    .admit_prio(&prompt, rng.split(), a.priority);
                info[e_best][a.queue].insert(sid, SeqInfo {
                    key: (tag as usize, k),
                    deadline: a.deadline.map(|dl| a.t + dl),
                    tag,
                });
                admitted += 1;
            }
        }

        // Board adoption: evacuated checkpoints drain whole to one Up
        // replica — rank `adopter_offset % |Up|` in least-loaded
        // (ties-low) order. Adoption re-mints slot ids; the sequence's
        // RNG stream rides the checkpoint, so the adopter's identity can
        // never change a token stream (the property test sweeps every
        // offset). With no Up replica the board simply waits — a later
        // restart adopts it, or teardown answers it failed.
        if !board.is_empty() {
            let t_adopt = clock.now();
            let mut cands: Vec<usize> = (0..ne)
                .filter(|&e| {
                    alive[e]
                        && liveness.state(e, t_adopt) == ReplicaState::Up
                })
                .collect();
            cands.sort_by_key(|&e| (load_of(&steppers, e), e));
            if !cands.is_empty() {
                let e_to = cands[opts.adopter_offset % cands.len()];
                for (q, ck, rec) in board.drain(..) {
                    let new_sid = steppers[e_to][q].adopt(ck);
                    info[e_to][q].insert(new_sid, rec);
                    placed[e_to][q].insert(new_sid);
                    evacuations += 1;
                }
            }
        }

        // Deadline sweep, per replica (mirrors the engine's
        // between-steps sweep; deadline sequences never migrate, so
        // each lives where it was admitted).
        let t_sweep = clock.now();
        for e in 0..ne {
            for q in 0..nq {
                let expired: Vec<SlotId> = info[e][q]
                    .iter()
                    .filter(|&(_, i)| {
                        i.deadline.map(|dl| t_sweep >= dl).unwrap_or(false)
                    })
                    .map(|(&sid, _)| sid)
                    .collect();
                for sid in expired {
                    let Some(i) = info[e][q].remove(&sid) else { continue };
                    if steppers[e][q].evict(sid).is_some() {
                        // Resident: stamp popped at placement.
                    } else if steppers[e][q].remove_pending(sid)
                        && !placed[e][q].contains(&sid)
                    {
                        xqs[e].cancel_enqueue(qids[e][q], 0, i.tag, 1);
                    }
                    deadline_sheds += 1;
                    dl_inflight += 1;
                }
            }
        }

        // Step phase: every replica with ready work steps once,
        // concurrently; the shared clock then advances by the max cost
        // among them (the fleet's wall time is the slowest replica's).
        let t0 = clock.now();
        let mut max_cost = 0.0f64;
        let mut any_stepped = false;
        for e in 0..ne {
            if !alive[e] {
                continue;
            }
            let ready: Vec<QueueId> = (0..nq)
                .filter(|&q| {
                    !steppers[e][q].is_idle()
                        && t0 + 1e-12 >= not_before[e][q]
                })
                .map(|q| qids[e][q])
                .collect();
            if ready.is_empty() {
                continue;
            }
            // Replica-kill scripts fire on the Nth step *attempt*,
            // before any model work — the FaultyStepper Kill seam's
            // virtual twin. The replica dies whole: its entire state
            // (every queue's residents and pending) evacuates to the
            // board, and it stops beating. Detection is the router's
            // job, at the missed-beat threshold.
            if matches!(kill_plans[e].advance(), Some(FaultKind::Kill)) {
                alive[e] = false;
                detected[e] = false;
                evacuate_replica_sim(e, &mut steppers, &mut info,
                                     &placed, &mut xqs, &qids, &mut board,
                                     &mut failed);
                continue;
            }
            let sid_q = xqs[e].pick(&ready).expect("ready set non-empty");
            let q = qids[e].iter().position(|&x| x == sid_q).unwrap();
            let step = steppers[e][q].step();
            let placed_now = steppers[e][q].take_placements();
            let mut i = 0;
            while i < placed_now.len() {
                let tag = info[e][q]
                    .get(&placed_now[i])
                    .map(|x| x.tag)
                    .expect("placed sequence was admitted");
                let mut j = i + 1;
                while j < placed_now.len()
                    && info[e][q].get(&placed_now[j]).map(|x| x.tag)
                        == Some(tag)
                {
                    j += 1;
                }
                xqs[e].placed_at_tag(qids[e][q], 0, tag, j - i, t0,
                                     |_| {});
                i = j;
            }
            for sid in &placed_now {
                placed[e][q].insert(*sid);
            }
            let cost =
                specs[q].step_cost + fault_states[e][q].take_stall();
            xqs[e].report_step(qids[e][q], cost);
            max_cost = max_cost.max(cost);
            any_stepped = true;
            steps[e] += 1;
            match step {
                Ok(done) => {
                    q_retries[e][q] = 0;
                    not_before[e][q] = 0.0;
                    for (sid, sample) in done {
                        let Some(i) = info[e][q].remove(&sid) else {
                            panic!("retired sequence was never admitted");
                        };
                        finished[e] += 1;
                        assert!(
                            tokens.insert(i.key, sample.tokens).is_none(),
                            "sequence {:?} answered twice", i.key
                        );
                    }
                }
                Err(StepError::Transient(_))
                    if q_retries[e][q] < cfg.supervise.max_retries =>
                {
                    q_retries[e][q] += 1;
                    not_before[e][q] = clock.now()
                        + cfg.supervise.backoff_for(q_retries[e][q]);
                }
                Err(_) => {
                    // Definitive failure: quarantine replica e's queue q
                    // only. Adopted sequences it held are counted failed
                    // here too (the live path reports them home; the sim
                    // owns both ends, so the global count is the same).
                    while let Some(ck) = steppers[e][q].evict_lowest() {
                        if info[e][q].remove(&ck.id()).is_some() {
                            failed += 1;
                        }
                    }
                    for sid in steppers[e][q].take_pending_ids() {
                        let Some(i) = info[e][q].remove(&sid) else {
                            continue;
                        };
                        if !placed[e][q].contains(&sid) {
                            xqs[e].cancel_enqueue(qids[e][q], 0, i.tag, 1);
                        }
                        failed += 1;
                    }
                    q_retries[e][q] = 0;
                    not_before[e][q] = 0.0;
                }
            }
        }
        if !any_stepped {
            // Live replicas wake at their earliest backoff expiry; dead
            // ones wake the fleet at their missed-beat detection instant
            // (strictly past the threshold) or their granted restart —
            // sequences stranded on an undetected corpse must not spin
            // the clock in place, and a dead fleet must still advance to
            // detection and through restart backoff.
            let wake = (0..ne)
                .filter(|&e| alive[e])
                .flat_map(|e| (0..nq).map(move |q| (e, q)))
                .filter(|&(e, q)| !steppers[e][q].is_idle())
                .map(|(e, q)| not_before[e][q])
                .fold(f64::INFINITY, f64::min);
            let wake = (0..ne)
                .filter(|&e| !detected[e])
                .map(|e| liveness.down_at(e) + 1e-9)
                .fold(wake, f64::min);
            let wake = (0..ne)
                .filter_map(|e| restart_at[e])
                .fold(wake, f64::min);
            let next_t = if next < trace.len() {
                trace[next].t
            } else {
                f64::INFINITY
            };
            let t = wake.min(next_t);
            if !t.is_finite() {
                break;
            }
            clock.set(t.max(clock.now()));
            continue;
        }
        clock.advance(max_cost);

        // Migration: an idle replica adopts one checkpoint from the
        // busiest queue (>= 2 residents, so the origin keeps stepping)
        // of the most loaded replica — at most one checkpoint in flight
        // per round, deadline-carrying sequences excluded, exactly the
        // live policy. Adoption re-mints the slot id in the adopter's
        // namespace; the sequence's RNG stream rides the checkpoint, so
        // its tokens stay bitwise identical either way.
        if opts.migrate && ne > 1 {
            let idle = (0..ne).find(|&e| {
                alive[e] && steppers[e].iter().all(|s| s.is_idle())
            });
            if let Some(e_to) = idle {
                let e_from = (0..ne)
                    .filter(|&e| e != e_to && alive[e])
                    .max_by_key(|&e| load_of(&steppers, e));
                if let Some(e_from) = e_from {
                    let q_best = (0..nq)
                        .filter(|&q| steppers[e_from][q].n_active() >= 2)
                        .max_by_key(|&q| steppers[e_from][q].n_active());
                    if let Some(q) = q_best {
                        if let Some(ck) = steppers[e_from][q].evict_lowest()
                        {
                            let sid = ck.id();
                            let eligible = info[e_from][q]
                                .get(&sid)
                                .map(|i| i.deadline.is_none())
                                .unwrap_or(false);
                            if eligible {
                                let Some(rec) = info[e_from][q].remove(&sid)
                                else {
                                    unreachable!("eligible checked above")
                                };
                                let new_sid =
                                    steppers[e_to][q].adopt(ck);
                                info[e_to][q].insert(new_sid, rec);
                                placed[e_to][q].insert(new_sid);
                                migrations += 1;
                            } else {
                                steppers[e_from][q].resume(ck);
                            }
                        }
                    }
                }
            }
        }
    }

    // Teardown: board leftovers nobody could adopt (every replica
    // permanently down) are answered failed — the live coordinator's
    // shutdown does exactly this to unadopted migrants via `home_fail`.
    failed += board.len();
    board.clear();

    // Conservation, fleet-wide: every admitted sequence is finished,
    // failed, or deadline-shed — exactly one of the three (in-transit
    // deadline sheds and brown-out rejections happen pre-admission and
    // are excluded here).
    let done: usize = finished.iter().sum();
    assert_eq!(tokens.len(), done, "a retired sequence is missing tokens");
    assert_eq!(admitted, done + failed + dl_inflight,
               "admitted sequences were lost");
    let shed: u64 = (0..ne)
        .map(|e| qids[e].iter().map(|&q| xqs[e].shed_of(q)).sum::<u64>())
        .sum();
    FleetReport {
        steps,
        finished,
        admitted,
        failed,
        deadline_sheds,
        shed,
        migrations,
        evacuations,
        replica_restarts,
        brownout_shed,
        tokens,
        t_end: clock.now(),
    }
}

/// Exact p95 over a non-empty sample (nearest-rank: the ceil(0.95·n)-th
/// smallest value).
pub fn p95(xs: &[f64]) -> f64 {
    assert!(!xs.is_empty());
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = ((v.len() as f64) * 0.95).ceil() as usize;
    v[rank.max(1).min(v.len()) - 1]
}

pub fn mean(xs: &[f64]) -> f64 {
    xs.iter().sum::<f64>() / xs.len() as f64
}

// ---------------------------------------------------------------------------
// Trace JSONL (record -> replay)
// ---------------------------------------------------------------------------

/// One event from a live run, streamed by the coordinator's
/// `BatcherConfig::trace` hook: request arrivals (backdated to the
/// caller-side enqueue instant) and executed step costs per model.
#[derive(Clone, Debug)]
pub enum TraceEvent {
    Arrival { t: f64, model: String, n: usize, seed: u64, priority: i32 },
    Step { model: String, cost_s: f64 },
}

/// Per-model geometry the recorder cannot observe from the event stream
/// (the replaying MockModel's shape and the policy to simulate under).
#[derive(Clone, Debug)]
pub struct QueueGeometry {
    pub model: String,
    pub d: usize,
    pub vocab: usize,
    pub bucket: usize,
    pub model_seed: u64,
    pub policy: QueuePolicy,
}

/// Assemble a recorded event stream into a replayable trace: one
/// [`QueueSpec`] per geometry entry (step cost = the mean observed cost
/// of that model's steps; 10ms when it never stepped) and time-sorted
/// [`Arrival`]s normalized to start at t = 0. Arrivals for models
/// without a geometry entry are dropped.
pub fn assemble_trace(events: &[TraceEvent], geometry: &[QueueGeometry])
                      -> (Vec<QueueSpec>, Vec<Arrival>) {
    let index: BTreeMap<&str, usize> = geometry
        .iter()
        .enumerate()
        .map(|(i, g)| (g.model.as_str(), i))
        .collect();
    let mut cost_sum = vec![0.0f64; geometry.len()];
    let mut cost_n = vec![0u64; geometry.len()];
    let mut t0 = f64::INFINITY;
    for ev in events {
        match ev {
            TraceEvent::Step { model, cost_s } => {
                if let Some(&i) = index.get(model.as_str()) {
                    cost_sum[i] += cost_s;
                    cost_n[i] += 1;
                }
            }
            TraceEvent::Arrival { t, model, .. } => {
                if index.contains_key(model.as_str()) {
                    t0 = t0.min(*t);
                }
            }
        }
    }
    if !t0.is_finite() {
        t0 = 0.0;
    }
    let specs: Vec<QueueSpec> = geometry
        .iter()
        .enumerate()
        .map(|(i, g)| QueueSpec {
            d: g.d,
            vocab: g.vocab,
            bucket: g.bucket,
            model_seed: g.model_seed,
            policy: g.policy.clone(),
            step_cost: if cost_n[i] > 0 {
                cost_sum[i] / cost_n[i] as f64
            } else {
                0.01
            },
            // Live recordings carry the faults that *happened*, not a
            // plan; chaos plans are authored into the trace file by hand.
            fault: None,
        })
        .collect();
    let mut arrivals: Vec<Arrival> = events
        .iter()
        .filter_map(|ev| match ev {
            TraceEvent::Arrival { t, model, n, seed, priority } => {
                index.get(model.as_str()).map(|&i| Arrival {
                    t: (t - t0).max(0.0),
                    queue: i,
                    n: *n,
                    seed: *seed,
                    priority: *priority,
                    deadline: None,
                })
            }
            _ => None,
        })
        .collect();
    arrivals.sort_by(|a, b| a.t.partial_cmp(&b.t).unwrap());
    (specs, arrivals)
}

fn u64_str(v: u64) -> Json {
    Json::str(v.to_string())
}

fn parse_u64(v: Option<&Json>) -> Result<u64, String> {
    match v {
        Some(Json::Str(s)) => {
            s.parse().map_err(|_| format!("bad u64 '{s}'"))
        }
        Some(j) => j
            .as_f64()
            .map(|n| n as u64)
            .ok_or_else(|| "bad u64".to_string()),
        None => Err("missing u64 field".into()),
    }
}

/// Replica-level chaos carried by a trace file: `replica` lines
/// (`{"kind":"replica","engine":E,"faults":"kill@N"}`) plus the fleet
/// config keys `heartbeat_s` / `restart_budget`. All-default for
/// single-engine traces; [`FleetScript::options`] folds it into a
/// [`FleetOptions`] for replay.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct FleetScript {
    pub replica_faults: Vec<(usize, FaultPlan)>,
    pub heartbeat_s: Option<f64>,
    pub restart_budget: Option<u32>,
}

impl FleetScript {
    pub fn is_empty(&self) -> bool {
        self.replica_faults.is_empty()
            && self.heartbeat_s.is_none()
            && self.restart_budget.is_none()
    }

    /// Fold into [`FleetOptions`], keeping that type's defaults for any
    /// key the trace omitted.
    pub fn options(&self, migrate: bool) -> FleetOptions {
        let d = FleetOptions::default();
        FleetOptions {
            migrate,
            replica_faults: self.replica_faults.clone(),
            heartbeat_timeout_s: self.heartbeat_s
                .unwrap_or(d.heartbeat_timeout_s),
            restart_budget: self.restart_budget.unwrap_or(d.restart_budget),
            adopter_offset: 0,
        }
    }
}

/// Serialize a (config, queues, arrivals) trace as JSONL (see module
/// docs for the line grammar). Creates parent directories as needed.
pub fn write_trace(path: &Path, cfg: &SchedConfig, specs: &[QueueSpec],
                   trace: &[Arrival]) -> std::io::Result<()> {
    write_trace_fleet(path, cfg, specs, trace, &FleetScript::default())
}

/// [`write_trace`] plus a replica-level chaos script (fleet config keys
/// on the config line, one `replica` line per scripted replica).
pub fn write_trace_fleet(path: &Path, cfg: &SchedConfig,
                         specs: &[QueueSpec], trace: &[Arrival],
                         fleet: &FleetScript) -> std::io::Result<()> {
    if let Some(dir) = path.parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir)?;
        }
    }
    let mut f = std::fs::File::create(path)?;
    let mut cfg_fields = vec![
        ("kind", Json::str("config")),
        ("starve_after", Json::num(cfg.starve_after as f64)),
        ("wait_alpha", Json::num(cfg.wait_alpha)),
        ("max_boost", Json::num(cfg.max_boost)),
        ("preempt_after", Json::num(cfg.preempt_after as f64)),
        ("max_retries", Json::num(cfg.supervise.max_retries as f64)),
        ("backoff_s", Json::num(cfg.supervise.backoff_s)),
        ("backoff_mult", Json::num(cfg.supervise.backoff_mult)),
        ("breaker_threshold",
         Json::num(cfg.supervise.breaker_threshold as f64)),
        ("breaker_cooldown_s",
         Json::num(cfg.supervise.breaker_cooldown_s)),
    ];
    if let Some(hb) = fleet.heartbeat_s {
        cfg_fields.push(("heartbeat_s", Json::num(hb)));
    }
    if let Some(rb) = fleet.restart_budget {
        cfg_fields.push(("restart_budget", Json::num(rb as f64)));
    }
    writeln!(f, "{}", Json::obj(cfg_fields))?;
    for (e, plan) in &fleet.replica_faults {
        writeln!(f, "{}", Json::obj(vec![
            ("kind", Json::str("replica")),
            ("engine", Json::num(*e as f64)),
            ("faults", Json::str(plan.format())),
        ]))?;
    }
    for s in specs {
        let mut fields = vec![
            ("kind", Json::str("queue")),
            ("d", Json::num(s.d as f64)),
            ("vocab", Json::num(s.vocab as f64)),
            ("bucket", Json::num(s.bucket as f64)),
            ("model_seed", u64_str(s.model_seed)),
            ("step_cost", Json::num(s.step_cost)),
            ("weight", Json::num(s.policy.weight)),
            ("burst", Json::num(s.policy.max_consecutive as f64)),
            ("shed", Json::Bool(s.policy.shed_on_full)),
            ("preempt", Json::Bool(s.policy.preempt)),
        ];
        if let Some(slo) = s.policy.slo_p95_s {
            fields.push(("slo", Json::num(slo)));
        }
        if s.policy.max_pending != usize::MAX {
            fields.push(("pending", Json::num(s.policy.max_pending as f64)));
        }
        if let Some(fp) = &s.fault {
            fields.push(("faults", Json::str(fp.format())));
        }
        writeln!(f, "{}", Json::obj(fields))?;
    }
    for a in trace {
        let mut fields = vec![
            ("kind", Json::str("arrival")),
            ("t", Json::num(a.t)),
            ("queue", Json::num(a.queue as f64)),
            ("n", Json::num(a.n as f64)),
            ("seed", u64_str(a.seed)),
            ("priority", Json::num(a.priority as f64)),
        ];
        if let Some(dl) = a.deadline {
            fields.push(("deadline", Json::num(dl)));
        }
        writeln!(f, "{}", Json::obj(fields))?;
    }
    Ok(())
}

/// Parse a JSONL trace written by [`write_trace`] /
/// [`write_trace_fleet`] (or by hand — missing optional fields take
/// their defaults). The [`FleetScript`] element is all-default for
/// traces without replica lines or fleet config keys.
pub fn read_trace(path: &Path)
                  -> Result<(SchedConfig, Vec<QueueSpec>, Vec<Arrival>,
                             FleetScript),
                            String> {
    let body = std::fs::read_to_string(path)
        .map_err(|e| format!("read {}: {e}", path.display()))?;
    let mut cfg = SchedConfig::default();
    let mut specs = Vec::new();
    let mut arrivals = Vec::new();
    let mut fleet = FleetScript::default();
    for (ln, line) in body.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        let v = Json::parse(line)
            .map_err(|e| format!("line {}: {e:?}", ln + 1))?;
        let kind = v
            .get("kind")
            .and_then(|k| k.as_str())
            .ok_or_else(|| format!("line {}: missing kind", ln + 1))?;
        match kind {
            "config" => {
                if let Some(x) = v.get("starve_after").and_then(Json::as_f64)
                {
                    cfg.starve_after = x as u64;
                }
                if let Some(x) = v.get("wait_alpha").and_then(Json::as_f64) {
                    cfg.wait_alpha = x;
                }
                if let Some(x) = v.get("max_boost").and_then(Json::as_f64) {
                    cfg.max_boost = x;
                }
                if let Some(x) =
                    v.get("preempt_after").and_then(Json::as_f64)
                {
                    cfg.preempt_after = x as u64;
                }
                if let Some(x) = v.get("max_retries").and_then(Json::as_f64)
                {
                    cfg.supervise.max_retries = x as u32;
                }
                if let Some(x) = v.get("backoff_s").and_then(Json::as_f64) {
                    cfg.supervise.backoff_s = x;
                }
                if let Some(x) =
                    v.get("backoff_mult").and_then(Json::as_f64)
                {
                    cfg.supervise.backoff_mult = x;
                }
                if let Some(x) =
                    v.get("breaker_threshold").and_then(Json::as_f64)
                {
                    cfg.supervise.breaker_threshold = x as u32;
                }
                if let Some(x) =
                    v.get("breaker_cooldown_s").and_then(Json::as_f64)
                {
                    cfg.supervise.breaker_cooldown_s = x;
                }
                fleet.heartbeat_s =
                    v.get("heartbeat_s").and_then(Json::as_f64);
                fleet.restart_budget = v
                    .get("restart_budget")
                    .and_then(Json::as_f64)
                    .map(|x| x as u32);
            }
            "replica" => {
                let engine =
                    v.get("engine").and_then(Json::as_usize).ok_or_else(
                        || format!("line {}: missing engine", ln + 1),
                    )?;
                let plan = v
                    .get("faults")
                    .and_then(Json::as_str)
                    .ok_or_else(|| {
                        format!("line {}: replica line needs faults",
                                ln + 1)
                    })
                    .and_then(|s| {
                        FaultPlan::parse(s)
                            .map_err(|e| format!("line {}: {e}", ln + 1))
                    })?;
                fleet.replica_faults.push((engine, plan));
            }
            "queue" => {
                let mut policy = QueuePolicy::default();
                if let Some(w) = v.get("weight").and_then(Json::as_f64) {
                    policy.weight = w;
                }
                policy.slo_p95_s = v.get("slo").and_then(Json::as_f64);
                if let Some(b) = v.get("burst").and_then(Json::as_f64) {
                    policy.max_consecutive = b as u32;
                }
                if let Some(p) = v.get("pending").and_then(Json::as_f64) {
                    policy.max_pending = p as usize;
                }
                policy.shed_on_full = v
                    .get("shed")
                    .and_then(Json::as_bool)
                    .unwrap_or(false);
                policy.preempt = v
                    .get("preempt")
                    .and_then(Json::as_bool)
                    .unwrap_or(false);
                specs.push(QueueSpec {
                    d: v.get("d")
                        .and_then(Json::as_usize)
                        .ok_or_else(|| format!("line {}: missing d",
                                               ln + 1))?,
                    vocab: v
                        .get("vocab")
                        .and_then(Json::as_usize)
                        .unwrap_or(6),
                    bucket: v
                        .get("bucket")
                        .and_then(Json::as_usize)
                        .unwrap_or(1),
                    model_seed: parse_u64(v.get("model_seed"))
                        .map_err(|e| format!("line {}: {e}", ln + 1))?,
                    policy,
                    step_cost: v
                        .get("step_cost")
                        .and_then(Json::as_f64)
                        .unwrap_or(0.01),
                    fault: v
                        .get("faults")
                        .and_then(Json::as_str)
                        .map(FaultPlan::parse)
                        .transpose()
                        .map_err(|e| format!("line {}: {e}", ln + 1))?,
                });
            }
            "arrival" => {
                let queue = v
                    .get("queue")
                    .and_then(Json::as_usize)
                    .ok_or_else(|| {
                        format!("line {}: missing queue", ln + 1)
                    })?;
                if queue >= specs.len() {
                    return Err(format!(
                        "line {}: arrival for queue {queue} but only {} \
                         queue lines precede it",
                        ln + 1,
                        specs.len()
                    ));
                }
                arrivals.push(Arrival {
                    t: v.get("t").and_then(Json::as_f64).unwrap_or(0.0),
                    queue,
                    n: v.get("n").and_then(Json::as_usize).unwrap_or(1),
                    seed: parse_u64(v.get("seed"))
                        .map_err(|e| format!("line {}: {e}", ln + 1))?,
                    priority: v
                        .get("priority")
                        .and_then(Json::as_f64)
                        .unwrap_or(0.0) as i32,
                    deadline: v.get("deadline").and_then(Json::as_f64),
                });
            }
            other => {
                return Err(format!("line {}: unknown kind '{other}'",
                                   ln + 1))
            }
        }
    }
    if specs.is_empty() {
        return Err("trace has no queue lines".into());
    }
    for w in arrivals.windows(2) {
        if w[0].t > w[1].t {
            return Err("arrival lines must be time-sorted".into());
        }
    }
    Ok((cfg, specs, arrivals, fleet))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn trace_roundtrips_losslessly() {
        let cfg = SchedConfig {
            starve_after: 32,
            preempt_after: 2,
            ..SchedConfig::default()
        };
        let specs = vec![
            QueueSpec::new(16, 4, 0.08, QueuePolicy {
                preempt: true,
                ..QueuePolicy::default()
            }),
            QueueSpec {
                fault: Some(FaultPlan::parse("err@2,stall@5:0.25").unwrap()),
                ..QueueSpec::new(8, 1, 0.004, QueuePolicy {
                    weight: 4.0,
                    slo_p95_s: Some(0.005),
                    max_pending: 256,
                    ..QueuePolicy::default()
                })
            },
        ];
        // A seed above 2^53 must survive (f64 JSON numbers would not).
        let trace = vec![
            Arrival { t: 0.0, queue: 0, n: 2,
                      seed: (1u64 << 60) + 12345, priority: 0,
                      deadline: None },
            Arrival { t: 0.5, queue: 1, n: 1, seed: 7, priority: 3,
                      deadline: Some(0.25) },
        ];
        let path = std::env::temp_dir()
            .join(format!("ssmd_trace_rt_{}.jsonl", std::process::id()));
        write_trace(&path, &cfg, &specs, &trace).unwrap();
        let (cfg2, specs2, trace2, fleet2) = read_trace(&path).unwrap();
        let _ = std::fs::remove_file(&path);
        assert!(fleet2.is_empty(), "plain traces carry no fleet script");
        assert_eq!(cfg2.starve_after, 32);
        assert_eq!(cfg2.preempt_after, 2);
        assert_eq!(specs2.len(), 2);
        assert_eq!(specs2[0].d, 16);
        assert!(specs2[0].policy.preempt);
        assert_eq!(specs2[0].step_cost, 0.08);
        assert_eq!(specs2[1].policy.slo_p95_s, Some(0.005));
        assert_eq!(specs2[1].policy.max_pending, 256);
        assert_eq!(specs2[1].policy.weight, 4.0);
        assert_eq!(specs2[0].fault, None);
        assert_eq!(specs2[1].fault,
                   Some(FaultPlan::parse("err@2,stall@5:0.25").unwrap()));
        assert_eq!(trace2.len(), 2);
        assert_eq!(trace2[0].seed, (1u64 << 60) + 12345);
        assert_eq!(trace2[0].n, 2);
        assert_eq!(trace2[0].deadline, None);
        assert_eq!(trace2[1].priority, 3);
        assert_eq!(trace2[1].t, 0.5);
        assert_eq!(trace2[1].deadline, Some(0.25));
    }

    #[test]
    fn fleet_script_round_trips_and_folds_into_options() {
        let cfg = SchedConfig::default();
        let specs = vec![QueueSpec::new(8, 2, 0.01,
                                        QueuePolicy::default())];
        let trace = vec![Arrival { seed: 11, ..Arrival::default() }];
        let fleet = FleetScript {
            replica_faults: vec![
                (1, FaultPlan::parse("kill@4").unwrap()),
                (0, FaultPlan::parse("kill@9,kill@40").unwrap()),
            ],
            heartbeat_s: Some(0.25),
            restart_budget: Some(1),
        };
        let path = std::env::temp_dir()
            .join(format!("ssmd_trace_fleet_{}.jsonl",
                          std::process::id()));
        write_trace_fleet(&path, &cfg, &specs, &trace, &fleet).unwrap();
        let (_, specs2, trace2, fleet2) = read_trace(&path).unwrap();
        let _ = std::fs::remove_file(&path);
        assert_eq!(specs2.len(), 1);
        assert_eq!(trace2.len(), 1);
        assert_eq!(fleet2, fleet, "fleet script must survive round-trip");
        let opts = fleet2.options(true);
        assert!(opts.migrate);
        assert_eq!(opts.heartbeat_timeout_s, 0.25);
        assert_eq!(opts.restart_budget, 1);
        assert_eq!(opts.replica_faults.len(), 2);
        // Omitted keys fall back to FleetOptions defaults.
        let d = FleetScript::default().options(false);
        assert_eq!(d.heartbeat_timeout_s,
                   FleetOptions::default().heartbeat_timeout_s);
    }

    #[test]
    fn assemble_trace_groups_models_and_averages_costs() {
        let geometry = vec![
            QueueGeometry {
                model: "bulk".into(),
                d: 16,
                vocab: 6,
                bucket: 4,
                model_seed: 7,
                policy: QueuePolicy::default(),
            },
            QueueGeometry {
                model: "slo".into(),
                d: 8,
                vocab: 6,
                bucket: 1,
                model_seed: 9,
                policy: QueuePolicy::default(),
            },
        ];
        let events = vec![
            TraceEvent::Arrival { t: 10.0, model: "bulk".into(), n: 2,
                                  seed: 1, priority: 0 },
            TraceEvent::Step { model: "bulk".into(), cost_s: 0.02 },
            TraceEvent::Step { model: "bulk".into(), cost_s: 0.04 },
            TraceEvent::Arrival { t: 10.5, model: "slo".into(), n: 1,
                                  seed: 2, priority: 5 },
            // Unknown models are dropped, not mis-bucketed.
            TraceEvent::Arrival { t: 10.1, model: "ghost".into(), n: 9,
                                  seed: 3, priority: 0 },
        ];
        let (specs, arrivals) = assemble_trace(&events, &geometry);
        assert_eq!(specs.len(), 2);
        assert!((specs[0].step_cost - 0.03).abs() < 1e-12);
        assert_eq!(specs[1].step_cost, 0.01, "no steps -> default cost");
        assert_eq!(arrivals.len(), 2);
        // Times normalized to the earliest kept arrival; order sorted.
        assert_eq!(arrivals[0].t, 0.0);
        assert_eq!(arrivals[0].queue, 0);
        assert!((arrivals[1].t - 0.5).abs() < 1e-12);
        assert_eq!(arrivals[1].queue, 1);
        assert_eq!(arrivals[1].priority, 5);
    }
}
