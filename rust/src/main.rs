//! ssmd CLI — leader entrypoint.
//!
//! Subcommands:
//!   serve     start the HTTP serving coordinator
//!   generate  sample from a model and print tokens / decoded text
//!   score     exact likelihood + rejection posterior of a token sequence
//!   flops     reproduce the Appendix E FLOP analysis
//!   models    list models in the artifact manifest

use std::collections::BTreeMap;
use std::time::Duration;

use anyhow::{anyhow, Result};

use ssmd::coordinator::{
    BatcherConfig, Coordinator, EngineModel, GenRequest, ModelMap,
    SamplerChoice, ScoreRequest,
};
use ssmd::engine::{MdmParams, SpecParams, Window};
use ssmd::flops::TransformerShape;
use ssmd::oracle;
use ssmd::runtime::{Manifest, Runtime};
use ssmd::server::Server;
use ssmd::util::args::Args;

fn main() {
    let args = Args::from_env();
    let cmd = args
        .positional()
        .first()
        .map(|s| s.as_str())
        .unwrap_or("help")
        .to_string();
    let result = match cmd.as_str() {
        "serve" => cmd_serve(&args),
        "generate" => cmd_generate(&args),
        "score" => cmd_score(&args),
        "flops" => cmd_flops(),
        "models" => cmd_models(&args),
        _ => {
            print_help();
            Ok(())
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn print_help() {
    println!(
        "ssmd — Self-Speculative Masked Diffusions serving stack\n\n\
         USAGE: ssmd <command> [--flags]\n\n\
         COMMANDS:\n\
         \x20 serve     --artifacts DIR --addr 127.0.0.1:8080 [--models a,b]\n\
         \x20           [--queue-policy \"pending:256,shed;m=weight:4,\n\
         \x20           slo:0.05,burst:2,preempt:on\"] (weighted SLO-aware\n\
         \x20           scheduling; preempt:on marks a queue evictable)\n\
         \x20           [--default-priority N] [--preempt-after K]\n\
         \x20           [--checkpoint-budget N] (cap on preemption redo\n\
         \x20           steps per victim queue; 0 disables preemption)\n\
         \x20           [--engines N] (shard into N replica engines behind\n\
         \x20           a least-loaded router with work stealing and\n\
         \x20           bitwise-identical checkpoint migration)\n\
         \x20           [--heartbeat-timeout-s S] (missed-beat threshold\n\
         \x20           before a replica is marked Down and its work\n\
         \x20           evacuates to survivors; default 30)\n\
         \x20           [--max-conns N] [--io-timeout-ms N] (connection\n\
         \x20           budget — 503 over the cap — and per-stream I/O\n\
         \x20           timeout)\n\
         \x20           [--step-threads N] (planar-phase workers; results\n\
         \x20           are bitwise identical for any N)\n\
         \x20           [--fault-plan \"m=err@2,panic@5;m2=stall@1:0.25\"]\n\
         \x20           (deterministic fault injection for chaos drills)\n\
         \x20           [--deadline-ms N] (default request deadline;\n\
         \x20           expired requests are answered 504 and counted in\n\
         \x20           deadline_sheds)\n\
         \x20 generate  --artifacts DIR --model NAME [--n 4] [--sampler\n\
         \x20           speculative|mdm] [--window cosine:0.05] [--n-verify 1]\n\
         \x20           [--steps 64] [--seed 0] [--priority P]\n\
         \x20           [--deadline-ms N] [--decode text8]\n\
         \x20 score     --artifacts DIR --model NAME --tokens 1,2,3 [--seed 0]\n\
         \x20 flops     reproduce Appendix E\n\
         \x20 models    --artifacts DIR"
    );
}

/// Build the engine-thread model factory for the given artifact dir.
/// `Fn + Clone` (not `FnOnce`): sharded serving runs one copy per
/// replica engine thread, since PJRT handles are not `Send`.
fn model_factory(artifacts: String, only: Option<Vec<String>>)
                 -> impl Fn() -> Result<ModelMap> + Clone + Send + 'static {
    move || {
        let manifest = Manifest::load(&artifacts)?;
        let runtime = Runtime::cpu()?;
        eprintln!("pjrt platform: {}", runtime.platform());
        let mut map: ModelMap = BTreeMap::new();
        for (name, entry) in &manifest.models {
            if let Some(only) = &only {
                if !only.contains(name) {
                    continue;
                }
            }
            eprintln!("compiling model '{name}' (buckets {:?})",
                      entry.buckets);
            map.insert(
                name.clone(),
                Box::new(runtime.load_model(entry)?) as Box<dyn EngineModel>,
            );
        }
        if map.is_empty() {
            return Err(anyhow!("no models loaded from {artifacts}"));
        }
        Ok(map)
    }
}

fn start_coordinator(args: &Args) -> Result<Coordinator> {
    let artifacts = args.str("artifacts", "artifacts");
    let only = args
        .opt_str("models")
        .map(|s| s.split(',').map(|x| x.trim().to_string()).collect());
    // Cross-queue scheduling policies, e.g.
    //   --queue-policy "pending:256,shed; owt=weight:4,slo:0.05;
    //                   gpt2=preempt:on"
    // (`;`-separated entries; `model=opts` overrides, bare opts edit the
    // default policy; opts are weight:W, slo:S, burst:N, pending:N,
    // preempt:on|off, shed | queue).
    let mut sched = ssmd::coordinator::SchedConfig::default();
    if let Some(spec) = args.opt_str("queue-policy") {
        sched
            .apply_cli(&spec)
            .map_err(|e| anyhow!("--queue-policy: {e}"))?;
    }
    // Preemptive serving knobs: --preempt-after K rounds of sustained
    // SLO ceiling pressure before a preempt:on queue's residents are
    // checkpointed out; --default-priority for requests that don't
    // carry a priority class of their own.
    sched.preempt_after =
        args.u64("preempt-after", sched.preempt_after).max(1);
    // --checkpoint-budget N caps the cumulative redo steps preemption
    // may park per victim queue (0 disables preemption entirely).
    sched.checkpoint_budget =
        args.u64("checkpoint-budget", sched.checkpoint_budget);
    sched.default_priority =
        args.i64("default-priority", sched.default_priority as i64) as i32;
    // Planar-phase executor width of the engine's shared step pool
    // (`--step-threads N`, or the STEP_THREADS env var — handy for CI
    // and benches). 1 = the exact single-threaded code path. Token
    // streams are bitwise identical for any value (see engine::pool),
    // so this is purely a throughput knob.
    let env_threads = std::env::var("STEP_THREADS")
        .ok()
        .and_then(|v| v.parse::<usize>().ok())
        .unwrap_or(1);
    sched.step_threads = args.usize("step-threads", env_threads).max(1);
    // Failure-layer knobs: --fault-plan scripts deterministic faults per
    // model (chaos drills against a live server, e.g.
    // "owt=err@2,panic@5;gpt2=stall@1:0.25"); --deadline-ms sets the
    // default request deadline for requests that carry none.
    let faults = match args.opt_str("fault-plan") {
        Some(spec) => ssmd::engine::fault::parse_fault_cli(&spec)
            .map_err(|e| anyhow!("--fault-plan: {e}"))?,
        None => BTreeMap::new(),
    };
    let default_deadline_ms = args
        .opt_str("deadline-ms")
        .map(|v| {
            v.parse::<u64>()
                .map_err(|_| anyhow!("--deadline-ms: bad value '{v}'"))
        })
        .transpose()?
        .filter(|&ms| ms > 0);
    // --engines N shards the engine into N replicas behind the
    // least-loaded router (work stealing + checkpoint migration); 1 is
    // the exact single-engine code path. --heartbeat-timeout-s tunes
    // replica death detection: strictly longer than this without a
    // load-gauge beat marks a replica Down (admission routes around it;
    // its checkpoints evacuate to survivors).
    let engines = args.usize("engines", 1).max(1);
    let heartbeat_timeout_s = args
        .f64("heartbeat-timeout-s",
             BatcherConfig::default().heartbeat_timeout_s)
        .max(0.001);
    Coordinator::start_sharded(
        model_factory(artifacts, only),
        BatcherConfig {
            max_wait: Duration::from_millis(args.u64("batch-wait-ms", 5)),
            sched,
            faults,
            default_deadline_ms,
            heartbeat_timeout_s,
            ..Default::default()
        },
        engines,
    )
}

fn cmd_serve(args: &Args) -> Result<()> {
    let coordinator = start_coordinator(args)?;
    let addr = args.str("addr", "127.0.0.1:8080");
    // Connection budget (503 + Connection: close over the cap) and
    // per-stream I/O timeout for reads and writes.
    let max_conns = args.usize("max-conns", 256).max(1);
    let io_timeout =
        Duration::from_millis(args.u64("io-timeout-ms", 30_000).max(1));
    Server::new(coordinator)
        .with_limits(max_conns, io_timeout)
        .serve(&addr)
}

fn sampler_from_args(args: &Args) -> Result<SamplerChoice> {
    Ok(match args.str("sampler", "speculative").as_str() {
        "speculative" => {
            let w = args.str("window", "cosine:0.05");
            SamplerChoice::Speculative(SpecParams {
                window: Window::parse(&w)
                    .ok_or_else(|| anyhow!("bad --window '{w}'"))?,
                n_verify: args.usize("n-verify", 1).max(1),
                temperature: args.f64("temperature", 1.0),
                ..Default::default()
            })
        }
        "mdm" => SamplerChoice::Mdm(MdmParams {
            steps: args.usize("steps", 64).max(1),
            temperature: args.f64("temperature", 1.0),
        }),
        other => return Err(anyhow!("unknown sampler '{other}'")),
    })
}

fn cmd_generate(args: &Args) -> Result<()> {
    let coordinator = start_coordinator(args)?;
    let model = args
        .opt_str("model")
        .ok_or_else(|| anyhow!("--model required"))?;
    let resp = coordinator.generate(GenRequest {
        model,
        n_samples: args.usize("n", 4),
        sampler: sampler_from_args(args)?,
        seed: args.u64("seed", 0),
        deterministic: args.bool("deterministic"),
        prompt: None,
        priority: args
            .opt_str("priority")
            .and_then(|p| p.parse::<i32>().ok()),
        deadline_ms: args
            .opt_str("deadline-ms")
            .and_then(|d| d.parse::<u64>().ok())
            .filter(|&ms| ms > 0),
    })?;
    let decode = args.str("decode", "none");
    for (i, s) in resp.samples.iter().enumerate() {
        println!(
            "--- sample {i}: nfe={:.2} outer={} accepted={} rejected={}",
            s.nfe, s.outer_loops, s.accepted, s.rejected
        );
        if decode == "text8" {
            println!("{}", oracle::decode_chars(&s.tokens));
        } else {
            println!("{:?}", s.tokens);
        }
    }
    println!("wall: {:.3}s for {} samples", resp.wall_s,
             resp.samples.len());
    coordinator.shutdown();
    Ok(())
}

fn cmd_score(args: &Args) -> Result<()> {
    let coordinator = start_coordinator(args)?;
    let model = args
        .opt_str("model")
        .ok_or_else(|| anyhow!("--model required"))?;
    let tokens: Vec<i32> = args
        .opt_str("tokens")
        .ok_or_else(|| anyhow!("--tokens required (comma separated)"))?
        .split(',')
        .filter_map(|t| t.trim().parse().ok())
        .collect();
    let resp = coordinator.score(ScoreRequest {
        model,
        tokens,
        sigma: None,
        seed: Some(args.u64("seed", 0)),
        with_posterior: true,
    })?;
    println!("log-likelihood (Prop 3.1): {:.4} nats", resp.log_likelihood);
    if let Some(post) = resp.rejection_posterior {
        let mean: f64 =
            post.iter().enumerate().map(|(n, p)| n as f64 * p).sum();
        println!("rejection posterior (Prop C.2): E[N] = {mean:.2}");
        for (n, p) in post.iter().enumerate().filter(|(_, p)| **p > 1e-3) {
            println!("  p(N={n}) = {p:.4}");
        }
    }
    coordinator.shutdown();
    Ok(())
}

fn cmd_flops() -> Result<()> {
    let t = TransformerShape::paper_owt();
    println!("Appendix E FLOP analysis (paper OWT settings)");
    println!("  embedding          = {:.3e}", t.embedding() as f64);
    println!("  qkv projection     = {:.3e}", t.qkv_projection() as f64);
    println!("  k@q                = {:.3e}", t.kq_matmul() as f64);
    println!("  softmax            = {:.3e}", t.softmax() as f64);
    println!("  softmax@query red. = {:.3e}",
             t.softmax_query_reduction() as f64);
    println!("  linear             = {:.3e}", t.attn_linear() as f64);
    println!("  attention total    = {:.3e}", t.attention() as f64);
    println!("  dense block        = {:.3e}", t.dense_block() as f64);
    println!("  final logits       = {:.3e}", t.final_logits() as f64);
    println!("  TOTAL vanilla      = {:.3e}", t.total_vanilla() as f64);
    println!("  spec overhead      = {:.3e}",
             t.speculative_overhead() as f64);
    println!("  overhead fraction  = {:.2}% (paper: 0.98%)",
             100.0 * t.overhead_fraction());
    Ok(())
}

fn cmd_models(args: &Args) -> Result<()> {
    let manifest = Manifest::load(&args.str("artifacts", "artifacts"))?;
    for (name, e) in &manifest.models {
        println!(
            "{name}: D={} V={} {}nc+{}c buckets={:?} verify={}",
            e.config.seq_len,
            e.config.vocab_size,
            e.config.n_noncausal,
            e.config.n_causal,
            e.buckets,
            e.has_verify()
        );
    }
    Ok(())
}
