//! HMM oracle: the exact-likelihood pLDDT proxy for the protein task
//! (Fig. 4's ESMFold substitute). Reproduces python/train/hmm.py: scaled
//! forward algorithm + fixed logistic calibration to a [0, 100] score.

use anyhow::{anyhow, Context, Result};

use crate::util::json::Json;

pub struct HmmOracle {
    pub k: usize,
    pub n_obs: usize,
    pub init: Vec<f64>,
    /// trans[i * k + j] = p(z' = j | z = i).
    pub trans: Vec<f64>,
    /// emis[i * n_obs + o] = p(x = o | z = i).
    pub emis: Vec<f64>,
    pub calib_mu: f64,
    pub calib_sigma: f64,
    pub calib_scale: f64,
    pub calib_offset: f64,
}

impl HmmOracle {
    pub fn from_spec_file(path: &str) -> Result<HmmOracle> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {path}"))?;
        Self::from_json(&Json::parse(&text).map_err(|e| anyhow!("{e}"))?)
    }

    pub fn from_json(v: &Json) -> Result<HmmOracle> {
        let init = v
            .get("init")
            .and_then(|x| x.as_f64_vec())
            .ok_or_else(|| anyhow!("missing init"))?;
        let k = init.len();
        let flat = |key: &str| -> Result<(Vec<f64>, usize)> {
            let rows = v
                .get(key)
                .and_then(|x| x.as_arr())
                .ok_or_else(|| anyhow!("missing {key}"))?;
            let mut out = Vec::new();
            let mut width = 0;
            for r in rows {
                let row = r.as_f64_vec().ok_or_else(|| anyhow!("bad row"))?;
                width = row.len();
                out.extend(row);
            }
            Ok((out, width))
        };
        let (trans, tw) = flat("trans")?;
        let (emis, n_obs) = flat("emis")?;
        if tw != k || trans.len() != k * k || emis.len() != k * n_obs {
            return Err(anyhow!("inconsistent hmm dims"));
        }
        let g = |key: &str, d: f64| {
            v.get(key).and_then(|x| x.as_f64()).unwrap_or(d)
        };
        Ok(HmmOracle {
            k,
            n_obs,
            init,
            trans,
            emis,
            calib_mu: g("calib_mu", 0.0),
            calib_sigma: g("calib_sigma", 1.0),
            calib_scale: g("calib_scale", 1.5),
            calib_offset: g("calib_offset", 1.7),
        })
    }

    /// Exact log p(seq) via the scaled forward algorithm.
    pub fn loglik(&self, seq: &[i32]) -> f64 {
        assert!(!seq.is_empty());
        let k = self.k;
        let mut a: Vec<f64> = (0..k)
            .map(|z| self.init[z] * self.emis[z * self.n_obs + seq[0] as usize])
            .collect();
        let mut ll = 0.0;
        let s: f64 = a.iter().sum();
        ll += s.ln();
        a.iter_mut().for_each(|x| *x /= s);
        let mut next = vec![0.0; k];
        for &obs in &seq[1..] {
            for j in 0..k {
                let mut acc = 0.0;
                for i in 0..k {
                    acc += a[i] * self.trans[i * k + j];
                }
                next[j] = acc * self.emis[j * self.n_obs + obs as usize];
            }
            let s: f64 = next.iter().sum();
            ll += s.ln();
            for j in 0..k {
                a[j] = next[j] / s;
            }
        }
        ll
    }

    pub fn per_residue_ll(&self, seq: &[i32]) -> f64 {
        self.loglik(seq) / seq.len() as f64
    }

    /// pLDDT proxy: logistic calibration of the per-residue log-likelihood,
    /// matching python/train/hmm.py `plddt_proxy`.
    pub fn plddt(&self, seq: &[i32]) -> f64 {
        let z = (self.per_residue_ll(seq) - self.calib_mu) / self.calib_sigma;
        let x = self.calib_scale * z + self.calib_offset;
        100.0 / (1.0 + (-x).exp())
    }

    /// (mean, standard error of the mean) of pLDDT over a batch — Fig. 4
    /// reports mean with SEM shading over 512 samples.
    pub fn plddt_mean_sem(&self, samples: &[i32], seq_len: usize)
                          -> (f64, f64) {
        let rows = samples.len() / seq_len;
        let vals: Vec<f64> = (0..rows)
            .map(|r| self.plddt(&samples[r * seq_len..(r + 1) * seq_len]))
            .collect();
        let mean = vals.iter().sum::<f64>() / rows as f64;
        let var = vals.iter().map(|v| (v - mean) * (v - mean)).sum::<f64>()
            / (rows.max(2) - 1) as f64;
        (mean, (var / rows as f64).sqrt())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> HmmOracle {
        // 2 states, 2 observations.
        HmmOracle {
            k: 2,
            n_obs: 2,
            init: vec![0.6, 0.4],
            trans: vec![0.7, 0.3, 0.2, 0.8],
            emis: vec![0.9, 0.1, 0.25, 0.75],
            calib_mu: -0.6,
            calib_sigma: 0.1,
            calib_scale: 1.5,
            calib_offset: 1.7,
        }
    }

    #[test]
    fn forward_matches_enumeration() {
        let o = tiny();
        let seq = [0i32, 1, 1];
        // Brute force over hidden paths.
        let mut p = 0.0;
        for z0 in 0..2 {
            for z1 in 0..2 {
                for z2 in 0..2 {
                    p += o.init[z0]
                        * o.emis[z0 * 2 + 0]
                        * o.trans[z0 * 2 + z1]
                        * o.emis[z1 * 2 + 1]
                        * o.trans[z1 * 2 + z2]
                        * o.emis[z2 * 2 + 1];
                }
            }
        }
        assert!((o.loglik(&seq) - p.ln()).abs() < 1e-12);
    }

    #[test]
    fn plddt_monotone_in_loglik() {
        let o = tiny();
        // seq likely under the model vs unlikely.
        let good = [0i32, 0, 0];
        let bad = [1i32, 0, 1];
        if o.per_residue_ll(&good) > o.per_residue_ll(&bad) {
            assert!(o.plddt(&good) > o.plddt(&bad));
        }
    }

    #[test]
    fn plddt_in_range() {
        let o = tiny();
        let v = o.plddt(&[0, 1, 0, 1]);
        assert!((0.0..=100.0).contains(&v));
    }

    #[test]
    fn mean_sem_sane() {
        let o = tiny();
        let batch = [0i32, 0, 1, 1, 0, 1, 1, 0];
        let (m, sem) = o.plddt_mean_sem(&batch, 2);
        assert!((0.0..=100.0).contains(&m));
        assert!(sem >= 0.0);
    }
}
