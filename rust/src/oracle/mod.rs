//! Evaluation oracles mirroring the python data generators bit-for-bit.
//!
//! Because the synthetic corpora replace the paper's data gates, the
//! *judges* can be exact: the bigram chain gives the true NLL a sample
//! should have (replacing GPT2 generative perplexity), the HMM forward
//! algorithm gives the true sequence likelihood (replacing ESMFold pLDDT),
//! and the lexicon gives text8 spelling accuracy verbatim. Specs are loaded
//! from the JSON files `aot.py` copies into `artifacts/`.

pub mod bigram;
pub mod hmm;
pub mod text;

pub use bigram::BigramOracle;
pub use hmm::HmmOracle;
pub use text::{decode_chars, spelling_accuracy, unigram_entropy};
