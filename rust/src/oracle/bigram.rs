//! Word-bigram oracle: exact NLL judge for the synthetic OpenWebText task
//! (Table 1's "GPT2 NLL" substitute) and the lexicon for text8 spelling
//! accuracy. Must reproduce python/train/data.py `BigramChain.nll_tokens`.

use anyhow::{anyhow, Context, Result};

use crate::util::json::Json;

pub struct BigramOracle {
    pub lexicon: Vec<String>,
    /// init[w] = stationary probability of word w.
    pub init: Vec<f64>,
    /// trans[i * n + j] = p(next = j | cur = i), row-major.
    pub trans: Vec<f64>,
    pub n: usize,
}

impl BigramOracle {
    pub fn from_spec_file(path: &str) -> Result<BigramOracle> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {path}"))?;
        Self::from_json(&Json::parse(&text).map_err(|e| anyhow!("{e}"))?)
    }

    pub fn from_json(v: &Json) -> Result<BigramOracle> {
        let lexicon: Vec<String> = v
            .get("lexicon")
            .and_then(|l| l.as_arr())
            .ok_or_else(|| anyhow!("spec missing lexicon"))?
            .iter()
            .map(|w| w.as_str().unwrap_or_default().to_string())
            .collect();
        let init = v
            .get("init")
            .and_then(|x| x.as_f64_vec())
            .ok_or_else(|| anyhow!("spec missing init"))?;
        let n = init.len();
        let rows = v
            .get("trans")
            .and_then(|x| x.as_arr())
            .ok_or_else(|| anyhow!("spec missing trans"))?;
        let mut trans = Vec::with_capacity(n * n);
        for row in rows {
            trans.extend(
                row.as_f64_vec().ok_or_else(|| anyhow!("bad trans row"))?,
            );
        }
        if trans.len() != n * n || lexicon.len() != n {
            return Err(anyhow!("inconsistent spec dims"));
        }
        Ok(BigramOracle { lexicon, init, trans, n })
    }

    /// Exact oracle NLL in nats/token of a word-token window; first token
    /// is scored under the stationary distribution (mid-stream windows).
    pub fn nll_tokens(&self, tokens: &[i32]) -> f64 {
        assert!(!tokens.is_empty());
        let mut lp = self.init[tokens[0] as usize].ln();
        for w in tokens.windows(2) {
            lp += self.trans[w[0] as usize * self.n + w[1] as usize].ln();
        }
        -lp / tokens.len() as f64
    }

    /// Mean oracle NLL over a batch of samples (rows of `seq_len`).
    pub fn mean_nll(&self, samples: &[i32], seq_len: usize) -> f64 {
        let rows = samples.len() / seq_len;
        (0..rows)
            .map(|r| self.nll_tokens(&samples[r * seq_len..(r + 1) * seq_len]))
            .sum::<f64>()
            / rows as f64
    }

    /// NLL of real data drawn from the chain itself == its entropy rate;
    /// useful as the "perfect sample" reference line in Table 1.
    pub fn entropy_rate(&self) -> f64 {
        let mut h = 0.0;
        for i in 0..self.n {
            let mut hi = 0.0;
            for j in 0..self.n {
                let p = self.trans[i * self.n + j];
                if p > 0.0 {
                    hi -= p * p.ln();
                }
            }
            h += self.init[i] * hi;
        }
        h
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> BigramOracle {
        // Two-word chain: p(0->1)=0.75, p(1->0)=0.5.
        BigramOracle {
            lexicon: vec!["aa".into(), "bb".into()],
            init: vec![0.4, 0.6],
            trans: vec![0.25, 0.75, 0.5, 0.5],
            n: 2,
        }
    }

    #[test]
    fn nll_matches_hand_computation() {
        let o = tiny();
        // p = init[0] * trans[0->1] * trans[1->1] = 0.4*0.75*0.5
        let expect = -(0.4f64 * 0.75 * 0.5).ln() / 3.0;
        assert!((o.nll_tokens(&[0, 1, 1]) - expect).abs() < 1e-12);
    }

    #[test]
    fn mean_nll_averages_rows() {
        let o = tiny();
        let a = o.nll_tokens(&[0, 1]);
        let b = o.nll_tokens(&[1, 0]);
        let m = o.mean_nll(&[0, 1, 1, 0], 2);
        assert!((m - (a + b) / 2.0).abs() < 1e-12);
    }

    #[test]
    fn entropy_rate_between_row_entropies() {
        let o = tiny();
        let h0 = -(0.25f64.ln() * 0.25 + 0.75f64.ln() * 0.75);
        let h1 = -(0.5f64.ln() * 0.5 + 0.5f64.ln() * 0.5);
        let h = o.entropy_rate();
        assert!(h > h0.min(h1) && h < h0.max(h1));
    }
}
