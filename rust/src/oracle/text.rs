//! Text metrics: char decoding, spelling accuracy (Sec. 5.1) and unigram
//! token entropy (Sec. 5.2), matching python/train/data.py exactly.

use std::collections::{HashMap, HashSet};

/// text8 char vocabulary: 0 = space, 1..=26 = 'a'..'z'.
pub fn decode_chars(ids: &[i32]) -> String {
    ids.iter()
        .map(|&i| {
            if i == 0 {
                ' '
            } else {
                (b'a' + (i as u8).saturating_sub(1).min(25)) as char
            }
        })
        .collect()
}

/// Fraction of whitespace-delimited words in the samples that appear in the
/// lexicon (paper Sec. 5.1: "proportion of words within the sample that
/// also appear in the training dataset").
pub fn spelling_accuracy(samples: &[i32], seq_len: usize,
                         lexicon: &[String]) -> f64 {
    let vocab: HashSet<&str> = lexicon.iter().map(|s| s.as_str()).collect();
    let rows = samples.len() / seq_len;
    let mut total = 0usize;
    let mut good = 0usize;
    for r in 0..rows {
        let text = decode_chars(&samples[r * seq_len..(r + 1) * seq_len]);
        for w in text.split(' ') {
            if w.is_empty() {
                continue;
            }
            total += 1;
            good += vocab.contains(w) as usize;
        }
    }
    good as f64 / total.max(1) as f64
}

/// Per-sample unigram entropy in nats, averaged over samples (Sec. 5.2).
pub fn unigram_entropy(samples: &[i32], seq_len: usize) -> f64 {
    let rows = samples.len() / seq_len;
    let mut acc = 0.0;
    for r in 0..rows {
        let row = &samples[r * seq_len..(r + 1) * seq_len];
        let mut counts: HashMap<i32, usize> = HashMap::new();
        for &t in row {
            *counts.entry(t).or_default() += 1;
        }
        let n = row.len() as f64;
        let ent: f64 = counts
            .values()
            .map(|&c| {
                let p = c as f64 / n;
                -p * p.ln()
            })
            .sum();
        acc += ent;
    }
    acc / rows.max(1) as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn decode_roundtrip() {
        assert_eq!(decode_chars(&[8, 9, 0, 20, 8, 5, 18, 5]), "hi there");
    }

    #[test]
    fn accuracy_counts_words() {
        // "hi there hix" with lexicon {hi, there} -> 2/3.
        let ids: Vec<i32> = "hi there hix"
            .chars()
            .map(|c| if c == ' ' { 0 } else { c as i32 - 'a' as i32 + 1 })
            .collect();
        let lex = vec!["hi".to_string(), "there".to_string()];
        let acc = spelling_accuracy(&ids, ids.len(), &lex);
        assert!((acc - 2.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn entropy_extremes() {
        // All-same tokens: entropy 0. Uniform over 4: ln 4.
        assert_eq!(unigram_entropy(&[3, 3, 3, 3], 4), 0.0);
        let e = unigram_entropy(&[0, 1, 2, 3], 4);
        assert!((e - 4f64.ln()).abs() < 1e-12);
    }

    #[test]
    fn entropy_averages_samples() {
        let e = unigram_entropy(&[1, 1, 0, 1], 2);
        let expect = (0.0 + 2f64.ln()) / 2.0;
        assert!((e - expect).abs() < 1e-12);
    }
}
