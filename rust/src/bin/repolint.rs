//! repolint — CLI front-end for the repo-native invariant linter
//! (`ssmd::lint`): the six lexical rules plus the concurrency pass
//! (lock-order, guard-blocking, lock-recovery). Walks `<root>/rust`
//! (src, tests, benches) and `<root>/examples`, prints
//! `path:line: [rule] msg` diagnostics, then the full allowlist (every
//! suppression with its written reason) and the lock-order graph
//! summary, and exits nonzero if anything fired. CI gates on it; the
//! same checks run under plain `cargo test` via the lint module's
//! meta-test.
//!
//! USAGE: cargo run --bin repolint [-- --root DIR] [--quiet]
//!   --root DIR   repo root to lint (default ".")
//!   --quiet      diagnostics only, no allowlist / summary

use std::path::Path;
use std::process::ExitCode;

use ssmd::lint;
use ssmd::util::args::Args;

fn main() -> ExitCode {
    let args = Args::from_env();
    let root = args.str("root", ".");
    let quiet = args.bool("quiet");

    let report = match lint::run_tree(Path::new(&root)) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("repolint: cannot walk {root}/rust: {e}");
            return ExitCode::from(2);
        }
    };

    for d in &report.diags {
        println!("{d}");
    }

    if !quiet {
        if !report.allows.is_empty() {
            println!("\nallowlist ({} entries):", report.allows.len());
            for a in &report.allows {
                println!(
                    "  {}:{} allow({}) — {}",
                    a.path,
                    a.target,
                    a.rules.join(", "),
                    a.reason
                );
            }
        }
        println!(
            "\nrepolint: {} files, {} diagnostic(s), {} allowlist \
             entr{}",
            report.files,
            report.diags.len(),
            report.allows.len(),
            if report.allows.len() == 1 { "y" } else { "ies" },
        );
        println!(
            "lock-order graph: {} fn(s), {} lock class(es), {} \
             edge(s), {} cycle(s)",
            report.stats.fns,
            report.stats.classes,
            report.stats.edges,
            report.stats.cycles,
        );
    }

    if report.clean() {
        ExitCode::SUCCESS
    } else {
        ExitCode::FAILURE
    }
}
