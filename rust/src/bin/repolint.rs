//! repolint — CLI front-end for the repo-native invariant linter
//! (`ssmd::lint`). Walks `<root>/rust`, prints `path:line: [rule] msg`
//! diagnostics, then the full allowlist (every suppression with its
//! written reason), and exits nonzero if anything fired. CI gates on it;
//! the same checks run under plain `cargo test` via the lint module's
//! meta-test.
//!
//! USAGE: cargo run --bin repolint [-- --root DIR] [--quiet]
//!   --root DIR   repo root to lint (default ".")
//!   --quiet      diagnostics only, no allowlist / summary

use std::path::Path;
use std::process::ExitCode;

use ssmd::lint;
use ssmd::util::args::Args;

fn main() -> ExitCode {
    let args = Args::from_env();
    let root = args.str("root", ".");
    let quiet = args.bool("quiet");

    let report = match lint::run_tree(Path::new(&root)) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("repolint: cannot walk {root}/rust: {e}");
            return ExitCode::from(2);
        }
    };

    for d in &report.diags {
        println!("{d}");
    }

    if !quiet {
        if !report.allows.is_empty() {
            println!("\nallowlist ({} entries):", report.allows.len());
            for a in &report.allows {
                println!(
                    "  {}:{} allow({}) — {}",
                    a.path,
                    a.target,
                    a.rules.join(", "),
                    a.reason
                );
            }
        }
        println!(
            "\nrepolint: {} files, {} diagnostic(s), {} allowlist \
             entr{}",
            report.files,
            report.diags.len(),
            report.allows.len(),
            if report.allows.len() == 1 { "y" } else { "ies" },
        );
    }

    if report.clean() {
        ExitCode::SUCCESS
    } else {
        ExitCode::FAILURE
    }
}
