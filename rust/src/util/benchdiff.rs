//! Bench trend diffing: compare a `BENCH_<target>.json` artifact (see
//! [`crate::util::bench::write_json`]) against a committed baseline
//! snapshot and flag mean-time regressions.
//!
//! Driven by `cargo run --example bench_trend`, which exits nonzero when
//! any benchmark's mean regressed more than the threshold (default 20%)
//! or a benchmark disappeared. Two honesty rules:
//!
//! * wall-clock comparisons only count when **neither** side is a smoke
//!   run (`BENCH_SMOKE=1` collapses to one iteration — artifact
//!   plumbing, not measurement; the JSON carries a `smoke` flag for
//!   exactly this decision);
//! * the free-form `extra` scalars (row counts, speedup ratios, …) are
//!   deterministic workload facts on several benches, so they are
//!   diffed and reported regardless of smoke state — they just don't
//!   gate, because their improvement direction is bench-specific.

use std::path::Path;

use crate::util::json::Json;

/// One benchmark present on both sides.
#[derive(Clone, Debug)]
pub struct Delta {
    pub name: String,
    pub base: f64,
    pub cur: f64,
}

impl Delta {
    /// Fractional change (+0.25 = 25% higher than baseline).
    pub fn change(&self) -> f64 {
        if self.base == 0.0 {
            if self.cur == 0.0 { 0.0 } else { f64::INFINITY }
        } else {
            self.cur / self.base - 1.0
        }
    }
}

#[derive(Debug)]
pub struct DiffReport {
    pub target: String,
    pub base_smoke: bool,
    pub cur_smoke: bool,
    /// Per-benchmark mean_s comparison (both sides).
    pub deltas: Vec<Delta>,
    /// Top-level `extra` scalar comparison (both sides).
    pub extra_deltas: Vec<Delta>,
    /// Benchmarks in the baseline missing from the current run.
    pub missing_in_current: Vec<String>,
    /// Benchmarks new in the current run (informational).
    pub new_in_current: Vec<String>,
    /// Extra scalars present only in the baseline (informational: e.g.
    /// timing-derived extras are deliberately omitted from smoke runs).
    pub missing_extras: Vec<String>,
}

impl DiffReport {
    /// Wall-clock numbers are trustworthy on both sides.
    pub fn comparable(&self) -> bool {
        !self.base_smoke && !self.cur_smoke
    }

    /// Mean-time regressions beyond `threshold` (fractional, e.g. 0.2).
    /// Empty when either side is a smoke run.
    pub fn regressions(&self, threshold: f64) -> Vec<&Delta> {
        if !self.comparable() {
            return Vec::new();
        }
        self.deltas.iter().filter(|d| d.change() > threshold).collect()
    }
}

/// (name, mean_s) pairs of a `BENCH_*.json` document.
fn results_of(v: &Json) -> Result<Vec<(String, f64)>, String> {
    let arr = v
        .get("results")
        .and_then(|r| r.as_arr())
        .ok_or("missing 'results' array")?;
    let mut out = Vec::new();
    for r in arr {
        let name = r
            .get("name")
            .and_then(|n| n.as_str())
            .ok_or("result missing 'name'")?
            .to_string();
        let mean = r
            .get("mean_s")
            .and_then(|m| m.as_f64())
            .ok_or("result missing 'mean_s'")?;
        out.push((name, mean));
    }
    Ok(out)
}

/// Top-level scalar `extra` fields (everything numeric that is not part
/// of the fixed schema).
fn extras_of(v: &Json) -> Vec<(String, f64)> {
    match v.as_obj() {
        Some(fields) => fields
            .iter()
            .filter(|(k, _)| {
                let k = k.as_str();
                k != "target" && k != "smoke" && k != "results"
            })
            .filter_map(|(k, val)| val.as_f64().map(|x| (k.clone(), x)))
            .collect(),
        None => Vec::new(),
    }
}

/// Diff a current artifact against its baseline.
pub fn diff(baseline: &Json, current: &Json) -> Result<DiffReport, String> {
    let target = current
        .get("target")
        .and_then(|t| t.as_str())
        .unwrap_or("?")
        .to_string();
    let smoke =
        |v: &Json| v.get("smoke").and_then(|s| s.as_bool()).unwrap_or(false);
    let base = results_of(baseline)?;
    let cur = results_of(current)?;
    let mut deltas = Vec::new();
    let mut missing = Vec::new();
    for (name, b) in &base {
        match cur.iter().find(|(n, _)| n == name) {
            Some((_, c)) => deltas.push(Delta {
                name: name.clone(),
                base: *b,
                cur: *c,
            }),
            None => missing.push(name.clone()),
        }
    }
    let new_in_current = cur
        .iter()
        .filter(|(n, _)| !base.iter().any(|(bn, _)| bn == n))
        .map(|(n, _)| n.clone())
        .collect();
    let base_extra = extras_of(baseline);
    let cur_extra = extras_of(current);
    let extra_deltas = cur_extra
        .iter()
        .filter_map(|(name, c)| {
            base_extra
                .iter()
                .find(|(bn, _)| bn == name)
                .map(|(_, b)| Delta { name: name.clone(), base: *b, cur: *c })
        })
        .collect();
    let missing_extras = base_extra
        .iter()
        .filter(|(bn, _)| !cur_extra.iter().any(|(cn, _)| cn == bn))
        .map(|(n, _)| n.clone())
        .collect();
    Ok(DiffReport {
        target,
        base_smoke: smoke(baseline),
        cur_smoke: smoke(current),
        deltas,
        extra_deltas,
        missing_in_current: missing,
        new_in_current,
        missing_extras,
    })
}

/// Read and parse one artifact.
pub fn load(path: &Path) -> Result<Json, String> {
    let body = std::fs::read_to_string(path)
        .map_err(|e| format!("read {}: {e}", path.display()))?;
    Json::parse(&body).map_err(|e| format!("parse {}: {e}", path.display()))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifact(smoke: bool, results: &[(&str, f64)],
                extras: &[(&str, f64)]) -> Json {
        let mut s = format!(
            r#"{{"target":"t","smoke":{smoke},"results":["#
        );
        for (i, (n, m)) in results.iter().enumerate() {
            if i > 0 {
                s.push(',');
            }
            s.push_str(&format!(r#"{{"name":"{n}","mean_s":{m}}}"#));
        }
        s.push(']');
        for (k, v) in extras {
            s.push_str(&format!(r#","{k}":{v}"#));
        }
        s.push('}');
        Json::parse(&s).unwrap()
    }

    #[test]
    fn flags_regressions_over_threshold() {
        let base = artifact(false, &[("a", 1.0), ("b", 1.0)], &[]);
        let cur = artifact(false, &[("a", 1.15), ("b", 1.30)], &[]);
        let rep = diff(&base, &cur).unwrap();
        assert!(rep.comparable());
        let regs = rep.regressions(0.20);
        assert_eq!(regs.len(), 1);
        assert_eq!(regs[0].name, "b");
        assert!((regs[0].change() - 0.30).abs() < 1e-12);
        assert!(rep.regressions(0.40).is_empty());
    }

    #[test]
    fn smoke_runs_never_gate_on_wall_time() {
        let base = artifact(true, &[("a", 1.0)], &[]);
        let cur = artifact(false, &[("a", 99.0)], &[]);
        let rep = diff(&base, &cur).unwrap();
        assert!(!rep.comparable());
        assert!(rep.regressions(0.2).is_empty());
        // ... and symmetrically for a smoke current run.
        let rep = diff(&artifact(false, &[("a", 1.0)], &[]),
                       &artifact(true, &[("a", 99.0)], &[]))
            .unwrap();
        assert!(rep.regressions(0.2).is_empty());
    }

    #[test]
    fn tracks_missing_and_new_benches() {
        let base = artifact(false, &[("kept", 1.0), ("gone", 1.0)], &[]);
        let cur = artifact(false, &[("kept", 1.0), ("fresh", 1.0)], &[]);
        let rep = diff(&base, &cur).unwrap();
        assert_eq!(rep.missing_in_current, vec!["gone".to_string()]);
        assert_eq!(rep.new_in_current, vec!["fresh".to_string()]);
        assert_eq!(rep.deltas.len(), 1);
    }

    #[test]
    fn extras_diff_even_under_smoke() {
        let base = artifact(true, &[("a", 1.0)],
                            &[("row_steps", 100.0), ("speedup", 8.0)]);
        let cur = artifact(true, &[("a", 1.0)],
                           &[("row_steps", 150.0)]);
        let rep = diff(&base, &cur).unwrap();
        assert_eq!(rep.extra_deltas.len(), 1);
        let rs = rep
            .extra_deltas
            .iter()
            .find(|d| d.name == "row_steps")
            .unwrap();
        assert!((rs.change() - 0.5).abs() < 1e-12);
        // An extra present only in the baseline (e.g. a timing-derived
        // value a smoke run deliberately omits) is surfaced, not lost.
        assert_eq!(rep.missing_extras, vec!["speedup".to_string()]);
    }

    #[test]
    fn rejects_malformed_artifacts() {
        let bad = Json::parse(r#"{"target":"t"}"#).unwrap();
        let good = artifact(false, &[("a", 1.0)], &[]);
        assert!(diff(&bad, &good).is_err());
        assert!(diff(&good, &bad).is_err());
    }

    #[test]
    fn zero_baseline_change_is_safe() {
        let d = Delta { name: "x".into(), base: 0.0, cur: 0.0 };
        assert_eq!(d.change(), 0.0);
        let d = Delta { name: "x".into(), base: 0.0, cur: 1.0 };
        assert!(d.change().is_infinite());
    }
}
