//! Injectable time source for the cross-queue scheduler.
//!
//! The scheduling core (`coordinator::sched`) is pure state driven by an
//! abstract [`Clock`] so the same code runs against two time sources:
//!
//! * [`MonotonicClock`] — wall time (an `Instant` anchor), used by the
//!   engine thread in production;
//! * [`SimClock`] — shared virtual time advanced explicitly by a test
//!   harness, used by `tests/sched_sim.rs` to replay scripted multi-queue
//!   arrival traces with synthetic per-step costs. Every latency/fairness
//!   assertion in that harness is exact: no sleeps, no flaky timing.
//!
//! Clocks report seconds since their own epoch as `f64` (the scheduler
//! only ever subtracts two readings, so the epoch cancels). `SimClock` is
//! cheaply cloneable and all clones share one timeline, which is how the
//! harness holds the clock it advances while the scheduler holds a boxed
//! clone of the same timeline.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Instant;

/// Abstract monotonic time source, in seconds since an arbitrary epoch.
pub trait Clock: Send {
    fn now(&self) -> f64;
}

/// Wall-clock time relative to construction.
pub struct MonotonicClock {
    origin: Instant,
}

impl MonotonicClock {
    pub fn new() -> MonotonicClock {
        MonotonicClock { origin: Instant::now() }
    }
}

impl Default for MonotonicClock {
    fn default() -> Self {
        MonotonicClock::new()
    }
}

impl Clock for MonotonicClock {
    fn now(&self) -> f64 {
        self.origin.elapsed().as_secs_f64()
    }
}

/// Shared virtual clock: clones observe one timeline; `advance`/`set`
/// move it forward deterministically. Time is stored as f64 bits in an
/// atomic so reading `now()` never allocates or locks.
#[derive(Clone)]
pub struct SimClock {
    t: Arc<AtomicU64>,
}

impl SimClock {
    pub fn new() -> SimClock {
        SimClock { t: Arc::new(AtomicU64::new(0f64.to_bits())) }
    }

    /// Advance the shared timeline by `dt` seconds (dt >= 0). Lossless
    /// under concurrent advancers (atomic read-modify-write).
    pub fn advance(&self, dt: f64) {
        debug_assert!(dt >= 0.0, "virtual time must not move backwards");
        let _ = self.t.fetch_update(
            Ordering::Relaxed,
            Ordering::Relaxed,
            |bits| Some((f64::from_bits(bits) + dt).to_bits()),
        );
    }

    /// Jump the shared timeline to `t` seconds (must not move backwards).
    pub fn set(&self, t: f64) {
        let _ = self.t.fetch_update(
            Ordering::Relaxed,
            Ordering::Relaxed,
            |bits| {
                debug_assert!(t >= f64::from_bits(bits),
                              "virtual time must not move backwards");
                Some(t.to_bits())
            },
        );
    }
}

impl Default for SimClock {
    fn default() -> Self {
        SimClock::new()
    }
}

impl Clock for SimClock {
    fn now(&self) -> f64 {
        f64::from_bits(self.t.load(Ordering::Relaxed))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn monotonic_clock_moves_forward() {
        let c = MonotonicClock::new();
        let a = c.now();
        let b = c.now();
        assert!(b >= a);
        assert!(a >= 0.0);
    }

    #[test]
    fn sim_clock_clones_share_a_timeline() {
        let c = SimClock::new();
        let view = c.clone();
        assert_eq!(c.now(), 0.0);
        c.advance(1.5);
        assert_eq!(view.now(), 1.5);
        view.advance(0.25);
        assert_eq!(c.now(), 1.75);
        c.set(3.0);
        assert_eq!(view.now(), 3.0);
    }

    #[test]
    fn sim_clock_is_exact() {
        // Virtual time is plain f64 arithmetic — no rounding surprises a
        // latency assertion could trip over.
        let c = SimClock::new();
        for _ in 0..1000 {
            c.advance(0.5);
        }
        assert_eq!(c.now(), 500.0);
    }

    #[test]
    fn boxed_dyn_clock_usable() {
        let sim = SimClock::new();
        let boxed: Box<dyn Clock> = Box::new(sim.clone());
        sim.advance(2.0);
        assert_eq!(boxed.now(), 2.0);
    }
}
