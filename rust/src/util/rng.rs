//! PCG64-family PRNG + sampling primitives for the serving hot path.
//!
//! Deterministic, seedable, and fast; `rand` is unavailable offline. The
//! generator is PCG-XSH-RR-64/32 extended to 64-bit output by concatenating
//! two draws, which is ample for sampling categorical distributions.

/// PCG-XSH-RR 64/32 with 64-bit convenience output.
#[derive(Clone, Debug)]
pub struct Pcg {
    state: u64,
    inc: u64,
}

const PCG_MULT: u64 = 6364136223846793005;

impl Pcg {
    pub fn new(seed: u64) -> Self {
        Self::with_stream(seed, 0xda3e39cb94b95bdb)
    }

    pub fn with_stream(seed: u64, stream: u64) -> Self {
        let mut rng = Pcg { state: 0, inc: (stream << 1) | 1 };
        rng.next_u32();
        rng.state = rng.state.wrapping_add(seed);
        rng.next_u32();
        rng
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in [0, 1) with 53 bits of entropy.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n) (Lemire-style rejection-free for our use).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.f64() * n as f64) as usize % n
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            xs.swap(i, self.below(i + 1));
        }
    }

    /// Uniform random permutation of 0..n as i32 (a generation ordering σ).
    pub fn permutation(&mut self, n: usize) -> Vec<i32> {
        let mut p: Vec<i32> = (0..n as i32).collect();
        self.shuffle(&mut p);
        p
    }

    /// Sample an index from an (unnormalized, non-negative) weight vector.
    pub fn categorical(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        debug_assert!(total > 0.0, "categorical needs positive mass");
        let mut u = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            u -= w;
            if u <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Sample from f32 probabilities (the engine's softmax output).
    pub fn categorical_f32(&mut self, probs: &[f32]) -> usize {
        let total: f64 = probs.iter().map(|&p| p as f64).sum();
        let mut u = self.f64() * total;
        for (i, &p) in probs.iter().enumerate() {
            u -= p as f64;
            if u <= 0.0 {
                return i;
            }
        }
        probs.len() - 1
    }

    /// Split off an independent stream (for per-request RNGs).
    pub fn split(&mut self) -> Pcg {
        Pcg::with_stream(self.next_u64(), self.next_u64())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Pcg::new(42);
        let mut b = Pcg::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Pcg::new(1);
        let mut b = Pcg::new(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn f64_in_unit_interval_and_roughly_uniform() {
        let mut rng = Pcg::new(7);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let u = rng.f64();
            assert!((0.0..1.0).contains(&u));
            sum += u;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn permutation_is_a_permutation() {
        let mut rng = Pcg::new(3);
        for n in [1usize, 2, 7, 64] {
            let mut p = rng.permutation(n);
            p.sort();
            assert_eq!(p, (0..n as i32).collect::<Vec<_>>());
        }
    }

    #[test]
    fn permutation_is_uniformish() {
        // Position of element 0 should be uniform over n slots.
        let mut rng = Pcg::new(11);
        let n = 8;
        let trials = 16_000;
        let mut counts = vec![0usize; n];
        for _ in 0..trials {
            let p = rng.permutation(n);
            counts[p.iter().position(|&x| x == 0).unwrap()] += 1;
        }
        let expect = trials as f64 / n as f64;
        for c in counts {
            assert!((c as f64 - expect).abs() < 6.0 * expect.sqrt());
        }
    }

    #[test]
    fn categorical_matches_weights() {
        let mut rng = Pcg::new(5);
        let w = [1.0, 3.0, 6.0];
        let trials = 30_000;
        let mut counts = [0usize; 3];
        for _ in 0..trials {
            counts[rng.categorical(&w)] += 1;
        }
        for (i, c) in counts.iter().enumerate() {
            let p = w[i] / 10.0;
            let expect = trials as f64 * p;
            assert!(
                (*c as f64 - expect).abs() < 6.0 * (expect * (1.0 - p)).sqrt(),
                "bucket {i}: {c} vs {expect}"
            );
        }
    }

    #[test]
    fn split_streams_are_independent_seeds() {
        let mut root = Pcg::new(9);
        let mut a = root.split();
        let mut b = root.split();
        assert_ne!(a.next_u64(), b.next_u64());
    }
}
