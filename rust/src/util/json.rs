//! Minimal JSON codec (serde is unavailable offline).
//!
//! Supports the full JSON grammar needed by this repo: manifests, data
//! generator specs (large float matrices), the HTTP API, and metrics
//! snapshots. Parsing is a single-pass recursive descent over bytes;
//! serialization is allocation-light. Not a general-purpose library — no
//! \uXXXX surrogate pairs beyond the BMP, numbers parse via `str::parse`.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    // -- typed accessors -------------------------------------------------
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Flatten an array of numbers to f64 (spec matrices).
    pub fn as_f64_vec(&self) -> Option<Vec<f64>> {
        self.as_arr()?.iter().map(|v| v.as_f64()).collect()
    }

    // -- builders ---------------------------------------------------------
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr<I: IntoIterator<Item = Json>>(items: I) -> Json {
        Json::Arr(items.into_iter().collect())
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    pub fn num(n: f64) -> Json {
        Json::Num(n)
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.is_finite() {
                    if *n == n.trunc() && n.abs() < 1e15 {
                        write!(f, "{}", *n as i64)
                    } else {
                        write!(f, "{n}")
                    }
                } else {
                    write!(f, "null") // JSON has no inf/nan
                }
            }
            Json::Str(s) => write_escaped(f, s),
            Json::Arr(v) => {
                write!(f, "[")?;
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{x}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write_escaped(f, k)?;
                    write!(f, ":{v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    write!(f, "\"")?;
    for c in s.chars() {
        match c {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\r' => write!(f, "\\r")?,
            '\t' => write!(f, "\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    write!(f, "\"")
}

#[derive(Debug)]
pub struct JsonError {
    pub msg: String,
    pub pos: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { msg: msg.to_string(), pos: self.i }
    }

    fn skip_ws(&mut self) {
        while self.i < self.b.len()
            && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.err("invalid literal"))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek().ok_or_else(|| self.err("unexpected end"))? {
            b'n' => self.lit("null", Json::Null),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'"' => self.string().map(Json::Str),
            b'[' => self.array(),
            b'{' => self.object(),
            _ => self.number(),
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.skip_ws();
            out.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(out));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut out = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            self.skip_ws();
            out.insert(k, self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(out));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            let c = *self.b.get(self.i).ok_or_else(|| self.err("eof in string"))?;
            self.i += 1;
            match c {
                b'"' => return Ok(out),
                b'\\' => {
                    let e = *self.b.get(self.i).ok_or_else(|| self.err("eof"))?;
                    self.i += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b't' => out.push('\t'),
                        b'r' => out.push('\r'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                return Err(self.err("bad \\u"));
                            }
                            let hex =
                                std::str::from_utf8(&self.b[self.i..self.i + 4])
                                    .map_err(|_| self.err("bad \\u"))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u"))?;
                            self.i += 4;
                            out.push(
                                char::from_u32(code).unwrap_or('\u{fffd}'),
                            );
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                }
                c if c < 0x80 => out.push(c as char),
                _ => {
                    // Re-decode multi-byte UTF-8.
                    let start = self.i - 1;
                    let len = utf8_len(c);
                    let end = (start + len).min(self.b.len());
                    let s = std::str::from_utf8(&self.b[start..end])
                        .map_err(|_| self.err("bad utf8"))?;
                    out.push_str(s);
                    self.i = end;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i],
                b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i])
            .map_err(|_| self.err("bad number"))?;
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

fn utf8_len(first: u8) -> usize {
    if first >= 0xf0 {
        4
    } else if first >= 0xe0 {
        3
    } else {
        2
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        for s in ["null", "true", "false", "1", "-2.5", "\"hi\""] {
            let v = Json::parse(s).unwrap();
            assert_eq!(Json::parse(&v.to_string()).unwrap(), v);
        }
    }

    #[test]
    fn parses_nested() {
        let v = Json::parse(
            r#"{"a":[1,2,{"b":null}],"c":"x\ny","d":1e-3}"#,
        )
        .unwrap();
        assert_eq!(v.get("d").unwrap().as_f64().unwrap(), 1e-3);
        assert_eq!(v.get("c").unwrap().as_str().unwrap(), "x\ny");
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
    }

    #[test]
    fn rejects_garbage() {
        for s in ["", "{", "[1,", "{\"a\"}", "tru", "1 2"] {
            assert!(Json::parse(s).is_err(), "{s}");
        }
    }

    #[test]
    fn unicode_escapes_and_utf8() {
        let v = Json::parse("\"\\u00e9 caf\u{00e9}\"").unwrap();
        assert_eq!(v.as_str().unwrap(), "\u{e9} caf\u{e9}");
    }

    #[test]
    fn float_matrix_roundtrip() {
        let m = Json::arr((0..4).map(|i| {
            Json::arr((0..4).map(move |j| Json::num((i * j) as f64 / 7.0)))
        }));
        let s = m.to_string();
        let back = Json::parse(&s).unwrap();
        for i in 0..4 {
            let row = back.as_arr().unwrap()[i].as_f64_vec().unwrap();
            for j in 0..4 {
                assert!((row[j] - (i * j) as f64 / 7.0).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn display_escapes_control_chars() {
        let s = Json::str("a\"b\\c\nd\u{1}").to_string();
        assert_eq!(s, "\"a\\\"b\\\\c\\nd\\u0001\"");
    }
}
