//! Poisoned-lock recovery, standardized.
//!
//! Every mutex in this codebase guards state that stays valid across a
//! panic on another thread: metrics counters, the router's liveness and
//! migration-board vectors, the step pool's completion counters, the
//! evacuation records' reply slots. All of them recover from poisoning
//! by taking the guard anyway (`PoisonError::into_inner`) — a panicked
//! peer must degrade one request, never wedge the fleet. Before this
//! module each site hand-rolled the recovery (`unwrap_or_else`, a
//! `match` with `clear_poison`, a plain `unwrap`); now there is exactly
//! one idiom, and the `lock-recovery` lint rule (rust/src/lint/
//! concurrency.rs) bans raw `.lock()` everywhere else so new sites
//! cannot drift.
//!
//! The helpers also clear the poison flag: recovery here means
//! *recovered* — later acquirers take the fast `Ok` path instead of
//! re-entering the error arm on every lock for the rest of the process.
//! Sites that want to observe recovery (the router counts board
//! poisonings into `/healthz`) use [`lock_recover_or`], whose hook runs
//! exactly once per poisoning because the flag is cleared under the
//! same acquisition.

use std::sync::{Condvar, Mutex, MutexGuard};

/// Acquire `m`, recovering (and clearing) poison silently.
///
/// This file is the one place allowed to call raw `.lock()`; everything
/// else goes through here (enforced by the `lock-recovery` lint rule).
pub fn lock_recover<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    match m.lock() {
        Ok(g) => g,
        Err(e) => {
            m.clear_poison();
            e.into_inner()
        }
    }
}

/// Acquire `m`; on poison, clear the flag, run `on_poison` (observe the
/// recovery — bump a counter, log), and return the guard anyway.
pub fn lock_recover_or<T>(
    m: &Mutex<T>,
    on_poison: impl FnOnce(),
) -> MutexGuard<'_, T> {
    match m.lock() {
        Ok(g) => g,
        Err(e) => {
            m.clear_poison();
            on_poison();
            e.into_inner()
        }
    }
}

/// `Condvar::wait` with the same recovery policy: a wait that observes
/// poison re-takes the guard instead of panicking the waiter.
pub fn wait_recover<'a, T>(
    cv: &Condvar,
    g: MutexGuard<'a, T>,
) -> MutexGuard<'a, T> {
    match cv.wait(g) {
        Ok(g) => g,
        Err(e) => e.into_inner(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::{Arc, Mutex};

    fn poison(m: &Arc<Mutex<u32>>) {
        let m2 = Arc::clone(m);
        let _ = std::thread::spawn(move || {
            let _g = m2.lock().unwrap();
            panic!("poison the lock");
        })
        .join();
        assert!(m.is_poisoned());
    }

    #[test]
    fn lock_recover_takes_and_clears_poison() {
        let m = Arc::new(Mutex::new(7u32));
        poison(&m);
        assert_eq!(*lock_recover(&m), 7);
        // Recovery cleared the flag: the next lock is a clean Ok.
        assert!(!m.is_poisoned());
        assert!(m.lock().is_ok());
    }

    #[test]
    fn lock_recover_or_fires_hook_exactly_once_per_poisoning() {
        let m = Arc::new(Mutex::new(0u32));
        poison(&m);
        let mut hits = 0;
        *lock_recover_or(&m, || hits += 1) += 1;
        // Flag cleared under the first recovery: no second hook fire.
        *lock_recover_or(&m, || hits += 1) += 1;
        assert_eq!(hits, 1);
        assert_eq!(*lock_recover(&m), 2);
    }

    #[test]
    fn wait_recover_returns_the_guard() {
        use std::sync::Condvar;
        let pair = Arc::new((Mutex::new(false), Condvar::new()));
        let p2 = Arc::clone(&pair);
        let h = std::thread::spawn(move || {
            let (m, cv) = &*p2;
            *lock_recover(m) = true;
            cv.notify_all();
        });
        let (m, cv) = &*pair;
        let mut g = lock_recover(m);
        while !*g {
            g = wait_recover(cv, g);
        }
        h.join().unwrap();
        assert!(*g);
    }
}
