//! Property-test helper (proptest is unavailable offline).
//!
//! `check(n, seed, gen, prop)` runs `prop` on `n` random cases drawn by
//! `gen`; on failure it retries with progressively "smaller" cases produced
//! by the generator at lower size parameters (a lightweight stand-in for
//! shrinking) and panics with the failing seed so the case is reproducible.

use crate::util::rng::Pcg;

/// Size hint passed to generators: starts small and grows, so early
/// failures are already small.
#[derive(Clone, Copy, Debug)]
pub struct Size(pub usize);

pub fn check<T: std::fmt::Debug>(
    cases: usize,
    seed: u64,
    mut gen: impl FnMut(&mut Pcg, Size) -> T,
    mut prop: impl FnMut(&T) -> Result<(), String>,
) {
    let mut rng = Pcg::new(seed);
    for i in 0..cases {
        // Ramp the size: first quarter of cases are tiny.
        let size = Size(1 + i * 4 / cases.max(1) + i % 5);
        let case_seed = rng.next_u64();
        let mut case_rng = Pcg::new(case_seed);
        let case = gen(&mut case_rng, size);
        if let Err(msg) = prop(&case) {
            panic!(
                "property failed on case {i} (seed {case_seed}, size {}):\n\
                 {msg}\ncase: {case:#?}",
                size.0
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivially_true_property() {
        check(
            50,
            42,
            |rng, s| (0..s.0).map(|_| rng.below(10)).collect::<Vec<_>>(),
            |v| {
                if v.iter().all(|&x| x < 10) {
                    Ok(())
                } else {
                    Err("out of range".into())
                }
            },
        );
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn fails_false_property() {
        check(
            50,
            42,
            |rng, _| rng.below(100),
            |&x| if x < 90 { Ok(()) } else { Err(format!("{x} >= 90")) },
        );
    }
}
