//! Property-test helper (proptest is unavailable offline).
//!
//! `check(n, seed, gen, prop)` runs `prop` on `n` random cases drawn by
//! `gen`; on failure it retries with progressively "smaller" cases produced
//! by the generator at lower size parameters (a lightweight stand-in for
//! shrinking) and panics with the failing seed so the case is reproducible.

use crate::util::rng::Pcg;

/// Size hint passed to generators: starts small and grows, so early
/// failures are already small.
#[derive(Clone, Copy, Debug)]
pub struct Size(pub usize);

pub fn check<T: std::fmt::Debug>(
    cases: usize,
    seed: u64,
    mut gen: impl FnMut(&mut Pcg, Size) -> T,
    mut prop: impl FnMut(&T) -> Result<(), String>,
) {
    let mut rng = Pcg::new(seed);
    for i in 0..cases {
        // Ramp the size: first quarter of cases are tiny.
        let size = Size(1 + i * 4 / cases.max(1) + i % 5);
        let case_seed = rng.next_u64();
        let mut case_rng = Pcg::new(case_seed);
        let case = gen(&mut case_rng, size);
        if let Err(msg) = prop(&case) {
            panic!(
                "property failed on case {i} (seed {case_seed}, size {}):\n\
                 {msg}\ncase: {case:#?}",
                size.0
            );
        }
    }
}

/// Pearson chi-square statistic of observed `counts` against expected
/// `probs` (which must sum to ~1; zero-probability bins are skipped).
/// Used by the sampling-kernel equivalence tests: empirical draw counts
/// from the logits-domain kernels are tested against the old
/// materialized-softmax distribution.
pub fn chi_square(counts: &[usize], probs: &[f64]) -> f64 {
    debug_assert_eq!(counts.len(), probs.len());
    let n: usize = counts.iter().sum();
    counts
        .iter()
        .zip(probs)
        .filter(|&(_, &p)| p > 0.0)
        .map(|(&c, &p)| {
            let e = p * n as f64;
            (c as f64 - e) * (c as f64 - e) / e
        })
        .sum()
}

/// Approximate 99.99% chi-square critical value for `df` degrees of
/// freedom (Wilson–Hilferty). Tests are seeded and deterministic, so the
/// generous significance level trades a sliver of power for a negligible
/// chance of a correct implementation ever tripping the bound; a wrong
/// sampler overshoots it by an order of magnitude.
pub fn chi_square_crit(df: usize) -> f64 {
    let df = df.max(1) as f64;
    let z = 3.719; // Phi^-1(0.9999)
    let a = 2.0 / (9.0 * df);
    df * (1.0 - a + z * a.sqrt()).powi(3)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chi_square_small_for_perfect_fit() {
        // Counts exactly proportional to probs -> statistic 0.
        let probs = [0.5, 0.3, 0.2];
        let counts = [500usize, 300, 200];
        assert!(chi_square(&counts, &probs) < 1e-9);
        // A grossly wrong distribution blows up far past the critical
        // value.
        let bad = [200usize, 300, 500];
        assert!(chi_square(&bad, &probs) > chi_square_crit(2) * 5.0);
    }

    #[test]
    fn chi_square_crit_tracks_df() {
        // Roughly df + 4*sqrt(2 df): grows monotonically and stays above
        // the mean of the distribution.
        let mut prev = 0.0;
        for df in [1usize, 5, 26, 100, 999] {
            let c = chi_square_crit(df);
            assert!(c > df as f64, "crit {c} <= df {df}");
            assert!(c > prev);
            prev = c;
        }
        // Sanity anchors (within a few percent of table values).
        assert!((chi_square_crit(26) - 61.9).abs() < 3.0);
        assert!((chi_square_crit(999) - 1173.0).abs() < 25.0);
    }

    /// Power demonstration: an actual sampler drawing from a
    /// deliberately skewed distribution must overshoot `chi_square_crit`
    /// by a wide margin when tested against the distribution it was
    /// *supposed* to follow. The equivalence tests elsewhere only ever
    /// pass-on-match; this pins that the statistic would actually catch
    /// a wrong sampler (expected chi2 here is ~n·Σ(q-p)²/p ≈ 1260,
    /// ~70x the 99.99% critical value for df=2).
    #[test]
    fn chi_square_rejects_deliberately_skewed_sampler() {
        let probs = [0.5, 0.3, 0.2]; // what the sampler should emit
        let skewed = [0.56, 0.3, 0.14]; // what it actually emits
        let mut rng = Pcg::new(0x5ca1ed);
        let n = 50_000;
        let mut counts = [0usize; 3];
        for _ in 0..n {
            counts[rng.categorical(&skewed)] += 1;
        }
        let chi2 = chi_square(&counts, &probs);
        let crit = chi_square_crit(2);
        assert!(
            chi2 > 5.0 * crit,
            "skewed sampler must be rejected decisively: chi2 {chi2:.1} \
             vs crit {crit:.1}"
        );
        // And the same draws pass against their true distribution, so
        // the rejection above is the skew, not the harness.
        let chi2_true = chi_square(&counts, &skewed);
        assert!(chi2_true < crit, "{chi2_true:.1} >= {crit:.1}");
    }

    #[test]
    fn passes_trivially_true_property() {
        check(
            50,
            42,
            |rng, s| (0..s.0).map(|_| rng.below(10)).collect::<Vec<_>>(),
            |v| {
                if v.iter().all(|&x| x < 10) {
                    Ok(())
                } else {
                    Err("out of range".into())
                }
            },
        );
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn fails_false_property() {
        check(
            50,
            42,
            |rng, _| rng.below(100),
            |&x| if x < 90 { Ok(()) } else { Err(format!("{x} >= 90")) },
        );
    }
}
