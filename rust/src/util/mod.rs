//! From-scratch substrates (DESIGN.md §2): the offline vendor set contains
//! only the `xla` crate's closure, so every auxiliary dependency a serving
//! framework normally pulls in is implemented here, each with its own tests.

pub mod args;
pub mod bench;
pub mod benchdiff;
pub mod json;
pub mod metrics;
pub mod ptest;
pub mod rng;
pub mod simclock;
pub mod sync;
