//! Bench-lite: a micro-benchmark harness (criterion is unavailable
//! offline). `cargo bench` targets set `harness = false` and drive this.
//!
//! Measures wall-clock over timed iterations after a warmup, reports
//! mean / p50 / p95 / throughput, and prints aligned table rows so the
//! paper-table harnesses in `examples/` and `rust/benches/` share one
//! formatter.

use std::time::Instant;

#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_s: f64,
    pub p50_s: f64,
    pub p95_s: f64,
    pub min_s: f64,
}

impl BenchResult {
    pub fn throughput(&self, items_per_iter: f64) -> f64 {
        items_per_iter / self.mean_s
    }
}

/// Run `f` repeatedly: `warmup` untimed iterations, then timed iterations
/// until `min_time_s` elapses (at least `min_iters`).
pub fn bench<F: FnMut()>(name: &str, warmup: usize, min_iters: usize,
                         min_time_s: f64, mut f: F) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::new();
    let start = Instant::now();
    while samples.len() < min_iters
        || start.elapsed().as_secs_f64() < min_time_s
    {
        let t = Instant::now();
        f();
        samples.push(t.elapsed().as_secs_f64());
        if samples.len() > 100_000 {
            break;
        }
    }
    summarize(name, samples)
}

pub fn summarize(name: &str, mut samples: Vec<f64>) -> BenchResult {
    assert!(!samples.is_empty());
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = samples.len();
    let mean = samples.iter().sum::<f64>() / n as f64;
    BenchResult {
        name: name.to_string(),
        iters: n,
        mean_s: mean,
        p50_s: samples[n / 2],
        p95_s: samples[(n as f64 * 0.95) as usize % n],
        min_s: samples[0],
    }
}

pub fn fmt_duration(s: f64) -> String {
    if s < 1e-6 {
        format!("{:.1}ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:.1}us", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2}ms", s * 1e3)
    } else {
        format!("{:.2}s", s)
    }
}

pub fn print_header(title: &str) {
    println!("\n== {title} ==");
    println!("{:<40} {:>8} {:>10} {:>10} {:>10}", "bench", "iters", "mean",
             "p50", "p95");
}

pub fn print_result(r: &BenchResult) {
    println!(
        "{:<40} {:>8} {:>10} {:>10} {:>10}",
        r.name,
        r.iters,
        fmt_duration(r.mean_s),
        fmt_duration(r.p50_s),
        fmt_duration(r.p95_s)
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let r = bench("spin", 2, 5, 0.0, || {
            std::hint::black_box((0..1000).sum::<u64>());
        });
        assert!(r.iters >= 5);
        assert!(r.mean_s > 0.0);
        assert!(r.p50_s >= r.min_s);
    }

    #[test]
    fn fmt_scales() {
        assert!(fmt_duration(3e-9).ends_with("ns"));
        assert!(fmt_duration(3e-6).ends_with("us"));
        assert!(fmt_duration(3e-3).ends_with("ms"));
        assert!(fmt_duration(3.0).ends_with('s'));
    }
}
