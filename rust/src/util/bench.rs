//! Bench-lite: a micro-benchmark harness (criterion is unavailable
//! offline). `cargo bench` targets set `harness = false` and drive this.
//!
//! Measures wall-clock over timed iterations after a warmup, reports
//! mean / p50 / p95 / throughput, and prints aligned table rows so the
//! paper-table harnesses in `examples/` and `rust/benches/` share one
//! formatter.
//!
//! Two serving-repo additions:
//! * [`write_json`] emits `BENCH_<target>.json` (name, iters,
//!   mean/p50/p95/min, throughput, plus free-form scalar extras) so CI
//!   can archive per-PR perf artifacts and the repo accumulates a
//!   machine-readable perf trajectory;
//! * [`smoke`] (`BENCH_SMOKE=1`) caps every [`bench`] call at one timed
//!   iteration so CI can exercise bench targets without paying full
//!   measurement time.

use std::io;
use std::path::PathBuf;
use std::time::Instant;

use crate::util::json::Json;

#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_s: f64,
    pub p50_s: f64,
    pub p95_s: f64,
    pub min_s: f64,
    /// Work items per iteration (0 = unset); gives `write_json` a
    /// throughput figure without re-deriving it at every call site.
    pub items_per_iter: f64,
}

impl BenchResult {
    pub fn throughput(&self, items_per_iter: f64) -> f64 {
        items_per_iter / self.mean_s
    }

    /// Attach an items-per-iteration count (for JSON throughput).
    pub fn with_items(mut self, items_per_iter: f64) -> BenchResult {
        self.items_per_iter = items_per_iter;
        self
    }

    /// A single-shot measurement (benches that run a scenario once).
    pub fn single(name: &str, wall_s: f64) -> BenchResult {
        BenchResult {
            name: name.to_string(),
            iters: 1,
            mean_s: wall_s,
            p50_s: wall_s,
            p95_s: wall_s,
            min_s: wall_s,
            items_per_iter: 0.0,
        }
    }
}

/// True when `BENCH_SMOKE` is set (and not "0"): bench targets should run
/// one timed iteration per measurement — enough to exercise the code and
/// emit JSON artifacts, not enough to trust the numbers.
pub fn smoke() -> bool {
    std::env::var("BENCH_SMOKE").map(|v| v != "0").unwrap_or(false)
}

/// Run `f` repeatedly: `warmup` untimed iterations, then timed iterations
/// until `min_time_s` elapses (at least `min_iters`). Under [`smoke`],
/// warmup and iteration counts collapse to 1.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, min_iters: usize,
                         min_time_s: f64, mut f: F) -> BenchResult {
    let (warmup, min_iters, min_time_s) = if smoke() {
        (warmup.min(1), 1, 0.0)
    } else {
        (warmup, min_iters, min_time_s)
    };
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::new();
    let start = Instant::now();
    while samples.len() < min_iters
        || start.elapsed().as_secs_f64() < min_time_s
    {
        let t = Instant::now();
        f();
        samples.push(t.elapsed().as_secs_f64());
        if samples.len() > 100_000 {
            break;
        }
    }
    summarize(name, samples)
}

pub fn summarize(name: &str, mut samples: Vec<f64>) -> BenchResult {
    assert!(!samples.is_empty());
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = samples.len();
    let mean = samples.iter().sum::<f64>() / n as f64;
    BenchResult {
        name: name.to_string(),
        iters: n,
        mean_s: mean,
        p50_s: samples[n / 2],
        p95_s: samples[(n as f64 * 0.95) as usize % n],
        min_s: samples[0],
        items_per_iter: 0.0,
    }
}

/// Write `BENCH_<target>.json` in the working directory (the workspace
/// root under `cargo bench`): per-result stats plus free-form scalar
/// `extra` pairs (row counts, speedup ratios, ...). CI uploads these as
/// per-PR artifacts so perf regressions are visible in review.
pub fn write_json(target: &str, results: &[BenchResult],
                  extra: &[(&str, f64)]) -> io::Result<PathBuf> {
    let path = PathBuf::from(format!("BENCH_{target}.json"));
    let mut fields = vec![
        ("target", Json::str(target)),
        // Smoke runs (1 iteration) are for artifact plumbing, not for
        // trend analysis — mark them so downstream diffing can skip them.
        ("smoke", Json::Bool(smoke())),
        (
            "results",
            Json::arr(results.iter().map(|r| {
                let mut obj = vec![
                    ("name", Json::str(r.name.clone())),
                    ("iters", Json::num(r.iters as f64)),
                    ("mean_s", Json::num(r.mean_s)),
                    ("p50_s", Json::num(r.p50_s)),
                    ("p95_s", Json::num(r.p95_s)),
                    ("min_s", Json::num(r.min_s)),
                ];
                if r.items_per_iter > 0.0 && r.mean_s > 0.0 {
                    obj.push((
                        "throughput_per_s",
                        Json::num(r.throughput(r.items_per_iter)),
                    ));
                }
                Json::obj(obj)
            })),
        ),
    ];
    for &(k, v) in extra {
        fields.push((k, Json::num(v)));
    }
    std::fs::write(&path, Json::obj(fields).to_string())?;
    Ok(path)
}

pub fn fmt_duration(s: f64) -> String {
    if s < 1e-6 {
        format!("{:.1}ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:.1}us", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2}ms", s * 1e3)
    } else {
        format!("{:.2}s", s)
    }
}

pub fn print_header(title: &str) {
    println!("\n== {title} ==");
    println!("{:<40} {:>8} {:>10} {:>10} {:>10}", "bench", "iters", "mean",
             "p50", "p95");
}

pub fn print_result(r: &BenchResult) {
    println!(
        "{:<40} {:>8} {:>10} {:>10} {:>10}",
        r.name,
        r.iters,
        fmt_duration(r.mean_s),
        fmt_duration(r.p50_s),
        fmt_duration(r.p95_s)
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let r = bench("spin", 2, 5, 0.0, || {
            std::hint::black_box((0..1000).sum::<u64>());
        });
        // min_iters must be honored on normal runs; under BENCH_SMOKE the
        // harness intentionally collapses to one iteration.
        if smoke() {
            assert!(r.iters >= 1);
        } else {
            assert!(r.iters >= 5);
        }
        assert!(r.mean_s > 0.0);
        assert!(r.p50_s >= r.min_s);
    }

    #[test]
    fn fmt_scales() {
        assert!(fmt_duration(3e-9).ends_with("ns"));
        assert!(fmt_duration(3e-6).ends_with("us"));
        assert!(fmt_duration(3e-3).ends_with("ms"));
        assert!(fmt_duration(3.0).ends_with('s'));
    }

    #[test]
    fn json_roundtrips_results_and_extras() {
        let r = summarize("fast_path", vec![0.5, 1.0, 1.5]).with_items(8.0);
        let path =
            write_json("unit_test", &[r], &[("speedup", 6.5)]).unwrap();
        let body = std::fs::read_to_string(&path).unwrap();
        let _ = std::fs::remove_file(&path);
        let v = Json::parse(&body).unwrap();
        assert_eq!(v.get("target").unwrap().as_str().unwrap(), "unit_test");
        assert_eq!(v.get("speedup").unwrap().as_f64().unwrap(), 6.5);
        let results = v.get("results").unwrap().as_arr().unwrap();
        assert_eq!(results.len(), 1);
        let r0 = &results[0];
        assert_eq!(r0.get("name").unwrap().as_str().unwrap(), "fast_path");
        assert_eq!(r0.get("iters").unwrap().as_usize().unwrap(), 3);
        assert!((r0.get("mean_s").unwrap().as_f64().unwrap() - 1.0).abs()
                < 1e-12);
        assert!((r0.get("throughput_per_s").unwrap().as_f64().unwrap()
                 - 8.0)
            .abs()
            < 1e-9);
    }
}
