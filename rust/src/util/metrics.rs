//! Serving metrics: counters + streaming latency histograms.
//!
//! Log-bucketed histograms (~4% relative resolution) cover nanoseconds to
//! minutes without pre-configuring bounds; quantile queries interpolate
//! within a bucket. A global-free `Registry` is shared behind an `Arc` by
//! the coordinator and exported as JSON at `GET /metrics`.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Instant;

use crate::util::json::Json;
use crate::util::sync::lock_recover;

/// Monotonic counter.
#[derive(Default)]
pub struct Counter(AtomicU64);

impl Counter {
    pub fn inc(&self) {
        self.add(1)
    }

    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }

    /// Overwrite the value — for the few gauge-like exports (e.g.
    /// `breaker_state`) that report a current level, not a total.
    pub fn set(&self, v: u64) {
        self.0.store(v, Ordering::Relaxed);
    }
}

const BUCKETS_PER_OCTAVE: usize = 16;
const N_BUCKETS: usize = 64 * BUCKETS_PER_OCTAVE;

/// Log-scale histogram over positive f64 values (e.g. seconds).
pub struct Histogram {
    counts: Mutex<Vec<u64>>,
    sum: Mutex<f64>,
    n: AtomicU64,
}

impl Default for Histogram {
    fn default() -> Self {
        Histogram {
            counts: Mutex::new(vec![0; N_BUCKETS]),
            sum: Mutex::new(0.0),
            n: AtomicU64::new(0),
        }
    }
}

fn bucket_index(v: f64) -> usize {
    // Map value ~1e-9..~1e10 onto log buckets.
    let lv = v.max(1e-9).log2() + 30.0; // 1e-9 -> ~0
    ((lv * BUCKETS_PER_OCTAVE as f64) as usize).min(N_BUCKETS - 1)
}

fn bucket_value(i: usize) -> f64 {
    2f64.powf((i as f64 + 0.5) / BUCKETS_PER_OCTAVE as f64 - 30.0)
}

impl Histogram {
    pub fn observe(&self, v: f64) {
        let mut counts = lock_recover(&self.counts);
        counts[bucket_index(v)] += 1;
        // Lock order: `counts` before `sum` (observe is the only place
        // both are held; every other method takes one at a time).
        *lock_recover(&self.sum) += v;
        self.n.fetch_add(1, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.n.load(Ordering::Relaxed)
    }

    pub fn mean(&self) -> f64 {
        let n = self.count();
        if n == 0 {
            0.0
        } else {
            *lock_recover(&self.sum) / n as f64
        }
    }

    pub fn quantile(&self, q: f64) -> f64 {
        let counts = lock_recover(&self.counts);
        let total: u64 = counts.iter().sum();
        if total == 0 {
            return 0.0;
        }
        let target = (q.clamp(0.0, 1.0) * total as f64).ceil().max(1.0) as u64;
        let mut acc = 0;
        for (i, c) in counts.iter().enumerate() {
            acc += c;
            if acc >= target {
                return bucket_value(i);
            }
        }
        bucket_value(N_BUCKETS - 1)
    }

    pub fn snapshot(&self) -> Json {
        Json::obj(vec![
            ("count", Json::num(self.count() as f64)),
            ("mean", Json::num(self.mean())),
            ("p50", Json::num(self.quantile(0.5))),
            ("p95", Json::num(self.quantile(0.95))),
            ("p99", Json::num(self.quantile(0.99))),
        ])
    }
}

/// Named metric registry exported at /metrics.
#[derive(Default)]
pub struct Registry {
    counters: Mutex<BTreeMap<String, std::sync::Arc<Counter>>>,
    histograms: Mutex<BTreeMap<String, std::sync::Arc<Histogram>>>,
}

impl Registry {
    pub fn counter(&self, name: &str) -> std::sync::Arc<Counter> {
        lock_recover(&self.counters)
            .entry(name.to_string())
            .or_default()
            .clone()
    }

    pub fn histogram(&self, name: &str) -> std::sync::Arc<Histogram> {
        lock_recover(&self.histograms)
            .entry(name.to_string())
            .or_default()
            .clone()
    }

    pub fn snapshot(&self) -> Json {
        // Clone the Arc'd values out under each registry guard, then
        // serialize with no guard held: `Histogram::snapshot` takes the
        // histogram's own locks, so reading it under a registry guard
        // would nest registry -> histogram lock acquisitions.
        let counters: Vec<(String, std::sync::Arc<Counter>)> =
            lock_recover(&self.counters)
                .iter()
                .map(|(k, v)| (k.clone(), v.clone()))
                .collect();
        let hists: Vec<(String, std::sync::Arc<Histogram>)> =
            lock_recover(&self.histograms)
                .iter()
                .map(|(k, v)| (k.clone(), v.clone()))
                .collect();
        let counters = Json::Obj(
            counters
                .into_iter()
                .map(|(k, v)| (k, Json::num(v.get() as f64)))
                .collect(),
        );
        let hists = Json::Obj(
            hists
                .into_iter()
                .map(|(k, v)| (k, v.snapshot()))
                .collect(),
        );
        Json::obj(vec![("counters", counters), ("histograms", hists)])
    }
}

/// RAII timer that records elapsed seconds into a histogram on drop.
pub struct Timer {
    start: Instant,
    hist: std::sync::Arc<Histogram>,
}

impl Timer {
    pub fn new(hist: std::sync::Arc<Histogram>) -> Timer {
        // lint: allow(clock-discipline) — operator-facing latency
        // histograms report real wall time; no scheduling decision
        // reads them.
        Timer { start: Instant::now(), hist }
    }
}

impl Drop for Timer {
    fn drop(&mut self) {
        self.hist.observe(self.start.elapsed().as_secs_f64());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_adds() {
        let c = Counter::default();
        c.inc();
        c.add(4);
        assert_eq!(c.get(), 5);
    }

    #[test]
    fn histogram_quantiles_bracket_values() {
        let h = Histogram::default();
        for i in 1..=1000 {
            h.observe(i as f64 / 1000.0); // 0.001..1.0 uniform
        }
        let p50 = h.quantile(0.5);
        assert!((0.4..0.62).contains(&p50), "p50={p50}");
        let p99 = h.quantile(0.99);
        assert!((0.9..1.1).contains(&p99), "p99={p99}");
        assert!((h.mean() - 0.5).abs() < 0.01);
    }

    #[test]
    fn histogram_relative_resolution() {
        let h = Histogram::default();
        h.observe(0.123);
        let q = h.quantile(0.5);
        assert!((q / 0.123 - 1.0).abs() < 0.05, "q={q}");
    }

    #[test]
    fn registry_snapshot_is_json() {
        let r = Registry::default();
        r.counter("reqs").add(3);
        r.histogram("lat").observe(0.01);
        let s = r.snapshot().to_string();
        let v = Json::parse(&s).unwrap();
        assert_eq!(
            v.get("counters").unwrap().get("reqs").unwrap().as_f64(),
            Some(3.0)
        );
    }

    #[test]
    fn timer_records() {
        let r = Registry::default();
        let h = r.histogram("t");
        {
            let _t = Timer::new(h.clone());
        }
        assert_eq!(h.count(), 1);
    }
}
