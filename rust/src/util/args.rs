//! Tiny CLI argument parser (clap is unavailable offline).
//!
//! Supports `--flag value`, `--flag=value`, boolean `--flag`, positional
//! arguments, and typed getters with defaults. Used by `main.rs`, the
//! examples and the bench binaries.

use std::collections::BTreeMap;

#[derive(Debug, Default)]
pub struct Args {
    flags: BTreeMap<String, String>,
    positional: Vec<String>,
}

impl Args {
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Args {
        let mut out = Args::default();
        let mut it = argv.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(rest) = a.strip_prefix("--") {
                if let Some((k, v)) = rest.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = it.next().unwrap();
                    out.flags.insert(rest.to_string(), v);
                } else {
                    out.flags.insert(rest.to_string(), "true".to_string());
                }
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn positional(&self) -> &[String] {
        &self.positional
    }

    pub fn str(&self, key: &str, default: &str) -> String {
        self.flags.get(key).cloned().unwrap_or_else(|| default.to_string())
    }

    pub fn opt_str(&self, key: &str) -> Option<String> {
        self.flags.get(key).cloned()
    }

    pub fn usize(&self, key: &str, default: usize) -> usize {
        self.flags
            .get(key)
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    }

    pub fn u64(&self, key: &str, default: u64) -> u64 {
        self.flags
            .get(key)
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    }

    pub fn i64(&self, key: &str, default: i64) -> i64 {
        self.flags
            .get(key)
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    }

    pub fn f64(&self, key: &str, default: f64) -> f64 {
        self.flags
            .get(key)
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    }

    pub fn bool(&self, key: &str) -> bool {
        matches!(
            self.flags.get(key).map(|s| s.as_str()),
            Some("true") | Some("1") | Some("yes")
        )
    }

    /// Comma-separated list of usizes, e.g. `--buckets 1,4,16`.
    pub fn usize_list(&self, key: &str, default: &[usize]) -> Vec<usize> {
        match self.flags.get(key) {
            None => default.to_vec(),
            Some(v) => v
                .split(',')
                .filter(|s| !s.is_empty())
                .filter_map(|s| s.trim().parse().ok())
                .collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn parses_forms() {
        let a = parse("cmd --x 3 --y=hello --flag --z 1.5 pos2");
        assert_eq!(a.positional(), &["cmd", "pos2"]);
        assert_eq!(a.usize("x", 0), 3);
        assert_eq!(a.str("y", ""), "hello");
        assert!(a.bool("flag"));
        assert!((a.f64("z", 0.0) - 1.5).abs() < 1e-12);
        assert_eq!(a.usize("missing", 7), 7);
    }

    #[test]
    fn i64_accepts_negative_values() {
        let a = parse("--priority -3");
        // "--priority -3": the "-3" token does not start with "--", so
        // it binds as the flag's value.
        assert_eq!(a.i64("priority", 0), -3);
        assert_eq!(a.i64("missing", -7), -7);
    }

    #[test]
    fn usize_list() {
        let a = parse("--buckets 1,4,16");
        assert_eq!(a.usize_list("buckets", &[]), vec![1, 4, 16]);
        assert_eq!(a.usize_list("other", &[2]), vec![2]);
    }

    #[test]
    fn bool_flag_before_another_flag() {
        let a = parse("--verbose --n 2");
        assert!(a.bool("verbose"));
        assert_eq!(a.usize("n", 0), 2);
    }
}
