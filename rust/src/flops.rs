//! Appendix E FLOP analysis, reproduced exactly.
//!
//! The paper derives (following Hoffmann et al. 2022, App. F) the forward
//! FLOPs of a vanilla transformer and shows the self-speculative
//! architecture adds only the causal input projection (`2*(3C)*C` per token)
//! plus the output residual add (`C` per token): a **0.98%** overhead at the
//! OpenWebText settings. `examples/flops_analysis.rs` regenerates the
//! numbers of App. E; the unit tests below pin them.

/// Transformer shape parameters (paper notation).
#[derive(Clone, Copy, Debug)]
pub struct TransformerShape {
    /// Base hidden dimension C.
    pub c: u64,
    /// Feed-forward hidden dimension F.
    pub f: u64,
    /// Number of heads H.
    pub h: u64,
    /// Key dimension K.
    pub k: u64,
    /// Vocabulary size V.
    pub v: u64,
    /// Sequence length S.
    pub s: u64,
    /// Number of layers.
    pub layers: u64,
}

impl TransformerShape {
    /// The paper's OpenWebText / GPT2-scale settings (App. E).
    pub fn paper_owt() -> Self {
        TransformerShape {
            c: 768,
            f: 3072,
            h: 12,
            k: 64,
            v: 50_257,
            s: 1024,
            layers: 12,
        }
    }

    pub fn embedding(&self) -> u64 {
        2 * self.s * self.v * self.c
    }

    pub fn qkv_projection(&self) -> u64 {
        6 * self.s * self.c * self.k * self.h
    }

    pub fn kq_matmul(&self) -> u64 {
        2 * self.s * self.s * self.k * self.h
    }

    pub fn softmax(&self) -> u64 {
        3 * self.h * self.s * self.s
    }

    pub fn softmax_query_reduction(&self) -> u64 {
        2 * self.s * self.s * self.k * self.h
    }

    pub fn attn_linear(&self) -> u64 {
        2 * self.s * self.k * self.h * self.c
    }

    pub fn attention(&self) -> u64 {
        self.qkv_projection()
            + self.kq_matmul()
            + self.softmax()
            + self.softmax_query_reduction()
            + self.attn_linear()
    }

    pub fn dense_block(&self) -> u64 {
        4 * self.s * self.c * self.f
    }

    pub fn final_logits(&self) -> u64 {
        2 * self.s * self.c * self.v
    }

    /// Total forward FLOPs of the vanilla transformer. Identical for AR
    /// models and MDMs — they differ only in the attention mask.
    pub fn total_vanilla(&self) -> u64 {
        self.embedding()
            + self.layers * (self.attention() + self.dense_block())
            + self.final_logits()
    }

    /// Extra FLOPs of the self-speculative architecture: the causal input
    /// projection of [h_cur; h_next; tok_emb] (3C -> C, i.e. 2*3C*C per
    /// token) plus the output residual add (C per token).
    pub fn speculative_overhead(&self) -> u64 {
        self.s * (6 * self.c * self.c + self.c)
    }

    /// Overhead as a fraction of the vanilla forward cost.
    pub fn overhead_fraction(&self) -> f64 {
        self.speculative_overhead() as f64 / self.total_vanilla() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_component_values() {
        // Appendix E reports these magnitudes for the OWT settings.
        let t = TransformerShape::paper_owt();
        assert_eq!(t.embedding(), 2 * 1024 * 50_257 * 768); // 7.9e10
        assert!((t.qkv_projection() as f64 - 3.6e9).abs() / 3.6e9 < 0.05);
        assert!((t.kq_matmul() as f64 - 1.6e9).abs() / 1.6e9 < 0.05);
        assert!((t.softmax() as f64 - 3.7e7).abs() / 3.7e7 < 0.05);
        assert!((t.attn_linear() as f64 - 1.2e9).abs() / 1.2e9 < 0.05);
        assert!((t.attention() as f64 - 8e9).abs() / 8e9 < 0.02);
        assert!((t.dense_block() as f64 - 9.7e9).abs() / 9.7e9 < 0.01);
        assert!((t.final_logits() as f64 - 7.9e10).abs() / 7.9e10 < 0.01);
        assert!((t.total_vanilla() as f64 - 3.7e11).abs() / 3.7e11 < 0.02);
    }

    #[test]
    fn overhead_is_0_98_percent() {
        let t = TransformerShape::paper_owt();
        let frac = t.overhead_fraction();
        assert!(
            (frac - 0.0098).abs() < 0.0002,
            "overhead fraction {frac} != 0.98%"
        );
    }

    #[test]
    fn overhead_shrinks_with_vocab() {
        // The logits/embedding terms dominate; a larger vocab dilutes the
        // causal-projection overhead.
        let mut t = TransformerShape::paper_owt();
        let base = t.overhead_fraction();
        t.v *= 2;
        assert!(t.overhead_fraction() < base);
    }
}
