//! Hand-rolled Rust lexer for `repolint` (see `lint` module docs).
//!
//! The linter's rules are lexical pattern matches over *code* tokens, so
//! the lexer's whole job is to classify source bytes well enough that a
//! banned identifier inside a comment, a string literal, or a doc
//! example can never produce a false diagnostic — and that comments
//! (where `// SAFETY:` obligations and `// lint:` directives live)
//! survive with their text and exact line spans. No external parser
//! crates: the build is offline, and full Rust grammar is not needed for
//! line-anchored lexical invariants.
//!
//! Handled beyond the obvious: nested block comments, doc comments
//! (`///`, `//!`, `/**`, `/*!`), raw strings with arbitrary `#` fences
//! (`r#"…"#`), byte/raw-byte strings and byte chars, char literals vs.
//! lifetimes (`'a'` vs. `'a`), escapes inside char/string literals, and
//! numeric literals with `_` separators and radix prefixes (normalized
//! by [`parse_int`] so rules can match constants by *value*, not
//! spelling).

/// Token classes the rules distinguish.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TokKind {
    /// Identifier or keyword.
    Ident,
    /// Numeric literal (raw text; see [`parse_int`]).
    Num,
    /// One punctuation character (multi-char operators arrive as
    /// consecutive tokens: `::` is `:`, `:`).
    Punct,
    /// `//…` comment (doc or plain), text includes the `//` marker.
    LineComment,
    /// `/*…*/` comment (doc or plain, possibly nested / multi-line).
    BlockComment,
    /// String, raw-string, byte-string or char literal. Contents are
    /// deliberately opaque to every rule.
    StrLit,
    /// `'a`-style lifetime (or loop label).
    Lifetime,
}

/// One lexed token with its position (1-based line, 0-based byte column
/// of the first character; multi-line tokens also record their last
/// line).
#[derive(Clone, Debug)]
pub struct Tok {
    pub kind: TokKind,
    pub text: String,
    pub line: u32,
    pub end_line: u32,
    pub col: u32,
}

impl Tok {
    pub fn is_comment(&self) -> bool {
        matches!(self.kind, TokKind::LineComment | TokKind::BlockComment)
    }

    /// Comment text with the `//` / `/*` / `*/` markers and doc sigils
    /// stripped, for directive and `SAFETY:` scanning.
    pub fn comment_text(&self) -> String {
        debug_assert!(self.is_comment());
        match self.kind {
            TokKind::LineComment => {
                let t = self.text.trim_start_matches('/');
                t.strip_prefix('!').unwrap_or(t).to_string()
            }
            _ => {
                let t = self
                    .text
                    .trim_start_matches("/*")
                    .trim_start_matches(['*', '!'])
                    .trim_end_matches("*/");
                t.to_string()
            }
        }
    }
}

/// Parse a Rust integer literal (any radix prefix, `_` separators, type
/// suffix) to its value. Returns `None` for floats and malformed text.
pub fn parse_int(text: &str) -> Option<u128> {
    let t: String = text.chars().filter(|&c| c != '_').collect();
    let (digits, radix) = if let Some(h) =
        t.strip_prefix("0x").or_else(|| t.strip_prefix("0X"))
    {
        (h, 16)
    } else if let Some(o) = t.strip_prefix("0o") {
        (o, 8)
    } else if let Some(b) = t.strip_prefix("0b") {
        (b, 2)
    } else {
        (t.as_str(), 10)
    };
    // Trim a trailing type suffix (u8/i64/usize/…): keep the leading run
    // of digits valid in this radix.
    let end = digits
        .char_indices()
        .find(|(_, c)| !c.is_digit(radix))
        .map(|(i, _)| i)
        .unwrap_or(digits.len());
    if end == 0 {
        return None;
    }
    u128::from_str_radix(&digits[..end], radix).ok()
}

/// Lex `src` into tokens. Never fails: unrecognized bytes become
/// single-char `Punct` tokens, so the rules always see *something* with
/// a correct line number.
pub fn lex(src: &str) -> Vec<Tok> {
    Lexer { src: src.as_bytes(), pos: 0, line: 1, col: 0, toks: Vec::new() }
        .run()
}

struct Lexer<'a> {
    src: &'a [u8],
    pos: usize,
    line: u32,
    col: u32,
    toks: Vec<Tok>,
}

fn is_ident_byte(b: u8) -> bool {
    matches!(b, b'_' | b'a'..=b'z' | b'A'..=b'Z' | b'0'..=b'9')
}

impl Lexer<'_> {
    fn peek(&self, ahead: usize) -> u8 {
        *self.src.get(self.pos + ahead).unwrap_or(&0)
    }

    /// Advance one byte, tracking line/column.
    fn bump(&mut self) {
        let b = self.src[self.pos];
        self.pos += 1;
        if b == b'\n' {
            self.line += 1;
            self.col = 0;
        } else {
            self.col += 1;
        }
    }

    fn push(&mut self, kind: TokKind, start: usize, line: u32, col: u32) {
        let text =
            String::from_utf8_lossy(&self.src[start..self.pos]).into_owned();
        self.toks.push(Tok { kind, text, line, end_line: self.line, col });
    }

    fn run(mut self) -> Vec<Tok> {
        while self.pos < self.src.len() {
            let (line, col, start) = (self.line, self.col, self.pos);
            match self.peek(0) {
                b' ' | b'\t' | b'\r' | b'\n' => self.bump(),
                b'/' if self.peek(1) == b'/' => {
                    while self.pos < self.src.len() && self.peek(0) != b'\n'
                    {
                        self.bump();
                    }
                    self.push(TokKind::LineComment, start, line, col);
                }
                b'/' if self.peek(1) == b'*' => {
                    self.block_comment();
                    self.push(TokKind::BlockComment, start, line, col);
                }
                b'"' => {
                    self.bump();
                    self.string_body(None);
                    self.push(TokKind::StrLit, start, line, col);
                }
                b'r' | b'b' if self.literal_prefix_len().is_some() => {
                    // r"…", r#"…"#, b"…", br#"…"#, b'…': scan decided it
                    // is a literal; consume prefix + body.
                    let (plen, fence, is_char) =
                        self.literal_prefix_len().unwrap();
                    for _ in 0..plen {
                        self.bump();
                    }
                    if is_char {
                        self.char_body();
                    } else {
                        self.bump(); // opening quote
                        self.string_body(fence);
                    }
                    self.push(TokKind::StrLit, start, line, col);
                }
                b'\'' => {
                    if self.lifetime_ahead() {
                        self.bump();
                        while is_ident_byte(self.peek(0)) {
                            self.bump();
                        }
                        self.push(TokKind::Lifetime, start, line, col);
                    } else {
                        self.char_body();
                        self.push(TokKind::StrLit, start, line, col);
                    }
                }
                b'_' | b'a'..=b'z' | b'A'..=b'Z' => {
                    while is_ident_byte(self.peek(0)) {
                        self.bump();
                    }
                    self.push(TokKind::Ident, start, line, col);
                }
                b'0'..=b'9' => {
                    while is_ident_byte(self.peek(0))
                        || (self.peek(0) == b'.'
                            && self.peek(1).is_ascii_digit())
                    {
                        self.bump();
                    }
                    self.push(TokKind::Num, start, line, col);
                }
                _ => {
                    self.bump();
                    self.push(TokKind::Punct, start, line, col);
                }
            }
        }
        self.toks
    }

    /// At an `r`/`b`: peek whether a raw/byte literal starts here.
    /// Returns `(prefix_len, raw_fence, is_char)` — `prefix_len` covers
    /// the letters and any `#` fence up to (not including) the opening
    /// quote; `raw_fence` is `Some(n)` for raw strings closed by
    /// `"` + `#`×n; `is_char` flags `b'…'`.
    fn literal_prefix_len(&self) -> Option<(usize, Option<usize>, bool)> {
        let (mut k, mut raw) = (0usize, false);
        if self.peek(0) == b'b' {
            k = 1;
            if self.peek(1) == b'r' {
                k = 2;
                raw = true;
            } else if self.peek(1) == b'\'' {
                return Some((1, None, true));
            }
        } else if self.peek(0) == b'r' {
            k = 1;
            raw = true;
        }
        let mut fence = 0usize;
        if raw {
            while self.peek(k) == b'#' {
                fence += 1;
                k += 1;
            }
        }
        if self.peek(k) == b'"' {
            Some((k, if raw { Some(fence) } else { None }, false))
        } else {
            None
        }
    }

    /// `'` starts a lifetime (not a char literal) iff an identifier char
    /// follows and the char after that identifier-start is not a closing
    /// quote ('a' is a char, 'a is a lifetime, 'ab could only be a
    /// label/lifetime).
    fn lifetime_ahead(&self) -> bool {
        let one = self.peek(1);
        (one == b'_' || one.is_ascii_alphabetic()) && self.peek(2) != b'\''
    }

    /// Nested block comment body: `/* … /* … */ … */`.
    fn block_comment(&mut self) {
        self.bump();
        self.bump();
        let mut depth = 1usize;
        while self.pos < self.src.len() && depth > 0 {
            if self.peek(0) == b'/' && self.peek(1) == b'*' {
                self.bump();
                self.bump();
                depth += 1;
            } else if self.peek(0) == b'*' && self.peek(1) == b'/' {
                self.bump();
                self.bump();
                depth -= 1;
            } else {
                self.bump();
            }
        }
    }

    /// String body after the opening quote. `fence: None` is a normal
    /// (escaped) string; `Some(n)` a raw string closed by `"` + `#`×n.
    fn string_body(&mut self, fence: Option<usize>) {
        while self.pos < self.src.len() {
            let b = self.peek(0);
            self.bump();
            match (b, fence) {
                (b'\\', None) => {
                    if self.pos < self.src.len() {
                        self.bump();
                    }
                }
                (b'"', None) => return,
                (b'"', Some(n)) => {
                    let mut seen = 0usize;
                    while seen < n && self.peek(0) == b'#' {
                        self.bump();
                        seen += 1;
                    }
                    if seen == n {
                        return;
                    }
                }
                _ => {}
            }
        }
    }

    /// Char (or byte-char) literal starting at the current `'`.
    fn char_body(&mut self) {
        self.bump(); // opening '
        if self.peek(0) == b'\\' {
            self.bump();
            if self.pos < self.src.len() {
                self.bump(); // escape head: n, ', \, u, x, …
            }
            // Multi-char escape tails (\u{…}, \x7f) run to the quote.
            while self.pos < self.src.len() && self.peek(0) != b'\'' {
                self.bump();
            }
        } else if self.pos < self.src.len() {
            self.bump();
        }
        if self.peek(0) == b'\'' {
            self.bump();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn kinds(src: &str) -> Vec<(TokKind, String)> {
        lex(src).into_iter().map(|t| (t.kind, t.text)).collect()
    }

    #[test]
    fn idents_and_puncts() {
        let ks = kinds("foo::bar(x)");
        assert_eq!(
            ks,
            vec![
                (TokKind::Ident, "foo".into()),
                (TokKind::Punct, ":".into()),
                (TokKind::Punct, ":".into()),
                (TokKind::Ident, "bar".into()),
                (TokKind::Punct, "(".into()),
                (TokKind::Ident, "x".into()),
                (TokKind::Punct, ")".into()),
            ]
        );
    }

    #[test]
    fn comments_keep_text_and_lines() {
        let toks = lex("let a = 1; // SAFETY: fine\n/* block\nspan */ b");
        let line = toks.iter().find(|t| t.kind == TokKind::LineComment)
            .unwrap();
        assert!(line.comment_text().contains("SAFETY: fine"));
        assert_eq!(line.line, 1);
        let block = toks.iter().find(|t| t.kind == TokKind::BlockComment)
            .unwrap();
        assert_eq!((block.line, block.end_line), (2, 3));
    }

    #[test]
    fn banned_names_inside_strings_are_opaque() {
        let toks = lex(r#"let s = "Instant::now() thread::sleep";"#);
        assert!(toks.iter().all(|t| t.kind != TokKind::Ident
                                 || (t.text != "Instant"
                                     && t.text != "sleep")));
    }

    #[test]
    fn raw_strings_and_fences() {
        let toks = lex(r##"let s = r#"unsafe { "nested" }"# ; x"##);
        let lits: Vec<_> =
            toks.iter().filter(|t| t.kind == TokKind::StrLit).collect();
        assert_eq!(lits.len(), 1);
        assert!(toks.iter().any(|t| t.kind == TokKind::Ident
                                && t.text == "x"));
        assert!(toks.iter().all(|t| t.text != "unsafe"
                                || t.kind == TokKind::StrLit));
    }

    #[test]
    fn byte_literals() {
        let toks = lex(r#"let a = b"bytes"; let c = b'x'; let r = rb;"#);
        let lits =
            toks.iter().filter(|t| t.kind == TokKind::StrLit).count();
        assert_eq!(lits, 2);
        // `rb` with no quote stays an identifier.
        assert!(toks.iter().any(|t| t.kind == TokKind::Ident
                                && t.text == "rb"));
    }

    #[test]
    fn char_vs_lifetime() {
        let toks = lex("fn f<'a>(x: &'a u8) { let c = 'x'; let d = '\\''; }");
        let lifetimes =
            toks.iter().filter(|t| t.kind == TokKind::Lifetime).count();
        assert_eq!(lifetimes, 2);
        let chars =
            toks.iter().filter(|t| t.kind == TokKind::StrLit).count();
        assert_eq!(chars, 2);
    }

    #[test]
    fn nested_block_comments() {
        let toks = lex("/* a /* b */ c */ after");
        assert_eq!(toks.len(), 2);
        assert_eq!(toks[1].text, "after");
    }

    #[test]
    fn deeply_nested_block_comments() {
        // The concurrency pass reads code *around* comments; a
        // mis-counted nesting level would swallow real acquisitions.
        let toks = lex("/* 1 /* 2 /* 3 */ 2 */ 1 */ lock_recover");
        assert_eq!(toks.len(), 2);
        assert_eq!(toks[0].kind, TokKind::BlockComment);
        assert_eq!(toks[1].text, "lock_recover");

        // Unterminated inner comment must not panic, and must not
        // leak trailing text as code.
        let toks = lex("/* outer /* inner */ still-open");
        assert!(toks.iter().all(|t| t.is_comment()));
    }

    #[test]
    fn raw_strings_with_many_hashes() {
        // `r##"…"#…"##`: a single-`#` close inside must not end the
        // literal early and expose `.lock()` tokens to the rules.
        let src = r###"let s = r##"x.lock() "# y.lock()"## ; tail"###;
        let toks = lex(src);
        let lits: Vec<_> =
            toks.iter().filter(|t| t.kind == TokKind::StrLit).collect();
        assert_eq!(lits.len(), 1);
        assert!(lits[0].text.contains("x.lock()"));
        assert!(toks.iter().all(|t| t.kind != TokKind::Ident
                                || t.text != "lock"));
        assert!(toks.iter().any(|t| t.kind == TokKind::Ident
                                && t.text == "tail"));
    }

    #[test]
    fn lifetime_vs_char_inside_generic_bounds() {
        // `MutexGuard<'a, T>` return types feed accessor detection:
        // the `'a` must lex as a lifetime, not open a char literal
        // that swallows `, T>`.
        let toks =
            lex("fn g<'a, T: Iterator<Item = &'a u8>>(x: &'a T) \
                 -> MutexGuard<'a, T> { let c = 'g'; }");
        let lifetimes =
            toks.iter().filter(|t| t.kind == TokKind::Lifetime).count();
        assert_eq!(lifetimes, 4);
        let chars: Vec<_> =
            toks.iter().filter(|t| t.kind == TokKind::StrLit).collect();
        assert_eq!(chars.len(), 1);
        assert!(toks.iter().any(|t| t.kind == TokKind::Ident
                                && t.text == "MutexGuard"));
    }

    #[test]
    fn numeric_literals_normalize() {
        assert_eq!(parse_int("0x9e37_79b9_7f4a_7c15"),
                   // lint: allow(rng-discipline) — lexer's own
                   // normalization test vector.
                   Some(0x9e3779b97f4a7c15));
        assert_eq!(parse_int("6364136223846793005"),
                   // lint: allow(rng-discipline) — lexer's own
                   // normalization test vector.
                   Some(6364136223846793005));
        assert_eq!(parse_int("1_000u64"), Some(1000));
        assert_eq!(parse_int("0b1010"), Some(10));
        assert_eq!(parse_int("abc"), None);
    }

    #[test]
    fn float_range_does_not_glue() {
        let ks = kinds("for i in 0..n_act {}");
        assert!(ks.contains(&(TokKind::Num, "0".into())));
        assert!(ks.contains(&(TokKind::Ident, "n_act".into())));
        let ks2 = kinds("let x = 0.5;");
        assert!(ks2.contains(&(TokKind::Num, "0.5".into())));
    }

    #[test]
    fn columns_are_tracked() {
        let toks = lex("ab /* c */ unsafe");
        let u = toks.iter().find(|t| t.text == "unsafe").unwrap();
        assert_eq!(u.col, 11);
        let c = toks.iter().find(|t| t.is_comment()).unwrap();
        assert_eq!(c.col, 3);
    }
}
