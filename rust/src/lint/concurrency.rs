//! Static concurrency analysis on the repolint lexer: per-function
//! lock-acquisition facts, an interprocedural lock-order graph, and
//! guard-discipline rules.
//!
//! The fleet's deadlock-freedom rests on conventions no compiler
//! checks: every mutex is taken through a recovery helper or a named
//! accessor (`live()`, `board_lock()`, `reply_lock()`), nested
//! acquisitions follow one global order, and no guard is held across a
//! blocking call. This pass enforces them as three CI-gating rules:
//!
//! * **lock-order** — nested acquisitions define edges in a global
//!   lock-order graph (lock classes are the mutex *field names*, which
//!   are unique across the codebase by convention). Any cycle is a
//!   potential deadlock; re-acquiring a class already held (directly or
//!   by calling a function that acquires it) is a guaranteed one.
//!   Acquisition facts propagate interprocedurally over a lexer-derived
//!   call graph (callees matched by name *and* arity, so `Option::take`
//!   never aliases `RouterState::take(max)`).
//! * **guard-blocking** — a live guard across a model call
//!   (`.step`/`.sample`/`.draft_into`/`.verify_into`), a channel
//!   `send`/`recv`, a `join`, a `thread::sleep`, or a condvar wait
//!   stalls every thread that needs the lock. Condvar waits are exempt
//!   for the guard they atomically release (`cv.wait(g)` /
//!   `wait_recover(&cv, g)` — the wait *names* the guard), but still
//!   flagged for any other guard held.
//! * **lock-recovery** — raw `.lock()` anywhere outside `util/sync.rs`
//!   drifts from the one poisoned-lock recovery policy; sites must use
//!   `lock_recover` / `lock_recover_or` (or a same-file accessor built
//!   on them).
//!
//! ## How facts are extracted
//!
//! Functions are found lexically (`fn name<…>(params)`); a *guard
//! accessor* is a same-file function returning a `MutexGuard` whose
//! body acquires exactly one class — calling it counts as acquiring
//! that class. Guard liveness is tracked per body: `let g = <acquire>`
//! holds to the end of the enclosing brace block or an explicit
//! `drop(g)`; an unbound acquisition is a temporary held to the end of
//! its statement. The per-file pass reports everything derivable from
//! one file (`check_source`); `check_tree` re-resolves calls against
//! the whole tree's function table and reports only what needed
//! cross-file knowledge, so nothing is double-reported.
//!
//! ## Soundness and limits
//!
//! The pass is conservative where it matters (a call edge propagates
//! the callee's *transitive* acquire set; same-named same-arity
//! functions are unioned) and unsound only in documented ways: guards
//! obtained through a *cross-file* accessor call are invisible to the
//! guard tracker (cross-file lock-order still flows through the call
//! graph), closures are analyzed as part of their enclosing function
//! (acquisitions inside a deferred closure attribute to the definer —
//! conservative), and blocking-call detection is pattern-based.
//! Findings are suppressed with the established
//! `// lint: allow(<rule>) — <why>` grammar; cycle diagnostics that
//! need cross-file facts are matched against allows at tree level.

use std::collections::{BTreeMap, BTreeSet};

use crate::lint::lexer::{Tok, TokKind};
use crate::lint::rules::seq_at;
use crate::lint::{Diagnostic, FileCtx};

/// Per-function facts: what it acquires directly, and every call site
/// (with the lock classes held at the call).
#[derive(Clone, Debug, Default)]
pub struct FnFacts {
    pub name: String,
    /// Parameter count excluding any `self` receiver.
    pub arity: usize,
    /// (lock class, line) acquired directly in the body.
    pub acquires: Vec<(String, u32)>,
    pub calls: Vec<CallSite>,
}

#[derive(Clone, Debug)]
pub struct CallSite {
    pub callee: String,
    pub arity: usize,
    pub line: u32,
    /// Lock classes held when the call is made.
    pub held: Vec<String>,
}

/// Everything the tree-level pass needs from one file.
#[derive(Clone, Debug, Default)]
pub struct FileFacts {
    pub path: String,
    pub fns: Vec<FnFacts>,
    /// Lock-order edges derivable from this file alone (direct nesting
    /// plus same-file call resolution): (held, acquired, line).
    pub edges: Vec<(String, String, u32)>,
    /// (class, line) of call-into-held-class deadlocks already reported
    /// by the per-file pass (so the tree pass does not repeat them).
    pub call_deadlocks: Vec<(String, u32)>,
}

/// Per-file analysis result: facts for the tree pass + raw diagnostics
/// (fed through the allowlist by `check_source` like any rule's).
pub struct FileAnalysis {
    pub facts: FileFacts,
    pub diags: Vec<Diagnostic>,
}

/// Tree-level summary printed by the repolint binary.
#[derive(Clone, Copy, Debug, Default)]
pub struct TreeStats {
    pub fns: usize,
    pub classes: usize,
    pub edges: usize,
    pub cycles: usize,
}

// ---------------------------------------------------------------------
// Per-file pass
// ---------------------------------------------------------------------

/// Names that are language/std plumbing, never lock-order call edges.
/// (`drop(g)` in particular must release, not "call `Drop::drop`".)
const NEVER_CALL_EDGE: [&str; 4] = ["drop", "Some", "Ok", "Err"];

const KEYWORDS: [&str; 12] = [
    "if", "while", "match", "for", "loop", "return", "let", "else",
    "move", "in", "as", "unsafe",
];

/// Blocking-call patterns: (display name, token pattern, wait-family).
/// Wait-family calls atomically release the guard they *name* in their
/// arguments, so that guard is exempt at the site.
const BLOCKING: [(&str, &[&str], bool); 13] = [
    (".send(", &[".", "send", "("], false),
    (".recv(", &[".", "recv", "("], false),
    (".recv_timeout(", &[".", "recv_timeout", "("], false),
    (".join(", &[".", "join", "("], false),
    ("thread::sleep", &["thread", ":", ":", "sleep"], false),
    (".step(", &[".", "step", "("], false),
    (".sample(", &[".", "sample", "("], false),
    (".draft_into(", &[".", "draft_into", "("], false),
    (".verify_into(", &[".", "verify_into", "("], false),
    (".wait(", &[".", "wait", "("], true),
    (".wait_timeout(", &[".", "wait_timeout", "("], true),
    (".wait_while(", &[".", "wait_while", "("], true),
    ("wait_recover(", &["wait_recover", "("], true),
];

struct FnDef {
    name: String,
    arity: usize,
    ret_guard: bool,
    /// Token index range of the body (inside the braces).
    body: std::ops::Range<usize>,
}

#[derive(Clone)]
struct Guard {
    /// `None` = statement temporary.
    name: Option<String>,
    class: String,
    depth: i32,
    line: u32,
}

/// Run the whole per-file analysis. Called by `lint::check_source` for
/// every file; `util/sync.rs` (the recovery primitives themselves) is
/// skipped.
pub fn analyze(ctx: &FileCtx) -> FileAnalysis {
    let mut a = FileAnalysis {
        facts: FileFacts { path: ctx.path.clone(), ..Default::default() },
        diags: Vec::new(),
    };
    if ctx.path.ends_with("util/sync.rs") {
        return a;
    }
    let code = &ctx.code;

    // lock-recovery: raw `.lock()` is banned outside util/sync.rs.
    for i in 0..code.len() {
        if seq_at(code, i, &[".", "lock", "("]) {
            a.diags.push(ctx.diag(
                "lock-recovery",
                code[i].line,
                "raw `.lock()` — poisoned-lock recovery must be uniform: \
                 use `util::sync::lock_recover` / `lock_recover_or`",
            ));
        }
    }

    let defs = parse_fns(code);
    let accessors = accessor_map(code, &defs);

    let mut edge_map: BTreeMap<(String, String), u32> = BTreeMap::new();
    for d in &defs {
        let f = walk_body(ctx, code, d, &accessors, &mut edge_map,
                          &mut a.diags);
        a.facts.fns.push(f);
    }

    // Same-file interprocedural resolution.
    let (call_edges, call_deadlocks) = resolve_calls(&a.facts.fns);
    for (e, line) in call_edges {
        edge_map.entry(e).or_insert(line);
    }
    for (class, line) in &call_deadlocks {
        a.diags.push(ctx.diag(
            "lock-order",
            *line,
            format!(
                "call acquires `{class}` while a guard on `{class}` is \
                 already held — re-entrant `Mutex` acquisition \
                 deadlocks"
            ),
        ));
    }
    a.facts.call_deadlocks = call_deadlocks;

    // Per-file cycle report (tree pass will skip these).
    let sited: BTreeMap<(String, String), (String, u32)> = edge_map
        .iter()
        .map(|((h, q), l)| {
            ((h.clone(), q.clone()), (ctx.path.clone(), *l))
        })
        .collect();
    cycle_diags(&sited, &mut a.diags);

    a.facts.edges = edge_map
        .into_iter()
        .map(|((h, q), l)| (h, q, l))
        .collect();
    a
}

// ---------------------------------------------------------------------
// Function discovery
// ---------------------------------------------------------------------

fn parse_fns(code: &[Tok]) -> Vec<FnDef> {
    let mut defs = Vec::new();
    let mut i = 0;
    while i < code.len() {
        if !(code[i].kind == TokKind::Ident && code[i].text == "fn") {
            i += 1;
            continue;
        }
        // `fn(u32) -> u32` pointer types have no name ident.
        let Some(name_tok) = code.get(i + 1) else { break };
        if name_tok.kind != TokKind::Ident {
            i += 1;
            continue;
        }
        let name = name_tok.text.clone();
        let mut j = i + 2;
        // Skip generic params `<…>` between name and `(`.
        if is_punct(code.get(j), "<") {
            let mut angle = 0i32;
            while j < code.len() {
                if is_punct(code.get(j), "<") {
                    angle += 1;
                } else if is_punct(code.get(j), ">")
                    && !is_punct(code.get(j.wrapping_sub(1)), "-")
                {
                    angle -= 1;
                    if angle == 0 {
                        j += 1;
                        break;
                    }
                }
                j += 1;
            }
        }
        if !is_punct(code.get(j), "(") {
            i += 2;
            continue;
        }
        let (arity_raw, has_self, close) = count_params(code, j);
        let arity = arity_raw.saturating_sub(has_self as usize);
        // Return type / where clause, then body `{` or trait-decl `;`.
        let mut k = close + 1;
        let mut ret_guard = false;
        while k < code.len() {
            let t = &code[k];
            if is_punct(Some(t), "{") || is_punct(Some(t), ";") {
                break;
            }
            if t.kind == TokKind::Ident && t.text == "MutexGuard" {
                ret_guard = true;
            }
            k += 1;
        }
        if is_punct(code.get(k), "{") {
            let end = match_brace(code, k);
            defs.push(FnDef {
                name,
                arity,
                ret_guard,
                body: (k + 1)..end,
            });
        }
        i += 2; // keep scanning inside the body: nested fns are fns too
    }
    defs
}

fn is_punct(t: Option<&Tok>, p: &str) -> bool {
    t.map_or(false, |t| t.kind == TokKind::Punct && t.text == p)
}

/// Index of the `}` matching the `{` at `open`.
fn match_brace(code: &[Tok], open: usize) -> usize {
    let mut depth = 0i32;
    for (k, t) in code.iter().enumerate().skip(open) {
        if t.kind == TokKind::Punct {
            match t.text.as_str() {
                "{" => depth += 1,
                "}" => {
                    depth -= 1;
                    if depth == 0 {
                        return k;
                    }
                }
                _ => {}
            }
        }
    }
    code.len()
}

/// Count comma-separated params of the list opening at `open`; commas
/// inside nested `()`/`[]`/`{}`/`<>` don't count. Returns
/// (count, first param mentions `self`, index of the closing paren).
fn count_params(code: &[Tok], open: usize) -> (usize, bool, usize) {
    let mut paren = 0i32;
    let mut angle = 0i32;
    let mut nest = 0i32; // [] and {}
    let mut commas = 0usize;
    let mut any = false;
    let mut has_self = false;
    let mut in_first = true;
    let mut k = open;
    while k < code.len() {
        let t = &code[k];
        if t.kind == TokKind::Punct {
            match t.text.as_str() {
                "(" => paren += 1,
                ")" => {
                    paren -= 1;
                    if paren == 0 {
                        return (commas + any as usize, has_self, k);
                    }
                }
                "[" | "{" => nest += 1,
                "]" | "}" => nest -= 1,
                "<" => angle += 1,
                ">" => {
                    if !is_punct(code.get(k.wrapping_sub(1)), "-") {
                        angle = (angle - 1).max(0);
                    }
                }
                "," if paren == 1 && angle == 0 && nest == 0 => {
                    commas += 1;
                    in_first = false;
                }
                _ => {}
            }
        }
        if k > open && paren >= 1 {
            any = true;
            if in_first
                && t.kind == TokKind::Ident
                && t.text == "self"
            {
                has_self = true;
            }
        }
        k += 1;
    }
    (commas + any as usize, has_self, code.len().saturating_sub(1))
}

/// Same-file guard accessors: a fn returning `MutexGuard` whose body
/// acquires exactly one class. Calling one acquires that class.
fn accessor_map(code: &[Tok], defs: &[FnDef])
                -> BTreeMap<String, String> {
    let mut map = BTreeMap::new();
    for d in defs.iter().filter(|d| d.ret_guard) {
        let mut classes = BTreeSet::new();
        let mut i = d.body.start;
        while i < d.body.end {
            if let Some((class, _, consumed)) =
                primitive_acquire_at(code, i)
            {
                classes.insert(class);
                i += consumed;
            } else {
                i += 1;
            }
        }
        if classes.len() == 1 {
            map.insert(d.name.clone(),
                       classes.into_iter().next().unwrap());
        }
    }
    map
}

// ---------------------------------------------------------------------
// Acquisition / binding detection
// ---------------------------------------------------------------------

/// A primitive acquisition at `i`: raw `.lock(`, `lock_recover(&…)`, or
/// `lock_recover_or(&…, …)`. Returns (class, binding-probe index,
/// tokens consumed).
fn primitive_acquire_at(code: &[Tok], i: usize)
                        -> Option<(String, usize, usize)> {
    if seq_at(code, i, &[".", "lock", "("]) {
        let recv = code.get(i.wrapping_sub(1))?;
        if recv.kind == TokKind::Ident {
            return Some((recv.text.clone(), i, 3));
        }
        return None;
    }
    for helper in ["lock_recover", "lock_recover_or"] {
        if seq_at(code, i, &[helper, "("]) {
            let class = first_arg_class(code, i + 1)?;
            return Some((class, i, 2));
        }
    }
    None
}

/// Last ident of the first argument of the call opening at `open`
/// (`&self.board, …` → `board`).
fn first_arg_class(code: &[Tok], open: usize) -> Option<String> {
    let mut depth = 0i32;
    let mut last = None;
    for t in code.iter().skip(open) {
        if t.kind == TokKind::Punct {
            match t.text.as_str() {
                "(" | "[" | "{" => depth += 1,
                ")" | "]" | "}" => {
                    depth -= 1;
                    if depth == 0 {
                        return last;
                    }
                }
                "," if depth == 1 => return last,
                _ => {}
            }
        } else if t.kind == TokKind::Ident && depth >= 1 {
            last = Some(t.text.clone());
        }
    }
    last
}

enum Binding {
    Named(String),
    Reassign(String),
    Temp,
}

/// What the acquisition whose expression reaches back from `probe`
/// binds to: `let [mut] NAME = …` → Named, `NAME = …` at statement
/// start → Reassign, anything else → Temp.
fn binding_before(code: &[Tok], probe: usize) -> Binding {
    let mut j = probe;
    while j > 0 {
        let t = &code[j - 1];
        let skip = (t.kind == TokKind::Ident && t.text != "let")
            || (t.kind == TokKind::Punct
                && matches!(t.text.as_str(), "." | ":" | "&" | "*"));
        if skip {
            j -= 1;
            continue;
        }
        break;
    }
    if j == 0 || !is_punct(code.get(j - 1), "=") {
        return Binding::Temp;
    }
    // `==`, `=>`, `+=` etc. are distinct tokens only if the lexer kept
    // them apart; guard against a comparison by requiring an ident.
    let Some(name) = code.get(j.wrapping_sub(2)) else {
        return Binding::Temp;
    };
    if name.kind != TokKind::Ident {
        return Binding::Temp;
    }
    let before = code.get(j.wrapping_sub(3));
    let is_let = |t: Option<&Tok>| {
        t.map_or(false, |t| t.kind == TokKind::Ident && t.text == "let")
    };
    if is_let(before) {
        return Binding::Named(name.text.clone());
    }
    if before.map_or(false, |t| {
        t.kind == TokKind::Ident && t.text == "mut"
    }) && is_let(code.get(j.wrapping_sub(4)))
    {
        return Binding::Named(name.text.clone());
    }
    // Statement-start plain assignment: re-binding an existing guard.
    if before.map_or(true, |t| {
        t.kind == TokKind::Punct
            && matches!(t.text.as_str(), ";" | "{" | "}")
    }) {
        return Binding::Reassign(name.text.clone());
    }
    Binding::Temp
}

// ---------------------------------------------------------------------
// The guard-tracking body walk
// ---------------------------------------------------------------------

#[allow(clippy::too_many_arguments)]
fn walk_body(
    ctx: &FileCtx,
    code: &[Tok],
    d: &FnDef,
    accessors: &BTreeMap<String, String>,
    edges: &mut BTreeMap<(String, String), u32>,
    diags: &mut Vec<Diagnostic>,
) -> FnFacts {
    let mut f = FnFacts {
        name: d.name.clone(),
        arity: d.arity,
        ..Default::default()
    };
    let mut guards: Vec<Guard> = Vec::new();
    let mut depth = 0i32;
    let mut i = d.body.start;
    while i < d.body.end {
        let t = &code[i];
        if t.kind == TokKind::Punct {
            match t.text.as_str() {
                "{" => depth += 1,
                "}" => {
                    depth -= 1;
                    guards.retain(|g| g.depth <= depth);
                }
                ";" => guards.retain(|g| {
                    g.name.is_some() || g.depth != depth
                }),
                _ => {}
            }
        }
        // Nested fn: its body is analyzed as its own FnDef.
        if t.kind == TokKind::Ident
            && t.text == "fn"
            && code.get(i + 1).map_or(false, |n| n.kind == TokKind::Ident)
        {
            let mut k = i + 2;
            while k < d.body.end && !is_punct(code.get(k), "{")
                && !is_punct(code.get(k), ";")
            {
                k += 1;
            }
            i = if is_punct(code.get(k), "{") {
                match_brace(code, k) + 1
            } else {
                k + 1
            };
            continue;
        }
        // `drop(g)` releases a named guard (and is never a call edge).
        if seq_at(code, i, &["drop", "("])
            && code.get(i + 2).map_or(false, |n| n.kind == TokKind::Ident)
            && is_punct(code.get(i + 3), ")")
        {
            let name = &code[i + 2].text;
            guards.retain(|g| g.name.as_deref() != Some(name));
            i += 4;
            continue;
        }
        // Acquisition (primitive or same-file accessor call)?
        let acq = primitive_acquire_at(code, i).or_else(|| {
            if code[i].kind == TokKind::Punct && code[i].text == "." {
                let name = code.get(i + 1)?;
                if name.kind == TokKind::Ident
                    && is_punct(code.get(i + 2), "(")
                    && is_punct(code.get(i + 3), ")")
                {
                    let class = accessors.get(&name.text)?;
                    return Some((class.clone(), i, 4));
                }
            }
            None
        });
        if let Some((class, probe, consumed)) = acq {
            f.acquires.push((class.clone(), t.line));
            for g in &guards {
                if g.class == class {
                    diags.push(ctx.diag(
                        "lock-order",
                        t.line,
                        format!(
                            "acquiring `{class}` while a guard on \
                             `{class}` (taken on line {}) is still \
                             held — re-entrant `Mutex` acquisition \
                             deadlocks",
                            g.line
                        ),
                    ));
                } else {
                    edges
                        .entry((g.class.clone(), class.clone()))
                        .or_insert(t.line);
                }
            }
            match binding_before(code, probe) {
                Binding::Named(n) | Binding::Reassign(n) => {
                    guards.push(Guard {
                        name: Some(n),
                        class,
                        depth,
                        line: t.line,
                    });
                }
                Binding::Temp => guards.push(Guard {
                    name: None,
                    class,
                    depth,
                    line: t.line,
                }),
            }
            i += consumed;
            continue;
        }
        // Blocking call with a guard live?
        if let Some((label, open, is_wait)) = blocking_at(code, i) {
            if !guards.is_empty() {
                let exempt: BTreeSet<String> = if is_wait {
                    arg_idents(code, open)
                } else {
                    BTreeSet::new()
                };
                let held: Vec<&Guard> = guards
                    .iter()
                    .filter(|g| {
                        g.name
                            .as_ref()
                            .map_or(true, |n| !exempt.contains(n))
                    })
                    .collect();
                if !held.is_empty() {
                    let classes: Vec<String> = held
                        .iter()
                        .map(|g| format!("`{}` (line {})", g.class,
                                         g.line))
                        .collect();
                    diags.push(ctx.diag(
                        "guard-blocking",
                        t.line,
                        format!(
                            "`{label}` while holding a guard on {} — \
                             blocking with a lock held stalls every \
                             thread that needs it; drop the guard \
                             first",
                            classes.join(", ")
                        ),
                    ));
                }
            }
            i += 2;
            continue;
        }
        // Call site (for interprocedural propagation)?
        if let Some((callee, arity, next)) = call_at(code, i, accessors) {
            f.calls.push(CallSite {
                callee,
                arity,
                line: t.line,
                held: guards.iter().map(|g| g.class.clone()).collect(),
            });
            i = next;
            continue;
        }
        i += 1;
    }
    f
}

/// Blocking-call pattern at `i`: (label, index of the open paren,
/// wait-family).
fn blocking_at(code: &[Tok], i: usize) -> Option<(&'static str, usize,
                                                  bool)> {
    for (label, pat, is_wait) in BLOCKING {
        if seq_at(code, i, pat) {
            // The paren is the pattern's last element except for
            // thread::sleep, where it follows the matched idents.
            let open = i + pat.len()
                - usize::from(pat.last() == Some(&"("));
            return Some((label, open, is_wait));
        }
    }
    None
}

/// Ident texts among the arguments of the call opening at `open`.
fn arg_idents(code: &[Tok], open: usize) -> BTreeSet<String> {
    let mut depth = 0i32;
    let mut out = BTreeSet::new();
    for t in code.iter().skip(open) {
        if t.kind == TokKind::Punct {
            match t.text.as_str() {
                "(" | "[" | "{" => depth += 1,
                ")" | "]" | "}" => {
                    depth -= 1;
                    if depth == 0 {
                        return out;
                    }
                }
                _ => {}
            }
        } else if t.kind == TokKind::Ident {
            out.insert(t.text.clone());
        }
    }
    out
}

/// A call site at `i`: `.name(…)` or bare `name(…)`. Returns
/// (callee, argument count, index to resume scanning at). Accessor
/// names and the recovery/wait primitives are handled elsewhere.
fn call_at(code: &[Tok], i: usize,
           accessors: &BTreeMap<String, String>)
           -> Option<(String, usize, usize)> {
    let (name_idx, method) =
        if code[i].kind == TokKind::Punct && code[i].text == "." {
            (i + 1, true)
        } else {
            (i, false)
        };
    let name = code.get(name_idx)?;
    if name.kind != TokKind::Ident {
        return None;
    }
    if !is_punct(code.get(name_idx + 1), "(") {
        return None;
    }
    let text = name.text.as_str();
    if KEYWORDS.contains(&text)
        || NEVER_CALL_EDGE.contains(&text)
        || accessors.contains_key(text)
        || matches!(text,
                    "lock" | "lock_recover" | "lock_recover_or"
                    | "wait_recover")
    {
        return None;
    }
    if !method {
        // `fn name(` is a definition; `.name(` was handled above.
        let prev = code.get(i.wrapping_sub(1));
        if prev.map_or(false, |p| {
            (p.kind == TokKind::Ident && p.text == "fn")
                || (p.kind == TokKind::Punct && p.text == ".")
        }) {
            return None;
        }
    }
    let (args, _, _close) = count_params(code, name_idx + 1);
    Some((name.text.clone(), args, name_idx + 2))
}

// ---------------------------------------------------------------------
// Interprocedural resolution (shared by the file and tree passes)
// ---------------------------------------------------------------------

/// Transitive acquire sets over the call graph, then the edges implied
/// by "call made while holding a guard". Functions are keyed by
/// (name, arity); same-keyed functions are unioned (conservative).
/// Returns (edges, call-into-held-class deadlocks).
fn resolve_calls(fns: &[FnFacts])
                 -> (Vec<((String, String), u32)>,
                     Vec<(String, u32)>) {
    resolve_calls_against(fns, fns)
}

/// Every elementary cycle in the lock-order graph, each reported once
/// as its list of consecutive edges. Detection: for each edge (a, b),
/// a shortest path b → a closes a cycle; canonical rotation dedupes.
fn find_cycles(edge_keys: &BTreeSet<(String, String)>)
               -> Vec<Vec<(String, String)>> {
    let mut adj: BTreeMap<&str, Vec<&str>> = BTreeMap::new();
    for (h, q) in edge_keys {
        adj.entry(h.as_str()).or_default().push(q.as_str());
    }
    let mut seen: BTreeSet<Vec<String>> = BTreeSet::new();
    let mut out = Vec::new();
    for (a, b) in edge_keys {
        let Some(path) = shortest_path(&adj, b, a) else { continue };
        let mut nodes: Vec<String> = vec![a.clone()];
        nodes.extend(path); // b, …, a
        nodes.pop(); // drop the repeated `a`
        // Canonical form: rotate so the smallest class leads.
        let min = nodes.iter().enumerate().min_by_key(|(_, n)| *n)
            .map(|(i, _)| i).unwrap_or(0);
        let key: Vec<String> =
            nodes[min..].iter().chain(nodes[..min].iter())
                .cloned().collect();
        if !seen.insert(key) {
            continue;
        }
        let mut legs = Vec::new();
        for w in 0..nodes.len() {
            let h = nodes[w].clone();
            let q = nodes[(w + 1) % nodes.len()].clone();
            legs.push((h, q));
        }
        out.push(legs);
    }
    out
}

/// Format the found cycles as diagnostics, each anchored at its first
/// edge's site and listing every edge's site as a deadlock trace.
fn cycle_diags(
    edges: &BTreeMap<(String, String), (String, u32)>,
    out: &mut Vec<Diagnostic>,
) {
    let keys: BTreeSet<(String, String)> =
        edges.keys().cloned().collect();
    for legs in find_cycles(&keys) {
        out.push(cycle_diag(&legs, edges));
    }
}

fn cycle_diag(
    legs: &[(String, String)],
    edges: &BTreeMap<(String, String), (String, u32)>,
) -> Diagnostic {
    let text: Vec<String> = legs
        .iter()
        .map(|k| {
            let (p, l) = &edges[k];
            format!("`{}` → `{}` ({p}:{l})", k.0, k.1)
        })
        .collect();
    let (path0, line0) = &edges[&legs[0]];
    Diagnostic {
        rule: "lock-order",
        path: path0.clone(),
        line: *line0,
        msg: format!(
            "lock-order cycle: {} — these acquisition orders oppose \
             each other and can deadlock under contention; pick one \
             global order",
            text.join(", ")
        ),
    }
}

fn shortest_path(
    adj: &BTreeMap<&str, Vec<&str>>,
    from: &str,
    to: &str,
) -> Option<Vec<String>> {
    use std::collections::VecDeque;
    let mut prev: BTreeMap<&str, &str> = BTreeMap::new();
    let mut q = VecDeque::from([from]);
    let mut visited = BTreeSet::from([from]);
    while let Some(n) = q.pop_front() {
        if n == to {
            let mut path = vec![to.to_string()];
            let mut cur = to;
            while cur != from {
                cur = prev[cur];
                path.push(cur.to_string());
            }
            path.reverse();
            return Some(path);
        }
        for &m in adj.get(n).into_iter().flatten() {
            if visited.insert(m) {
                prev.insert(m, n);
                q.push_back(m);
            }
        }
    }
    None
}

// ---------------------------------------------------------------------
// Tree-level pass
// ---------------------------------------------------------------------

/// Re-resolve every call against the whole tree's function table and
/// report what needed cross-file knowledge: lock-order cycles whose
/// edges span files (or rest on cross-file call resolution) and
/// call-into-held-class deadlocks the per-file pass could not see.
pub fn check_tree(files: &[FileFacts])
                  -> (Vec<Diagnostic>, TreeStats) {
    let mut diags = Vec::new();

    // Global edge map with file attribution + "derivable per-file".
    let mut edges: BTreeMap<(String, String), (String, u32, bool)> =
        BTreeMap::new();
    for f in files {
        for (h, q, line) in &f.edges {
            edges.insert((h.clone(), q.clone()),
                         (f.path.clone(), *line, true));
        }
    }
    let all_fns: Vec<FnFacts> =
        files.iter().flat_map(|f| f.fns.iter().cloned()).collect();
    for f in files {
        let (ce, deadlocks) = resolve_calls_against(&f.fns, &all_fns);
        for ((h, q), line) in ce {
            edges.entry((h, q)).or_insert((f.path.clone(), line,
                                           false));
        }
        for (class, line) in deadlocks {
            if f.call_deadlocks.contains(&(class.clone(), line)) {
                continue; // already reported per-file
            }
            diags.push(Diagnostic {
                rule: "lock-order",
                path: f.path.clone(),
                line,
                msg: format!(
                    "call acquires `{class}` (through the cross-file \
                     call graph) while a guard on `{class}` is held — \
                     re-entrant `Mutex` acquisition deadlocks"
                ),
            });
        }
    }

    // Cycles: skip ones fully derivable from a single file (the
    // per-file pass already reported them).
    let keys: BTreeSet<(String, String)> =
        edges.keys().cloned().collect();
    let sited: BTreeMap<(String, String), (String, u32)> = edges
        .iter()
        .map(|(k, (p, l, _))| (k.clone(), (p.clone(), *l)))
        .collect();
    let cycles = find_cycles(&keys);
    let n_cycles = cycles.len();
    for legs in cycles {
        let per_file_derivable = legs.iter().all(|k| {
            let (p, _, local) = &edges[k];
            *local && *p == edges[&legs[0]].0
        });
        if !per_file_derivable {
            diags.push(cycle_diag(&legs, &sited));
        }
    }

    let classes: BTreeSet<&String> = all_fns
        .iter()
        .flat_map(|f| f.acquires.iter().map(|(c, _)| c))
        .collect();
    let stats = TreeStats {
        fns: all_fns.len(),
        classes: classes.len(),
        edges: edges.len(),
        cycles: n_cycles,
    };
    (diags, stats)
}

/// Like `resolve_calls`, but `local` fns' calls resolve against the
/// whole tree's table (`global`).
fn resolve_calls_against(
    local: &[FnFacts],
    global: &[FnFacts],
) -> (Vec<((String, String), u32)>, Vec<(String, u32)>) {
    type Key = (String, usize);
    let mut acq: BTreeMap<Key, BTreeSet<String>> = BTreeMap::new();
    for f in global {
        let e = acq.entry((f.name.clone(), f.arity)).or_default();
        e.extend(f.acquires.iter().map(|(c, _)| c.clone()));
    }
    loop {
        let mut changed = false;
        for f in global {
            let key = (f.name.clone(), f.arity);
            let mut add = BTreeSet::new();
            for c in &f.calls {
                if let Some(s) = acq.get(&(c.callee.clone(), c.arity))
                {
                    add.extend(s.iter().cloned());
                }
            }
            let e = acq.entry(key).or_default();
            let before = e.len();
            e.extend(add);
            changed |= e.len() != before;
        }
        if !changed {
            break;
        }
    }
    let mut edges = Vec::new();
    let mut deadlocks = Vec::new();
    for f in local {
        for c in f.calls.iter().filter(|c| !c.held.is_empty()) {
            let Some(s) = acq.get(&(c.callee.clone(), c.arity)) else {
                continue;
            };
            for class in s {
                for h in &c.held {
                    if h == class {
                        deadlocks.push((class.clone(), c.line));
                    } else {
                        edges.push(((h.clone(), class.clone()),
                                    c.line));
                    }
                }
            }
        }
    }
    deadlocks.sort();
    deadlocks.dedup();
    (edges, deadlocks)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lint::check_source;

    fn diags(src: &str) -> Vec<Diagnostic> {
        check_source("rust/src/coordinator/fx.rs", src)
            .diags
            .into_iter()
            .filter(|d| {
                matches!(d.rule,
                         "lock-order" | "guard-blocking"
                         | "lock-recovery")
            })
            .collect()
    }

    #[test]
    fn self_deadlock_direct() {
        let d = diags(
            "fn f(s: &S) {\n\
             let a = lock_recover(&s.state);\n\
             let b = lock_recover(&s.state);\n\
             let _ = (a, b);\n\
             }\n",
        );
        assert!(d.iter().any(|d| d.rule == "lock-order"
                             && d.msg.contains("re-entrant")),
                "{d:?}");
    }

    #[test]
    fn opposite_orders_cycle_and_drop_releases() {
        let d = diags(
            "fn ab(s: &S) {\n\
             let a = lock_recover(&s.alpha);\n\
             let b = lock_recover(&s.beta);\n\
             let _ = (a, b);\n\
             }\n\
             fn ba(s: &S) {\n\
             let b = lock_recover(&s.beta);\n\
             drop(b);\n\
             let a = lock_recover(&s.alpha);\n\
             let _ = a;\n\
             }\n",
        );
        assert!(d.is_empty(), "drop() must break the edge: {d:?}");

        let d = diags(
            "fn ab(s: &S) {\n\
             let a = lock_recover(&s.alpha);\n\
             let b = lock_recover(&s.beta);\n\
             let _ = (a, b);\n\
             }\n\
             fn ba(s: &S) {\n\
             let b = lock_recover(&s.beta);\n\
             let a = lock_recover(&s.alpha);\n\
             let _ = (a, b);\n\
             }\n",
        );
        assert_eq!(
            d.iter().filter(|d| d.msg.contains("cycle")).count(),
            1,
            "{d:?}"
        );
    }

    #[test]
    fn interprocedural_edge_through_call_graph() {
        // f holds alpha and calls g; g locks beta. h does beta→alpha
        // directly. Cycle needs the call edge.
        let d = diags(
            "fn f(s: &S) {\n\
             let a = lock_recover(&s.alpha);\n\
             g(s);\n\
             let _ = a;\n\
             }\n\
             fn g(s: &S) {\n\
             let b = lock_recover(&s.beta);\n\
             let _ = b;\n\
             }\n\
             fn h(s: &S) {\n\
             let b = lock_recover(&s.beta);\n\
             let a = lock_recover(&s.alpha);\n\
             let _ = (a, b);\n\
             }\n",
        );
        assert_eq!(
            d.iter().filter(|d| d.msg.contains("cycle")).count(),
            1,
            "{d:?}"
        );
    }

    #[test]
    fn arity_separates_same_named_callees() {
        // `o.take()` (arity 0) must not resolve to `take(s, max)`
        // (arity 2), so no beta edge — and no cycle.
        let d = diags(
            "fn take(s: &S, max: usize) -> usize {\n\
             let b = lock_recover(&s.beta);\n\
             max\n\
             }\n\
             fn f(s: &S, o: &mut Option<u32>) {\n\
             let a = lock_recover(&s.alpha);\n\
             let _ = o.take();\n\
             let _ = a;\n\
             }\n\
             fn h(s: &S) {\n\
             let b = lock_recover(&s.beta);\n\
             let a = lock_recover(&s.alpha);\n\
             let _ = (a, b);\n\
             }\n",
        );
        assert!(d.iter().all(|d| !d.msg.contains("cycle")), "{d:?}");
    }

    #[test]
    fn guard_blocking_fires_and_condvar_own_guard_is_exempt() {
        let d = diags(
            "fn f(s: &S, tx: &Sender<u32>) {\n\
             let g = lock_recover(&s.state);\n\
             tx.send(1).ok();\n\
             drop(g);\n\
             }\n",
        );
        assert_eq!(
            d.iter().filter(|d| d.rule == "guard-blocking").count(),
            1, "{d:?}"
        );

        let d = diags(
            "fn f(s: &S) {\n\
             let mut st = lock_recover(&s.state);\n\
             st = wait_recover(&s.cv, st);\n\
             let _ = st;\n\
             }\n",
        );
        assert!(d.is_empty(),
                "wait on the guard's own lock is the protocol: {d:?}");

        // …but a *second* guard held across the wait is flagged.
        let d = diags(
            "fn f(s: &S) {\n\
             let other = lock_recover(&s.other);\n\
             let mut st = lock_recover(&s.state);\n\
             st = wait_recover(&s.cv, st);\n\
             let _ = (st, other);\n\
             }\n",
        );
        assert_eq!(
            d.iter().filter(|d| d.rule == "guard-blocking").count(),
            1, "{d:?}"
        );
    }

    #[test]
    fn accessor_call_is_an_acquisition() {
        let src = "\
impl S {
    fn live(&self) -> MutexGuard<'_, u32> {
        lock_recover(&self.liveness)
    }
    fn f(&self, tx: &Sender<u32>) {
        let lv = self.live();
        tx.send(1).ok();
        drop(lv);
    }
}
";
        let d = diags(src);
        assert!(
            d.iter().any(|d| d.rule == "guard-blocking"
                         && d.msg.contains("liveness")),
            "accessor guard must be tracked by class: {d:?}"
        );
    }

    #[test]
    fn lock_recovery_bans_raw_lock_outside_sync() {
        let d = diags("fn f(m: &Mutex<u32>) { let _ = m.lock(); }\n");
        assert_eq!(
            d.iter().filter(|d| d.rule == "lock-recovery").count(),
            1, "{d:?}"
        );
        // util/sync.rs itself is the one sanctioned home.
        let out = check_source(
            "rust/src/util/sync.rs",
            "fn f(m: &Mutex<u32>) { let _ = m.lock(); }\n",
        );
        assert!(out.diags.is_empty(), "{:?}", out.diags);
    }

    #[test]
    fn temp_guard_dies_at_statement_end() {
        // `s.board_lock().push(x)` then a send on the next statement:
        // the temporary guard must not leak across the `;`.
        let src = "\
impl S {
    fn board_lock(&self) -> MutexGuard<'_, Vec<u32>> {
        lock_recover_or(&self.board, || {})
    }
    fn f(&self, tx: &Sender<u32>) {
        self.board_lock().push(1);
        tx.send(1).ok();
    }
}
";
        let d = diags(src);
        assert!(d.is_empty(), "{d:?}");
    }

    #[test]
    fn tree_pass_sees_cross_file_cycles() {
        let a = check_source(
            "rust/src/coordinator/a.rs",
            "fn fa(s: &S) {\n\
             let a = lock_recover(&s.alpha);\n\
             let b = lock_recover(&s.beta);\n\
             let _ = (a, b);\n\
             }\n",
        );
        let b = check_source(
            "rust/src/coordinator/b.rs",
            "fn fb(s: &S) {\n\
             let b = lock_recover(&s.beta);\n\
             let a = lock_recover(&s.alpha);\n\
             let _ = (a, b);\n\
             }\n",
        );
        assert!(a.diags.is_empty() && b.diags.is_empty(),
                "each file alone is consistent: {:?} {:?}",
                a.diags, b.diags);
        let (diags, stats) = check_tree(&[a.facts, b.facts]);
        assert_eq!(stats.cycles, 1, "{diags:?}");
        assert_eq!(diags.len(), 1, "{diags:?}");
        assert!(diags[0].msg.contains("cycle"), "{diags:?}");
    }

    #[test]
    fn tree_pass_skips_cycles_already_reported_per_file() {
        let a = check_source(
            "rust/src/coordinator/a.rs",
            "fn ab(s: &S) {\n\
             let a = lock_recover(&s.alpha);\n\
             let b = lock_recover(&s.beta);\n\
             let _ = (a, b);\n\
             }\n\
             fn ba(s: &S) {\n\
             let b = lock_recover(&s.beta);\n\
             let a = lock_recover(&s.alpha);\n\
             let _ = (a, b);\n\
             }\n",
        );
        assert_eq!(
            a.diags.iter().filter(|d| d.msg.contains("cycle")).count(),
            1
        );
        let (diags, stats) = check_tree(&[a.facts]);
        assert_eq!(stats.cycles, 1);
        assert!(diags.is_empty(),
                "per-file cycle must not repeat at tree level: \
                 {diags:?}");
    }
}
