//! `repolint` — repo-native static analysis enforcing the invariants the
//! runtime tests can only sample.
//!
//! The headline guarantees of this codebase — bitwise-identical token
//! streams across thread counts, SIMD on/off, and evict/resume — rest on
//! conventions no compiler checks: counter-based per-sequence RNG,
//! injected `Clock` time, zero-warm-alloc arenas, disjoint-write
//! `SharedSlice` chunks, `// SAFETY:` obligations on every unsafe site,
//! one global lock order and one poisoned-lock recovery policy across
//! the fleet. This module walks `rust/` and `examples/` (skipping
//! `vendor/` and lint `fixtures/`) and enforces them as CI-gating
//! diagnostics. The lexical rules live in [`rules`]; the concurrency
//! pass (lock-order graph, guard-across-blocking-call, lock-recovery)
//! in [`concurrency`]; the hand-rolled lexer (comments/strings/
//! attributes aware, no external parser — the build is offline) in
//! [`lexer`].
//!
//! ## Annotation grammar
//!
//! * `// lint: allow(<rule>[, <rule>…]) — <reason>` — suppress the named
//!   rule(s) on the annotated line. Trailing on the offending line, or a
//!   standalone comment directly above it (it covers the next code
//!   line). The reason is **required**; an allow without one, naming an
//!   unknown rule, or matching no diagnostic is itself a diagnostic.
//! * `// lint: hot-region` … `// lint: end-hot-region` — fence a region
//!   for the `warm-alloc` rule (allocation constructors banned inside).
//! * `// lint: serve-region` … `// lint: end-serve-region` — fence a
//!   request-handling region for the `serve-no-unwrap` rule (panicking
//!   extractors banned inside; the rule runs only under
//!   `src/coordinator/`, `src/server/`, and `examples/`).
//!
//! Run as `cargo run --bin repolint` (exit 0 = clean); the meta-test in
//! this module keeps the live tree clean under plain `cargo test`.

pub mod concurrency;
pub mod lexer;
pub mod rules;

use std::fmt;
use std::path::Path;

use lexer::{Tok, TokKind};

/// One finding, pointing at `path:line`.
#[derive(Clone, Debug)]
pub struct Diagnostic {
    pub rule: &'static str,
    pub path: String,
    pub line: u32,
    pub msg: String,
}

impl fmt::Display for Diagnostic {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}:{}: [{}] {}", self.path, self.line, self.rule,
               self.msg)
    }
}

/// A parsed `lint: allow(...)` annotation (kept for reporting: repolint
/// prints the full allowlist so reviewers see every suppression and its
/// written reason).
#[derive(Clone, Debug)]
pub struct AllowEntry {
    pub path: String,
    /// Line of the annotation comment itself.
    pub line: u32,
    /// Code line the annotation covers.
    pub target: u32,
    pub rules: Vec<String>,
    pub reason: String,
}

/// Lexed file plus the line-level classification the rules consume.
pub struct FileCtx {
    pub path: String,
    /// Non-comment tokens, in order.
    pub code: Vec<Tok>,
    /// Inclusive line spans fenced by `lint: hot-region` markers.
    pub hot_regions: Vec<(u32, u32)>,
    /// Inclusive line spans fenced by `lint: serve-region` markers.
    pub serve_regions: Vec<(u32, u32)>,
    /// All tokens (comments included), for same-line comment scans.
    toks: Vec<Tok>,
    /// 1-based; true if any non-comment token touches the line.
    line_code: Vec<bool>,
    /// 1-based; true if the first code token on the line is `#`.
    line_attr: Vec<bool>,
}

impl FileCtx {
    pub fn diag(&self, rule: &'static str, line: u32,
                msg: impl Into<String>) -> Diagnostic {
        Diagnostic { rule, path: self.path.clone(), line,
                     msg: msg.into() }
    }

    pub fn line_has_code(&self, line: u32) -> bool {
        self.line_code.get(line as usize).copied().unwrap_or(false)
    }

    pub fn is_attr_line(&self, line: u32) -> bool {
        self.line_attr.get(line as usize).copied().unwrap_or(false)
    }

    /// Comment tokens whose span covers `line`.
    pub fn comments_on(&self, line: u32) -> Vec<&Tok> {
        self.toks
            .iter()
            .filter(|t| t.is_comment() && t.line <= line
                    && line <= t.end_line)
            .collect()
    }

    pub fn in_hot_region(&self, line: u32) -> bool {
        self.hot_regions.iter().any(|&(a, b)| a <= line && line <= b)
    }

    pub fn in_serve_region(&self, line: u32) -> bool {
        self.serve_regions.iter().any(|&(a, b)| a <= line && line <= b)
    }
}

/// Outcome of linting one source text.
pub struct FileOutcome {
    pub diags: Vec<Diagnostic>,
    pub allows: Vec<AllowEntry>,
    /// Lock-acquisition facts for the tree-level concurrency pass.
    pub facts: concurrency::FileFacts,
    /// `lock-order` allows that matched nothing per-file: cycle
    /// diagnostics can need cross-file facts, so their usefulness is
    /// decided by `run_tree`, not here.
    pub deferred: Vec<AllowEntry>,
}

/// Outcome of linting a tree.
pub struct Report {
    pub files: usize,
    pub diags: Vec<Diagnostic>,
    pub allows: Vec<AllowEntry>,
    /// Lock-order graph summary (classes / edges / cycles).
    pub stats: concurrency::TreeStats,
}

impl Report {
    pub fn clean(&self) -> bool {
        self.diags.is_empty()
    }
}

/// Lint one file's source. `path` is the repo-relative label used in
/// diagnostics and for the per-rule path exemptions (see [`rules`]).
pub fn check_source(path: &str, src: &str) -> FileOutcome {
    let toks = lexer::lex(src);
    let n_lines = src.lines().count().max(1) as u32;

    let mut line_code = vec![false; n_lines as usize + 2];
    let mut line_attr = vec![false; n_lines as usize + 2];
    let mut first_code_col = vec![u32::MAX; n_lines as usize + 2];
    for t in &toks {
        if t.is_comment() {
            continue;
        }
        for l in t.line..=t.end_line.min(n_lines) {
            line_code[l as usize] = true;
        }
        let l = t.line as usize;
        if t.col < first_code_col[l] {
            first_code_col[l] = t.col;
            line_attr[l] =
                t.kind == TokKind::Punct && t.text == "#";
        }
    }

    let mut diags = Vec::new();
    let mut allows: Vec<AllowEntry> = Vec::new();
    let mut hot_regions = Vec::new();
    let mut open_hot: Option<u32> = None;
    let mut serve_regions = Vec::new();
    let mut open_serve: Option<u32> = None;

    // ---- parse `lint:` directives out of the comments ----------------
    for t in toks.iter().filter(|t| t.is_comment()) {
        let text = t.comment_text();
        let trimmed = text.trim();
        let Some(rest) = trimmed.strip_prefix("lint:") else {
            continue;
        };
        let rest = rest.trim();
        if let Some(args) = rest.strip_prefix("allow(") {
            match parse_allow(args) {
                Ok((rule_names, reason)) => {
                    let mut bad = false;
                    for r in &rule_names {
                        if !rules::RULES.contains(&r.as_str()) {
                            diags.push(directive_diag(
                                path, t.line,
                                format!("unknown rule `{r}` in lint: \
                                         allow(...)"),
                            ));
                            bad = true;
                        }
                    }
                    if reason.is_empty() {
                        diags.push(directive_diag(
                            path, t.line,
                            "lint: allow(...) requires a written reason \
                             after an em-dash (`— <why>`)",
                        ));
                        bad = true;
                    }
                    if !bad {
                        let target = if line_code
                            .get(t.line as usize)
                            .copied()
                            .unwrap_or(false)
                        {
                            t.line
                        } else {
                            // Standalone comment: covers the next code
                            // line.
                            ((t.end_line + 1)..=n_lines)
                                .find(|&l| line_code[l as usize])
                                .unwrap_or(0)
                        };
                        allows.push(AllowEntry {
                            path: path.to_string(),
                            line: t.line,
                            target,
                            rules: rule_names,
                            reason,
                        });
                    }
                }
                Err(msg) => diags.push(directive_diag(path, t.line, msg)),
            }
        } else if rest.starts_with("end-hot-region") {
            match open_hot.take() {
                Some(open) => hot_regions.push((open, t.line)),
                None => diags.push(directive_diag(
                    path, t.line,
                    "lint: end-hot-region without an open hot-region",
                )),
            }
        } else if rest.starts_with("hot-region") {
            if open_hot.is_some() {
                diags.push(directive_diag(
                    path, t.line,
                    "nested lint: hot-region (close the previous fence \
                     first)",
                ));
            } else {
                open_hot = Some(t.line);
            }
        // `end-serve-region` must be tested before `serve-region` —
        // the latter is a prefix of the former.
        } else if rest.starts_with("end-serve-region") {
            match open_serve.take() {
                Some(open) => serve_regions.push((open, t.line)),
                None => diags.push(directive_diag(
                    path, t.line,
                    "lint: end-serve-region without an open serve-region",
                )),
            }
        } else if rest.starts_with("serve-region") {
            if open_serve.is_some() {
                diags.push(directive_diag(
                    path, t.line,
                    "nested lint: serve-region (close the previous fence \
                     first)",
                ));
            } else {
                open_serve = Some(t.line);
            }
        } else {
            diags.push(directive_diag(
                path, t.line,
                format!("unknown lint directive `{rest}`"),
            ));
        }
    }
    if let Some(open) = open_hot {
        diags.push(directive_diag(
            path, open,
            "lint: hot-region never closed (missing end-hot-region)",
        ));
    }
    if let Some(open) = open_serve {
        diags.push(directive_diag(
            path, open,
            "lint: serve-region never closed (missing end-serve-region)",
        ));
    }

    let ctx = FileCtx {
        path: path.to_string(),
        code: toks.iter().filter(|t| !t.is_comment()).cloned().collect(),
        hot_regions,
        serve_regions,
        toks,
        line_code,
        line_attr,
    };

    // ---- rules + the concurrency pass, then the allowlist ------------
    let mut raw = Vec::new();
    rules::run_all(&ctx, &mut raw);
    let analysis = concurrency::analyze(&ctx);
    raw.extend(analysis.diags);

    let mut used = vec![false; allows.len()];
    for d in raw {
        let hit = allows.iter().position(|a| {
            a.target == d.line && a.rules.iter().any(|r| r == d.rule)
        });
        match hit {
            Some(i) => used[i] = true,
            None => diags.push(d),
        }
    }
    let mut deferred = Vec::new();
    for (a, used) in allows.iter().zip(&used) {
        if *used {
            continue;
        }
        // Unmatched `lock-order` allows may suppress a tree-level
        // cycle diagnostic: their verdict belongs to `run_tree`.
        if a.rules.iter().any(|r| r == "lock-order") {
            deferred.push(a.clone());
            continue;
        }
        diags.push(directive_diag(
            path, a.line,
            format!("unused lint: allow({}) — nothing to suppress \
                     on line {}", a.rules.join(", "), a.target),
        ));
    }

    diags.sort_by(|a, b| (a.line, a.rule).cmp(&(b.line, b.rule)));
    FileOutcome { diags, allows, facts: analysis.facts, deferred }
}

fn directive_diag(path: &str, line: u32, msg: impl Into<String>)
                  -> Diagnostic {
    Diagnostic { rule: "lint-directive", path: path.to_string(), line,
                 msg: msg.into() }
}

/// Parse `<rule>[, <rule>…]) — <reason>` (the text after `allow(`).
fn parse_allow(args: &str) -> Result<(Vec<String>, String), String> {
    let close = args
        .find(')')
        .ok_or_else(|| "unclosed lint: allow(".to_string())?;
    let rule_names: Vec<String> = args[..close]
        .split(',')
        .map(|s| s.trim().to_string())
        .filter(|s| !s.is_empty())
        .collect();
    if rule_names.is_empty() {
        return Err("empty rule list in lint: allow()".to_string());
    }
    let reason = args[close + 1..]
        .trim_start()
        .trim_start_matches(['—', '–', '-', ':'])
        .trim()
        .to_string();
    Ok((rule_names, reason))
}

/// Lint every `.rs` file under `<root>/rust` and `<root>/examples`
/// (the trace-replay / fleet-smoke examples carry serve-path code),
/// skipping `vendor/` (third-party), `fixtures/` (intentionally-bad
/// lint test inputs) and build output; then run the tree-level
/// concurrency pass (cross-file lock-order cycles) over the collected
/// facts. Diagnostics are sorted `(path, line, rule)`.
pub fn run_tree(root: &Path) -> std::io::Result<Report> {
    let mut files = Vec::new();
    collect_rs(&root.join("rust"), &mut files)?;
    collect_rs(&root.join("examples"), &mut files)?;
    files.sort();
    let mut report =
        Report { files: files.len(), diags: Vec::new(),
                 allows: Vec::new(),
                 stats: concurrency::TreeStats::default() };
    let mut facts = Vec::new();
    let mut deferred = Vec::new();
    for f in &files {
        let bytes = std::fs::read(f)?;
        let src = String::from_utf8_lossy(&bytes);
        let label = f
            .strip_prefix(root)
            .unwrap_or(f)
            .to_string_lossy()
            .replace('\\', "/");
        let mut outcome = check_source(&label, &src);
        report.diags.append(&mut outcome.diags);
        report.allows.append(&mut outcome.allows);
        facts.push(outcome.facts);
        deferred.append(&mut outcome.deferred);
    }

    // ---- tree-level concurrency pass, with deferred allows -----------
    let (tree_diags, stats) = concurrency::check_tree(&facts);
    report.stats = stats;
    let mut used = vec![false; deferred.len()];
    for d in tree_diags {
        let hit = deferred.iter().position(|a| {
            a.path == d.path
                && a.target == d.line
                && a.rules.iter().any(|r| r == d.rule)
        });
        match hit {
            Some(i) => used[i] = true,
            None => report.diags.push(d),
        }
    }
    for (a, used) in deferred.iter().zip(&used) {
        if !used {
            report.diags.push(directive_diag(
                &a.path, a.line,
                format!("unused lint: allow({}) — nothing to suppress \
                         on line {}", a.rules.join(", "), a.target),
            ));
        }
    }
    report
        .diags
        .sort_by(|a, b| (a.path.clone(), a.line, a.rule)
                 .cmp(&(b.path.clone(), b.line, b.rule)));
    Ok(report)
}

fn collect_rs(dir: &Path, out: &mut Vec<std::path::PathBuf>)
              -> std::io::Result<()> {
    if !dir.is_dir() {
        return Ok(());
    }
    for entry in std::fs::read_dir(dir)? {
        let entry = entry?;
        let path = entry.path();
        let name = entry.file_name();
        let name = name.to_string_lossy();
        if path.is_dir() {
            if matches!(name.as_ref(),
                        "vendor" | "fixtures" | "target" | ".git")
            {
                continue;
            }
            collect_rs(&path, out)?;
        } else if name.ends_with(".rs") {
            out.push(path);
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn diags_of(path: &str, src: &str) -> Vec<Diagnostic> {
        check_source(path, src).diags
    }

    fn rules_hit(diags: &[Diagnostic]) -> Vec<&'static str> {
        let mut r: Vec<_> = diags.iter().map(|d| d.rule).collect();
        r.dedup();
        r
    }

    // ---- per-rule fixtures (bad must fire, good must be silent) ------

    #[test]
    fn unsafe_safety_fixtures() {
        let bad = include_str!("fixtures/unsafe_safety_bad.rs");
        let d = diags_of("rust/src/engine/fx.rs", bad);
        assert!(d.iter().any(|d| d.rule == "unsafe-safety"),
                "bad fixture must fire: {d:?}");
        // Expected lines are marked in the fixture with `MISSING` text.
        let flagged: Vec<u32> = d.iter()
            .filter(|d| d.rule == "unsafe-safety")
            .map(|d| d.line)
            .collect();
        assert_eq!(flagged.len(), 3, "{d:?}");

        let good = include_str!("fixtures/unsafe_safety_good.rs");
        let d = diags_of("rust/src/engine/fx.rs", good);
        assert!(d.iter().all(|d| d.rule != "unsafe-safety"),
                "good fixture must be silent: {d:?}");
    }

    #[test]
    fn clock_discipline_fixtures() {
        let bad = include_str!("fixtures/clock_bad.rs");
        let d = diags_of("rust/src/coordinator/fx.rs", bad);
        let hits =
            d.iter().filter(|d| d.rule == "clock-discipline").count();
        assert_eq!(hits, 3, "Instant::now + SystemTime + sleep: {d:?}");

        let good = include_str!("fixtures/clock_good.rs");
        let d = diags_of("rust/src/coordinator/fx.rs", good);
        assert!(d.is_empty(), "{d:?}");

        // The two clock-owning modules are exempt by path.
        let d = diags_of("rust/src/util/simclock.rs", bad);
        assert!(d.iter().all(|d| d.rule != "clock-discipline"));
    }

    #[test]
    fn rng_discipline_fixtures() {
        let bad = include_str!("fixtures/rng_bad.rs");
        let d = diags_of("rust/src/coordinator/fx.rs", bad);
        let hits =
            d.iter().filter(|d| d.rule == "rng-discipline").count();
        assert_eq!(hits, 4,
                   "constant (2 spellings) + entropy + struct lit: {d:?}");

        // kernels.rs and rng.rs are the sanctioned randomness sources.
        let d = diags_of("rust/src/engine/kernels.rs", bad);
        assert!(d.iter().all(|d| d.rule != "rng-discipline"));

        let good = include_str!("fixtures/rng_good.rs");
        let d = diags_of("rust/src/coordinator/fx.rs", good);
        assert!(d.is_empty(), "{d:?}");
    }

    #[test]
    fn warm_alloc_fixtures() {
        let bad = include_str!("fixtures/warm_alloc_bad.rs");
        let d = diags_of("rust/src/engine/fx.rs", bad);
        let hits: Vec<_> = d.iter()
            .filter(|d| d.rule == "warm-alloc")
            .collect();
        assert_eq!(hits.len(), 4,
                   "vec! + collect + format! + Box::new: {hits:?}");

        let good = include_str!("fixtures/warm_alloc_good.rs");
        let d = diags_of("rust/src/engine/fx.rs", good);
        assert!(d.is_empty(), "{d:?}");
    }

    #[test]
    fn det_iteration_fixtures() {
        let bad = include_str!("fixtures/det_iteration_bad.rs");
        let d = diags_of("rust/src/engine/fx.rs", bad);
        let hits =
            d.iter().filter(|d| d.rule == "det-iteration").count();
        assert_eq!(hits, 2, "HashMap + HashSet: {d:?}");

        // Outside engine/ the rule does not apply.
        let d = diags_of("rust/src/coordinator/fx.rs", bad);
        assert!(d.iter().all(|d| d.rule != "det-iteration"));

        let good = include_str!("fixtures/det_iteration_good.rs");
        let d = diags_of("rust/src/engine/fx.rs", good);
        assert!(d.is_empty(), "{d:?}");
    }

    #[test]
    fn serve_no_unwrap_fixtures() {
        let bad = include_str!("fixtures/serve_no_unwrap_bad.rs");
        let d = diags_of("rust/src/coordinator/fx.rs", bad);
        let hits =
            d.iter().filter(|d| d.rule == "serve-no-unwrap").count();
        assert_eq!(hits, 3,
                   "unwrap + expect + unwrap, fenced sites only: {d:?}");

        // Outside coordinator/ and server/ the rule does not apply.
        let d = diags_of("rust/src/engine/fx.rs", bad);
        assert!(d.iter().all(|d| d.rule != "serve-no-unwrap"), "{d:?}");

        // Non-panicking extraction, `unwrap_or*` spellings, and a
        // reasoned allow must all be silent.
        let good = include_str!("fixtures/serve_no_unwrap_good.rs");
        let d = diags_of("rust/src/server/fx.rs", good);
        assert!(d.is_empty(), "{d:?}");
    }

    #[test]
    fn lock_order_fixtures() {
        let bad = include_str!("fixtures/lock_order_bad.rs");
        let d = diags_of("rust/src/coordinator/fx.rs", bad);
        let hits = d.iter().filter(|d| d.rule == "lock-order").count();
        assert_eq!(hits, 2,
                   "one cycle + one re-entrant acquisition: {d:?}");
        assert!(d.iter().any(|d| d.msg.contains("cycle")), "{d:?}");
        assert!(d.iter().any(|d| d.msg.contains("re-entrant")),
                "{d:?}");

        let good = include_str!("fixtures/lock_order_good.rs");
        let d = diags_of("rust/src/coordinator/fx.rs", good);
        assert!(d.is_empty(), "{d:?}");
    }

    #[test]
    fn guard_blocking_fixtures() {
        let bad = include_str!("fixtures/guard_blocking_bad.rs");
        let d = diags_of("rust/src/coordinator/fx.rs", bad);
        let hits =
            d.iter().filter(|d| d.rule == "guard-blocking").count();
        assert_eq!(hits, 2,
                   "send under lock + wait with a second guard: {d:?}");

        let good = include_str!("fixtures/guard_blocking_good.rs");
        let d = diags_of("rust/src/coordinator/fx.rs", good);
        assert!(d.is_empty(), "{d:?}");
    }

    #[test]
    fn lock_recovery_fixtures() {
        let bad = include_str!("fixtures/lock_recovery_bad.rs");
        let d = diags_of("rust/src/coordinator/fx.rs", bad);
        let hits =
            d.iter().filter(|d| d.rule == "lock-recovery").count();
        assert_eq!(hits, 2,
                   "both raw `.lock()` spellings must fire: {d:?}");

        let good = include_str!("fixtures/lock_recovery_good.rs");
        let d = diags_of("rust/src/coordinator/fx.rs", good);
        assert!(d.is_empty(), "{d:?}");
    }

    #[test]
    fn serve_region_close_without_open_fires() {
        let src = "// lint: end-serve-region\nfn f() {}\n";
        let d = diags_of("rust/src/server/fx.rs", src);
        assert!(d.iter().any(|d| d.msg.contains("without an open")),
                "{d:?}");

        let src = "// lint: serve-region — fence\nfn f() {}\n";
        let d = diags_of("rust/src/server/fx.rs", src);
        assert!(d.iter().any(|d| d.msg.contains("never closed")),
                "{d:?}");
    }

    // ---- annotation grammar ------------------------------------------

    #[test]
    fn allow_suppresses_with_reason_trailing_and_standalone() {
        let src = "\
fn f() {
    let t = std::time::Instant::now(); // lint: allow(clock-discipline) — OS wait
    // lint: allow(clock-discipline) — startup stamp
    let u = std::time::Instant::now();
    let _ = (t, u);
}
";
        let out = check_source("rust/src/server/fx.rs", src);
        assert!(out.diags.is_empty(), "{:?}", out.diags);
        assert_eq!(out.allows.len(), 2);
        assert_eq!(out.allows[0].reason, "OS wait");
        assert_eq!(out.allows[1].target, 4);
    }

    #[test]
    fn allow_without_reason_is_a_diagnostic() {
        let src = "\
fn f() {
    let t = std::time::Instant::now(); // lint: allow(clock-discipline)
    let _ = t;
}
";
        let d = diags_of("rust/src/server/fx.rs", src);
        assert!(d.iter().any(|d| d.rule == "lint-directive"
                             && d.msg.contains("reason")), "{d:?}");
        // The underlying violation also still fires.
        assert!(d.iter().any(|d| d.rule == "clock-discipline"), "{d:?}");
    }

    #[test]
    fn unknown_rule_and_unused_allow_are_diagnostics() {
        let bad = include_str!("fixtures/directives_bad.rs");
        let d = diags_of("rust/src/server/fx.rs", bad);
        assert!(d.iter().any(|d| d.msg.contains("unknown rule")),
                "{d:?}");
        assert!(d.iter().any(|d| d.msg.contains("unused lint: allow")),
                "{d:?}");
        assert!(d.iter().any(|d| d.msg.contains("never closed")),
                "{d:?}");
    }

    #[test]
    fn hot_region_close_without_open_fires() {
        let src = "// lint: end-hot-region\nfn f() {}\n";
        let d = diags_of("rust/src/engine/fx.rs", src);
        assert!(d.iter().any(|d| d.msg.contains("without an open")),
                "{d:?}");
    }

    // ---- the meta-test: the live tree must be clean ------------------

    #[test]
    fn repolint_is_clean_on_the_live_tree() {
        let root = Path::new(env!("CARGO_MANIFEST_DIR"));
        let report = run_tree(root).expect("walk rust/ + examples/");
        assert!(report.files > 40,
                "walked only {} files — wrong root, or the examples/ \
                 walk regressed?", report.files);
        assert!(
            report.clean(),
            "repolint found {} diagnostic(s) on the live tree:\n{}",
            report.diags.len(),
            report.diags.iter().map(|d| d.to_string())
                .collect::<Vec<_>>().join("\n"),
        );
        // Every allowlist entry carries a written reason (enforced at
        // parse time, re-asserted here as the acceptance criterion).
        assert!(report.allows.iter().all(|a| !a.reason.is_empty()));
        // The concurrency pass saw the fleet's lock classes and found
        // a cycle-free order (the acceptance criterion for the
        // lock-order rule: zero cycles on the live tree).
        assert_eq!(report.stats.cycles, 0,
                   "lock-order cycles on the live tree");
        assert!(report.stats.classes >= 5 && report.stats.edges >= 1,
                "concurrency pass extracted implausibly few facts: \
                 {:?}", report.stats);
    }
}
