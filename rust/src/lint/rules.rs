//! The six lexical repo-native invariant rules (see `lint` module docs
//! for the invariant each one guards and README §"Correctness tooling"
//! for the annotation grammar). The three concurrency rules —
//! lock-order, guard-blocking, lock-recovery — live in
//! `lint::concurrency` and share this module's `RULES` registry and
//! token-sequence matcher.
//!
//! Every rule is a lexical pass over a [`FileCtx`]: code tokens with
//! line/column positions, per-line code/comment classification, and the
//! `// lint: hot-region` fences. Rules push raw [`Diagnostic`]s; the
//! runner in `lint::mod` applies the annotated allowlist afterwards, so
//! rules themselves never consult `allow` directives.

use crate::lint::lexer::{parse_int, Tok, TokKind};
use crate::lint::{Diagnostic, FileCtx};

/// Rule ids, as spelled inside `lint: allow(...)` annotations. The
/// first six are the lexical rules in this module; the last three are
/// the concurrency pass (`lint::concurrency`): lock-order (deadlock
/// cycles + re-entrant acquisition), guard-blocking (guard held across
/// a blocking call), and lock-recovery (raw `.lock()` outside
/// `util/sync.rs`).
pub const RULES: [&str; 9] = [
    "unsafe-safety",
    "clock-discipline",
    "rng-discipline",
    "warm-alloc",
    "det-iteration",
    "serve-no-unwrap",
    "lock-order",
    "guard-blocking",
    "lock-recovery",
];

/// RNG constants whose presence outside the sanctioned modules means a
/// parallel generator is being hand-rolled: the PCG-XSH-RR multiplier
/// and the three SplitMix64 finalizer/increment constants. Matched by
/// *value* (any radix / `_` spelling).
const RNG_CONSTANTS: [u128; 4] = [
    // PCG multiplier (0x5851f42d4c957f2d).
    6364136223846793005, // lint: allow(rng-discipline) — the rule's own match table, not a generator
    // SplitMix64 golden-ratio increment.
    0x9e3779b97f4a7c15, // lint: allow(rng-discipline) — the rule's own match table, not a generator
    // SplitMix64 finalizer round 1.
    0xbf58476d1ce4e5b9, // lint: allow(rng-discipline) — the rule's own match table, not a generator
    // SplitMix64 finalizer round 2.
    0x94d049bb133111eb, // lint: allow(rng-discipline) — the rule's own match table, not a generator
];

/// Identifiers that reach for OS entropy or nondeterministic seeding.
const ENTROPY_IDENTS: [&str; 5] =
    ["getrandom", "OsRng", "from_entropy", "thread_rng", "RandomState"];

/// Allocation constructors banned inside `// lint: hot-region` fences
/// (each pattern is a code-token sequence; `!` and `.` anchor macros and
/// method calls).
const ALLOC_PATTERNS: [&[&str]; 10] = [
    &["Vec", ":", ":", "new"],
    &["vec", "!"],
    &[".", "to_vec"],
    &[".", "collect"],
    &["format", "!"],
    &["Box", ":", ":", "new"],
    &["String", ":", ":", "from"],
    &["String", ":", ":", "new"],
    &[".", "to_string"],
    &[".", "to_owned"],
];

/// Run every rule that applies to `ctx.path` and append raw diagnostics.
pub fn run_all(ctx: &FileCtx, out: &mut Vec<Diagnostic>) {
    unsafe_safety(ctx, out);
    // Exempt: the two clock-owning util modules implement the `Clock`
    // sources themselves, and benches measure wall time by definition.
    if !path_is(ctx, &["util/simclock.rs", "util/bench.rs"])
        && !ctx.path.contains("benches/")
    {
        clock_discipline(ctx, out);
    }
    if !path_is(ctx, &["util/rng.rs", "engine/kernels.rs"]) {
        rng_discipline(ctx, out);
    }
    warm_alloc(ctx, out);
    if ctx.path.contains("src/engine/") {
        det_iteration(ctx, out);
    }
    if ctx.path.contains("src/coordinator/")
        || ctx.path.contains("src/server/")
        || ctx.path.starts_with("examples/")
    {
        serve_no_unwrap(ctx, out);
    }
}

fn path_is(ctx: &FileCtx, suffixes: &[&str]) -> bool {
    suffixes.iter().any(|s| ctx.path.ends_with(s))
}

/// Match `pat` against the code tokens starting at `i`: alphanumeric
/// pattern elements must be whole `Ident` tokens, single-char elements
/// `Punct` tokens. Shared with the concurrency pass.
pub(crate) fn seq_at(code: &[Tok], i: usize, pat: &[&str]) -> bool {
    if i + pat.len() > code.len() {
        return false;
    }
    pat.iter().enumerate().all(|(k, p)| {
        let t = &code[i + k];
        if p.chars().all(|c| c.is_ascii_alphanumeric() || c == '_') {
            t.kind == TokKind::Ident && t.text == *p
        } else {
            t.kind == TokKind::Punct && t.text == *p
        }
    })
}

/// **unsafe-safety** — every `unsafe` token (block, fn, or impl) must be
/// immediately preceded by a justification: a `// SAFETY:` comment (or a
/// `/// # Safety` doc section) in the contiguous comment/attribute block
/// directly above it, or an earlier same-line comment. Guards: the
/// hand-written aliasing contracts (`SharedSlice`, `ResidentPtr`) only
/// stay sound while every site states its obligation.
fn unsafe_safety(ctx: &FileCtx, out: &mut Vec<Diagnostic>) {
    for u in ctx.code.iter().filter(|t| {
        t.kind == TokKind::Ident && t.text == "unsafe"
    }) {
        if has_safety_justification(ctx, u) {
            continue;
        }
        out.push(ctx.diag(
            "unsafe-safety",
            u.line,
            "`unsafe` without an immediately-preceding `// SAFETY:` \
             comment (or `# Safety` doc section) stating the proof \
             obligation",
        ));
    }
}

fn comment_has_safety_marker(t: &Tok) -> bool {
    let text = t.comment_text();
    text.contains("SAFETY:") || text.contains("# Safety")
}

fn has_safety_justification(ctx: &FileCtx, u: &Tok) -> bool {
    // Same line, earlier column: `/* SAFETY: … */ unsafe { … }`.
    if ctx.comments_on(u.line).iter().any(|c| {
        c.col < u.col && comment_has_safety_marker(c)
    }) {
        return true;
    }
    // Scan the contiguous comment/attribute block directly above.
    let mut l = u.line.saturating_sub(1);
    while l >= 1 {
        if ctx.line_has_code(l) {
            if ctx.is_attr_line(l) {
                l -= 1;
                continue;
            }
            return false;
        }
        let comments = ctx.comments_on(l);
        if comments.is_empty() {
            return false; // blank line ends the block
        }
        if comments.iter().any(|c| comment_has_safety_marker(c)) {
            return true;
        }
        l -= 1;
    }
    false
}

/// **clock-discipline** — no raw `Instant::now` / `SystemTime` /
/// `thread::sleep` outside `util/simclock.rs`, `util/bench.rs` and the
/// wall-time-by-definition `benches/` harnesses: all
/// scheduler-visible time flows through the injected `Clock`, so the
/// virtual-time sim (`src/sim.rs`, `tests/sched_sim.rs`) can replay any
/// policy decision deterministically. Wall-time-by-necessity call sites
/// (OS timeouts, client-facing stamps) carry an allow annotation.
fn clock_discipline(ctx: &FileCtx, out: &mut Vec<Diagnostic>) {
    let code = &ctx.code;
    for i in 0..code.len() {
        if seq_at(code, i, &["Instant", ":", ":", "now"]) {
            out.push(ctx.diag(
                "clock-discipline",
                code[i].line,
                "raw `Instant::now()` — route through the injected \
                 `Clock` (util/simclock.rs) or allowlist with a reason",
            ));
        } else if seq_at(code, i, &["SystemTime"]) {
            out.push(ctx.diag(
                "clock-discipline",
                code[i].line,
                "`SystemTime` is wall time the sim cannot virtualize — \
                 use the injected `Clock` or allowlist with a reason",
            ));
        } else if seq_at(code, i, &["thread", ":", ":", "sleep"]) {
            out.push(ctx.diag(
                "clock-discipline",
                code[i].line,
                "raw `thread::sleep` — schedulable code must not block \
                 on wall time; allowlist only OS-level waits",
            ));
        }
    }
}

/// **rng-discipline** — outside `util/rng.rs` (the sequential PCG
/// streams) and `engine/kernels.rs` (the counter-based SplitMix64 noise
/// stream), no PCG/SplitMix construction and no OS-entropy calls: the
/// per-sequence counter streams must remain the only randomness source,
/// or bitwise evict/resume and thread-invariance break silently.
fn rng_discipline(ctx: &FileCtx, out: &mut Vec<Diagnostic>) {
    let code = &ctx.code;
    for i in 0..code.len() {
        let t = &code[i];
        if t.kind == TokKind::Num {
            if let Some(v) = parse_int(&t.text) {
                if RNG_CONSTANTS.contains(&v) {
                    out.push(ctx.diag(
                        "rng-discipline",
                        t.line,
                        "PCG/SplitMix64 constant outside util/rng.rs / \
                         engine/kernels.rs — a parallel generator is \
                         being hand-rolled",
                    ));
                }
            }
        } else if t.kind == TokKind::Ident
            && ENTROPY_IDENTS.contains(&t.text.as_str())
        {
            out.push(ctx.diag(
                "rng-discipline",
                t.line,
                "OS-entropy / nondeterministic seeding — all randomness \
                 must derive from seeded per-sequence streams",
            ));
        } else if seq_at(code, i, &["Pcg", "{"])
            // Not a literal when preceded by `>` (return type position),
            // `struct`, or `impl`.
            && (i == 0
                || !matches!(code[i - 1].text.as_str(),
                             ">" | "struct" | "impl"))
        {
            out.push(ctx.diag(
                "rng-discipline",
                t.line,
                "struct-literal `Pcg { .. }` bypasses the seeding \
                 discipline — use `Pcg::new` / `Pcg::with_stream`",
            ));
        }
    }
}

/// **warm-alloc** — inside `// lint: hot-region` fences no allocation
/// constructors: the statically-visible complement of the counting-
/// allocator gate (`tests/alloc_regression.rs`), which can only observe
/// the paths a given test run happens to execute.
fn warm_alloc(ctx: &FileCtx, out: &mut Vec<Diagnostic>) {
    if ctx.hot_regions.is_empty() {
        return;
    }
    let code = &ctx.code;
    for i in 0..code.len() {
        let line = code[i].line;
        if !ctx.in_hot_region(line) {
            continue;
        }
        for pat in ALLOC_PATTERNS {
            if seq_at(code, i, pat) {
                out.push(ctx.diag(
                    "warm-alloc",
                    line,
                    format!(
                        "`{}` inside a `lint: hot-region` fence — warm \
                         steps must be allocation-free (see \
                         tests/alloc_regression.rs)",
                        pat.join("")
                    ),
                ));
                break; // one diagnostic per token position
            }
        }
    }
}

/// **serve-no-unwrap** — inside `// lint: serve-region` fences (the
/// request-handling paths of `coordinator/` and `server/`), no
/// panicking extractors: a stray `.unwrap()` / `.expect(..)` turns a
/// bad request or a contained engine fault into a panic on the serving
/// thread — a dropped connection or a hung client — instead of an error
/// response. The `unwrap_or*` family never matches (each is a single
/// ident token distinct from `unwrap`); genuinely-infallible sites
/// carry a `lint: allow(serve-no-unwrap)` with the invariant written
/// out.
fn serve_no_unwrap(ctx: &FileCtx, out: &mut Vec<Diagnostic>) {
    if ctx.serve_regions.is_empty() {
        return;
    }
    let code = &ctx.code;
    for i in 0..code.len() {
        let line = code[i].line;
        if !ctx.in_serve_region(line) {
            continue;
        }
        for pat in [&[".", "unwrap"][..], &[".", "expect"][..]] {
            if seq_at(code, i, pat) {
                out.push(ctx.diag(
                    "serve-no-unwrap",
                    line,
                    format!(
                        "`{}` inside a `lint: serve-region` fence — \
                         request paths must answer errors, not panic \
                         the serving thread",
                        pat.join("")
                    ),
                ));
                break; // one diagnostic per token position
            }
        }
    }
}

/// **det-iteration** — no `HashMap`/`HashSet` in `engine/` code:
/// iteration order is seeded per-process, so any stream-affecting use
/// breaks bitwise reproducibility across runs. Index-ordered structures
/// (`Vec`, `VecDeque`, `BTreeMap`) only.
fn det_iteration(ctx: &FileCtx, out: &mut Vec<Diagnostic>) {
    for t in ctx.code.iter().filter(|t| {
        t.kind == TokKind::Ident
            && (t.text == "HashMap" || t.text == "HashSet")
    }) {
        out.push(ctx.diag(
            "det-iteration",
            t.line,
            format!(
                "`{}` in engine code — iteration order is seeded \
                 per-process; use an index-ordered structure (Vec, \
                 VecDeque, BTreeMap) or allowlist with a reason",
                t.text
            ),
        ));
    }
}
