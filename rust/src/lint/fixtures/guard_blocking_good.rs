//! Lint fixture (never compiled): the disciplined counterparts —
//! drop before send, condvar wait naming its own guard, statement
//! temporaries that die before the blocking call. Expected: silent.

use std::sync::Mutex;

pub struct S {
    state: Mutex<u32>,
    count: Mutex<u64>,
}

pub fn send_after_drop(s: &S, tx: &std::sync::mpsc::Sender<u32>) {
    let g = lock_recover(&s.state);
    let v = *g;
    drop(g);
    tx.send(v).ok();
}

pub fn wait_own_guard(s: &S, cv: &std::sync::Condvar) {
    let mut st = lock_recover(&s.state);
    while *st == 0 {
        st = wait_recover(cv, st);
    }
}

pub fn temp_guard_then_send(s: &S, tx: &std::sync::mpsc::Sender<u32>) {
    *lock_recover(&s.count) += 1;
    tx.send(0).ok();
}
