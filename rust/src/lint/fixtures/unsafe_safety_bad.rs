// Lint fixture (never compiled): three `unsafe` sites with MISSING
// justification, two correctly documented ones.
struct W(*mut u8);

unsafe impl Send for W {} // MISSING: no SAFETY comment anywhere above

fn f(w: &W) {
    let x = unsafe { *w.0 }; // MISSING: the comment above is prose
    // This comment talks about performance, not safety.
    let y = unsafe { *w.0.add(1) }; // MISSING: prose comment above

    // SAFETY: w.0 is valid for reads per the constructor contract.
    let z = unsafe { *w.0 };
    let _ = (x, y, z);
}

/// Reads a raw pointer.
///
/// # Safety
///
/// `p` must be valid for reads.
pub unsafe fn documented(p: *const u8) -> u8 {
    *p
}
