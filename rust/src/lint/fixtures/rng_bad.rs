// Lint fixture (never compiled): hand-rolled generator constants (two
// spellings of the same family), OS entropy, and a struct-literal Pcg.
use crate::util::rng::Pcg;

fn f(state: u64) -> u64 {
    let a = state.wrapping_mul(6364136223846793005);
    let b = a ^ 0x9e37_79b9_7f4a_7c15u64;
    b
}

fn g() -> u64 {
    let seed = getrandom();
    seed
}

fn h() -> Pcg {
    Pcg { state: 1, inc: 3 }
}
