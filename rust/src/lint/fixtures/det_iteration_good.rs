// Lint fixture (never compiled): index-ordered structures only.
use std::collections::{BTreeMap, VecDeque};

fn f(keys: &[u64]) -> u64 {
    let map: BTreeMap<u64, u64> =
        keys.iter().map(|&k| (k, k * 2)).collect();
    let q: VecDeque<u64> = keys.iter().copied().collect();
    let mut acc = 0;
    for (k, v) in &map {
        acc ^= k ^ v; // BTreeMap iterates in key order: deterministic
    }
    acc + q.len() as u64
    // Prose may mention HashMap / HashSet without firing.
}
