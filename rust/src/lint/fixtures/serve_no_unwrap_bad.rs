//! Fixture: `serve-no-unwrap` must fire on every panicking extractor
//! inside a `lint: serve-region` fence (3 hits below) and stay silent
//! on the same spellings outside the fence.

fn outside_the_fence() {
    let x: Option<u32> = Some(1);
    let _ = x.unwrap(); // not fenced: silent
}

// lint: serve-region — fixture fence
fn handle(req: Option<&str>) -> usize {
    let body = req.unwrap(); // MISSING
    let parsed: Result<usize, ()> = Ok(body.len());
    let n = parsed.expect("fixture"); // MISSING
    let m: Option<usize> = Some(n);
    m.unwrap() // MISSING
}
// lint: end-serve-region
