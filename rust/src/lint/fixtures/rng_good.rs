// Lint fixture (never compiled): the sanctioned ways to hold
// randomness outside util/rng.rs — seeded streams via the public API.
use crate::util::rng::Pcg;

fn f(seed: u64, request_index: u64) -> u64 {
    // Construction through the seeding API is the discipline; the
    // constants live in util/rng.rs (and the counter stream in
    // engine/kernels.rs) only.
    let mut root = Pcg::new(seed);
    let mut stream = Pcg::with_stream(seed, request_index);
    let mut child = root.split();
    // Mentions in strings/comments do not fire: "0x9e3779b97f4a7c15".
    stream.next_u64() ^ child.next_u64()
}

fn returns_are_not_struct_literals(p: &mut Pcg) -> Pcg {
    p.split()
}
