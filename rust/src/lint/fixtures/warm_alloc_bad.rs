// Lint fixture (never compiled): allocation constructors inside a
// hot-region fence — one diagnostic per construct.
fn step(xs: &mut Vec<u32>, n: usize) -> usize {
    // lint: hot-region
    let v = vec![0u32; n];
    let doubled: Vec<u32> = xs.iter().map(|x| x * 2).collect();
    let label = format!("step {n}");
    let boxed = Box::new(n);
    // lint: end-hot-region
    v.len() + doubled.len() + label.len() + *boxed
}

fn outside_the_fence_is_fine(n: usize) -> Vec<u32> {
    let mut v = Vec::new();
    v.resize(n, 0);
    v
}
