// Lint fixture (never compiled): injected-clock discipline, plus the
// constructs that must NOT fire (names in strings/comments, `Instant`
// as a type, an annotated wall-time site).
use std::time::Instant;

struct Stamp {
    at: Instant, // holding an Instant is fine; *reading the clock* isn't
}

fn f(clock: &dyn crate::util::simclock::Clock, s: &Stamp) -> f64 {
    // Instant::now() in prose does not fire; neither does the string:
    let _doc = "Instant::now() / thread::sleep belong in comments only";
    let t0 = clock.now();
    let _ = s;
    clock.now() - t0
}

fn g() {
    // OS-level timed wait: genuinely needs wall time.
    let t = Instant::now(); // lint: allow(clock-discipline) — fixture: OS timeout example
    let _ = t;
}
