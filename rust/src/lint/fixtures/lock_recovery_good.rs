//! Lint fixture (never compiled): the sanctioned spellings — the
//! recovery helpers, the counted-recovery variant, and a reasoned
//! allow as the escape hatch. Expected: silent.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

pub struct S {
    count: Mutex<u64>,
    board: Mutex<Vec<u32>>,
    poisoned: AtomicU64,
}

pub fn observe(s: &S) {
    *lock_recover(&s.count) += 1;
}

pub fn observe_counted(s: &S) {
    let mut g = lock_recover_or(&s.board, || {
        s.poisoned.fetch_add(1, Ordering::Relaxed);
    });
    g.push(1);
}

pub fn raw_with_reason(m: &Mutex<u32>) {
    // lint: allow(lock-recovery) — foreign guard type the helper cannot express
    let _ = m.lock();
}
