//! Lint fixture (never compiled): raw `.lock()` outside `util/sync.rs`
//! — both the panicking and the hand-rolled-recovery spelling drift
//! from the one recovery policy. Expected: exactly two `lock-recovery`
//! diagnostics.

use std::sync::Mutex;

pub struct S {
    state: Mutex<u32>,
}

pub fn observe(s: &S) {
    let mut g = s.state.lock().unwrap();
    *g += 1;
}

pub fn observe_recovering(s: &S) {
    let mut g = s.state.lock().unwrap_or_else(|e| e.into_inner());
    *g += 1;
}
