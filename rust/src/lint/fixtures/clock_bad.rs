// Lint fixture (never compiled): each banned wall-time construct once.
use std::time::Instant;

fn f() {
    let t = Instant::now();
    let s = std::time::SystemTime::now();
    std::thread::sleep(std::time::Duration::from_millis(1));
    let _ = (t, s);
}
