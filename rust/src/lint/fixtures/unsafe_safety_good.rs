// Lint fixture (never compiled): every unsafe site justified, in each
// supported position.
struct W(*mut u8);

// SAFETY: W is only handed to one thread at a time by the pool.
unsafe impl Send for W {}

fn f(w: &W) {
    // SAFETY: w.0 is valid for reads per the constructor contract,
    // and the comment block may span several lines.
    let x = unsafe { *w.0 };
    /* SAFETY: same-line block comment form. */ let y = unsafe { *w.0 };

    // SAFETY: attribute between the comment and the unsafe token is
    // fine — attributes are skipped by the upward scan.
    #[allow(clippy::identity_op)]
    let z = unsafe { *w.0.add(0) };
    let _ = (x, y, z);
}

/// Reads a raw pointer.
///
/// # Safety
///
/// `p` must be valid for reads.
pub unsafe fn documented(p: *const u8) -> u8 {
    *p
}

fn strings_and_comments_do_not_count_as_sites() {
    let _s = "unsafe { this is a string, not code }";
    // unsafe in prose: this comment mentions unsafe but is not a site.
}
