// Lint fixture (never compiled): seeded-order containers in engine
// code — iteration order would differ run to run.
fn f(keys: &[u64]) -> u64 {
    let set: std::collections::HashSet<u64> =
        keys.iter().copied().collect();
    let mut acc = 0;
    for k in &set {
        acc ^= k; // order-dependent fold: the actual hazard
    }
    let map = std::collections::HashMap::<u64, u64>::new();
    acc + map.len() as u64
}
