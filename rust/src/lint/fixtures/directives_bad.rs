// Lint fixture (never compiled): every way to get the annotation
// grammar wrong.
fn f() {
    // Unknown rule name:
    let a = 1; // lint: allow(no-such-rule) — reason present but rule bogus
    // Allow that suppresses nothing:
    let b = 2; // lint: allow(det-iteration) — nothing to suppress here
    let _ = (a, b);
}

fn g(n: usize) -> usize {
    // lint: hot-region
    n + 1
    // ... never closed: unbalanced fence diagnostic at the open line.
}
