//! Lint fixture (never compiled): guards held across blocking calls.
//! Expected: exactly two `guard-blocking` diagnostics — a channel send
//! under a lock, and a condvar wait with a *second* guard still held
//! (the waited-on guard itself is the protocol and exempt).

use std::sync::Mutex;

pub struct S {
    state: Mutex<u32>,
    other: Mutex<u32>,
}

pub fn send_under_lock(s: &S, tx: &std::sync::mpsc::Sender<u32>) {
    let g = lock_recover(&s.state);
    tx.send(*g).ok();
    drop(g);
}

pub fn wait_with_second_guard(s: &S, cv: &std::sync::Condvar) {
    let other = lock_recover(&s.other);
    let mut st = lock_recover(&s.state);
    st = wait_recover(cv, st);
    let _ = (st, other);
}
