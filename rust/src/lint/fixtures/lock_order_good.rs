//! Lint fixture (never compiled): every nesting follows one global
//! order (alpha before beta), an explicit `drop` releases before the
//! opposite-order site, and the accessor idiom is tracked by class.
//! Expected: silent.

use std::sync::{Mutex, MutexGuard};

pub struct S {
    alpha: Mutex<u32>,
    beta: Mutex<u32>,
}

impl S {
    fn alpha_lock(&self) -> MutexGuard<'_, u32> {
        lock_recover(&self.alpha)
    }

    pub fn ab(&self) {
        let a = self.alpha_lock();
        let b = lock_recover(&self.beta);
        let _ = (a, b);
    }

    pub fn ab_again(&self) {
        let a = lock_recover(&self.alpha);
        let b = lock_recover(&self.beta);
        let _ = (a, b);
    }

    // beta alone, after alpha is explicitly released: no edge.
    pub fn a_then_b(&self) {
        let a = self.alpha_lock();
        drop(a);
        let b = lock_recover(&self.beta);
        let _ = b;
    }
}
