//! Lint fixture (never compiled): opposite nested acquisition orders
//! and a re-entrant acquisition. Expected: exactly two `lock-order`
//! diagnostics — one cycle, one re-entrant deadlock.

use std::sync::Mutex;

pub struct S {
    alpha: Mutex<u32>,
    beta: Mutex<u32>,
}

pub fn ab(s: &S) {
    let a = lock_recover(&s.alpha);
    let b = lock_recover(&s.beta);
    let _ = (a, b);
}

// Opposite order: closes the alpha → beta → alpha cycle.
pub fn ba(s: &S) {
    let b = lock_recover(&s.beta);
    let a = lock_recover(&s.alpha);
    let _ = (a, b);
}

// Re-entrant acquisition: a guaranteed self-deadlock.
pub fn aa(s: &S) {
    let first = lock_recover(&s.alpha);
    let second = lock_recover(&s.alpha);
    let _ = (first, second);
}
