//! Fixture: fenced request-handling code that answers errors instead of
//! panicking must be silent — including the `unwrap_or*` family, whose
//! names merely contain "unwrap", and an allow-annotated site whose
//! infallibility invariant is written out.

// lint: serve-region — fixture fence
fn handle(req: Option<&str>) -> usize {
    let body = req.unwrap_or("");
    let n: Option<usize> = Some(body.len());
    let n = n.unwrap_or_else(|| 0);
    match Some(n) {
        Some(v) => v,
        None => 0,
    }
}

fn fixed_point(x: Option<u32>) -> u32 {
    // lint: allow(serve-no-unwrap) — fixture: caller guarantees Some
    x.unwrap()
}
// lint: end-serve-region
