// Lint fixture (never compiled): the arena idiom — warm steps reuse
// capacity (clear/resize/copy_from_slice), never construct.
fn step(arena: &mut Vec<u32>, scratch: &mut Vec<u32>, n: usize) -> u32 {
    // lint: hot-region
    arena.clear();
    arena.resize(n, 0);
    scratch.copy_from_slice(&arena[..scratch.len().min(n)]);
    let mut acc = 0u32;
    for &x in arena.iter() {
        acc = acc.wrapping_add(x);
    }
    // A string mentioning vec![] or format!() does not fire.
    let _doc = "vec![0; n] and format!() are banned here";
    // lint: end-hot-region
    acc
}

fn cold_setup(n: usize) -> Vec<u32> {
    // Outside any fence: allocation is fine (setup/retirement paths).
    let v = vec![0u32; n];
    v
}
