//! Exact likelihood of Algorithm 2 — Propositions 3.1 and C.2.
//!
//! The inner-loop target distribution shifts whenever a rejection occurs
//! (the non-causal conditioning gains the freshly revealed tokens), so the
//! likelihood of a full sequence naively sums over exponentially many
//! accept/reject paths. Prop. 3.1 gives an O(D^2) dynamic program over the
//! "last rejection position"; Prop. C.2 extends it with the rejection-count
//! posterior p(N^D | x, sigma) (one plus the number of rejections = the
//! number of forward passes Algorithm 2 spends on the sequence).
//!
//! Everything reduces (Lemma C.1) to per-position scalars under each
//! possible conditioning context c (= number of revealed tokens at the last
//! rejection):
//!
//!   accept mass  a(c, d) = min(p_c(x_d), q_c(x_d))
//!   reject mass  r(c, d) = max(0, q_c(x_d) - p_c(x_d))
//!
//! which `SpecTable` tabulates — either from closed-form mocks (tests) or
//! from D draft + D verify passes of a real model (`from_model`, batched
//! into the model's buckets).

use crate::engine::kernels::lse_f64;
use crate::engine::HybridModel;

const NEG_INF: f64 = f64::NEG_INFINITY;

/// Per-context / per-position probabilities of the *observed* tokens.
///
/// `p[c][d]` = draft probability of token x_sigma(d) when the non-causal
/// context is the first `c` ordering positions; `q[c][d]` = the causal
/// target probability with the same context (track `d-1`). Both are defined
/// for `d >= c`; entries below the diagonal are unused. The first-position
/// rule requires `q[0][0] == p[0][0]`.
#[derive(Clone, Debug)]
pub struct SpecTable {
    pub d: usize,
    pub p: Vec<Vec<f64>>,
    pub q: Vec<Vec<f64>>,
}

impl SpecTable {
    /// Tabulate from a model for a given sample and ordering. Runs D draft
    /// and D verify passes, chunked into the model's largest batch bucket
    /// (O(D) network passes total, as in Prop. 3.1).
    pub fn from_model<M: HybridModel>(model: &M, tokens: &[i32],
                                      sigma: &[i32]) -> SpecTable {
        let d = model.seq_len();
        let v = model.vocab();
        let mask = model.mask_id();
        assert_eq!(tokens.len(), d);
        assert_eq!(sigma.len(), d);
        let bucket = model.buckets().into_iter().max().unwrap_or(1);

        let mut p = vec![vec![0.0; d]; d];
        let mut q = vec![vec![0.0; d]; d];
        // Batched LSE tables, one slot per flat logits row of the chunk
        // (NaN = not yet normalized): every draft/verify row the chunk
        // scores has its log-sum-exp computed **exactly once**, even when
        // several scored tokens index the same row — the old loop called
        // the O(V) normalizer once per scored token.
        let mut dlse = vec![f64::NAN; bucket * d];
        let mut qlse = vec![f64::NAN; bucket * d];

        let contexts: Vec<usize> = (0..d).collect();
        for chunk in contexts.chunks(bucket) {
            // Build masked contexts: row r reveals the first chunk[r]
            // ordering positions.
            let mut masked = vec![mask; bucket * d];
            for (r, &c) in chunk.iter().enumerate() {
                for &posi in sigma.iter().take(c) {
                    masked[r * d + posi as usize] = tokens[posi as usize];
                }
            }
            let (state, draft_logits) = model.draft(&masked, bucket);
            let full: Vec<i32> = (0..bucket)
                .flat_map(|_| tokens.iter().copied())
                .collect();
            let sig: Vec<i32> = (0..bucket)
                .flat_map(|_| sigma.iter().copied())
                .collect();
            let target_logits = model.verify(&state, &full, &sig, bucket);

            // ---- batched LSE pass over every row this chunk reads ----
            dlse.iter_mut().for_each(|x| *x = f64::NAN);
            qlse.iter_mut().for_each(|x| *x = f64::NAN);
            for (r, &c) in chunk.iter().enumerate() {
                for dd in c..d {
                    let fl = r * d + sigma[dd] as usize;
                    if dlse[fl].is_nan() {
                        dlse[fl] = lse_f64(&draft_logits
                            [fl * v..fl * v + v]);
                    }
                    if dd > 0 {
                        let tl = r * d + (dd - 1);
                        if qlse[tl].is_nan() {
                            qlse[tl] = lse_f64(&target_logits
                                [tl * v..tl * v + v]);
                        }
                    }
                }
            }

            // ---- scoring pass: one scalar read + cached LSE per entry
            // (exp(l[tok] - lse) replaces the old softmax_row(row)[tok],
            // which allocated and normalized a V-length vector per entry).
            for (r, &c) in chunk.iter().enumerate() {
                for dd in c..d {
                    let pos = sigma[dd] as usize;
                    let tok = tokens[pos] as usize;
                    let fl = r * d + pos;
                    p[c][dd] = (draft_logits[fl * v + tok] as f64
                        - dlse[fl])
                        .exp();
                    if dd == 0 {
                        q[c][dd] = p[c][dd]; // first-position rule
                    } else {
                        let tl = r * d + (dd - 1);
                        q[c][dd] = (target_logits[tl * v + tok] as f64
                            - qlse[tl])
                            .exp();
                    }
                }
            }
        }
        SpecTable { d, p, q }
    }

    #[inline]
    fn ln_accept(&self, c: usize, d: usize) -> f64 {
        let a = self.p[c][d].min(self.q[c][d]);
        if a > 0.0 {
            a.ln()
        } else {
            NEG_INF
        }
    }

    #[inline]
    fn ln_reject(&self, c: usize, d: usize) -> f64 {
        let r = (self.q[c][d] - self.p[c][d]).max(0.0);
        if r > 0.0 {
            r.ln()
        } else {
            NEG_INF
        }
    }
}

fn log_sum_exp(xs: &[f64]) -> f64 {
    let m = xs.iter().copied().fold(NEG_INF, f64::max);
    if m == NEG_INF {
        return NEG_INF;
    }
    m + xs.iter().map(|x| (x - m).exp()).sum::<f64>().ln()
}

/// Prop. 3.1: log p(x^sigma(1:D) | sigma) under Algorithm 2, O(D^2).
pub fn log_likelihood(t: &SpecTable) -> f64 {
    let d = t.d;
    // acc[c][j] = sum_{l=c..j-1} ln a(c, l): log prob that positions c..j-1
    // are all accepted when the last rejection left context c.
    // Stored as prefix sums per context for O(1) range queries.
    let mut acc = vec![vec![0.0; d + 1]; d];
    for c in 0..d {
        for l in c..d {
            acc[c][l + 1] = acc[c][l] + t.ln_accept(c, l);
        }
    }
    // r[dd] = ln p(x^{1..dd}, R at ordering position dd-1) (1-indexed dd).
    let mut r = vec![NEG_INF; d + 1];
    let mut terms = Vec::with_capacity(d);
    for dd in 1..=d {
        terms.clear();
        // Last rejection before this one left context c = k-1; positions
        // k-1 .. dd-2 (0-indexed) accepted, position dd-1 rejected.
        for k in 1..=dd {
            let c = k - 1;
            let prev = if c == 0 { 0.0 } else { r[c] };
            if prev == NEG_INF {
                continue;
            }
            let a = acc[c][dd - 1] - acc[c][c]; // accepts c..dd-2
            let rej = t.ln_reject(c, dd - 1);
            terms.push(prev + a + rej);
        }
        r[dd] = log_sum_exp(&terms);
    }
    // Total: all-accept path + sum over last-rejection positions.
    let mut total = Vec::with_capacity(d + 1);
    total.push(acc[0][d] - acc[0][0]);
    for dd in 1..=d {
        if r[dd] == NEG_INF {
            continue;
        }
        let tail = if dd < d { acc[dd][d] - acc[dd][dd] } else { 0.0 };
        total.push(r[dd] + tail);
    }
    log_sum_exp(&total)
}

/// Simple-recursion oracle: walk positions left to right carrying the
/// current context (last rejection point); exponential-looking but
/// mathematically identical — used to validate the Prop. 3.1 decomposition.
pub fn brute_force_log_likelihood(t: &SpecTable) -> f64 {
    fn rec(t: &SpecTable, d: usize, c: usize) -> f64 {
        if d == t.d {
            return 1.0;
        }
        let a = t.p[c][d].min(t.q[c][d]);
        let r = (t.q[c][d] - t.p[c][d]).max(0.0);
        let mut total = 0.0;
        if a > 0.0 {
            total += a * rec(t, d + 1, c); // accept keeps the context
        }
        if r > 0.0 {
            total += r * rec(t, d + 1, d + 1); // reject resets it
        }
        total
    }
    rec(t, 0, 0).ln()
}

/// Prop. C.2: posterior p(N^D = n | x, sigma) over the number of
/// rejections, n = 0..D. Algorithm 2 spends (n + 1) draft passes on the
/// sequence, so this also gives the exact NFE posterior.
pub fn rejection_posterior(t: &SpecTable) -> Vec<f64> {
    let d = t.d;
    let mut acc = vec![vec![0.0; d + 1]; d];
    for c in 0..d {
        for l in c..d {
            acc[c][l + 1] = acc[c][l] + t.ln_accept(c, l);
        }
    }
    // rn[dd][n] = ln p(x^{1..dd}, R^{dd}, N = n).
    let mut rn = vec![vec![NEG_INF; d + 1]; d + 1];
    rn[0][0] = 0.0;
    for dd in 1..=d {
        for n in 1..=dd {
            let mut terms = Vec::new();
            for k in 1..=dd {
                let c = k - 1;
                let prev = rn[c][n - 1];
                if prev == NEG_INF {
                    continue;
                }
                let a = acc[c][dd - 1] - acc[c][c];
                let rej = t.ln_reject(c, dd - 1);
                terms.push(prev + a + rej);
            }
            rn[dd][n] = log_sum_exp(&terms);
        }
    }
    // p(x, N=n) = sum_{dd=0..D} rn[dd][n] * (all-accept tail from dd).
    let mut joint = vec![NEG_INF; d + 1];
    for n in 0..=d {
        let mut terms = Vec::new();
        for dd in 0..=d {
            if rn[dd][n] == NEG_INF {
                continue;
            }
            let tail = if dd < d { acc[dd][d] - acc[dd][dd] } else { 0.0 };
            terms.push(rn[dd][n] + tail);
        }
        joint[n] = log_sum_exp(&terms);
    }
    let z = log_sum_exp(&joint);
    joint.iter().map(|&j| (j - z).exp()).collect()
}

/// Brute-force oracle for the rejection-count joint (validation).
pub fn brute_force_rejection_posterior(t: &SpecTable) -> Vec<f64> {
    fn rec(t: &SpecTable, d: usize, c: usize, n: usize, w: f64,
           out: &mut [f64]) {
        if d == t.d {
            out[n] += w;
            return;
        }
        let a = t.p[c][d].min(t.q[c][d]);
        let r = (t.q[c][d] - t.p[c][d]).max(0.0);
        if a > 0.0 {
            rec(t, d + 1, c, n, w * a, out);
        }
        if r > 0.0 {
            rec(t, d + 1, d + 1, n + 1, w * r, out);
        }
    }
    let mut out = vec![0.0; t.d + 1];
    rec(t, 0, 0, 0, 1.0, &mut out);
    let z: f64 = out.iter().sum();
    out.iter_mut().for_each(|x| *x /= z);
    out
}

/// Monte-Carlo ELBO of Eq. 12: E_sigma[log p(x | sigma)] <= log p(x).
pub fn elbo<M: HybridModel>(model: &M, tokens: &[i32], n_orderings: usize,
                            rng: &mut crate::util::rng::Pcg) -> f64 {
    let d = model.seq_len();
    let mut acc = 0.0;
    for _ in 0..n_orderings {
        let sigma = rng.permutation(d);
        let table = SpecTable::from_model(model, tokens, &sigma);
        acc += log_likelihood(&table);
    }
    acc / n_orderings as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::mock::MockModel;
    use crate::engine::{speculative_sample, Prompt, SpecParams, Window};
    use crate::util::ptest::{self, Size};
    use crate::util::rng::Pcg;

    /// Random consistent table: arbitrary per-token probabilities in (0,1)
    /// with the first-position rule enforced.
    fn random_table(rng: &mut Pcg, d: usize) -> SpecTable {
        let mut p = vec![vec![0.0; d]; d];
        let mut q = vec![vec![0.0; d]; d];
        for c in 0..d {
            for dd in c..d {
                p[c][dd] = 0.05 + rng.f64() * 0.9;
                q[c][dd] = 0.05 + rng.f64() * 0.9;
            }
        }
        q[0][0] = p[0][0];
        SpecTable { d, p, q }
    }

    #[test]
    fn dp_matches_brute_force_property() {
        ptest::check(
            60,
            0x51ab,
            |rng: &mut Pcg, s: Size| random_table(rng, 2 + s.0.min(8)),
            |t| {
                let dp = log_likelihood(t);
                let bf = brute_force_log_likelihood(t);
                if (dp - bf).abs() < 1e-9 {
                    Ok(())
                } else {
                    Err(format!("dp {dp} != brute force {bf}"))
                }
            },
        );
    }

    #[test]
    fn rejection_posterior_matches_brute_force() {
        ptest::check(
            40,
            0xc2,
            |rng: &mut Pcg, s: Size| random_table(rng, 2 + s.0.min(7)),
            |t| {
                let dp = rejection_posterior(t);
                let bf = brute_force_rejection_posterior(t);
                for (a, b) in dp.iter().zip(&bf) {
                    if (a - b).abs() > 1e-9 {
                        return Err(format!("{dp:?} vs {bf:?}"));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn posterior_sums_to_one_and_consistent_with_likelihood() {
        let mut rng = Pcg::new(77);
        let t = random_table(&mut rng, 7);
        let post = rejection_posterior(&t);
        assert!((post.iter().sum::<f64>() - 1.0).abs() < 1e-9);
        // N = 0 requires the all-accept path: p(N=0|x) = exp(A - loglik).
        let all_accept: f64 =
            (0..7).map(|l| t.p[0][l].min(t.q[0][l]).ln()).sum();
        let expect = (all_accept - log_likelihood(&t)).exp();
        assert!((post[0] - expect).abs() < 1e-9);
    }

    #[test]
    fn all_accept_when_q_equals_p() {
        // target == draft: rejection mass is zero everywhere, so the
        // likelihood is the plain product of draft probabilities and
        // p(N=0) = 1.
        let d = 5;
        let mut rng = Pcg::new(3);
        let mut t = random_table(&mut rng, d);
        t.q = t.p.clone();
        let expect: f64 = (0..d).map(|l| t.p[0][l].ln()).sum();
        assert!((log_likelihood(&t) - expect).abs() < 1e-9);
        let post = rejection_posterior(&t);
        assert!((post[0] - 1.0).abs() < 1e-9);
    }

    #[test]
    fn from_model_table_shape_and_first_position_rule() {
        let m = MockModel::new(6, 4, 21);
        let tokens = vec![0, 1, 2, 3, 0, 1];
        let mut rng = Pcg::new(4);
        let sigma = rng.permutation(6);
        let t = SpecTable::from_model(&m, &tokens, &sigma);
        assert_eq!(t.d, 6);
        assert!((t.q[0][0] - t.p[0][0]).abs() < 1e-12);
        for c in 0..6 {
            for dd in c..6 {
                assert!(t.p[c][dd] > 0.0 && t.p[c][dd] < 1.0);
                assert!(t.q[c][dd] > 0.0 && t.q[c][dd] < 1.0);
            }
        }
    }

    /// End-to-end statistical check: empirical sampling frequencies of
    /// Algorithm 2 (window = D, one verify pass per draft) must match the
    /// Prop. 3.1 likelihood for every outcome of a tiny model.
    #[test]
    fn sampler_frequencies_match_likelihood() {
        let d = 4;
        let v = 2;
        let m = MockModel::new(d, v, 123);
        let sigma: Vec<i32> = vec![2, 0, 3, 1];
        let params = SpecParams {
            window: Window::Constant(d),
            n_verify: 1,
            sigma: Some(sigma.clone()),
            ..Default::default()
        };
        let n_samples = 40_000;
        let mut counts = std::collections::HashMap::new();
        let mut rng = Pcg::new(9);
        for _ in 0..n_samples {
            let (s, _) = speculative_sample(&m, &[Prompt::empty(d)], &params,
                                            &mut rng);
            *counts.entry(s[0].tokens.clone()).or_insert(0usize) += 1;
        }
        // Compare every outcome with >= 100 observations.
        for (tokens, count) in counts {
            if count < 100 {
                continue;
            }
            let t = SpecTable::from_model(&m, &tokens, &sigma);
            let model_p = log_likelihood(&t).exp();
            let emp = count as f64 / n_samples as f64;
            let sd = (model_p * (1.0 - model_p) / n_samples as f64).sqrt();
            assert!(
                (emp - model_p).abs() < 5.0 * sd + 1e-3,
                "tokens {tokens:?}: empirical {emp:.4} vs model {model_p:.4}"
            );
        }
    }

    /// The rejection-count posterior must predict the sampler's observed
    /// rejection counts conditioned on the produced sequence.
    #[test]
    fn rejection_posterior_matches_sampler() {
        let d = 3;
        let m = MockModel::new(d, 2, 55);
        let sigma: Vec<i32> = vec![1, 2, 0];
        let params = SpecParams {
            window: Window::Constant(d),
            n_verify: 1,
            sigma: Some(sigma.clone()),
            ..Default::default()
        };
        let mut rng = Pcg::new(10);
        // Conditioned on the most frequent outcome.
        let mut by_outcome: std::collections::HashMap<Vec<i32>, Vec<usize>> =
            Default::default();
        for _ in 0..30_000 {
            let (s, _) = speculative_sample(&m, &[Prompt::empty(d)], &params,
                                            &mut rng);
            by_outcome
                .entry(s[0].tokens.clone())
                .or_default()
                .push(s[0].rejected);
        }
        let (tokens, rejs) =
            by_outcome.into_iter().max_by_key(|(_, v)| v.len()).unwrap();
        let t = SpecTable::from_model(&m, &tokens, &sigma);
        let post = rejection_posterior(&t);
        let n = rejs.len() as f64;
        for nn in 0..=d {
            let emp = rejs.iter().filter(|&&r| r == nn).count() as f64 / n;
            let sd = (post[nn] * (1.0 - post[nn]) / n).sqrt();
            assert!(
                (emp - post[nn]).abs() < 5.0 * sd + 2e-2,
                "N={nn}: empirical {emp:.3} vs posterior {:.3}",
                post[nn]
            );
        }
    }
}
