//! Experiment harness support: quality-vs-NFE sweeps and table formatting.
//!
//! Every table/figure harness in `examples/` follows the paper's recipe
//! (Sec. 5.1/5.2): trace a metric-NFE trade-off curve by sweeping sampler
//! settings (Table 3/4), then read metrics off at fixed NFE levels by
//! linear interpolation between the two nearest points (Table 1 caption).

use anyhow::Result;

use crate::coordinator::EngineModel;
use crate::coordinator::SamplerChoice;
use crate::engine::{MdmParams, Prompt, SpecParams, Window};
use crate::runtime::{Manifest, PjrtModel, Runtime};
use crate::util::rng::Pcg;

/// Load + compile a set of models for single-threaded harness use. The
/// returned `Runtime` must outlive the models only notionally (executables
/// hold their own client handle) but is returned to make lifetimes obvious.
pub fn load_models(artifacts: &str, names: &[&str])
                   -> Result<(Runtime, Manifest,
                              std::collections::BTreeMap<String, PjrtModel>)> {
    let manifest = Manifest::load(artifacts)?;
    let runtime = Runtime::cpu()?;
    let mut map = std::collections::BTreeMap::new();
    for name in names {
        let entry = manifest.model(name)?;
        eprintln!("[harness] compiling '{name}' (buckets {:?})",
                  entry.buckets);
        map.insert(name.to_string(), runtime.load_model(entry)?);
    }
    Ok((runtime, manifest, map))
}

/// One point of a quality-NFE curve: samples generated at some setting.
#[derive(Clone, Debug)]
pub struct CurvePoint {
    pub label: String,
    pub nfe: f64,
    /// Flattened samples, `n_samples` rows of `seq_len`.
    pub samples: Vec<i32>,
    pub n_samples: usize,
    pub accept_rate: f64,
}

/// Generate `n_samples` with a sampler setting, batching through the
/// model's largest bucket, and average the per-sample NFE.
pub fn run_point(model: &dyn EngineModel, sampler: &SamplerChoice,
                 label: &str, n_samples: usize, seed: u64)
                 -> Result<CurvePoint> {
    let d = model.seq_len();
    let bucket = model.max_bucket();
    let mut rng = Pcg::new(seed);
    let mut samples = Vec::with_capacity(n_samples * d);
    let mut nfe_acc = 0.0;
    let mut acc = 0usize;
    let mut rej = 0usize;
    let mut produced = 0;
    while produced < n_samples {
        let n = bucket.min(n_samples - produced);
        let prompts = vec![Prompt::empty(d); n];
        let out = model.sample(&prompts, sampler, &mut rng)?;
        for s in out {
            nfe_acc += s.nfe;
            acc += s.accepted;
            rej += s.rejected;
            samples.extend_from_slice(&s.tokens);
            produced += 1;
        }
    }
    let decided = (acc + rej).max(1);
    Ok(CurvePoint {
        label: label.to_string(),
        nfe: nfe_acc / n_samples as f64,
        samples,
        n_samples,
        accept_rate: acc as f64 / decided as f64,
    })
}

/// The paper's speculative sweep: (n_verify, dtau) setting pairs
/// (Table 3 for text8, Table 4 for OpenWebText).
pub fn spec_sweep(model: &dyn EngineModel,
                  settings: &[(usize, f64)], n_samples: usize, seed: u64)
                  -> Result<Vec<CurvePoint>> {
    let mut out = Vec::new();
    for &(n_verify, dtau) in settings {
        let sampler = SamplerChoice::Speculative(SpecParams {
            window: Window::Cosine { dtau },
            n_verify,
            ..Default::default()
        });
        let label = format!("spec n={n_verify} dtau={dtau}");
        out.push(run_point(model, &sampler, &label, n_samples, seed)?);
    }
    Ok(out)
}

/// MDM baseline sweep over timestep counts.
pub fn mdm_sweep(model: &dyn EngineModel, steps_list: &[usize],
                 n_samples: usize, seed: u64) -> Result<Vec<CurvePoint>> {
    let mut out = Vec::new();
    for &steps in steps_list {
        let sampler =
            SamplerChoice::Mdm(MdmParams { steps, temperature: 1.0 });
        out.push(run_point(model, &sampler, &format!("mdm K={steps}"),
                           n_samples, seed)?);
    }
    Ok(out)
}

/// Linear interpolation of a metric at a fixed NFE level (Table 1 caption:
/// "values at each NFE are read off by linearly interpolating between the
/// two nearest points"). Points need not be sorted. Returns None if `nfe`
/// is outside the curve's range.
pub fn interp_at(points: &[(f64, f64)], nfe: f64) -> Option<f64> {
    let mut pts: Vec<(f64, f64)> = points.to_vec();
    pts.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
    if pts.is_empty() || nfe < pts[0].0 - 1e-9
        || nfe > pts[pts.len() - 1].0 + 1e-9
    {
        return None;
    }
    for w in pts.windows(2) {
        let (x0, y0) = w[0];
        let (x1, y1) = w[1];
        if nfe >= x0 - 1e-9 && nfe <= x1 + 1e-9 {
            if (x1 - x0).abs() < 1e-12 {
                return Some(y0);
            }
            let t = (nfe - x0) / (x1 - x0);
            return Some(y0 + t * (y1 - y0));
        }
    }
    Some(pts[pts.len() - 1].1)
}

/// Headline metric of the paper: the NFE reduction factor of the
/// speculative curve vs the baseline at matched quality. For each baseline
/// point whose quality lies inside the speculative curve's range, find the
/// speculative NFE achieving the same quality (interpolating NFE as a
/// function of quality) and average the ratios. Assumes quality improves
/// with NFE for both curves.
pub fn nfe_reduction(spec: &[(f64, f64)], baseline: &[(f64, f64)])
                     -> Option<f64> {
    // Build quality -> NFE mapping for the speculative curve.
    let q_to_nfe: Vec<(f64, f64)> =
        spec.iter().map(|&(nfe, q)| (q, nfe)).collect();
    let mut ratios = Vec::new();
    for &(b_nfe, b_q) in baseline {
        if let Some(s_nfe) = interp_at(&q_to_nfe, b_q) {
            if s_nfe > 0.0 {
                ratios.push(b_nfe / s_nfe);
            }
        }
    }
    if ratios.is_empty() {
        None
    } else {
        Some(ratios.iter().sum::<f64>() / ratios.len() as f64)
    }
}

/// Markdown-ish aligned table printer shared by the harnesses.
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(header: &[&str]) -> Table {
        Table {
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        self.rows.push(cells);
    }

    pub fn print(&self) {
        let mut widths: Vec<usize> =
            self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                if i < widths.len() {
                    widths[i] = widths[i].max(c.len());
                }
            }
        }
        let fmt_row = |cells: &[String]| {
            let mut line = String::from("|");
            for (i, c) in cells.iter().enumerate() {
                let w = widths.get(i).copied().unwrap_or(c.len());
                line.push_str(&format!(" {c:<w$} |"));
            }
            line
        };
        println!("{}", fmt_row(&self.header));
        let sep: Vec<String> =
            widths.iter().map(|w| "-".repeat(*w)).collect();
        println!("{}", fmt_row(&sep));
        for row in &self.rows {
            println!("{}", fmt_row(row));
        }
    }
}

pub fn fmt_f(x: f64, prec: usize) -> String {
    format!("{x:.prec$}")
}

pub fn fmt_opt(x: Option<f64>, prec: usize) -> String {
    x.map(|v| fmt_f(v, prec)).unwrap_or_else(|| "-".into())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::mock::MockModel;

    #[test]
    fn interp_basic() {
        let pts = [(1.0, 10.0), (3.0, 30.0), (2.0, 20.0)];
        assert!((interp_at(&pts, 2.5).unwrap() - 25.0).abs() < 1e-9);
        assert!((interp_at(&pts, 1.0).unwrap() - 10.0).abs() < 1e-9);
        assert_eq!(interp_at(&pts, 0.5), None);
        assert_eq!(interp_at(&pts, 3.5), None);
    }

    #[test]
    fn run_point_counts_and_shapes() {
        let m = MockModel::new(8, 4, 3);
        let p = run_point(&m, &SamplerChoice::default(), "x", 5, 1).unwrap();
        assert_eq!(p.n_samples, 5);
        assert_eq!(p.samples.len(), 40);
        assert!(p.nfe > 0.0);
        assert!(p.accept_rate > 0.0 && p.accept_rate <= 1.0);
    }

    #[test]
    fn sweeps_produce_points() {
        let m = MockModel::new(8, 4, 3);
        let s = spec_sweep(&m, &[(1, 0.02), (2, 0.1)], 3, 1).unwrap();
        assert_eq!(s.len(), 2);
        let md = mdm_sweep(&m, &[2, 8], 3, 1).unwrap();
        assert_eq!(md.len(), 2);
        assert!(md[0].nfe <= 2.0 + 1e-9);
    }

    #[test]
    fn table_prints() {
        let mut t = Table::new(&["a", "bb"]);
        t.row(vec!["1".into(), "2".into()]);
        t.print(); // smoke: no panic
    }
}
