//! Engine hot-path benchmarks (mock model — isolates L3 coordinator cost
//! from PJRT execution, which `benches/runtime.rs` measures separately).
//!
//! These are the §Perf numbers for the speculative sampling loop itself:
//! draft-token sampling, accept/reject sweeps, residual resampling, and the
//! Prop. 3.1 likelihood DP.

use ssmd::engine::{
    mdm_sample, speculative_sample, MdmParams, MockModel, Prompt, SpecParams,
    Window,
};
use ssmd::likelihood::{log_likelihood, rejection_posterior, SpecTable};
use ssmd::util::bench::{bench, print_header, print_result};
use ssmd::util::rng::Pcg;

fn main() {
    print_header("engine (mock model, D=64 V=256)");
    let model = MockModel::new(64, 256, 7);

    for (label, n_verify, dtau) in [
        ("spec n_verify=1 dtau=0.02", 1usize, 0.02),
        ("spec n_verify=4 dtau=0.083", 4, 0.083),
    ] {
        let params = SpecParams {
            window: Window::Cosine { dtau },
            n_verify,
            ..Default::default()
        };
        let mut rng = Pcg::new(1);
        let prompts = vec![Prompt::empty(64); 16];
        let r = bench(label, 2, 5, 1.0, || {
            let _ = speculative_sample(&model, &prompts, &params, &mut rng);
        });
        print_result(&r);
        println!("    -> {:.0} samples/s", r.throughput(16.0));
    }

    {
        let params = MdmParams { steps: 32, temperature: 1.0 };
        let mut rng = Pcg::new(2);
        let prompts = vec![Prompt::empty(64); 16];
        let r = bench("mdm K=32", 2, 5, 1.0, || {
            let _ = mdm_sample(&model, &prompts, &params, &mut rng);
        });
        print_result(&r);
        println!("    -> {:.0} samples/s", r.throughput(16.0));
    }

    print_header("likelihood (Prop 3.1 / C.2, D=64)");
    let tokens: Vec<i32> = (0..64).map(|i| (i * 7) % 256).collect();
    let mut rng = Pcg::new(3);
    let sigma = rng.permutation(64);
    let table = SpecTable::from_model(&model, &tokens, &sigma);
    print_result(&bench("SpecTable::from_model", 1, 3, 0.5, || {
        let _ = SpecTable::from_model(&model, &tokens, &sigma);
    }));
    print_result(&bench("log_likelihood DP", 10, 50, 0.5, || {
        let _ = log_likelihood(&table);
    }));
    print_result(&bench("rejection_posterior DP", 5, 20, 0.5, || {
        let _ = rejection_posterior(&table);
    }));
}
