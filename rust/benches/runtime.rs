//! PJRT runtime benchmarks: draft / verify executable latency per batch
//! bucket. This is the L2-side cost the coordinator amortizes via dynamic
//! batching; per-token cost falling with bucket size is what makes the
//! batcher worthwhile. Skips gracefully if `artifacts/` is missing.

use ssmd::engine::HybridModel;
use ssmd::harness;
use ssmd::util::args::Args;
use ssmd::util::bench::{bench, print_header, print_result};
use ssmd::util::rng::Pcg;

fn main() {
    let args = Args::from_env();
    let artifacts = args.str("artifacts", "artifacts");
    if !std::path::Path::new(&artifacts).join("manifest.json").exists() {
        println!("(runtime bench skipped: no {artifacts}/manifest.json — \
                  run `make artifacts`)");
        return;
    }
    let (_rt, _m, models) = match harness::load_models(&artifacts, &["owt"]) {
        Ok(x) => x,
        Err(e) => {
            println!("(runtime bench skipped: {e})");
            return;
        }
    };
    let model = &models["owt"];
    let d = model.seq_len();
    let v = model.vocab() as i32;
    let mut rng = Pcg::new(5);

    print_header("pjrt runtime (owt)");
    for bucket in model.buckets() {
        let tokens: Vec<i32> = (0..bucket * d)
            .map(|_| rng.below(v as usize) as i32)
            .collect();
        let r = bench(&format!("draft b{bucket}"), 3, 10, 1.0, || {
            std::hint::black_box(model.draft(&tokens, bucket));
        });
        print_result(&r);
        println!("    -> {:.0} tokens/s",
                 r.throughput((bucket * d) as f64));

        let (state, _) = model.draft(&tokens, bucket);
        let sigma: Vec<i32> = (0..bucket)
            .flat_map(|_| rng.permutation(d))
            .collect();
        let r = bench(&format!("verify b{bucket}"), 3, 10, 1.0, || {
            std::hint::black_box(model.verify(&state, &tokens, &sigma,
                                              bucket));
        });
        print_result(&r);
        println!("    -> {:.0} tokens/s",
                 r.throughput((bucket * d) as f64));
    }
}
