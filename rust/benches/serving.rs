//! End-to-end serving benchmark: full coordinator path (queue -> batcher ->
//! engine thread -> PJRT) under concurrent load, across batcher settings.
//! The paper's efficiency claim is NFE; this bench translates it into the
//! serving currency (samples/s, p50/p95 latency) on this testbed.
//! Skips gracefully if `artifacts/` is missing.

use std::time::{Duration, Instant};

use ssmd::coordinator::{
    BatcherConfig, Coordinator, EngineModel, GenRequest, ModelMap,
    SamplerChoice,
};
use ssmd::engine::{MdmParams, SpecParams, Window};
use ssmd::util::args::Args;
use ssmd::util::bench::{fmt_duration, print_header, summarize};

fn factory(artifacts: String)
           -> impl FnOnce() -> anyhow::Result<ModelMap> + Send {
    move || {
        let manifest = ssmd::runtime::Manifest::load(&artifacts)?;
        let runtime = ssmd::runtime::Runtime::cpu()?;
        let mut map = ModelMap::new();
        map.insert(
            "owt".to_string(),
            Box::new(runtime.load_model(manifest.model("owt")?)?)
                as Box<dyn EngineModel>,
        );
        Ok(map)
    }
}

fn drive(c: &Coordinator, sampler: SamplerChoice, clients: usize,
         reqs: usize) -> (Vec<f64>, f64) {
    let started = Instant::now();
    let mut handles = Vec::new();
    for cl in 0..clients {
        let cc = c.clone();
        let s = sampler.clone();
        handles.push(std::thread::spawn(move || {
            let mut lat = Vec::new();
            for r in 0..reqs {
                let t = Instant::now();
                cc.generate(GenRequest {
                    model: "owt".into(),
                    n_samples: 1,
                    sampler: s.clone(),
                    seed: (cl * 100 + r) as u64,
                    ..Default::default()
                })
                .unwrap();
                lat.push(t.elapsed().as_secs_f64());
            }
            lat
        }));
    }
    let mut all = Vec::new();
    for h in handles {
        all.extend(h.join().unwrap());
    }
    (all, started.elapsed().as_secs_f64())
}

fn main() {
    let args = Args::from_env();
    let artifacts = args.str("artifacts", "artifacts");
    if !std::path::Path::new(&artifacts).join("manifest.json").exists() {
        println!("(serving bench skipped: no {artifacts}/manifest.json — \
                  run `make artifacts`)");
        return;
    }
    let clients = args.usize("clients", 4);
    let reqs = args.usize("requests", 4);

    print_header("end-to-end serving (owt, concurrent clients)");
    for (label, wait_ms) in [("batch-wait 0ms", 0u64), ("batch-wait 10ms", 10)]
    {
        let c = Coordinator::start(
            factory(artifacts.clone()),
            BatcherConfig {
                max_wait: Duration::from_millis(wait_ms),
                ..Default::default()
            },
        )
        .unwrap();
        for (name, sampler) in [
            (
                "speculative",
                SamplerChoice::Speculative(SpecParams {
                    window: Window::Cosine { dtau: 0.05 },
                    n_verify: 2,
                    ..Default::default()
                }),
            ),
            ("mdm K=32",
             SamplerChoice::Mdm(MdmParams { steps: 32, temperature: 1.0 })),
        ] {
            let (lat, wall) = drive(&c, sampler, clients, reqs);
            let r = summarize(&format!("{label} {name}"), lat.clone());
            println!(
                "{:<40} p50 {:>9} p95 {:>9}  {:>7.2} samples/s",
                r.name,
                fmt_duration(r.p50_s),
                fmt_duration(r.p95_s),
                lat.len() as f64 / wall
            );
        }
        c.shutdown();
    }
}
