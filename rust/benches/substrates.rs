//! Substrate micro-benchmarks: the from-scratch utility layers that sit on
//! the request path (softmax/categorical, JSON codec, oracle scorers,
//! histogram observe). Regressions here show up as coordinator overhead.

use ssmd::engine::softmax::{log_softmax_row, softmax_row};
use ssmd::oracle::{spelling_accuracy, unigram_entropy};
use ssmd::util::bench::{bench, print_header, print_result};
use ssmd::util::json::Json;
use ssmd::util::metrics::Histogram;
use ssmd::util::rng::Pcg;

fn main() {
    print_header("substrates");
    let mut rng = Pcg::new(1);
    let logits: Vec<f32> = (0..256).map(|_| rng.f64() as f32 * 8.0).collect();

    print_result(&bench("softmax_row V=256", 100, 1000, 0.5, || {
        std::hint::black_box(softmax_row(&logits));
    }));
    print_result(&bench("log_softmax_row V=256", 100, 1000, 0.5, || {
        std::hint::black_box(log_softmax_row(&logits));
    }));

    let probs = softmax_row(&logits);
    print_result(&bench("categorical V=256", 100, 1000, 0.5, || {
        std::hint::black_box(rng.categorical(&probs));
    }));
    print_result(&bench("permutation D=1024", 20, 200, 0.5, || {
        std::hint::black_box(rng.permutation(1024));
    }));

    let payload = format!(
        r#"{{"model":"owt","n":4,"samples":[{}]}}"#,
        (0..64).map(|i| i.to_string()).collect::<Vec<_>>().join(",")
    );
    print_result(&bench("json parse (api req)", 100, 1000, 0.5, || {
        std::hint::black_box(Json::parse(&payload).unwrap());
    }));
    let v = Json::parse(&payload).unwrap();
    print_result(&bench("json serialize", 100, 1000, 0.5, || {
        std::hint::black_box(v.to_string());
    }));

    let sample: Vec<i32> = (0..4096).map(|_| rng.below(27) as i32).collect();
    let lexicon: Vec<String> =
        (0..500).map(|i| format!("word{i}")).collect();
    print_result(&bench("spelling_accuracy 64x64", 10, 100, 0.5, || {
        std::hint::black_box(spelling_accuracy(&sample, 64, &lexicon));
    }));
    print_result(&bench("unigram_entropy 64x64", 10, 100, 0.5, || {
        std::hint::black_box(unigram_entropy(&sample, 64));
    }));

    let h = Histogram::default();
    print_result(&bench("histogram observe", 100, 1000, 0.2, || {
        h.observe(0.0123);
    }));
}
